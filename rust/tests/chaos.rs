//! Chaos suite (PR 7): seeded fault injection against the job
//! lifecycle. Gated on the `failpoints` feature and run with
//! `--test-threads=1` in CI (`cargo test --features failpoints --test
//! chaos -- --test-threads=1`) because the failpoint registry is
//! process-global.
//!
//! Every scenario asserts the robustness invariants, not scenario
//! specifics: no wedged waiters (every `wait` returns), the admission
//! budget drains to zero, occupancy gauges settle, terminal states are
//! legal, and checkpointed retries are bit-identical to uninterrupted
//! runs.

#![cfg(feature = "failpoints")]

use snowball::coordinator::{
    Backend, Coordinator, CoordinatorConfig, JobCtl, JobSpec, JobState, ReplicaScheduler, Service,
};
use snowball::engine::{Mode, Schedule, SelectorKind};
use snowball::failpoint;
use snowball::graph::generators;
use snowball::problems::MaxCut;
use snowball::rng::StatelessRng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Teardown hygiene: whatever a test armed (fired or not) is cleared
/// even when the test itself panics.
struct DisarmGuard;
impl Drop for DisarmGuard {
    fn drop(&mut self) {
        failpoint::disarm_all();
    }
}

/// Tiny seeded generator for churn decisions (the suite must be
/// reproducible; no entropy from time or thread order).
struct Lcg(u64);
impl Lcg {
    fn next(&mut self) -> u64 {
        // splitmix64 step — plenty for churn decisions.
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

fn spec(label: &str, seed: u64, steps: u64) -> JobSpec {
    let rng = StatelessRng::new(seed);
    let p = MaxCut::new(generators::erdos_renyi(40, 150, &[-1, 1], &rng));
    JobSpec {
        model: Arc::new(p.model().clone()),
        label: label.into(),
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 5.0, t1: 0.05 },
        steps,
        replicas: 2,
        seed,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
        budget_ms: 0,
        max_retries: 0,
        backend: Backend::Native,
        portfolio: None,
    }
}

fn key(v: &[snowball::coordinator::ReplicaResult]) -> Vec<(u32, i64, u64)> {
    v.iter().map(|r| (r.replica, r.best_energy, r.flips)).collect()
}

/// Random cancels and a deadline storm against one coordinator:
/// whatever order the preemptions land in, every job reaches a legal
/// terminal state, the lifecycle counters account for every job
/// exactly once, and the admission budget + occupancy gauges drain to
/// zero.
#[test]
fn seeded_cancel_and_deadline_storm_conserves_accounting() {
    let _guard = DisarmGuard;
    let coord = Coordinator::start_with(CoordinatorConfig {
        workers: 2,
        max_inflight_replicas: 4,
        ..Default::default()
    });
    let mut lcg = Lcg(0xC4A0_5);
    const JOBS: usize = 18;
    let mut ids = Vec::new();
    let mut victims = Vec::new();
    for j in 0..JOBS {
        let slow = lcg.next() % 3 == 0;
        let mut sp = spec(&format!("storm-{j}"), 900 + j as u64, if slow { 50_000_000 } else { 2_000 });
        // Slow jobs always carry a tight budget so the storm drains
        // even if their cancel loses the race.
        sp.budget_ms = if slow { 10 + lcg.next() % 20 } else { 0 };
        let id = coord.submit(sp);
        if lcg.next() % 2 == 0 {
            victims.push(id);
        }
        ids.push(id);
    }
    for &v in &victims {
        // Cancel returning false is fine — the job may already be
        // terminal; the verdict just must match the observed state.
        let accepted = coord.cancel(v);
        let state = coord.state(v).expect("submitted job has a state");
        assert!(accepted || state.is_terminal(), "cancel refused a live job {v}: {state:?}");
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut tallies = (0u64, 0u64, 0u64); // done, cancelled, timed_out
    for &id in &ids {
        // No wedged waiters: every wait returns (None only for Failed,
        // which nothing in this storm injects).
        let r = coord.wait(id).expect("storm jobs never Fail");
        match coord.state(id).expect("terminal state persists") {
            JobState::Done => {
                assert!(r.completed);
                tallies.0 += 1;
            }
            JobState::Cancelled => {
                assert!(!r.completed);
                tallies.1 += 1;
            }
            JobState::TimedOut => {
                assert!(!r.completed);
                tallies.2 += 1;
            }
            other => panic!("illegal terminal state {other:?}"),
        }
    }
    let m = &coord.metrics;
    assert_eq!(m.get("jobs_done"), tallies.0);
    assert_eq!(m.get("jobs_cancelled"), tallies.1);
    assert_eq!(m.get("jobs_timed_out"), tallies.2);
    assert_eq!(m.get("jobs_failed"), 0);
    assert_eq!(tallies.0 + tallies.1 + tallies.2, JOBS as u64, "a job escaped the tally");
    assert_eq!(coord.committed_weight(), 0, "admission budget leaked");
    assert_eq!(m.gauge("jobs_running"), 0);
    assert_eq!(m.gauge("jobs_queued"), 0);
    assert_eq!(m.gauge("replicas_inflight"), 0);
    coord.shutdown();
}

/// A replica killed before it runs (`pool.run` failpoint) is retried
/// and the job completes — bit-identical to a fault-free run, since
/// the retry replays the same stateless-RNG trajectory.
#[test]
fn injected_pool_panic_is_retried_and_completes_bit_identically() {
    let _guard = DisarmGuard;
    let clean = ReplicaScheduler::new(1).run_native(&spec("clean", 77, 6_000));
    let coord = Coordinator::start(1);
    let mut sp = spec("faulted", 77, 6_000);
    sp.max_retries = 1;
    failpoint::arm_panic("pool.run", 0);
    let id = coord.submit(sp);
    let r = coord.wait(id).expect("retried job completes");
    assert_eq!(coord.state(id), Some(JobState::Done));
    assert!(r.completed);
    assert_eq!(key(&r.replicas), key(&clean), "retry diverged from the fault-free run");
    assert_eq!(coord.metrics.get("jobs_retried"), 1);
    assert_eq!(coord.metrics.get("jobs_failed"), 0);
    coord.shutdown();
}

/// The acceptance scenario: a replica killed *mid-run* right after its
/// first journaled checkpoint (`engine.checkpoint` failpoint) resumes
/// from that checkpoint and finishes bit-identical to an uninterrupted
/// run — both against a checkpointing-but-healthy control and against
/// a plain run with no journal at all.
#[test]
fn injected_checkpoint_panic_resumes_bit_identically() {
    let _guard = DisarmGuard;
    let sched = ReplicaScheduler::new(1);
    let mut sp = spec("ckpt", 31, 16_000); // stride 2000: 7 checkpoints fire
    sp.replicas = 1;
    let plain = sched.run_native(&sp);

    let mut healthy_ctl = JobCtl::unmanaged();
    healthy_ctl.max_retries = 1;
    let healthy = sched.try_run_native_ctl(&sp, &healthy_ctl).expect("healthy run");

    let mut faulted_ctl = JobCtl::unmanaged();
    faulted_ctl.max_retries = 1;
    failpoint::arm_panic("engine.checkpoint", 0); // dies right after checkpoint #1
    let faulted = sched.try_run_native_ctl(&sp, &faulted_ctl).expect("retry survives the kill");

    assert_eq!(faulted_ctl.journal.retries(), 1, "exactly one retry");
    assert!(
        faulted_ctl.journal.checkpoint(0).is_some(),
        "the resumed attempt keeps journaling"
    );
    assert_eq!(key(&faulted), key(&healthy), "resume diverged from healthy checkpointed run");
    assert_eq!(key(&faulted), key(&plain), "resume diverged from the plain engine run");
}

/// A shard lane killed mid-broadcast (`mailbox.post`) or at the epoch
/// barrier (`gate.arrive`) aborts the gate — siblings unwind instead of
/// wedging — and the sharded replica is retried from scratch (sharded
/// runs don't checkpoint) to a well-formed result, promptly.
#[test]
fn sharded_lane_panic_unwinds_the_gate_and_retries() {
    let _guard = DisarmGuard;
    let sched = ReplicaScheduler::new(2);
    for (site, skip) in [("mailbox.post", 8), ("gate.arrive", 4)] {
        let mut sp = spec("lanes", 64, 2_000);
        sp.replicas = 1;
        sp.shards = 4;
        let mut ctl = JobCtl::unmanaged();
        ctl.max_retries = 1;
        failpoint::arm_panic(site, skip);
        let t0 = Instant::now();
        let out = sched.try_run_native_ctl(&sp, &ctl).expect("lane panic must be retried");
        assert!(t0.elapsed() < Duration::from_secs(30), "{site}: siblings wedged at the gate");
        assert_eq!(ctl.journal.retries(), 1, "{site}: exactly one retry");
        assert_eq!(out.len(), 1);
        assert!(out[0].flips > 0, "{site}: retried replica made no progress");
    }
}

/// With the retry budget exhausted the injected fault surfaces as a
/// clean job failure: `wait` returns `None`, the state names the
/// failpoint, and the coordinator keeps serving later jobs.
#[test]
fn retry_budget_exhaustion_fails_the_job_cleanly() {
    let _guard = DisarmGuard;
    let coord = Coordinator::start(1);
    failpoint::arm_panic("pool.run", 0);
    let doomed = coord.submit(spec("doomed", 5, 2_000)); // max_retries = 0
    assert!(coord.wait(doomed).is_none(), "failed jobs yield no result");
    match coord.state(doomed) {
        Some(JobState::Failed(msg)) => {
            assert!(msg.contains("failpoint pool.run fired"), "payload lost: {msg}")
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    assert_eq!(coord.metrics.get("jobs_failed"), 1);
    assert_eq!(coord.committed_weight(), 0, "failure leaked admission budget");
    // The coordinator is unharmed: the next job completes normally.
    let next = coord.submit(spec("after", 6, 2_000));
    assert!(coord.wait(next).is_some());
    assert_eq!(coord.state(next), Some(JobState::Done));
    coord.shutdown();
}

/// Client hang-up churn: clients park in `WAIT` on long jobs and
/// vanish. The waiter gauge settles to zero (no leaked handler state),
/// a surviving connection cancels everything, and nothing wedges.
#[test]
fn client_hangup_churn_leaves_no_wedged_waiters() {
    let _guard = DisarmGuard;
    let coord = Coordinator::start(2);
    let metrics = coord.metrics.clone();
    let addr = Service::bind(coord.clone(), "127.0.0.1:0").unwrap().serve_in_background();
    let mut ids = Vec::new();
    for c in 0..4u64 {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        writeln!(s, "SOLVE instance=er:64:256 steps=2000000000 replicas=2 seed={}", 70 + c)
            .unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        ids.push(id);
        writeln!(s, "WAIT id={id}").unwrap();
        std::thread::sleep(Duration::from_millis(10));
        drop((s, r)); // hang up mid-WAIT
    }
    let t0 = Instant::now();
    while metrics.gauge("service_waiters") != 0 && t0.elapsed() < Duration::from_secs(30) {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(metrics.gauge("service_waiters"), 0, "abandoned waiters leaked");
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    for id in &ids {
        writeln!(s, "CANCEL id={id}").unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("CANCELLED id={id}"));
    }
    for &id in &ids {
        assert!(coord.wait(id).is_some(), "cancelled job {id} wedged");
        assert_eq!(coord.state(id), Some(JobState::Cancelled));
    }
    assert_eq!(coord.committed_weight(), 0);
    coord.shutdown();
}
