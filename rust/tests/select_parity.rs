//! Bit-identity of the Fenwick-tree Mode II selection path against the
//! legacy linear prefix scan (PR 2 tentpole): same modes, datapaths,
//! schedules and seeds must give exactly the same runs — same flip
//! sequence, same counters, same spins — because the Fenwick path only
//! reorganizes *how* the identical lane weights are summed and searched.

use snowball::engine::{
    Datapath, EngineConfig, Mode, Schedule, SelectorKind, SnowballEngine, StepOutcome,
};
use snowball::graph::generators;
use snowball::ising::{IsingModel, SpinVec};
use snowball::problems::MaxCut;
use snowball::rng::{salt, StatelessRng};

/// The observable run signature the acceptance criterion names, plus the
/// exact spin configurations.
type Signature = (i64, i64, u64, u64, u64, Vec<i8>, Vec<i8>);

fn run_signature(
    model: &IsingModel,
    mode: Mode,
    dp: Datapath,
    selector: SelectorKind,
    schedule: Schedule,
    steps: u64,
    seed: u64,
) -> Signature {
    let cfg = EngineConfig {
        mode,
        datapath: dp,
        selector,
        schedule,
        steps,
        seed,
        planes: None,
        trace_stride: 0,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
    };
    let mut e = SnowballEngine::new(model, cfg);
    let r = e.run();
    (
        r.best_energy,
        r.final_energy,
        r.flips,
        r.fallbacks,
        r.nulls,
        r.best_spins.to_spins(),
        r.final_spins.to_spins(),
    )
}

/// A sparse instance with nonzero external fields (so the `u = J·s + h`
/// folding is exercised, not just the Max-Cut `h == 0` special case).
fn sparse_instance(seed: u64) -> IsingModel {
    let rng = StatelessRng::new(seed);
    let g = generators::erdos_renyi(96, 400, &[-1, 1], &rng);
    let mut m = MaxCut::new(g).model().clone();
    for i in 0..m.len() {
        let h = rng.below(8, i as u64, salt::PROBLEM, 5) as i32 - 2;
        m.set_h(i, h);
    }
    m
}

/// A dense all-to-all instance: exercises the dense-row fast path
/// (no CSR; Fenwick refreshes through the bulk lane kernel).
fn dense_instance(seed: u64) -> IsingModel {
    let rng = StatelessRng::new(seed);
    MaxCut::new(generators::complete(48, &[-1, 1], &rng)).model().clone()
}

#[test]
fn fenwick_matches_scan_across_modes_datapaths_schedules_seeds() {
    let schedules: Vec<(&str, Schedule)> = vec![
        // Warm plateau: rejection-free regime, incremental path dominant.
        ("constant-warm", Schedule::Constant(2.0)),
        // Cold plateau: Q16 underflow → W == 0 fallbacks and (for RWA-U)
        // null transitions.
        ("constant-cold", Schedule::Constant(0.15)),
        // Continuous ramp: a full lane refresh every step.
        ("geometric", Schedule::Geometric { t0: 6.0, t1: 0.05 }),
        // Staged ramp: plateau boundaries mix bulk refreshes with
        // incremental interior steps.
        ("staged", Schedule::Geometric { t0: 6.0, t1: 0.05 }.quantized(8)),
    ];
    for (instance_name, model) in
        [("sparse", sparse_instance(21)), ("dense", dense_instance(22))]
    {
        for mode in [Mode::RouletteWheel, Mode::RouletteUniformized] {
            for dp in [Datapath::Dense, Datapath::BitPlane] {
                for (sched_name, schedule) in &schedules {
                    for seed in [1u64, 99] {
                        let scan = run_signature(
                            &model,
                            mode,
                            dp,
                            SelectorKind::LinearScan,
                            schedule.clone(),
                            600,
                            seed,
                        );
                        let fenwick = run_signature(
                            &model,
                            mode,
                            dp,
                            SelectorKind::Fenwick,
                            schedule.clone(),
                            600,
                            seed,
                        );
                        assert_eq!(
                            scan, fenwick,
                            "divergence: {instance_name}/{mode:?}/{dp:?}/{sched_name}/seed {seed}"
                        );
                    }
                }
            }
        }
    }
}

/// The degenerate-weight fallback (W == 0 at T = 0 in a locally optimal
/// state) must behave identically through the Fenwick path: fall back to
/// Mode I, reject the uphill move, leave the ground state untouched.
#[test]
fn frozen_fallback_is_identical_through_fenwick() {
    let mut m = IsingModel::zeros(2);
    m.set_j(0, 1, 1);
    for selector in [SelectorKind::LinearScan, SelectorKind::Fenwick] {
        let mut cfg = EngineConfig::new(Mode::RouletteWheel, 0, 13);
        cfg.selector = selector;
        let mut e = SnowballEngine::with_spins(&m, cfg, SpinVec::from_spins(&[1, 1]));
        for t in 0..20 {
            match e.step(t, 0.0) {
                StepOutcome::FallbackRejected => {}
                other => panic!("{selector:?}: expected FallbackRejected, got {other:?}"),
            }
        }
        assert_eq!(e.energy(), -1, "{selector:?}: ground state disturbed");
    }
}

/// Uniformized null transitions draw from W* = N and compare against W;
/// both selectors must take the exact same null/flip decisions.
#[test]
fn uniformized_nulls_are_identical_through_fenwick() {
    let model = sparse_instance(31);
    for seed in 0..4u64 {
        let scan = run_signature(
            &model,
            Mode::RouletteUniformized,
            Datapath::Dense,
            SelectorKind::LinearScan,
            Schedule::Constant(0.3),
            800,
            seed,
        );
        let fenwick = run_signature(
            &model,
            Mode::RouletteUniformized,
            Datapath::Dense,
            SelectorKind::Fenwick,
            Schedule::Constant(0.3),
            800,
            seed,
        );
        assert_eq!(scan, fenwick, "seed {seed}");
        assert!(scan.4 > 0, "seed {seed}: expected null transitions at T = 0.3");
    }
}

/// Step-by-step agreement (not just end-of-run): every outcome —
/// including WHICH spin flipped — matches between the selectors, with
/// temperatures driven externally through the public `step` API the way
/// parallel tempering drives engines (temp changes between bursts).
#[test]
fn per_step_outcomes_match_under_external_temperature_control() {
    let model = sparse_instance(41);
    let mk = |selector| {
        let mut cfg = EngineConfig::new(Mode::RouletteWheel, 0, 5);
        cfg.selector = selector;
        SnowballEngine::new(&model, cfg)
    };
    let mut a = mk(SelectorKind::LinearScan);
    let mut b = mk(SelectorKind::Fenwick);
    let temps = [2.0, 2.0, 2.0, 0.7, 0.7, 1.3, 1.3, 1.3, 1.3, 0.2];
    for t in 0..400u64 {
        let temp = temps[(t as usize / 40) % temps.len()];
        let oa = a.step(t, temp);
        let ob = b.step(t, temp);
        assert_eq!(oa, ob, "step {t} at T = {temp}");
        assert_eq!(a.energy(), b.energy(), "energy divergence at step {t}");
    }
    assert_eq!(a.spins(), b.spins(), "final configurations differ");
    assert_eq!(a.fields(), b.fields(), "final fields differ");
}

/// Long plateau stress: thousands of incremental (dirty-lane) updates
/// between bulk refreshes must not drift from the from-scratch lane
/// evaluation the scan path performs every step.
#[test]
fn long_plateau_incremental_maintenance_does_not_drift() {
    let model = sparse_instance(51);
    let schedule = Schedule::Geometric { t0: 5.0, t1: 0.1 }.quantized(4);
    for dp in [Datapath::Dense, Datapath::BitPlane] {
        let scan = run_signature(
            &model,
            Mode::RouletteWheel,
            dp,
            SelectorKind::LinearScan,
            schedule.clone(),
            6_000,
            7,
        );
        let fenwick = run_signature(
            &model,
            Mode::RouletteWheel,
            dp,
            SelectorKind::Fenwick,
            schedule.clone(),
            6_000,
            7,
        );
        assert_eq!(scan, fenwick, "{dp:?}");
    }
}
