//! Service load harness (ROADMAP "TCP service load test"): drive the
//! TCP service with 100+ concurrent clients issuing mixed-size
//! `SOLVE`/`WAIT`/`RESULT` traffic plus `METRICS` pollers, against both
//! dispatchers, and assert that
//!
//! * overlapping dispatch shows a lower p99 `queue_wait` (via
//!   `Metrics::quantile_us`) than the serial dispatcher on the same
//!   trace — the pool stops idling between jobs, and
//! * every job's result stays **bit-identical** to a serial
//!   single-worker reference run of the same spec
//!   (`pool_determinism.rs`-style), i.e. saturation never leaks between
//!   jobs or perturbs a replica stream.

use snowball::coordinator::{service, Coordinator, ReplicaScheduler, Service};
use snowball::coordinator::{Backend, JobSpec};
use snowball::engine::{Mode, Schedule, SelectorKind};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

/// 96 solving clients + 8 metrics pollers = 104 concurrent connections.
const SOLVERS: usize = 96;
const POLLERS: usize = 8;

/// Client `c`'s deterministic request: sizes cycle through four instance
/// shapes so every drain of the admission queue holds a size mix.
fn trace_entry(c: usize) -> (&'static str, u64, u64) {
    let seed = 1000 + c as u64;
    match c % 4 {
        0 => ("er:16:40", 2000, seed),
        1 => ("er:24:80", 2500, seed),
        2 => ("er:48:180", 3000, seed),
        _ => ("er:96:380", 4000, seed),
    }
}

/// The `JobSpec` the service builds for `trace_entry(c)` (same defaults
/// as the `SOLVE` handler: rwa, fenwick, geometric 8→0.05, 2 replicas).
fn reference_spec(c: usize) -> JobSpec {
    let (inst, steps, seed) = trace_entry(c);
    let (label, model) = service::build_instance(inst, seed).unwrap();
    JobSpec {
        model: Arc::new(model),
        label,
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
        steps,
        replicas: 2,
        seed,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        budget_ms: 0,
        max_retries: 0,
        backend: Backend::Native,
    }
}

fn send(s: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> String {
    writeln!(s, "{req}").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// One solving client: SOLVE → WAIT → RESULT, returning the reported
/// best energy.
fn solve_client(addr: std::net::SocketAddr, c: usize) -> i64 {
    let (inst, steps, seed) = trace_entry(c);
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let reply = send(
        &mut s,
        &mut r,
        &format!("SOLVE instance={inst} mode=rwa steps={steps} replicas=2 seed={seed}"),
    );
    assert!(reply.starts_with("JOB id="), "{reply}");
    let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
    let state = send(&mut s, &mut r, &format!("WAIT id={id}"));
    assert_eq!(state, format!("STATE id={id} state=done"));
    let res = send(&mut s, &mut r, &format!("RESULT id={id}"));
    let best = res
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("best="))
        .unwrap_or_else(|| panic!("no best= in {res}"));
    best.parse().unwrap()
}

/// One metrics poller: a few METRICS round trips, checking the dump is
/// well-formed (terminated by END) while load is in flight.
fn metrics_client(addr: std::net::SocketAddr) {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    for _ in 0..5 {
        writeln!(s, "METRICS").unwrap();
        let mut line = String::new();
        loop {
            line.clear();
            assert!(r.read_line(&mut line).unwrap() > 0, "connection died mid-METRICS");
            if line.trim_end().ends_with("END") {
                break;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

/// Run the whole trace against one coordinator; returns per-client best
/// energies and the p99 of the `queue_wait` stage timer.
fn run_trace(coord: Coordinator) -> (BTreeMap<usize, i64>, u64, u64) {
    let metrics = coord.metrics.clone();
    let addr = Service::bind(coord.clone(), "127.0.0.1:0").unwrap().serve_in_background();
    let mut solvers = Vec::new();
    for c in 0..SOLVERS {
        solvers.push(std::thread::spawn(move || (c, solve_client(addr, c))));
    }
    let pollers: Vec<_> =
        (0..POLLERS).map(|_| std::thread::spawn(move || metrics_client(addr))).collect();
    let bests: BTreeMap<usize, i64> = solvers.into_iter().map(|h| h.join().unwrap()).collect();
    for p in pollers {
        p.join().unwrap();
    }
    assert_eq!(metrics.get("jobs_done"), SOLVERS as u64);
    assert_eq!(metrics.samples("queue_wait"), SOLVERS as u64);
    let p99 = metrics.quantile_us("queue_wait", 0.99).expect("queue_wait observed");
    let wall_p99 = metrics.quantile_us("job_wall", 0.99).expect("job_wall observed");
    coord.shutdown();
    (bests, p99, wall_p99)
}

#[test]
fn overlapping_dispatch_beats_serial_p99_and_stays_bit_identical() {
    let (serial_bests, serial_p99, serial_wall_p99) = run_trace(Coordinator::start_serial(4));
    let (overlap_bests, overlap_p99, overlap_wall_p99) = run_trace(Coordinator::start(4));

    // Same trace, same answers: dispatch mode is invisible in results.
    assert_eq!(serial_bests, overlap_bests, "dispatch mode changed job results");

    // And both match a single-worker reference run of each spec — the
    // service + queue + pool stack perturbs no replica stream.
    let reference = ReplicaScheduler::new(1);
    for (&c, &best) in &serial_bests {
        let expect = reference
            .run_native(&reference_spec(c))
            .iter()
            .map(|r| r.best_energy)
            .min()
            .unwrap();
        assert_eq!(best, expect, "client {c}: service result diverged from serial reference");
    }

    // The tentpole claim: with ~100 concurrent clients, overlapping
    // dispatch keeps jobs out of the queue while serial dispatch makes
    // the tail wait for every predecessor. Buckets are powers of two,
    // so strict inequality is a ≥2× separation.
    assert!(
        overlap_p99 < serial_p99,
        "overlapping dispatch should shrink p99 queue_wait: overlapping {overlap_p99} µs \
         vs serial {serial_p99} µs"
    );

    // queue_wait alone can't see waiting that moved into the pool's own
    // backlog (that time lands in `run`/`job_wall`), so also guard the
    // client-visible end-to-end latency: with identical total work and
    // workers, overlap must not blow up p99 job_wall. The 4× (two
    // power-of-two buckets) headroom keeps this a regression tripwire,
    // not a flaky benchmark.
    assert!(
        overlap_wall_p99 <= serial_wall_p99 * 4,
        "overlapping dispatch regressed end-to-end latency: p99 job_wall {overlap_wall_p99} µs \
         vs serial {serial_wall_p99} µs"
    );
}

/// Occupancy gauges and stage timers must be visible through the same
/// metrics the TCP METRICS command renders, and occupancy must return
/// to zero once the trace drains.
#[test]
fn saturation_is_observable_and_settles() {
    let coord = Coordinator::start(2);
    let metrics = coord.metrics.clone();
    let addr = Service::bind(coord.clone(), "127.0.0.1:0").unwrap().serve_in_background();
    let handles: Vec<_> =
        (0..12).map(|c| std::thread::spawn(move || solve_client(addr, c))).collect();
    for h in handles {
        h.join().unwrap();
    }
    let dump = metrics.render();
    for series in ["queue_wait", "dispatch", "run", "job_wall"] {
        assert!(dump.contains(&format!("histogram {series} ")), "missing {series} in:\n{dump}");
    }
    for gauge in ["jobs_queued", "jobs_running", "replicas_inflight"] {
        assert!(dump.contains(&format!("gauge {gauge} 0")), "{gauge} should settle to 0:\n{dump}");
    }
    assert!(dump.contains("counter batch_groups"), "batcher accounting missing:\n{dump}");
    coord.shutdown();
}

/// Disconnect-mid-WAIT cohort (PR 7 satellite): clients that hang up
/// while parked in `WAIT` must not leak waiter state. Each client
/// submits a job that would run for minutes, issues `WAIT`, and drops
/// the socket without reading the reply. The service's waiter loop
/// notices the dead peer, unwinds (the `service_waiters` gauge settles
/// back to 0), and the coordinator keeps serving fresh connections —
/// which then CANCEL the abandoned jobs so the trace drains promptly.
#[test]
fn disconnect_mid_wait_leaks_no_waiter_state() {
    let coord = Coordinator::start(2);
    let metrics = coord.metrics.clone();
    let addr = Service::bind(coord.clone(), "127.0.0.1:0").unwrap().serve_in_background();
    let mut ids = Vec::new();
    for c in 0..6u64 {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let reply = send(
            &mut s,
            &mut r,
            &format!("SOLVE instance=er:64:256 steps=2000000000 replicas=2 seed={}", 50 + c),
        );
        assert!(reply.starts_with("JOB id="), "{reply}");
        let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
        ids.push(id);
        writeln!(s, "WAIT id={id}").unwrap();
        // Give the handler a beat to enter the waiter loop, then hang up
        // without ever reading the STATE reply.
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(r);
        drop(s);
    }
    // The waiter loop re-checks its peer every poll tick; every
    // abandoned waiter must be reaped, not parked forever.
    let t0 = std::time::Instant::now();
    while metrics.gauge("service_waiters") != 0
        && t0.elapsed() < std::time::Duration::from_secs(30)
    {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(metrics.gauge("service_waiters"), 0, "hang-ups leaked waiter state");
    // The service still answers a fresh connection, and the abandoned
    // jobs are still cancellable (no handler wedged holding state).
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    for id in &ids {
        let reply = send(&mut s, &mut r, &format!("CANCEL id={id}"));
        assert_eq!(reply, format!("CANCELLED id={id}"), "job {id} not cancellable");
    }
    for id in &ids {
        let state = send(&mut s, &mut r, &format!("WAIT id={id}"));
        assert_eq!(state, format!("STATE id={id} state=cancelled"));
    }
    coord.shutdown();
}
