//! Service load harness (ROADMAP "TCP service load test"): drive the
//! TCP service with 100+ concurrent clients issuing mixed-size
//! `SOLVE`/`WAIT`/`RESULT` traffic plus `METRICS` pollers, against both
//! dispatchers, and assert that
//!
//! * overlapping dispatch shows a lower p99 `queue_wait` (via
//!   `Metrics::quantile_us`) than the serial dispatcher on the same
//!   trace — the pool stops idling between jobs, and
//! * every job's result stays **bit-identical** to a serial
//!   single-worker reference run of the same spec
//!   (`pool_determinism.rs`-style), i.e. saturation never leaks between
//!   jobs or perturbs a replica stream.
//!
//! The routed-tier storm at the bottom scales the same discipline to
//! the dispatch tier: a thousand clients over a 4-worker `Router` with
//! mixed inline / PUT-then-by-hash / disconnect-churn traffic and a
//! worker killed mid-storm, asserting zero lost jobs and bit-identical
//! results throughout.

use snowball::coordinator::{service, Coordinator, Dispatch, ReplicaScheduler, Router, Service};
use snowball::coordinator::{Backend, JobSpec};
use snowball::engine::{Mode, Schedule, SelectorKind};
use snowball::ising::IsingModel;
use std::collections::{BTreeMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// 96 solving clients + 8 metrics pollers = 104 concurrent connections.
const SOLVERS: usize = 96;
const POLLERS: usize = 8;

/// Client `c`'s deterministic request: sizes cycle through four instance
/// shapes so every drain of the admission queue holds a size mix.
fn trace_entry(c: usize) -> (&'static str, u64, u64) {
    let seed = 1000 + c as u64;
    match c % 4 {
        0 => ("er:16:40", 2000, seed),
        1 => ("er:24:80", 2500, seed),
        2 => ("er:48:180", 3000, seed),
        _ => ("er:96:380", 4000, seed),
    }
}

/// The `JobSpec` the service builds for `trace_entry(c)` (same defaults
/// as the `SOLVE` handler: rwa, fenwick, geometric 8→0.05, 2 replicas).
fn reference_spec(c: usize) -> JobSpec {
    let (inst, steps, seed) = trace_entry(c);
    let (label, model) = service::build_instance(inst, seed).unwrap();
    JobSpec {
        model: Arc::new(model),
        label,
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
        steps,
        replicas: 2,
        seed,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
        budget_ms: 0,
        max_retries: 0,
        backend: Backend::Native,
        portfolio: None,
    }
}

fn send(s: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> String {
    writeln!(s, "{req}").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// One solving client: SOLVE → WAIT → RESULT, returning the reported
/// best energy.
fn solve_client(addr: std::net::SocketAddr, c: usize) -> i64 {
    let (inst, steps, seed) = trace_entry(c);
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let reply = send(
        &mut s,
        &mut r,
        &format!("SOLVE instance={inst} mode=rwa steps={steps} replicas=2 seed={seed}"),
    );
    assert!(reply.starts_with("JOB id="), "{reply}");
    let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
    let state = send(&mut s, &mut r, &format!("WAIT id={id}"));
    assert_eq!(state, format!("STATE id={id} state=done"));
    let res = send(&mut s, &mut r, &format!("RESULT id={id}"));
    let best = res
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("best="))
        .unwrap_or_else(|| panic!("no best= in {res}"));
    best.parse().unwrap()
}

/// One metrics poller: a few METRICS round trips, checking the dump is
/// well-formed (terminated by END) while load is in flight.
fn metrics_client(addr: std::net::SocketAddr) {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    for _ in 0..5 {
        writeln!(s, "METRICS").unwrap();
        let mut line = String::new();
        loop {
            line.clear();
            assert!(r.read_line(&mut line).unwrap() > 0, "connection died mid-METRICS");
            if line.trim_end().ends_with("END") {
                break;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

/// Run the whole trace against one coordinator; returns per-client best
/// energies and the p99 of the `queue_wait` stage timer.
fn run_trace(coord: Coordinator) -> (BTreeMap<usize, i64>, u64, u64) {
    let metrics = coord.metrics.clone();
    let addr = Service::bind(coord.clone(), "127.0.0.1:0").unwrap().serve_in_background();
    let mut solvers = Vec::new();
    for c in 0..SOLVERS {
        solvers.push(std::thread::spawn(move || (c, solve_client(addr, c))));
    }
    let pollers: Vec<_> =
        (0..POLLERS).map(|_| std::thread::spawn(move || metrics_client(addr))).collect();
    let bests: BTreeMap<usize, i64> = solvers.into_iter().map(|h| h.join().unwrap()).collect();
    for p in pollers {
        p.join().unwrap();
    }
    assert_eq!(metrics.get("jobs_done"), SOLVERS as u64);
    assert_eq!(metrics.samples("queue_wait"), SOLVERS as u64);
    let p99 = metrics.quantile_us("queue_wait", 0.99).expect("queue_wait observed");
    let wall_p99 = metrics.quantile_us("job_wall", 0.99).expect("job_wall observed");
    coord.shutdown();
    (bests, p99, wall_p99)
}

#[test]
fn overlapping_dispatch_beats_serial_p99_and_stays_bit_identical() {
    let (serial_bests, serial_p99, serial_wall_p99) = run_trace(Coordinator::start_serial(4));
    let (overlap_bests, overlap_p99, overlap_wall_p99) = run_trace(Coordinator::start(4));

    // Same trace, same answers: dispatch mode is invisible in results.
    assert_eq!(serial_bests, overlap_bests, "dispatch mode changed job results");

    // And both match a single-worker reference run of each spec — the
    // service + queue + pool stack perturbs no replica stream.
    let reference = ReplicaScheduler::new(1);
    for (&c, &best) in &serial_bests {
        let expect = reference
            .run_native(&reference_spec(c))
            .iter()
            .map(|r| r.best_energy)
            .min()
            .unwrap();
        assert_eq!(best, expect, "client {c}: service result diverged from serial reference");
    }

    // The tentpole claim: with ~100 concurrent clients, overlapping
    // dispatch keeps jobs out of the queue while serial dispatch makes
    // the tail wait for every predecessor. Buckets are powers of two,
    // so strict inequality is a ≥2× separation.
    assert!(
        overlap_p99 < serial_p99,
        "overlapping dispatch should shrink p99 queue_wait: overlapping {overlap_p99} µs \
         vs serial {serial_p99} µs"
    );

    // queue_wait alone can't see waiting that moved into the pool's own
    // backlog (that time lands in `run`/`job_wall`), so also guard the
    // client-visible end-to-end latency: with identical total work and
    // workers, overlap must not blow up p99 job_wall. The 4× (two
    // power-of-two buckets) headroom keeps this a regression tripwire,
    // not a flaky benchmark.
    assert!(
        overlap_wall_p99 <= serial_wall_p99 * 4,
        "overlapping dispatch regressed end-to-end latency: p99 job_wall {overlap_wall_p99} µs \
         vs serial {serial_wall_p99} µs"
    );
}

/// Occupancy gauges and stage timers must be visible through the same
/// metrics the TCP METRICS command renders, and occupancy must return
/// to zero once the trace drains.
#[test]
fn saturation_is_observable_and_settles() {
    let coord = Coordinator::start(2);
    let metrics = coord.metrics.clone();
    let addr = Service::bind(coord.clone(), "127.0.0.1:0").unwrap().serve_in_background();
    let handles: Vec<_> =
        (0..12).map(|c| std::thread::spawn(move || solve_client(addr, c))).collect();
    for h in handles {
        h.join().unwrap();
    }
    let dump = metrics.render();
    for series in ["queue_wait", "dispatch", "run", "job_wall"] {
        assert!(dump.contains(&format!("histogram {series} ")), "missing {series} in:\n{dump}");
    }
    for gauge in ["jobs_queued", "jobs_running", "replicas_inflight"] {
        assert!(dump.contains(&format!("gauge {gauge} 0")), "{gauge} should settle to 0:\n{dump}");
    }
    assert!(dump.contains("counter batch_groups"), "batcher accounting missing:\n{dump}");
    coord.shutdown();
}

/// Disconnect-mid-WAIT cohort (PR 7 satellite): clients that hang up
/// while parked in `WAIT` must not leak waiter state. Each client
/// submits a job that would run for minutes, issues `WAIT`, and drops
/// the socket without reading the reply. The service's waiter loop
/// notices the dead peer, unwinds (the `service_waiters` gauge settles
/// back to 0), and the coordinator keeps serving fresh connections —
/// which then CANCEL the abandoned jobs so the trace drains promptly.
#[test]
fn disconnect_mid_wait_leaks_no_waiter_state() {
    let coord = Coordinator::start(2);
    let metrics = coord.metrics.clone();
    let addr = Service::bind(coord.clone(), "127.0.0.1:0").unwrap().serve_in_background();
    let mut ids = Vec::new();
    for c in 0..6u64 {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let reply = send(
            &mut s,
            &mut r,
            &format!("SOLVE instance=er:64:256 steps=2000000000 replicas=2 seed={}", 50 + c),
        );
        assert!(reply.starts_with("JOB id="), "{reply}");
        let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
        ids.push(id);
        writeln!(s, "WAIT id={id}").unwrap();
        // Give the handler a beat to enter the waiter loop, then hang up
        // without ever reading the STATE reply.
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(r);
        drop(s);
    }
    // The waiter loop re-checks its peer every poll tick; every
    // abandoned waiter must be reaped, not parked forever.
    let t0 = std::time::Instant::now();
    while metrics.gauge("service_waiters") != 0
        && t0.elapsed() < std::time::Duration::from_secs(30)
    {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(metrics.gauge("service_waiters"), 0, "hang-ups leaked waiter state");
    // The service still answers a fresh connection, and the abandoned
    // jobs are still cancellable (no handler wedged holding state).
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    for id in &ids {
        let reply = send(&mut s, &mut r, &format!("CANCEL id={id}"));
        assert_eq!(reply, format!("CANCELLED id={id}"), "job {id} not cancellable");
    }
    for id in &ids {
        let state = send(&mut s, &mut r, &format!("WAIT id={id}"));
        assert_eq!(state, format!("STATE id={id} state=cancelled"));
    }
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// Routed dispatch tier: thousand-client churn/kill storm.
// ---------------------------------------------------------------------------

/// Storm shape: 1024 solving clients (+ pollers) against a 4-worker
/// dispatch tier. Connection *concurrency* is bounded by [`Gate`] so
/// the harness stays under default fd limits — every client is still a
/// real thread holding a real TCP connection for its whole exchange.
const STORM_INLINE: usize = 400;
const STORM_BY_HASH: usize = 400;
const STORM_CHURN: usize = 224;
const STORM_MODELS: usize = 8;
const STORM_SOCKETS: usize = 160;

/// A counting semaphore from Mutex + Condvar (the repo bans raw
/// atomics outside audited files; this needs no speed anyway).
struct Gate {
    permits: Mutex<usize>,
    cv: Condvar,
}

struct Permit(Arc<Gate>);

impl Gate {
    fn new(n: usize) -> Arc<Gate> {
        Arc::new(Gate { permits: Mutex::new(n), cv: Condvar::new() })
    }

    fn acquire(self: &Arc<Gate>) -> Permit {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
        Permit(self.clone())
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        *self.0.permits.lock().unwrap() += 1;
        self.0.cv.notify_one();
    }
}

/// Full SOLVE → WAIT → RESULT round trip for an arbitrary request;
/// returns the job id and the reported best energy.
fn solve_round_trip(addr: std::net::SocketAddr, req: &str) -> (u64, i64) {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    solve_on(&mut s, &mut r, req)
}

/// Same round trip on an already-open connection (so a client can PUT
/// first and SOLVE by hash on the same socket).
fn solve_on(s: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> (u64, i64) {
    let reply = send(s, r, req);
    assert!(reply.starts_with("JOB id="), "{reply}");
    let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
    let state = send(s, r, &format!("WAIT id={id}"));
    assert_eq!(state, format!("STATE id={id} state=done"));
    let res = send(s, r, &format!("RESULT id={id}"));
    let best = res
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("best="))
        .unwrap_or_else(|| panic!("no best= in {res}"));
    (id, best.parse().unwrap())
}

/// The wire body of a `PUT` upload for `model` (couplings then fields,
/// END-terminated) — what `snowball put` sends.
fn put_body(model: &IsingModel) -> String {
    let mut body = format!("PUT n={}\n", model.len());
    for i in 0..model.len() {
        for (k, w) in model.j_row(i).iter().enumerate().skip(i + 1) {
            if w != 0 {
                body.push_str(&format!("{i} {k} {w}\n"));
            }
        }
    }
    for i in 0..model.len() {
        if model.h(i) != 0 {
            body.push_str(&format!("H {i} {}\n", model.h(i)));
        }
    }
    body.push_str("END\n");
    body
}

/// The `c % STORM_MODELS` shared models of the by-hash cohort: 50
/// clients reference each, so the registry must hold exactly 8 entries
/// however the 400 concurrent PUTs interleave.
fn storm_model(k: usize) -> IsingModel {
    let (_, model) = service::build_instance(&format!("er:32:{}", 96 + 8 * k), 900 + k as u64)
        .expect("storm model");
    model
}

/// Per-client storm parameters. Seeds are globally distinct so every
/// job has a unique bit-exact answer; steps stagger so queue drains mix
/// sizes.
fn storm_solve_params(c: usize) -> (u64, u64) {
    (2_000 + (c % 4) as u64 * 500, 5_000 + c as u64)
}

/// Reference spec mirroring exactly what the service builds for a
/// storm request (same defaults as the SOLVE handler).
fn storm_reference_spec(model: IsingModel, steps: u64, seed: u64) -> JobSpec {
    JobSpec {
        model: Arc::new(model),
        label: String::new(),
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
        steps,
        replicas: 2,
        seed,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
        budget_ms: 0,
        max_retries: 0,
        backend: Backend::Native,
        portfolio: None,
    }
}

/// Churn cohort jobs run long enough (~tens of ms) that the killed
/// worker reliably holds several mid-flight, forcing re-dispatch.
const CHURN_STEPS: u64 = 1_200_000;

fn churn_reference_spec(c: usize) -> JobSpec {
    let (_, model) = service::build_instance("er:64:256", 80_000 + c as u64).unwrap();
    storm_reference_spec(model, CHURN_STEPS, 80_000 + c as u64)
}

/// The ISSUE's headline harness: ≥1000 concurrent TCP clients against
/// a front-end routing over 4 coordinator workers, mixing
/// PUT-then-SOLVE-by-hash with inline-SOLVE traffic and
/// disconnect-mid-WAIT churn, with one worker killed mid-storm.
///
/// Asserts, in order: zero lost jobs (every submitted id reaches a
/// terminal state), every result bit-identical to a single-worker
/// reference run, exactly [`STORM_MODELS`] registry entries with
/// dedup/hit/miss counters reconciling the observed traffic, at least
/// one re-dispatch, and every worker's committed admission weight and
/// the service waiter gauge drained to zero.
#[test]
fn routed_tier_survives_thousand_client_storm_with_worker_kill() {
    let router = Router::start(4, 2);
    let metrics = router.metrics.clone();
    let addr = Service::bind(router.clone(), "127.0.0.1:0").unwrap().serve_in_background();
    let gate = Gate::new(STORM_SOCKETS);

    // Kill thread: wait until the busiest worker holds a few live jobs
    // (the storm makes that near-instant), then kill it mid-flight.
    let killer = {
        let router = router.clone();
        std::thread::spawn(move || {
            let t0 = Instant::now();
            loop {
                let victim = (0..router.worker_count())
                    .max_by_key(|&w| router.live_jobs_on(w))
                    .unwrap();
                if router.live_jobs_on(victim) >= 2 || t0.elapsed() > Duration::from_secs(30) {
                    router.kill_worker(victim);
                    return victim;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        })
    };

    // Cohort A: inline SOLVE (the model travels in the request line).
    let mut inline = Vec::new();
    for c in 0..STORM_INLINE {
        let gate = gate.clone();
        inline.push(std::thread::spawn(move || {
            let _p = gate.acquire();
            let (inst, steps, seed) = trace_entry(c);
            let (id, best) = solve_round_trip(
                addr,
                &format!("SOLVE instance={inst} mode=rwa steps={steps} replicas=2 seed={seed}"),
            );
            (c, id, best)
        }));
    }

    // Cohort B: PUT the model (8 distinct bodies across 400 clients),
    // then SOLVE it by hash on the same socket.
    let mut by_hash = Vec::new();
    for c in 0..STORM_BY_HASH {
        let gate = gate.clone();
        by_hash.push(std::thread::spawn(move || {
            let _p = gate.acquire();
            let (steps, seed) = storm_solve_params(c);
            let mut s = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            s.write_all(put_body(&storm_model(c % STORM_MODELS)).as_bytes()).unwrap();
            let mut stored = String::new();
            r.read_line(&mut stored).unwrap();
            let hash = stored
                .trim()
                .strip_prefix("STORED model=")
                .unwrap_or_else(|| panic!("bad PUT reply: {stored}"))
                .to_string();
            let (id, best) = solve_on(
                &mut s,
                &mut r,
                &format!("SOLVE model={hash} mode=rwa steps={steps} replicas=2 seed={seed}"),
            );
            (c, id, best)
        }));
    }

    // Cohort C: churn — submit, park in WAIT, hang up without reading
    // the reply. The jobs must still reach `done` on their own.
    let mut churn = Vec::new();
    for c in 0..STORM_CHURN {
        let gate = gate.clone();
        churn.push(std::thread::spawn(move || {
            let _p = gate.acquire();
            let mut s = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let reply = send(
                &mut s,
                &mut r,
                &format!(
                    "SOLVE instance=er:64:256 mode=rwa steps={CHURN_STEPS} replicas=2 seed={}",
                    80_000 + c as u64
                ),
            );
            assert!(reply.starts_with("JOB id="), "{reply}");
            let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
            writeln!(s, "WAIT id={id}").unwrap();
            std::thread::sleep(Duration::from_millis(5));
            id
        }));
    }

    // A few METRICS pollers keep protocol traffic mixed during the storm.
    let pollers: Vec<_> = (0..8)
        .map(|_| {
            let gate = gate.clone();
            std::thread::spawn(move || {
                let _p = gate.acquire();
                metrics_client(addr);
            })
        })
        .collect();

    let inline: Vec<(usize, u64, i64)> = inline.into_iter().map(|h| h.join().unwrap()).collect();
    let by_hash: Vec<(usize, u64, i64)> = by_hash.into_iter().map(|h| h.join().unwrap()).collect();
    let churn_ids: Vec<u64> = churn.into_iter().map(|h| h.join().unwrap()).collect();
    for p in pollers {
        p.join().unwrap();
    }
    let victim = killer.join().unwrap();

    // Zero lost jobs: every churn id reaches a terminal state (done —
    // nothing cancels them) and reports a result, kill or no kill.
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut churn_bests = Vec::new();
    for (c, &id) in churn_ids.iter().enumerate() {
        let state = send(&mut s, &mut r, &format!("WAIT id={id}"));
        assert_eq!(state, format!("STATE id={id} state=done"), "churn job {c} lost");
        let res = send(&mut s, &mut r, &format!("RESULT id={id}"));
        let best: i64 = res
            .split_whitespace()
            .find_map(|tok| tok.strip_prefix("best="))
            .unwrap_or_else(|| panic!("no best= in {res}"))
            .parse()
            .unwrap();
        churn_bests.push((c, best));
    }

    // Router ids are unique across the whole storm.
    let total = STORM_INLINE + STORM_BY_HASH + STORM_CHURN;
    let distinct: HashSet<u64> = inline
        .iter()
        .map(|&(_, id, _)| id)
        .chain(by_hash.iter().map(|&(_, id, _)| id))
        .chain(churn_ids.iter().copied())
        .collect();
    assert_eq!(distinct.len(), total, "router ids collided");

    // Bit-identity: every observed best equals a single-worker
    // reference run of the same spec — including jobs the kill
    // re-dispatched mid-run (checkpoint resume is deterministic).
    let mut checks: Vec<(JobSpec, i64)> = Vec::new();
    for &(c, _, best) in &inline {
        checks.push((reference_spec(c), best));
    }
    for &(c, _, best) in &by_hash {
        let (steps, seed) = storm_solve_params(c);
        checks.push((storm_reference_spec(storm_model(c % STORM_MODELS), steps, seed), best));
    }
    for &(c, best) in &churn_bests {
        checks.push((churn_reference_spec(c), best));
    }
    let checks = Arc::new(checks);
    let cursor = Arc::new(Mutex::new(0usize));
    let verifiers: Vec<_> = (0..8)
        .map(|_| {
            let checks = checks.clone();
            let cursor = cursor.clone();
            std::thread::spawn(move || {
                let sched = ReplicaScheduler::new(1);
                loop {
                    let i = {
                        let mut n = cursor.lock().unwrap();
                        let i = *n;
                        *n += 1;
                        i
                    };
                    let Some((spec, observed)) = checks.get(i) else { break };
                    let expect =
                        sched.run_native(spec).iter().map(|r| r.best_energy).min().unwrap();
                    assert_eq!(*observed, expect, "storm job {i} diverged from reference");
                }
            })
        })
        .collect();
    for v in verifiers {
        v.join().unwrap();
    }

    // Registry accounting: 400 uploads of 8 distinct bodies converge to
    // 8 entries; the rest deduplicate. Every by-hash SOLVE checkout
    // hit; nothing ever missed; nothing stays pinned after the drain.
    let stats = router.registry().stats();
    assert_eq!(stats.entries, STORM_MODELS, "registry entry count");
    assert_eq!(stats.dedup, (STORM_BY_HASH - STORM_MODELS) as u64, "dedup count");
    assert_eq!(stats.hits, STORM_BY_HASH as u64, "every by-hash checkout should hit");
    assert_eq!(stats.misses, 0, "no checkout should miss");
    assert_eq!(metrics.get("registry_hits"), stats.hits, "metrics/stats hit reconcile");
    assert_eq!(metrics.get("registry_misses"), 0);
    assert_eq!(metrics.gauge("registry_entries"), STORM_MODELS as i64);

    // Dispatch accounting: every client's job was admitted exactly
    // once at the router, the kill re-dispatched at least one job, and
    // locality kept most by-hash placements on the resident worker.
    assert_eq!(metrics.get("jobs_submitted"), total as u64);
    assert_eq!(metrics.get("router_jobs_adopted"), total as u64);
    assert!(metrics.get("router_redispatches") >= 1, "kill mid-storm must re-dispatch");
    assert!(
        metrics.get("router_locality_hits") >= (STORM_BY_HASH as u64) / 2,
        "locality hits {} too low for {} by-hash jobs",
        metrics.get("router_locality_hits"),
        STORM_BY_HASH
    );

    // Every worker (survivors and victim alike) drains its committed
    // admission weight, no waiter state leaks, no pin leaks. Bounded
    // settle loop: cancelled replicas on the victim unwind at their
    // next stop-token poll.
    let t0 = Instant::now();
    let drained = |router: &Router| {
        (0..router.worker_count()).all(|w| router.worker(w).committed_weight() == 0)
            && router.registry().stats().pinned == 0
            && metrics.gauge("service_waiters") == 0
    };
    while !drained(&router) && t0.elapsed() < Duration::from_secs(30) {
        std::thread::sleep(Duration::from_millis(10));
    }
    for w in 0..router.worker_count() {
        assert_eq!(
            router.worker(w).committed_weight(),
            0,
            "worker {w} (victim was {victim}) leaked committed weight"
        );
    }
    assert_eq!(router.registry().stats().pinned, 0, "pins leaked");
    assert_eq!(metrics.gauge("service_waiters"), 0, "waiter state leaked");

    Dispatch::shutdown(&router);
}
