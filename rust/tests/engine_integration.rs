//! Engine-level integration tests: convergence quality, mode comparisons
//! and figure-harness behaviours on realistic instances.

use snowball::baselines::{Budget, Solver};
use snowball::engine::{Datapath, EngineConfig, Mode, Schedule, SelectorKind, SnowballEngine};
use snowball::graph::gset::{self, GsetId};
use snowball::harness;
use snowball::problems::MaxCut;
use snowball::rng::StatelessRng;

/// On a planted-optimum instance both modes recover the ground state.
#[test]
fn both_modes_recover_planted_grid() {
    let (frac, trace, _) = harness::fig4(150_000, 3);
    assert!(frac > 0.99, "recovered only {:.1}% of the planted pattern", frac * 100.0);
    // Energy decreases overall along the linear schedule (Fig 4a).
    let first = trace.first().unwrap().1;
    let last = trace.last().unwrap().1;
    assert!(last < first, "no net energy decrease: {first} -> {last}");
}

/// RWA needs fewer steps than RSA to reach a fixed quality bar on a
/// dense instance — the paper's §III-A convergence claim.
#[test]
fn rwa_converges_in_fewer_steps_than_rsa() {
    let rng = StatelessRng::new(1);
    let g = snowball::graph::generators::complete(96, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    let bar = {
        // Quality bar: what RSA reaches with a generous budget.
        let cfg = EngineConfig::new(Mode::RandomScan, 40_000, 3);
        let mut e = SnowballEngine::new(p.model(), cfg);
        e.run().best_energy
    };
    // Count steps for each mode to first reach the bar (median of 5 seeds).
    let steps_to_bar = |mode: Mode| -> u64 {
        let mut counts = Vec::new();
        for seed in 0..5u64 {
            let cfg = EngineConfig {
                mode,
                datapath: Datapath::Dense,
                selector: SelectorKind::Fenwick,
                schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
                steps: 40_000,
                seed,
                planes: None,
                trace_stride: 0,
                shards: 1,
                pin_lanes: false,
                local_rows: false,
            };
            let mut e = SnowballEngine::new(p.model(), cfg);
            let r = e.run();
            counts.push(if r.best_energy <= bar { r.best_step } else { u64::MAX });
        }
        counts.sort_unstable();
        counts[2]
    };
    let rwa = steps_to_bar(Mode::RouletteWheel);
    let rsa = steps_to_bar(Mode::RandomScan);
    assert!(
        rwa <= rsa,
        "RWA took {rwa} steps vs RSA {rsa} to reach energy {bar} — parallel-evaluation \
         selection should not be slower in steps"
    );
}

/// Gset-scale smoke: G11 (800 spins, torus) reaches a sane cut with both
/// Snowball modes and beats a random configuration by a wide margin.
#[test]
fn g11_scale_run() {
    let g = gset::instance(GsetId::G11, 42);
    let p = MaxCut::new(g);
    for mode in [Mode::RandomScan, Mode::RouletteWheel] {
        let solver = match mode {
            Mode::RandomScan => snowball::baselines::SnowballSolver::rsa(),
            _ => snowball::baselines::SnowballSolver::rwa(),
        };
        let r = solver.solve(p.model(), Budget::sweeps(60), 7);
        let cut = p.cut_of_energy(r.best_energy);
        // |E| = 1600, random cut ≈ (|E+|-|E-|)/2 ≈ 17. A real anneal gets
        // several hundred.
        assert!(cut > 300, "{}: cut {cut} too low", solver.name());
    }
}

/// The uniformized variant's null-transition rate tracks 1 − W/W*.
#[test]
fn uniformized_null_rate_tracks_weight() {
    let rng = StatelessRng::new(5);
    let g = snowball::graph::generators::erdos_renyi(64, 400, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    // Hot chain: W is large, nulls rare. Cold chain: W tiny, nulls dominate.
    let run = |t: f64| {
        let cfg = EngineConfig {
            mode: Mode::RouletteUniformized,
            datapath: Datapath::Dense,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Constant(t),
            steps: 2_000,
            seed: 9,
            planes: None,
            trace_stride: 0,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
        };
        let mut e = SnowballEngine::new(p.model(), cfg);
        let r = e.run();
        r.nulls as f64 / r.steps as f64
    };
    let hot = run(50.0);
    let cold = run(0.2);
    assert!(hot < 0.7, "hot chain nulled {hot}");
    assert!(cold > hot, "cold chain must null more ({cold} vs {hot})");
}

/// Figure harnesses at reduced budgets produce sane shapes (full budgets
/// run in the bench binaries).
#[test]
fn figure_harnesses_smoke() {
    // Fig 14 cycle model: naive monotonically worse, e2e ≥ kernel.
    for p in harness::fig14_model(&[10, 1_000]) {
        assert!(p.naive_ms > p.end_to_end_ms && p.end_to_end_ms >= p.kernel_ms);
    }
    // Fig 3: LUT within 1e-3 of exact everywhere sampled.
    for (_, pts) in harness::fig3(&[0.5, 2.0], 6) {
        for (_, exact, approx) in pts {
            assert!((exact - approx).abs() < 1e-3);
        }
    }
    // Fig 13 speedups: Neal row is 1x by construction.
    let rows = vec![
        snowball::tts::TtsRow::quoted("Neal", "CPU", 100.0, 0.5, 100.0),
        snowball::tts::TtsRow::quoted("X", "FPGA", 1.0, 0.9, 1.0),
    ];
    let sp = harness::fig13(&rows);
    assert_eq!(sp[0].1, 1.0);
    assert_eq!(sp[1].1, 100.0);
}

/// Solver trait consistency across the whole Table II line-up on a tiny
/// instance: reported best energy matches re-evaluating the spins.
#[test]
fn lineup_reports_are_consistent() {
    let rng = StatelessRng::new(8);
    let g = snowball::graph::generators::erdos_renyi(32, 120, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    for solver in snowball::baselines::table2_lineup() {
        let r = solver.solve(p.model(), Budget::sweeps(40), 11);
        assert_eq!(
            r.best_energy,
            p.model().energy(&r.best_spins),
            "{} misreported its best energy",
            solver.name()
        );
    }
}
