//! The sharded-engine contract (engine/shard):
//!
//! 1. **Virtual-time merge parity** — an S-shard run in the
//!    deterministic merge mode is bit-identical to the single-shard
//!    `SnowballEngine` for the same seed, across modes, selectors,
//!    datapaths, shard counts, seeds, and instance densities.
//! 2. **Async quality parity** — the asynchronous mode reaches at
//!    least comparable best energy on a G-set-style instance at
//!    N ≥ 2048 with the same total step budget.
//! 3. **Bounded staleness** — the lag any lane observes never exceeds
//!    the configured window, and the epoch bookkeeping is exact.

use snowball::engine::{
    Datapath, EngineConfig, MergeMode, Mode, Schedule, SelectorKind, ShardedEngine, SnowballEngine,
};
use snowball::graph::generators;
use snowball::problems::MaxCut;
use snowball::rng::StatelessRng;

fn cfg(mode: Mode, steps: u64, seed: u64, shards: usize) -> EngineConfig {
    EngineConfig {
        mode,
        datapath: Datapath::Dense,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 5.0, t1: 0.08 },
        steps,
        seed,
        planes: None,
        trace_stride: 97,
        shards,
        pin_lanes: false,
        local_rows: false,
    }
}

type Signature = (i64, u64, i64, u64, u64, u64, Vec<(u64, i64)>, Vec<i8>, Vec<i8>);

fn signature(r: snowball::engine::RunResult) -> Signature {
    (
        r.best_energy,
        r.best_step,
        r.final_energy,
        r.flips,
        r.fallbacks,
        r.nulls,
        r.trace,
        r.best_spins.to_spins(),
        r.final_spins.to_spins(),
    )
}

/// The tentpole guarantee: virtual-time S-shard runs are bit-identical
/// to the single-shard engine — every observable, including the energy
/// trace and both spin configurations — for every mode, both
/// selectors (now honored INSIDE the shard lanes via the shared lane
/// kernel), both datapaths, several shard counts and seeds, on a
/// sparse (CSR path) and a dense (row-walk path) instance.
#[test]
fn virtual_time_merge_is_bit_identical_to_single_shard_engine() {
    let sparse = MaxCut::new(generators::erdos_renyi(128, 260, &[-1, 1], &StatelessRng::new(71)));
    let dense = MaxCut::new(generators::complete(64, &[-1, 1], &StatelessRng::new(72)));
    for (label, p) in [("sparse", &sparse), ("dense", &dense)] {
        for mode in [Mode::RandomScan, Mode::RouletteWheel, Mode::RouletteUniformized] {
            for seed in [3u64, 11] {
                // Reference runs: both selectors and both datapaths
                // must already agree with each other (PR-1/PR-2
                // contracts) — and the sharded merge must match all of
                // them.
                let mut refs = Vec::new();
                for selector in [SelectorKind::Fenwick, SelectorKind::LinearScan] {
                    for dp in [Datapath::Dense, Datapath::BitPlane] {
                        let mut c = cfg(mode, 1_200, seed, 1);
                        c.selector = selector;
                        c.datapath = dp;
                        refs.push(signature(SnowballEngine::new(p.model(), c).run()));
                    }
                }
                for w in refs.windows(2) {
                    assert_eq!(w[0], w[1], "{label}/{mode:?}/seed {seed}: references diverged");
                }
                // The sharded matrix: selector × datapath × shard
                // count, every cell bit-identical to the references.
                for selector in [SelectorKind::Fenwick, SelectorKind::LinearScan] {
                    for dp in [Datapath::Dense, Datapath::BitPlane] {
                        for shards in [2usize, 3, 5, 8] {
                            let mut c = cfg(mode, 1_200, seed, shards);
                            c.selector = selector;
                            c.datapath = dp;
                            let got = signature(
                                ShardedEngine::new(p.model(), c, MergeMode::VirtualTime).run(),
                            );
                            assert_eq!(
                                got, refs[0],
                                "{label}/{mode:?}/{selector:?}/{dp:?}/seed {seed}/{shards} \
                                 shards: virtual-time merge diverged from the single-shard \
                                 engine"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Sparse incremental-vs-bulk parity under a plateau schedule: the
/// Fenwick (incremental dirty-set) lanes and the linear-scan (bulk
/// refresh) lanes must produce bit-identical virtual-time runs across
/// shard counts — i.e. the speedup the sparse BENCH_shard section
/// measures can never come from diverging work. Quantized schedules
/// maximize the incremental path's exposure (long plateaus, dirty-set
/// refresh on almost every step).
#[test]
fn sparse_incremental_and_bulk_lanes_are_bit_identical() {
    let n = 512usize;
    let p = MaxCut::new(generators::erdos_renyi(n, 2 * n, &[-1, 1], &StatelessRng::new(73)));
    let schedule = Schedule::Geometric { t0: 5.0, t1: 0.08 }.quantized(32);
    for mode in [Mode::RouletteWheel, Mode::RouletteUniformized] {
        let run = |selector: SelectorKind, shards: usize| {
            let mut c = cfg(mode, 4_000, 19, shards);
            c.selector = selector;
            c.schedule = schedule.clone();
            signature(ShardedEngine::new(p.model(), c, MergeMode::VirtualTime).run())
        };
        let reference = run(SelectorKind::Fenwick, 1);
        for shards in [1usize, 3, 8] {
            for selector in [SelectorKind::Fenwick, SelectorKind::LinearScan] {
                assert_eq!(
                    run(selector, shards),
                    reference,
                    "{mode:?}/{selector:?}/{shards} shards diverged on the sparse plateau run"
                );
            }
        }
    }
}

/// Async mode on a G-set-style instance at N ≥ 2048: with the same
/// total step budget, the sharded run's best energy must be at least
/// comparable to the single-lane engine's (within a small annealing
/// tolerance — asynchronous lanes see bounded-stale cross-shard
/// fields, which is the paper's trade; what must NOT happen is a
/// quality collapse).
#[test]
fn async_mode_matches_single_lane_quality_at_scale() {
    let n = 2048usize;
    let p = MaxCut::new(generators::erdos_renyi(n, 4 * n, &[-1, 1], &StatelessRng::new(2048)));
    let steps = 160_000u64;
    let schedule = Schedule::Geometric { t0: 6.0, t1: 0.05 }.quantized(64);

    let mut base = cfg(Mode::RouletteWheel, steps, 9, 1);
    base.schedule = schedule.clone();
    base.trace_stride = 0;
    let serial = SnowballEngine::new(p.model(), base.clone()).run();

    let mut sharded_cfg = base;
    sharded_cfg.shards = 4;
    let (sharded, stats) = ShardedEngine::new(p.model(), sharded_cfg, MergeMode::Async)
        .with_window(32)
        .run_with_stats();

    // Exactness of the distributed bookkeeping (independent of quality).
    assert_eq!(
        sharded.final_energy,
        p.model().energy(&sharded.final_spins),
        "distributed energy accounting drifted"
    );
    assert_eq!(sharded.best_energy, p.model().energy(&sharded.best_spins));
    assert_eq!(stats.per_shard_flips.iter().sum::<u64>(), sharded.flips);

    // Quality: within 3% of the single-lane anneal (energies are
    // negative: closer to -inf is better).
    assert!(
        (sharded.best_energy as f64) <= (serial.best_energy as f64) * 0.97,
        "async sharded best {} vs single-lane best {} — quality collapsed",
        sharded.best_energy,
        serial.best_energy
    );
    // And not a degenerate run.
    assert!(sharded.flips > steps / 4, "async lanes barely flipped: {}", sharded.flips);
}

/// Bounded-staleness property: across windows, the maximum lag any
/// lane observes stays within the window, the epoch count matches the
/// window arithmetic, and the run stays exact. `window = 1` is the
/// near-lock-step extreme.
#[test]
fn staleness_never_exceeds_the_window() {
    let p = MaxCut::new(generators::erdos_renyi(256, 1024, &[-1, 1], &StatelessRng::new(77)));
    let shards = 4usize;
    let steps = 12_000u64;
    for window in [1u64, 4, 16, 64] {
        let mut c = cfg(Mode::RouletteWheel, steps, 5, shards);
        c.trace_stride = 0;
        let (r, stats) = ShardedEngine::new(p.model(), c, MergeMode::Async)
            .with_window(window)
            .run_with_stats();
        assert!(
            stats.max_lag <= window,
            "window {window}: observed lag {} exceeds the bound",
            stats.max_lag
        );
        let steps_local = steps.div_ceil(shards as u64);
        assert_eq!(
            stats.sync_points,
            steps_local.div_ceil(window),
            "window {window}: epoch count off"
        );
        assert_eq!(
            r.final_energy,
            p.model().energy(&r.final_spins),
            "window {window}: bookkeeping drifted"
        );
        assert_eq!(r.steps, steps_local * shards as u64);
    }
}

/// Async mode honours every engine mode (the dual-mode contract): RSA,
/// RWA and uniformized RWA lanes all make progress and keep exact
/// bookkeeping.
#[test]
fn async_mode_supports_all_selection_modes() {
    let p = MaxCut::new(generators::erdos_renyi(192, 700, &[-1, 1], &StatelessRng::new(88)));
    for mode in [Mode::RandomScan, Mode::RouletteWheel, Mode::RouletteUniformized] {
        let mut c = cfg(mode, 8_000, 3, 3);
        c.schedule = Schedule::Constant(1.5);
        c.trace_stride = 0;
        let (r, _) = ShardedEngine::new(p.model(), c, MergeMode::Async)
            .with_window(16)
            .run_with_stats();
        assert_eq!(r.final_energy, p.model().energy(&r.final_spins), "{mode:?}");
        assert!(r.flips > 0, "{mode:?}: no progress");
        if mode == Mode::RouletteUniformized {
            assert!(r.nulls > 0, "uniformized lanes never nulled");
        }
    }
}
