//! The precision-packed coupling store's bit-identity contract
//! (ising/store): a model whose couplings pack as i8 or i16 must
//! produce runs **byte-identical** to the same model force-widened to
//! i32 storage — across every deterministic execution path the repo
//! pins elsewhere (single-lane engine, virtual-time sharded merge,
//! both selectors, both datapaths; the same matrix
//! rust/tests/shard_parity.rs runs), plus the by-hash dispatch leg:
//! tier never reaches the content digest, so a widened upload dedups
//! to the same registry entry and serves the same jobs.

use snowball::coordinator::{service, Coordinator, Service};
use snowball::engine::{
    Datapath, EngineConfig, MergeMode, Mode, Schedule, SelectorKind, ShardedEngine, SnowballEngine,
};
use snowball::graph::generators;
use snowball::ising::{IsingModel, Tier};
use snowball::problems::MaxCut;
use snowball::rng::StatelessRng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn cfg(mode: Mode, steps: u64, seed: u64, shards: usize) -> EngineConfig {
    EngineConfig {
        mode,
        datapath: Datapath::Dense,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 5.0, t1: 0.08 },
        steps,
        seed,
        planes: None,
        trace_stride: 97,
        shards,
        pin_lanes: false,
        local_rows: false,
    }
}

type Signature = (i64, u64, i64, u64, u64, u64, Vec<(u64, i64)>, Vec<i8>, Vec<i8>);

fn signature(r: snowball::engine::RunResult) -> Signature {
    (
        r.best_energy,
        r.best_step,
        r.final_energy,
        r.flips,
        r.fallbacks,
        r.nulls,
        r.trace,
        r.best_spins.to_spins(),
        r.final_spins.to_spins(),
    )
}

/// The same instance with its coupling store force-widened to i32 —
/// identical values, 4×/2× the bytes.
fn widened(m: &IsingModel) -> IsingModel {
    let mut w = m.clone();
    w.force_tier(Tier::I32);
    assert_eq!(w.tier(), Tier::I32);
    w
}

/// The tentpole guarantee: packed storage never changes a run. For the
/// exact instance/mode/seed/selector/datapath/shard matrix
/// shard_parity.rs pins, the packed model and its force-widened i32
/// twin produce identical signatures — best/final energy and spins,
/// flip/fallback/null counters, and the full energy trace — through
/// the single-lane engine and the deterministic virtual-time sharded
/// merge.
#[test]
fn packed_tiers_are_bit_identical_to_i32_across_the_matrix() {
    let sparse = MaxCut::new(generators::erdos_renyi(128, 260, &[-1, 1], &StatelessRng::new(71)));
    let dense = MaxCut::new(generators::complete(64, &[-1, 1], &StatelessRng::new(72)));
    let mid = MaxCut::new(generators::erdos_renyi(96, 240, &[-700, 700], &StatelessRng::new(74)));
    assert_eq!(sparse.model().tier(), Tier::I8);
    assert_eq!(dense.model().tier(), Tier::I8);
    assert_eq!(mid.model().tier(), Tier::I16);
    for (label, p) in [("sparse/i8", &sparse), ("dense/i8", &dense), ("sparse/i16", &mid)] {
        let packed = p.model();
        let wide = widened(packed);
        assert_eq!(&wide, packed, "widening must preserve every coupling");
        for mode in [Mode::RandomScan, Mode::RouletteWheel, Mode::RouletteUniformized] {
            for seed in [3u64, 11] {
                for selector in [SelectorKind::Fenwick, SelectorKind::LinearScan] {
                    for dp in [Datapath::Dense, Datapath::BitPlane] {
                        // Single-lane engine.
                        let mut c = cfg(mode, 1_200, seed, 1);
                        c.selector = selector;
                        c.datapath = dp;
                        let want = signature(SnowballEngine::new(packed, c.clone()).run());
                        let got = signature(SnowballEngine::new(&wide, c).run());
                        assert_eq!(
                            got, want,
                            "{label}/{mode:?}/{selector:?}/{dp:?}/seed {seed}: \
                             packed vs i32 diverged in the single-lane engine"
                        );
                        // Virtual-time sharded merge, every pinned
                        // shard count.
                        for shards in [2usize, 3, 5, 8] {
                            let mut c = cfg(mode, 1_200, seed, shards);
                            c.selector = selector;
                            c.datapath = dp;
                            let want = signature(
                                ShardedEngine::new(packed, c.clone(), MergeMode::VirtualTime)
                                    .run(),
                            );
                            let got = signature(
                                ShardedEngine::new(&wide, c, MergeMode::VirtualTime).run(),
                            );
                            assert_eq!(
                                got, want,
                                "{label}/{mode:?}/{selector:?}/{dp:?}/seed {seed}/{shards} \
                                 shards: packed vs i32 diverged in the virtual-time merge"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Energy/field oracles are tier-invariant on arbitrary spin
/// configurations — the packed row walks accumulate in the same order
/// with the same widened i64 terms.
#[test]
fn oracles_are_tier_invariant() {
    let rng = StatelessRng::new(75);
    let p = MaxCut::new(generators::erdos_renyi(80, 320, &[-3, -1, 1, 3], &rng));
    let packed = p.model();
    let wide = widened(packed);
    for k in 0..8u64 {
        let s = snowball::ising::SpinVec::random(packed.len(), &StatelessRng::new(100 + k));
        assert_eq!(wide.energy(&s), packed.energy(&s));
        assert_eq!(wide.local_fields(&s), packed.local_fields(&s));
    }
    assert_eq!(wide.j_matrix(), packed.j_matrix());
    assert_eq!(wide.coupling_count(), packed.coupling_count());
    assert_eq!(wide.max_abs_coeff(), packed.max_abs_coeff());
}

fn send(s: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> String {
    writeln!(s, "{req}").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// SOLVE → WAIT(done) → RESULT best= on an open connection.
fn solve_best(s: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> i64 {
    let reply = send(s, r, req);
    assert!(reply.starts_with("JOB id="), "{reply}");
    let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
    let state = send(s, r, &format!("WAIT id={id}"));
    assert_eq!(state, format!("STATE id={id} state=done"));
    let res = send(s, r, &format!("RESULT id={id}"));
    res.split_whitespace()
        .find_map(|tok| tok.strip_prefix("best="))
        .unwrap_or_else(|| panic!("no best= in {res}"))
        .parse()
        .unwrap()
}

/// The by-hash leg: the content digest ignores the storage tier, so a
/// force-widened copy of an uploaded model dedups to the SAME registry
/// entry (accounted at the packed footprint), and a wire `SOLVE
/// model=<hash>` reports the same answer as the inline submission.
#[test]
fn by_hash_dispatch_is_tier_invariant() {
    let coord = Coordinator::start(2);
    let reg = coord.registry().clone();
    let inst = "er:40:160";
    let seed = 77u64;
    let (_, model) = service::build_instance(inst, seed).unwrap();
    assert_eq!(model.tier(), Tier::I8, "±1 instance packs as i8");
    let packed_bytes = model.approx_bytes();

    let h1 = reg.put(model.clone()).expect("put packed");
    let h2 = reg.put(widened(&model)).expect("put widened");
    assert_eq!(h1, h2, "tier reached the content digest");
    let stats = reg.stats();
    assert_eq!((stats.entries, stats.dedup), (1, 1), "widened upload must dedup");
    assert_eq!(stats.bytes, packed_bytes, "the FIRST (packed) body is what stays stored");

    let addr = Service::bind(coord, "127.0.0.1:0").unwrap().serve_in_background();
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let tail = format!("mode=rwa selector=fenwick steps=4000 replicas=2 seed={seed}");
    let inline = solve_best(&mut s, &mut r, &format!("SOLVE instance={inst} {tail}"));
    let by_hash = solve_best(&mut s, &mut r, &format!("SOLVE model={} {tail}", h1.to_hex()));
    assert_eq!(by_hash, inline, "by-hash SOLVE diverged from inline");
}

/// Strict SOLVE parsing for the new knob, exact ERR form (the string
/// docs/PROTOCOL.md specifies) — and the happy path right after on the
/// same connection, proving the refusal left the line protocol
/// synchronized.
#[test]
fn malformed_local_rows_err_form_is_exact() {
    let coord = Coordinator::start(1);
    let addr = Service::bind(coord, "127.0.0.1:0").unwrap().serve_in_background();
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    for bad in ["yes", "2", "TRUE"] {
        let got = send(&mut s, &mut r, &format!("SOLVE instance=er:16:40 local_rows={bad}"));
        assert_eq!(got, format!("ERR local_rows must be 0|1|true|false (got {bad})"));
    }
    for ok in ["0", "1", "true", "false"] {
        let reply = send(
            &mut s,
            &mut r,
            &format!("SOLVE instance=er:16:40 steps=200 replicas=1 seed=5 local_rows={ok}"),
        );
        assert!(reply.starts_with("JOB id="), "local_rows={ok}: {reply}");
        let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
        let state = send(&mut s, &mut r, &format!("WAIT id={id}"));
        assert_eq!(state, format!("STATE id={id} state=done"));
    }
}
