//! Tier-1 end-to-end lifecycle acceptance (PR 7): deadlines, cancel
//! and grace-bounded shutdown observed through the public coordinator
//! API. The wire-level (`SOLVE budget_ms=` / `CANCEL`) counterparts
//! live in the service unit tests; these exercise the same machinery
//! on jobs whose *natural* runtime is minutes, so any promptness
//! assertion that passes can only be explained by preemption working.

use snowball::coordinator::{
    Backend, Coordinator, CoordinatorConfig, JobSpec, JobState,
};
use snowball::engine::{Mode, Schedule, SelectorKind};
use snowball::graph::generators;
use snowball::problems::MaxCut;
use snowball::rng::StatelessRng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A job that would run for minutes uninterrupted: the promptness
/// bounds below are only satisfiable via preemption.
fn long_job(label: &str, seed: u64, steps: u64, budget_ms: u64) -> JobSpec {
    let rng = StatelessRng::new(seed);
    let p = MaxCut::new(generators::erdos_renyi(96, 380, &[-1, 1], &rng));
    JobSpec {
        model: Arc::new(p.model().clone()),
        label: label.into(),
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 6.0, t1: 0.05 },
        steps,
        replicas: 2,
        seed,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
        budget_ms,
        max_retries: 0,
        backend: Backend::Native,
        portfolio: None,
    }
}

/// Acceptance: a `budget_ms = 50` job over an instance sized for
/// minutes of work comes back `TimedOut` promptly, with a well-formed
/// best-so-far partial result from every replica.
#[test]
fn deadline_preempts_oversized_job_within_envelope() {
    let coord = Coordinator::start(2);
    let t0 = Instant::now();
    let id = coord.submit(long_job("deadline", 11, 2_000_000_000, 50));
    let r = coord.wait(id).expect("timed-out job still publishes a result");
    let elapsed = t0.elapsed();
    assert_eq!(coord.state(id), Some(JobState::TimedOut));
    assert!(!r.completed, "a preempted job must not claim completion");
    assert_eq!(r.replicas.len(), 2, "partial result covers every replica");
    // Promptness: the nominal acceptance envelope is ~2× the budget;
    // the CI bound is looser (shared runners stall arbitrarily) but
    // still orders of magnitude below the natural runtime, so only
    // working preemption can pass it.
    assert!(elapsed < Duration::from_secs(30), "preemption too slow: {elapsed:?}");
    // The partial result carries a real incumbent, not a placeholder.
    assert!(r.best_energy() < i64::MAX, "partial result must carry an incumbent energy");
    for rep in &r.replicas {
        assert!(rep.wall < Duration::from_secs(30), "replica wall time out of envelope");
    }
    assert_eq!(coord.metrics.get("jobs_timed_out"), 1);
    assert_eq!(coord.metrics.get("jobs_done"), 0);
    coord.shutdown();
}

/// Satellite (a): with `shutdown_grace_ms` set, `shutdown` under a
/// 10⁹-step in-flight job completes promptly — the job is preempted to
/// `Cancelled` with its best-so-far published, instead of the legacy
/// drain waiting minutes for it.
#[test]
fn shutdown_grace_aborts_billion_step_job_promptly() {
    let coord = Coordinator::start_with(CoordinatorConfig {
        workers: 2,
        shutdown_grace_ms: 50,
        ..Default::default()
    });
    let id = coord.submit(long_job("grace", 13, 1_000_000_000, 0));
    // Let it get off the queue and into the pool, so the grace path
    // (not the pre-dispatch shortcut) is what aborts it.
    let t0 = Instant::now();
    while coord.state(id) == Some(JobState::Queued) && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(coord.state(id), Some(JobState::Running), "job never started");
    let t1 = Instant::now();
    coord.shutdown();
    let r = coord.wait(id).expect("aborted job still publishes best-so-far");
    assert!(
        t1.elapsed() < Duration::from_secs(30),
        "shutdown grace did not preempt promptly: {:?}",
        t1.elapsed()
    );
    assert_eq!(coord.state(id), Some(JobState::Cancelled));
    assert!(!r.completed);
    assert_eq!(r.replicas.len(), 2);
    assert_eq!(coord.metrics.get("jobs_cancelled"), 1);
}

/// Cancel is idempotent-safe across the whole lifecycle: before
/// dispatch, mid-run, and after the terminal state it returns the
/// documented verdicts and the job ends `Cancelled` exactly once.
#[test]
fn cancel_verdicts_across_the_lifecycle() {
    // Serial single worker: the second job is guaranteed still queued
    // while the first runs.
    let coord = Coordinator::start_serial(1);
    let head = coord.submit(long_job("head", 17, 500_000_000, 0));
    let queued = coord.submit(long_job("queued", 19, 500_000_000, 0));
    assert!(coord.cancel(queued), "cancelling a queued job");
    assert!(coord.cancel(head), "cancelling the running job");
    let rq = coord.wait(queued).expect("queued-cancel publishes a result");
    let rh = coord.wait(head).expect("running-cancel publishes a result");
    assert_eq!(coord.state(queued), Some(JobState::Cancelled));
    assert_eq!(coord.state(head), Some(JobState::Cancelled));
    // Pre-dispatch cancel never ran a replica; mid-run cancel ran some.
    assert!(rq.replicas.is_empty(), "pre-dispatch cancel must not run replicas");
    assert!(!rh.completed && !rq.completed);
    // Terminal and unknown ids refuse.
    assert!(!coord.cancel(head), "cancel after terminal must refuse");
    assert!(!coord.cancel(424242), "cancel of unknown id must refuse");
    assert_eq!(coord.metrics.get("jobs_cancelled"), 2);
    assert_eq!(coord.committed_weight(), 0, "admission budget must drain");
    coord.shutdown();
}
