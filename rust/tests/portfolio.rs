//! Portfolio-layer integration tests: winner optimality across the
//! roster, seeded determinism of a full-budget race, loser cancellation
//! on a target hit, admission-budget conservation through the
//! coordinator, and (under `--features failpoints`) a panicking
//! contender not failing the race.

use snowball::coordinator::{Backend, Coordinator, JobSpec};
use snowball::engine::{Mode, Schedule, SelectorKind};
use snowball::graph::generators;
use snowball::ising::IsingModel;
use snowball::portfolio::{race, resolve_roster, PortfolioSpec, RaceConfig};
use snowball::problems::{landscape, MaxCut};
use snowball::rng::StatelessRng;
use snowball::stop::StopToken;
use std::sync::Arc;

/// The failpoint registry is process-global, so the failpoint test must
/// not overlap any other race in this binary. Every test takes this
/// lock; the races are small, so serializing them costs nothing.
fn serial() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn model(n: usize, m: usize, seed: u64) -> IsingModel {
    let rng = StatelessRng::new(seed);
    MaxCut::new(generators::erdos_renyi(n, m, &[-1, 1], &rng)).model().clone()
}

fn cfg(steps: u64, seed: u64, target: Option<i64>) -> RaceConfig {
    RaceConfig {
        steps,
        schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
        seed,
        target,
        pin_lanes: false,
        local_rows: false,
    }
}

fn list(names: &[&str]) -> PortfolioSpec {
    PortfolioSpec::List(names.iter().map(|s| s.to_string()).collect())
}

/// The winner is the argmin: no contender may beat it, and every
/// reported energy must be consistent with the reported spins.
#[test]
fn winner_energy_is_minimal_across_contenders() {
    let _g = serial();
    let roster_spec = list(&["rwa", "rsa", "neal", "tabu", "sb"]);
    for seed in [1u64, 2, 3] {
        let m = model(32, 120, seed);
        let roster = resolve_roster(&roster_spec, &m);
        let out = race(&m, &roster, &cfg(3_000, seed, None), Arc::new(StopToken::new()));
        let best = out.reports[out.winner].best_energy;
        for r in &out.reports {
            assert!(!r.panicked, "{} panicked", r.name);
            assert_eq!(r.best_energy, m.energy(&r.best_spins), "{} spins/energy", r.name);
            assert!(best <= r.best_energy, "winner beaten by {} (seed {seed})", r.name);
        }
    }
}

/// With no target the race always runs to budget, so the same seed and
/// roster must reproduce the winner, every report, and the incumbent
/// trajectory bit-for-bit.
#[test]
fn seeded_race_is_deterministic() {
    let _g = serial();
    let m = model(40, 150, 9);
    let roster = resolve_roster(&list(&["rsa", "rwa", "neal", "tabu"]), &m);
    let run = || race(&m, &roster, &cfg(4_000, 9, None), Arc::new(StopToken::new()));
    let (a, b) = (run(), run());
    assert_eq!(a.winner, b.winner);
    assert_eq!(a.trajectory, b.trajectory);
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.best_energy, rb.best_energy, "{}", ra.name);
        assert_eq!(ra.attempts, rb.attempts, "{}", ra.name);
        assert!(ra.stopped.is_none(), "{} preempted without a target", ra.name);
    }
}

/// First incumbent at the ground state ends the race: every stop token
/// (winner's included) is tripped, and the winning energy reaches the
/// target.
#[test]
fn target_hit_trips_every_loser() {
    let _g = serial();
    let m = model(16, 40, 4);
    let (_, optimum) = landscape::ground_state(&m);
    let roster = resolve_roster(&list(&["tabu", "rwa", "neal", "rsa"]), &m);
    let out = race(&m, &roster, &cfg(50_000, 7, Some(optimum)), Arc::new(StopToken::new()));
    assert_eq!(out.reports[out.winner].best_energy, optimum, "16 spins must reach optimum");
    assert!(
        out.tokens.iter().all(|t| t.is_stopped()),
        "target hit must trip every contender token"
    );
}

/// A portfolio job through the coordinator: `replicas` is normalized to
/// one race, the result carries one `ReplicaResult` per roster slot plus
/// the `PortfolioOutcome`, and the admission budget fully drains.
#[test]
fn coordinator_portfolio_job_conserves_admission_budget() {
    let _g = serial();
    let m = model(32, 100, 6);
    let coord = Coordinator::start(2);
    let id = coord.submit(JobSpec {
        model: Arc::new(m),
        label: "race".into(),
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
        steps: 2_000,
        replicas: 7, // normalized away: a portfolio job is one race
        seed: 3,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
        budget_ms: 0,
        max_retries: 0,
        backend: Backend::Native,
        portfolio: Some(list(&["rsa", "neal", "tabu"])),
    });
    let r = coord.wait(id).expect("portfolio job completes");
    assert_eq!(r.replicas.len(), 3, "one ReplicaResult per roster slot");
    let p = r.portfolio.as_ref().expect("portfolio outcome present");
    assert_eq!(p.contenders, vec!["rsa".to_string(), "neal".into(), "tabu".into()]);
    assert!(p.contenders.contains(&p.winner), "winner from the roster: {}", p.winner);
    let best = r.best_energy();
    let widx = p.contenders.iter().position(|c| *c == p.winner).unwrap();
    assert_eq!(r.replicas[widx].best_energy, best, "winner is the argmin replica");
    assert_eq!(coord.committed_weight(), 0, "admission budget must drain");
    coord.shutdown();
}

/// One contender dying mid-race (the `portfolio.contender` failpoint)
/// costs its slot, not the race: the survivors still elect a winner.
#[cfg(feature = "failpoints")]
#[test]
fn panicking_contender_does_not_fail_the_race() {
    let _g = serial();
    snowball::failpoint::disarm_all();
    snowball::failpoint::arm_panic("portfolio.contender", 0);
    let m = model(24, 60, 5);
    let roster = resolve_roster(&list(&["rwa", "neal", "tabu"]), &m);
    let out = race(&m, &roster, &cfg(2_000, 3, None), Arc::new(StopToken::new()));
    snowball::failpoint::disarm_all();
    let dead = out.reports.iter().filter(|r| r.panicked).count();
    assert_eq!(dead, 1, "the one-shot failpoint kills exactly one contender");
    let w = &out.reports[out.winner];
    assert!(!w.panicked, "a panicked slot never wins");
    assert_eq!(w.best_energy, m.energy(&w.best_spins));
}
