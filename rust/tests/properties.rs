//! Property-based tests over the core invariants (via the in-repo
//! `testutil::Cases` helper — the offline stand-in for proptest).

use snowball::bitplane::BitPlanes;
use snowball::coordinator::{batcher, Registry};
use snowball::engine::{
    Datapath, EngineConfig, LaneKernel, Mode, PwlLogistic, Schedule, SelectorKind, SnowballEngine,
};
use snowball::ising::{IsingModel, SpinVec};
use snowball::problems::quantize;
use snowball::rng::salt;
use snowball::testutil::{gen, Cases};

/// The batch planner partitions: every job appears in exactly one group
/// (or overflow), every assignment respects its class capacity and is
/// the *smallest* fitting class, and overflow jobs fit no class.
#[test]
fn prop_batch_plan_partitions_jobs() {
    Cases::new(0xB1, 80).run(|rng, size| {
        let jobs = size * 2;
        let sizes: Vec<usize> =
            (0..jobs).map(|j| 1 + rng.below(50, j as u64, salt::PROBLEM, 3000) as usize).collect();
        let mut classes: Vec<usize> = (0..(1 + size / 8))
            .map(|k| 1 + rng.below(51, k as u64, salt::PROBLEM, 2500) as usize)
            .collect();
        classes.push(64); // at least one plausible class
        let plan = batcher::plan(&sizes, &classes);

        // Exactly-once partition.
        let mut seen = vec![0u32; jobs];
        for a in &plan.assignments {
            seen[a.job] += 1;
        }
        for &j in &plan.overflow {
            seen[j] += 1;
        }
        if seen.iter().any(|&c| c != 1) {
            return Err(format!("jobs not partitioned exactly once: {seen:?}"));
        }
        // Groups list the same assignments, each under its class.
        let grouped: usize = plan.groups().iter().map(|(_, g)| g.len()).sum();
        if grouped != plan.assignments.len() {
            return Err("groups() dropped or duplicated an assignment".into());
        }
        // Capacity + smallest-fit, and overflow really fits nowhere.
        let max_class = classes.iter().copied().max().unwrap();
        for a in &plan.assignments {
            if sizes[a.job] > a.class_n {
                let (j, s) = (a.job, sizes[a.job]);
                return Err(format!("job {j} (size {s}) over class {}", a.class_n));
            }
            if classes.iter().any(|&c| c >= sizes[a.job] && c < a.class_n) {
                return Err(format!("job {} not in smallest fitting class", a.job));
            }
        }
        for &j in &plan.overflow {
            if sizes[j] <= max_class {
                return Err(format!("job {j} overflowed but fits class {max_class}"));
            }
        }
        Ok(())
    });
}

/// Exact-fit sizes waste nothing: when every job size is itself a class,
/// `padding_waste` is exactly 0.
#[test]
fn prop_batch_padding_waste_zero_for_exact_fits() {
    Cases::new(0xB2, 60).run(|rng, size| {
        let classes: Vec<usize> = (0..size.max(1))
            .map(|k| 1 + rng.below(52, k as u64, salt::PROBLEM, 4000) as usize)
            .collect();
        // Jobs drawn *from* the class list → every assignment is exact.
        let sizes: Vec<usize> = (0..size * 2)
            .map(|j| classes[rng.below(53, j as u64, salt::PROBLEM, classes.len() as u32) as usize])
            .collect();
        let plan = batcher::plan(&sizes, &classes);
        if !plan.overflow.is_empty() {
            return Err("exact-fit jobs cannot overflow".into());
        }
        let waste = plan.padding_waste(&sizes);
        if waste != 0.0 {
            return Err(format!("exact fits must waste nothing, got {waste}"));
        }
        Ok(())
    });
}

/// ΔE from the local field equals the brute-force energy difference, for
/// arbitrary models, configurations and flip targets.
#[test]
fn prop_delta_e_equals_energy_difference() {
    Cases::new(0xA1, 60).run(|rng, size| {
        let n = size.max(2);
        let m = gen::model(rng, n, 9);
        let mut s = gen::spins(rng, n);
        let i = rng.below(1, 0, salt::SITE, n as u32) as usize;
        let e0 = m.energy(&s);
        let de = IsingModel::delta_e(s.get(i), m.local_field(&s, i));
        s.flip(i);
        let e1 = m.energy(&s);
        if e1 - e0 != de {
            return Err(format!("ΔE {de} but energies moved {}", e1 - e0));
        }
        Ok(())
    });
}

/// Incremental bit-plane field updates track full recomputation across
/// arbitrary flip sequences (Eqs. 17–20 vs Eq. 16).
#[test]
fn prop_bitplane_incremental_tracks_reinit() {
    Cases::new(0xA2, 30).run(|rng, size| {
        let n = size.max(2);
        let m = gen::model(rng, n, 31);
        let bp = BitPlanes::encode(&m, None);
        let mut s = gen::spins(rng, n);
        let mut u = bp.init_fields(&s);
        for t in 0..(3 * n as u64) {
            let j = rng.below(2, t, salt::SITE, n as u32) as usize;
            let s_old = s.flip(j);
            bp.incr_update(&mut u, j, s_old);
        }
        if u != bp.init_fields(&s) {
            return Err("incremental fields drifted from reinit".into());
        }
        Ok(())
    });
}

/// Bit-plane encode/decode round-trips any integer matrix that fits the
/// plane budget (Eq. 13).
#[test]
fn prop_bitplane_roundtrip() {
    Cases::new(0xA3, 40).run(|rng, size| {
        let n = size.max(2);
        let max_abs = 1 + rng.below(3, 0, salt::PROBLEM, 2000) as i32;
        let m = gen::model(rng, n, max_abs);
        let bp = BitPlanes::encode(&m, None);
        let d = bp.decode();
        if d.j_matrix() != m.j_matrix() {
            return Err(format!("roundtrip failed at n={n}, max_abs={max_abs}"));
        }
        Ok(())
    });
}

/// The engine's incrementally tracked energy and fields always match the
/// dense oracle after arbitrary runs, in every mode × datapath.
#[test]
fn prop_engine_state_consistency() {
    Cases::new(0xA4, 18).run(|rng, size| {
        let n = (size + 2).min(48);
        let m = gen::model(rng, n, 5);
        let mode = match rng.below(4, 0, salt::PROBLEM, 3) {
            0 => Mode::RandomScan,
            1 => Mode::RouletteWheel,
            _ => Mode::RouletteUniformized,
        };
        let dp = if rng.below(5, 0, salt::PROBLEM, 2) == 0 {
            Datapath::Dense
        } else {
            Datapath::BitPlane
        };
        let selector = if rng.below(7, 0, salt::PROBLEM, 2) == 0 {
            SelectorKind::LinearScan
        } else {
            SelectorKind::Fenwick
        };
        let cfg = EngineConfig {
            mode,
            datapath: dp,
            selector,
            schedule: Schedule::Geometric { t0: 4.0, t1: 0.1 },
            steps: 200,
            seed: rng.u64(6, 0, salt::PROBLEM),
            planes: None,
            trace_stride: 0,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
        };
        let mut e = SnowballEngine::new(&m, cfg);
        e.run();
        if e.energy() != m.energy(e.spins()) {
            return Err(format!("energy drift in {mode:?}/{dp:?}"));
        }
        if e.fields() != &m.local_fields(e.spins())[..] {
            return Err(format!("field drift in {mode:?}/{dp:?}"));
        }
        Ok(())
    });
}

/// Lane-kernel dirty-set invariant: for an arbitrary model, an
/// arbitrary contiguous sub-range, and an arbitrary interleaving of
/// local flips, remote flips and temperature changes, the kernel's
/// incrementally maintained weights after a sync equal a from-scratch
/// bulk evaluation of the current configuration, its fields track the
/// dense oracle, and Fenwick selection matches the linear-scan
/// reference — through the CSR and the bit-plane delta sources.
#[test]
fn prop_lane_kernel_dirty_set_tracks_bulk_refresh() {
    Cases::new(0xC3, 16).run(|rng, size| {
        let n = (size + 8).min(72);
        let m = gen::model(rng, n, 4);
        let adj = m.adjacency();
        let bp = BitPlanes::encode(&m, None);
        let lut = PwlLogistic::default();
        // Random non-empty sub-range.
        let lo = rng.below(20, 0, salt::SITE, (n as u32) / 2 + 1) as usize;
        let hi = (lo + 1 + rng.below(21, 0, salt::SITE, (n - lo) as u32) as usize).min(n);
        for (label, use_adj) in [("csr", true), ("bitplane", false)] {
            let adj = use_adj.then_some(&adj);
            let planes = (!use_adj).then_some(&bp);
            let mut spins = gen::spins(rng, n);
            let u = m.local_fields(&spins);
            let mut k = LaneKernel::new(lo..hi, &spins, &u, &lut, true);
            for step in 0..8u64 {
                // Plateaus of 4 steps, then a temperature change.
                let temp = if step < 4 { 1.3 } else { 0.7 };
                for f in 0..3u64 {
                    let j = rng.below(22, step * 8 + f, salt::SITE, n as u32) as usize;
                    if (lo..hi).contains(&j) {
                        let (_, _, de) = k.flip_local(&m, adj, planes, j - lo);
                        let want = IsingModel::delta_e(spins.get(j), m.local_field(&spins, j));
                        if de != want {
                            return Err(format!("{label}: ΔE {de} != oracle {want}"));
                        }
                        spins.flip(j);
                    } else {
                        let s_old = spins.flip(j);
                        k.apply_remote(&m, adj, planes, j, s_old);
                    }
                }
                let u_now = m.local_fields(&spins);
                if k.fields() != &u_now[lo..hi] {
                    return Err(format!("{label}: fields drifted at step {step}"));
                }
                let w = k.sync_weights(&lut, temp);
                // Bulk reference over the same range.
                let mut local = SpinVec::all_down(hi - lo);
                for i in lo..hi {
                    local.set(i - lo, spins.get(i));
                }
                let ctx = lut.lane_ctx(temp);
                let mut want = vec![0u32; hi - lo];
                let w_want = lut.eval_lanes(&ctx, &u_now[lo..hi], local.words(), &mut want);
                if w != w_want {
                    return Err(format!("{label}: W {w} != bulk {w_want} at step {step}"));
                }
                if k.weights() != &want[..] {
                    return Err(format!("{label}: weights diverged at step {step}"));
                }
                if w > 0 {
                    for trial in 0..6u64 {
                        let r = rng.u64(23, step * 100 + trial, salt::ROULETTE) % w;
                        let mut acc = 0u64;
                        let mut linear = want.len() - 1;
                        for (i, &pw) in want.iter().enumerate() {
                            acc += pw as u64;
                            if r < acc {
                                linear = i;
                                break;
                            }
                        }
                        if k.select_local(r) != linear {
                            return Err(format!("{label}: selection diverged at r = {r}"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Quantization never *increases* coefficient magnitude and the shifted
/// model's coefficients equal the arithmetic shift exactly.
#[test]
fn prop_quantization_shrinks() {
    Cases::new(0xA5, 40).run(|rng, size| {
        let n = size.max(2);
        let m = gen::model(rng, n, 100);
        let bits = rng.below(7, 0, salt::PROBLEM, 4) + 1;
        let q = quantize::arithmetic_shift(&m, bits);
        for i in 0..n {
            for k in 0..n {
                if i != k && q.j(i, k) != m.j(i, k) >> bits {
                    return Err(format!("bad shift at ({i},{k})"));
                }
                if q.j(i, k).abs() > m.j(i, k).abs() {
                    return Err("magnitude grew".into());
                }
            }
        }
        Ok(())
    });
}

/// Engine trajectories are a pure function of the seed (stateless RNG):
/// same seed → identical runs, different seed → different runs (whp).
#[test]
fn prop_seed_determinism() {
    Cases::new(0xA6, 15).run(|rng, size| {
        let n = (size + 4).min(40);
        let m = gen::model(rng, n, 3);
        let run = |seed: u64| {
            let cfg = EngineConfig::new(Mode::RouletteWheel, 150, seed);
            let mut e = SnowballEngine::new(&m, cfg);
            let r = e.run();
            (r.final_energy, r.flips)
        };
        let s = rng.u64(8, 0, salt::PROBLEM);
        if run(s) != run(s) {
            return Err("same seed diverged".into());
        }
        Ok(())
    });
}

/// Max-Cut cut/energy bijection holds on arbitrary graphs and configs.
#[test]
fn prop_maxcut_cut_energy_identity() {
    Cases::new(0xA7, 40).run(|rng, size| {
        let n = size.max(2);
        let m_edges = (n * (n - 1) / 2).min(4 * n);
        let g = snowball::graph::generators::erdos_renyi(n, m_edges, &[-2, -1, 1, 3], rng);
        let p = snowball::problems::MaxCut::new(g);
        let s = gen::spins(rng, n);
        let via_energy = p.cut_of_energy(p.model().energy(&s));
        if via_energy != p.cut_value(&s) {
            return Err("cut/energy identity failed".into());
        }
        Ok(())
    });
}

/// SpinVec word packing: get/set/flip/count agree with a Vec<i8> mirror.
#[test]
fn prop_spinvec_matches_mirror() {
    Cases::new(0xA8, 40).run(|rng, size| {
        let n = size * 3 + 1; // exercise word boundaries
        let mut v = SpinVec::all_down(n);
        let mut mirror = vec![-1i8; n];
        for t in 0..(2 * n as u64) {
            let i = rng.below(9, t, salt::SITE, n as u32) as usize;
            match rng.below(10, t, salt::PROBLEM, 3) {
                0 => {
                    v.set(i, 1);
                    mirror[i] = 1;
                }
                1 => {
                    v.set(i, -1);
                    mirror[i] = -1;
                }
                _ => {
                    v.flip(i);
                    mirror[i] = -mirror[i];
                }
            }
        }
        if v.to_spins() != mirror {
            return Err("mirror mismatch".into());
        }
        if v.count_up() != mirror.iter().filter(|&&s| s == 1).count() {
            return Err("count mismatch".into());
        }
        Ok(())
    });
}

/// Job lifecycle legality (PR 7): under arbitrary cancel timing and
/// random deadlines, every observed per-job state sequence is a prefix
/// of Queued → Running → {Done, Failed, Cancelled, TimedOut} (with the
/// pre-dispatch shortcut Queued → {Cancelled, TimedOut} allowed), and a
/// terminal state, once observed, never changes — no resurrection.
#[test]
fn prop_job_state_transitions_are_legal() {
    use snowball::coordinator::{Backend, Coordinator, JobSpec, JobState};
    use std::sync::Arc;

    fn rank(s: &JobState) -> u8 {
        match s {
            JobState::Queued => 0,
            JobState::Running => 1,
            _ => 2, // terminal
        }
    }

    Cases::new(0xD7, 8).run(|rng, size| {
        let n = (size + 4).min(24);
        let m = gen::model(rng, n, 3);
        let coord = Coordinator::start(2);
        let jobs = 3usize;
        let mut ids = Vec::new();
        for j in 0..jobs {
            // A size mix: some finish instantly, some run long enough to
            // be caught Running (and to need the cancel below).
            let steps = 500 + 40_000 * rng.below(30, j as u64, salt::PROBLEM, 500) as u64;
            ids.push(coord.submit(JobSpec {
                model: Arc::new(m.clone()),
                label: format!("prop-{j}"),
                mode: Mode::RouletteWheel,
                selector: SelectorKind::Fenwick,
                schedule: Schedule::Geometric { t0: 4.0, t1: 0.1 },
                steps,
                replicas: 2,
                seed: rng.u64(31, j as u64, salt::PROBLEM),
                target_energy: None,
                shards: 1,
                pin_lanes: false,
                local_rows: false,
                // A third of the jobs carry a tight deadline.
                budget_ms: if rng.below(32, j as u64, salt::PROBLEM, 3) == 0 { 5 } else { 0 },
                max_retries: 0,
                backend: Backend::Native,
                portfolio: None,
            }));
        }
        let mut last: Vec<Option<JobState>> = vec![None; jobs];
        let mut cancelled = false;
        let t0 = std::time::Instant::now();
        loop {
            let mut all_terminal = true;
            for (k, &id) in ids.iter().enumerate() {
                let s = coord.state(id).ok_or_else(|| format!("job {id} state vanished"))?;
                if let Some(prev) = &last[k] {
                    if rank(&s) < rank(prev) {
                        return Err(format!("job {k} went backwards: {prev:?} -> {s:?}"));
                    }
                    if rank(prev) == 2 && s != *prev {
                        return Err(format!("job {k} resurrected: {prev:?} -> {s:?}"));
                    }
                }
                all_terminal &= rank(&s) == 2;
                last[k] = Some(s);
            }
            if all_terminal {
                break;
            }
            // Mid-flight, cancel one arbitrary job (may race with its
            // natural completion — both orders must stay legal).
            if !cancelled && t0.elapsed().as_millis() > 2 {
                let victim = ids[rng.below(33, 0, salt::PROBLEM, jobs as u32) as usize];
                coord.cancel(victim);
                cancelled = true;
            }
            if t0.elapsed() > std::time::Duration::from_secs(60) {
                return Err(format!("jobs wedged; last states {last:?}"));
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        coord.shutdown();
        Ok(())
    });
}

/// The batcher never drops or duplicates jobs, and never assigns a class
/// smaller than the job.
#[test]
fn prop_batcher_conservation() {
    Cases::new(0xA9, 50).run(|rng, _| {
        let n_jobs = 1 + rng.below(11, 0, salt::PROBLEM, 40) as usize;
        let sizes: Vec<usize> =
            (0..n_jobs).map(|i| 1 + rng.below(12, i as u64, salt::PROBLEM, 5000) as usize).collect();
        let classes = [256usize, 800, 2048];
        let plan = snowball::coordinator::batcher::plan(&sizes, &classes);
        let mut seen = vec![false; n_jobs];
        for a in &plan.assignments {
            if seen[a.job] {
                return Err("duplicate assignment".into());
            }
            seen[a.job] = true;
            if a.class_n < sizes[a.job] {
                return Err("class too small".into());
            }
        }
        for &j in &plan.overflow {
            if seen[j] {
                return Err("overflow double-assigned".into());
            }
            seen[j] = true;
            if sizes[j] <= 2048 {
                return Err("fit job sent to overflow".into());
            }
        }
        if !seen.iter().all(|&b| b) {
            return Err("job dropped".into());
        }
        Ok(())
    });
}

/// Rebuild `m` by applying its couplings and fields in the opposite
/// order — an "equivalent upload" whose wire body is a permutation of
/// the original's.
fn rebuilt_in_reverse(m: &IsingModel) -> IsingModel {
    let n = m.len();
    let mut r = IsingModel::zeros(n);
    for i in (0..n).rev() {
        for k in ((i + 1)..n).rev() {
            if m.j(i, k) != 0 {
                r.set_j(i, k, m.j(i, k));
            }
        }
        r.set_h(i, m.h(i));
    }
    r
}

/// Registry hashing is canonical: equivalent uploads built in any row
/// order collapse to one hash (and one entry, via dedup), while
/// perturbing a single coupling yields a distinct hash and entry.
#[test]
fn prop_registry_hash_order_invariant_and_perturbation_sensitive() {
    Cases::new(0xE1, 40).run(|rng, size| {
        let n = (size + 2).min(64);
        let m = gen::model(rng, n, 9);
        let reg = Registry::with_defaults();
        let h1 = reg.put(m.clone()).map_err(|e| e.to_string())?;
        let h2 = reg.put(rebuilt_in_reverse(&m)).map_err(|e| e.to_string())?;
        if h1 != h2 {
            return Err(format!("equivalent uploads hashed apart: {h1} vs {h2}"));
        }
        let s = reg.stats();
        if s.entries != 1 || s.dedup != 1 {
            return Err(format!("dedup failed: {} entries, {} dedup", s.entries, s.dedup));
        }
        // Perturb one off-diagonal coupling: the hash must move.
        let i = rng.below(40, 0, salt::SITE, n as u32) as usize;
        let k = (i + 1 + rng.below(41, 0, salt::SITE, (n - 1) as u32) as usize) % n;
        let mut p = m.clone();
        p.set_j(i, k, m.j(i, k) + 1 + rng.below(42, 0, salt::PROBLEM, 7) as i32);
        let h3 = reg.put(p).map_err(|e| e.to_string())?;
        if h3 == h1 {
            return Err(format!("perturbed ({i},{k}) but hash unchanged"));
        }
        if reg.stats().entries != 2 {
            return Err("perturbed model should be a second entry".into());
        }
        Ok(())
    });
}

/// The content digest is storage-tier invariant: force-widening a
/// packed model to i16/i32 changes its memory footprint but neither
/// its hash nor its equality — by-hash dispatch cannot fork on how a
/// client happened to pack its upload.
#[test]
fn prop_registry_hash_is_tier_invariant() {
    use snowball::ising::Tier;
    Cases::new(0xE5, 40).run(|rng, size| {
        let n = (size + 2).min(64);
        let m = gen::model(rng, n, 9); // ±9 couplings pack as i8
        if m.tier() != Tier::I8 {
            return Err(format!("expected an i8 instance, got {:?}", m.tier()));
        }
        for tier in [Tier::I16, Tier::I32] {
            let mut wide = m.clone();
            wide.force_tier(tier);
            if wide.content_digest() != m.content_digest() {
                return Err(format!("digest moved when widening to {tier:?}"));
            }
            if wide != m {
                return Err(format!("equality broke when widening to {tier:?}"));
            }
            if wide.approx_bytes() <= m.approx_bytes() {
                return Err(format!("widening to {tier:?} did not grow the footprint"));
            }
        }
        Ok(())
    });
}

/// Pin refcounts saturate at zero: arbitrary pin/unpin interleavings
/// (including over-unpinning) track a non-negative mirror, and a fresh
/// pin after an over-unpin storm still registers — the count never
/// went negative underneath.
#[test]
fn prop_registry_refcount_never_negative() {
    Cases::new(0xE2, 40).run(|rng, size| {
        let n = (size + 2).min(32);
        let reg = Registry::with_defaults();
        let h = reg.put(gen::model(rng, n, 5)).map_err(|e| e.to_string())?;
        let mut mirror: u64 = 0;
        for t in 0..40u64 {
            if rng.below(43, t, salt::PROBLEM, 3) == 0 {
                if !reg.pin(h) {
                    return Err("pin of a stored hash failed".into());
                }
                mirror += 1;
            } else {
                reg.unpin(h);
                mirror = mirror.saturating_sub(1);
            }
            let pinned = reg.stats().pinned;
            if pinned != usize::from(mirror > 0) {
                return Err(format!("pinned={pinned} but mirror refcount={mirror} at op {t}"));
            }
        }
        for _ in 0..5 {
            reg.unpin(h);
        }
        if !reg.pin(h) || reg.stats().pinned != 1 {
            return Err("refcount went negative: a fresh pin was swallowed".into());
        }
        reg.unpin(h);
        if reg.stats().pinned != 0 {
            return Err("final unpin did not release".into());
        }
        Ok(())
    });
}

/// LRU eviction under a tiny capacity never removes a pinned entry,
/// however the put/pin sequence interleaves — and it does evict
/// unpinned ones (the capacity is real).
#[test]
fn prop_registry_eviction_never_removes_pinned() {
    Cases::new(0xE3, 30).run(|rng, size| {
        let n = (size + 4).min(24);
        let base = gen::model(rng, n, 4);
        // Size slots from the PACKED footprint (every model below stays
        // at base's i8 tier) — the i32 worst case would leave the
        // capacity 4× too roomy to ever evict.
        let bytes = base.approx_bytes();
        let reg = Registry::new(bytes * 3, bytes * 2);
        let mut pinned = Vec::new();
        for t in 0..10u64 {
            // Distinct models of identical size: vary one coupling.
            let mut m = base.clone();
            m.set_j(0, 1, 1 + t as i32);
            m.set_h(0, 1 + t as i32);
            let h = reg.put(m).map_err(|e| e.to_string())?;
            if pinned.len() < 2 && rng.below(44, t, salt::PROBLEM, 2) == 0 {
                if !reg.pin(h) {
                    return Err("pin right after put failed".into());
                }
                pinned.push(h);
            }
            for &p in &pinned {
                if !reg.contains(p) {
                    return Err(format!("evicted pinned entry after put {t}"));
                }
            }
            if reg.stats().pinned != pinned.len() {
                return Err("pinned count drifted".into());
            }
        }
        if reg.stats().evictions == 0 {
            return Err("10 same-size puts into 3 slots must evict".into());
        }
        for &p in &pinned {
            reg.unpin(p);
        }
        if reg.stats().pinned != 0 {
            return Err("unpin-all left pins".into());
        }
        Ok(())
    });
}

/// Concurrent PUTs of one body from many threads converge to a single
/// entry, every caller sees the same hash, and the losers all count as
/// dedups — no duplicate storage, no lost upload.
#[test]
fn prop_registry_concurrent_put_yields_one_entry() {
    Cases::new(0xE4, 8).run(|rng, size| {
        let n = (size + 4).min(48);
        let m = gen::model(rng, n, 6);
        let reg = std::sync::Arc::new(Registry::with_defaults());
        let threads = 8usize;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let reg = reg.clone();
                let m = m.clone();
                std::thread::spawn(move || reg.put(m).expect("concurrent put"))
            })
            .collect();
        let hashes: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        if hashes.iter().any(|&h| h != hashes[0]) {
            return Err(format!("concurrent puts disagreed on the hash: {hashes:?}"));
        }
        let s = reg.stats();
        if s.entries != 1 {
            return Err(format!("{} entries after concurrent puts of one body", s.entries));
        }
        if s.dedup != (threads - 1) as u64 {
            return Err(format!("expected {} dedups, saw {}", threads - 1, s.dedup));
        }
        Ok(())
    });
}
