//! Loom permutation tests for the shard engine's synchronization core.
//!
//! These compile ONLY under `--cfg loom` + `--features loom`:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --release --features loom --test loom_shard
//! ```
//!
//! Under that flag `crate::sync` (see `rust/src/sync.rs`) resolves to
//! loom's instrumented doubles, so `loom::model` re-executes each test
//! body under EVERY thread interleaving and C11-memory-model reordering
//! its bounded explorer can produce — including ones no real machine a
//! CI job happens to run on would exhibit. A test passing here is a
//! proof (within the preemption bound) that the `FlipRing` SPSC
//! protocol and the `SyncGate` barrier have no data race, no lost
//! message and no wedged waiter, not merely an observation that one
//! scheduling didn't fail.
//!
//! State-space budget: loom's cost is exponential in threads ×
//! preemptions, so each model uses ≤ 3 threads and single-digit message
//! counts. The deterministic and stress twins of these tests (which run
//! the same protocols at scale, and under Miri) live in the in-module
//! tests of `engine/shard/mailbox.rs` and `engine/shard/gate.rs`.

#![cfg(all(loom, feature = "loom"))]

use loom::sync::Arc;
use loom::thread;
use snowball::engine::shard::gate::{GateAborted, SyncGate};
use snowball::engine::shard::mailbox::{Flip, FlipRing};

fn flip(j: u32) -> Flip {
    Flip { j, s_old: 1, step: j as u64 }
}

/// SPSC delivery across threads: a cap-2 ring carrying 3 messages must
/// hand every message over exactly once, in order, under every
/// interleaving — the producer necessarily hits both the full-ring
/// path and the wraparound slot reuse on the way.
#[test]
fn loom_ring_delivers_in_order_across_wraparound() {
    loom::model(|| {
        let ring = Arc::new(FlipRing::new(2));
        let producer = {
            let ring = ring.clone();
            thread::spawn(move || {
                for k in 0..3u32 {
                    while !ring.try_push(flip(k)) {
                        thread::yield_now();
                    }
                }
            })
        };
        let mut next = 0u32;
        while next < 3 {
            match ring.pop() {
                Some(f) => {
                    assert_eq!(f.j, next, "lost, duplicated or reordered");
                    assert_eq!(f.step, next as u64, "payload torn across the slot hand-off");
                    next += 1;
                }
                None => thread::yield_now(),
            }
        }
        producer.join().unwrap();
        assert!(ring.pop().is_none(), "exactly 3 messages, no ghosts");
    });
}

/// Full-ring refusal and post-pop resumption, checked exhaustively:
/// with the producer and consumer racing, `try_push` may refuse only
/// while 2 messages are genuinely in flight, and a refusal must always
/// be followed by eventual success once the consumer drains. The
/// deterministic single-thread twin
/// (`full_ring_backpressure_refuses_then_resumes`) pins the exact
/// refusal sequence; this model proves no interleaving breaks it.
#[test]
fn loom_ring_full_refusal_then_wraparound_reuse() {
    loom::model(|| {
        let ring = Arc::new(FlipRing::new(2));
        // Fill deterministically before the race starts.
        assert!(ring.try_push(flip(0)));
        assert!(ring.try_push(flip(1)));
        assert!(!ring.try_push(flip(9)), "full ring must refuse");
        let consumer = {
            let ring = ring.clone();
            thread::spawn(move || {
                for expect in 0..4u32 {
                    loop {
                        match ring.pop() {
                            Some(f) => {
                                assert_eq!(f.j, expect);
                                break;
                            }
                            None => thread::yield_now(),
                        }
                    }
                }
            })
        };
        // Producer: two more messages through the recycled slots.
        for k in 2..4u32 {
            while !ring.try_push(flip(k)) {
                thread::yield_now();
            }
        }
        consumer.join().unwrap();
        assert!(ring.is_empty());
    });
}

/// The consumer-side `len()` snapshot: between the consumer's own
/// operations it must exactly count the in-flight messages (0, 1 or 2
/// here), never underflowing to a wrapped huge value — under every
/// reordering of the producer's concurrent stores.
#[test]
fn loom_consumer_len_is_bounded_by_capacity() {
    loom::model(|| {
        let ring = Arc::new(FlipRing::new(2));
        let producer = {
            let ring = ring.clone();
            thread::spawn(move || {
                for k in 0..2u32 {
                    while !ring.try_push(flip(k)) {
                        thread::yield_now();
                    }
                }
            })
        };
        let mut drained = 0u32;
        while drained < 2 {
            let len = ring.len();
            assert!(len <= 2, "len() underflowed/wrapped: {len}");
            match ring.pop() {
                Some(_) => drained += 1,
                None => thread::yield_now(),
            }
        }
        producer.join().unwrap();
        assert_eq!(ring.len(), 0, "consumer-side len is exact after its own drain");
    });
}

/// Gate arrival: with 2 parties racing to the barrier, exactly one of
/// them is elected leader per round, in each of 2 consecutive rounds
/// (reuse), under every interleaving of arrivals and condvar wakeups.
#[test]
fn loom_gate_elects_exactly_one_leader_per_round() {
    loom::model(|| {
        let gate = Arc::new(SyncGate::new(2));
        let peer = {
            let gate = gate.clone();
            thread::spawn(move || {
                let mut led = 0usize;
                for _ in 0..2 {
                    if gate.wait().unwrap() {
                        led += 1;
                    }
                }
                led
            })
        };
        let mut led = 0usize;
        for _ in 0..2 {
            if gate.wait().unwrap() {
                led += 1;
            }
        }
        led += peer.join().unwrap();
        assert_eq!(led, 2, "exactly one leader in each of the 2 rounds");
    });
}

/// Abort vs. a parked waiter: whatever order the park and the abort
/// land in, the waiter must return `Err(GateAborted)` — never hang,
/// never `Ok` — and the abort must be sticky for future waits.
#[test]
fn loom_gate_abort_wakes_parked_waiter() {
    loom::model(|| {
        let gate = Arc::new(SyncGate::new(2));
        let waiter = {
            let gate = gate.clone();
            // The 2nd party never arrives (it "panicked"); only the
            // abort can release this wait.
            thread::spawn(move || gate.wait())
        };
        gate.abort();
        assert_eq!(waiter.join().unwrap(), Err(GateAborted));
        assert_eq!(gate.wait(), Err(GateAborted), "abort must be sticky");
    });
}

/// Generation rollover: a gate whose counter starts at `u64::MAX`
/// wraps to 0 on its first round. The park loop compares generations
/// by wrapping equality, so both rounds across the wrap must elect
/// exactly one leader and release every waiter — loom proves no
/// interleaving lets a waiter miss the wrapped bump and park forever.
#[test]
fn loom_gate_generation_rollover() {
    loom::model(|| {
        let gate = Arc::new(SyncGate::with_start_generation(2, u64::MAX));
        let peer = {
            let gate = gate.clone();
            thread::spawn(move || {
                let mut led = 0usize;
                for _ in 0..2 {
                    if gate.wait().unwrap() {
                        led += 1;
                    }
                }
                led
            })
        };
        let mut led = 0usize;
        for _ in 0..2 {
            if gate.wait().unwrap() {
                led += 1;
            }
        }
        led += peer.join().unwrap();
        assert_eq!(led, 2, "one leader per round straight across the u64 wrap");
    });
}
