//! Integration test: the AOT XLA anneal chunk (L1 Pallas + L2 JAX) is
//! **bit-identical** to the native Rust engine (L3) — same stateless RNG
//! streams, same Q16 PWL, same prefix-scan selection, same incremental
//! field updates. This is the strongest composition proof the three-layer
//! stack admits.
//!
//! Requires `make artifacts` to have produced `artifacts/manifest.txt`;
//! the tests are skipped (with a notice) when artifacts are missing so
//! `cargo test` stays green on a fresh checkout. The whole file is
//! compiled only with the `xla` cargo feature (PJRT bindings).

#![cfg(feature = "xla")]

use snowball::engine::{Datapath, EngineConfig, Mode, Schedule, SelectorKind, SnowballEngine};
use snowball::graph::generators;
use snowball::ising::SpinVec;
use snowball::problems::MaxCut;
use snowball::rng::StatelessRng;
use snowball::runtime::{chunk::ChunkState, ArtifactManifest, ChunkRunner, Runtime};

fn manifest_or_skip() -> Option<ArtifactManifest> {
    match ArtifactManifest::discover() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP xla parity tests: {e}");
            None
        }
    }
}

#[test]
fn chunked_xla_run_matches_native_engine_bit_for_bit() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(spec) = manifest.find("anneal_chunk", 256) else {
        eprintln!("SKIP: no anneal_chunk n=256 artifact");
        return;
    };
    let chunk_len = spec.chunk.unwrap();
    let total_steps = chunk_len * 2;
    let seed = 0xFEED_u64;

    // Instance with N == artifact N so no padding enters the picture.
    let rng = StatelessRng::new(7);
    let g = generators::erdos_renyi(256, 3000, &[-1, 1], &rng);
    let p = MaxCut::new(g);

    // Native run, roulette mode, with the exact schedule the chunk gets.
    let schedule = Schedule::Geometric { t0: 8.0, t1: 0.05 };
    let cfg = EngineConfig {
        mode: Mode::RouletteWheel,
        datapath: Datapath::Dense,
        selector: SelectorKind::Fenwick,
        schedule: schedule.clone(),
        steps: total_steps,
        seed,
        planes: None,
        trace_stride: 0,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
    };
    let init_spins = SpinVec::random(256, &StatelessRng::new(seed));
    let mut native = SnowballEngine::with_spins(p.model(), cfg, init_spins.clone());
    let native_run = native.run();

    // XLA chunked run with identical seed/stages/temperatures.
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let runner = ChunkRunner::new(&rt, spec, p.model(), seed).expect("compile artifact");
    let mut state = ChunkState::init(p.model(), init_spins);
    let temps = schedule.materialize(total_steps);
    for c in 0..(total_steps / chunk_len) {
        let lo = (c * chunk_len) as usize;
        let hi = lo + chunk_len as usize;
        runner.run_chunk(&rt, &mut state, &temps[lo..hi]).expect("run chunk");
    }

    assert_eq!(state.energy as i64, native_run.final_energy, "energy trajectories diverged");
    assert_eq!(state.spins, native.spins().clone(), "spin configurations diverged");
    let native_u: Vec<f64> = native.fields().iter().map(|&v| v as f64).collect();
    assert_eq!(state.u, native_u, "local fields diverged");
    // And the state is self-consistent against the dense oracle.
    assert_eq!(p.model().energy(&state.spins) as f64, state.energy);
}

#[test]
fn flip_probs_artifact_matches_native_lut() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(spec) = manifest.find("flip_probs", 256) else {
        eprintln!("SKIP: no flip_probs n=256 artifact");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let exe = rt.load_hlo_text(&spec.file).expect("load flip_probs");

    let rng = StatelessRng::new(3);
    let g = generators::erdos_renyi(256, 2000, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    let spins = SpinVec::random(256, &rng);
    let u = p.model().local_fields(&spins);
    let lut = snowball::engine::PwlLogistic::default();

    for temp in [0.05f64, 1.0, 8.0] {
        let s_f: Vec<f32> = (0..256).map(|i| spins.get(i) as f32).collect();
        let u_f: Vec<f64> = u.iter().map(|&v| v as f64).collect();
        let out = exe
            .run(&[
                xla::Literal::vec1(&s_f),
                xla::Literal::vec1(&u_f),
                xla::Literal::vec1(&[temp]),
            ])
            .expect("execute");
        let got: Vec<u32> = out[0].to_vec().expect("u32 output");
        for i in 0..256 {
            let de = snowball::ising::IsingModel::delta_e(spins.get(i), u[i]);
            assert_eq!(got[i], lut.flip_prob_q16(de, temp), "spin {i} at T={temp}");
        }
    }
}

#[test]
fn field_init_artifact_matches_bitplane_store() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(spec) = manifest.find_padded("field_init", 128) else {
        eprintln!("SKIP: no field_init artifact");
        return;
    };
    if spec.n != 128 {
        eprintln!("SKIP: field_init artifact is n={}, test wants 128", spec.n);
        return;
    }
    let planes_b = spec.planes.unwrap();
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let exe = rt.load_hlo_text(&spec.file).expect("load field_init");

    // Random model fitting in the artifact's plane budget.
    let max_abs = (1i32 << (planes_b - 1)) - 1;
    let rng = StatelessRng::new(11);
    let mut m = snowball::ising::IsingModel::zeros(128);
    let mut idx = 0u64;
    for i in 0..128 {
        for k in (i + 1)..128 {
            let v = rng.below(9, idx, snowball::rng::salt::PROBLEM, (2 * max_abs + 1) as u32)
                as i32
                - max_abs;
            idx += 1;
            if v != 0 {
                m.set_j(i, k, v);
            }
        }
    }
    let spins = SpinVec::random(128, &rng);
    let bp = snowball::bitplane::BitPlanes::encode(&m, Some(planes_b));
    let want = bp.init_fields(&spins);

    // Build signed planes input [B, N, N] from the model.
    let n = 128usize;
    let mut planes = vec![0f32; planes_b as usize * n * n];
    for b in 0..planes_b as usize {
        for i in 0..n {
            for k in 0..n {
                let v = m.j(i, k);
                let mag = v.unsigned_abs();
                if (mag >> b) & 1 == 1 {
                    planes[(b * n + i) * n + k] = if v > 0 { 1.0 } else { -1.0 };
                }
            }
        }
    }
    let s_f: Vec<f32> = (0..n).map(|i| spins.get(i) as f32).collect();
    let planes_lit = xla::Literal::vec1(&planes)
        .reshape(&[planes_b as i64, n as i64, n as i64])
        .expect("reshape");
    let out = exe.run(&[planes_lit, xla::Literal::vec1(&s_f)]).expect("execute");
    let got: Vec<f64> = out[0].to_vec().expect("f64 output");
    for i in 0..n {
        assert_eq!(got[i] as i64, want[i], "field {i}");
    }
}
