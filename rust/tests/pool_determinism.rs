//! Determinism and correctness of the parallel replica layer.
//!
//! The stateless RNG makes every replica stream a pure function of
//! `child(index)`, so fanning replicas over the [`ReplicaPool`] must be
//! **bit-identical** to serial execution — these tests pin that contract
//! at the three places that use the pool: `ParallelTempering`, the
//! coordinator's `ReplicaScheduler`, and concurrent `Coordinator` job
//! submission.
//!
//! [`ReplicaPool`]: snowball::engine::ReplicaPool

use snowball::coordinator::{Backend, Coordinator, JobSpec, ReplicaScheduler};
use snowball::engine::{Mode, ParallelTempering, ReplicaPool, Schedule, SelectorKind};
use snowball::graph::generators;
use snowball::problems::MaxCut;
use snowball::rng::StatelessRng;
use std::sync::Arc;

/// The tentpole determinism guarantee: `ParallelTempering::run` with one
/// worker and with many workers produces identical `best_energy`,
/// `best_spins` and `swap_rates` for the same seed.
#[test]
fn tempering_is_bit_identical_across_worker_counts() {
    let rng = StatelessRng::new(17);
    let g = generators::erdos_renyi(96, 600, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    for mode in [Mode::RandomScan, Mode::RouletteWheel, Mode::RouletteUniformized] {
        let run = |workers: usize| {
            ParallelTempering::geometric(6, 6.0, 0.3, mode)
                .with_workers(workers)
                .run(p.model(), 20_000, 11)
        };
        let serial = run(1);
        let wide = run(8);
        assert_eq!(serial.best_energy, wide.best_energy, "{mode:?}: best energy diverged");
        assert_eq!(serial.best_spins, wide.best_spins, "{mode:?}: best spins diverged");
        assert_eq!(serial.swap_rates, wide.swap_rates, "{mode:?}: swap rates diverged");
        assert_eq!(serial.steps, wide.steps);
        // And the result is self-consistent against the dense oracle.
        assert_eq!(serial.best_energy, p.model().energy(&serial.best_spins));
    }
}

/// Reusing one pool across runs (the coordinator's pattern, via
/// `run_on`) changes nothing either.
#[test]
fn tempering_run_on_shared_pool_matches_fresh_pool() {
    let rng = StatelessRng::new(23);
    let g = generators::erdos_renyi(48, 220, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    let pt = ParallelTempering::geometric(4, 5.0, 0.4, Mode::RouletteWheel);
    let fresh = pt.run(p.model(), 8_000, 5);
    let pool = ReplicaPool::new(3);
    let shared_a = pt.run_on(&pool, p.model(), 8_000, 5);
    let shared_b = pt.run_on(&pool, p.model(), 8_000, 5);
    assert_eq!(fresh.best_energy, shared_a.best_energy);
    assert_eq!(shared_a.best_energy, shared_b.best_energy);
    assert_eq!(shared_a.best_spins, shared_b.best_spins);
    assert_eq!(fresh.swap_rates, shared_a.swap_rates);
}

fn job(label: &str, seed: u64, replicas: u32) -> JobSpec {
    let rng = StatelessRng::new(seed);
    let p = MaxCut::new(generators::erdos_renyi(40, 160, &[-1, 1], &rng));
    JobSpec {
        model: Arc::new(p.model().clone()),
        label: label.into(),
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 6.0, t1: 0.05 },
        steps: 1_500,
        replicas,
        seed,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
        budget_ms: 0,
        max_retries: 0,
        backend: Backend::Native,
        portfolio: None,
    }
}

/// Concurrent submission from many client threads to the (default)
/// overlapping dispatcher: every job's result must equal a serial
/// single-worker reference run of the same spec — i.e. the queue, the
/// size-class batcher and the per-replica work items route nothing to
/// the wrong job and perturb no replica stream.
#[test]
fn concurrent_jobs_match_serial_reference_results() {
    let coord = Coordinator::start(4);
    let mut handles = Vec::new();
    for k in 0..6u64 {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let label = format!("job-{k}");
            let spec = job(&label, 100 + k, 4);
            let id = coord.submit(spec);
            let result = coord.wait(id).expect("job must finish");
            (k, id, result)
        }));
    }
    let serial = ReplicaScheduler::new(1);
    for h in handles {
        let (k, id, result) = h.join().unwrap();
        assert_eq!(result.job_id, id);
        assert_eq!(result.label, format!("job-{k}"));
        assert_eq!(result.replicas.len(), 4);
        // Reference: the same spec executed serially.
        let expect = serial.run_native(&job(&format!("job-{k}"), 100 + k, 4));
        let got: Vec<(u32, i64, u64)> =
            result.replicas.iter().map(|r| (r.replica, r.best_energy, r.flips)).collect();
        let want: Vec<(u32, i64, u64)> =
            expect.iter().map(|r| (r.replica, r.best_energy, r.flips)).collect();
        assert_eq!(got, want, "job {k}: parallel results diverged from serial reference");
    }
    coord.shutdown();
}

/// The dispatch mode is invisible in results: a burst of mixed-size
/// jobs through the serial dispatcher and through the overlapping
/// dispatcher produces identical replica tuples job-for-job.
#[test]
fn overlapping_dispatcher_is_bit_identical_to_serial_dispatcher() {
    let specs: Vec<JobSpec> = (0..8u64)
        .map(|k| {
            let mut s = job(&format!("mix-{k}"), 300 + k, 3);
            // Mixed sizes so the batcher forms several class groups.
            if k % 2 == 1 {
                let rng = StatelessRng::new(300 + k);
                let p = MaxCut::new(generators::erdos_renyi(80, 400, &[-1, 1], &rng));
                s.model = Arc::new(p.model().clone());
            }
            s
        })
        .collect();
    let run = |coord: Coordinator| -> Vec<Vec<(u32, i64, u64)>> {
        let ids: Vec<u64> = specs.iter().map(|s| coord.submit(s.clone())).collect();
        let out = ids
            .iter()
            .map(|&id| {
                let r = coord.wait(id).expect("job finishes");
                r.replicas.iter().map(|p| (p.replica, p.best_energy, p.flips)).collect()
            })
            .collect();
        coord.shutdown();
        out
    };
    let serial = run(Coordinator::start_serial(3));
    let overlapping = run(Coordinator::start(3));
    assert_eq!(serial, overlapping, "dispatch mode leaked into results");
}

/// The scheduler's result ordering and seeds are index-keyed, so worker
/// count is invisible even at awkward replica/worker ratios.
#[test]
fn scheduler_worker_sweep_is_invariant() {
    let spec = job("sweep", 77, 9);
    let reference: Vec<i64> =
        ReplicaScheduler::new(1).run_native(&spec).iter().map(|r| r.best_energy).collect();
    for workers in [2usize, 3, 8, 16] {
        let got: Vec<i64> =
            ReplicaScheduler::new(workers).run_native(&spec).iter().map(|r| r.best_energy).collect();
        assert_eq!(got, reference, "{workers} workers diverged");
    }
}
