//! Registry parity (the tentpole's bit-identity contract):
//! `SOLVE model=<hash>` must produce results bit-identical to the
//! equivalent inline `SOLVE` — over the wire and through the direct
//! API, across modes and selectors — and every concurrent job
//! referencing one hash must share a single `Arc<IsingModel>`
//! allocation (one copy in memory, however many jobs run).

use snowball::coordinator::{service, Backend, Coordinator, Dispatch, JobResult, JobSpec, Service};
use snowball::engine::{Mode, Schedule, SelectorKind};
use snowball::ising::IsingModel;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn send(s: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> String {
    writeln!(s, "{req}").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// The wire body of a `PUT` upload for `model`.
fn put_body(model: &IsingModel) -> String {
    let mut body = format!("PUT n={}\n", model.len());
    for i in 0..model.len() {
        for (k, w) in model.j_row(i).iter().enumerate().skip(i + 1) {
            if w != 0 {
                body.push_str(&format!("{i} {k} {w}\n"));
            }
        }
    }
    for i in 0..model.len() {
        if model.h(i) != 0 {
            body.push_str(&format!("H {i} {}\n", model.h(i)));
        }
    }
    body.push_str("END\n");
    body
}

/// SOLVE → WAIT(done) → RESULT best= on an open connection.
fn solve_best(s: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> i64 {
    let reply = send(s, r, req);
    assert!(reply.starts_with("JOB id="), "{reply}");
    let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
    let state = send(s, r, &format!("WAIT id={id}"));
    assert_eq!(state, format!("STATE id={id} state=done"));
    let res = send(s, r, &format!("RESULT id={id}"));
    res.split_whitespace()
        .find_map(|tok| tok.strip_prefix("best="))
        .unwrap_or_else(|| panic!("no best= in {res}"))
        .parse()
        .unwrap()
}

/// Over the wire: for every mode × selector, uploading the model once
/// and solving it by hash reports the same best energy as shipping the
/// matrix inline — same seed, same trajectory, same answer.
#[test]
fn by_hash_matches_inline_over_the_wire_across_modes() {
    let coord = Coordinator::start(2);
    let addr = Service::bind(coord, "127.0.0.1:0").unwrap().serve_in_background();
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());

    let inst = "er:40:160";
    let seed = 77u64;
    let (_, model) = service::build_instance(inst, seed).unwrap();
    s.write_all(put_body(&model).as_bytes()).unwrap();
    let mut stored = String::new();
    r.read_line(&mut stored).unwrap();
    let hash = stored
        .trim()
        .strip_prefix("STORED model=")
        .unwrap_or_else(|| panic!("bad PUT reply: {stored}"))
        .to_string();

    for mode in ["rwa", "rsa"] {
        for selector in ["fenwick", "scan"] {
            let tail =
                format!("mode={mode} selector={selector} steps=4000 replicas=3 seed={seed}");
            let inline = solve_best(&mut s, &mut r, &format!("SOLVE instance={inst} {tail}"));
            let by_hash = solve_best(&mut s, &mut r, &format!("SOLVE model={hash} {tail}"));
            assert_eq!(
                by_hash, inline,
                "by-hash SOLVE diverged from inline for mode={mode} selector={selector}"
            );
        }
    }
}

fn spec(model: Arc<IsingModel>, steps: u64, seed: u64) -> JobSpec {
    JobSpec {
        model,
        label: "parity".into(),
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
        steps,
        replicas: 3,
        seed,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
        budget_ms: 0,
        max_retries: 0,
        backend: Backend::Native,
        portfolio: None,
    }
}

fn triples(r: &JobResult) -> Vec<(u32, i64, u64)> {
    r.replicas.iter().map(|x| (x.replica, x.best_energy, x.flips)).collect()
}

/// Direct API: the by-hash path is bit-identical *replica for replica*
/// (best energy AND flip count per replica), not just on the best.
/// Also: a sharded (`shards=2`) by-hash job completes — the shared Arc
/// feeds the shard lanes like any owned model.
#[test]
fn by_hash_matches_inline_replica_for_replica() {
    let coord = Coordinator::start(2);
    let (_, model) = service::build_instance("er:48:180", 13).unwrap();

    let inline_id = coord.submit(spec(Arc::new(model.clone()), 6_000, 13));
    let inline = coord.wait(inline_id).expect("inline job result");

    let h = coord.registry().put(model).expect("put");
    let shared = coord.registry().checkout(h).expect("checkout");
    let id = coord.submit_spec(spec(shared, 6_000, 13), Some(h)).expect("submit by hash");
    let by_hash = coord.wait(id).expect("by-hash job result");
    assert_eq!(triples(&by_hash), triples(&inline), "replica streams diverged");

    // Sharded by-hash job: lanes borrow the same shared model.
    let sharded = coord.registry().checkout(h).expect("checkout for shards");
    let mut s = spec(sharded, 50_000, 14);
    s.shards = 2;
    let id = coord.submit_spec(s, Some(h)).expect("submit sharded");
    let r = coord.wait(id).expect("sharded result");
    assert!(r.completed, "sharded by-hash job must complete");
    assert_eq!(coord.registry().stats().pinned, 0, "pins released at terminal");
    coord.shutdown();
}

/// The memory claim behind the registry: N concurrent jobs referencing
/// one hash are all backed by the *same* `IsingModel` allocation.
/// Checkouts are pointer-identical, the strong count grows by exactly
/// the handles we minted, and the registry stores one entry of one
/// model's bytes throughout.
#[test]
fn one_arc_instance_serves_all_concurrent_jobs() {
    let coord = Coordinator::start(2);
    let (_, model) = service::build_instance("er:32:120", 5).unwrap();
    let bytes = model.approx_bytes();
    let reg = coord.registry().clone();
    let h = reg.put(model).expect("put");

    let shared = reg.checkout(h).expect("checkout");
    let again = reg.checkout(h).expect("second checkout");
    assert!(Arc::ptr_eq(&shared, &again), "checkouts must return the same allocation");
    drop(again);
    reg.unpin(h); // release the second checkout's pin
    let base = Arc::strong_count(&shared);

    // Long enough that all four jobs coexist (two queued behind two
    // running on the 2-worker pool) while we count.
    let jobs = 4usize;
    let mut ids = Vec::new();
    for j in 0..jobs {
        let m = reg.checkout(h).expect("checkout per job");
        assert!(Arc::ptr_eq(&shared, &m), "job {j} got a different allocation");
        ids.push(coord.submit_spec(spec(m, 5_000_000, 100 + j as u64), Some(h)).unwrap());
    }
    // Every in-flight spec holds a clone of the one allocation: the
    // count rose by at least the four handles we just minted (replicas
    // may add more), and the registry still holds exactly one entry of
    // one model's bytes — no copy per job anywhere.
    assert!(
        Arc::strong_count(&shared) >= base + jobs,
        "strong count {} did not grow by the {jobs} job handles over base {base}",
        Arc::strong_count(&shared)
    );
    let stats = reg.stats();
    assert_eq!((stats.entries, stats.bytes), (1, bytes), "one entry, one copy");
    assert_eq!(stats.pinned, 1, "the shared entry is pinned while jobs are in flight");

    for id in ids {
        coord.wait(id).expect("job result");
    }
    // Job pins are released before waiters wake; only our own checkout
    // pin remains, and releasing it drains the entry completely.
    assert_eq!(reg.stats().pinned, 1, "only the observation pin should remain");
    reg.unpin(h);
    assert_eq!(reg.stats().pinned, 0, "all pins released");
    // Worker threads may still be unwinding their spec clones for a
    // moment after `wait` returns; settle, then the registry + this
    // handle are the only references to the one allocation.
    let t0 = std::time::Instant::now();
    while Arc::strong_count(&shared) > 2 && t0.elapsed() < std::time::Duration::from_secs(10) {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(Arc::strong_count(&shared), 2, "only the registry + this handle remain");
    coord.shutdown();
}
