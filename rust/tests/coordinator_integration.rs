//! Coordinator + service integration: end-to-end job lifecycle over TCP,
//! concurrent clients, replica statistics and TTS plumbing.

use snowball::coordinator::registry::DEFAULT_MAX_MODEL_BYTES;
use snowball::coordinator::{service, Backend, Coordinator, JobSpec, Service};
use snowball::engine::{Mode, Schedule, SelectorKind};
use snowball::ising::IsingModel;
use snowball::problems::landscape;
use snowball::rng::StatelessRng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn start_service() -> std::net::SocketAddr {
    let coord = Coordinator::start(2);
    Service::bind(coord, "127.0.0.1:0").unwrap().serve_in_background()
}

fn send(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> String {
    writeln!(stream, "{req}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim().to_string()
}

#[test]
fn full_job_lifecycle_over_tcp_with_tts() {
    let addr = start_service();
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());

    // Exact target from enumeration of a deterministic small instance.
    let (_, model) = service::build_instance("er:18:60", 5).unwrap();
    let (_, optimum) = landscape::ground_state(&model);

    let reply = send(
        &mut s,
        &mut r,
        &format!("SOLVE instance=er:18:60 mode=rwa steps=8000 replicas=6 seed=5 target={optimum}"),
    );
    assert!(reply.starts_with("JOB id="), "{reply}");
    let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
    loop {
        let st = send(&mut s, &mut r, &format!("STATUS id={id}"));
        if st.contains("state=done") {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let res = send(&mut s, &mut r, &format!("RESULT id={id} target={optimum}"));
    assert!(res.contains(&format!("best={optimum}")), "should hit the optimum: {res}");
    assert!(res.contains("pa=1.000"), "all replicas should succeed: {res}");
    assert!(!res.contains("tts99_ms=inf"), "TTS must be finite: {res}");
}

#[test]
fn concurrent_clients_get_isolated_jobs() {
    let addr = start_service();
    let mut handles = Vec::new();
    for client in 0..4u64 {
        handles.push(std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let reply = send(
                &mut s,
                &mut r,
                &format!("SOLVE instance=er:24:80 mode=rsa steps=3000 replicas=2 seed={client}"),
            );
            let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
            loop {
                let st = send(&mut s, &mut r, &format!("STATUS id={id}"));
                if st.contains("state=done") {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            let res = send(&mut s, &mut r, &format!("RESULT id={id}"));
            assert!(res.contains(&format!("RESULT id={id}")), "{res}");
            id
        }));
    }
    let ids: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let mut dedup = ids.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), ids.len(), "job ids collided: {ids:?}");
}

#[test]
fn coordinator_direct_api_with_target_statistics() {
    let coord = Coordinator::start(2);
    let rng = StatelessRng::new(21);
    let g = snowball::graph::generators::erdos_renyi(40, 160, &[-1, 1], &rng);
    let p = snowball::problems::MaxCut::new(g);
    let id = coord.submit(JobSpec {
        model: Arc::new(p.model().clone()),
        label: "stats".into(),
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 6.0, t1: 0.05 },
        steps: 4_000,
        replicas: 8,
        seed: 3,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
        budget_ms: 0,
        max_retries: 0,
        backend: Backend::Native,
        portfolio: None,
    });
    let res = coord.wait(id).unwrap();
    assert_eq!(res.replicas.len(), 8);
    // Use the observed best as target: at least one replica (the best
    // one) must "succeed" and TTS must be finite.
    let best = res.best_energy();
    let est = res.successes(best);
    assert!(est.successes >= 1);
    let tts = snowball::tts::tts99(res.mean_replica_seconds(), est);
    assert!(tts.is_finite() && tts > 0.0);
    coord.shutdown();
}

#[test]
fn metrics_surface_through_service() {
    let addr = start_service();
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    send(&mut s, &mut r, "PING");
    writeln!(s, "METRICS").unwrap();
    let mut saw_counter = false;
    let mut line = String::new();
    loop {
        line.clear();
        r.read_line(&mut line).unwrap();
        if line.contains("counter service_requests") {
            saw_counter = true;
        }
        if line.trim_end().ends_with("END") {
            break;
        }
    }
    assert!(saw_counter, "metrics should include the request counter");
}

/// Table-driven coverage of the registry protocol's ERR forms, each
/// matched *exactly* against the strings docs/PROTOCOL.md specifies —
/// all on one connection, proving every refusal leaves the line
/// protocol synchronized (including refused PUT headers, whose bodies
/// must still be drained to END).
#[test]
fn registry_protocol_err_forms_are_exact() {
    let addr = start_service();
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());

    let over_n = 4100usize;
    let over_bytes = IsingModel::approx_bytes_for(over_n);
    assert!(over_bytes > DEFAULT_MAX_MODEL_BYTES, "test premise: n={over_n} must exceed the cap");
    let unknown = "deadbeefdeadbeefdeadbeefdeadbeef";
    let bad32 = "g".repeat(32);

    let cases: Vec<(String, String)> = vec![
        // REGISTRY on an empty store.
        ("REGISTRY".into(), "ERR registry empty (PUT a model first)".into()),
        // Well-formed but unknown hash.
        (
            format!("SOLVE model={unknown}"),
            format!("ERR unknown model {unknown} (PUT it first)"),
        ),
        // Malformed hashes: wrong length, wrong alphabet.
        (
            "SOLVE model=abc123".into(),
            "ERR malformed model hash 'abc123' (expect 32 hex chars)".into(),
        ),
        (
            format!("SOLVE model={bad32}"),
            format!("ERR malformed model hash '{bad32}' (expect 32 hex chars)"),
        ),
        // Model resolution is mandatory and exclusive.
        ("SOLVE".into(), "ERR missing instance= (or model=<hash>)".into()),
        (
            format!("SOLVE instance=er:8:10 model={unknown}"),
            "ERR instance= and model= are mutually exclusive".into(),
        ),
        // PUT body over the registry's max_model_bytes cap.
        (
            format!("PUT n={over_n}\nEND"),
            format!("ERR model too large: {over_bytes} bytes exceeds max_model_bytes \
                     {DEFAULT_MAX_MODEL_BYTES}"),
        ),
        // PUT header and body malformations.
        ("PUT\nEND".into(), "ERR missing n=".into()),
        (
            "PUT n=4\n0 1 2 3\nEND".into(),
            "ERR malformed PUT body line '0 1 2 3' (expect '<i> <k> <J>' or 'H <i> <h>')".into(),
        ),
        (
            "PUT n=4\n0 0 2\nEND".into(),
            "ERR self-coupling 0 0 is not allowed (zero diagonal)".into(),
        ),
        ("PUT n=4\n1 7 2\nEND".into(), "ERR spin index 7 out of range (n=4)".into()),
        ("PUT n=4\nH 9 1\nEND".into(), "ERR spin index 9 out of range (n=4)".into()),
    ];
    for (req, want) in &cases {
        let got = send(&mut s, &mut r, req);
        assert_eq!(&got, want, "for request {req:?}");
    }

    // After a dozen refusals the very same connection still serves the
    // happy path: PUT, REGISTRY, SOLVE by hash.
    let stored = send(&mut s, &mut r, "PUT n=4\n0 1 2\n2 3 -1\nH 0 1\nEND");
    let hash = stored.strip_prefix("STORED model=").unwrap_or_else(|| panic!("{stored}"));
    assert_eq!(hash.len(), 32, "hash is 32 hex chars: {stored}");
    let reg = send(&mut s, &mut r, "REGISTRY");
    assert!(reg.starts_with("REGISTRY entries=1 bytes="), "{reg}");
    let reply = send(&mut s, &mut r, &format!("SOLVE model={hash} steps=500 replicas=2 seed=9"));
    assert!(reply.starts_with("JOB id="), "{reply}");
    let id: u64 = reply.rsplit('=').next().unwrap().parse().unwrap();
    let state = send(&mut s, &mut r, &format!("WAIT id={id}"));
    assert_eq!(state, format!("STATE id={id} state=done"));
}

#[test]
fn build_instance_covers_all_forms() {
    assert!(service::build_instance("G6", 1).is_ok());
    assert!(service::build_instance("k2000", 1).is_ok());
    assert!(service::build_instance("er:10:20", 1).is_ok());
    assert!(service::build_instance("er:10", 1).is_err());
    assert!(service::build_instance("nope", 1).is_err());
}
