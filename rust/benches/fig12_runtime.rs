//! Regenerates **Fig 12** in isolation: per-solver runtime on each Gset
//! instance at matched sweep budgets, with per-attempt normalization so
//! the convergence-speed claim ("RWA/RSA runtime is fastest") can be
//! separated from raw step cost.
//!
//!     cargo bench --bench fig12_runtime -- [--quick]

use snowball::baselines::{table2_lineup, Budget};
use snowball::cli::Args;
use snowball::engine::{Datapath, EngineConfig, Mode, Schedule, SelectorKind, SnowballEngine};
use snowball::graph::gset::{self, GsetId};
use snowball::harness as hx;
use snowball::problems::MaxCut;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let quick = args.flag("quick");
    let sweeps: u64 = args.get_parse_or("sweeps", if quick { 100 } else { 1000 }).unwrap();
    let seed: u64 = args.get_parse_or("seed", 42u64).unwrap();
    let instances: Vec<GsetId> =
        if quick { vec![GsetId::G11] } else { vec![GsetId::G11, GsetId::G18, GsetId::G6] };

    let mut rows = Vec::new();
    for id in &instances {
        let g = gset::load_or_synthesize(*id, None, seed);
        let p = MaxCut::new(g);
        for solver in table2_lineup() {
            let r = solver.solve(p.model(), Budget::sweeps(sweeps), seed);
            rows.push(vec![
                id.name().to_string(),
                solver.name().to_string(),
                hx::fmt_ms(r.wall.as_secs_f64()),
                format!("{:.1}", r.wall.as_secs_f64() * 1e9 / r.attempts as f64),
                p.cut_of_energy(r.best_energy).to_string(),
            ]);
        }
    }
    print!(
        "{}",
        hx::render_table(
            "Fig 12: runtime per solver",
            &["instance", "solver", "total ms", "ns/attempt", "cut"],
            &rows
        )
    );

    // Addendum (PR 2): RWA selection-path runtime on the same Gset
    // instances — legacy Θ(N) scan vs Fenwick Θ(deg + log N), identical
    // results asserted, so the table isolates pure selection cost.
    let sel_steps: u64 = if quick { 20_000 } else { 100_000 };
    let mut sel_rows = Vec::new();
    for id in &instances {
        let g = gset::load_or_synthesize(*id, None, seed);
        let p = MaxCut::new(g);
        let mut cuts = Vec::new();
        let mut times = Vec::new();
        for selector in [SelectorKind::LinearScan, SelectorKind::Fenwick] {
            let cfg = EngineConfig {
                mode: Mode::RouletteWheel,
                datapath: Datapath::Dense,
                selector,
                schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 }.quantized(64),
                steps: sel_steps,
                seed,
                planes: None,
                trace_stride: 0,
                shards: 1,
                pin_lanes: false,
                local_rows: false,
            };
            let mut e = SnowballEngine::new(p.model(), cfg);
            let start = std::time::Instant::now();
            let r = e.run();
            times.push(start.elapsed().as_secs_f64());
            cuts.push(p.cut_of_energy(r.best_energy));
        }
        assert_eq!(cuts[0], cuts[1], "{}: selector paths diverged", id.name());
        sel_rows.push(vec![
            id.name().to_string(),
            hx::fmt_ms(times[0]),
            hx::fmt_ms(times[1]),
            format!("{:.1}x", times[0] / times[1]),
            cuts[0].to_string(),
        ]);
    }
    print!(
        "{}",
        hx::render_table(
            "Fig 12 addendum: RWA selection path (staged geometric, 64 plateaus)",
            &["instance", "scan", "fenwick", "speedup", "cut"],
            &sel_rows
        )
    );
}
