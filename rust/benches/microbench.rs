//! §Perf microbenchmarks: per-step cost of the engine hot paths across
//! instance sizes, datapaths and Mode II selectors, plus the XLA chunk
//! throughput when artifacts are available. These are the numbers
//! EXPERIMENTS.md §Perf tracks before/after optimization.
//!
//! Besides the printed tables, the run writes `BENCH_engine.json`
//! (steps/sec per configuration plus the Fenwick-vs-scan comparison) so
//! the perf trajectory is machine-readable across PRs.
//!
//!     cargo bench --bench microbench -- [--quick|--smoke]
//!
//! `--load` switches to the **service load benchmark** instead: a
//! trace of mixed-size SOLVE jobs from 100+ concurrent TCP clients is
//! replayed against the serial and the overlapping dispatcher, and the
//! per-stage latency quantiles (`queue_wait`, `job_wall`) land in
//! `BENCH_service.json`:
//!
//!     cargo bench --bench microbench -- --load [--quick]
//!
//! `--shards` switches to the **sharded-engine benchmark**: the
//! single-lane engine vs the asynchronous sharded engine on a large
//! all-to-all instance (N = 4096), after a virtual-time parity guard,
//! writing `BENCH_shard.json`:
//!
//!     cargo bench --bench microbench -- --shards [--quick]
//!
//! `--registry` switches to the **content-addressed registry
//! benchmark**: inline vs by-hash submission latency and the
//! resident-model-bytes proxy at N = 4096 × 32 jobs (N = 1024 × 8 under
//! `--quick`), plus locality-hit vs miss placement on the 4-worker
//! dispatch tier, writing `BENCH_registry.json`:
//!
//!     cargo bench --bench microbench -- --registry [--quick]
//!
//! `--portfolio` switches to the **solver portfolio benchmark**: the
//! full contender roster (Snowball configurations plus every Table
//! II/III baseline) raced on one sparse and one dense instance under a
//! shared step budget, writing per-contender quality/throughput and the
//! winner to `BENCH_portfolio.json`:
//!
//!     cargo bench --bench microbench -- --portfolio [--quick]
//!
//! `--precision` switches to the **coupling-precision sweep** (paper
//! challenge 3): one sparse and one dense wide-coefficient instance
//! quantized to each bit-width in {2..16}, the roster raced per width,
//! the winner re-scored on the full-precision model, each point paired
//! with the hwsim plane-count cycle cost — `BENCH_precision.json`:
//!
//!     cargo bench --bench microbench -- --precision [--quick]
//!
//! `--locality` switches to the **memory-bandwidth benchmark** behind
//! the precision-packed coupling store and the NUMA-local lane rows:
//! packed (i8) vs force-widened i32 storage on the N = 4096 dense and
//! sparse workloads — after a bit-identity guard, so every ratio
//! compares provably identical MCMC work — plus the pinned/unpinned ×
//! local-rows-on/off grid on the async sharded engine, writing
//! `BENCH_locality.json`:
//!
//!     cargo bench --bench microbench -- --locality [--quick]

use snowball::cli::Args;
use snowball::coordinator::{Backend, Coordinator, Dispatch, JobSpec, Router, Service, WaitOutcome};
use snowball::engine::{
    Datapath, EngineConfig, MergeMode, Mode, ReplicaPool, Schedule, SelectorKind, ShardedEngine,
    SnowballEngine,
};
use snowball::graph::generators;
use snowball::harness as hx;
use snowball::ising::IsingModel;
use snowball::problems::MaxCut;
use snowball::rng::StatelessRng;
use std::sync::Arc;

/// One measured engine configuration, serialized into the JSON report.
struct BenchRow {
    n: usize,
    mode: &'static str,
    datapath: &'static str,
    selector: &'static str,
    ns_per_step: f64,
    steps_per_sec: f64,
    flip_rate: f64,
}

impl BenchRow {
    fn json(&self) -> String {
        format!(
            "{{\"n\":{},\"mode\":\"{}\",\"datapath\":\"{}\",\"selector\":\"{}\",\
             \"ns_per_step\":{:.1},\"steps_per_sec\":{:.1},\"flip_rate\":{:.4}}}",
            self.n, self.mode, self.datapath, self.selector, self.ns_per_step,
            self.steps_per_sec, self.flip_rate
        )
    }
}

fn run_engine(p: &MaxCut, mode: Mode, dp: Datapath, sel: SelectorKind, steps: u64) -> (f64, f64) {
    let cfg = EngineConfig {
        mode,
        datapath: dp,
        selector: sel,
        schedule: Schedule::Constant(1.0),
        steps,
        seed: 3,
        planes: None,
        trace_stride: 0,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
    };
    let mut e = SnowballEngine::new(p.model(), cfg);
    let start = std::time::Instant::now();
    let r = e.run();
    let total = start.elapsed().as_secs_f64();
    (total * 1e9 / steps as f64, r.flips as f64 / steps as f64)
}

fn bench_engine(n: usize, mode: Mode, dp: Datapath, sel: SelectorKind, steps: u64) -> (f64, f64) {
    let rng = StatelessRng::new(1);
    let g = generators::complete(n, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    run_engine(&p, mode, dp, sel, steps)
}

/// The headline comparison the PR-2 acceptance tracks: Mode II on a
/// sparse N-spin instance, legacy Θ(N) scan vs Fenwick Θ(deg + log N),
/// measured in the same process on the same instance — with a parity
/// assert so the speedup can never come from diverging work.
fn bench_fenwick_vs_scan(n: usize, edges: usize, steps: u64) -> (f64, f64) {
    let rng = StatelessRng::new(7);
    let g = generators::erdos_renyi(n, edges, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    let mut results = Vec::new();
    let mut rates = Vec::new();
    for sel in [SelectorKind::LinearScan, SelectorKind::Fenwick] {
        let cfg = EngineConfig {
            mode: Mode::RouletteWheel,
            datapath: Datapath::Dense,
            selector: sel,
            schedule: Schedule::Constant(1.0),
            steps,
            seed: 11,
            planes: None,
            trace_stride: 0,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
        };
        let mut e = SnowballEngine::new(p.model(), cfg);
        let start = std::time::Instant::now();
        let r = e.run();
        let secs = start.elapsed().as_secs_f64();
        results.push((r.best_energy, r.final_energy, r.flips, r.fallbacks, r.nulls));
        rates.push(steps as f64 / secs);
    }
    assert_eq!(results[0], results[1], "selector paths diverged — benchmark void");
    (rates[0], rates[1])
}

/// One dispatcher's numbers under the client trace.
struct LoadRow {
    mode: &'static str,
    wall_ms: f64,
    jobs_per_sec: f64,
    queue_wait_p50_us: u64,
    queue_wait_p99_us: u64,
    job_wall_p99_us: u64,
}

impl LoadRow {
    fn json(&self) -> String {
        format!(
            "\"{}\": {{\"wall_ms\":{:.1},\"jobs_per_sec\":{:.1},\"queue_wait_p50_us\":{},\
             \"queue_wait_p99_us\":{},\"job_wall_p99_us\":{}}}",
            self.mode,
            self.wall_ms,
            self.jobs_per_sec,
            self.queue_wait_p50_us,
            self.queue_wait_p99_us,
            self.job_wall_p99_us
        )
    }
}

/// Replay `clients` concurrent TCP clients (mixed SOLVE sizes, one job
/// each: SOLVE → WAIT → RESULT) against `coord` and read the stage
/// timers back out of its metrics.
fn run_service_trace(mode: &'static str, coord: Coordinator, clients: usize) -> LoadRow {
    use std::io::{BufRead, BufReader, Write};
    let metrics = coord.metrics.clone();
    let addr = Service::bind(coord.clone(), "127.0.0.1:0").unwrap().serve_in_background();
    let start = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let (inst, steps) = match c % 4 {
                    0 => ("er:16:40", 1000),
                    1 => ("er:24:80", 1200),
                    2 => ("er:48:180", 1500),
                    _ => ("er:96:380", 2000),
                };
                let mut s = std::net::TcpStream::connect(addr).unwrap();
                let mut r = BufReader::new(s.try_clone().unwrap());
                let mut line = String::new();
                writeln!(s, "SOLVE instance={inst} mode=rwa steps={steps} replicas=2 seed={c}")
                    .unwrap();
                r.read_line(&mut line).unwrap();
                let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
                for req in [format!("WAIT id={id}"), format!("RESULT id={id}")] {
                    writeln!(s, "{req}").unwrap();
                    line.clear();
                    r.read_line(&mut line).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = start.elapsed().as_secs_f64();
    let row = LoadRow {
        mode,
        wall_ms: wall * 1e3,
        jobs_per_sec: clients as f64 / wall,
        queue_wait_p50_us: metrics.quantile_us("queue_wait", 0.5).unwrap_or(0),
        queue_wait_p99_us: metrics.quantile_us("queue_wait", 0.99).unwrap_or(0),
        job_wall_p99_us: metrics.quantile_us("job_wall", 0.99).unwrap_or(0),
    };
    coord.shutdown();
    row
}

/// `--load`: the service saturation benchmark behind `BENCH_service.json`.
fn bench_service_load(quick: bool) {
    let clients = if quick { 48 } else { 120 };
    let serial = run_service_trace("serial", Coordinator::start_serial(0), clients);
    let overlapping = run_service_trace("overlapping", Coordinator::start(0), clients);
    for row in [&serial, &overlapping] {
        println!(
            "{:>12}: {} clients in {:.1} ms ({:.1} jobs/s) | queue_wait p50 {} µs p99 {} µs | \
             job_wall p99 {} µs",
            row.mode,
            clients,
            row.wall_ms,
            row.jobs_per_sec,
            row.queue_wait_p50_us,
            row.queue_wait_p99_us,
            row.job_wall_p99_us
        );
    }
    let ratio = serial.queue_wait_p99_us as f64 / overlapping.queue_wait_p99_us.max(1) as f64;
    println!("queue_wait p99: serial/overlapping = {ratio:.1}x");
    let json = format!(
        "{{\n  \"schema\": \"snowball.bench.service/v1\",\n  \"profile\": \"{}\",\n  \
         \"clients\": {clients},\n  \"replicas_per_job\": 2,\n  {},\n  {},\n  \
         \"queue_wait_p99_ratio\": {ratio:.2}\n}}\n",
        if quick { "quick" } else { "full" },
        serial.json(),
        overlapping.json()
    );
    let path = "BENCH_service.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// `--shards`: single-lane vs asynchronous sharded engine on a large
/// all-to-all instance, behind a virtual-time parity guard, plus the
/// incremental-vs-bulk per-lane selection comparison on a sparse
/// N = 4096 instance (S ∈ {1, 4, 8}) — the numbers behind
/// `BENCH_shard.json`.
fn bench_shards(quick: bool) {
    // Parity guard first: the deterministic merge mode must reproduce
    // the single-shard engine bit for bit, or the speedup numbers
    // compare diverging work and the benchmark is void.
    {
        let rng = StatelessRng::new(17);
        let p = MaxCut::new(generators::erdos_renyi(96, 400, &[-1, 1], &rng));
        let cfg = |shards: usize| EngineConfig {
            mode: Mode::RouletteWheel,
            datapath: Datapath::Dense,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 6.0, t1: 0.05 },
            steps: 2_000,
            seed: 23,
            planes: None,
            trace_stride: 0,
            shards,
            pin_lanes: false,
            local_rows: false,
        };
        let want = SnowballEngine::new(p.model(), cfg(1)).run();
        let got = ShardedEngine::new(p.model(), cfg(5), MergeMode::VirtualTime).run();
        assert_eq!(
            (got.best_energy, got.final_energy, got.flips, got.fallbacks, got.nulls),
            (want.best_energy, want.final_energy, want.flips, want.fallbacks, want.nulls),
            "virtual-time merge diverged from the single-shard engine — benchmark void"
        );
        println!("virtual-time parity: OK (5 shards bit-identical to 1)");
    }

    // Throughput: N = 4096 all-to-all ±1 (the paper's workload shape —
    // every flip touches every lane, so the single-lane engine is
    // Θ(N)/step and sharding splits exactly that).
    let n = 4096usize;
    let steps: u64 = if quick { 16_000 } else { 48_000 };
    let rng = StatelessRng::new(5);
    let g = generators::complete(n, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    let mk_cfg = |shards: usize| EngineConfig {
        mode: Mode::RouletteWheel,
        datapath: Datapath::Dense,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
        steps,
        seed: 7,
        planes: None,
        trace_stride: 0,
        shards,
        pin_lanes: false,
        local_rows: false,
    };
    let single = {
        let mut e = SnowballEngine::new(p.model(), mk_cfg(1));
        let start = std::time::Instant::now();
        let r = e.run();
        let sps = steps as f64 / start.elapsed().as_secs_f64();
        println!(
            "single lane : N={n} {steps} steps | {sps:>12.0} steps/s | best {}",
            r.best_energy
        );
        (sps, r.best_energy)
    };
    let cores = ReplicaPool::auto_workers();
    let mut shard_rows = Vec::new();
    for s in [2usize, 4, 8] {
        if s > cores {
            println!("{s:>2} lanes    : skipped ({cores} cores)");
            continue;
        }
        let mut e = ShardedEngine::new(p.model(), mk_cfg(s), MergeMode::Async);
        let start = std::time::Instant::now();
        let r = e.run();
        let sps = r.steps as f64 / start.elapsed().as_secs_f64();
        let speedup = sps / single.0;
        println!(
            "{s:>2} lanes    : N={n} {} steps | {sps:>12.0} steps/s | best {} | {speedup:.2}x",
            r.steps, r.best_energy
        );
        shard_rows.push(format!(
            "{{\"shards\":{s},\"steps_per_sec\":{sps:.1},\"speedup\":{speedup:.3},\
             \"best_energy\":{}}}",
            r.best_energy
        ));
    }
    // Incremental vs bulk per-lane selection: sparse N = 4096 (average
    // degree 8), plateau (quantized) schedule, deterministic
    // virtual-time mode so both selector paths do provably identical
    // MCMC work (asserted per lane count) and the timing difference is
    // pure per-step selection cost — Θ(N/S) bulk lane refresh (scan)
    // vs Θ(log(N/S) + deg) dirty-set refresh (fenwick).
    let sparse_n = 4096usize;
    let sparse_edges = 16_384usize;
    let sparse_steps: u64 = if quick { 8_000 } else { 24_000 };
    let sparse_rows = {
        let rng = StatelessRng::new(9);
        let sp = MaxCut::new(generators::erdos_renyi(sparse_n, sparse_edges, &[-1, 1], &rng));
        let mk = |selector: SelectorKind, shards: usize| EngineConfig {
            mode: Mode::RouletteWheel,
            datapath: Datapath::Dense,
            selector,
            schedule: Schedule::Geometric { t0: 6.0, t1: 0.05 }.quantized(64),
            steps: sparse_steps,
            seed: 13,
            planes: None,
            trace_stride: 0,
            shards,
            pin_lanes: false,
            local_rows: false,
        };
        let mut rows = Vec::new();
        for s in [1usize, 4, 8] {
            let run = |selector: SelectorKind| {
                let mut e =
                    ShardedEngine::new(sp.model(), mk(selector, s), MergeMode::VirtualTime);
                let start = std::time::Instant::now();
                let r = e.run();
                let sps = sparse_steps as f64 / start.elapsed().as_secs_f64();
                (sps, (r.best_energy, r.final_energy, r.flips, r.fallbacks, r.nulls))
            };
            let (bulk_sps, bulk_sig) = run(SelectorKind::LinearScan);
            let (inc_sps, inc_sig) = run(SelectorKind::Fenwick);
            assert_eq!(
                bulk_sig, inc_sig,
                "S = {s}: selector paths diverged — sparse benchmark void"
            );
            let speedup = inc_sps / bulk_sps;
            println!(
                "sparse S={s} : N={sparse_n} |E|={sparse_edges} {sparse_steps} steps | \
                 bulk {bulk_sps:>10.0} steps/s | incremental {inc_sps:>10.0} steps/s | \
                 {speedup:.1}x"
            );
            rows.push(format!(
                "{{\"shards\":{s},\"bulk_steps_per_sec\":{bulk_sps:.1},\
                 \"incremental_steps_per_sec\":{inc_sps:.1},\"speedup\":{speedup:.3}}}"
            ));
        }
        rows
    };

    // Cycle-model companion (hwsim): what the FPGA's asynchronous
    // update units would gain at the same geometry, bulk and
    // incremental per-lane datapaths.
    let hw = snowball::hwsim::HwModel::default();
    let geom = snowball::hwsim::Geometry { n, planes: 1 };
    let model_speedup_8 = hw.sharded_roulette_round_cycles(geom, 1) as f64
        / (hw.sharded_roulette_round_cycles(geom, 8) as f64 / 8.0);
    println!("cycle model : 8 async update units = {model_speedup_8:.1}x steps/cycle");
    // The incremental-lane win needs enough local lanes for the saved
    // evaluates to outweigh the deeper (2-read) selection tree, so the
    // model point is the at-scale geometry (64k spins, 8k per lane).
    let geom_big = snowball::hwsim::Geometry { n: 65_536, planes: 1 };
    let model_incremental_8 = hw.sharded_roulette_round_cycles(geom_big, 8) as f64
        / hw.sharded_roulette_round_cycles_incremental(geom_big, 8, 9) as f64;
    println!(
        "cycle model : incremental lanes (N=64k, deg 8, S=8) = \
         {model_incremental_8:.1}x cycles/round"
    );

    let json = format!(
        "{{\n  \"schema\": \"snowball.bench.shard/v2\",\n  \"profile\": \"{}\",\n  \
         \"n\": {n},\n  \"steps\": {steps},\n  \"virtual_parity\": true,\n  \
         \"single_steps_per_sec\": {:.1},\n  \"single_best_energy\": {},\n  \
         \"cores\": {cores},\n  \"sharded\": [\n    {}\n  ],\n  \
         \"sparse\": {{\"n\": {sparse_n}, \"edges\": {sparse_edges}, \
         \"steps\": {sparse_steps}, \"rows\": [\n    {}\n  ]}},\n  \
         \"hwsim_speedup_8_lanes\": {model_speedup_8:.2},\n  \
         \"hwsim_incremental_round_speedup_8_lanes\": {model_incremental_8:.2}\n}}\n",
        if quick { "quick" } else { "full" },
        single.0,
        single.1,
        shard_rows.join(",\n    "),
        sparse_rows.join(",\n    ")
    );
    let path = "BENCH_shard.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// `--registry`: the content-addressed registry benchmark behind
/// `BENCH_registry.json`. Three lanes on the same all-to-all model:
/// inline submission (every job clones the full matrix into its spec —
/// the pre-registry cost in both submit latency and resident bytes),
/// by-hash submission (one `put`, then a cheap pin + `Arc` clone per
/// job), and routed by-hash submission through the 4-worker dispatch
/// tier (measuring locality hits vs misses on placement).
fn bench_registry(quick: bool) {
    let (n, jobs) = if quick { (1024usize, 8usize) } else { (4096usize, 32usize) };
    let steps: u64 = 200;
    let rng = StatelessRng::new(31);
    let p = MaxCut::new(generators::complete(n, &[-1, 1], &rng));
    let model = p.model().clone();
    let bytes = model.approx_bytes();
    let mk_spec = |m: Arc<IsingModel>, seed: u64| JobSpec {
        model: m,
        label: "bench".into(),
        mode: Mode::RouletteWheel,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Constant(1.0),
        steps,
        replicas: 1,
        seed,
        target_energy: None,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
        budget_ms: 0,
        max_retries: 0,
        backend: Backend::Native,
        portfolio: None,
    };

    // Inline lane: the submit loop pays a full O(N²) matrix clone per
    // job, and every queued job holds its own copy resident.
    let coord = Coordinator::start(0);
    let t0 = std::time::Instant::now();
    let ids: Vec<u64> =
        (0..jobs).map(|j| coord.submit(mk_spec(Arc::new(model.clone()), j as u64))).collect();
    let inline_submit_us = t0.elapsed().as_secs_f64() * 1e6 / jobs as f64;
    for id in &ids {
        coord.wait(*id).expect("inline job result");
    }
    coord.shutdown();
    let inline_bytes = bytes * jobs;

    // By-hash lane: one put, then each submit is a registry checkout
    // (pin + Arc clone) — no copy, one resident model however many jobs.
    let coord = Coordinator::start(0);
    let hash = coord.registry().put(model.clone()).expect("registry put");
    let t0 = std::time::Instant::now();
    let ids: Vec<u64> = (0..jobs)
        .map(|j| {
            let m = coord.registry().checkout(hash).expect("checkout");
            coord.submit_spec(mk_spec(m, j as u64), Some(hash)).expect("submit by hash")
        })
        .collect();
    let by_hash_submit_us = t0.elapsed().as_secs_f64() * 1e6 / jobs as f64;
    for id in &ids {
        coord.wait(*id).expect("by-hash job result");
    }
    let stats = coord.registry().stats();
    assert_eq!(stats.entries, 1, "one entry serves every by-hash job");
    let (reg_hits, reg_dedup) = (stats.hits, stats.dedup);
    coord.shutdown();
    let by_hash_bytes = bytes;

    let submit_speedup = inline_submit_us / by_hash_submit_us.max(1e-3);
    let bytes_ratio = inline_bytes as f64 / by_hash_bytes as f64;
    println!(
        "submit      : N={n} x {jobs} jobs | inline {inline_submit_us:>8.1} us/job | \
         by-hash {by_hash_submit_us:>8.1} us/job | {submit_speedup:.1}x"
    );
    println!(
        "resident    : inline {inline_bytes} bytes | by-hash {by_hash_bytes} bytes | \
         {bytes_ratio:.0}x"
    );

    // Routed lane: the first job for a hash establishes its home worker
    // (one locality miss); every later job for the same hash routes
    // straight back to it (a hit), keeping the model's pages warm on
    // one worker instead of spraying the load across all four.
    let router = Router::start(4, 1);
    let hash = router.registry().put(model).expect("router put");
    let t0 = std::time::Instant::now();
    let ids: Vec<u64> = (0..jobs)
        .map(|j| {
            let m = router.registry().checkout(hash).expect("router checkout");
            router.submit_spec(mk_spec(m, 500 + j as u64), Some(hash)).expect("routed submit")
        })
        .collect();
    let routed_submit_us = t0.elapsed().as_secs_f64() * 1e6 / jobs as f64;
    for id in &ids {
        match router.wait_for(*id, std::time::Duration::from_secs(300)) {
            WaitOutcome::Terminal(_) => {}
            other => panic!("routed job {id} did not finish: {other:?}"),
        }
    }
    let hits = router.metrics.get("router_locality_hits");
    let misses = router.metrics.get("router_locality_misses");
    assert_eq!(hits + misses, jobs as u64, "every placement is a hit or a miss");
    assert!(hits >= jobs as u64 - 1, "all but the first placement should hit: {hits}");
    Dispatch::shutdown(&router);
    println!(
        "routed      : 4 workers | {routed_submit_us:>8.1} us/job | \
         locality {hits} hits / {misses} misses"
    );

    let json = format!(
        "{{\n  \"schema\": \"snowball.bench.registry/v1\",\n  \"profile\": \"{}\",\n  \
         \"n\": {n},\n  \"jobs\": {jobs},\n  \"model_bytes\": {bytes},\n  \
         \"inline\": {{\"submit_us_per_job\": {inline_submit_us:.1}, \
         \"resident_model_bytes\": {inline_bytes}}},\n  \
         \"by_hash\": {{\"submit_us_per_job\": {by_hash_submit_us:.1}, \
         \"resident_model_bytes\": {by_hash_bytes}, \"registry_hits\": {reg_hits}, \
         \"registry_dedup\": {reg_dedup}}},\n  \
         \"submit_speedup\": {submit_speedup:.2},\n  \"bytes_ratio\": {bytes_ratio:.1},\n  \
         \"routed\": {{\"dispatch_workers\": 4, \"submit_us_per_job\": {routed_submit_us:.1}, \
         \"locality_hits\": {hits}, \"locality_misses\": {misses}}}\n}}\n",
        if quick { "quick" } else { "full" }
    );
    let path = "BENCH_registry.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// `--portfolio`: race the full contender roster on one sparse and one
/// dense instance under a shared step budget — the Table-II-style fleet
/// comparison behind `BENCH_portfolio.json`.
fn bench_portfolio(quick: bool) {
    use snowball::portfolio::{race, resolve_roster, roster_names, PortfolioSpec, RaceConfig};
    use snowball::stop::StopToken;

    let steps: u64 = if quick { 4_000 } else { 40_000 };
    let rng = StatelessRng::new(41);
    let sparse = MaxCut::new(generators::erdos_renyi(512, 2_048, &[-1, 1], &rng));
    let dense =
        MaxCut::new(generators::complete(if quick { 128 } else { 256 }, &[-1, 1], &rng));
    let mut blocks = Vec::new();
    for (label, p) in [("sparse_er", &sparse), ("dense_complete", &dense)] {
        let m = p.model();
        let roster = resolve_roster(&PortfolioSpec::Full, m);
        let cfg = RaceConfig {
            steps,
            schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
            seed: 9,
            target: None,
            pin_lanes: false,
            local_rows: false,
        };
        let start = std::time::Instant::now();
        let out = race(m, &roster, &cfg, Arc::new(StopToken::new()));
        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        println!("{label} (N={}): winner {} | race {wall_ms:.1} ms", m.len(), out.winner_name());
        let mut rows = Vec::new();
        for r in &out.reports {
            println!(
                "  {:>13}: best {:>8} | {:>10} attempts | {:>9.1} ms",
                r.name,
                r.best_energy,
                r.attempts,
                r.wall.as_secs_f64() * 1e3
            );
            rows.push(format!(
                "{{\"name\":\"{}\",\"best_energy\":{},\"attempts\":{},\"wall_ms\":{:.1}}}",
                r.name,
                r.best_energy,
                r.attempts,
                r.wall.as_secs_f64() * 1e3
            ));
        }
        let auto = roster_names(&PortfolioSpec::Auto, m);
        println!("  auto roster : {}", auto.join(","));
        blocks.push(format!(
            "\"{label}\": {{\"n\": {}, \"winner\": \"{}\", \"race_wall_ms\": {wall_ms:.1}, \
             \"auto_roster\": \"{}\", \"contenders\": [\n    {}\n  ]}}",
            m.len(),
            out.winner_name(),
            auto.join(","),
            rows.join(",\n    ")
        ));
    }
    let json = format!(
        "{{\n  \"schema\": \"snowball.bench.portfolio/v1\",\n  \"profile\": \"{}\",\n  \
         \"steps\": {steps},\n  {}\n}}\n",
        if quick { "quick" } else { "full" },
        blocks.join(",\n  ")
    );
    let path = "BENCH_portfolio.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// `--precision`: the coupling bit-width sweep behind
/// `BENCH_precision.json` (paper challenge 3). Quality = the winner's
/// configuration re-scored on the full-precision model; cost = hwsim
/// cycles per step at that plane count.
fn bench_precision(quick: bool) {
    use snowball::portfolio::{precision, PortfolioSpec};

    let widths: Vec<u32> = if quick { vec![2, 4, 8, 16] } else { vec![2, 3, 4, 6, 8, 12, 16] };
    let steps: u64 = if quick { 2_000 } else { 20_000 };
    let spec =
        PortfolioSpec::List(vec!["rwa".into(), "rsa".into(), "neal".into(), "tabu".into()]);
    // Wide coefficient palette so low widths genuinely distort the
    // landscape — a ±1 instance would be quantization-invariant.
    let palette: &[i32] = &[-100, -73, -31, 7, 45, 100];
    let rng = StatelessRng::new(43);
    let sparse = MaxCut::new(generators::erdos_renyi(192, 768, palette, &rng));
    let dense = MaxCut::new(generators::complete(96, palette, &rng));
    let mut blocks = Vec::new();
    for (label, p) in [("sparse_er", &sparse), ("dense_complete", &dense)] {
        let m = p.model();
        let pts = precision::sweep(m, &spec, &widths, steps, 17);
        println!("{label} (N={}):", m.len());
        let mut rows = Vec::new();
        for pt in &pts {
            println!(
                "  {:>2} bits: winner {:>6} | quantized {:>9} | original {:>9} | \
                 {:>5} cycles/step | {:>8} B ({})",
                pt.bits,
                pt.winner,
                pt.quantized_energy,
                pt.original_energy,
                pt.step_cycles,
                pt.model_bytes,
                pt.tier
            );
            rows.push(format!(
                "{{\"bits\":{},\"winner\":\"{}\",\"quantized_energy\":{},\
                 \"original_energy\":{},\"step_cycles\":{},\"end_to_end_seconds\":{:.6},\
                 \"model_bytes\":{},\"tier\":\"{}\"}}",
                pt.bits,
                pt.winner,
                pt.quantized_energy,
                pt.original_energy,
                pt.step_cycles,
                pt.end_to_end_seconds,
                pt.model_bytes,
                pt.tier
            ));
        }
        blocks.push(format!(
            "\"{label}\": {{\"n\": {}, \"points\": [\n    {}\n  ]}}",
            m.len(),
            rows.join(",\n    ")
        ));
    }
    let json = format!(
        "{{\n  \"schema\": \"snowball.bench.precision/v1\",\n  \"profile\": \"{}\",\n  \
         \"steps\": {steps},\n  \"widths\": {widths:?},\n  {}\n}}\n",
        if quick { "quick" } else { "full" },
        blocks.join(",\n  ")
    );
    let path = "BENCH_precision.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// `--locality`: the memory-bandwidth numbers behind
/// `BENCH_locality.json` (precision-packed store + NUMA-local lane
/// rows). Every packed-vs-i32 comparison runs behind a full-signature
/// bit-identity guard, so equal flip counts make the bytes/step ratio
/// exact — the speedup (if any) and the traffic cut can never come
/// from diverging work.
fn bench_locality(quick: bool) {
    use snowball::ising::Tier;

    let n = 4096usize;
    let steps: u64 = if quick { 8_000 } else { 24_000 };
    let mk_cfg = |steps: u64, shards: usize, pin: bool, local: bool| EngineConfig {
        mode: Mode::RouletteWheel,
        datapath: Datapath::Dense,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
        steps,
        seed: 7,
        planes: None,
        trace_stride: 0,
        shards,
        pin_lanes: pin,
        local_rows: local,
    };
    let widened = |m: &IsingModel| {
        let mut w = m.clone();
        w.force_tier(Tier::I32);
        w
    };
    let timed = |m: &IsingModel| {
        let mut e = SnowballEngine::new(m, mk_cfg(steps, 1, false, false));
        let start = std::time::Instant::now();
        let r = e.run();
        (steps as f64 / start.elapsed().as_secs_f64(), r)
    };
    let sig = |r: &snowball::engine::RunResult| {
        (
            r.best_energy,
            r.best_step,
            r.final_energy,
            r.flips,
            r.fallbacks,
            r.nulls,
            r.best_spins.to_spins(),
            r.final_spins.to_spins(),
        )
    };

    // Dense section: N = 4096 all-to-all ±1 (the paper's workload
    // shape). Every flip walks a full N-element row of the packed
    // store, so the per-step coupling traffic is flips/steps × N ×
    // element width — the tier cut lands directly on the hot loop.
    let rng = StatelessRng::new(5);
    let dense = MaxCut::new(generators::complete(n, &[-1, 1], &rng));
    let dense_packed = dense.model();
    assert_eq!(dense_packed.tier(), Tier::I8, "±1 all-to-all must pack as i8");
    let dense_wide = widened(dense_packed);
    let (dense_sps_packed, rp) = timed(dense_packed);
    let (dense_sps_i32, rw) = timed(&dense_wide);
    assert_eq!(sig(&rp), sig(&rw), "dense: packed vs i32 diverged — benchmark void");
    let row_traffic = |r: &snowball::engine::RunResult, tier: Tier| {
        r.flips as f64 / steps as f64 * n as f64 * tier.bytes_per_coupling() as f64
    };
    let dense_bps_packed = row_traffic(&rp, dense_packed.tier());
    let dense_bps_i32 = row_traffic(&rw, Tier::I32);
    let dense_ratio = dense_bps_i32 / dense_bps_packed;
    println!(
        "dense       : N={n} {steps} steps | packed({}) {dense_sps_packed:>10.0} steps/s \
         {dense_bps_packed:>8.0} B/step | i32 {dense_sps_i32:>10.0} steps/s \
         {dense_bps_i32:>8.0} B/step | {dense_ratio:.1}x less traffic",
        dense_packed.tier().label()
    );
    // The acceptance line this benchmark exists for: the packed dense
    // row walk must move at least 2x fewer coupling bytes per step
    // (it is exactly 4x for i8 — flip counts are equal by the guard).
    assert!(
        dense_ratio >= 2.0,
        "packed dense traffic only {dense_ratio:.2}x lighter than i32 — tentpole regressed"
    );

    // Sparse section: N = 4096, average degree 8. The hot loop here
    // runs on the CSR adjacency slabs, whose index+weight layout is
    // tier-invariant — what the packed store cuts is the resident
    // model footprint (and with it registry capacity and lane-copy
    // cost), so that is what the section records.
    let edges = 16_384usize;
    let rng = StatelessRng::new(9);
    let sparse = MaxCut::new(generators::erdos_renyi(n, edges, &[-1, 1], &rng));
    let sparse_packed = sparse.model();
    assert_eq!(sparse_packed.tier(), Tier::I8);
    let sparse_wide = widened(sparse_packed);
    let (sparse_sps_packed, rp) = timed(sparse_packed);
    let (sparse_sps_i32, rw) = timed(&sparse_wide);
    assert_eq!(sig(&rp), sig(&rw), "sparse: packed vs i32 diverged — benchmark void");
    let sparse_bytes_packed = sparse_packed.approx_bytes();
    let sparse_bytes_i32 = sparse_wide.approx_bytes();
    let sparse_bytes_ratio = sparse_bytes_i32 as f64 / sparse_bytes_packed as f64;
    println!(
        "sparse      : N={n} |E|={edges} {steps} steps | packed {sparse_sps_packed:>10.0} \
         steps/s {sparse_bytes_packed} resident B | i32 {sparse_sps_i32:>10.0} steps/s \
         {sparse_bytes_i32} resident B | {sparse_bytes_ratio:.1}x smaller"
    );

    // NUMA grid: the async sharded engine on the dense instance,
    // pinned/unpinned x local-rows-on/off. Async lanes are
    // real-nondeterministic, so the guard here is exactness of the
    // distributed bookkeeping, not bit-identity.
    let shards = 2usize;
    let grid_steps: u64 = if quick { 8_000 } else { 24_000 };
    let mut cells = Vec::new();
    for (pin, local) in [(false, false), (false, true), (true, false), (true, true)] {
        let mut e = ShardedEngine::new(
            dense_packed,
            mk_cfg(grid_steps, shards, pin, local),
            MergeMode::Async,
        )
        .with_window(64);
        let start = std::time::Instant::now();
        let (r, stats) = e.run_with_stats();
        let sps = r.steps as f64 / start.elapsed().as_secs_f64();
        assert_eq!(
            r.final_energy,
            dense_packed.energy(&r.final_spins),
            "pin={pin} local={local}: distributed bookkeeping drifted"
        );
        if local {
            assert!(stats.local_row_bytes > 0, "local_rows on but no lane materialized a copy");
        } else {
            assert_eq!(stats.local_row_bytes, 0, "local_rows off but lanes copied rows");
        }
        println!(
            "numa grid   : pin={pin:<5} local_rows={local:<5} | {sps:>10.0} steps/s | \
             {} pinned lanes | {} local row bytes",
            stats.pinned_lanes, stats.local_row_bytes
        );
        cells.push(format!(
            "{{\"pin_lanes\":{pin},\"local_rows\":{local},\"steps_per_sec\":{sps:.1},\
             \"pinned_lanes\":{},\"local_row_bytes\":{}}}",
            stats.pinned_lanes, stats.local_row_bytes
        ));
    }

    let json = format!(
        "{{\n  \"schema\": \"snowball.bench.locality/v1\",\n  \"profile\": \"{}\",\n  \
         \"n\": {n},\n  \"steps\": {steps},\n  \"bit_identity\": true,\n  \
         \"dense\": {{\"tier\": \"{}\", \
         \"steps_per_sec_packed\": {dense_sps_packed:.1}, \
         \"steps_per_sec_i32\": {dense_sps_i32:.1}, \
         \"coupling_bytes_per_step_packed\": {dense_bps_packed:.1}, \
         \"coupling_bytes_per_step_i32\": {dense_bps_i32:.1}, \
         \"bytes_per_step_ratio\": {dense_ratio:.2}}},\n  \
         \"sparse\": {{\"tier\": \"{}\", \"edges\": {edges}, \
         \"steps_per_sec_packed\": {sparse_sps_packed:.1}, \
         \"steps_per_sec_i32\": {sparse_sps_i32:.1}, \
         \"model_bytes_packed\": {sparse_bytes_packed}, \
         \"model_bytes_i32\": {sparse_bytes_i32}, \
         \"model_bytes_ratio\": {sparse_bytes_ratio:.2}, \
         \"csr_traffic_tier_invariant\": true}},\n  \
         \"numa_grid\": {{\"shards\": {shards}, \"steps\": {grid_steps}, \"cells\": [\n    \
         {}\n  ]}}\n}}\n",
        if quick { "quick" } else { "full" },
        dense_packed.tier().label(),
        sparse_packed.tier().label(),
        cells.join(",\n    ")
    );
    let path = "BENCH_locality.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let smoke = args.flag("smoke");
    let quick = args.flag("quick") || smoke;
    if args.flag("portfolio") {
        bench_portfolio(quick);
        return;
    }
    if args.flag("precision") {
        bench_precision(quick);
        return;
    }
    if args.flag("load") {
        bench_service_load(quick);
        return;
    }
    if args.flag("shards") {
        bench_shards(quick);
        return;
    }
    if args.flag("registry") {
        bench_registry(quick);
        return;
    }
    if args.flag("locality") {
        bench_locality(quick);
        return;
    }
    let sizes: Vec<usize> = if smoke {
        vec![256]
    } else if quick {
        vec![256, 1024]
    } else {
        vec![256, 512, 1024, 2000]
    };
    let steps: u64 = if smoke { 2_000 } else if quick { 5_000 } else { 20_000 };
    let profile = if smoke {
        "smoke"
    } else if quick {
        "quick"
    } else {
        "full"
    };

    let mut json_rows: Vec<BenchRow> = Vec::new();
    let mut rows = Vec::new();
    for &n in &sizes {
        for (mode, dp, sel, label) in [
            (Mode::RandomScan, Datapath::Dense, SelectorKind::Fenwick, "RSA/dense"),
            (Mode::RouletteWheel, Datapath::Dense, SelectorKind::LinearScan, "RWA/dense/scan"),
            (Mode::RouletteWheel, Datapath::Dense, SelectorKind::Fenwick, "RWA/dense/fenwick"),
            (Mode::RouletteWheel, Datapath::BitPlane, SelectorKind::Fenwick, "RWA/bitplane"),
        ] {
            let (ns, flip_rate) = bench_engine(n, mode, dp, sel, steps);
            rows.push(vec![
                n.to_string(),
                label.to_string(),
                format!("{ns:.0}"),
                format!("{:.0}", ns / n as f64 * 1000.0),
                format!("{flip_rate:.2}"),
            ]);
            json_rows.push(BenchRow {
                n,
                mode: mode.name(),
                datapath: if dp == Datapath::Dense { "dense" } else { "bitplane" },
                selector: sel.name(),
                ns_per_step: ns,
                steps_per_sec: 1e9 / ns,
                flip_rate,
            });
        }
    }
    print!(
        "{}",
        hx::render_table(
            "engine hot path (complete ±1 graphs)",
            &["N", "mode/datapath/selector", "ns/step", "ps/spin-step", "flip rate"],
            &rows
        )
    );

    // Fenwick vs scan on the sparse RWA workload the tentpole targets:
    // N = 4096 with average degree 8, constant-temperature plateau.
    let (fn_n, fn_edges) = (4096usize, 16_384usize);
    let fn_steps: u64 = if quick { 5_000 } else { 20_000 };
    let (scan_sps, fenwick_sps) = bench_fenwick_vs_scan(fn_n, fn_edges, fn_steps);
    let speedup = fenwick_sps / scan_sps;
    println!(
        "\nfenwick vs scan: N={fn_n} sparse (|E|={fn_edges}) RWA x {fn_steps} steps | \
         scan {scan_sps:.0} steps/s | fenwick {fenwick_sps:.0} steps/s | {speedup:.1}x"
    );

    // Replica-pool scaling: R independent replicas through the shared
    // ReplicaPool, serial vs one-worker-per-core. Asserts the pool's
    // determinism contract (identical best energies) while measuring the
    // wall-clock speedup.
    let pool_line = {
        let n = if quick { 512 } else { 1024 };
        let replicas = 8usize;
        let pool_steps: u64 = if smoke { 1_000 } else if quick { 2_000 } else { 10_000 };
        let rng = StatelessRng::new(11);
        let g = generators::complete(n, &[-1, 1], &rng);
        let p = MaxCut::new(g);
        let run_with = |workers: usize| -> (f64, usize, Vec<i64>) {
            let pool = ReplicaPool::new(workers);
            let root = StatelessRng::new(21);
            let start = std::time::Instant::now();
            let best: Vec<i64> = pool.run_indexed(replicas, |i| {
                let cfg = EngineConfig {
                    mode: Mode::RouletteWheel,
                    datapath: Datapath::Dense,
                    selector: SelectorKind::Fenwick,
                    schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
                    steps: pool_steps,
                    seed: root.child(i as u64).seed(),
                    planes: None,
                    trace_stride: 0,
                    shards: 1,
                    pin_lanes: false,
                    local_rows: false,
                };
                SnowballEngine::new(p.model(), cfg).run().best_energy
            });
            (start.elapsed().as_secs_f64(), pool.workers(), best)
        };
        let (t_serial, _, serial) = run_with(1);
        let (t_wide, cores, wide) = run_with(0);
        assert_eq!(serial, wide, "replica pool must be deterministic across worker counts");
        let line = format!(
            "replica pool: {replicas} replicas x {pool_steps} RWA steps (N={n}) | \
             1 worker {:.1} ms | {cores} workers {:.1} ms | {:.2}x speedup",
            t_serial * 1e3,
            t_wide * 1e3,
            t_serial / t_wide
        );
        println!("\n{line}");
        format!(
            "{{\"replicas\":{replicas},\"steps\":{pool_steps},\"n\":{n},\
             \"serial_ms\":{:.3},\"parallel_ms\":{:.3},\"workers\":{cores}}}",
            t_serial * 1e3,
            t_wide * 1e3
        )
    };

    // Machine-readable report for cross-PR tracking.
    let json = format!(
        "{{\n  \"schema\": \"snowball.bench.engine/v1\",\n  \"profile\": \"{profile}\",\n  \
         \"rows\": [\n    {}\n  ],\n  \"fenwick_vs_scan\": {{\"n\": {fn_n}, \"edges\": {fn_edges}, \
         \"steps\": {fn_steps}, \"scan_steps_per_sec\": {scan_sps:.1}, \
         \"fenwick_steps_per_sec\": {fenwick_sps:.1}, \"speedup\": {speedup:.2}}},\n  \
         \"replica_pool\": {pool_line}\n}}\n",
        json_rows.iter().map(|r| r.json()).collect::<Vec<_>>().join(",\n    ")
    );
    let path = "BENCH_engine.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    // XLA chunk throughput, if artifacts are present.
    if let (Ok(manifest), Ok(rt)) =
        (snowball::runtime::ArtifactManifest::discover(), snowball::runtime::Runtime::cpu())
    {
        println!();
        for spec in manifest.specs.iter().filter(|s| s.kind == "anneal_chunk") {
            let n = spec.n;
            let rng = StatelessRng::new(2);
            let g = generators::complete(n, &[-1, 1], &rng);
            let p = MaxCut::new(g);
            let runner = match snowball::runtime::ChunkRunner::new(&rt, spec, p.model(), 7) {
                Ok(r) => r,
                Err(e) => {
                    println!("{}: skipped ({e})", spec.name);
                    continue;
                }
            };
            let spins = snowball::ising::SpinVec::random(n, &rng);
            let mut state = snowball::runtime::chunk::ChunkState::init(p.model(), spins);
            let temps = vec![1.0f64; runner.chunk_len() as usize];
            // Warm-up + timed chunks.
            let _ = runner.run_chunk(&rt, &mut state, &temps);
            let reps = if quick { 2 } else { 5 };
            let start = std::time::Instant::now();
            for _ in 0..reps {
                runner.run_chunk(&rt, &mut state, &temps).unwrap();
            }
            let total = start.elapsed().as_secs_f64();
            let steps = reps * runner.chunk_len();
            println!(
                "XLA {}: {:.1} us/step ({} steps in {:.1} ms)",
                spec.name,
                total * 1e6 / steps as f64,
                steps,
                total * 1e3
            );
        }
    } else {
        println!("\nXLA chunk bench skipped (no artifacts; run `make artifacts`)");
    }
}
