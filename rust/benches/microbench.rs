//! §Perf microbenchmarks: per-step cost of the engine hot paths across
//! instance sizes and datapaths, plus the XLA chunk throughput when
//! artifacts are available. These are the numbers EXPERIMENTS.md §Perf
//! tracks before/after optimization.
//!
//!     cargo bench --bench microbench -- [--quick]

use snowball::cli::Args;
use snowball::engine::{Datapath, EngineConfig, Mode, ReplicaPool, Schedule, SnowballEngine};
use snowball::graph::generators;
use snowball::harness as hx;
use snowball::problems::MaxCut;
use snowball::rng::StatelessRng;

fn bench_engine(n: usize, mode: Mode, dp: Datapath, steps: u64) -> (f64, f64) {
    let rng = StatelessRng::new(1);
    let g = generators::complete(n, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    let cfg = EngineConfig {
        mode,
        datapath: dp,
        schedule: Schedule::Constant(1.0),
        steps,
        seed: 3,
        planes: None,
        trace_stride: 0,
    };
    let mut e = SnowballEngine::new(p.model(), cfg);
    let start = std::time::Instant::now();
    let r = e.run();
    let total = start.elapsed().as_secs_f64();
    (total * 1e9 / steps as f64, r.flips as f64 / steps as f64)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let quick = args.flag("quick");
    let sizes: Vec<usize> = if quick { vec![256, 1024] } else { vec![256, 512, 1024, 2000] };
    let steps: u64 = if quick { 5_000 } else { 20_000 };

    let mut rows = Vec::new();
    for &n in &sizes {
        for (mode, dp, label) in [
            (Mode::RandomScan, Datapath::Dense, "RSA/dense"),
            (Mode::RouletteWheel, Datapath::Dense, "RWA/dense"),
            (Mode::RouletteWheel, Datapath::BitPlane, "RWA/bitplane"),
        ] {
            let (ns, flip_rate) = bench_engine(n, mode, dp, steps);
            rows.push(vec![
                n.to_string(),
                label.to_string(),
                format!("{ns:.0}"),
                format!("{:.0}", ns / n as f64 * 1000.0),
                format!("{flip_rate:.2}"),
            ]);
        }
    }
    print!(
        "{}",
        hx::render_table(
            "engine hot path (complete ±1 graphs)",
            &["N", "mode/datapath", "ns/step", "ps/spin-step", "flip rate"],
            &rows
        )
    );

    // Replica-pool scaling: R independent replicas through the shared
    // ReplicaPool, serial vs one-worker-per-core. Asserts the pool's
    // determinism contract (identical best energies) while measuring the
    // wall-clock speedup — the repo's first recorded multi-core point.
    {
        let n = if quick { 512 } else { 1024 };
        let replicas = 8usize;
        let pool_steps: u64 = if quick { 2_000 } else { 10_000 };
        let rng = StatelessRng::new(11);
        let g = generators::complete(n, &[-1, 1], &rng);
        let p = MaxCut::new(g);
        let run_with = |workers: usize| -> (f64, usize, Vec<i64>) {
            let pool = ReplicaPool::new(workers);
            let root = StatelessRng::new(21);
            let start = std::time::Instant::now();
            let best: Vec<i64> = pool.run_indexed(replicas, |i| {
                let cfg = EngineConfig {
                    mode: Mode::RouletteWheel,
                    datapath: Datapath::Dense,
                    schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
                    steps: pool_steps,
                    seed: root.child(i as u64).seed(),
                    planes: None,
                    trace_stride: 0,
                };
                SnowballEngine::new(p.model(), cfg).run().best_energy
            });
            (start.elapsed().as_secs_f64(), pool.workers(), best)
        };
        let (t_serial, _, serial) = run_with(1);
        let (t_wide, cores, wide) = run_with(0);
        assert_eq!(serial, wide, "replica pool must be deterministic across worker counts");
        println!(
            "\nreplica pool: {replicas} replicas x {pool_steps} RWA steps (N={n}) | \
             1 worker {:.1} ms | {cores} workers {:.1} ms | {:.2}x speedup",
            t_serial * 1e3,
            t_wide * 1e3,
            t_serial / t_wide
        );
    }

    // XLA chunk throughput, if artifacts are present.
    if let (Ok(manifest), Ok(rt)) =
        (snowball::runtime::ArtifactManifest::discover(), snowball::runtime::Runtime::cpu())
    {
        println!();
        for spec in manifest.specs.iter().filter(|s| s.kind == "anneal_chunk") {
            let n = spec.n;
            let rng = StatelessRng::new(2);
            let g = generators::complete(n, &[-1, 1], &rng);
            let p = MaxCut::new(g);
            let runner = match snowball::runtime::ChunkRunner::new(&rt, spec, p.model(), 7) {
                Ok(r) => r,
                Err(e) => {
                    println!("{}: skipped ({e})", spec.name);
                    continue;
                }
            };
            let spins = snowball::ising::SpinVec::random(n, &rng);
            let mut state = snowball::runtime::chunk::ChunkState::init(p.model(), spins);
            let temps = vec![1.0f64; runner.chunk_len() as usize];
            // Warm-up + timed chunks.
            let _ = runner.run_chunk(&rt, &mut state, &temps);
            let reps = if quick { 2 } else { 5 };
            let start = std::time::Instant::now();
            for _ in 0..reps {
                runner.run_chunk(&rt, &mut state, &temps).unwrap();
            }
            let total = start.elapsed().as_secs_f64();
            let steps = reps * runner.chunk_len();
            println!(
                "XLA {}: {:.1} us/step ({} steps in {:.1} ms)",
                spec.name,
                total * 1e6 / steps as f64,
                steps,
                total * 1e3
            );
        }
    } else {
        println!("\nXLA chunk bench skipped (no artifacts; run `make artifacts`)");
    }
}
