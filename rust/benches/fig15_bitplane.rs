//! Regenerates **Fig 15**: encode a 64×64 16-bit target field into
//! coupler bit-planes (B = 16), anneal with the cosine schedule, decode
//! the planes and report the pixel-exact 16-bit agreement (paper: 99.5%)
//! plus the annealing energy trace (the 2-D/3-D landscape alignment).
//!
//! Also regenerates **Fig 2/3/8** (the small analytic figures).
//!
//!     cargo bench --bench fig15_bitplane

use snowball::harness as hx;

fn main() {
    // ---- Fig 15 ---------------------------------------------------------
    let r = hx::fig15(42);
    println!("== Fig 15: 16-bit bit-plane field recovery ==");
    println!("pixel-exact accuracy : {:.2}% (paper: 99.5%)", r.pixel_accuracy * 100.0);
    println!("energy alignment     : {:.3} of the |F|1 bound", r.spin_alignment);
    let trace: Vec<f64> = r.energy_trace.iter().map(|&(_, e)| e as f64).collect();
    println!("cosine-anneal trace  : {}", hx::sparkline(&trace));
    println!(
        "trace endpoints      : H(start) = {}, H(end) = {}",
        r.energy_trace.first().map(|&(_, e)| e).unwrap_or(0),
        r.energy_trace.last().map(|&(_, e)| e).unwrap_or(0),
    );

    // ---- Fig 3 ----------------------------------------------------------
    println!("\n== Fig 3: Glauber P_flip vs dE (exact | LUT) ==");
    for (t, pts) in hx::fig3(&[0.25, 1.0, 4.0, 1e9], 4) {
        let line: Vec<String> = pts
            .iter()
            .map(|(de, ex, ap)| format!("dE={de}: {ex:.3}|{ap:.3}"))
            .collect();
        println!("T={t:<8} {}", line.join("  "));
    }

    // ---- Fig 2 / Fig 8 --------------------------------------------------
    let (model, landscape) = hx::fig2();
    let min = landscape.iter().min().unwrap();
    println!("\n== Fig 2: K5 landscape ==");
    println!("N=5, 2^5 = {} configs, ground energy {min} (paper: -24)", landscape.len());
    println!("landscape: {}", hx::sparkline(&landscape.iter().map(|&v| v as f64).collect::<Vec<_>>()));
    assert_eq!(model.len(), 5);

    let (e0, e1, moved) = hx::fig8();
    println!("\n== Fig 8: 2-bit arithmetic-shift quantization ==");
    println!("original : {}", hx::sparkline(&e0.iter().map(|&v| v as f64).collect::<Vec<_>>()));
    println!("quantized: {}", hx::sparkline(&e1.iter().map(|&v| v as f64).collect::<Vec<_>>()));
    println!("ground state moved: {moved} (the paper's precision-loss hazard)");
}
