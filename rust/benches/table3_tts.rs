//! Regenerates **Table III** (TTS(0.99) on the K2000 Max-Cut instance)
//! and **Fig 13** (speedup over the Neal baseline): every machine row
//! reimplemented and measured on the same synthesized K2000, with FPGA
//! cycle-model projections for the Snowball modes and the paper's
//! reported rows printed alongside.
//!
//!     cargo bench --bench table3_tts
//!     cargo bench --bench table3_tts -- --quick
//!     cargo bench --bench table3_tts -- --threshold 33000 --runs 20 --sweeps 2000

use snowball::cli::Args;
use snowball::harness as hx;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let quick = args.flag("quick");
    let cfg = hx::TtsConfig {
        // Default threshold 31500 (~94.5% of the SK optimum ≈ 33340):
        // chosen so the default CPU budget resolves success probabilities
        // across the whole line-up. Pass --threshold 33000 --sweeps 4000
        // --runs 20 for the paper's exact bar (long run).
        cut_threshold: args.get_parse_or("threshold", 31_500i64).unwrap(),
        runs: args.get_parse_or("runs", if quick { 4 } else { 8 }).unwrap(),
        sweeps: args.get_parse_or("sweeps", if quick { 150 } else { 400 }).unwrap(),
        seed: args.get_parse_or("seed", 1u64).unwrap(),
        // Serial trials by default: P_a/best-cut are worker-count
        // independent (stateless child seeds), but per-trial wall times
        // — and so the reported t_a/TTS columns — inflate under
        // concurrent contention. Pass --workers 0 (auto) to trade
        // timing fidelity for turnaround.
        workers: args.get_parse_or("workers", 1usize).unwrap(),
    };
    eprintln!(
        "table3: threshold {} | {} runs x {} sweeps | {} workers",
        cfg.cut_threshold,
        cfg.runs,
        cfg.sweeps,
        if cfg.workers == 0 { "auto".to_string() } else { cfg.workers.to_string() }
    );
    let (rows, best) = hx::table3(&cfg);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.machine.clone(),
                r.hardware.clone(),
                format!("{:.3}", r.t_a_ms),
                format!("{:.2}", r.p_a),
                if r.tts99_ms.is_finite() { format!("{:.3}", r.tts99_ms) } else { "inf".into() },
            ]
        })
        .collect();
    print!(
        "{}",
        hx::render_table(
            "Table III: TTS(0.99) on K2000 (measured)",
            &["Machine", "Hardware", "t_a [ms]", "P_a", "TTS(0.99) [ms]"],
            &table
        )
    );
    println!("best cut observed: {best} (threshold {})", cfg.cut_threshold);

    println!("\nFig 13: speedup over measured Neal");
    for (name, s) in hx::fig13(&rows) {
        if s.is_finite() {
            println!("  {name:32} {s:>14.1}x");
        } else {
            println!("  {name:32} {:>14}", "n/a");
        }
    }

    println!("\npaper-reported Table III rows (quoted for context):");
    for r in hx::table3_quoted_rows() {
        println!(
            "  {:24} t_a={:<8} P_a={:<5} TTS(0.99)={} ms",
            r.machine, r.t_a_ms, r.p_a, r.tts99_ms
        );
    }
}
