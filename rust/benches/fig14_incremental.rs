//! Regenerates **Fig 14**: kernel-only vs end-to-end (DMA included) vs
//! naive (no incremental updates) runtime across Monte Carlo step
//! counts, from both the FPGA cycle model (K2000 geometry, 300 MHz) and
//! a measured CPU companion (incremental engine vs Θ(N²) recompute).
//!
//!     cargo bench --bench fig14_incremental -- [--quick]

use snowball::cli::Args;
use snowball::harness as hx;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let quick = args.flag("quick");

    // Cycle-model sweep (the paper's x-axis is MC steps).
    let steps: Vec<u64> = vec![100, 1_000, 10_000, 100_000, 1_000_000];
    let pts = hx::fig14_model(&steps);
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| {
            vec![
                p.steps.to_string(),
                format!("{:.4}", p.kernel_ms),
                format!("{:.4}", p.end_to_end_ms),
                format!("{:.4}", p.naive_ms),
                format!("{:.1}x", p.naive_ms / p.end_to_end_ms),
            ]
        })
        .collect();
    print!(
        "{}",
        hx::render_table(
            "Fig 14 (cycle model, K2000 @300MHz): runtime vs MC steps [ms]",
            &["steps", "kernel-only", "end-to-end", "naive", "naive/e2e"],
            &rows
        )
    );
    let last = pts.last().unwrap();
    println!(
        "kernel/e2e overlap at 1M steps: {:.2}% (paper: ~100% ⇒ compute-bound)",
        last.kernel_ms / last.end_to_end_ms * 100.0
    );

    // Measured CPU companion.
    let n = if quick { 256 } else { 1024 };
    let steps = if quick { 200 } else { 2000 };
    let (inc, naive) = hx::fig14_measured(n, steps, 42);
    println!(
        "\nmeasured (CPU, N={n}, {steps} roulette steps): incremental {:.1} ms | naive {:.1} ms | {:.1}x",
        inc * 1e3,
        naive * 1e3,
        naive / inc
    );
}
