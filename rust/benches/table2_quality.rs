//! Regenerates **Table II** (solution quality on the Gset Max-Cut
//! instances) and its **Fig 12** runtime companion: the full 11-solver
//! line-up (SFG MFG SFA MFA ASF AMF ASA Neal Tabu RWA RSA).
//!
//!     cargo bench --bench table2_quality            # full (6 instances)
//!     cargo bench --bench table2_quality -- --quick # 2 instances, small budget
//!
//! Budget: every solver gets the same per-instance sweep budget (the
//! ReAIM fairness criterion); absolute cut values depend on the
//! synthesized instances (DESIGN.md §3) — the reproduction target is the
//! ORDERING (RWA ≥ RSA ≥ annealed ReAIM family > Neal/Tabu).

use snowball::cli::Args;
use snowball::graph::gset::GsetId;
use snowball::harness as hx;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let quick = args.flag("quick");
    let sweeps: u64 = args.get_parse_or("sweeps", if quick { 100 } else { 400 }).unwrap();
    let seed: u64 = args.get_parse_or("seed", 42u64).unwrap();
    let instances: Vec<GsetId> =
        if quick { vec![GsetId::G11, GsetId::G18] } else { GsetId::TABLE2.to_vec() };

    eprintln!("table2: {} instances, {sweeps} sweeps each, seed {seed}", instances.len());
    let cells = hx::table2(&instances, sweeps, seed);

    let solvers: Vec<String> = {
        let mut v = Vec::new();
        for c in &cells {
            if !v.contains(&c.solver) {
                v.push(c.solver.clone());
            }
        }
        v
    };
    let mut header: Vec<&str> = vec![""];
    header.extend(solvers.iter().map(|s| s.as_str()));
    let mut cut_rows = Vec::new();
    let mut ms_rows = Vec::new();
    for id in &instances {
        let mut cr = vec![id.name().to_string()];
        let mut mr = vec![id.name().to_string()];
        for s in &solvers {
            let cell = cells.iter().find(|c| c.instance == id.name() && &c.solver == s).unwrap();
            cr.push(cell.cut.to_string());
            mr.push(hx::fmt_ms(cell.seconds));
        }
        cut_rows.push(cr);
        ms_rows.push(mr);
    }
    print!("{}", hx::render_table("Table II: cut values (higher is better)", &header, &cut_rows));
    println!();
    print!("{}", hx::render_table("Fig 12: runtimes (ms)", &header, &ms_rows));

    // Reproduction check: Snowball modes lead on every instance.
    let mut wins = 0;
    for id in &instances {
        let best_other = cells
            .iter()
            .filter(|c| c.instance == id.name() && c.solver != "RWA" && c.solver != "RSA")
            .map(|c| c.cut)
            .max()
            .unwrap();
        let snowball_best = cells
            .iter()
            .filter(|c| c.instance == id.name() && (c.solver == "RWA" || c.solver == "RSA"))
            .map(|c| c.cut)
            .max()
            .unwrap();
        if snowball_best >= best_other {
            wins += 1;
        }
    }
    println!(
        "\nreproduction shape: Snowball best-or-tied on {wins}/{} instances (paper: all)",
        instances.len()
    );
}
