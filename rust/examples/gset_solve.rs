//! Gset benchmark mini-run: synthesize a Table I instance and race the
//! full Table II solver line-up on it (scaled-down budgets; the full
//! run is `cargo bench --bench table2_quality`).
//!
//!     cargo run --release --example gset_solve -- --instance G11 --sweeps 500

use snowball::baselines::{table2_lineup, Budget};
use snowball::cli::Args;
use snowball::graph::gset::{self, GsetId};
use snowball::problems::MaxCut;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let name = args.get_or("instance", "G11");
    let sweeps: u64 = args.get_parse_or("sweeps", 500u64)?;
    let seed: u64 = args.get_parse_or("seed", 42u64)?;

    let id = GsetId::ALL
        .iter()
        .copied()
        .find(|i| i.name().eq_ignore_ascii_case(&name))
        .ok_or_else(|| anyhow::anyhow!("unknown instance {name}"))?;
    let g = gset::load_or_synthesize(id, None, seed);
    println!(
        "{}: |V|={} |E|={} density={:.2}% (synthesized to Table I stats)",
        id.name(),
        g.n,
        g.edge_count(),
        g.density() * 100.0
    );
    let problem = MaxCut::new(g);

    println!("{:>8} {:>10} {:>12}", "solver", "cut", "ms");
    for solver in table2_lineup() {
        let r = solver.solve(problem.model(), Budget::sweeps(sweeps), seed);
        println!(
            "{:>8} {:>10} {:>12.1}",
            solver.name(),
            problem.cut_of_energy(r.best_energy),
            r.wall.as_secs_f64() * 1e3
        );
    }
    Ok(())
}
