//! Fig 4 reproduction: plant a dot-matrix "ISCA26" pattern as the ground
//! state of a grid Max-Cut instance, anneal with a linear schedule, and
//! watch the pattern emerge at checkpoints [A]–[F].
//!
//!     cargo run --release --example isca_grid

use snowball::engine::{Datapath, EngineConfig, Mode, Schedule, SelectorKind, SnowballEngine};
use snowball::harness::{isca_pattern, render_grid};
use snowball::problems::MaxCut;

fn main() -> anyhow::Result<()> {
    let (rows, cols, pattern) = isca_pattern();
    let n = rows * cols;
    // Planted instance (same construction as harness::fig4, reproduced
    // here so the checkpoints can be rendered mid-run).
    let mut g = snowball::graph::Graph::empty(n);
    let id = |r: usize, c: usize| (r * cols + c) as u32;
    for r in 0..rows {
        for c in 0..cols {
            let s = pattern[r * cols + c];
            if c + 1 < cols {
                g.add_edge(id(r, c), id(r, c + 1), if s == pattern[r * cols + c + 1] { -1 } else { 1 });
            }
            if r + 1 < rows {
                g.add_edge(id(r, c), id(r + 1, c), if s == pattern[(r + 1) * cols + c] { -1 } else { 1 });
            }
        }
    }
    let problem = MaxCut::new(g);
    let total_steps: u64 = 200_000;
    let schedule = Schedule::Linear { t0: 3.0, t1: 0.0 };
    let cfg = EngineConfig {
        mode: Mode::RouletteWheel,
        datapath: Datapath::Dense,
        selector: SelectorKind::Fenwick,
        schedule: schedule.clone(),
        steps: 0, // stepped manually below
        seed: 2,
        planes: None,
        trace_stride: 0,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
    };
    let mut engine = SnowballEngine::new(problem.model(), cfg);
    let checkpoints = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0];
    let labels = ["A", "B", "C", "D", "E", "F"];
    let mut next = 0usize;
    for t in 0..total_steps {
        let frac = t as f64 / (total_steps - 1) as f64;
        if next < checkpoints.len() && frac >= checkpoints[next] {
            let temp = schedule.temperature(t, total_steps);
            println!(
                "[{}] step {t} T={temp:.3} H={}\n{}",
                labels[next],
                engine.energy(),
                render_grid(engine.spins(), rows, cols)
            );
            next += 1;
        }
        let temp = schedule.temperature(t, total_steps);
        engine.step(t, temp);
    }
    // Final checkpoint: the recovered pattern (mod global flip).
    let mut same = 0usize;
    for i in 0..n {
        if engine.spins().get(i) == pattern[i] {
            same += 1;
        }
    }
    let frac = same.max(n - same) as f64 / n as f64;
    println!(
        "[F] step {total_steps} T=0.000 H={}\n{}",
        engine.energy(),
        render_grid(engine.spins(), rows, cols)
    );
    println!("pattern recovery: {:.1}% of spins (paper: exact at [F])", frac * 100.0);
    Ok(())
}
