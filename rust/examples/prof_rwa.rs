// profile: RWA dense hot path, N=2000, constant T
use snowball::engine::{EngineConfig, Mode, Schedule, SnowballEngine};
fn main() {
    let rng = snowball::rng::StatelessRng::new(1);
    let g = snowball::graph::generators::complete(2000, &[-1, 1], &rng);
    let p = snowball::problems::MaxCut::new(g);
    let mut cfg = EngineConfig::new(Mode::RouletteWheel, 30_000, 3);
    cfg.schedule = Schedule::Constant(1.0);
    let mut e = SnowballEngine::new(p.model(), cfg);
    let start = std::time::Instant::now();
    let r = e.run();
    println!("{} steps, {:?}, {} flips, E={}", r.steps, start.elapsed(), r.flips, r.final_energy);
}
