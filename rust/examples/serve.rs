//! Service demo: start the coordinator + TCP service, then act as a
//! client — submit jobs, poll status, fetch results and metrics over the
//! line protocol. This is the "host software" view of the Ising machine.
//!
//!     cargo run --release --example serve

use snowball::coordinator::{Coordinator, Service};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> String {
    writeln!(stream, "{req}").expect("write");
    let mut line = String::new();
    reader.read_line(&mut line).expect("read");
    let line = line.trim().to_string();
    println!("> {req}\n< {line}");
    line
}

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::start(0);
    let svc = Service::bind(coord, "127.0.0.1:0")?;
    let addr = svc.serve_in_background();
    println!("service on {addr}\n");

    let mut s = TcpStream::connect(addr)?;
    let mut r = BufReader::new(s.try_clone()?);

    request(&mut s, &mut r, "PING");
    // Two concurrent jobs of different sizes.
    let j1 = request(&mut s, &mut r, "SOLVE instance=er:128:600 mode=rwa steps=30000 replicas=6 seed=3 target=-260");
    let j2 = request(&mut s, &mut r, "SOLVE instance=G11 mode=rsa steps=200000 replicas=4 seed=5");
    let id1: u64 = j1.rsplit('=').next().unwrap().parse()?;
    let id2: u64 = j2.rsplit('=').next().unwrap().parse()?;

    for id in [id1, id2] {
        loop {
            let st = request(&mut s, &mut r, &format!("STATUS id={id}"));
            if st.contains("state=done") {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        request(&mut s, &mut r, &format!("RESULT id={id} target=-260"));
    }
    // Metrics dump (multi-line; read until END).
    writeln!(s, "METRICS")?;
    println!("> METRICS");
    let mut line = String::new();
    loop {
        line.clear();
        r.read_line(&mut line)?;
        let t = line.trim_end();
        println!("< {t}");
        if t.ends_with("END") {
            break;
        }
    }
    request(&mut s, &mut r, "QUIT");
    println!("\nserve demo OK");
    Ok(())
}
