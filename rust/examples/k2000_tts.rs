//! **End-to-end driver** (DESIGN.md §6): the paper's headline experiment
//! on a real workload — TTS(0.99) on the K2000 Max-Cut instance
//! (complete graph, 2000 spins, J ∈ {±1}), exercising the full stack:
//!
//!  1. workload construction (graph substrate, Table I statistics),
//!  2. the L3 coordinator fanning replicas over the thread pool
//!     (native engine, both RSA and RWA modes),
//!  3. the AOT **XLA backend** (L1 Pallas + L2 JAX scan loaded via PJRT)
//!     advancing a chain chunk-by-chunk with the coupling matrix resident
//!     on device — proving all three layers compose at K2000 scale,
//!  4. TTS(0.99) statistics (Eq. 32) + FPGA cycle-model projection.
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example k2000_tts -- [--replicas 16] [--sweeps 1500]
//!         [--threshold 32500] [--xla-chunks 2]

use snowball::cli::Args;
use snowball::coordinator::{Backend, Coordinator, JobSpec};
use snowball::engine::{Mode, Schedule, SelectorKind};
use snowball::graph::gset::{self, GsetId};
use snowball::harness;
use snowball::hwsim::{Geometry, HwModel};
use snowball::problems::MaxCut;
use snowball::runtime::{chunk::ChunkState, ArtifactManifest, ChunkRunner, Runtime};
use snowball::tts;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let replicas: u32 = args.get_parse_or("replicas", 12u32)?;
    let sweeps: u64 = args.get_parse_or("sweeps", 800u64)?;
    let threshold: i64 = args.get_parse_or("threshold", 32_500i64)?;
    let xla_chunks: u64 = args.get_parse_or("xla-chunks", 2u64)?;
    let seed: u64 = args.get_parse_or("seed", 1u64)?;

    println!("== K2000 end-to-end driver ==");
    let g = gset::load_or_synthesize(GsetId::K2000, None, seed);
    let problem = MaxCut::new(g);
    let model = problem.model();
    let n = model.len() as u64;
    let target_energy = problem.energy_of_cut(threshold);
    println!(
        "instance: N={} |E|={} threshold cut {} (energy {})",
        n,
        problem.graph.edge_count(),
        threshold,
        target_energy
    );

    // ---- native coordinator runs: RSA and RWA --------------------------
    let coord = Coordinator::start(0);
    let schedule = Schedule::Geometric { t0: 10.0, t1: 0.05 };
    let hw = HwModel::default();
    let geom = Geometry { n: n as usize, planes: 1 };
    let mut rows: Vec<tts::TtsRow> = Vec::new();
    for mode in [Mode::RouletteWheel, Mode::RandomScan] {
        let steps = sweeps * n;
        let id = coord.submit(JobSpec {
            model: Arc::new(model.clone()),
            label: format!("K2000-{}", mode.name()),
            mode,
            selector: SelectorKind::Fenwick,
            schedule: schedule.clone(),
            steps,
            replicas,
            seed,
            target_energy: Some(target_energy),
            shards: 1,
            pin_lanes: false,
            local_rows: false,
            budget_ms: 0,
            max_retries: 0,
            backend: Backend::Native,
            portfolio: None,
        });
        let result = coord.wait(id).ok_or_else(|| anyhow::anyhow!("job failed"))?;
        let est = result.successes(target_energy);
        let t_a = result.mean_replica_seconds();
        let best_cut = problem.cut_of_energy(result.best_energy());
        println!(
            "{}: best cut {} | P_a {}/{} | t_a {:.1} ms | TTS(0.99) {}",
            mode.name(),
            best_cut,
            est.successes,
            est.runs,
            t_a * 1e3,
            harness::fmt_ms(tts::tts99(t_a, est))
        );
        rows.push(tts::TtsRow::measured(mode.name(), "CPU (native)", t_a, est));
        // FPGA @300MHz projection via the cycle model.
        let report = match mode {
            Mode::RandomScan => hw.random_scan_run(geom, steps, steps / 2),
            _ => hw.roulette_run(geom, steps),
        };
        rows.push(tts::TtsRow::measured(
            &format!("{} (FPGA-projected)", mode.name()),
            "FPGA @300MHz",
            report.end_to_end_seconds,
            est,
        ));
    }
    coord.shutdown();

    // ---- XLA backend: the AOT artifact at K2000 scale -------------------
    match (ArtifactManifest::discover(), Runtime::cpu()) {
        (Ok(manifest), Ok(rt)) => {
            if let Some(spec) = manifest.find_padded("anneal_chunk", n as usize) {
                let chunk_len = spec.chunk.unwrap();
                println!(
                    "\nXLA backend: artifact {} (N={} chunk={})",
                    spec.name, spec.n, chunk_len
                );
                let runner = ChunkRunner::new(&rt, spec, model, seed)?;
                let spins = snowball::ising::SpinVec::random(
                    model.len(),
                    &snowball::rng::StatelessRng::new(seed),
                );
                let mut state = ChunkState::init(model, spins);
                let total = chunk_len * xla_chunks;
                let temps = schedule.materialize(total);
                let start = std::time::Instant::now();
                for c in 0..xla_chunks {
                    let lo = (c * chunk_len) as usize;
                    runner.run_chunk(&rt, &mut state, &temps[lo..lo + chunk_len as usize])?;
                }
                let wall = start.elapsed();
                println!(
                    "XLA: {} steps in {:?} ({:.1} us/step), energy {} -> cut {}",
                    total,
                    wall,
                    wall.as_secs_f64() * 1e6 / total as f64,
                    state.energy,
                    problem.cut_of_energy(state.energy as i64)
                );
                println!("(composition proof: rust/tests/xla_parity.rs asserts bit-parity with the native engine)");
            } else {
                println!("\nXLA backend: no anneal_chunk artifact ≥ N={n}; run `make artifacts`");
            }
        }
        (m, r) => {
            println!(
                "\nXLA backend unavailable ({})",
                m.err().map(|e| e.to_string()).unwrap_or_else(|| r
                    .err()
                    .map(|e| e.to_string())
                    .unwrap_or_default())
            );
        }
    }

    // ---- summary table --------------------------------------------------
    println!();
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.machine.clone(),
                r.hardware.clone(),
                format!("{:.3}", r.t_a_ms),
                format!("{:.2}", r.p_a),
                if r.tts99_ms.is_finite() { format!("{:.3}", r.tts99_ms) } else { "inf".into() },
            ]
        })
        .collect();
    print!(
        "{}",
        harness::render_table(
            "K2000 TTS(0.99) summary",
            &["Machine", "Hardware", "t_a [ms]", "P_a", "TTS(0.99) [ms]"],
            &table
        )
    );
    Ok(())
}
