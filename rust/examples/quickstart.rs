//! Quickstart: build a small Max-Cut instance, anneal it with both of
//! Snowball's selection modes, and verify the result against the exact
//! optimum from exhaustive enumeration.
//!
//!     cargo run --release --example quickstart

use snowball::engine::{EngineConfig, Mode, Schedule, SelectorKind, SnowballEngine};
use snowball::graph::generators;
use snowball::problems::{landscape, MaxCut};
use snowball::rng::StatelessRng;

fn main() -> anyhow::Result<()> {
    // A 20-spin ±1 Erdős–Rényi Max-Cut instance: small enough to verify
    // the annealers against the exact ground state (2^20 enumeration).
    let rng = StatelessRng::new(7);
    let g = generators::erdos_renyi(20, 80, &[-1, 1], &rng);
    let problem = MaxCut::new(g);
    let (_, exact_min) = landscape::ground_state(problem.model());
    println!("instance: N=20, |E|=80, exact ground energy = {exact_min}");

    for mode in [Mode::RandomScan, Mode::RouletteWheel] {
        let cfg = EngineConfig {
            mode,
            datapath: snowball::engine::Datapath::Dense,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.02 },
            steps: 20_000,
            seed: 1,
            planes: None,
            trace_stride: 0,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
        };
        let mut engine = SnowballEngine::new(problem.model(), cfg);
        let run = engine.run();
        let cut = problem.cut_of_energy(run.best_energy);
        println!(
            "{:6}: best energy {} (cut {}), optimal: {}, flips {}, {:?}",
            mode.name(),
            run.best_energy,
            cut,
            run.best_energy == exact_min,
            run.flips,
            run.wall
        );
        assert_eq!(run.best_energy, exact_min, "{} missed the optimum", mode.name());
    }
    println!("quickstart OK");
    Ok(())
}
