//! Cycle-approximate model of the Snowball FPGA implementation
//! (paper §IV-B, §V; substitution for the AMD Alveo U250 — DESIGN.md §3).
//!
//! The model counts datapath work in units the architecture defines:
//! 64-coupler words streamed, parallel lanes evaluated, adder-tree
//! levels — then converts to time at the 300 MHz kernel clock the paper
//! reports. It also models the PCIe DMA cost of loading the bit-planes,
//! so the Fig. 14 kernel-only vs end-to-end vs naive comparison can be
//! regenerated.
//!
//! This is a *first-order* model: it reproduces scaling shapes and
//! relative costs (who wins, where incremental updates matter), not
//! place-and-route timing.

/// U250-class platform constants.
#[derive(Clone, Copy, Debug)]
pub struct HwParams {
    /// Kernel clock (paper: 300 MHz).
    pub clock_hz: f64,
    /// Parallel evaluation lanes in the MCMC engine (spins evaluated per
    /// cycle in Mode II; one BRAM port pair per lane).
    pub eval_lanes: usize,
    /// 64-bit coupler words processed per cycle during field init /
    /// column updates (bounded by BRAM ports).
    pub words_per_cycle: usize,
    /// Host→device PCIe bandwidth (bytes/s) for DMA modeling.
    pub pcie_bytes_per_sec: f64,
    /// Fixed DMA invocation latency (s).
    pub dma_latency_s: f64,
}

impl Default for HwParams {
    fn default() -> Self {
        Self {
            clock_hz: 300e6,
            eval_lanes: 64,
            words_per_cycle: 16,
            pcie_bytes_per_sec: 12e9, // PCIe gen3 x16 effective
            dma_latency_s: 10e-6,
        }
    }
}

/// Instance geometry the cycle model needs.
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    /// Spins.
    pub n: usize,
    /// Magnitude bit-planes B.
    pub planes: u32,
}

impl Geometry {
    /// Words per row, `W = ceil(N/64)`.
    pub fn words(&self) -> usize {
        self.n.div_ceil(64)
    }

    /// Bytes of coupler bit-planes shipped over DMA (B⁺/B⁻ × row/col).
    pub fn plane_bytes(&self) -> usize {
        4 * self.planes as usize * self.n * self.words() * 8
    }
}

/// Cycle/time report for a run (the Fig. 14 quantities).
#[derive(Clone, Copy, Debug, Default)]
pub struct HwReport {
    pub init_cycles: u64,
    pub step_cycles: u64,
    pub kernel_seconds: f64,
    pub dma_seconds: f64,
    pub end_to_end_seconds: f64,
}

/// The cycle model.
#[derive(Clone, Copy, Debug, Default)]
pub struct HwModel {
    pub params: HwParams,
}

impl HwModel {
    pub fn new(params: HwParams) -> Self {
        Self { params }
    }

    /// Cycles to initialize all local fields from the row-major planes
    /// (Eqs. 14–16): stream `2·B·N·W` words (B⁺ and B⁻) through
    /// `words_per_cycle` popcount units, plus an adder-tree drain.
    pub fn init_cycles(&self, g: Geometry) -> u64 {
        let words = 2 * g.planes as u64 * g.n as u64 * g.words() as u64;
        let stream = words.div_ceil(self.params.words_per_cycle as u64);
        let drain = (g.words() as u64).next_power_of_two().trailing_zeros() as u64 + 4;
        stream + drain
    }

    /// Cycles for one Mode II (roulette) step: evaluate N lanes through
    /// the LUT (`N / eval_lanes` cycles), accumulate W + select via the
    /// comparator tree (log2 N levels), then the column-major incremental
    /// update (`2·B·W` words).
    pub fn roulette_step_cycles(&self, g: Geometry) -> u64 {
        let eval = (g.n as u64).div_ceil(self.params.eval_lanes as u64);
        let select = (g.n as u64).next_power_of_two().trailing_zeros() as u64 + 2;
        eval + select + self.update_cycles(g)
    }

    /// Cycles for one plateau-interior Mode II step under the PR-2
    /// incremental datapath: only `touched` lanes (≈ deg + 1) re-evaluate
    /// through the LUT, and selection descends a comparator/Fenwick tree
    /// (two reads per level instead of the flat tree's one), before the
    /// usual column-major incremental field update.
    pub fn roulette_step_cycles_incremental(&self, g: Geometry, touched: usize) -> u64 {
        let lanes = (touched.min(g.n) as u64).div_ceil(self.params.eval_lanes as u64).max(1);
        let select = 2 * ((g.n as u64).next_power_of_two().trailing_zeros() as u64) + 2;
        lanes + select + self.update_cycles(g)
    }

    /// Full report for `steps` plateau-interior Mode II steps with an
    /// average touched-lane count per flip (boundary costs excluded; see
    /// [`Self::roulette_run_staged`] for whole-schedule accounting).
    pub fn roulette_run_incremental(&self, g: Geometry, steps: u64, touched: usize) -> HwReport {
        let init = self.init_cycles(g);
        let step = self.roulette_step_cycles_incremental(g, touched) * steps;
        self.report(g, init, step)
    }

    /// Whole-run Mode II accounting under the incremental datapath for an
    /// arbitrary schedule: each plateau (from [`Schedule::plateaus`])
    /// pays one full-evaluation step at its boundary and incremental
    /// steps inside. A continuous ramp degenerates to all-bulk steps —
    /// the model's way of showing why the staged `{T_k}` schedules
    /// matter. `touched` ≈ max degree + 1 (`Adjacency::max_degree`).
    pub fn roulette_run_staged(
        &self,
        g: Geometry,
        schedule: &crate::engine::Schedule,
        steps: u64,
        touched: usize,
    ) -> HwReport {
        let init = self.init_cycles(g);
        let mut step_cycles = 0u64;
        for p in schedule.plateaus(steps) {
            step_cycles += self.roulette_step_cycles(g); // boundary bulk refresh
            step_cycles += self.roulette_step_cycles_incremental(g, touched) * (p.len() - 1);
        }
        self.report(g, init, step_cycles)
    }

    /// Cycles one asynchronous shard lane spends per **round** (every
    /// one of the `shards` update units completes one local step —
    /// `shards` global steps of progress, the paper's asynchronous
    /// update units generalized to S lanes):
    ///
    /// * evaluate its `⌈N/S⌉` local lanes through the LUT;
    /// * comparator-tree select over the local lanes;
    /// * apply its own flip plus up to `S − 1` remote flips, each
    ///   streaming only the lane's `2·B·⌈⌈N/S⌉/64⌉` column-segment
    ///   words;
    /// * exchange flip notices with `S − 1` peers (2 cycles each —
    ///   mailbox write + read, degree-independent because receivers
    ///   derive their own deltas).
    ///
    /// `shards == 1` degenerates exactly to
    /// [`Self::roulette_step_cycles`].
    pub fn sharded_roulette_round_cycles(&self, g: Geometry, shards: usize) -> u64 {
        let s = shards.clamp(1, g.n.max(1)) as u64;
        let local_n = (g.n as u64).div_ceil(s);
        let local = Geometry { n: local_n as usize, planes: g.planes };
        let eval = local_n.div_ceil(self.params.eval_lanes as u64);
        let select = local_n.next_power_of_two().trailing_zeros() as u64 + 2;
        let updates = s * self.update_cycles(local);
        let exchange = 2 * (s - 1);
        eval + select + updates + exchange
    }

    /// Full report for `steps` TOTAL Mode II steps spread over
    /// `shards` asynchronous lanes: `⌈steps/S⌉` rounds, each advancing
    /// S steps — wall-clock scales with the round count while the work
    /// per flip stays local.
    pub fn sharded_roulette_run(&self, g: Geometry, shards: usize, steps: u64) -> HwReport {
        let s = shards.clamp(1, g.n.max(1)) as u64;
        let init = self.init_cycles(g);
        let rounds = steps.div_ceil(s);
        let step = self.sharded_roulette_round_cycles(g, shards) * rounds;
        self.report(g, init, step)
    }

    /// Cycles per asynchronous round when each lane runs the
    /// **incremental** per-lane datapath (the shared lane kernel with
    /// Fenwick selection): only `touched` lanes (≈ deg + 1, the local
    /// flip's plus the mailbox flips' in-range neighbourhoods)
    /// re-evaluate through the LUT, selection descends a
    /// comparator/Fenwick tree over the `⌈N/S⌉` local lanes (two reads
    /// per level), and the update/exchange terms are unchanged from
    /// [`Self::sharded_roulette_round_cycles`]. `shards == 1`
    /// degenerates exactly to
    /// [`Self::roulette_step_cycles_incremental`].
    pub fn sharded_roulette_round_cycles_incremental(
        &self,
        g: Geometry,
        shards: usize,
        touched: usize,
    ) -> u64 {
        let s = shards.clamp(1, g.n.max(1)) as u64;
        let local_n = (g.n as u64).div_ceil(s);
        let local = Geometry { n: local_n as usize, planes: g.planes };
        let lanes = (touched.min(local.n) as u64).div_ceil(self.params.eval_lanes as u64).max(1);
        let select = 2 * (local_n.next_power_of_two().trailing_zeros() as u64) + 2;
        let updates = s * self.update_cycles(local);
        let exchange = 2 * (s - 1);
        lanes + select + updates + exchange
    }

    /// Full report for `steps` TOTAL Mode II steps over `shards`
    /// incremental lanes (plateau-interior accounting; boundary bulk
    /// refreshes excluded, as in [`Self::roulette_run_incremental`]).
    pub fn sharded_roulette_run_incremental(
        &self,
        g: Geometry,
        shards: usize,
        steps: u64,
        touched: usize,
    ) -> HwReport {
        let s = shards.clamp(1, g.n.max(1)) as u64;
        let init = self.init_cycles(g);
        let rounds = steps.div_ceil(s);
        let step = self.sharded_roulette_round_cycles_incremental(g, shards, touched) * rounds;
        self.report(g, init, step)
    }

    /// Cycles for one Mode I (random-scan) step: single-site evaluate
    /// (constant) + incremental update on accept.
    pub fn random_scan_step_cycles(&self, g: Geometry, accepted: bool) -> u64 {
        let eval = 6; // field read, ΔE, LUT, compare — pipelined constant
        if accepted {
            eval + self.update_cycles(g)
        } else {
            eval
        }
    }

    /// Column-major incremental update: stream `2·B·W` words (Eqs. 19–20).
    pub fn update_cycles(&self, g: Geometry) -> u64 {
        let words = 2 * g.planes as u64 * g.words() as u64;
        words.div_ceil(self.params.words_per_cycle as u64)
    }

    /// The *naive* alternative (Fig. 14 baseline): recompute every local
    /// field from scratch after each flip — a full init per step.
    pub fn naive_step_cycles(&self, g: Geometry) -> u64 {
        let eval = (g.n as u64).div_ceil(self.params.eval_lanes as u64);
        eval + self.init_cycles(g)
    }

    /// DMA time to ship the bit-planes (+ fields/h vectors) to the card.
    pub fn dma_seconds(&self, g: Geometry) -> f64 {
        let bytes = g.plane_bytes() + 2 * 8 * g.n;
        self.params.dma_latency_s + bytes as f64 / self.params.pcie_bytes_per_sec
    }

    /// Full report for a run of `steps` Mode II steps (incremental).
    pub fn roulette_run(&self, g: Geometry, steps: u64) -> HwReport {
        let init = self.init_cycles(g);
        let step = self.roulette_step_cycles(g) * steps;
        self.report(g, init, step)
    }

    /// Full report for a run of `steps` naive (non-incremental) steps.
    pub fn naive_run(&self, g: Geometry, steps: u64) -> HwReport {
        let init = self.init_cycles(g);
        let step = self.naive_step_cycles(g) * steps;
        self.report(g, init, step)
    }

    /// Full report for a Mode I run with an observed acceptance count.
    pub fn random_scan_run(&self, g: Geometry, steps: u64, accepted: u64) -> HwReport {
        let init = self.init_cycles(g);
        let rejected = steps - accepted.min(steps);
        let step = self.random_scan_step_cycles(g, true) * accepted
            + self.random_scan_step_cycles(g, false) * rejected;
        self.report(g, init, step)
    }

    fn report(&self, g: Geometry, init_cycles: u64, step_cycles: u64) -> HwReport {
        let kernel = (init_cycles + step_cycles) as f64 / self.params.clock_hz;
        let dma = self.dma_seconds(g);
        HwReport {
            init_cycles,
            step_cycles,
            kernel_seconds: kernel,
            dma_seconds: dma,
            end_to_end_seconds: kernel + dma,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k2000() -> Geometry {
        Geometry { n: 2000, planes: 1 }
    }

    #[test]
    fn incremental_beats_naive_per_step() {
        let hw = HwModel::default();
        let g = k2000();
        assert!(
            hw.roulette_step_cycles(g) < hw.naive_step_cycles(g) / 10,
            "incremental update must be an order of magnitude cheaper"
        );
    }

    #[test]
    fn compute_bound_at_scale() {
        // Fig 14's claim: kernel time dominates DMA for realistic step
        // counts (compute-bound), i.e. end-to-end ≈ kernel-only.
        let hw = HwModel::default();
        let g = k2000();
        let r = hw.roulette_run(g, 200_000);
        assert!(r.kernel_seconds / r.end_to_end_seconds > 0.95);
    }

    #[test]
    fn init_scales_linearly_in_planes() {
        let hw = HwModel::default();
        let c1 = hw.init_cycles(Geometry { n: 1024, planes: 1 });
        let c4 = hw.init_cycles(Geometry { n: 1024, planes: 4 });
        // Linear up to the constant adder-tree drain.
        assert!((c4 as f64 / c1 as f64) > 3.5 && (c4 as f64 / c1 as f64) < 4.5);
    }

    #[test]
    fn incremental_selection_beats_full_evaluation() {
        let hw = HwModel::default();
        let g = k2000();
        // Sparse touch sets (deg ≈ 8) make the step much cheaper than the
        // full N-lane evaluate + flat select.
        let sparse = hw.roulette_step_cycles_incremental(g, 9);
        assert!(
            sparse < hw.roulette_step_cycles(g),
            "incremental step ({sparse}) must beat full evaluation ({})",
            hw.roulette_step_cycles(g)
        );
        // Monotone in the touched count, and within ~2x of the full
        // evaluate when everything is touched (deeper select tree).
        let dense = hw.roulette_step_cycles_incremental(g, g.n);
        assert!(sparse < dense);
        assert!(dense <= 2 * hw.roulette_step_cycles(g));
        // Run-level accounting matches step-level accounting.
        let r = hw.roulette_run_incremental(g, 1000, 9);
        assert_eq!(r.step_cycles, 1000 * sparse);
    }

    #[test]
    fn staged_schedule_amortizes_bulk_refreshes() {
        use crate::engine::Schedule;
        let hw = HwModel::default();
        let g = k2000();
        let steps = 100_000u64;
        let cont = Schedule::Geometric { t0: 8.0, t1: 0.05 };
        // Continuous ramp: every plateau has length 1 → all-bulk steps,
        // identical to the non-incremental run.
        let all_bulk = hw.roulette_run_staged(g, &cont, steps, 9);
        assert_eq!(all_bulk.step_cycles, steps * hw.roulette_step_cycles(g));
        // 32 coarse stages: bulk refreshes amortize away.
        let staged = hw.roulette_run_staged(g, &cont.quantized(32), steps, 9);
        assert!(
            staged.step_cycles * 10 < all_bulk.step_cycles * 7,
            "staged {} vs continuous {}",
            staged.step_cycles,
            all_bulk.step_cycles
        );
    }

    #[test]
    fn sharded_round_reduces_to_single_lane() {
        let hw = HwModel::default();
        let g = k2000();
        assert_eq!(
            hw.sharded_roulette_round_cycles(g, 1),
            hw.roulette_step_cycles(g),
            "one lane must cost exactly the classic step"
        );
        let r1 = hw.sharded_roulette_run(g, 1, 10_000);
        let r0 = hw.roulette_run(g, 10_000);
        assert_eq!(r1.step_cycles, r0.step_cycles);
    }

    #[test]
    fn sharded_lanes_raise_step_throughput() {
        let hw = HwModel::default();
        let g = k2000();
        // Cycles per GLOBAL step (round / S) must strictly improve as
        // lanes are added on a big all-to-all instance…
        let per_step =
            |s: usize| hw.sharded_roulette_round_cycles(g, s) as f64 / s as f64;
        assert!(per_step(2) < per_step(1));
        assert!(per_step(4) < per_step(2));
        assert!(per_step(8) < per_step(4));
        // …and the run-level accounting follows the round count.
        let steps = 64_000u64;
        let run = hw.sharded_roulette_run(g, 8, steps);
        assert_eq!(
            run.step_cycles,
            steps.div_ceil(8) * hw.sharded_roulette_round_cycles(g, 8)
        );
        assert!(run.kernel_seconds < hw.roulette_run(g, steps).kernel_seconds);
    }

    #[test]
    fn incremental_sharded_round_beats_bulk_and_degenerates_cleanly() {
        let hw = HwModel::default();
        let g = k2000();
        // One lane degenerates exactly to the single-lane incremental
        // step, as the bulk round degenerates to the classic step.
        assert_eq!(
            hw.sharded_roulette_round_cycles_incremental(g, 1, 9),
            hw.roulette_step_cycles_incremental(g, 9)
        );
        // At scale the local evaluate dominates and the incremental
        // round wins for every lane count; on small local lane counts
        // the doubled tree-descent reads can eat the saving — which is
        // exactly the SHARD_AUTO_MIN_N-style size story.
        let big = Geometry { n: 65_536, planes: 1 };
        for s in [2usize, 4, 8] {
            let inc = hw.sharded_roulette_round_cycles_incremental(big, s, 9);
            let bulk = hw.sharded_roulette_round_cycles(big, s);
            assert!(inc < bulk, "S = {s}: incremental {inc} !< bulk {bulk}");
            // Monotone in the touched count.
            assert!(inc <= hw.sharded_roulette_round_cycles_incremental(big, s, big.n));
        }
        // Run-level accounting matches step-level accounting.
        let r = hw.sharded_roulette_run_incremental(g, 4, 10_000, 9);
        assert_eq!(
            r.step_cycles,
            10_000u64.div_ceil(4) * hw.sharded_roulette_round_cycles_incremental(g, 4, 9)
        );
    }

    #[test]
    fn sharding_tiny_instances_is_overhead_bound() {
        // On a small instance the exchange term dominates: per-step
        // cycles stop improving long before the lane count does — the
        // cycle-model justification for the SHARD_AUTO_MIN_N policy.
        let hw = HwModel::default();
        let g = Geometry { n: 128, planes: 1 };
        let per_step =
            |s: usize| hw.sharded_roulette_round_cycles(g, s) as f64 / s as f64;
        let speedup_16 = per_step(1) / per_step(16);
        assert!(speedup_16 < 16.0 / 2.0, "tiny instance speedup {speedup_16} implausible");
    }

    #[test]
    fn rejected_steps_are_cheap() {
        let hw = HwModel::default();
        let g = k2000();
        assert!(hw.random_scan_step_cycles(g, false) < hw.random_scan_step_cycles(g, true));
        // With wide planes the update dominates: B = 8 planes.
        let wide = Geometry { n: 2000, planes: 8 };
        assert!(
            hw.random_scan_step_cycles(wide, false) < hw.random_scan_step_cycles(wide, true) / 2
        );
    }

    #[test]
    fn dma_accounts_plane_bytes() {
        let hw = HwModel::default();
        let g = Geometry { n: 2048, planes: 2 };
        // 4 arrays × 2 planes × 2048 rows × 32 words × 8 bytes = 4 MiB.
        assert_eq!(g.plane_bytes(), 4 * 2 * 2048 * 32 * 8);
        assert!(hw.dma_seconds(g) > hw.params.dma_latency_s);
    }
}
