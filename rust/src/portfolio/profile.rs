//! Instance profiling behind `portfolio=auto`: measure the structural
//! features that predict which engines are competitive (size, coupling
//! density, precision bits, external fields — the
//! algorithm-per-instance-profile selection argument of
//! arXiv:2605.12959) and derive a default roster from them.

use super::{contender_by_name, Contender};
use crate::ising::IsingModel;
use crate::problems::quantize;

/// Structural features of one instance.
#[derive(Clone, Copy, Debug)]
pub struct InstanceProfile {
    pub n: usize,
    /// Nonzero couplings over N·(N−1)/2.
    pub density: f64,
    /// Signed bits needed to represent the widest coefficient
    /// ([`quantize::required_bits`]) — the paper's challenge-3 axis.
    pub bits: u32,
    /// Any nonzero external field h_i.
    pub has_fields: bool,
}

impl InstanceProfile {
    pub fn of(model: &IsingModel) -> Self {
        Self {
            n: model.len(),
            density: model.density(),
            bits: quantize::required_bits(model),
            has_fields: (0..model.len()).any(|i| model.h(i) != 0),
        }
    }
}

/// The `portfolio=auto` roster policy. Always races both Snowball
/// modes; the rest of the roster follows the profile:
///
/// * small instances (N ≤ 256) add the strong sequential heuristics
///   (`tabu`, `neal`) — their Θ(N) move scans are still cheap;
/// * dense instances (≥ 25% of couplings present) add the mat-vec
///   solvers (`sb`, `statica`) that amortize full-row work;
/// * sparse instances add `checkerboard` (few colour classes) and
///   `reaim`;
/// * large instances (N ≥ 2048) add the sharded engine;
/// * narrow coefficients (≤ 6 signed bits) add the bit-plane datapath,
///   whose per-step cost scales with plane count.
pub fn auto_roster(p: &InstanceProfile) -> Vec<Contender> {
    let mut names: Vec<&str> = vec!["rwa", "rsa"];
    if p.n <= 256 {
        names.push("tabu");
        names.push("neal");
    }
    if p.density >= 0.25 {
        names.push("sb");
        names.push("statica");
    } else {
        names.push("checkerboard");
        names.push("reaim");
    }
    if p.n >= 2048 {
        names.push("rwa-sharded");
    }
    if p.bits <= 6 {
        names.push("rwa-bitplane");
    }
    names.into_iter().filter_map(contender_by_name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::StatelessRng;

    #[test]
    fn profile_measures_structure() {
        let rng = StatelessRng::new(3);
        let p = MaxCut::new(generators::erdos_renyi(64, 300, &[-3, 3], &rng));
        let prof = InstanceProfile::of(p.model());
        assert_eq!(prof.n, 64);
        assert!(prof.density > 0.0 && prof.density <= 1.0);
        assert_eq!(prof.bits, 2); // max |J| = 3 → 2 magnitude bits
        assert!(!prof.has_fields);
    }

    #[test]
    fn auto_roster_tracks_profile() {
        let sparse_small =
            InstanceProfile { n: 128, density: 0.05, bits: 2, has_fields: false };
        let names: Vec<&str> =
            auto_roster(&sparse_small).iter().map(|c| c.name).collect();
        assert!(names.contains(&"rwa") && names.contains(&"rsa"));
        assert!(names.contains(&"tabu") && names.contains(&"checkerboard"));
        assert!(names.contains(&"rwa-bitplane"));
        assert!(!names.contains(&"rwa-sharded"));

        let dense_large =
            InstanceProfile { n: 4096, density: 0.5, bits: 12, has_fields: true };
        let names: Vec<&str> =
            auto_roster(&dense_large).iter().map(|c| c.name).collect();
        assert!(names.contains(&"sb") && names.contains(&"statica"));
        assert!(names.contains(&"rwa-sharded"));
        assert!(!names.contains(&"rwa-bitplane"));
        assert!(!names.contains(&"tabu"));
    }
}
