//! Heterogeneous solver portfolio: race one instance across a roster of
//! contenders — Snowball engine configurations (mode × selector ×
//! datapath × shard count) plus every Table II/III baseline — under one
//! shared budget, first-finisher-wins (HETRI-style multiprocessing,
//! arXiv:2410.23517).
//!
//! The racer gives each contender its own [`StopToken`] and a
//! decorrelated child seed. The first contender whose incumbent reaches
//! the target energy trips *every* token, so losers return their
//! best-so-far partials within one stop-check stride; a job-level
//! cancel/deadline/shutdown token is forwarded the same way. The winner
//! is the argmin over final reported energies (lowest roster index
//! breaks ties), which makes the outcome deterministic whenever the
//! race runs to budget — the property `tests/portfolio.rs` pins.
//!
//! Submitting is threaded end-to-end like `shards=` was:
//! [`JobSpec::portfolio`], wire `SOLVE portfolio=auto|full|<list>`,
//! CLI `solve --portfolio`, `RESULT ... winner=<name> c<i>=<stats>`,
//! and `portfolio_*` metrics (docs/PROTOCOL.md, docs/ARCHITECTURE.md
//! § Portfolio layer).
//!
//! Submodules: [`profile`] (instance profiling behind `portfolio=auto`)
//! and [`precision`] (the coupling bit-width sweep harness behind
//! `BENCH_precision.json` — paper challenge 3).

pub mod precision;
pub mod profile;

use crate::baselines::{
    Budget, Checkerboard, Cim, Neal, ReAim, SimulatedBifurcation, SolveCtl, Solver, Statica, Tabu,
};
use crate::coordinator::{JobSpec, ReplicaResult};
use crate::engine::{
    Datapath, EngineConfig, MergeMode, Mode, Schedule, SelectorKind, ShardedEngine, SnowballEngine,
};
use crate::ising::{IsingModel, SpinVec};
use crate::rng::StatelessRng;
use crate::stop::{StopCause, StopToken};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How a job picks its roster. Parsed from `SOLVE portfolio=` / CLI
/// `--portfolio` / config `[job] portfolio`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PortfolioSpec {
    /// Instance-profile-driven roster ([`profile::auto_roster`]).
    Auto,
    /// Every known contender.
    Full,
    /// An explicit comma-separated contender list (duplicates allowed —
    /// they race as independent copies with decorrelated seeds).
    List(Vec<String>),
}

impl PortfolioSpec {
    /// Parse a `portfolio=` value. The two error strings are wire ERR
    /// forms, pinned verbatim by `tests/portfolio.rs` and
    /// docs/PROTOCOL.md.
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        match s {
            "" => Err("portfolio must be auto|full|<name>[,<name>...]".to_string()),
            "auto" => Ok(PortfolioSpec::Auto),
            "full" => Ok(PortfolioSpec::Full),
            list => {
                let names: Vec<String> =
                    list.split(',').map(|t| t.trim().to_string()).collect();
                for name in &names {
                    if contender_by_name(name).is_none() {
                        return Err(format!(
                            "unknown portfolio contender '{name}' (expected {})",
                            KNOWN_CONTENDERS.join("|")
                        ));
                    }
                }
                Ok(PortfolioSpec::List(names))
            }
        }
    }

    /// The canonical wire form (`parse(x.as_str()) == x`).
    pub fn as_str(&self) -> String {
        match self {
            PortfolioSpec::Auto => "auto".to_string(),
            PortfolioSpec::Full => "full".to_string(),
            PortfolioSpec::List(names) => names.join(","),
        }
    }
}

/// Every contender name [`PortfolioSpec::parse`] accepts, in the order
/// `full` races them.
pub const KNOWN_CONTENDERS: [&str; 12] = [
    "rsa",
    "rwa",
    "rwa-scan",
    "rwa-bitplane",
    "rwa-sharded",
    "neal",
    "tabu",
    "sb",
    "cim",
    "reaim",
    "statica",
    "checkerboard",
];

/// One roster slot: a named engine configuration or baseline factory.
#[derive(Clone, Copy)]
pub struct Contender {
    pub name: &'static str,
    pub kind: ContenderKind,
}

#[derive(Clone, Copy)]
pub enum ContenderKind {
    /// The Snowball engine itself, across its configuration axes.
    Snowball { mode: Mode, selector: SelectorKind, datapath: Datapath, shards: u32 },
    /// A Table II/III baseline (factory so the slot stays `Copy`).
    Baseline(fn() -> Box<dyn Solver>),
}

impl Contender {
    /// Thread lanes this contender occupies — what the coordinator's
    /// admission control charges for it.
    pub fn lanes(&self) -> usize {
        match self.kind {
            ContenderKind::Snowball { shards, .. } => shards.max(1) as usize,
            ContenderKind::Baseline(_) => 1,
        }
    }
}

/// Look a contender up by wire name.
pub fn contender_by_name(name: &str) -> Option<Contender> {
    let snow = |name, mode, selector, datapath, shards| Contender {
        name,
        kind: ContenderKind::Snowball { mode, selector, datapath, shards },
    };
    let base = |name, f: fn() -> Box<dyn Solver>| Contender { name, kind: ContenderKind::Baseline(f) };
    Some(match name {
        "rsa" => snow("rsa", Mode::RandomScan, SelectorKind::Fenwick, Datapath::Dense, 1),
        "rwa" => snow("rwa", Mode::RouletteWheel, SelectorKind::Fenwick, Datapath::Dense, 1),
        "rwa-scan" => {
            snow("rwa-scan", Mode::RouletteWheel, SelectorKind::LinearScan, Datapath::Dense, 1)
        }
        "rwa-bitplane" => {
            snow("rwa-bitplane", Mode::RouletteWheel, SelectorKind::Fenwick, Datapath::BitPlane, 1)
        }
        "rwa-sharded" => {
            snow("rwa-sharded", Mode::RouletteWheel, SelectorKind::Fenwick, Datapath::Dense, 4)
        }
        "neal" => base("neal", || Box::new(Neal::default())),
        "tabu" => base("tabu", || Box::new(Tabu::default())),
        "sb" => base("sb", || Box::new(SimulatedBifurcation::default())),
        "cim" => base("cim", || Box::new(Cim::default())),
        "reaim" => base("reaim", || Box::new(ReAim::asa())),
        "statica" => base("statica", || Box::new(Statica::default())),
        "checkerboard" => base("checkerboard", || Box::new(Checkerboard::default())),
        _ => return None,
    })
}

/// Resolve a [`PortfolioSpec`] into its concrete roster for `model`.
pub fn resolve_roster(spec: &PortfolioSpec, model: &IsingModel) -> Vec<Contender> {
    match spec {
        PortfolioSpec::Auto => profile::auto_roster(&profile::InstanceProfile::of(model)),
        PortfolioSpec::Full => {
            KNOWN_CONTENDERS.iter().filter_map(|n| contender_by_name(n)).collect()
        }
        PortfolioSpec::List(names) => {
            names.iter().filter_map(|n| contender_by_name(n)).collect()
        }
    }
}

/// Roster names in race order (index-aligned with the job's
/// `ReplicaResult`s — what `RESULT` prints per contender).
pub fn roster_names(spec: &PortfolioSpec, model: &IsingModel) -> Vec<String> {
    resolve_roster(spec, model).iter().map(|c| c.name.to_string()).collect()
}

/// Total thread lanes a portfolio job occupies — its admission weight.
pub fn roster_weight(spec: &PortfolioSpec, model: &IsingModel) -> usize {
    resolve_roster(spec, model).iter().map(|c| c.lanes()).sum::<usize>().max(1)
}

/// Race parameters shared by every contender.
#[derive(Clone, Debug)]
pub struct RaceConfig {
    /// Engine steps per Snowball contender; baselines get the
    /// equivalent sweep budget (`steps / N`).
    pub steps: u64,
    pub schedule: Schedule,
    /// Root seed; contender `i` runs under `child(i)`.
    pub seed: u64,
    /// First incumbent at or below this energy ends the race.
    pub target: Option<i64>,
    /// Pin shard lanes of sharded Snowball contenders.
    pub pin_lanes: bool,
    /// Materialize lane-local coupling-row copies in sharded Snowball
    /// contenders (first-touch NUMA placement, pair with `pin_lanes`).
    pub local_rows: bool,
}

/// One contender's final report.
#[derive(Clone, Debug)]
pub struct ContenderReport {
    pub name: String,
    pub best_energy: i64,
    pub best_spins: SpinVec,
    /// Single-spin attempts / engine steps actually executed.
    pub attempts: u64,
    pub wall: Duration,
    /// Why the contender was preempted (`None` = ran its full budget,
    /// or stopped on its own target hit before any token tripped).
    pub stopped: Option<StopCause>,
    /// The contender thread panicked; `best_energy` is `i64::MAX` and
    /// the race carried on without it.
    pub panicked: bool,
    /// Shard lanes successfully pinned (sharded contenders with
    /// `pin_lanes`; 0 otherwise).
    pub pinned_lanes: usize,
    /// Bytes of lane-local coupling rows materialized (sharded
    /// contenders with `local_rows`; 0 otherwise).
    pub local_row_bytes: usize,
}

/// The race outcome: per-contender reports (roster order), the winner,
/// and the deterministic incumbent trajectory.
#[derive(Clone, Debug)]
pub struct RaceOutcome {
    pub reports: Vec<ContenderReport>,
    /// Roster index of the winner: argmin over reported energies,
    /// lowest index on ties.
    pub winner: usize,
    /// Incumbent improvements folded over reports in roster order:
    /// `(contender_index, energy)` each time the incumbent improved.
    pub trajectory: Vec<(usize, i64)>,
    /// Every contender's stop token, post-race — exposed so the
    /// loser-cancellation test can assert they all tripped.
    pub tokens: Vec<Arc<StopToken>>,
}

impl RaceOutcome {
    pub fn winner_name(&self) -> &str {
        &self.reports[self.winner].name
    }
}

fn trip_all(tokens: &[Arc<StopToken>], cause: StopCause) {
    for t in tokens {
        t.trip(cause);
    }
}

/// Race `roster` on `model`. Blocks until every contender has returned
/// (losers stop within one stop-check stride of a target hit). The
/// job-level `job_stop` token is forwarded to every contender, so a
/// coordinator cancel/deadline preempts the whole race.
pub fn race(
    model: &IsingModel,
    roster: &[Contender],
    cfg: &RaceConfig,
    job_stop: Arc<StopToken>,
) -> RaceOutcome {
    let tokens: Vec<Arc<StopToken>> =
        (0..roster.len()).map(|_| Arc::new(StopToken::new())).collect();
    let root = StatelessRng::new(cfg.seed);
    let reports: Vec<ContenderReport> = std::thread::scope(|s| {
        let handles: Vec<_> = roster
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let token = tokens[i].clone();
                let all = &tokens;
                let seed = root.child(i as u64).seed();
                s.spawn(move || {
                    crate::failpoint::hit("portfolio.contender");
                    run_contender(model, c, cfg, seed, token, all)
                })
            })
            .collect();
        // Forward a job-level preemption to every contender; once it is
        // delivered (or everyone finished on their own) just join.
        loop {
            if let Some(cause) = job_stop.get() {
                trip_all(&tokens, cause);
                break;
            }
            if handles.iter().all(|h| h.is_finished()) {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        handles
            .into_iter()
            .enumerate()
            .map(|(i, h)| {
                h.join().unwrap_or_else(|_| ContenderReport {
                    name: roster[i].name.to_string(),
                    best_energy: i64::MAX,
                    best_spins: SpinVec::all_down(model.len()),
                    attempts: 0,
                    wall: Duration::ZERO,
                    stopped: tokens[i].get(),
                    panicked: true,
                    pinned_lanes: 0,
                    local_row_bytes: 0,
                })
            })
            .collect()
    });
    let winner = reports
        .iter()
        .enumerate()
        .min_by_key(|&(i, r)| (r.best_energy, i))
        .map(|(i, _)| i)
        .unwrap_or(0);
    let mut trajectory = Vec::new();
    let mut incumbent = i64::MAX;
    for (i, r) in reports.iter().enumerate() {
        if r.best_energy < incumbent {
            incumbent = r.best_energy;
            trajectory.push((i, incumbent));
        }
    }
    RaceOutcome { reports, winner, trajectory, tokens }
}

/// One contender's run. A target hit trips every token (the race's
/// finish line); the per-contender token also carries preemption from
/// the racer or a sibling.
fn run_contender(
    model: &IsingModel,
    c: &Contender,
    cfg: &RaceConfig,
    seed: u64,
    token: Arc<StopToken>,
    all: &[Arc<StopToken>],
) -> ContenderReport {
    let start = Instant::now();
    let (best_energy, best_spins, attempts, pinned_lanes, local_row_bytes) = match c.kind {
        ContenderKind::Baseline(factory) => {
            let solver = factory();
            let sweeps = (cfg.steps / model.len().max(1) as u64).max(1);
            let ctl = SolveCtl::new(token.clone(), cfg.target);
            let r = solver.solve_ctl(model, Budget::sweeps(sweeps), seed, &ctl);
            (r.best_energy, r.best_spins, r.attempts, 0, 0)
        }
        ContenderKind::Snowball { mode, selector, datapath, shards } => {
            let ecfg = EngineConfig {
                mode,
                datapath,
                selector,
                schedule: cfg.schedule.clone(),
                steps: cfg.steps,
                seed,
                planes: None,
                trace_stride: 0,
                shards,
                pin_lanes: cfg.pin_lanes,
                local_rows: cfg.local_rows,
            };
            if shards > 1 {
                let (r, stats) =
                    ShardedEngine::new(model, ecfg, MergeMode::Async).run_with_stop(&token);
                (r.best_energy, r.best_spins, r.steps, stats.pinned_lanes, stats.local_row_bytes)
            } else {
                let mut engine = SnowballEngine::new(model, ecfg);
                let stride = (cfg.steps / 64).clamp(64, 65_536);
                let r = engine.run_session(&token, None, stride, |ck| {
                    if matches!(cfg.target, Some(t) if ck.best_energy <= t) {
                        trip_all(all, StopCause::Cancel);
                    }
                });
                (r.best_energy, r.best_spins, r.steps, 0, 0)
            }
        }
    };
    // The finish line: an incumbent at or below target ends the race for
    // everyone (losers observe their token within one check stride).
    if matches!(cfg.target, Some(t) if best_energy <= t) {
        trip_all(all, StopCause::Cancel);
    }
    ContenderReport {
        name: c.name.to_string(),
        best_energy,
        best_spins,
        attempts,
        wall: start.elapsed(),
        stopped: token.get(),
        panicked: false,
        pinned_lanes,
        local_row_bytes,
    }
}

/// Run a portfolio [`JobSpec`] for the scheduler: resolve the roster,
/// race it, and fold the reports into index-aligned [`ReplicaResult`]s
/// (replica `i` = roster slot `i`). `Err` only when every contender
/// panicked — a partial fleet still produces a winner.
pub fn run_for_job(spec: &JobSpec, job_stop: &Arc<StopToken>) -> Result<Vec<ReplicaResult>, String> {
    let pspec = spec.portfolio.as_ref().ok_or("not a portfolio job")?;
    let roster = resolve_roster(pspec, &spec.model);
    if roster.is_empty() {
        return Err("portfolio roster resolved empty".to_string());
    }
    let cfg = RaceConfig {
        steps: spec.steps,
        schedule: spec.schedule.clone(),
        seed: spec.seed,
        target: spec.target_energy,
        pin_lanes: spec.pin_lanes,
        local_rows: spec.local_rows,
    };
    let out = race(&spec.model, &roster, &cfg, job_stop.clone());
    if out.reports.iter().all(|r| r.panicked) {
        return Err("every portfolio contender panicked".to_string());
    }
    Ok(out
        .reports
        .iter()
        .enumerate()
        .map(|(i, r)| ReplicaResult {
            replica: i as u32,
            best_energy: r.best_energy,
            flips: r.attempts,
            wall: r.wall,
            stopped: r.stopped.is_some(),
            pinned_lanes: r.pinned_lanes,
            local_row_bytes: r.local_row_bytes,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    fn problem() -> MaxCut {
        let rng = StatelessRng::new(11);
        MaxCut::new(generators::erdos_renyi(32, 120, &[-1, 1], &rng))
    }

    #[test]
    fn parse_round_trips() {
        for s in ["auto", "full", "rsa,neal,tabu"] {
            let p = PortfolioSpec::parse(s).unwrap();
            assert_eq!(p.as_str(), s);
        }
        assert!(PortfolioSpec::parse("").is_err());
        let err = PortfolioSpec::parse("bogus").unwrap_err();
        assert!(err.starts_with("unknown portfolio contender 'bogus'"), "{err}");
    }

    #[test]
    fn every_known_contender_resolves() {
        for name in KNOWN_CONTENDERS {
            assert!(contender_by_name(name).is_some(), "{name} must resolve");
        }
        let p = problem();
        assert_eq!(
            resolve_roster(&PortfolioSpec::Full, p.model()).len(),
            KNOWN_CONTENDERS.len()
        );
    }

    #[test]
    fn race_reports_are_consistent() {
        let p = problem();
        let m = p.model();
        let roster = resolve_roster(
            &PortfolioSpec::List(vec!["rsa".into(), "neal".into(), "tabu".into()]),
            m,
        );
        let cfg = RaceConfig {
            steps: 2_000,
            schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
            seed: 7,
            target: None,
            pin_lanes: false,
            local_rows: false,
        };
        let out = race(m, &roster, &cfg, Arc::new(StopToken::new()));
        assert_eq!(out.reports.len(), 3);
        for r in &out.reports {
            assert!(!r.panicked);
            assert_eq!(r.best_energy, m.energy(&r.best_spins), "{}", r.name);
        }
        // No target, no preemption: every contender ran to completion.
        assert!(out.reports.iter().all(|r| r.stopped.is_none()));
        assert_eq!(out.trajectory.last().unwrap().1, out.reports[out.winner].best_energy);
    }
}
