//! Coupling-precision sweep (paper challenge 3, §III-C): quantize an
//! instance to each bit-width, race the portfolio roster on the
//! quantized model, and score the winner's configuration on the
//! *original* model — quality-vs-bits — alongside the `hwsim` cycle
//! cost of a datapath with that many bit-planes. `benches/microbench.rs
//! --precision` turns the points into `BENCH_precision.json`.

use super::{race, resolve_roster, PortfolioSpec, RaceConfig};
use crate::engine::Schedule;
use crate::hwsim::{Geometry, HwModel};
use crate::ising::IsingModel;
use crate::problems::quantize;
use crate::stop::StopToken;
use std::sync::Arc;

/// One (instance, bit-width) measurement.
#[derive(Clone, Debug)]
pub struct PrecisionPoint {
    /// Magnitude bits the quantized couplings kept.
    pub bits: u32,
    /// Roster winner at this width.
    pub winner: String,
    /// Winner's best energy on the quantized model it actually solved.
    pub quantized_energy: i64,
    /// Winner's configuration re-scored on the full-precision model —
    /// the quality axis (how much the distorted landscape misleads).
    pub original_energy: i64,
    /// `hwsim` cycles for one Mode II step at this plane count.
    pub step_cycles: u64,
    /// `hwsim` end-to-end seconds for the full step budget.
    pub end_to_end_seconds: f64,
    /// What the quantized model actually occupies in memory: its
    /// precision-packed [`CouplingStore`](crate::ising::CouplingStore)
    /// footprint. Narrow widths land in the i8/i16 tiers, so this is
    /// the software-side memory axis next to the hwsim cycle axis.
    pub model_bytes: usize,
    /// The packed storage tier's label (`"i8"`/`"i16"`/`"i32"`).
    pub tier: &'static str,
}

/// Sweep `widths`, racing `spec`'s roster per width. Widths at or above
/// the instance's native precision race the unmodified coefficients
/// (shift 0), so the curve plateaus at full quality.
pub fn sweep(
    model: &IsingModel,
    spec: &PortfolioSpec,
    widths: &[u32],
    steps: u64,
    seed: u64,
) -> Vec<PrecisionPoint> {
    let native = quantize::required_bits(model);
    let hw = HwModel::default();
    widths
        .iter()
        .map(|&bits| {
            let shift = native.saturating_sub(bits.max(1));
            let quantized = quantize::arithmetic_shift(model, shift);
            let roster = resolve_roster(spec, &quantized);
            let cfg = RaceConfig {
                steps,
                schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
                seed,
                target: None,
                pin_lanes: false,
                local_rows: false,
            };
            let out = race(&quantized, &roster, &cfg, Arc::new(StopToken::new()));
            let win = &out.reports[out.winner];
            let g = Geometry { n: model.len(), planes: bits.max(1) };
            let report = hw.roulette_run(g, steps);
            PrecisionPoint {
                bits,
                winner: win.name.clone(),
                quantized_energy: win.best_energy,
                original_energy: model.energy(&win.best_spins),
                step_cycles: report.step_cycles / steps.max(1),
                end_to_end_seconds: report.end_to_end_seconds,
                model_bytes: quantized.approx_bytes(),
                tier: quantized.tier().label(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::StatelessRng;

    #[test]
    fn sweep_covers_widths_and_scores_on_original() {
        let rng = StatelessRng::new(21);
        // Wide coefficient range so low widths genuinely distort.
        let p = MaxCut::new(generators::erdos_renyi(24, 90, &[-100, -31, 7, 100], &rng));
        let spec = PortfolioSpec::List(vec!["rsa".into(), "tabu".into()]);
        let pts = sweep(p.model(), &spec, &[2, 8], 1_200, 5);
        assert_eq!(pts.len(), 2);
        for pt in &pts {
            assert!(!pt.winner.is_empty());
            assert!(pt.step_cycles > 0);
            assert!(pt.end_to_end_seconds > 0.0);
            // ±100 magnitudes pack as i8 at every width here, and the
            // footprint is the real packed store, not an i32 bound.
            assert_eq!(pt.tier, "i8");
            assert!(pt.model_bytes > 0);
            assert!(pt.model_bytes < IsingModel::approx_bytes_for(p.model().len()));
        }
        // More planes cost more per step in the bit-plane datapath.
        assert!(pts[1].step_cycles >= pts[0].step_cycles);
    }
}
