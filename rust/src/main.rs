//! `snowball` — CLI for the Snowball Ising machine reproduction.
//!
//! Subcommands:
//!   solve  --instance <id|er:n:m> [--mode rsa|rwa] [--steps N] [--replicas R]
//!          [--seed S] [--schedule kind:t0:t1[:stages]] [--target E]
//!          [--workers W] [--selector scan|fenwick] [--shards S] [--pin-lanes]
//!          [--local-rows] [--budget-ms MS] [--max-retries K]
//!          [--addr host:port [--model <hash>]]   (submit to a remote service)
//!   serve  [--addr host:port] [--workers W] [--dispatch-workers D]
//!          [--max-inflight-replicas N] [--reject-saturated]
//!          [--shutdown-grace-ms MS] [--registry-capacity-bytes B]
//!          [--max-model-bytes B]
//!   put    --addr host:port --instance <id|er:n:m>  (upload to the registry)
//!   bench  <table1|table2|table3|fig3|fig8|fig13|fig14|fig15> [options]
//!   gen    --instance <id> --out <path>       (write Gset-format file)
//!   info                                        (platform / artifact info)

use anyhow::Result;
use snowball::cli::Args;
use snowball::coordinator::{registry, service, Backend, Coordinator, JobSpec, Registry, Service};
use snowball::engine::{Mode, Schedule, SelectorKind};
use snowball::graph::gset::{self, GsetId};
use snowball::harness as hx;
use snowball::tts;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_str() {
        "solve" => cmd_solve(&args),
        "serve" => cmd_serve(&args),
        "put" => cmd_put(&args),
        "bench" => cmd_bench(&args),
        "gen" => cmd_gen(&args),
        "info" => cmd_info(),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (see `snowball help`)"),
    }
}

const HELP: &str = "\
snowball — all-to-all Ising machine with dual-mode MCMC (paper reproduction)

USAGE:
  snowball solve --instance <G6|G11|...|K2000|er:n:m> [--mode rsa|rwa]
                 [--steps N] [--replicas R] [--seed S]
                 [--schedule kind:t0:t1[:stages]] [--target E] [--workers W]
                 [--selector scan|fenwick] [--shards S] [--pin-lanes]
                 [--local-rows] [--budget-ms MS] [--max-retries K]
                 [--portfolio auto|full|<name>[,<name>...]]
                 [--file <path> [--format qubo|mc]]
                    (--shards: 1 = classic engine, >1 = async sharded
                     lanes per replica, 0 = auto by instance size;
                     --pin-lanes: pin lane threads to cores, Linux;
                     --local-rows: materialize NUMA-local per-lane
                     coupling rows (pair with --pin-lanes);
                     --budget-ms: wall-clock budget, 0 = none — on
                     expiry the job is preempted and the best-so-far
                     partial result is reported;
                     --max-retries: re-run panicked replicas from
                     their last checkpoint up to K times;
                     --portfolio: race a roster of solvers on the
                     instance, first to the target wins and losers
                     are stopped — prints the winner and the
                     per-contender stats;
                     --file: load a qbsolv QUBO (--format qubo) or
                     Gset-layout Max-Cut (--format mc) text file
                     instead of --instance)
                 [--addr host:port [--model <hash>]]
                    (--addr: submit over the wire to a running
                     `snowball serve` instead of solving in-process;
                     --model: reference a registry hash from
                     `snowball put` instead of --instance)
  snowball serve [--addr 127.0.0.1:7878] [--workers W]
                 [--dispatch-workers D] [--max-inflight-replicas N]
                 [--reject-saturated] [--shutdown-grace-ms MS]
                 [--registry-capacity-bytes B] [--max-model-bytes B]
                    (--dispatch-workers: >= 2 starts the routed
                     dispatch tier — D coordinator workers behind one
                     front-end sharing one model registry;
                     --shutdown-grace-ms: on shutdown, abort jobs
                     still running after MS instead of draining)
  snowball put   --addr host:port --instance <id|er:n:m> [--seed S]
                    (upload the instance to the service's
                     content-addressed registry; prints the hash to
                     pass to `solve --model`)
  snowball bench <table1|table2|table3|fig3|fig5|fig8|fig13|fig14|fig15> [--quick]
  snowball gen   --instance <id> --out <path>
  snowball info
";

fn cmd_solve(args: &Args) -> Result<()> {
    // `--addr` redirects the whole job to a running service over the
    // wire (optionally referencing a registry model via `--model`).
    if let Some(addr) = args.get("addr") {
        return cmd_solve_remote(args, addr);
    }
    // Declarative config file first (`--config run.toml`, `[job]`
    // section), then CLI overrides on top.
    let file_job = match args.get("config") {
        Some(path) => Some(snowball::config::Config::load(std::path::Path::new(path))?.job(1)?),
        None => None,
    };
    let fj = file_job.as_ref();
    let instance = args
        .get("instance")
        .map(str::to_string)
        .or_else(|| fj.map(|j| j.instance.clone()))
        .unwrap_or_else(|| "G11".into());
    let seed: u64 = args.get_parse_or("seed", fj.map(|j| j.seed).unwrap_or(1))?;
    // `--file` loads an on-disk instance instead of a named one:
    // qbsolv QUBO text (`--format qubo`, converted to Ising) or the
    // Gset/Biq-Mac Max-Cut layout (`--format mc`); the format defaults
    // from the extension (`.mc` → mc, anything else → qubo).
    let (label, model) = match args.get("file") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            let fmt = args
                .get("format")
                .map(str::to_string)
                .unwrap_or_else(|| if path.ends_with(".mc") { "mc".into() } else { "qubo".into() });
            let base = std::path::Path::new(path)
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or(path)
                .to_string();
            let model = match fmt.as_str() {
                "qubo" => {
                    snowball::problems::Qubo::parse(&text).map_err(|e| anyhow::anyhow!(e))?.model
                }
                "mc" => snowball::problems::qubo::parse_maxcut(&text)
                    .map_err(|e| anyhow::anyhow!(e))?
                    .model()
                    .clone(),
                other => anyhow::bail!("--format must be qubo|mc (got {other})"),
            };
            (format!("{fmt}:{base}"), model)
        }
        None => service::build_instance(&instance, seed)?,
    };
    let mode = match args.get("mode") {
        Some(m) => Mode::parse(m)?,
        None => fj.map(|j| j.mode).unwrap_or(Mode::RouletteWheel),
    };
    let selector = match args.get("selector") {
        Some(s) => SelectorKind::parse(s)?,
        None => fj.map(|j| j.selector).unwrap_or(SelectorKind::Fenwick),
    };
    let steps: u64 =
        args.get_parse_or("steps", fj.map(|j| j.steps).unwrap_or((model.len() as u64) * 200))?;
    let replicas: u32 = args.get_parse_or("replicas", fj.map(|j| j.replicas).unwrap_or(8))?;
    let schedule = match args.get("schedule") {
        Some(s) => Schedule::parse(s)?,
        None => fj
            .map(|j| j.schedule.clone())
            .unwrap_or(Schedule::Geometric { t0: 8.0, t1: 0.05 }),
    };
    let target: Option<i64> = match args.get("target") {
        Some(v) => Some(v.parse()?),
        None => fj.and_then(|j| j.target),
    };
    let workers: usize = args.get_parse_or("workers", 0usize)?;
    let shards: u32 = args.get_parse_or("shards", fj.map(|j| j.shards).unwrap_or(1))?;
    anyhow::ensure!(
        shards as usize <= snowball::engine::shard::MAX_SHARDS,
        "--shards must be <= {} (got {shards})",
        snowball::engine::shard::MAX_SHARDS
    );
    let pin_lanes = args.flag("pin-lanes") || fj.map(|j| j.pin_lanes).unwrap_or(false);
    let local_rows = args.flag("local-rows") || fj.map(|j| j.local_rows).unwrap_or(false);
    let budget_ms: u64 = args.get_parse_or("budget-ms", 0u64)?;
    let max_retries: u32 = args.get_parse_or("max-retries", 0u32)?;
    // Portfolio racing: CLI flag first, then the config file's
    // `[job] portfolio` — same layering as every other knob.
    let portfolio = args
        .get("portfolio")
        .map(str::to_string)
        .or_else(|| fj.and_then(|j| j.portfolio.clone()))
        .map(|v| snowball::portfolio::PortfolioSpec::parse(&v))
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?;

    let w_total: i64 = -model.j_matrix().iter().map(|&v| v as i64).sum::<i64>() / 2;
    let coord = Coordinator::start(workers);
    let id = coord.submit(JobSpec {
        model: Arc::new(model),
        label: label.clone(),
        mode,
        selector,
        schedule,
        steps,
        replicas,
        seed,
        target_energy: target,
        shards,
        pin_lanes,
        local_rows,
        budget_ms,
        max_retries,
        backend: Backend::Native,
        portfolio,
    });
    let r = coord.wait(id).ok_or_else(|| {
        // Surface the preserved failure detail (replica panic message)
        // instead of a generic error.
        match coord.state(id) {
            Some(snowball::coordinator::JobState::Failed(msg)) => {
                anyhow::anyhow!("job failed: {msg}")
            }
            _ => anyhow::anyhow!("job failed"),
        }
    })?;
    if !r.completed {
        // Preempted (deadline or signal): the result below is the
        // best-so-far partial, clearly labelled.
        let state = match coord.state(id) {
            Some(snowball::coordinator::JobState::TimedOut) => "timed_out",
            Some(snowball::coordinator::JobState::Cancelled) => "cancelled",
            _ => "preempted",
        };
        println!("state={state} (partial best-so-far result)");
    }
    let best = r.best_energy();
    println!("instance={label} mode={} steps={steps} replicas={replicas}", mode.name());
    println!("best_energy={best} (cut={})", (w_total - best) / 2);
    println!("mean_replica_ms={:.3}", r.mean_replica_seconds() * 1e3);
    if let Some(p) = &r.portfolio {
        println!("winner={}", p.winner);
        for (rep, name) in r.replicas.iter().zip(&p.contenders) {
            println!(
                "  {name:14} best={} attempts={} wall_ms={:.3}{}",
                rep.best_energy,
                rep.flips,
                rep.wall.as_secs_f64() * 1e3,
                if rep.stopped { " (stopped)" } else { "" },
            );
        }
    }
    if let Some(t) = target {
        let est = r.successes(t);
        println!(
            "target={t} p_a={:.3} tts99_ms={:.3}",
            est.p_a(),
            tts::tts99(r.mean_replica_seconds(), est) * 1e3
        );
    }
    coord.shutdown();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // Declarative config file first (`--config serve.toml`, `[serve]`
    // section), then CLI overrides on top — same layering as `solve`.
    let file = match args.get("config") {
        Some(path) => Some(snowball::config::Config::load(std::path::Path::new(path))?.serve()),
        None => None,
    };
    let fs = file.as_ref();
    let addr = args
        .get("addr")
        .map(str::to_string)
        .or_else(|| fs.map(|s| s.addr.clone()))
        .unwrap_or_else(|| "127.0.0.1:7878".into());
    let workers: usize = args.get_parse_or("workers", fs.map(|s| s.workers).unwrap_or(0))?;
    let dispatch_workers: usize =
        args.get_parse_or("dispatch-workers", fs.map(|s| s.dispatch_workers).unwrap_or(1))?;
    anyhow::ensure!(dispatch_workers >= 1, "--dispatch-workers must be >= 1");
    let max_inflight: usize = args
        .get_parse_or("max-inflight-replicas", fs.map(|s| s.max_inflight_replicas).unwrap_or(0))?;
    let shutdown_grace_ms: u64 =
        args.get_parse_or("shutdown-grace-ms", fs.map(|s| s.shutdown_grace_ms).unwrap_or(0))?;
    let reject = args.flag("reject-saturated") || fs.map(|s| s.reject_saturated).unwrap_or(false);
    let cap_bytes: usize = args.get_parse_or(
        "registry-capacity-bytes",
        fs.map(|s| s.registry_capacity_bytes).unwrap_or(registry::DEFAULT_CAPACITY_BYTES),
    )?;
    let max_model: usize = args.get_parse_or(
        "max-model-bytes",
        fs.map(|s| s.max_model_bytes).unwrap_or(registry::DEFAULT_MAX_MODEL_BYTES),
    )?;
    let store = Arc::new(Registry::new(cap_bytes, max_model));
    let cfg = snowball::coordinator::CoordinatorConfig {
        workers,
        max_inflight_replicas: max_inflight,
        reject_when_saturated: reject,
        shutdown_grace_ms,
        registry: Some(store.clone()),
        ..Default::default()
    };
    if max_inflight > 0 {
        println!("admission: max {max_inflight} inflight replicas");
    }
    if dispatch_workers >= 2 {
        let router = snowball::coordinator::Router::start_with(dispatch_workers, cfg);
        let svc = Service::bind(router, &addr)?;
        println!(
            "snowball service listening on {} ({dispatch_workers}-worker dispatch tier)",
            svc.addr()
        );
        svc.serve()
    } else {
        let coord = Coordinator::start_with(cfg);
        // The coordinator only auto-attaches metrics to a registry it
        // created itself; wire the shared one up (first-writer-wins).
        store.attach_metrics(coord.metrics.clone());
        let svc = Service::bind(coord, &addr)?;
        println!("snowball service listening on {}", svc.addr());
        svc.serve()
    }
}

/// Upload an instance to a running service's content-addressed
/// registry (`PUT` over the wire) and print the `STORED` hash.
fn cmd_put(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let name = args.get("instance").ok_or_else(|| anyhow::anyhow!("--instance required"))?;
    let seed: u64 = args.get_parse_or("seed", 1u64)?;
    let (label, model) = service::build_instance(name, seed)?;
    let mut body = format!("PUT n={}\n", model.len());
    for i in 0..model.len() {
        for (k, w) in model.j_row(i).iter().enumerate().skip(i + 1) {
            if w != 0 {
                body.push_str(&format!("{i} {k} {w}\n"));
            }
        }
    }
    for i in 0..model.len() {
        if model.h(i) != 0 {
            body.push_str(&format!("H {i} {}\n", model.h(i)));
        }
    }
    body.push_str("END\n");
    let mut stream = TcpStream::connect(&addr)?;
    stream.write_all(body.as_bytes())?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    let reply = reply.trim();
    anyhow::ensure!(reply.starts_with("STORED model="), "server replied: {reply}");
    println!("{label}: {reply}");
    Ok(())
}

/// Submit over the wire instead of in-process: `solve --addr host:port`
/// with either `--model <hash>` (a registry reference from `snowball
/// put`) or `--instance <id>` (built server-side from the SOLVE line).
fn cmd_solve_remote(args: &Args, addr: &str) -> Result<()> {
    let mut req = String::from("SOLVE");
    match (args.get("model"), args.get("instance")) {
        (Some(h), None) => req.push_str(&format!(" model={h}")),
        (None, Some(inst)) => req.push_str(&format!(" instance={inst}")),
        (Some(_), Some(_)) => anyhow::bail!("--model and --instance are mutually exclusive"),
        (None, None) => anyhow::bail!("--instance or --model required with --addr"),
    }
    for (flag, key) in [
        ("mode", "mode"),
        ("selector", "selector"),
        ("schedule", "schedule"),
        ("steps", "steps"),
        ("replicas", "replicas"),
        ("seed", "seed"),
        ("target", "target"),
        ("shards", "shards"),
        ("budget-ms", "budget_ms"),
        ("max-retries", "max_retries"),
        ("portfolio", "portfolio"),
    ] {
        if let Some(v) = args.get(flag) {
            req.push_str(&format!(" {key}={v}"));
        }
    }
    if args.flag("pin-lanes") {
        req.push_str(" pin_lanes=1");
    }
    if args.flag("local-rows") {
        req.push_str(" local_rows=1");
    }
    let mut stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    writeln!(stream, "{req}")?;
    reader.read_line(&mut line)?;
    let submitted = line.trim().to_string();
    anyhow::ensure!(submitted.starts_with("JOB id="), "server replied: {submitted}");
    let id: u64 = submitted.rsplit('=').next().unwrap_or_default().parse()?;
    writeln!(stream, "WAIT id={id}")?;
    line.clear();
    reader.read_line(&mut line)?;
    println!("{}", line.trim());
    match args.get("target") {
        Some(t) => writeln!(stream, "RESULT id={id} target={t}")?,
        None => writeln!(stream, "RESULT id={id}")?,
    }
    line.clear();
    reader.read_line(&mut line)?;
    println!("{}", line.trim());
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let name = args.get("instance").ok_or_else(|| anyhow::anyhow!("--instance required"))?;
    let out = args.get("out").ok_or_else(|| anyhow::anyhow!("--out required"))?;
    let seed: u64 = args.get_parse_or("seed", 42u64)?;
    let id = GsetId::ALL
        .iter()
        .find(|i| i.name().eq_ignore_ascii_case(name))
        .ok_or_else(|| anyhow::anyhow!("unknown instance {name}"))?;
    let g = gset::instance(*id, seed);
    let f = std::fs::File::create(out)?;
    gset::write(&g, std::io::BufWriter::new(f))?;
    println!("wrote {} ({} vertices, {} edges)", out, g.n, g.edge_count());
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("snowball {} — paper reproduction build", env!("CARGO_PKG_VERSION"));
    match snowball::runtime::ArtifactManifest::discover() {
        Ok(m) => {
            println!("artifacts: {} ({} entries)", m.dir.display(), m.specs.len());
            for s in &m.specs {
                println!("  {} kind={} n={}", s.name, s.kind, s.n);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    match snowball::runtime::Runtime::cpu() {
        Ok(rt) => println!("pjrt: platform={}", rt.platform()),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("table1");
    let quick = args.flag("quick");
    let seed: u64 = args.get_parse_or("seed", 42u64)?;
    match which {
        "table1" => {
            let rows: Vec<Vec<String>> = hx::table1(seed)
                .into_iter()
                .map(|r| {
                    vec![
                        r.name,
                        r.topology.to_string(),
                        r.v.to_string(),
                        r.e.to_string(),
                        r.e_pos.to_string(),
                        r.e_neg.to_string(),
                        format!("{:.1}%", r.density * 100.0),
                    ]
                })
                .collect();
            print!(
                "{}",
                hx::render_table(
                    "Table I: benchmark instances",
                    &["Instance", "Topology", "|V|", "|E|", "|E+|", "|E-|", "rho"],
                    &rows
                )
            );
        }
        "table2" => {
            let sweeps: u64 = args.get_parse_or("sweeps", if quick { 50 } else { 2000 })?;
            let instances =
                if quick { vec![GsetId::G11, GsetId::G6] } else { GsetId::TABLE2.to_vec() };
            let cells = hx::table2(&instances, sweeps, seed);
            print_table2(&cells);
        }
        "table3" => {
            let cfg = hx::TtsConfig {
                cut_threshold: args.get_parse_or("threshold", 33_000i64)?,
                runs: args.get_parse_or("runs", if quick { 5 } else { 20 })?,
                sweeps: args.get_parse_or("sweeps", if quick { 200 } else { 2000 })?,
                seed,
                // Serial trials by default: concurrent trials contend and
                // inflate the measured t_a (see TtsConfig::workers).
                workers: args.get_parse_or("workers", 1usize)?,
            };
            let (rows, best) = hx::table3(&cfg);
            print_table3(&rows, best, cfg.cut_threshold);
            println!("\nFig 13 speedups over measured Neal:");
            for (name, s) in hx::fig13(&rows) {
                println!("  {name:32} {s:>12.1}x");
            }
        }
        "fig3" => {
            for (t, pts) in hx::fig3(&[0.25, 1.0, 4.0, 1e6], 8) {
                println!("T = {t}");
                for (de, exact, approx) in pts {
                    println!("  dE={de:>3} exact={exact:.4} lut={approx:.4}");
                }
            }
        }
        "fig8" => {
            let (e0, e1, moved) = hx::fig8();
            println!(
                "original landscape : {}",
                hx::sparkline(&e0.iter().map(|&v| v as f64).collect::<Vec<_>>())
            );
            println!(
                "2-bit shifted      : {}",
                hx::sparkline(&e1.iter().map(|&v| v as f64).collect::<Vec<_>>())
            );
            println!("ground state moved : {moved}");
        }
        "fig14" => {
            let pts = hx::fig14_model(&[100, 1_000, 10_000, 100_000, 1_000_000]);
            let rows: Vec<Vec<String>> = pts
                .iter()
                .map(|p| {
                    vec![
                        p.steps.to_string(),
                        format!("{:.3}", p.kernel_ms),
                        format!("{:.3}", p.end_to_end_ms),
                        format!("{:.3}", p.naive_ms),
                    ]
                })
                .collect();
            print!(
                "{}",
                hx::render_table(
                    "Fig 14: runtime vs MC steps (K2000, cycle model, ms)",
                    &["steps", "kernel", "end-to-end", "naive"],
                    &rows
                )
            );
            let n = if quick { 256 } else { 512 };
            let steps = if quick { 200 } else { 1000 };
            let (inc, naive) = hx::fig14_measured(n, steps, seed);
            println!(
                "measured on CPU (N={n}, {steps} steps): incremental {:.1} ms, naive {:.1} ms ({:.1}x)",
                inc * 1e3,
                naive * 1e3,
                naive / inc
            );
        }
        "fig5" => {
            // §III-A: minor-embedding overhead of K_n on Chimera vs
            // Snowball's native all-to-all (zero overhead).
            println!("K_n on Chimera (triangle embedding) vs all-to-all:");
            println!("{:>6} {:>14} {:>11} {:>10}", "n", "physical", "max chain", "overhead");
            for n in [6usize, 8, 16, 32, 64, 128] {
                if let Some((n, phys, chain, ov)) = snowball::graph::chimera::overhead_row(n) {
                    println!("{n:>6} {phys:>14} {chain:>11} {ov:>10.1}x");
                }
            }
            println!("(Snowball all-to-all: physical == logical, chain == 1, overhead 1.0x)");
        }
        "fig15" => {
            let r = hx::fig15(seed);
            println!(
                "pixel-exact 16-bit accuracy: {:.2}% (paper: 99.5%)",
                r.pixel_accuracy * 100.0
            );
            println!("energy alignment ratio     : {:.3}", r.spin_alignment);
            let trace: Vec<f64> = r.energy_trace.iter().map(|&(_, e)| e as f64).collect();
            println!("anneal trace               : {}", hx::sparkline(&trace));
        }
        other => anyhow::bail!("unknown bench '{other}'"),
    }
    Ok(())
}

fn print_table2(cells: &[hx::QualityCell]) {
    let mut instances: Vec<String> = Vec::new();
    let mut solvers: Vec<String> = Vec::new();
    for c in cells {
        if !instances.contains(&c.instance) {
            instances.push(c.instance.clone());
        }
        if !solvers.contains(&c.solver) {
            solvers.push(c.solver.clone());
        }
    }
    let solver_names: Vec<String> = solvers.clone();
    let mut header: Vec<&str> = vec![""];
    for s in &solver_names {
        header.push(s);
    }
    let mut rows = Vec::new();
    for inst in &instances {
        let mut row = vec![inst.clone()];
        for s in &solvers {
            let cut = cells
                .iter()
                .find(|c| &c.instance == inst && &c.solver == s)
                .map(|c| c.cut.to_string())
                .unwrap_or_default();
            row.push(cut);
        }
        rows.push(row);
    }
    print!("{}", hx::render_table("Table II: cut values (higher is better)", &header, &rows));
    // Fig 12 companion: runtimes.
    let mut rows = Vec::new();
    for inst in &instances {
        let mut row = vec![inst.clone()];
        for s in &solvers {
            let secs = cells
                .iter()
                .find(|c| &c.instance == inst && &c.solver == s)
                .map(|c| hx::fmt_ms(c.seconds))
                .unwrap_or_default();
            row.push(secs);
        }
        rows.push(row);
    }
    print!("{}", hx::render_table("Fig 12: runtimes (ms)", &header, &rows));
}

fn print_table3(rows: &[tts::TtsRow], best_cut: i64, threshold: i64) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.machine.clone(),
                r.hardware.clone(),
                format!("{:.3}", r.t_a_ms),
                format!("{:.2}", r.p_a),
                if r.tts99_ms.is_finite() { format!("{:.3}", r.tts99_ms) } else { "inf".into() },
            ]
        })
        .collect();
    print!(
        "{}",
        hx::render_table(
            "Table III: TTS(0.99) on K2000",
            &["Machine", "Hardware", "t_a [ms]", "P_a", "TTS(0.99) [ms]"],
            &table
        )
    );
    println!("best cut observed: {best_cut} (threshold {threshold})");
    println!("\npaper-reported rows for context:");
    for r in hx::table3_quoted_rows() {
        println!("  {:24} t_a={:<10} P_a={:<5} TTS={}", r.machine, r.t_a_ms, r.p_a, r.tts99_ms);
    }
}
