//! Ising-model substrate: dense all-to-all instances and bit-packed spin
//! configurations (paper §II-B).

pub mod model;
pub mod spins;

pub use model::IsingModel;
pub use spins::SpinVec;
