//! Ising-model substrate: dense all-to-all instances and bit-packed spin
//! configurations (paper §II-B).

pub mod model;
pub mod partition;
pub mod spins;

pub use model::{Adjacency, IsingModel};
pub use partition::Partition;
pub use spins::SpinVec;
