//! Ising-model substrate: dense all-to-all instances, the
//! precision-packed coupling store behind them, and bit-packed spin
//! configurations (paper §II-B).

// `store` is the ising layer's audited-unsafe member (the AVX2
// widening row kernels behind `JRow::fold_delta`) and stays under the
// crate-level `deny`; every other submodule is re-escalated to
// `forbid`, which a file-local allow cannot override.
#[forbid(unsafe_code)]
pub mod model;
#[forbid(unsafe_code)]
pub mod partition;
#[forbid(unsafe_code)]
pub mod spins;
pub mod store;

pub use model::{Adjacency, IsingModel};
pub use partition::Partition;
pub use spins::SpinVec;
pub use store::{CouplingStore, JRow, Tier};
