//! Bit-packed spin configurations.
//!
//! The hardware encodes each spin `s_i ∈ {-1,+1}` as a bit
//! `x_i = (s_i + 1)/2 ∈ {0,1}` and packs spins into `W = ceil(N/64)` 64-bit
//! words (paper §IV-B). This module is the software mirror of that layout:
//! the bit-plane Hamming-weight datapath (`crate::bitplane`) operates
//! directly on these words with popcounts, exactly like the FPGA's
//! word-parallel accumulator.

use crate::rng::{salt, StatelessRng};

/// A configuration of `n` spins, bit-packed 64 per word.
///
/// Bit j of word w holds spin index `64*w + j`; `1` encodes `s = +1`.
/// Trailing bits past `n` are kept zero (class invariant) so popcount-based
/// reductions never see garbage lanes.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct SpinVec {
    n: usize,
    words: Vec<u64>,
}

impl SpinVec {
    /// All-down (-1) configuration.
    pub fn all_down(n: usize) -> Self {
        Self { n, words: vec![0; n.div_ceil(64)] }
    }

    /// All-up (+1) configuration.
    pub fn all_up(n: usize) -> Self {
        let mut v = Self::all_down(n);
        for i in 0..n {
            v.set(i, 1);
        }
        v
    }

    /// Uniformly random configuration from the stateless RNG
    /// (stage 0, salt `INIT`, one draw per word).
    pub fn random(n: usize, rng: &StatelessRng) -> Self {
        let mut v = Self::all_down(n);
        for w in 0..v.words.len() {
            v.words[w] = rng.u64(0, w as u64, salt::INIT);
        }
        v.mask_tail();
        v
    }

    /// Build from a slice of ±1 values.
    pub fn from_spins(spins: &[i8]) -> Self {
        let mut v = Self::all_down(spins.len());
        for (i, &s) in spins.iter().enumerate() {
            debug_assert!(s == 1 || s == -1);
            v.set(i, s);
        }
        v
    }

    /// Number of spins.
    #[inline(always)]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the configuration is empty.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The packed 64-bit words (`x` encoding).
    #[inline(always)]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Spin value at `i` as ±1.
    #[inline(always)]
    pub fn get(&self, i: usize) -> i8 {
        debug_assert!(i < self.n);
        if (self.words[i >> 6] >> (i & 63)) & 1 == 1 {
            1
        } else {
            -1
        }
    }

    /// Bit value at `i` (`x_i = (s_i+1)/2`).
    #[inline(always)]
    pub fn bit(&self, i: usize) -> u64 {
        (self.words[i >> 6] >> (i & 63)) & 1
    }

    /// Set spin `i` to ±1.
    #[inline(always)]
    pub fn set(&mut self, i: usize, s: i8) {
        debug_assert!(i < self.n && (s == 1 || s == -1));
        let w = i >> 6;
        let b = 1u64 << (i & 63);
        if s == 1 {
            self.words[w] |= b;
        } else {
            self.words[w] &= !b;
        }
    }

    /// Flip spin `i`, returning its OLD value (±1) — the quantity the
    /// incremental field update (Eq. 17) needs.
    #[inline(always)]
    pub fn flip(&mut self, i: usize) -> i8 {
        debug_assert!(i < self.n);
        let w = i >> 6;
        let b = 1u64 << (i & 63);
        let old = if self.words[w] & b != 0 { 1 } else { -1 };
        self.words[w] ^= b;
        old
    }

    /// Overwrite `self` with `src` (same length) without reallocating —
    /// the engines' best-configuration tracking hot path, which would
    /// otherwise clone a fresh `Vec` on every energy improvement.
    #[inline]
    pub fn assign_from(&mut self, src: &SpinVec) {
        assert_eq!(self.n, src.n, "assign_from requires equal lengths");
        self.words.copy_from_slice(&src.words);
    }

    /// Number of +1 spins.
    pub fn count_up(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Magnetization `Σ s_i = 2·count_up − n`.
    pub fn magnetization(&self) -> i64 {
        2 * self.count_up() as i64 - self.n as i64
    }

    /// Unpack to a ±1 vector.
    pub fn to_spins(&self) -> Vec<i8> {
        (0..self.n).map(|i| self.get(i)).collect()
    }

    /// Hamming distance to another configuration of the same length.
    pub fn hamming(&self, other: &Self) -> usize {
        assert_eq!(self.n, other.n);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a ^ b).count_ones() as usize)
            .sum()
    }

    fn mask_tail(&mut self) {
        let rem = self.n & 63;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_flip_roundtrip() {
        let mut v = SpinVec::all_down(130);
        assert_eq!(v.get(0), -1);
        v.set(0, 1);
        v.set(129, 1);
        assert_eq!(v.get(0), 1);
        assert_eq!(v.get(129), 1);
        assert_eq!(v.count_up(), 2);
        let old = v.flip(129);
        assert_eq!(old, 1);
        assert_eq!(v.get(129), -1);
        let old = v.flip(64);
        assert_eq!(old, -1);
        assert_eq!(v.get(64), 1);
    }

    #[test]
    fn random_tail_is_masked() {
        let rng = StatelessRng::new(3);
        let v = SpinVec::random(70, &rng);
        let last = *v.words().last().unwrap();
        assert_eq!(last >> 6, 0, "bits past n must be zero");
    }

    #[test]
    fn from_to_spins_roundtrip() {
        let spins: Vec<i8> = (0..97).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        let v = SpinVec::from_spins(&spins);
        assert_eq!(v.to_spins(), spins);
    }

    #[test]
    fn magnetization_matches() {
        let spins: Vec<i8> = vec![1, 1, -1, 1, -1];
        let v = SpinVec::from_spins(&spins);
        assert_eq!(v.magnetization(), 1);
        assert_eq!(SpinVec::all_up(5).magnetization(), 5);
        assert_eq!(SpinVec::all_down(5).magnetization(), -5);
    }

    #[test]
    fn assign_from_copies_without_realloc() {
        let rng = StatelessRng::new(5);
        let src = SpinVec::random(130, &rng);
        let mut dst = SpinVec::all_down(130);
        let words_ptr = dst.words.as_ptr();
        dst.assign_from(&src);
        assert_eq!(dst, src);
        assert_eq!(dst.words.as_ptr(), words_ptr, "must reuse the existing buffer");
    }

    #[test]
    #[should_panic(expected = "equal lengths")]
    fn assign_from_length_mismatch_panics() {
        let mut a = SpinVec::all_down(10);
        a.assign_from(&SpinVec::all_down(11));
    }

    #[test]
    fn hamming_distance() {
        let a = SpinVec::from_spins(&[1, -1, 1, -1]);
        let b = SpinVec::from_spins(&[1, 1, 1, 1]);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.hamming(&a), 0);
    }
}
