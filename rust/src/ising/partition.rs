//! Degree-balanced spin partitioning for the sharded engine
//! (`crate::engine::shard`).
//!
//! A [`Partition`] splits the spin indices `0..N` into `S` **contiguous**
//! ranges. Contiguity is load-bearing: concatenating the shards' local
//! lanes in shard order reproduces the global lane order, which is what
//! lets the sharded engine's deterministic virtual-time merge mode stay
//! bit-identical to the single-shard engine (a permuting partition would
//! reorder the roulette prefix sums and change which spin a given draw
//! selects). The same trick CSR SpMV row-splitting uses applies here:
//! balance is achieved by *where the cuts fall*, not by reordering —
//! boundaries are chosen so every shard carries an equal share of the
//! coupling-degree mass, so a hub-heavy prefix does not turn shard 0
//! into the straggler.

use super::model::IsingModel;

/// A contiguous, degree-balanced partition of `0..n` into shards.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// Shard boundaries, length `shards + 1`; shard `s` owns
    /// `bounds[s]..bounds[s + 1]`.
    bounds: Vec<usize>,
}

impl Partition {
    /// Split `0..n` into `shards` ranges of (near-)equal spin count,
    /// ignoring degrees. `shards` is clamped to `[1, max(n, 1)]`.
    pub fn uniform(n: usize, shards: usize) -> Self {
        let s = shards.clamp(1, n.max(1));
        let bounds = (0..=s).map(|k| k * n / s).collect();
        Self { bounds }
    }

    /// Split `0..n` into `shards` contiguous ranges carrying equal
    /// shares of the degree mass `w_i = deg(i) + 1` (the `+1` keeps
    /// isolated spins from collapsing a range to zero width). Boundary
    /// `s` is placed at the first index whose prefix mass reaches
    /// `s/S`-th of the total — the standard balanced prefix-sum split.
    pub fn by_degree(model: &IsingModel, shards: usize) -> Self {
        let n = model.len();
        let s = shards.clamp(1, n.max(1));
        if s == 1 || n == 0 {
            return Self { bounds: vec![0, n] };
        }
        // Degree mass prefix: Θ(N²) over the dense matrix, paid once at
        // engine construction (same order as the local-field init).
        let mut prefix = Vec::with_capacity(n + 1);
        prefix.push(0u64);
        let mut acc = 0u64;
        for i in 0..n {
            let deg = model.j_row(i).count_nonzero() as u64;
            acc += deg + 1;
            prefix.push(acc);
        }
        let total = acc;
        let mut bounds = Vec::with_capacity(s + 1);
        bounds.push(0usize);
        for k in 1..s {
            let target = total * k as u64 / s as u64;
            // First index with prefix >= target, but always advance at
            // least one spin past the previous boundary so no interior
            // shard is empty.
            let lo = bounds[k - 1] + 1;
            let hi = n - (s - k); // leave one spin for each later shard
            let mut cut = prefix.partition_point(|&p| p < target);
            cut = cut.clamp(lo, hi);
            bounds.push(cut);
        }
        bounds.push(n);
        Self { bounds }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total spins covered.
    pub fn len(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// True when the partition covers no spins.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The half-open index range shard `s` owns.
    #[inline(always)]
    pub fn range(&self, s: usize) -> std::ops::Range<usize> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// All shard ranges in shard order — what the sharded engine
    /// iterates to build one range-restricted lane kernel per shard.
    pub fn ranges(&self) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        (0..self.shards()).map(move |s| self.range(s))
    }

    /// Spins in shard `s`.
    #[inline(always)]
    pub fn shard_len(&self, s: usize) -> usize {
        self.bounds[s + 1] - self.bounds[s]
    }

    /// The shard owning spin `i` (binary search over the boundaries).
    #[inline]
    pub fn owner(&self, i: usize) -> usize {
        debug_assert!(i < self.len());
        self.bounds.partition_point(|&b| b <= i) - 1
    }

    /// Degree mass per shard (`Σ deg + 1` over the range) — the balance
    /// diagnostic the partition optimizes.
    pub fn loads(&self, model: &IsingModel) -> Vec<u64> {
        (0..self.shards())
            .map(|s| {
                self.range(s)
                    .map(|i| model.j_row(i).count_nonzero() as u64 + 1)
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::StatelessRng;

    #[test]
    fn uniform_tiles_exactly() {
        for (n, s) in [(10usize, 3usize), (64, 8), (7, 7), (5, 9), (1, 4)] {
            let p = Partition::uniform(n, s);
            assert_eq!(p.len(), n);
            let mut next = 0;
            for k in 0..p.shards() {
                assert_eq!(p.range(k).start, next);
                next = p.range(k).end;
                for i in p.range(k) {
                    assert_eq!(p.owner(i), k, "owner of {i}");
                }
            }
            assert_eq!(next, n);
            assert!(p.shards() <= n.max(1), "shards clamp to n");
        }
    }

    #[test]
    fn by_degree_balances_hub_heavy_prefix() {
        // Spins 0..16 form a dense clique, 16..256 are a sparse ring: a
        // uniform split would give shard 0 nearly all the degree mass.
        let rng = StatelessRng::new(3);
        let mut g = generators::erdos_renyi(256, 240, &[-1, 1], &rng);
        for a in 0..16u32 {
            for b in (a + 1)..16 {
                g.add_edge(a, b, 1);
            }
        }
        let p = MaxCut::new(g);
        let part = Partition::by_degree(p.model(), 4);
        assert_eq!(part.shards(), 4);
        assert_eq!(part.len(), 256);
        let loads = part.loads(p.model());
        let (min, max) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
        // Balanced within the largest single-spin mass (the clique hub).
        assert!(
            *max < 2 * *min + 40,
            "degree split unbalanced: {loads:?}"
        );
        // The uniform split on the same instance is measurably worse.
        let uni_loads = Partition::uniform(256, 4).loads(p.model());
        assert!(uni_loads[0] > loads[0], "uniform {uni_loads:?} vs degree {loads:?}");
    }

    #[test]
    fn by_degree_tiles_and_clamps() {
        let rng = StatelessRng::new(5);
        let g = generators::erdos_renyi(33, 100, &[-1, 1], &rng);
        let p = MaxCut::new(g);
        for s in [1usize, 2, 5, 33, 64] {
            let part = Partition::by_degree(p.model(), s);
            assert!(part.shards() >= 1 && part.shards() <= 33);
            let mut next = 0;
            for k in 0..part.shards() {
                let r = part.range(k);
                assert_eq!(r.start, next);
                assert!(r.end > r.start, "empty shard {k} of {s}");
                next = r.end;
            }
            assert_eq!(next, 33);
        }
    }

    #[test]
    fn ranges_iterates_all_shards_in_order() {
        let p = Partition::uniform(20, 3);
        let got: Vec<_> = p.ranges().collect();
        let want: Vec<_> = (0..p.shards()).map(|s| p.range(s)).collect();
        assert_eq!(got, want);
        assert_eq!(got.first().unwrap().start, 0);
        assert_eq!(got.last().unwrap().end, 20);
    }

    #[test]
    fn zero_spin_model() {
        let m = IsingModel::zeros(0);
        let p = Partition::by_degree(&m, 4);
        assert_eq!(p.shards(), 1);
        assert_eq!(p.len(), 0);
        assert!(p.is_empty());
    }
}
