//! Dense all-to-all Ising model (paper §II-B).
//!
//! `H(s) = -Σ_{i<j} J_ij s_i s_j - Σ_i h_i s_i`  (Eq. 1)
//!
//! Couplings and fields are integer-valued — Snowball is a *digital*
//! machine and all combinatorial-optimization encodings used in the
//! paper (Max-Cut, graph partitioning) produce integer coefficients.
//! The coupling matrix lives in a precision-packed [`CouplingStore`]:
//! the narrowest exact integer tier (`i8`/`i16`/`i32`) selected at
//! construction, so the bandwidth-bound row walks stream up to 4×
//! fewer bytes while every value stays exactly representable. Energies
//! and local fields are accumulated in `i64`, which cannot overflow
//! for any instance with `N · max|J| < 2^31` (K2000 uses `N = 2000`,
//! `|J| = 1`) — and because widening loads are exact, every engine
//! output is bit-identical across storage tiers.

use super::spins::SpinVec;
use super::store::{CouplingStore, JRow, Tier};

/// A dense, symmetric Ising instance over `n` spins.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IsingModel {
    n: usize,
    /// Row-major `n × n` coupling matrix, packed to the narrowest
    /// exact tier; symmetric, zero diagonal.
    j: CouplingStore,
    /// External fields, length `n`.
    h: Vec<i32>,
}

impl IsingModel {
    /// A model with all-zero couplings and fields.
    pub fn zeros(n: usize) -> Self {
        Self { n, j: CouplingStore::zeros(n), h: vec![0; n] }
    }

    /// Build from a dense row-major coupling matrix and field vector.
    ///
    /// The matrix is symmetrized (`(J + Jᵀ)/2` must be exact, i.e. equal
    /// off-diagonal pairs are required) and the diagonal must be zero.
    pub fn new(n: usize, j: Vec<i32>, h: Vec<i32>) -> Self {
        assert_eq!(j.len(), n * n, "J must be n×n");
        assert_eq!(h.len(), n, "h must have length n");
        for i in 0..n {
            assert_eq!(j[i * n + i], 0, "diagonal J[{i}][{i}] must be 0");
            for k in (i + 1)..n {
                assert_eq!(j[i * n + k], j[k * n + i], "J must be symmetric at ({i},{k})");
            }
        }
        Self { n, j: CouplingStore::from_dense(n, j), h }
    }

    /// Number of spins.
    #[inline(always)]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the model has no spins.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Coupling `J_ij`.
    #[inline(always)]
    pub fn j(&self, i: usize, k: usize) -> i32 {
        self.j.get(i, k)
    }

    /// Row `i` of the coupling matrix as a typed packed slice — match
    /// once on the tier, then walk a monomorphized loop (or call the
    /// widening [`JRow::iter`] on cold paths).
    #[inline(always)]
    pub fn j_row(&self, i: usize) -> JRow<'_> {
        self.j.row(i)
    }

    /// The full row-major coupling matrix, widened to the legacy dense
    /// `i32` layout (interop / verification; allocates Θ(n²)).
    pub fn j_matrix(&self) -> Vec<i32> {
        self.j.to_vec_i32()
    }

    /// The storage tier of the packed coupling store.
    pub fn tier(&self) -> Tier {
        self.j.tier()
    }

    /// Widen the coupling store to (at least) `tier` — values are
    /// preserved exactly, so every engine output is unchanged. For
    /// benches and parity tests that need an unpacked `i32` baseline
    /// of a naturally-narrow instance.
    pub fn force_tier(&mut self, tier: Tier) {
        self.j.force_tier(tier);
    }

    /// External field `h_i`.
    #[inline(always)]
    pub fn h(&self, i: usize) -> i32 {
        self.h[i]
    }

    /// The field vector.
    pub fn h_vec(&self) -> &[i32] {
        &self.h
    }

    /// Set a symmetric coupling pair `J_ij = J_ji = v` (i ≠ j). The
    /// store widens on demand if `v` exceeds the current tier.
    pub fn set_j(&mut self, i: usize, k: usize, v: i32) {
        assert_ne!(i, k, "diagonal couplings are not allowed");
        self.j.set(i, k, v);
        self.j.set(k, i, v);
    }

    /// Add to a symmetric coupling pair.
    pub fn add_j(&mut self, i: usize, k: usize, v: i32) {
        assert_ne!(i, k);
        let v = self.j.get(i, k) + v;
        self.j.set(i, k, v);
        self.j.set(k, i, v);
    }

    /// Set external field `h_i = v`.
    pub fn set_h(&mut self, i: usize, v: i32) {
        self.h[i] = v;
    }

    /// Largest absolute coefficient (used to size bit-planes; the
    /// coupling part also drives the store's tier selection).
    pub fn max_abs_coeff(&self) -> i32 {
        let jm = self.j.max_abs();
        let hm = self.h.iter().map(|v| v.abs()).max().unwrap_or(0);
        jm.max(hm)
    }

    /// Number of nonzero couplings (i < j).
    pub fn coupling_count(&self) -> usize {
        let mut c = 0;
        for i in 0..self.n {
            self.j.row(i).for_each_nonzero(|k, _| {
                if k > i {
                    c += 1;
                }
            });
        }
        c
    }

    /// Fraction of nonzero entries in the coupling matrix (directed
    /// count over n²) — a diagnostic; the engine's CSR gate counts
    /// inline with an early-exit cap (`Adjacency::build_if_sparse`).
    pub fn density(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let nnz: usize = (0..self.n).map(|i| self.j.row(i).count_nonzero()).sum();
        nnz as f64 / (self.n * self.n) as f64
    }

    /// Compressed-sparse-row view of the nonzero coupling rows — Θ(deg)
    /// row walks plus an explicit touched-field delta report, where the
    /// dense `j_row` walk is Θ(N). This is what makes the engine's
    /// incremental field/weight maintenance sublinear on sparse
    /// instances.
    pub fn adjacency(&self) -> Adjacency {
        Adjacency::build_with_cap(self, usize::MAX).expect("uncapped build cannot fail")
    }

    /// Full Hamiltonian `H(s)` (Eq. 1). Θ(N²) — use only for
    /// initialization and verification; the engines track energy
    /// incrementally.
    pub fn energy(&self, s: &SpinVec) -> i64 {
        debug_assert_eq!(s.len(), self.n);
        let mut pair = 0i64;
        for i in 0..self.n {
            let si = s.get(i) as i64;
            pair += si * self.j_row(i).dot_spins(s, i + 1);
        }
        let field: i64 = (0..self.n).map(|i| self.h[i] as i64 * s.get(i) as i64).sum();
        -pair - field
    }

    /// Local field `u_i = h_i + Σ_{j≠i} J_ij s_j` (defined below Eq. 2).
    pub fn local_field(&self, s: &SpinVec, i: usize) -> i64 {
        // J_ii == 0 so no need to exclude k == i.
        self.h[i] as i64 + self.j_row(i).dot_spins(s, 0)
    }

    /// All local fields, Θ(N²) from-scratch (the "initialization" path;
    /// the bit-plane datapath in `crate::bitplane` computes the same thing
    /// with Hamming-weight accumulation).
    pub fn local_fields(&self, s: &SpinVec) -> Vec<i64> {
        (0..self.n).map(|i| self.local_field(s, i)).collect()
    }

    /// Canonical 128-bit content digest of the model — the identity the
    /// coordinator's instance registry stores models under
    /// (`coordinator::registry`, wire verbs `PUT` / `SOLVE model=`).
    ///
    /// The digest is computed over the *constructed* model — `n`, every
    /// nonzero upper-triangle coupling `(i, k, J_ik)` in row-major
    /// order, and every nonzero field `(i, h_i)` — so two uploads that
    /// list the same couplings in different orders hash identically,
    /// while any perturbed coefficient changes the digest. Two
    /// independent splitmix-style lanes keep the collision surface at
    /// 128 bits without external dependencies.
    pub fn content_digest(&self) -> u128 {
        fn mix(h: u64, x: u64) -> u64 {
            let mut z = (h ^ x).wrapping_add(0x9e37_79b9_7f4a_7c15);
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        }
        let (mut a, mut b) = (
            mix(0x5357_4241_4c4c_0001, self.n as u64),
            mix(0x5357_4241_4c4c_0002, (self.n as u64).rotate_left(32)),
        );
        let mut absorb = |x: u64| {
            a = mix(a, x);
            b = mix(b, x.rotate_left(17));
        };
        // Values are widened to `i32` by the row view, so the digest is
        // invariant to the storage tier: the same matrix hashes
        // identically whether it sits packed at i8 or unpacked at i32
        // (pinned by `tests/properties.rs`).
        for i in 0..self.n {
            self.j_row(i).for_each_nonzero(|k, v| {
                if k > i {
                    absorb(((i as u64) << 32) | k as u64);
                    absorb(v as i64 as u64);
                }
            });
        }
        for (i, &h) in self.h.iter().enumerate() {
            if h != 0 {
                absorb((i as u64) | (1 << 63));
                absorb(h as i64 as u64);
            }
        }
        ((a as u128) << 64) | b as u128
    }

    /// Worst-case bytes a dense `n`-spin model can materialize: the
    /// `n × n` coupling matrix at the widest (`i32`) tier plus the
    /// field vector. This is the conservative bound `PUT` checks
    /// against `max_model_bytes` *before* parsing or allocating
    /// anything — the tier is unknown until the values are seen.
    pub fn approx_bytes_for(n: usize) -> usize {
        n * n * 4 + n * 4
    }

    /// Bytes *this* model actually materializes: the packed coupling
    /// store at its selected tier plus the `i32` field vector. At most
    /// [`Self::approx_bytes_for`]`(n)`; 4× less for i8-tier instances.
    /// This is what the registry charges against its capacity.
    pub fn approx_bytes(&self) -> usize {
        self.j.bytes() + self.n * 4
    }

    /// Flip energy change `ΔE_i = H(s^(i→-i)) − H(s) = 2 s_i u_i` (Eq. 2).
    #[inline(always)]
    pub fn delta_e(s_i: i8, u_i: i64) -> i64 {
        2 * s_i as i64 * u_i
    }

    /// Apply a single-spin flip to the energy: `H' = H + ΔE_i`.
    /// (Helper for engines that track energy incrementally.)
    #[inline(always)]
    pub fn energy_after_flip(energy: i64, s_i: i8, u_i: i64) -> i64 {
        energy + Self::delta_e(s_i, u_i)
    }
}

/// Compressed-sparse-row adjacency of an [`IsingModel`]'s nonzero
/// couplings. Row `i` lists `(j, J_ij)` for every nonzero `J_ij`, in
/// ascending `j` — the same visit order as the dense row walk, so field
/// updates through either path produce identical `i64` sums.
#[derive(Clone, Debug)]
pub struct Adjacency {
    /// Row start offsets, length `n + 1`.
    offsets: Vec<usize>,
    /// Column indices of nonzero entries.
    neighbors: Vec<u32>,
    /// The matching coupling values.
    weights: Vec<i32>,
}

impl Adjacency {
    /// Build only when the model is sparse enough that CSR walks win
    /// (directed density at or below `max_density`); dense instances
    /// return `None` and keep the cache-friendly dense row walk. Single
    /// pass over the matrix, aborting as soon as the nonzero count
    /// exceeds the cap — the dense case never pays a full scan twice.
    pub fn build_if_sparse(model: &IsingModel, max_density: f64) -> Option<Adjacency> {
        if model.is_empty() {
            return None;
        }
        let n = model.len();
        let max_nnz = (max_density * (n * n) as f64) as usize;
        Self::build_with_cap(model, max_nnz)
    }

    /// CSR construction with an nnz budget; `None` once the budget would
    /// be exceeded.
    fn build_with_cap(model: &IsingModel, max_nnz: usize) -> Option<Adjacency> {
        let n = model.len();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        let mut neighbors = Vec::new();
        let mut weights = Vec::new();
        for i in 0..n {
            for (k, v) in model.j_row(i).iter().enumerate() {
                if v != 0 {
                    if neighbors.len() == max_nnz {
                        return None;
                    }
                    neighbors.push(k as u32);
                    weights.push(v);
                }
            }
            offsets.push(neighbors.len());
        }
        Some(Adjacency { offsets, neighbors, weights })
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    /// True when there are no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total nonzero (directed) entries.
    pub fn nnz(&self) -> usize {
        self.neighbors.len()
    }

    /// Row `i` as parallel `(neighbors, weights)` slices.
    #[inline(always)]
    pub fn row(&self, i: usize) -> (&[u32], &[i32]) {
        let (a, b) = (self.offsets[i], self.offsets[i + 1]);
        (&self.neighbors[a..b], &self.weights[a..b])
    }

    /// Row `i` restricted to neighbours in `range` — the per-shard row
    /// view a range-restricted lane kernel folds remote flips through.
    /// Two binary searches over the (ascending) neighbour list, then the
    /// same parallel slices as [`Self::row`]: `Θ(log deg)` to locate,
    /// `Θ(deg ∩ range)` to walk, identical visit order.
    #[inline]
    pub fn row_range(&self, i: usize, range: std::ops::Range<usize>) -> (&[u32], &[i32]) {
        let (neigh, vals) = self.row(i);
        let from = neigh.partition_point(|&k| (k as usize) < range.start);
        let to = from + neigh[from..].partition_point(|&k| (k as usize) < range.end);
        (&neigh[from..to], &vals[from..to])
    }

    /// Degree of row `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// Largest row degree (what the incremental-step cycle model takes
    /// as the touched-lane count).
    pub fn max_degree(&self) -> usize {
        (0..self.len()).map(|i| self.degree(i)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StatelessRng;

    /// The worked K5 example from Fig. 2 has ground state energy −24 at
    /// s = (+1,+1,−1,+1,−1); we reconstruct a compatible instance and
    /// check the invariants that the paper states hold for any instance.
    fn small_model() -> IsingModel {
        let n = 4;
        let mut m = IsingModel::zeros(n);
        m.set_j(0, 1, 2);
        m.set_j(0, 2, -1);
        m.set_j(1, 3, 3);
        m.set_j(2, 3, 1);
        m.set_h(0, 1);
        m.set_h(3, -2);
        m
    }

    #[test]
    fn energy_by_hand() {
        let m = small_model();
        let s = SpinVec::from_spins(&[1, 1, -1, -1]);
        // pair: J01*1*1 + J02*1*(-1) + J13*1*(-1) + J23*(-1)(-1)
        //     = 2 - (-1)*... => 2*1 + (-1)*(-1) + 3*(-1) + 1*1 = 2+1-3+1 = 1
        // field: h0*1 + h3*(-1) = 1 + 2 = 3
        assert_eq!(m.energy(&s), -1 - 3);
    }

    #[test]
    fn delta_e_matches_energy_difference() {
        let m = small_model();
        let rng = StatelessRng::new(99);
        for trial in 0..20u64 {
            let mut s = SpinVec::random(m.len(), &rng.child(trial));
            for i in 0..m.len() {
                let e0 = m.energy(&s);
                let u = m.local_field(&s, i);
                let de = IsingModel::delta_e(s.get(i), u);
                s.flip(i);
                let e1 = m.energy(&s);
                assert_eq!(e1 - e0, de, "ΔE mismatch at spin {i}");
                s.flip(i); // restore
            }
        }
    }

    #[test]
    fn local_fields_match_definition() {
        let m = small_model();
        let s = SpinVec::from_spins(&[1, -1, 1, -1]);
        let u = m.local_fields(&s);
        // u_0 = h0 + J01*s1 + J02*s2 = 1 - 2 - 1 = -2
        assert_eq!(u[0], -2);
        // u_3 = h3 + J13*s1 + J23*s2 = -2 - 3 + 1 = -4
        assert_eq!(u[3], -4);
    }

    #[test]
    #[should_panic(expected = "symmetric")]
    fn asymmetric_rejected() {
        let mut j = vec![0i32; 4];
        j[1] = 1; // J01 = 1, J10 = 0
        IsingModel::new(2, j, vec![0, 0]);
    }

    #[test]
    fn coupling_count_and_max_abs() {
        let m = small_model();
        assert_eq!(m.coupling_count(), 4);
        assert_eq!(m.max_abs_coeff(), 3);
    }

    #[test]
    fn adjacency_matches_dense_rows() {
        let m = small_model();
        let adj = m.adjacency();
        assert_eq!(adj.len(), m.len());
        assert_eq!(adj.nnz(), 2 * m.coupling_count());
        for i in 0..m.len() {
            let (neigh, vals) = adj.row(i);
            let dense: Vec<(u32, i32)> = m
                .j_row(i)
                .iter()
                .enumerate()
                .filter(|&(_, v)| v != 0)
                .map(|(k, v)| (k as u32, v))
                .collect();
            let csr: Vec<(u32, i32)> = neigh.iter().copied().zip(vals.iter().copied()).collect();
            assert_eq!(csr, dense, "row {i}");
            assert_eq!(adj.degree(i), dense.len());
        }
        assert_eq!(adj.max_degree(), 2); // spins 1, 2 and 3 have degree 2
    }

    /// `row_range` must return exactly the row entries whose neighbour
    /// index falls in the range, for arbitrary (including empty and
    /// full) ranges — the filtered-row reference the shard lanes rely on.
    #[test]
    fn row_range_filters_exactly() {
        let rng = StatelessRng::new(31);
        let mut m = IsingModel::zeros(40);
        let mut idx = 0u64;
        for i in 0..40usize {
            for k in (i + 1)..40 {
                let v = rng.below(1, idx, crate::rng::salt::PROBLEM, 5) as i32 - 2;
                idx += 1;
                if v != 0 {
                    m.set_j(i, k, v);
                }
            }
        }
        let adj = m.adjacency();
        for i in [0usize, 7, 39] {
            let (neigh, vals) = adj.row(i);
            for (lo, hi) in [(0usize, 40usize), (0, 13), (13, 27), (27, 40), (5, 5), (38, 40)] {
                let (rn, rv) = adj.row_range(i, lo..hi);
                let want: Vec<(u32, i32)> = neigh
                    .iter()
                    .copied()
                    .zip(vals.iter().copied())
                    .filter(|&(k, _)| (k as usize) >= lo && (k as usize) < hi)
                    .collect();
                let got: Vec<(u32, i32)> =
                    rn.iter().copied().zip(rv.iter().copied()).collect();
                assert_eq!(got, want, "row {i}, range {lo}..{hi}");
            }
        }
    }

    /// The content digest is a pure function of the constructed model:
    /// insertion order is invisible, any coefficient perturbation is
    /// not, and the byte proxy matches the dense layout.
    #[test]
    fn content_digest_is_canonical() {
        let m = small_model();
        // Same couplings inserted in reverse order → same matrix →
        // same digest.
        let mut rev = IsingModel::zeros(4);
        rev.set_h(3, -2);
        rev.set_h(0, 1);
        rev.set_j(2, 3, 1);
        rev.set_j(1, 3, 3);
        rev.set_j(0, 2, -1);
        rev.set_j(0, 1, 2);
        assert_eq!(m.content_digest(), rev.content_digest());
        // Symmetric pair listed from the other side is the same model.
        let mut sym = m.clone();
        sym.set_j(1, 0, 2);
        assert_eq!(m.content_digest(), sym.content_digest());
        // Any perturbation — a coupling, a field, or the spin count —
        // moves the digest.
        let mut p = m.clone();
        p.set_j(0, 1, 3);
        assert_ne!(m.content_digest(), p.content_digest());
        let mut p = m.clone();
        p.set_h(1, 1);
        assert_ne!(m.content_digest(), p.content_digest());
        assert_ne!(IsingModel::zeros(4).content_digest(), IsingModel::zeros(5).content_digest());
        // max |J| = 3 → the store packs at i8: 1 byte per coupling
        // plus the i32 field vector; the static bound stays the
        // worst-case i32 layout.
        assert_eq!(m.tier(), crate::ising::Tier::I8);
        assert_eq!(m.approx_bytes(), 4 * 4 + 4 * 4);
        assert_eq!(IsingModel::approx_bytes_for(4), 4 * 4 * 4 + 4 * 4);
        assert!(m.approx_bytes() <= IsingModel::approx_bytes_for(4));
        // Widening the tier changes the footprint but nothing else —
        // not the digest, not the values, not equality.
        let mut wide = m.clone();
        wide.force_tier(crate::ising::Tier::I32);
        assert_eq!(wide.approx_bytes(), IsingModel::approx_bytes_for(4));
        assert_eq!(wide.content_digest(), m.content_digest());
        assert_eq!(wide, m);
    }

    #[test]
    fn density_and_sparse_gate() {
        let m = small_model(); // 8 directed nonzeros over 16 cells
        assert!((m.density() - 0.5).abs() < 1e-12);
        assert!(Adjacency::build_if_sparse(&m, 0.25).is_none());
        assert!(Adjacency::build_if_sparse(&m, 0.5).is_some());
        assert_eq!(IsingModel::zeros(0).density(), 0.0);
    }
}
