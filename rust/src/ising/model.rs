//! Dense all-to-all Ising model (paper §II-B).
//!
//! `H(s) = -Σ_{i<j} J_ij s_i s_j - Σ_i h_i s_i`  (Eq. 1)
//!
//! Couplings and fields are stored as `i32` integers — Snowball is a
//! *digital* machine and all combinatorial-optimization encodings used in
//! the paper (Max-Cut, graph partitioning) produce integer coefficients.
//! Energies and local fields are accumulated in `i64`, which cannot
//! overflow for any instance with `N · max|J| < 2^31` (K2000 uses
//! `N = 2000`, `|J| = 1`).

use super::spins::SpinVec;

/// A dense, symmetric Ising instance over `n` spins.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IsingModel {
    n: usize,
    /// Row-major `n × n` coupling matrix; symmetric, zero diagonal.
    j: Vec<i32>,
    /// External fields, length `n`.
    h: Vec<i32>,
}

impl IsingModel {
    /// A model with all-zero couplings and fields.
    pub fn zeros(n: usize) -> Self {
        Self { n, j: vec![0; n * n], h: vec![0; n] }
    }

    /// Build from a dense row-major coupling matrix and field vector.
    ///
    /// The matrix is symmetrized (`(J + Jᵀ)/2` must be exact, i.e. equal
    /// off-diagonal pairs are required) and the diagonal must be zero.
    pub fn new(n: usize, j: Vec<i32>, h: Vec<i32>) -> Self {
        assert_eq!(j.len(), n * n, "J must be n×n");
        assert_eq!(h.len(), n, "h must have length n");
        for i in 0..n {
            assert_eq!(j[i * n + i], 0, "diagonal J[{i}][{i}] must be 0");
            for k in (i + 1)..n {
                assert_eq!(j[i * n + k], j[k * n + i], "J must be symmetric at ({i},{k})");
            }
        }
        Self { n, j, h }
    }

    /// Number of spins.
    #[inline(always)]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the model has no spins.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Coupling `J_ij`.
    #[inline(always)]
    pub fn j(&self, i: usize, k: usize) -> i32 {
        self.j[i * self.n + k]
    }

    /// Row `i` of the coupling matrix.
    #[inline(always)]
    pub fn j_row(&self, i: usize) -> &[i32] {
        &self.j[i * self.n..(i + 1) * self.n]
    }

    /// The full row-major coupling matrix.
    pub fn j_matrix(&self) -> &[i32] {
        &self.j
    }

    /// External field `h_i`.
    #[inline(always)]
    pub fn h(&self, i: usize) -> i32 {
        self.h[i]
    }

    /// The field vector.
    pub fn h_vec(&self) -> &[i32] {
        &self.h
    }

    /// Set a symmetric coupling pair `J_ij = J_ji = v` (i ≠ j).
    pub fn set_j(&mut self, i: usize, k: usize, v: i32) {
        assert_ne!(i, k, "diagonal couplings are not allowed");
        self.j[i * self.n + k] = v;
        self.j[k * self.n + i] = v;
    }

    /// Add to a symmetric coupling pair.
    pub fn add_j(&mut self, i: usize, k: usize, v: i32) {
        assert_ne!(i, k);
        self.j[i * self.n + k] += v;
        self.j[k * self.n + i] += v;
    }

    /// Set external field `h_i = v`.
    pub fn set_h(&mut self, i: usize, v: i32) {
        self.h[i] = v;
    }

    /// Largest absolute coefficient (used to size bit-planes).
    pub fn max_abs_coeff(&self) -> i32 {
        let jm = self.j.iter().map(|v| v.abs()).max().unwrap_or(0);
        let hm = self.h.iter().map(|v| v.abs()).max().unwrap_or(0);
        jm.max(hm)
    }

    /// Number of nonzero couplings (i < j).
    pub fn coupling_count(&self) -> usize {
        let mut c = 0;
        for i in 0..self.n {
            for k in (i + 1)..self.n {
                if self.j[i * self.n + k] != 0 {
                    c += 1;
                }
            }
        }
        c
    }

    /// Full Hamiltonian `H(s)` (Eq. 1). Θ(N²) — use only for
    /// initialization and verification; the engines track energy
    /// incrementally.
    pub fn energy(&self, s: &SpinVec) -> i64 {
        debug_assert_eq!(s.len(), self.n);
        let mut pair = 0i64;
        for i in 0..self.n {
            let si = s.get(i) as i64;
            let row = self.j_row(i);
            let mut acc = 0i64;
            for k in (i + 1)..self.n {
                acc += row[k] as i64 * s.get(k) as i64;
            }
            pair += si * acc;
        }
        let field: i64 = (0..self.n).map(|i| self.h[i] as i64 * s.get(i) as i64).sum();
        -pair - field
    }

    /// Local field `u_i = h_i + Σ_{j≠i} J_ij s_j` (defined below Eq. 2).
    pub fn local_field(&self, s: &SpinVec, i: usize) -> i64 {
        let row = self.j_row(i);
        let mut acc = self.h[i] as i64;
        for k in 0..self.n {
            // J_ii == 0 so no need to exclude k == i.
            acc += row[k] as i64 * s.get(k) as i64;
        }
        acc
    }

    /// All local fields, Θ(N²) from-scratch (the "initialization" path;
    /// the bit-plane datapath in `crate::bitplane` computes the same thing
    /// with Hamming-weight accumulation).
    pub fn local_fields(&self, s: &SpinVec) -> Vec<i64> {
        (0..self.n).map(|i| self.local_field(s, i)).collect()
    }

    /// Flip energy change `ΔE_i = H(s^(i→-i)) − H(s) = 2 s_i u_i` (Eq. 2).
    #[inline(always)]
    pub fn delta_e(s_i: i8, u_i: i64) -> i64 {
        2 * s_i as i64 * u_i
    }

    /// Apply a single-spin flip to the energy: `H' = H + ΔE_i`.
    /// (Helper for engines that track energy incrementally.)
    #[inline(always)]
    pub fn energy_after_flip(energy: i64, s_i: i8, u_i: i64) -> i64 {
        energy + Self::delta_e(s_i, u_i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StatelessRng;

    /// The worked K5 example from Fig. 2 has ground state energy −24 at
    /// s = (+1,+1,−1,+1,−1); we reconstruct a compatible instance and
    /// check the invariants that the paper states hold for any instance.
    fn small_model() -> IsingModel {
        let n = 4;
        let mut m = IsingModel::zeros(n);
        m.set_j(0, 1, 2);
        m.set_j(0, 2, -1);
        m.set_j(1, 3, 3);
        m.set_j(2, 3, 1);
        m.set_h(0, 1);
        m.set_h(3, -2);
        m
    }

    #[test]
    fn energy_by_hand() {
        let m = small_model();
        let s = SpinVec::from_spins(&[1, 1, -1, -1]);
        // pair: J01*1*1 + J02*1*(-1) + J13*1*(-1) + J23*(-1)(-1)
        //     = 2 - (-1)*... => 2*1 + (-1)*(-1) + 3*(-1) + 1*1 = 2+1-3+1 = 1
        // field: h0*1 + h3*(-1) = 1 + 2 = 3
        assert_eq!(m.energy(&s), -1 - 3);
    }

    #[test]
    fn delta_e_matches_energy_difference() {
        let m = small_model();
        let rng = StatelessRng::new(99);
        for trial in 0..20u64 {
            let mut s = SpinVec::random(m.len(), &rng.child(trial));
            for i in 0..m.len() {
                let e0 = m.energy(&s);
                let u = m.local_field(&s, i);
                let de = IsingModel::delta_e(s.get(i), u);
                s.flip(i);
                let e1 = m.energy(&s);
                assert_eq!(e1 - e0, de, "ΔE mismatch at spin {i}");
                s.flip(i); // restore
            }
        }
    }

    #[test]
    fn local_fields_match_definition() {
        let m = small_model();
        let s = SpinVec::from_spins(&[1, -1, 1, -1]);
        let u = m.local_fields(&s);
        // u_0 = h0 + J01*s1 + J02*s2 = 1 - 2 - 1 = -2
        assert_eq!(u[0], -2);
        // u_3 = h3 + J13*s1 + J23*s2 = -2 - 3 + 1 = -4
        assert_eq!(u[3], -4);
    }

    #[test]
    #[should_panic(expected = "symmetric")]
    fn asymmetric_rejected() {
        let mut j = vec![0i32; 4];
        j[1] = 1; // J01 = 1, J10 = 0
        IsingModel::new(2, j, vec![0, 0]);
    }

    #[test]
    fn coupling_count_and_max_abs() {
        let m = small_model();
        assert_eq!(m.coupling_count(), 4);
        assert_eq!(m.max_abs_coeff(), 3);
    }
}
