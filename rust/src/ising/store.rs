//! Precision-packed coupling store: the `n × n` matrix behind
//! [`IsingModel`](super::IsingModel), held at the narrowest integer
//! tier (`i8` / `i16` / `i32`) that represents every coefficient
//! *exactly*.
//!
//! The engine hot path — the dense row walk that updates local fields
//! after a flip — is memory-bandwidth bound, and the paper's benchmark
//! encodings (Max-Cut ±1 weights, 4–8-bit quantized QUBOs) rarely need
//! more than a byte per coupling. Packing cuts bytes-per-step up to 4×
//! while keeping every arithmetic result bit-identical: values are
//! required to fit their tier (widening is exact, narrowing never
//! happens implicitly), rows widen to `i64` on load, and all
//! accumulation stays in `i64` exactly as the unpacked `Vec<i32>`
//! datapath did. Consumers read rows through [`JRow`] — a typed-slice
//! enum dispatched *once per row*, so per-element code is monomorphized
//! with no per-element branching.

// AUDITED UNSAFE ALLOWLIST MEMBER (see docs/ARCHITECTURE.md
// § Concurrency correctness): the only unsafe here is the AVX2
// widening row kernel behind [`JRow::fold_delta`] —
// `#[target_feature]` dispatch (feature presence verified at runtime
// before every call) and bounds-checked-by-construction SIMD
// loads/stores, the same pattern as `engine::lut::eval_lanes`. Every
// unsafe operation carries a `SAFETY:` comment (enforced by
// `cargo run -p xtask -- lint-safety`), and each tier's kernel is
// pinned bit-identical to the safe scalar path by
// `simd_fold_delta_matches_scalar`.
#![allow(unsafe_code)]

/// Storage width of a [`CouplingStore`]. Ordered narrow → wide so
/// `max`/comparisons pick the widest tier a value set needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    /// 1 byte per coupling, `|J| ≤ 127`.
    I8,
    /// 2 bytes per coupling, `|J| ≤ 32767`.
    I16,
    /// 4 bytes per coupling — the legacy unpacked width.
    I32,
}

impl Tier {
    /// Narrowest tier that represents `v` exactly.
    #[inline]
    pub fn for_value(v: i32) -> Tier {
        if i8::try_from(v).is_ok() {
            Tier::I8
        } else if i16::try_from(v).is_ok() {
            Tier::I16
        } else {
            Tier::I32
        }
    }

    /// Bytes one coupling occupies at this tier.
    #[inline]
    pub fn bytes_per_coupling(self) -> usize {
        match self {
            Tier::I8 => 1,
            Tier::I16 => 2,
            Tier::I32 => 4,
        }
    }

    /// Stable label for metrics gauges and bench JSON
    /// (`coupling_bytes_{i8,i16,i32}`).
    pub fn label(self) -> &'static str {
        match self {
            Tier::I8 => "i8",
            Tier::I16 => "i16",
            Tier::I32 => "i32",
        }
    }
}

/// The tier-specific backing storage. Row-major `n × n`, symmetric,
/// zero diagonal — the invariants [`IsingModel`](super::IsingModel)
/// enforces above this layer.
#[derive(Clone, Debug)]
enum Packed {
    I8(Vec<i8>),
    I16(Vec<i16>),
    I32(Vec<i32>),
}

/// A dense symmetric coupling matrix packed to the narrowest exact
/// integer tier. Tier selection happens at construction (and widens
/// on demand when a wider value is written); it never narrows, so a
/// row handed out as [`JRow`] stays valid for the borrow's lifetime.
#[derive(Clone, Debug)]
pub struct CouplingStore {
    n: usize,
    data: Packed,
}

impl CouplingStore {
    /// An all-zero `n × n` store at the narrowest tier.
    pub fn zeros(n: usize) -> Self {
        Self { n, data: Packed::I8(vec![0; n * n]) }
    }

    /// Pack a dense row-major `i32` matrix at the narrowest tier that
    /// holds every value exactly. The caller (the model constructor)
    /// has already validated shape and symmetry.
    pub fn from_dense(n: usize, j: Vec<i32>) -> Self {
        assert_eq!(j.len(), n * n, "J must be n×n");
        let tier = j.iter().map(|&v| Tier::for_value(v)).max().unwrap_or(Tier::I8);
        Self { n, data: pack(tier, j) }
    }

    /// Number of rows (= columns = spins).
    #[inline(always)]
    pub fn n(&self) -> usize {
        self.n
    }

    /// The storage tier currently in use.
    #[inline]
    pub fn tier(&self) -> Tier {
        match &self.data {
            Packed::I8(_) => Tier::I8,
            Packed::I16(_) => Tier::I16,
            Packed::I32(_) => Tier::I32,
        }
    }

    /// Bytes the coupling matrix occupies at its current tier.
    pub fn bytes(&self) -> usize {
        self.n * self.n * self.tier().bytes_per_coupling()
    }

    /// Linear-index read, widened to `i32`.
    #[inline(always)]
    fn at(&self, idx: usize) -> i32 {
        match &self.data {
            Packed::I8(v) => v[idx] as i32,
            Packed::I16(v) => v[idx] as i32,
            Packed::I32(v) => v[idx],
        }
    }

    /// `J[i][k]`, widened to `i32`.
    #[inline(always)]
    pub fn get(&self, i: usize, k: usize) -> i32 {
        self.at(i * self.n + k)
    }

    /// Write one cell, widening the whole store first if `v` does not
    /// fit the current tier. At most two widenings can ever happen over
    /// a store's lifetime (i8 → i16 → i32), so incremental model
    /// construction via `set_j`/`add_j` stays O(n²) total.
    pub fn set(&mut self, i: usize, k: usize, v: i32) {
        let need = Tier::for_value(v);
        if need > self.tier() {
            self.widen_to(need);
        }
        let idx = i * self.n + k;
        match &mut self.data {
            Packed::I8(d) => d[idx] = v as i8,
            Packed::I16(d) => d[idx] = v as i16,
            Packed::I32(d) => d[idx] = v,
        }
    }

    /// Force the store to (at least) `tier`, widening only — values are
    /// preserved exactly. Used by benches and parity tests to build an
    /// unpacked `i32` baseline of a naturally-narrow instance; it never
    /// changes any arithmetic result.
    pub fn force_tier(&mut self, tier: Tier) {
        assert!(tier >= self.tier(), "force_tier can only widen (store is {:?})", self.tier());
        self.widen_to(tier);
    }

    fn widen_to(&mut self, tier: Tier) {
        if tier <= self.tier() {
            return;
        }
        let wide = self.to_vec_i32();
        self.data = pack(tier, wide);
    }

    /// Row `i` as a typed slice.
    #[inline(always)]
    pub fn row(&self, i: usize) -> JRow<'_> {
        let (a, b) = (i * self.n, (i + 1) * self.n);
        match &self.data {
            Packed::I8(v) => JRow::I8(&v[a..b]),
            Packed::I16(v) => JRow::I16(&v[a..b]),
            Packed::I32(v) => JRow::I32(&v[a..b]),
        }
    }

    /// Largest absolute coupling (saturating at `i32::MAX`).
    pub fn max_abs(&self) -> i32 {
        let m = match &self.data {
            Packed::I8(v) => v.iter().map(|&x| (x as i32).unsigned_abs()).max().unwrap_or(0),
            Packed::I16(v) => v.iter().map(|&x| (x as i32).unsigned_abs()).max().unwrap_or(0),
            Packed::I32(v) => v.iter().map(|&x| x.unsigned_abs()).max().unwrap_or(0),
        };
        m.min(i32::MAX as u32) as i32
    }

    /// The full matrix widened back to the legacy dense `i32` layout
    /// (interop / verification; Θ(n²) allocation).
    pub fn to_vec_i32(&self) -> Vec<i32> {
        match &self.data {
            Packed::I8(v) => v.iter().map(|&x| x as i32).collect(),
            Packed::I16(v) => v.iter().map(|&x| x as i32).collect(),
            Packed::I32(v) => v.clone(),
        }
    }
}

fn pack(tier: Tier, j: Vec<i32>) -> Packed {
    // Every value has been checked to fit `tier`, so the `as` casts
    // below are exact (no truncation).
    match tier {
        Tier::I8 => Packed::I8(j.into_iter().map(|v| v as i8).collect()),
        Tier::I16 => Packed::I16(j.into_iter().map(|v| v as i16).collect()),
        Tier::I32 => Packed::I32(j),
    }
}

/// Value equality regardless of tier: a store that was widened by a
/// transient large write and then overwritten back can sit one tier
/// above a freshly-packed equal matrix, and the two must still compare
/// equal (the model's derived `PartialEq` depends on this).
impl PartialEq for CouplingStore {
    fn eq(&self, other: &Self) -> bool {
        if self.n != other.n {
            return false;
        }
        match (&self.data, &other.data) {
            (Packed::I8(a), Packed::I8(b)) => a == b,
            (Packed::I16(a), Packed::I16(b)) => a == b,
            (Packed::I32(a), Packed::I32(b)) => a == b,
            _ => (0..self.n * self.n).all(|idx| self.at(idx) == other.at(idx)),
        }
    }
}

impl Eq for CouplingStore {}

/// One coupling row as a typed slice: match once, then run a
/// monomorphized loop — no per-element branching, and the narrow tiers
/// stream 2–4× fewer bytes through the cache hierarchy than the
/// unpacked `i32` walk.
#[derive(Clone, Copy, Debug)]
pub enum JRow<'a> {
    /// 1-byte couplings.
    I8(&'a [i8]),
    /// 2-byte couplings.
    I16(&'a [i16]),
    /// 4-byte couplings (legacy width).
    I32(&'a [i32]),
}

impl<'a> JRow<'a> {
    /// Number of entries.
    #[inline(always)]
    pub fn len(&self) -> usize {
        match self {
            JRow::I8(r) => r.len(),
            JRow::I16(r) => r.len(),
            JRow::I32(r) => r.len(),
        }
    }

    /// True when the row has no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entry `k`, widened to `i32`.
    #[inline(always)]
    pub fn get(&self, k: usize) -> i32 {
        match self {
            JRow::I8(r) => r[k] as i32,
            JRow::I16(r) => r[k] as i32,
            JRow::I32(r) => r[k],
        }
    }

    /// Sub-slice `range` of the row (e.g. a shard lane's `lo..hi`
    /// column window).
    #[inline(always)]
    pub fn slice(self, range: std::ops::Range<usize>) -> JRow<'a> {
        match self {
            JRow::I8(r) => JRow::I8(&r[range]),
            JRow::I16(r) => JRow::I16(&r[range]),
            JRow::I32(r) => JRow::I32(&r[range]),
        }
    }

    /// Widening iterator over the row, yielding `i32` by value.
    /// Convenience for cold paths (construction, digesting, tests);
    /// the hot walks below are monomorphized per tier instead.
    pub fn iter(self) -> JRowIter<'a> {
        JRowIter { row: self, pos: 0 }
    }

    /// Call `f(k, J_ik)` for every nonzero entry, in ascending `k` —
    /// the visit order every datapath shares.
    #[inline]
    pub fn for_each_nonzero(self, f: impl FnMut(usize, i32)) {
        fn walk<T: Copy + Into<i32>>(r: &[T], mut f: impl FnMut(usize, i32)) {
            for (k, &v) in r.iter().enumerate() {
                let v: i32 = v.into();
                if v != 0 {
                    f(k, v);
                }
            }
        }
        match self {
            JRow::I8(r) => walk(r, f),
            JRow::I16(r) => walk(r, f),
            JRow::I32(r) => walk(r, f),
        }
    }

    /// Number of nonzero entries.
    pub fn count_nonzero(self) -> usize {
        fn count<T: Copy + Into<i32>>(r: &[T]) -> usize {
            r.iter().filter(|&&v| Into::<i32>::into(v) != 0).count()
        }
        match self {
            JRow::I8(r) => count(r),
            JRow::I16(r) => count(r),
            JRow::I32(r) => count(r),
        }
    }

    /// `Σ_{k ≥ from} J_ik · s_k` in `i64` — the energy / local-field
    /// inner product (`from = i+1` for the upper-triangle energy sum,
    /// `from = 0` for local fields; `J_ii = 0` makes self-exclusion
    /// unnecessary).
    #[inline]
    pub fn dot_spins(self, s: &crate::ising::spins::SpinVec, from: usize) -> i64 {
        fn dot<T: Copy + Into<i64>>(r: &[T], s: &crate::ising::spins::SpinVec, from: usize) -> i64 {
            let mut acc = 0i64;
            for (k, &v) in r.iter().enumerate().skip(from) {
                acc += Into::<i64>::into(v) * s.get(k) as i64;
            }
            acc
        }
        match self {
            JRow::I8(r) => dot(r, s, from),
            JRow::I16(r) => dot(r, s, from),
            JRow::I32(r) => dot(r, s, from),
        }
    }

    /// The dense field-delta walk: `u[k] -= factor · J[k]` over
    /// `min(u.len(), row.len())` entries — the hot kernel behind every
    /// lane's post-flip field update (`u_i ← u_i − 2 J_ij s_j_old`).
    ///
    /// With the `simd` cargo feature on x86-64 this runs through an
    /// AVX2 widening kernel (runtime-detected, 4 × i64 lanes per
    /// iteration); the scalar fallback is bit-identical. `factor` must
    /// fit `i32` for the SIMD path (it is always `±2` in the engines);
    /// wider factors fall back to scalar rather than truncate.
    #[inline]
    pub fn fold_delta(self, factor: i64, u: &mut [i64]) {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            if i32::try_from(factor).is_ok() && is_x86_feature_detected!("avx2") {
                // SAFETY: AVX2 presence verified at runtime; `factor`
                // fits i32 so the 32×32→64 multiply is exact.
                unsafe {
                    match self {
                        JRow::I8(r) => fold_delta_avx2_i8(r, factor, u),
                        JRow::I16(r) => fold_delta_avx2_i16(r, factor, u),
                        JRow::I32(r) => fold_delta_avx2_i32(r, factor, u),
                    }
                }
                return;
            }
        }
        self.fold_delta_scalar(factor, u)
    }

    fn fold_delta_scalar(self, factor: i64, u: &mut [i64]) {
        fn fold<T: Copy + Into<i64>>(r: &[T], factor: i64, u: &mut [i64]) {
            for (ui, &jv) in u.iter_mut().zip(r.iter()) {
                *ui -= factor * Into::<i64>::into(jv);
            }
        }
        match self {
            JRow::I8(r) => fold(r, factor, u),
            JRow::I16(r) => fold(r, factor, u),
            JRow::I32(r) => fold(r, factor, u),
        }
    }
}

/// Widening row iterator ([`JRow::iter`]), yielding `i32` by value.
pub struct JRowIter<'a> {
    row: JRow<'a>,
    pos: usize,
}

impl Iterator for JRowIter<'_> {
    type Item = i32;

    #[inline]
    fn next(&mut self) -> Option<i32> {
        if self.pos >= self.row.len() {
            return None;
        }
        let v = self.row.get(self.pos);
        self.pos += 1;
        Some(v)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.row.len() - self.pos;
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for JRowIter<'_> {}

/// AVX2 widening kernel for the `i8` tier: load 4 bytes, sign-extend
/// to 4 × i64, multiply by `factor` (32×32→64, exact because both
/// operands fit `i32`), subtract from the `u` quad in place. Tail
/// entries run the scalar loop. Bit-identical to
/// [`JRow::fold_delta_scalar`] — same widening, same `i64` arithmetic,
/// same visit order.
///
/// # Safety
///
/// The caller must verify the CPU supports AVX2 (e.g. via
/// `is_x86_feature_detected!("avx2")`) before calling, and must pass a
/// `factor` that fits `i32` (the multiply reads only the low 32 bits
/// of each 64-bit lane).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn fold_delta_avx2_i8(row: &[i8], factor: i64, u: &mut [i64]) {
    use std::arch::x86_64::*;
    let n = u.len().min(row.len());
    let mut k = 0usize;
    // SAFETY: the fn-level contract guarantees AVX2 is present, so
    // every intrinsic is executable. The 4-byte row read is a safe
    // slice index; the unaligned load/store on `u[k..k + 4]` are in
    // bounds because the loop condition holds `k + 4 <= n <= u.len()`.
    unsafe {
        let f = _mm256_set1_epi64x(factor);
        while k + 4 <= n {
            let b = &row[k..k + 4];
            let bits = i32::from_le_bytes([b[0] as u8, b[1] as u8, b[2] as u8, b[3] as u8]);
            let jv = _mm256_cvtepi8_epi64(_mm_cvtsi32_si128(bits));
            let prod = _mm256_mul_epi32(jv, f);
            let uv = _mm256_loadu_si256(u.as_ptr().add(k) as *const __m256i);
            _mm256_storeu_si256(u.as_mut_ptr().add(k) as *mut __m256i, _mm256_sub_epi64(uv, prod));
            k += 4;
        }
    }
    while k < n {
        u[k] -= factor * row[k] as i64;
        k += 1;
    }
}

/// AVX2 widening kernel for the `i16` tier — see [`fold_delta_avx2_i8`].
///
/// # Safety
///
/// Same contract as [`fold_delta_avx2_i8`]: AVX2 verified at runtime,
/// `factor` fits `i32`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn fold_delta_avx2_i16(row: &[i16], factor: i64, u: &mut [i64]) {
    use std::arch::x86_64::*;
    let n = u.len().min(row.len());
    let mut k = 0usize;
    // SAFETY: AVX2 presence per the fn contract. The 8-byte unaligned
    // load reads `row[k..k + 4]` (4 × i16), in bounds because
    // `k + 4 <= n <= row.len()`; the `u` load/store quad is in bounds
    // for the same reason.
    unsafe {
        let f = _mm256_set1_epi64x(factor);
        while k + 4 <= n {
            let jv =
                _mm256_cvtepi16_epi64(_mm_loadl_epi64(row.as_ptr().add(k) as *const __m128i));
            let prod = _mm256_mul_epi32(jv, f);
            let uv = _mm256_loadu_si256(u.as_ptr().add(k) as *const __m256i);
            _mm256_storeu_si256(u.as_mut_ptr().add(k) as *mut __m256i, _mm256_sub_epi64(uv, prod));
            k += 4;
        }
    }
    while k < n {
        u[k] -= factor * row[k] as i64;
        k += 1;
    }
}

/// AVX2 widening kernel for the `i32` tier — see [`fold_delta_avx2_i8`].
///
/// # Safety
///
/// Same contract as [`fold_delta_avx2_i8`]: AVX2 verified at runtime,
/// `factor` fits `i32`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn fold_delta_avx2_i32(row: &[i32], factor: i64, u: &mut [i64]) {
    use std::arch::x86_64::*;
    let n = u.len().min(row.len());
    let mut k = 0usize;
    // SAFETY: AVX2 presence per the fn contract. The 16-byte unaligned
    // load reads `row[k..k + 4]` (4 × i32), in bounds because
    // `k + 4 <= n <= row.len()`; the `u` load/store quad is in bounds
    // for the same reason.
    unsafe {
        let f = _mm256_set1_epi64x(factor);
        while k + 4 <= n {
            let jv =
                _mm256_cvtepi32_epi64(_mm_loadu_si128(row.as_ptr().add(k) as *const __m128i));
            let prod = _mm256_mul_epi32(jv, f);
            let uv = _mm256_loadu_si256(u.as_ptr().add(k) as *const __m256i);
            _mm256_storeu_si256(u.as_mut_ptr().add(k) as *mut __m256i, _mm256_sub_epi64(uv, prod));
            k += 4;
        }
    }
    while k < n {
        u[k] -= factor * row[k] as i64;
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{salt, StatelessRng};

    fn reference(n: usize, seed: u64, span: i32) -> Vec<i32> {
        let rng = StatelessRng::new(seed);
        let mut j = vec![0i32; n * n];
        let mut idx = 0u64;
        for i in 0..n {
            for k in (i + 1)..n {
                let v = rng.below(1, idx, salt::PROBLEM, (2 * span + 1) as u64) as i32 - span;
                idx += 1;
                j[i * n + k] = v;
                j[k * n + i] = v;
            }
        }
        j
    }

    #[test]
    fn tier_selection_is_tight() {
        assert_eq!(Tier::for_value(0), Tier::I8);
        assert_eq!(Tier::for_value(127), Tier::I8);
        assert_eq!(Tier::for_value(-128), Tier::I8);
        assert_eq!(Tier::for_value(128), Tier::I16);
        assert_eq!(Tier::for_value(-129), Tier::I16);
        assert_eq!(Tier::for_value(32_767), Tier::I16);
        assert_eq!(Tier::for_value(-32_768), Tier::I16);
        assert_eq!(Tier::for_value(32_768), Tier::I32);
        assert_eq!(Tier::for_value(i32::MIN), Tier::I32);
        for (span, tier, bpc) in
            [(3, Tier::I8, 1usize), (1_000, Tier::I16, 2), (100_000, Tier::I32, 4)]
        {
            let j = reference(12, 5, span);
            let s = CouplingStore::from_dense(12, j.clone());
            assert_eq!(s.tier(), tier, "span {span}");
            assert_eq!(s.bytes(), 12 * 12 * bpc);
            assert_eq!(s.to_vec_i32(), j, "span {span} round-trips exactly");
        }
    }

    #[test]
    fn set_widens_on_demand_and_preserves_values() {
        let mut s = CouplingStore::zeros(4);
        assert_eq!(s.tier(), Tier::I8);
        s.set(0, 1, 100);
        assert_eq!(s.tier(), Tier::I8);
        s.set(1, 2, 1_000);
        assert_eq!(s.tier(), Tier::I16);
        assert_eq!(s.get(0, 1), 100, "widening preserves existing values");
        s.set(2, 3, 1 << 20);
        assert_eq!(s.tier(), Tier::I32);
        assert_eq!((s.get(0, 1), s.get(1, 2), s.get(2, 3)), (100, 1_000, 1 << 20));
        // Overwriting with a small value never narrows…
        s.set(2, 3, 1);
        assert_eq!(s.tier(), Tier::I32);
        // …and tier-mismatched equal stores still compare equal.
        let mut t = CouplingStore::zeros(4);
        t.set(0, 1, 100);
        t.set(1, 2, 1_000);
        t.set(2, 3, 1);
        assert_eq!(s, t);
        assert_ne!(s.tier(), t.tier());
    }

    #[test]
    fn force_tier_widens_exactly_and_rejects_narrowing() {
        let j = reference(10, 9, 2);
        let mut s = CouplingStore::from_dense(10, j.clone());
        assert_eq!(s.tier(), Tier::I8);
        s.force_tier(Tier::I32);
        assert_eq!(s.tier(), Tier::I32);
        assert_eq!(s.to_vec_i32(), j);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.force_tier(Tier::I8);
        }));
        assert!(r.is_err(), "narrowing must panic");
    }

    #[test]
    fn row_views_match_reference_across_tiers() {
        use crate::ising::SpinVec;
        let n = 23;
        for (seed, span) in [(1u64, 2i32), (2, 900), (3, 70_000)] {
            let j = reference(n, seed, span);
            let s = CouplingStore::from_dense(n, j.clone());
            let spins = SpinVec::random(n, &StatelessRng::new(seed ^ 0xabc));
            for i in 0..n {
                let row = s.row(i);
                assert_eq!(row.len(), n);
                let want = &j[i * n..(i + 1) * n];
                let got: Vec<i32> = row.iter().collect();
                assert_eq!(got, want, "iter, row {i}");
                for k in 0..n {
                    assert_eq!(row.get(k), want[k]);
                }
                let sl: Vec<i32> = row.slice(5..17).iter().collect();
                assert_eq!(sl, &want[5..17], "slice, row {i}");
                assert_eq!(
                    row.count_nonzero(),
                    want.iter().filter(|&&v| v != 0).count(),
                    "count_nonzero, row {i}"
                );
                let mut nz = Vec::new();
                row.for_each_nonzero(|k, v| nz.push((k, v)));
                let want_nz: Vec<(usize, i32)> = want
                    .iter()
                    .enumerate()
                    .filter(|&(_, &v)| v != 0)
                    .map(|(k, &v)| (k, v))
                    .collect();
                assert_eq!(nz, want_nz, "for_each_nonzero, row {i}");
                for from in [0usize, i + 1, n] {
                    let want_dot: i64 = (from..n)
                        .map(|k| want[k] as i64 * spins.get(k) as i64)
                        .sum();
                    assert_eq!(row.dot_spins(&spins, from), want_dot, "dot, row {i} from {from}");
                }
            }
        }
    }

    #[test]
    fn fold_delta_matches_naive_loop_across_tiers() {
        let n = 37;
        for (seed, span) in [(11u64, 3i32), (12, 500), (13, 40_000)] {
            let j = reference(n, seed, span);
            let s = CouplingStore::from_dense(n, j.clone());
            for factor in [-2i64, 2, 0, 6] {
                for (lo, hi) in [(0usize, n), (0, 13), (13, n), (5, 9)] {
                    let base: Vec<i64> =
                        (0..hi - lo).map(|k| (k as i64 - 7) * 1_000_003).collect();
                    let mut got = base.clone();
                    s.row(3).slice(lo..hi).fold_delta(factor, &mut got);
                    let mut want = base;
                    for (off, w) in want.iter_mut().enumerate() {
                        *w -= factor * j[3 * n + lo + off] as i64;
                    }
                    assert_eq!(got, want, "seed {seed}, factor {factor}, {lo}..{hi}");
                }
            }
        }
    }

    /// With the `simd` feature on, every tier's AVX2 kernel (when the
    /// CPU has it) must agree with the scalar kernel bit for bit —
    /// including extreme values (`i8::MIN`, `i16::MIN`) and
    /// non-multiple-of-4 lengths.
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[test]
    fn simd_fold_delta_matches_scalar() {
        if !is_x86_feature_detected!("avx2") {
            return;
        }
        let rng = StatelessRng::new(21);
        for n in [1usize, 3, 4, 7, 64, 129] {
            for (tag, span) in [(0u64, 127i32), (1, 32_767), (2, 1 << 30)] {
                let vals: Vec<i32> = (0..n)
                    .map(|k| {
                        let r =
                            rng.below(tag, k as u64, salt::PROBLEM, (2 * span as u64) + 1) as i64;
                        (r - span as i64) as i32
                    })
                    .collect();
                // Include the exact type minimum, which |x| handling
                // gets wrong more often than any other value.
                let mut vals = vals;
                if n > 1 {
                    vals[0] = -span - 1;
                }
                let store = {
                    let mut flat = vec![0i32; n * n];
                    flat[..n].copy_from_slice(&vals);
                    CouplingStore::from_dense(n, flat)
                };
                for factor in [-2i64, 2, 1 - (1i64 << 31)] {
                    let base: Vec<i64> = (0..n).map(|k| k as i64 * 17 - 40).collect();
                    let mut scalar = base.clone();
                    store.row(0).fold_delta_scalar(factor, &mut scalar);
                    let mut simd = base;
                    // `fold_delta` dispatches to AVX2 under the guard
                    // above (factor always fits i32 here).
                    store.row(0).fold_delta(factor, &mut simd);
                    assert_eq!(scalar, simd, "n={n}, span={span}, factor={factor}");
                }
            }
        }
    }
}
