//! Minimal property-testing helper (offline stand-in for `proptest`).
//!
//! `Cases` drives a closure over many pseudo-random inputs derived from
//! the stateless RNG; on failure it reports the failing case index and
//! seed so the case can be replayed deterministically. A lightweight
//! "shrink" pass retries the failing case with smaller size hints.

use crate::rng::StatelessRng;

/// A deterministic case generator for property tests.
pub struct Cases {
    rng: StatelessRng,
    cases: u64,
}

impl Cases {
    /// `cases` random cases keyed by `seed`.
    pub fn new(seed: u64, cases: u64) -> Self {
        Self { rng: StatelessRng::new(seed), cases }
    }

    /// Run `prop` for each case. `prop` receives a per-case RNG and a
    /// size hint that grows with the case index (small cases first, so
    /// failures reproduce minimally by construction).
    ///
    /// Panics with the case index and seed on the first failure.
    pub fn run<F>(&self, mut prop: F)
    where
        F: FnMut(&StatelessRng, usize) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let rng = self.rng.child(case);
            // Sizes ramp 2..=66 over the case budget.
            let size = 2 + (case * 64 / self.cases.max(1)) as usize;
            if let Err(msg) = prop(&rng, size) {
                panic!(
                    "property failed at case {case} (seed {}, size {size}): {msg}",
                    self.rng.seed()
                );
            }
        }
    }
}

/// Random helpers shared by property tests.
pub mod gen {
    use crate::ising::{IsingModel, SpinVec};
    use crate::rng::{salt, StatelessRng};

    /// A random symmetric model with |J|, |h| ≤ `max_abs` on `n` spins.
    pub fn model(rng: &StatelessRng, n: usize, max_abs: i32) -> IsingModel {
        let mut m = IsingModel::zeros(n);
        let mut idx = 0u64;
        let span = (2 * max_abs + 1) as u32;
        for i in 0..n {
            for k in (i + 1)..n {
                let v = rng.below(40, idx, salt::PROBLEM, span) as i32 - max_abs;
                idx += 1;
                if v != 0 {
                    m.set_j(i, k, v);
                }
            }
            let hv = rng.below(41, i as u64, salt::PROBLEM, span) as i32 - max_abs;
            m.set_h(i, hv);
        }
        m
    }

    /// A random spin configuration.
    pub fn spins(rng: &StatelessRng, n: usize) -> SpinVec {
        SpinVec::random(n, &rng.child(0xF00D))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic() {
        let mut sizes1 = Vec::new();
        Cases::new(1, 10).run(|rng, size| {
            sizes1.push((rng.u32(0, 0, 0), size));
            Ok(())
        });
        let mut sizes2 = Vec::new();
        Cases::new(1, 10).run(|rng, size| {
            sizes2.push((rng.u32(0, 0, 0), size));
            Ok(())
        });
        assert_eq!(sizes1, sizes2);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failure_reports_case() {
        Cases::new(2, 5).run(|_, size| {
            if size >= 2 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }
}
