//! Artifact manifest: what `make artifacts` produced and how to call it.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` with one line
//! per artifact: whitespace-separated `key=value` pairs. Keys:
//! `name`, `file`, `kind` (`anneal_chunk` | `flip_probs` | `field_init`),
//! `n` (spins), plus kind-specific fields (`chunk` steps, `planes`).

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub n: usize,
    /// Steps per call for `anneal_chunk`.
    pub chunk: Option<u64>,
    /// Bit-planes for `field_init`.
    pub planes: Option<u32>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub specs: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Locate the artifacts directory: `$SNOWBALL_ARTIFACTS` or
    /// `./artifacts` relative to the current directory / manifest dir.
    pub fn discover() -> Result<Self> {
        if let Ok(dir) = std::env::var("SNOWBALL_ARTIFACTS") {
            return Self::load(Path::new(&dir));
        }
        let candidates = [
            PathBuf::from("artifacts"),
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        ];
        for c in &candidates {
            if c.join("manifest.txt").exists() {
                return Self::load(c);
            }
        }
        anyhow::bail!(
            "no artifacts/manifest.txt found (run `make artifacts`, or set SNOWBALL_ARTIFACTS)"
        )
    }

    /// Parse manifest text.
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut specs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv = HashMap::new();
            for tok in line.split_whitespace() {
                let (k, v) = tok
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad token {tok}", lineno + 1))?;
                kv.insert(k.to_string(), v.to_string());
            }
            let get = |k: &str| -> Result<String> {
                kv.get(k).cloned().with_context(|| format!("manifest line {}: missing {k}", lineno + 1))
            };
            specs.push(ArtifactSpec {
                name: get("name")?,
                file: dir.join(get("file")?),
                kind: get("kind")?,
                n: get("n")?.parse()?,
                chunk: kv.get("chunk").map(|v| v.parse()).transpose()?,
                planes: kv.get("planes").map(|v| v.parse()).transpose()?,
            });
        }
        Ok(Self { dir: dir.to_path_buf(), specs })
    }

    /// Find an artifact by kind and exact size.
    pub fn find(&self, kind: &str, n: usize) -> Option<&ArtifactSpec> {
        self.specs.iter().find(|s| s.kind == kind && s.n == n)
    }

    /// Find the smallest artifact of `kind` with capacity ≥ `n`
    /// (the coordinator's size-batching rule: pad up).
    pub fn find_padded(&self, kind: &str, n: usize) -> Option<&ArtifactSpec> {
        self.specs.iter().filter(|s| s.kind == kind && s.n >= n).min_by_key(|s| s.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
name=anneal_chunk_n256_c128 file=anneal_chunk_n256_c128.hlo.txt kind=anneal_chunk n=256 chunk=128
name=flip_probs_n256 file=flip_probs_n256.hlo.txt kind=flip_probs n=256
name=field_init_n256_b4 file=field_init_n256_b4.hlo.txt kind=field_init n=256 planes=4
name=anneal_chunk_n2048_c256 file=anneal_chunk_n2048_c256.hlo.txt kind=anneal_chunk n=2048 chunk=256
";

    #[test]
    fn parse_and_find() {
        let m = ArtifactManifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.specs.len(), 4);
        let a = m.find("anneal_chunk", 256).unwrap();
        assert_eq!(a.chunk, Some(128));
        assert_eq!(a.file, Path::new("/tmp/a/anneal_chunk_n256_c128.hlo.txt"));
        let f = m.find("field_init", 256).unwrap();
        assert_eq!(f.planes, Some(4));
        assert!(m.find("anneal_chunk", 512).is_none());
    }

    #[test]
    fn find_padded_picks_smallest_fit() {
        let m = ArtifactManifest::parse(Path::new("/x"), SAMPLE).unwrap();
        assert_eq!(m.find_padded("anneal_chunk", 300).unwrap().n, 2048);
        assert_eq!(m.find_padded("anneal_chunk", 100).unwrap().n, 256);
        assert!(m.find_padded("anneal_chunk", 4096).is_none());
    }

    #[test]
    fn bad_lines_error() {
        assert!(ArtifactManifest::parse(Path::new("/x"), "name=a bogus").is_err());
        assert!(ArtifactManifest::parse(Path::new("/x"), "file=f kind=k n=1").is_err());
    }
}
