//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! from the Rust hot path (DESIGN.md §2).
//!
//! Python runs only at build time (`make artifacts`): `python/compile/
//! aot.py` lowers the L2 model (which calls the L1 Pallas kernels) to
//! **HLO text** — not a serialized `HloModuleProto`, which jax ≥ 0.5
//! emits with 64-bit instruction ids that xla_extension 0.5.1 rejects.
//! This module loads that text with `HloModuleProto::from_text_file`,
//! compiles it on the PJRT CPU client once, and executes it with either
//! host literals or resident device buffers.
//!
//! The PJRT bindings (`xla` crate) are **not** vendored in the offline
//! build environment, so the whole backend sits behind the `xla` cargo
//! feature. With the feature off (the default), [`Runtime`] and
//! [`chunk::ChunkRunner`] are compiled as stubs whose constructors
//! return descriptive errors; artifact-manifest parsing and the
//! [`chunk::ChunkState`] plumbing stay available so every caller
//! (CLI `info`, the `k2000_tts` example, `microbench`) compiles and
//! degrades gracefully.

pub mod artifacts;
pub mod chunk;

pub use artifacts::{ArtifactManifest, ArtifactSpec};
pub use chunk::ChunkRunner;

#[cfg(feature = "xla")]
mod backend {
    use anyhow::{Context, Result};
    use std::path::Path;

    /// A PJRT client plus the executables loaded on it.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create a CPU PJRT client (the only backend in this environment;
        /// on a TPU host the same artifacts compile via `PjRtClient::tpu`).
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
            Ok(Self { client })
        }

        /// Platform string, e.g. `"cpu"`.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// The underlying client.
        pub fn client(&self) -> &xla::PjRtClient {
            &self.client
        }

        /// Load an HLO-text artifact and compile it.
        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path is not UTF-8")?,
            )
            .map_err(to_anyhow)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(to_anyhow)?;
            Ok(Executable { exe, name: path.display().to_string() })
        }

        /// Upload a literal as a resident device buffer (used to keep the
        /// coupling matrix on device across chunk calls).
        pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
            self.client.buffer_from_host_literal(None, lit).map_err(to_anyhow)
        }
    }

    /// A compiled artifact.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl Executable {
        /// Execute with host literals; returns the flattened tuple elements
        /// (artifacts are lowered with `return_tuple=True`).
        pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let out = self.exe.execute::<xla::Literal>(args).map_err(to_anyhow)?;
            self.unpack(out)
        }

        /// Execute with resident device buffers.
        pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
            let out = self.exe.execute_b(args).map_err(to_anyhow)?;
            self.unpack(out)
        }

        fn unpack(&self, out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
            let first = out
                .first()
                .and_then(|r| r.first())
                .with_context(|| format!("{}: empty execution result", self.name))?;
            let lit = first.to_literal_sync().map_err(to_anyhow)?;
            lit.to_tuple().map_err(to_anyhow)
        }

        /// Artifact name (for diagnostics).
        pub fn name(&self) -> &str {
            &self.name
        }
    }

    /// Convert `xla::Error` (non-std error type) into `anyhow::Error`.
    pub(crate) fn to_anyhow(e: xla::Error) -> anyhow::Error {
        anyhow::anyhow!("{e:?}")
    }

    /// Helpers for building literals from engine-side state.
    pub mod lit {
        use anyhow::Result;

        /// f32 matrix literal from row-major data.
        pub fn f32_matrix(rows: usize, cols: usize, data: &[f32]) -> Result<xla::Literal> {
            assert_eq!(data.len(), rows * cols);
            xla::Literal::vec1(data)
                .reshape(&[rows as i64, cols as i64])
                .map_err(super::to_anyhow)
        }

        /// f32 vector literal.
        pub fn f32_vec(data: &[f32]) -> xla::Literal {
            xla::Literal::vec1(data)
        }

        /// u32 vector literal.
        pub fn u32_vec(data: &[u32]) -> xla::Literal {
            xla::Literal::vec1(data)
        }
    }
}

#[cfg(feature = "xla")]
pub use backend::{lit, Executable, Runtime};
#[cfg(feature = "xla")]
pub(crate) use backend::to_anyhow;

#[cfg(not(feature = "xla"))]
mod backend {
    use anyhow::Result;

    /// Stub PJRT runtime (the `xla` cargo feature is off). [`Runtime::cpu`]
    /// always errors, so no instance can exist; the remaining methods keep
    /// the call sites type-checking.
    pub struct Runtime {
        _unconstructable: (),
    }

    impl Runtime {
        /// Always fails: the PJRT backend was not compiled in.
        pub fn cpu() -> Result<Self> {
            anyhow::bail!(
                "XLA backend not built: rebuild with the `xla` cargo feature AND \
                 the external PJRT `xla` crate added as a dependency (it is not \
                 declared in Cargo.toml so offline builds never try to resolve it \
                 — see the [features] note in rust/Cargo.toml)"
            )
        }

        /// Platform string (unreachable: no stub `Runtime` can be built).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use backend::Runtime;
