//! Chunked execution of the AOT anneal graph (L2/L1) from the L3 hot
//! path.
//!
//! One `anneal_chunk` artifact advances a roulette-mode chain by `C`
//! steps per call, entirely inside XLA: per-step flip probabilities come
//! from the L1 Pallas PWL kernel, selection/update/energy tracking from
//! the L2 scan (see `python/compile/model.py`). The Rust side keeps the
//! coupling matrix **resident on the device** and round-trips only the
//! O(N) chain state per call.
//!
//! The chunk is bit-parity-matched to the native engine: same stateless
//! RNG streams, same Q16 PWL table, same integer ΔE — `rust/tests/
//! xla_parity.rs` asserts identical trajectories.
//!
//! Artifact calling convention (see `python/compile/model.py`):
//!   inputs  = (J f32[N,N], s f32[N], u f64[N], energy f64[],
//!              temps f64[C], seed u64[], step0 u64[])
//!   outputs = (s f32[N], u f64[N], energy f64[], trace f64[C])
//!
//! [`ChunkRunner`] needs the PJRT bindings and is gated on the `xla`
//! cargo feature (a stub that errors on construction is compiled
//! otherwise); [`ChunkState`] is plain data and always available.

use crate::ising::{IsingModel, SpinVec};

/// Chain state ferried between Rust and the device.
#[derive(Clone, Debug)]
pub struct ChunkState {
    pub spins: SpinVec,
    pub u: Vec<f64>,
    pub energy: f64,
    /// Global step counter (drives the stateless RNG stage index).
    pub step: u64,
}

impl ChunkState {
    /// Initialize from a model + configuration (fields from scratch).
    pub fn init(model: &IsingModel, spins: SpinVec) -> Self {
        let u: Vec<f64> = model.local_fields(&spins).iter().map(|&v| v as f64).collect();
        let energy = model.energy(&spins) as f64;
        Self { spins, u, energy, step: 0 }
    }
}

#[cfg(feature = "xla")]
mod runner {
    use super::super::{lit, ArtifactSpec, Executable, Runtime};
    use super::ChunkState;
    use crate::ising::IsingModel;
    use anyhow::{Context, Result};

    /// Runs `anneal_chunk` artifacts with a resident coupling buffer.
    pub struct ChunkRunner {
        exe: Executable,
        /// Device-resident J (uploaded once).
        j_buffer: xla::PjRtBuffer,
        n: usize,
        chunk: u64,
        seed: u64,
        rt_n: usize,
    }

    impl ChunkRunner {
        /// Compile the artifact and upload the (zero-padded) coupling matrix.
        ///
        /// The artifact size `spec.n` may exceed the model's N — the
        /// coordinator's batcher pads instances up to the nearest artifact
        /// (padding spins have zero couplings and frozen fields, so they
        /// never win the roulette; see `python/compile/model.py`).
        pub fn new(
            rt: &Runtime,
            spec: &ArtifactSpec,
            model: &IsingModel,
            seed: u64,
        ) -> Result<Self> {
            anyhow::ensure!(
                spec.kind == "anneal_chunk",
                "artifact {} is not an anneal_chunk",
                spec.name
            );
            anyhow::ensure!(
                spec.n >= model.len(),
                "artifact N {} < model N {}",
                spec.n,
                model.len()
            );
            let chunk = spec.chunk.context("anneal_chunk artifact missing chunk length")?;
            let exe = rt.load_hlo_text(&spec.file)?;
            let rt_n = spec.n;
            let n = model.len();
            // Row-major J as f32, zero-padded to rt_n × rt_n.
            let mut jf = vec![0f32; rt_n * rt_n];
            for i in 0..n {
                let row = model.j_row(i);
                for (k, v) in row.iter().enumerate() {
                    jf[i * rt_n + k] = v as f32;
                }
            }
            let j_lit = lit::f32_matrix(rt_n, rt_n, &jf)?;
            let j_buffer = rt.upload(&j_lit)?;
            Ok(Self { exe, j_buffer, n, chunk, seed, rt_n })
        }

        /// Steps advanced per call.
        pub fn chunk_len(&self) -> u64 {
            self.chunk
        }

        /// Artifact (padded) size.
        pub fn padded_n(&self) -> usize {
            self.rt_n
        }

        /// Advance the chain by one chunk; `temps` must have exactly
        /// `chunk_len()` entries. Returns the per-step energy trace.
        pub fn run_chunk(
            &self,
            rt: &Runtime,
            state: &mut ChunkState,
            temps: &[f64],
        ) -> Result<Vec<f64>> {
            anyhow::ensure!(
                temps.len() as u64 == self.chunk,
                "need {} temps, got {}",
                self.chunk,
                temps.len()
            );
            // Pack state, padding tail spins to +1 with "infinitely" positive
            // fields: ΔE = 2·s·u = huge > 0 ⇒ p_flip = 0 ⇒ never selected.
            let mut s = vec![1f32; self.rt_n];
            for i in 0..self.n {
                s[i] = state.spins.get(i) as f32;
            }
            let mut u = vec![1e12f64; self.rt_n];
            u[..self.n].copy_from_slice(&state.u);
            let args = [
                // J is resident; the rest are uploaded per call (O(N)).
                None,
                Some(lit::f32_vec(&s)),
                Some(xla::Literal::vec1(&u)),
                Some(xla::Literal::scalar(state.energy)),
                Some(xla::Literal::vec1(temps)),
                Some(xla::Literal::scalar(self.seed)),
                Some(xla::Literal::scalar(state.step)),
            ];
            let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len() - 1);
            for a in args.iter().flatten() {
                bufs.push(rt.upload(a)?);
            }
            let mut all: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
            all.push(&self.j_buffer);
            for b in &bufs {
                all.push(b);
            }
            let out = self.exe.run_b(&all)?;
            anyhow::ensure!(out.len() == 4, "anneal_chunk returned {} outputs, want 4", out.len());
            let s_new: Vec<f32> = out[0].to_vec().map_err(super::super::to_anyhow)?;
            let u_new: Vec<f64> = out[1].to_vec().map_err(super::super::to_anyhow)?;
            let e_new: f64 = out[2].get_first_element().map_err(super::super::to_anyhow)?;
            let trace: Vec<f64> = out[3].to_vec().map_err(super::super::to_anyhow)?;
            for i in 0..self.n {
                state.spins.set(i, if s_new[i] >= 0.0 { 1 } else { -1 });
            }
            state.u.copy_from_slice(&u_new[..self.n]);
            state.energy = e_new;
            state.step += self.chunk;
            Ok(trace)
        }
    }
}

#[cfg(not(feature = "xla"))]
mod runner {
    use super::super::{ArtifactSpec, Runtime};
    use super::ChunkState;
    use crate::ising::IsingModel;
    use anyhow::Result;

    /// Stub chunk runner (the `xla` cargo feature is off). [`new`] always
    /// errors, so no instance can exist; the remaining methods keep the
    /// call sites type-checking.
    ///
    /// [`new`]: ChunkRunner::new
    pub struct ChunkRunner {
        _unconstructable: (),
    }

    impl ChunkRunner {
        /// Always fails: the PJRT backend was not compiled in.
        pub fn new(
            _rt: &Runtime,
            spec: &ArtifactSpec,
            _model: &IsingModel,
            _seed: u64,
        ) -> Result<Self> {
            anyhow::bail!(
                "cannot execute artifact {}: XLA backend not built (rebuild with \
                 the `xla` feature + dependency; see rust/Cargo.toml [features])",
                spec.name
            )
        }

        /// Steps advanced per call (unreachable: no stub runner exists).
        pub fn chunk_len(&self) -> u64 {
            0
        }

        /// Artifact (padded) size (unreachable: no stub runner exists).
        pub fn padded_n(&self) -> usize {
            0
        }

        /// Always fails: the PJRT backend was not compiled in.
        pub fn run_chunk(
            &self,
            _rt: &Runtime,
            _state: &mut ChunkState,
            _temps: &[f64],
        ) -> Result<Vec<f64>> {
            anyhow::bail!(
                "XLA backend not built (rebuild with the `xla` feature + dependency; \
                 see rust/Cargo.toml [features])"
            )
        }
    }
}

pub use runner::ChunkRunner;
