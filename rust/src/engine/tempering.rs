//! Parallel tempering / replica exchange (paper §IV-A discusses it as
//! the alternative annealing mechanism and why Snowball prefers plain
//! SA; implemented here as the optional extension so the trade-off is
//! measurable).
//!
//! `R` replicas run the same instance at a temperature ladder
//! `T_0 > … > T_{R−1}`; every `exchange_every` steps, neighbouring
//! replicas propose a configuration swap accepted with the standard
//! probability `min(1, exp((1/T_a − 1/T_b)(E_a − E_b)))`, which leaves
//! the product Gibbs measure invariant.
//!
//! Between exchange barriers the replica chains are completely
//! independent (each engine draws from its own stateless child stream),
//! so each step burst fans out over the shared [`ReplicaPool`]. The
//! exchange step and the best-configuration reduction run serially in
//! replica-index order, which makes the whole run **bit-identical for
//! any worker count** — asserted by `worker_count_invariance` below and
//! `rust/tests/pool_determinism.rs`.

use super::pool::ReplicaPool;
use super::{Datapath, EngineConfig, Mode, Schedule, SelectorKind, SnowballEngine};
use crate::ising::IsingModel;
use crate::rng::{salt, StatelessRng};

/// Parallel-tempering driver over the Snowball engine.
pub struct ParallelTempering {
    pub temps: Vec<f64>,
    pub exchange_every: u64,
    pub mode: Mode,
    /// Worker threads for the replica bursts (0 = one per CPU). Results
    /// do not depend on this — it only changes wall-clock.
    pub workers: usize,
}

/// Outcome of a tempering run.
#[derive(Debug)]
pub struct TemperingResult {
    pub best_energy: i64,
    pub best_spins: crate::ising::SpinVec,
    /// Swap acceptance rate per neighbouring pair.
    pub swap_rates: Vec<f64>,
    pub steps: u64,
}

impl ParallelTempering {
    /// Geometric ladder between `t_hot` and `t_cold` with `r` replicas.
    pub fn geometric(r: usize, t_hot: f64, t_cold: f64, mode: Mode) -> Self {
        assert!(r >= 2 && t_hot > t_cold && t_cold > 0.0);
        let temps = (0..r)
            .map(|i| t_hot * (t_cold / t_hot).powf(i as f64 / (r - 1) as f64))
            .collect();
        Self { temps, exchange_every: 64, mode, workers: 0 }
    }

    /// Set the worker count (builder style; 0 = one per CPU).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Size the pool through the stack-wide replica-vs-shard policy
    /// ([`crate::engine::shard::plan_parallelism`]) for an `n`-spin
    /// instance: the ladder's chains are the "units", so tempering
    /// always takes the plan's replica-level share (its chains
    /// lock-step at exchange barriers, which rules out blocking shard
    /// lanes inside a burst — shard-level parallelism is the
    /// [`crate::coordinator::ReplicaScheduler`]'s side of the same
    /// policy). Concretely: never more pool workers than chains, so a
    /// big-instance ladder leaves the spare cores to other tenants of
    /// the machine instead of oversubscribing its own bursts.
    pub fn with_auto_parallelism(mut self, n: usize) -> Self {
        let plan = crate::engine::shard::plan_parallelism(
            n,
            self.temps.len(),
            super::pool::ReplicaPool::auto_workers(),
        );
        self.workers = plan.replica_workers;
        self
    }

    /// Run `steps` single-spin updates per replica on a fresh pool.
    pub fn run(&self, model: &IsingModel, steps: u64, seed: u64) -> TemperingResult {
        let pool = ReplicaPool::new(self.workers);
        self.run_on(&pool, model, steps, seed)
    }

    /// Run `steps` single-spin updates per replica, fanning the bursts
    /// over an existing pool (so callers batching many tempering runs —
    /// coordinator jobs, harness sweeps — reuse one set of threads).
    pub fn run_on(
        &self,
        pool: &ReplicaPool,
        model: &IsingModel,
        steps: u64,
        seed: u64,
    ) -> TemperingResult {
        let r = self.temps.len();
        let root = StatelessRng::new(seed);
        let mut engines: Vec<SnowballEngine> = (0..r)
            .map(|i| {
                let cfg = EngineConfig {
                    mode: self.mode,
                    datapath: Datapath::Dense,
                    selector: SelectorKind::Fenwick,
                    schedule: Schedule::Constant(self.temps[i]),
                    steps: 0,
                    seed: root.child(i as u64).seed(),
                    planes: None,
                    trace_stride: 0,
                    shards: 1,
                    pin_lanes: false,
                    local_rows: false,
                };
                SnowballEngine::new(model, cfg)
            })
            .collect();
        // ladder[k] = which engine currently holds temperature k.
        let mut ladder: Vec<usize> = (0..r).collect();
        let mut best_energy = i64::MAX;
        let mut best_spins = engines[0].spins().clone();
        let mut proposals = vec![0u64; r - 1];
        let mut accepts = vec![0u64; r - 1];
        // temp_of[e] = temperature engine e runs at during the next burst.
        let mut temp_of = vec![0.0f64; r];
        let mut t = 0u64;
        while t < steps {
            let burst = self.exchange_every.min(steps - t);
            for (k, &e) in ladder.iter().enumerate() {
                temp_of[e] = self.temps[k];
            }
            // Parallel burst: replica streams are independent between
            // exchanges (distinct child seeds, own state), so each engine
            // advances on its own worker.
            {
                let temp_of = &temp_of;
                pool.for_each_mut(&mut engines, |e, engine| {
                    let temp = temp_of[e];
                    for dt in 0..burst {
                        engine.step(t + dt, temp);
                    }
                });
            }
            // Best reduction in engine-index order: deterministic
            // regardless of which worker finished first.
            for engine in &engines {
                if engine.energy() < best_energy {
                    best_energy = engine.energy();
                    best_spins = engine.spins().clone();
                }
            }
            t += burst;
            // Neighbour swaps, alternating parity for ergodic exchange.
            let parity = ((t / self.exchange_every) % 2) as usize;
            for k in (parity..r - 1).step_by(2) {
                proposals[k] += 1;
                let (ta, tb) = (self.temps[k], self.temps[k + 1]);
                let (ea, eb) =
                    (engines[ladder[k]].energy() as f64, engines[ladder[k + 1]].energy() as f64);
                let log_acc = (1.0 / ta - 1.0 / tb) * (ea - eb);
                let accept = log_acc >= 0.0
                    || root.unit_f64(t, k as u64, salt::BASELINE) < log_acc.exp();
                if accept {
                    ladder.swap(k, k + 1);
                    accepts[k] += 1;
                }
            }
        }
        TemperingResult {
            best_energy,
            best_spins,
            swap_rates: accepts
                .iter()
                .zip(&proposals)
                .map(|(&a, &p)| if p == 0 { 0.0 } else { a as f64 / p as f64 })
                .collect(),
            steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    #[test]
    fn tempering_finds_low_energy_and_swaps() {
        let rng = StatelessRng::new(9);
        let g = generators::erdos_renyi(48, 220, &[-1, 1], &rng);
        let p = MaxCut::new(g);
        let pt = ParallelTempering::geometric(6, 6.0, 0.3, Mode::RandomScan);
        let r = pt.run(p.model(), 30_000, 3);
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins));
        assert!(r.best_energy < -50, "PT best {} too weak", r.best_energy);
        // A reasonable geometric ladder must actually exchange.
        let mean: f64 = r.swap_rates.iter().sum::<f64>() / r.swap_rates.len() as f64;
        assert!(mean > 0.1, "swap rate {mean} collapsed (ladder too sparse)");
    }

    #[test]
    fn sparse_ladder_degrades_swap_rate() {
        // The paper's §IV-A argument for preferring SA: with too few
        // replicas the acceptance collapses.
        let rng = StatelessRng::new(11);
        let g = generators::erdos_renyi(64, 400, &[-1, 1], &rng);
        let p = MaxCut::new(g);
        let dense = ParallelTempering::geometric(8, 8.0, 0.2, Mode::RandomScan)
            .run(p.model(), 20_000, 1);
        let sparse = ParallelTempering::geometric(2, 8.0, 0.2, Mode::RandomScan)
            .run(p.model(), 20_000, 1);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(
            mean(&dense.swap_rates) > mean(&sparse.swap_rates),
            "denser ladder must swap more: {:?} vs {:?}",
            dense.swap_rates,
            sparse.swap_rates
        );
    }

    /// The auto policy never hands a tempering ladder more pool
    /// workers than it has chains (its bursts cannot use them), and it
    /// cannot change results — only wall-clock.
    #[test]
    fn auto_parallelism_caps_workers_at_chain_count() {
        let pt = ParallelTempering::geometric(4, 6.0, 0.3, Mode::RandomScan)
            .with_auto_parallelism(100_000);
        assert!(pt.workers >= 1 && pt.workers <= 4, "workers {} vs 4 chains", pt.workers);
        let rng = StatelessRng::new(31);
        let g = generators::erdos_renyi(40, 180, &[-1, 1], &rng);
        let p = MaxCut::new(g);
        let auto = ParallelTempering::geometric(4, 5.0, 0.3, Mode::RandomScan)
            .with_auto_parallelism(p.model().len())
            .run(p.model(), 2_000, 7);
        let serial = ParallelTempering::geometric(4, 5.0, 0.3, Mode::RandomScan)
            .with_workers(1)
            .run(p.model(), 2_000, 7);
        assert_eq!(auto.best_energy, serial.best_energy);
        assert_eq!(auto.best_spins, serial.best_spins);
    }

    #[test]
    fn ladder_is_geometric() {
        let pt = ParallelTempering::geometric(4, 8.0, 1.0, Mode::RandomScan);
        let ratios: Vec<f64> = pt.temps.windows(2).map(|w| w[1] / w[0]).collect();
        for w in ratios.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-12);
        }
    }

    /// The tentpole guarantee: one worker and many workers produce the
    /// same trajectory bit for bit (the integration suite repeats this
    /// on a larger instance with swap-rate comparison).
    #[test]
    fn worker_count_invariance() {
        let rng = StatelessRng::new(13);
        let g = generators::erdos_renyi(40, 180, &[-1, 1], &rng);
        let p = MaxCut::new(g);
        for mode in [Mode::RandomScan, Mode::RouletteWheel] {
            let serial = ParallelTempering::geometric(4, 5.0, 0.3, mode)
                .with_workers(1)
                .run(p.model(), 4_000, 7);
            let wide = ParallelTempering::geometric(4, 5.0, 0.3, mode)
                .with_workers(4)
                .run(p.model(), 4_000, 7);
            assert_eq!(serial.best_energy, wide.best_energy, "{mode:?}");
            assert_eq!(serial.best_spins, wide.best_spins, "{mode:?}");
            assert_eq!(serial.swap_rates, wide.swap_rates, "{mode:?}");
        }
    }
}
