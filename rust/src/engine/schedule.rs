//! Annealing schedules (paper Algorithm 1 `Cooling(T0, T1, t, K)`).
//!
//! The paper uses a linear schedule for the Fig. 4 demonstration and a
//! cosine schedule for the Fig. 15 field-recovery experiment; the FPGA
//! preloads an arbitrary programmable `{T_k}` table, which `Table`
//! models.

/// A temperature schedule over `K` annealing steps.
#[derive(Clone, Debug)]
pub enum Schedule {
    /// Fixed temperature (plain MCMC sampling; detailed-balance regime).
    Constant(f64),
    /// Linear interpolation T0 → T1.
    Linear { t0: f64, t1: f64 },
    /// Geometric decay T0 → T1 (multiplicative; classic SA).
    Geometric { t0: f64, t1: f64 },
    /// Half-cosine ramp T0 → T1 (used in the Fig. 15 experiment).
    Cosine { t0: f64, t1: f64 },
    /// Explicit preloaded table, one entry per annealing stage — the
    /// hardware's programmable `{T_k}` memory.
    Table(Vec<f64>),
}

impl Schedule {
    /// Temperature at step `t ∈ [0, k_total)`.
    pub fn temperature(&self, t: u64, k_total: u64) -> f64 {
        let frac = if k_total <= 1 { 0.0 } else { t as f64 / (k_total - 1) as f64 };
        match self {
            Schedule::Constant(v) => *v,
            Schedule::Linear { t0, t1 } => t0 + (t1 - t0) * frac,
            Schedule::Geometric { t0, t1 } => {
                debug_assert!(*t0 > 0.0 && *t1 > 0.0);
                t0 * (t1 / t0).powf(frac)
            }
            Schedule::Cosine { t0, t1 } => {
                t1 + (t0 - t1) * 0.5 * (1.0 + (std::f64::consts::PI * frac).cos())
            }
            Schedule::Table(v) => {
                if v.is_empty() {
                    0.0
                } else {
                    let idx = ((t as usize) * v.len() / (k_total.max(1) as usize)).min(v.len() - 1);
                    v[idx]
                }
            }
        }
    }

    /// Materialize the schedule as a table of `k_total` temperatures —
    /// what `make artifacts` bakes into the AOT chunk inputs and what the
    /// FPGA would preload.
    pub fn materialize(&self, k_total: u64) -> Vec<f64> {
        (0..k_total).map(|t| self.temperature(t, k_total)).collect()
    }

    /// Quantize into `stages` piecewise-constant plateaus — the FPGA's
    /// coarse programmable `{T_k}` stage memory. A plateaued schedule is
    /// what lets the Fenwick selection path reuse lane weights across the
    /// steps inside a stage (only touched lanes are re-evaluated);
    /// continuous ramps force a full lane refresh every step.
    pub fn quantized(&self, stages: usize) -> Schedule {
        assert!(stages >= 1, "a schedule needs at least one stage");
        Schedule::Table(self.materialize(stages as u64))
    }

    /// Iterate the maximal constant-temperature runs of a `k_total`-step
    /// run. Θ(1) per plateau for `Constant`, `Table` and degenerate
    /// (`t0 == t1`) ramps; continuous ramps yield length-1 plateaus.
    pub fn plateaus(&self, k_total: u64) -> Plateaus<'_> {
        Plateaus { sched: self, k_total, next: 0 }
    }

    /// For `Table` schedules: the first step strictly after `start` at
    /// which the table index changes (table entry `idx` spans the steps
    /// `t` with `⌊t·len/K⌋ == idx`).
    fn table_seg_end(len: u64, k_total: u64, start: u64) -> u64 {
        let idx = (start as u128 * len as u128) / k_total as u128;
        if idx + 1 >= len as u128 {
            k_total
        } else {
            (((idx + 1) * k_total as u128).div_ceil(len as u128)) as u64
        }
    }

    /// Parse `"kind:t0:t1"` / `"constant:t"` (CLI syntax). Ramps accept
    /// an optional fourth field `":stages"` that quantizes them into that
    /// many plateaus (e.g. `"geometric:8:0.05:32"`).
    pub fn parse(s: &str) -> anyhow::Result<Schedule> {
        let parts: Vec<&str> = s.split(':').collect();
        let get = |i: usize| -> anyhow::Result<f64> {
            parts
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("schedule '{s}': missing field {i}"))?
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("schedule '{s}': {e}"))
        };
        let stages = |sched: Schedule| -> anyhow::Result<Schedule> {
            match parts.get(3) {
                None => Ok(sched),
                Some(v) => {
                    let k: usize =
                        v.parse().map_err(|e| anyhow::anyhow!("schedule '{s}': stages: {e}"))?;
                    anyhow::ensure!(k >= 1, "schedule '{s}': stages must be >= 1");
                    Ok(sched.quantized(k))
                }
            }
        };
        match parts[0] {
            "constant" => Ok(Schedule::Constant(get(1)?)),
            "linear" => stages(Schedule::Linear { t0: get(1)?, t1: get(2)? }),
            "geometric" => stages(Schedule::Geometric { t0: get(1)?, t1: get(2)? }),
            "cosine" => stages(Schedule::Cosine { t0: get(1)?, t1: get(2)? }),
            other => anyhow::bail!("unknown schedule kind '{other}'"),
        }
    }
}

/// A maximal half-open run of steps `[start, end)` sharing one
/// temperature.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Plateau {
    pub start: u64,
    pub end: u64,
    pub temp: f64,
}

impl Plateau {
    /// Steps in the plateau.
    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    /// True for an empty run (never yielded by the iterator).
    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// Iterator over a schedule's plateaus (see [`Schedule::plateaus`]).
pub struct Plateaus<'a> {
    sched: &'a Schedule,
    k_total: u64,
    next: u64,
}

impl Iterator for Plateaus<'_> {
    type Item = Plateau;

    fn next(&mut self) -> Option<Plateau> {
        if self.next >= self.k_total {
            return None;
        }
        let start = self.next;
        let temp = self.sched.temperature(start, self.k_total);
        let mut end = match self.sched {
            Schedule::Constant(_) => self.k_total,
            Schedule::Linear { t0, t1 }
            | Schedule::Geometric { t0, t1 }
            | Schedule::Cosine { t0, t1 } => {
                if t0 == t1 || self.k_total == 1 {
                    self.k_total
                } else {
                    start + 1
                }
            }
            Schedule::Table(v) => {
                if v.is_empty() {
                    self.k_total
                } else {
                    Schedule::table_seg_end(v.len() as u64, self.k_total, start)
                }
            }
        };
        // Merge adjacent table entries that quantized to the same value.
        if let Schedule::Table(v) = self.sched {
            if !v.is_empty() {
                while end < self.k_total && self.sched.temperature(end, self.k_total) == temp {
                    end = Schedule::table_seg_end(v.len() as u64, self.k_total, end);
                }
            }
        }
        self.next = end;
        Some(Plateau { start, end, temp })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_endpoints() {
        let s = Schedule::Linear { t0: 10.0, t1: 1.0 };
        assert_eq!(s.temperature(0, 100), 10.0);
        assert!((s.temperature(99, 100) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geometric_is_monotone_decreasing() {
        let s = Schedule::Geometric { t0: 8.0, t1: 0.5 };
        let temps = s.materialize(50);
        for w in temps.windows(2) {
            assert!(w[1] < w[0]);
        }
        assert!((temps[0] - 8.0).abs() < 1e-12);
        assert!((temps[49] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cosine_endpoints_and_shape() {
        let s = Schedule::Cosine { t0: 4.0, t1: 0.0 };
        assert!((s.temperature(0, 101) - 4.0).abs() < 1e-12);
        assert!(s.temperature(100, 101).abs() < 1e-12);
        // Mid-point is the arithmetic mean for cosine.
        assert!((s.temperature(50, 101) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn table_lookup() {
        let s = Schedule::Table(vec![3.0, 2.0, 1.0]);
        assert_eq!(s.temperature(0, 3), 3.0);
        assert_eq!(s.temperature(2, 3), 1.0);
        // Resampled across more steps than entries.
        assert_eq!(s.temperature(5, 6), 1.0);
    }

    #[test]
    fn parse_roundtrip() {
        assert!(matches!(Schedule::parse("constant:2.5").unwrap(), Schedule::Constant(v) if v == 2.5));
        assert!(matches!(Schedule::parse("linear:5:0").unwrap(), Schedule::Linear { .. }));
        assert!(Schedule::parse("bogus:1").is_err());
        assert!(Schedule::parse("linear:5").is_err());
    }

    #[test]
    fn parse_staged_ramp() {
        let s = Schedule::parse("geometric:8:0.05:16").unwrap();
        match &s {
            Schedule::Table(v) => {
                assert_eq!(v.len(), 16);
                assert!((v[0] - 8.0).abs() < 1e-12);
                assert!((v[15] - 0.05).abs() < 1e-9);
            }
            other => panic!("expected Table, got {other:?}"),
        }
        assert!(Schedule::parse("geometric:8:0.05:0").is_err());
        assert!(Schedule::parse("geometric:8:0.05:x").is_err());
    }

    /// Plateau runs must tile [0, K) exactly and agree with per-step
    /// temperature lookups, for every schedule kind.
    #[test]
    fn plateaus_tile_and_match_temperatures() {
        let k = 257u64;
        for s in [
            Schedule::Constant(2.0),
            Schedule::Linear { t0: 5.0, t1: 1.0 },
            Schedule::Linear { t0: 3.0, t1: 3.0 },
            Schedule::Geometric { t0: 8.0, t1: 0.1 },
            Schedule::Cosine { t0: 4.0, t1: 0.5 },
            Schedule::Table(vec![3.0, 2.0, 2.0, 1.0]),
            Schedule::Geometric { t0: 8.0, t1: 0.1 }.quantized(10),
        ] {
            let mut next = 0u64;
            for p in s.plateaus(k) {
                assert_eq!(p.start, next, "{s:?}: plateaus must tile");
                assert!(p.end > p.start && p.end <= k);
                for t in p.start..p.end {
                    assert_eq!(s.temperature(t, k), p.temp, "{s:?} step {t}");
                }
                // Maximality: the next step (if any) has a new temperature.
                if p.end < k {
                    assert_ne!(s.temperature(p.end, k), p.temp, "{s:?}: not maximal at {}", p.end);
                }
                next = p.end;
            }
            assert_eq!(next, k, "{s:?}: plateaus must cover the whole run");
        }
    }

    #[test]
    fn plateau_counts() {
        assert_eq!(Schedule::Constant(1.0).plateaus(100).count(), 1);
        assert_eq!(Schedule::Linear { t0: 2.0, t1: 2.0 }.plateaus(100).count(), 1);
        let staged = Schedule::Geometric { t0: 8.0, t1: 0.05 }.quantized(10);
        assert_eq!(staged.plateaus(1000).count(), 10);
        // Continuous ramps degenerate to one plateau per step.
        assert_eq!(Schedule::Linear { t0: 2.0, t1: 1.0 }.plateaus(50).count(), 50);
        // Equal adjacent table entries merge into one plateau.
        assert_eq!(Schedule::Table(vec![2.0, 2.0, 1.0]).plateaus(99).count(), 2);
    }

    #[test]
    fn quantized_matches_table_semantics() {
        let base = Schedule::Geometric { t0: 8.0, t1: 0.05 };
        let q = base.quantized(8);
        // Stage temperatures are the base schedule sampled over 8 steps.
        let expect = base.materialize(8);
        for (t, e) in q.materialize(8).iter().zip(&expect) {
            assert_eq!(t, e);
        }
        // Across a longer run each stage holds for a run of steps.
        assert_eq!(q.temperature(0, 800), expect[0]);
        assert_eq!(q.temperature(799, 800), expect[7]);
    }
}
