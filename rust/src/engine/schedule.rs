//! Annealing schedules (paper Algorithm 1 `Cooling(T0, T1, t, K)`).
//!
//! The paper uses a linear schedule for the Fig. 4 demonstration and a
//! cosine schedule for the Fig. 15 field-recovery experiment; the FPGA
//! preloads an arbitrary programmable `{T_k}` table, which `Table`
//! models.

/// A temperature schedule over `K` annealing steps.
#[derive(Clone, Debug)]
pub enum Schedule {
    /// Fixed temperature (plain MCMC sampling; detailed-balance regime).
    Constant(f64),
    /// Linear interpolation T0 → T1.
    Linear { t0: f64, t1: f64 },
    /// Geometric decay T0 → T1 (multiplicative; classic SA).
    Geometric { t0: f64, t1: f64 },
    /// Half-cosine ramp T0 → T1 (used in the Fig. 15 experiment).
    Cosine { t0: f64, t1: f64 },
    /// Explicit preloaded table, one entry per annealing stage — the
    /// hardware's programmable `{T_k}` memory.
    Table(Vec<f64>),
}

impl Schedule {
    /// Temperature at step `t ∈ [0, k_total)`.
    pub fn temperature(&self, t: u64, k_total: u64) -> f64 {
        let frac = if k_total <= 1 { 0.0 } else { t as f64 / (k_total - 1) as f64 };
        match self {
            Schedule::Constant(v) => *v,
            Schedule::Linear { t0, t1 } => t0 + (t1 - t0) * frac,
            Schedule::Geometric { t0, t1 } => {
                debug_assert!(*t0 > 0.0 && *t1 > 0.0);
                t0 * (t1 / t0).powf(frac)
            }
            Schedule::Cosine { t0, t1 } => {
                t1 + (t0 - t1) * 0.5 * (1.0 + (std::f64::consts::PI * frac).cos())
            }
            Schedule::Table(v) => {
                if v.is_empty() {
                    0.0
                } else {
                    let idx = ((t as usize) * v.len() / (k_total.max(1) as usize)).min(v.len() - 1);
                    v[idx]
                }
            }
        }
    }

    /// Materialize the schedule as a table of `k_total` temperatures —
    /// what `make artifacts` bakes into the AOT chunk inputs and what the
    /// FPGA would preload.
    pub fn materialize(&self, k_total: u64) -> Vec<f64> {
        (0..k_total).map(|t| self.temperature(t, k_total)).collect()
    }

    /// Parse `"kind:t0:t1"` / `"constant:t"` (CLI syntax).
    pub fn parse(s: &str) -> anyhow::Result<Schedule> {
        let parts: Vec<&str> = s.split(':').collect();
        let get = |i: usize| -> anyhow::Result<f64> {
            parts
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("schedule '{s}': missing field {i}"))?
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("schedule '{s}': {e}"))
        };
        match parts[0] {
            "constant" => Ok(Schedule::Constant(get(1)?)),
            "linear" => Ok(Schedule::Linear { t0: get(1)?, t1: get(2)? }),
            "geometric" => Ok(Schedule::Geometric { t0: get(1)?, t1: get(2)? }),
            "cosine" => Ok(Schedule::Cosine { t0: get(1)?, t1: get(2)? }),
            other => anyhow::bail!("unknown schedule kind '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_endpoints() {
        let s = Schedule::Linear { t0: 10.0, t1: 1.0 };
        assert_eq!(s.temperature(0, 100), 10.0);
        assert!((s.temperature(99, 100) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geometric_is_monotone_decreasing() {
        let s = Schedule::Geometric { t0: 8.0, t1: 0.5 };
        let temps = s.materialize(50);
        for w in temps.windows(2) {
            assert!(w[1] < w[0]);
        }
        assert!((temps[0] - 8.0).abs() < 1e-12);
        assert!((temps[49] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cosine_endpoints_and_shape() {
        let s = Schedule::Cosine { t0: 4.0, t1: 0.0 };
        assert!((s.temperature(0, 101) - 4.0).abs() < 1e-12);
        assert!(s.temperature(100, 101).abs() < 1e-12);
        // Mid-point is the arithmetic mean for cosine.
        assert!((s.temperature(50, 101) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn table_lookup() {
        let s = Schedule::Table(vec![3.0, 2.0, 1.0]);
        assert_eq!(s.temperature(0, 3), 3.0);
        assert_eq!(s.temperature(2, 3), 1.0);
        // Resampled across more steps than entries.
        assert_eq!(s.temperature(5, 6), 1.0);
    }

    #[test]
    fn parse_roundtrip() {
        assert!(matches!(Schedule::parse("constant:2.5").unwrap(), Schedule::Constant(v) if v == 2.5));
        assert!(matches!(Schedule::parse("linear:5:0").unwrap(), Schedule::Linear { .. }));
        assert!(Schedule::parse("bogus:1").is_err());
        assert!(Schedule::parse("linear:5").is_err());
    }
}
