//! The Snowball engine (paper §IV): dual-mode MCMC spin selection,
//! asynchronous single-spin updates, PWL Glauber LUT and annealing
//! schedules.

pub mod diagnostics;
pub mod lut;
pub mod pool;
pub mod schedule;
pub mod select;
pub mod snowball;
pub mod tempering;

pub use lut::{glauber_exact, LaneCtx, PwlLogistic, ONE_Q16};
pub use pool::ReplicaPool;
pub use schedule::{Plateau, Plateaus, Schedule};
pub use select::{Fenwick, SelectorKind};
pub use snowball::{Datapath, EngineConfig, Mode, RunResult, SnowballEngine, StepOutcome};
pub use tempering::{ParallelTempering, TemperingResult};
