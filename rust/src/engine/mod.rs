//! The Snowball engine (paper §IV): dual-mode MCMC spin selection,
//! asynchronous single-spin updates, PWL Glauber LUT and annealing
//! schedules.
//!
//! Single-replica execution lives in [`SnowballEngine`];
//! multi-replica fan-out (blocking `run_indexed` or fire-and-forget
//! `spawn`, both deterministic by the stateless-RNG contract) goes
//! through [`pool::ReplicaPool`] — the layer the coordinator's
//! overlapping dispatcher saturates. Within-instance parallelism
//! (asynchronous sharded lanes with a deterministic virtual-time merge
//! mode) lives in [`shard::ShardedEngine`]. Both engines run their
//! per-step Mode II selection and flip application through the shared
//! [`lane::LaneKernel`] — the engine as one full-range kernel, each
//! shard lane as a range-restricted one. `docs/ARCHITECTURE.md` maps
//! the whole stack.

// `lut` and `shard` (for `shard::mailbox` / `shard::affinity`) are the
// engine's audited-unsafe subtrees and stay under the crate-level
// `deny`; every other submodule is re-escalated to `forbid`, which a
// file-local allow cannot override.
#[forbid(unsafe_code)]
pub mod diagnostics;
#[forbid(unsafe_code)]
pub mod lane;
pub mod lut;
#[forbid(unsafe_code)]
pub mod pool;
#[forbid(unsafe_code)]
pub mod schedule;
#[forbid(unsafe_code)]
pub mod select;
pub mod shard;
#[forbid(unsafe_code)]
pub mod snowball;
#[forbid(unsafe_code)]
pub mod tempering;

pub use lane::LaneKernel;
pub use lut::{glauber_exact, LaneCtx, PwlLogistic, ONE_Q16};
pub use pool::ReplicaPool;
pub use schedule::{Plateau, Plateaus, Schedule};
pub use select::{Fenwick, SelectorKind};
pub use shard::{MergeMode, ParallelismPlan, ShardStats, ShardedEngine};
pub use snowball::{
    Datapath, EngineCheckpoint, EngineConfig, Mode, RunResult, SnowballEngine, StepOutcome,
};
pub use tempering::{ParallelTempering, TemperingResult};
