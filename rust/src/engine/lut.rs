//! Piecewise-linear LUT approximation of the Glauber flip probability
//! (paper §IV-B3a, Eqs. 21/25).
//!
//! The hardware replaces `P_flip = 1/(1 + exp(ΔE/T))` with a fixed-point
//! piecewise-linear lookup: `z = ΔE/T` is clamped to a finite domain,
//! quantized to a segment index, and linearly interpolated between table
//! entries stored in Q16. This module is the bit-level model of that
//! block: probabilities are `u32` values in `[0, 2^16]` and the
//! accept/roulette logic consumes them as integers, exactly as the FPGA
//! comparator tree does. The same segment table is exported to the JAX/
//! Pallas side (see `python/compile/kernels/pwl.py`) so L1/L2/L3 share
//! numerics.

// AUDITED UNSAFE ALLOWLIST MEMBER (see docs/ARCHITECTURE.md
// § Concurrency correctness): the only unsafe here is the AVX2 lane
// kernel — `#[target_feature]` dispatch (feature presence verified at
// runtime before every call) and bounds-checked-by-construction SIMD
// loads/stores. Every unsafe operation carries a `SAFETY:` comment
// (enforced by `cargo run -p xtask -- lint-safety`), and the kernel is
// pinned bit-identical to the safe scalar path by
// `simd_lane_kernel_matches_scalar`.
#![allow(unsafe_code)]

/// Fixed-point scale of stored probabilities: Q16, so 65536 == 1.0.
pub const ONE_Q16: u32 = 1 << 16;

/// Exact Glauber flip probability `1/(1 + e^z)` (reference / Fig. 3).
#[inline(always)]
pub fn glauber_exact(z: f64) -> f64 {
    1.0 / (1.0 + z.exp())
}

/// Per-temperature context for bulk/incremental lane evaluation: the
/// hoisted reciprocal temperature plus the integer-domain saturation
/// window and endpoint values. Build once per plateau via
/// [`PwlLogistic::lane_ctx`]; consumed by [`PwlLogistic::eval_lanes`]
/// (full refresh) and [`PwlLogistic::lane_p`] (single-lane refresh).
#[derive(Clone, Copy, Debug)]
pub struct LaneCtx {
    /// The temperature the context was built for.
    pub temp: f64,
    /// `1/temp` (0 when `temp <= 0`; that path never multiplies).
    pub inv_t: f64,
    /// ΔE at or above which the output is exactly `p_tail`.
    pub de_hi: i64,
    /// ΔE at or below which the output is exactly `p_head`.
    pub de_lo: i64,
    /// Saturated head value (`eval(−∞)` ≈ 1 in Q16).
    pub p_head: u32,
    /// Saturated tail value (`eval(+∞)` ≈ 0 in Q16).
    pub p_tail: u32,
}

/// Piecewise-linear logistic table.
///
/// `segments` uniform pieces over `z ∈ [−z_max, z_max]`; outside the
/// domain the probability saturates to the endpoint values (≈1 and ≈0 for
/// `z_max ≥ 16`, indistinguishable at Q16 resolution).
#[derive(Clone, Debug)]
pub struct PwlLogistic {
    z_max: f64,
    inv_step: f64,
    /// Q16 endpoint values, length `segments + 1`.
    table: Vec<u32>,
    /// Precomputed f64 endpoints, padded with one duplicated tail entry
    /// (`table_f64[segments+1] == table_f64[segments]`) so the hot-path
    /// interpolation is branchless: `pos` clamps to `[0, segments]` and
    /// `idx + 1` never reads out of bounds.
    table_f64: Vec<f64>,
    /// `z` beyond which the output is exactly the tail value (flat run).
    sat_hi_z: f64,
    /// `z` below which the output is exactly the head value (flat run).
    sat_lo_z: f64,
}

impl Default for PwlLogistic {
    /// The configuration used throughout the reproduction: 256 segments
    /// over [−16, 16] — 1 BRAM's worth of table on the FPGA, max absolute
    /// error ≈ 2e-4 (verified by `max_error_is_small`).
    fn default() -> Self {
        Self::new(256, 16.0)
    }
}

impl PwlLogistic {
    /// Build a table with `segments` uniform pieces over `[-z_max, z_max]`.
    pub fn new(segments: usize, z_max: f64) -> Self {
        assert!(segments >= 2 && z_max > 0.0);
        let step = 2.0 * z_max / segments as f64;
        let table: Vec<u32> = (0..=segments)
            .map(|i| {
                let z = -z_max + i as f64 * step;
                (glauber_exact(z) * ONE_Q16 as f64).round() as u32
            })
            .collect();
        let mut table_f64: Vec<f64> = table.iter().map(|&v| v as f64).collect();
        table_f64.push(table_f64[segments]); // pad for branchless idx+1
        // Flat-saturation boundaries: the first index from which every
        // entry equals the tail value, and the last index up to which
        // every entry equals the head value. Within those runs the lerp
        // is exactly the endpoint, so evaluation can be skipped.
        let tail = table[segments];
        let mut hi_start = segments;
        while hi_start > 0 && table[hi_start - 1] == tail {
            hi_start -= 1;
        }
        let head = table[0];
        let mut lo_end = 0;
        while lo_end < segments && table[lo_end + 1] == head {
            lo_end += 1;
        }
        let sat_hi_z = -z_max + hi_start as f64 * step;
        let sat_lo_z = -z_max + lo_end as f64 * step;
        Self { z_max, inv_step: 1.0 / step, table, table_f64, sat_hi_z, sat_lo_z }
    }

    /// Smallest `z` from which `eval_q16(z) == tail value` exactly.
    pub fn sat_hi_z(&self) -> f64 {
        self.sat_hi_z
    }

    /// Largest `z` up to which `eval_q16(z) == head value` exactly.
    pub fn sat_lo_z(&self) -> f64 {
        self.sat_lo_z
    }

    /// Head/tail saturated values (`eval(−∞)`, `eval(+∞)`).
    pub fn sat_values(&self) -> (u32, u32) {
        (self.table[0], self.table[self.table.len() - 1])
    }

    /// Number of linear segments.
    pub fn segments(&self) -> usize {
        self.table.len() - 1
    }

    /// Domain half-width.
    pub fn z_max(&self) -> f64 {
        self.z_max
    }

    /// The raw Q16 endpoint table (exported to the python side).
    pub fn table_q16(&self) -> &[u32] {
        &self.table
    }

    /// Evaluate the PWL approximation at `z`, returning Q16 in [0, 2^16].
    ///
    /// Branchless hot path: the position clamps into `[0, segments]`
    /// (saturating the endpoint values exactly, since the padded table
    /// duplicates the tail) and both endpoint loads come from the
    /// precomputed f64 table. The JAX model computes the identical f64
    /// sequence (`python/compile/kernels/pwl.py::eval_q16`).
    #[inline(always)]
    pub fn eval_q16(&self, z: f64) -> u32 {
        // Saturation early-outs first: in a cold chain most lanes sit far
        // outside the domain (p ≈ 0 or 1), so these two compares skip the
        // whole interpolation for the common case (measured 2× on the
        // K2000 roulette loop). The clamped/lerped interior value is
        // IDENTICAL to what the early-outs return at the boundaries, so
        // the branch-free JAX mirror stays bit-equal.
        if z <= -self.z_max {
            return ONE_Q16.min(self.table[0]);
        }
        let segs = self.table.len() - 1;
        if z >= self.z_max {
            return self.table[segs];
        }
        let pos = ((z + self.z_max) * self.inv_step).clamp(0.0, segs as f64);
        let idx = pos as usize; // floor; pos in [0, segs]
        let frac = pos - idx as f64;
        let a = self.table_f64[idx];
        let b = self.table_f64[idx + 1];
        (a + (b - a) * frac) as u32
    }

    /// Flip probability for an energy change `ΔE` at temperature `T`
    /// (Q16). `T <= 0` degenerates to the zero-temperature rule:
    /// accept iff ΔE < 0, coin-flip at ΔE == 0 (paper Fig. 3 limits).
    ///
    /// Perf note: `z = ΔE · (1/T)` (reciprocal multiply), not `ΔE / T` —
    /// the engine hot loop hoists the reciprocal via
    /// [`Self::flip_prob_q16_inv`]. The JAX model computes the identical
    /// `1/T`-then-multiply sequence so f64 results stay bit-equal.
    #[inline(always)]
    pub fn flip_prob_q16(&self, delta_e: i64, t: f64) -> u32 {
        if t <= 0.0 {
            return match delta_e.cmp(&0) {
                std::cmp::Ordering::Less => ONE_Q16,
                std::cmp::Ordering::Equal => ONE_Q16 / 2,
                std::cmp::Ordering::Greater => 0,
            };
        }
        self.eval_q16(delta_e as f64 * (1.0 / t))
    }

    /// Hot-loop variant with the reciprocal temperature precomputed
    /// (caller guarantees `inv_t = 1/T` for some `T > 0`).
    #[inline(always)]
    pub fn flip_prob_q16_inv(&self, delta_e: i64, inv_t: f64) -> u32 {
        self.eval_q16(delta_e as f64 * inv_t)
    }

    /// Convenience f64 view of the approximation.
    pub fn eval(&self, z: f64) -> f64 {
        self.eval_q16(z) as f64 / ONE_Q16 as f64
    }

    /// Build the per-temperature lane-evaluation context: hoisted
    /// reciprocal plus the integer saturation window. `de_hi`/`de_lo` are
    /// the |ΔE| bounds beyond which the lerp equals the endpoint exactly
    /// (+1 slack absorbs reciprocal rounding; an over-estimate only sends
    /// a lane down the slow path, never to a wrong value), so the
    /// classification below is bit-identical to full evaluation.
    pub fn lane_ctx(&self, temp: f64) -> LaneCtx {
        let (p_head, p_tail) = self.sat_values();
        if temp > 0.0 {
            LaneCtx {
                temp,
                inv_t: 1.0 / temp,
                de_hi: (self.sat_hi_z * temp).ceil() as i64 + 1,
                de_lo: (self.sat_lo_z * temp).floor() as i64 - 1,
                p_head,
                p_tail,
            }
        } else {
            // T <= 0 degenerates to the sign rule (Fig. 3 limits); the
            // thresholds are never consulted on that path.
            LaneCtx { temp, inv_t: 0.0, de_hi: i64::MAX, de_lo: i64::MIN, p_head, p_tail }
        }
    }

    /// One lane of the Mode II evaluation: flip probability (Q16) of a
    /// spin with packed bit `bit` (0 ⇒ −1, 1 ⇒ +1) and local field `u_i`.
    /// Bit-identical to the corresponding [`Self::eval_lanes`] output —
    /// this is the single-lane refresh the incremental Fenwick path uses.
    #[inline(always)]
    pub fn lane_p(&self, ctx: &LaneCtx, bit: u64, u_i: i64) -> u32 {
        let s = (2 * bit as i64) - 1;
        let de = 2 * s * u_i;
        if ctx.temp > 0.0 {
            if de >= ctx.de_hi {
                ctx.p_tail
            } else if de <= ctx.de_lo {
                ctx.p_head
            } else {
                self.flip_prob_q16_inv(de, ctx.inv_t)
            }
        } else {
            self.flip_prob_q16(de, ctx.temp)
        }
    }

    /// Bulk lane evaluation — the software analogue of the FPGA's
    /// `eval_lanes` datapath. Fills `out[i]` with the Q16 flip
    /// probability of every spin and returns the aggregate weight `W`.
    ///
    /// Lanes are processed in 64-wide blocks over the packed spin words:
    /// ΔE for a whole block is computed branch-free (the loop
    /// auto-vectorizes), then the saturation classification picks the
    /// endpoint value or falls through to the PWL interpolation. With the
    /// `simd` cargo feature on x86-64 the block pass runs through an AVX2
    /// kernel (runtime-detected); the scalar fallback is bit-identical.
    pub fn eval_lanes(&self, ctx: &LaneCtx, u: &[i64], spin_words: &[u64], out: &mut [u32]) -> u64 {
        let n = u.len();
        assert_eq!(out.len(), n);
        assert!(spin_words.len() >= n.div_ceil(64));
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            if ctx.temp > 0.0 && is_x86_feature_detected!("avx2") {
                // SAFETY: AVX2 presence verified at runtime.
                return unsafe { self.eval_lanes_avx2(ctx, u, spin_words, out) };
            }
        }
        self.eval_lanes_scalar(ctx, u, spin_words, out)
    }

    fn eval_lanes_scalar(
        &self,
        ctx: &LaneCtx,
        u: &[i64],
        spin_words: &[u64],
        out: &mut [u32],
    ) -> u64 {
        let n = u.len();
        let mut w_total = 0u64;
        let mut de_buf = [0i64; 64];
        for (w, &word) in spin_words.iter().enumerate() {
            let base = w << 6;
            if base >= n {
                break;
            }
            let len = (n - base).min(64);
            let ub = &u[base..base + len];
            // ΔE_i = 2 s_i u_i for the whole block, branch-free.
            for (k, de) in de_buf[..len].iter_mut().enumerate() {
                let s = (((word >> k) & 1) as i64) * 2 - 1;
                *de = 2 * s * ub[k];
            }
            let ob = &mut out[base..base + len];
            if ctx.temp > 0.0 {
                for (k, o) in ob.iter_mut().enumerate() {
                    let de = de_buf[k];
                    let p = if de >= ctx.de_hi {
                        ctx.p_tail
                    } else if de <= ctx.de_lo {
                        ctx.p_head
                    } else {
                        self.flip_prob_q16_inv(de, ctx.inv_t)
                    };
                    *o = p;
                    w_total += p as u64;
                }
            } else {
                for (k, o) in ob.iter_mut().enumerate() {
                    let p = self.flip_prob_q16(de_buf[k], ctx.temp);
                    *o = p;
                    w_total += p as u64;
                }
            }
        }
        w_total
    }

    /// AVX2 block kernel: ΔE and the saturation classification for four
    /// i64 lanes per iteration; only unclassified (interior) lanes fall
    /// through to the scalar PWL interpolation. Bit-identical to
    /// [`Self::eval_lanes_scalar`] (same comparisons, same endpoint
    /// values, same interior evaluation).
    ///
    /// # Safety
    ///
    /// The caller must verify the CPU supports AVX2 (e.g. via
    /// `is_x86_feature_detected!("avx2")`) before calling; executing
    /// the 256-bit instructions on a CPU without them is undefined
    /// behaviour.
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[target_feature(enable = "avx2")]
    unsafe fn eval_lanes_avx2(
        &self,
        ctx: &LaneCtx,
        u: &[i64],
        spin_words: &[u64],
        out: &mut [u32],
    ) -> u64 {
        use std::arch::x86_64::*;
        debug_assert!(ctx.temp > 0.0);
        let n = u.len();
        let mut w_total = 0u64;
        let mut i = 0usize;
        // SAFETY: the fn-level contract guarantees AVX2 is present, so
        // every intrinsic is executable. The only memory operations
        // are the unaligned load from `u[i..i + 4]` — in bounds
        // because the loop condition holds `i + 4 <= n == u.len()` —
        // and the unaligned store into the local `de_arr: [i64; 4]`,
        // whose size matches the 256-bit register exactly.
        unsafe {
            let zero = _mm256_setzero_si256();
            // `cmpgt` is strict: de >= hi ⇔ de > hi−1, de <= lo ⇔ lo+1 > de.
            let hi_m1 = _mm256_set1_epi64x(ctx.de_hi - 1);
            let lo_p1 = _mm256_set1_epi64x(ctx.de_lo + 1);
            while i + 4 <= n {
                // i is a multiple of 4, so the four lanes share one spin word.
                let word = spin_words[i >> 6];
                let k = i & 63;
                let bitsel = _mm256_set_epi64x(
                    (1u64 << (k + 3)) as i64,
                    (1u64 << (k + 2)) as i64,
                    (1u64 << (k + 1)) as i64,
                    (1u64 << k) as i64,
                );
                let wv = _mm256_set1_epi64x(word as i64);
                let up = _mm256_cmpeq_epi64(_mm256_and_si256(wv, bitsel), bitsel);
                let uv = _mm256_loadu_si256(u.as_ptr().add(i) as *const __m256i);
                // s·u: u where the spin bit is set, −u otherwise.
                let su = _mm256_blendv_epi8(_mm256_sub_epi64(zero, uv), uv, up);
                let de = _mm256_add_epi64(su, su); // 2·s·u
                let hi = _mm256_cmpgt_epi64(de, hi_m1);
                let lo = _mm256_cmpgt_epi64(lo_p1, de);
                let hi_bits = _mm256_movemask_pd(_mm256_castsi256_pd(hi)) as u32;
                let lo_bits = _mm256_movemask_pd(_mm256_castsi256_pd(lo)) as u32;
                let mut de_arr = [0i64; 4];
                _mm256_storeu_si256(de_arr.as_mut_ptr() as *mut __m256i, de);
                for lane in 0..4 {
                    let p = if hi_bits & (1 << lane) != 0 {
                        ctx.p_tail
                    } else if lo_bits & (1 << lane) != 0 {
                        ctx.p_head
                    } else {
                        self.flip_prob_q16_inv(de_arr[lane], ctx.inv_t)
                    };
                    out[i + lane] = p;
                    w_total += p as u64;
                }
                i += 4;
            }
        }
        while i < n {
            let bit = (spin_words[i >> 6] >> (i & 63)) & 1;
            let p = self.lane_p(ctx, bit, u[i]);
            out[i] = p;
            w_total += p as u64;
            i += 1;
        }
        w_total
    }

    /// Maximum absolute error against the exact logistic, sampled at
    /// `samples` points (used by tests and the perf notes in DESIGN.md).
    pub fn max_error(&self, samples: usize) -> f64 {
        (0..samples)
            .map(|i| {
                let z = -self.z_max + 2.0 * self.z_max * i as f64 / (samples - 1) as f64;
                (self.eval(z) - glauber_exact(z)).abs()
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_and_midpoint() {
        let l = PwlLogistic::default();
        // z = 0 → exactly 1/2.
        assert_eq!(l.eval_q16(0.0), ONE_Q16 / 2);
        // Deep negative → ~1, deep positive → ~0.
        assert_eq!(l.eval_q16(-100.0), ONE_Q16);
        assert_eq!(l.eval_q16(100.0), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "100k-sample sweep is too slow under the interpreter")]
    fn max_error_is_small() {
        let l = PwlLogistic::default();
        let err = l.max_error(100_000);
        assert!(err < 5e-4, "PWL max error {err} too large");
        // Finer table → smaller error (monotone refinement sanity).
        let l2 = PwlLogistic::new(1024, 16.0);
        assert!(l2.max_error(100_000) < err);
    }

    #[test]
    fn monotone_decreasing_in_z() {
        let l = PwlLogistic::default();
        let mut prev = u32::MAX;
        for i in 0..1000 {
            let z = -20.0 + 40.0 * i as f64 / 999.0;
            let v = l.eval_q16(z);
            assert!(v <= prev, "PWL must be non-increasing");
            prev = v;
        }
    }

    #[test]
    fn zero_temperature_limits_match_fig3() {
        let l = PwlLogistic::default();
        assert_eq!(l.flip_prob_q16(-5, 0.0), ONE_Q16);
        assert_eq!(l.flip_prob_q16(0, 0.0), ONE_Q16 / 2);
        assert_eq!(l.flip_prob_q16(5, 0.0), 0);
    }

    #[test]
    fn high_temperature_approaches_half() {
        let l = PwlLogistic::default();
        let p = l.flip_prob_q16(10, 1e9);
        assert!((p as i64 - (ONE_Q16 / 2) as i64).abs() <= 2);
    }

    /// Cross-language golden pins — the same table lives in
    /// `python/tests/test_pwl_parity.py::GOLDEN`.
    #[test]
    fn cross_language_golden_values() {
        let l = PwlLogistic::default();
        for (de, t, expect) in [
            (2i64, 1.0, 7812u32),
            (-2, 1.0, 57724),
            (3, 0.7, 891),
            (0, 5.0, 32768),
            (40, 1.0, 0),
            (-40, 1.0, 65536),
            (1, 0.05, 0),
            (-1, 0.05, 65536),
            (0, 0.0, 32768),
            (-5, 0.0, 65536),
            (5, 0.0, 0),
        ] {
            assert_eq!(l.flip_prob_q16(de, t), expect, "ΔE={de}, T={t}");
        }
    }

    /// The chunked lane kernel must be bit-identical to the naive
    /// per-lane reference (`flip_prob_q16` over ΔE = 2 s u), across warm,
    /// cold and zero temperatures and non-multiple-of-64 lane counts.
    #[test]
    fn eval_lanes_matches_per_lane_reference() {
        use crate::ising::SpinVec;
        use crate::rng::{salt, StatelessRng};
        let l = PwlLogistic::default();
        let rng = StatelessRng::new(77);
        for n in [1usize, 3, 63, 64, 65, 130, 300] {
            let spins = SpinVec::random(n, &rng.child(n as u64));
            let u: Vec<i64> = (0..n)
                .map(|i| rng.below(1, i as u64, salt::PROBLEM, 41) as i64 - 20)
                .collect();
            for temp in [0.0, 0.05, 0.7, 1.0, 5.0, 1e6] {
                let ctx = l.lane_ctx(temp);
                let mut out = vec![0u32; n];
                let w = l.eval_lanes(&ctx, &u, spins.words(), &mut out);
                let mut w_ref = 0u64;
                for i in 0..n {
                    let de = 2 * spins.get(i) as i64 * u[i];
                    let p = l.flip_prob_q16(de, temp);
                    assert_eq!(out[i], p, "lane {i}, n={n}, T={temp}");
                    // The single-lane refresh path must agree too.
                    assert_eq!(l.lane_p(&ctx, spins.bit(i), u[i]), p);
                    w_ref += p as u64;
                }
                assert_eq!(w, w_ref, "aggregate weight, n={n}, T={temp}");
            }
        }
    }

    /// With the `simd` feature on, the AVX2 kernel (when the CPU has it)
    /// must agree with the scalar kernel bit for bit.
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[test]
    fn simd_lane_kernel_matches_scalar() {
        use crate::ising::SpinVec;
        use crate::rng::{salt, StatelessRng};
        if !is_x86_feature_detected!("avx2") {
            return;
        }
        let l = PwlLogistic::default();
        let rng = StatelessRng::new(78);
        for n in [4usize, 67, 256, 1000] {
            let spins = SpinVec::random(n, &rng.child(n as u64));
            let u: Vec<i64> = (0..n)
                .map(|i| rng.below(2, i as u64, salt::PROBLEM, 2001) as i64 - 1000)
                .collect();
            for temp in [0.05, 1.0, 50.0] {
                let ctx = l.lane_ctx(temp);
                let mut scalar = vec![0u32; n];
                let ws = l.eval_lanes_scalar(&ctx, &u, spins.words(), &mut scalar);
                let mut simd = vec![0u32; n];
                // SAFETY: AVX2 presence verified by the
                // `is_x86_feature_detected!` guard at the top of the test.
                let wv = unsafe { l.eval_lanes_avx2(&ctx, &u, spins.words(), &mut simd) };
                assert_eq!(scalar, simd, "n={n}, T={temp}");
                assert_eq!(ws, wv);
            }
        }
    }

    #[test]
    fn glauber_detailed_balance_identity() {
        // P(z) / P(-z) == e^{-z}: the identity behind Eq. (8). Check the
        // exact function, and that the PWL honours it to table precision.
        for &z in &[0.5f64, 1.0, 2.0, 4.0] {
            let ratio = glauber_exact(z) / glauber_exact(-z);
            assert!((ratio - (-z).exp()).abs() < 1e-12);
            let l = PwlLogistic::default();
            let approx = l.eval(z) / l.eval(-z);
            assert!((approx - (-z).exp()).abs() < 2e-3, "z={z}: {approx}");
        }
    }
}
