//! Piecewise-linear LUT approximation of the Glauber flip probability
//! (paper §IV-B3a, Eqs. 21/25).
//!
//! The hardware replaces `P_flip = 1/(1 + exp(ΔE/T))` with a fixed-point
//! piecewise-linear lookup: `z = ΔE/T` is clamped to a finite domain,
//! quantized to a segment index, and linearly interpolated between table
//! entries stored in Q16. This module is the bit-level model of that
//! block: probabilities are `u32` values in `[0, 2^16]` and the
//! accept/roulette logic consumes them as integers, exactly as the FPGA
//! comparator tree does. The same segment table is exported to the JAX/
//! Pallas side (see `python/compile/kernels/pwl.py`) so L1/L2/L3 share
//! numerics.

/// Fixed-point scale of stored probabilities: Q16, so 65536 == 1.0.
pub const ONE_Q16: u32 = 1 << 16;

/// Exact Glauber flip probability `1/(1 + e^z)` (reference / Fig. 3).
#[inline(always)]
pub fn glauber_exact(z: f64) -> f64 {
    1.0 / (1.0 + z.exp())
}

/// Piecewise-linear logistic table.
///
/// `segments` uniform pieces over `z ∈ [−z_max, z_max]`; outside the
/// domain the probability saturates to the endpoint values (≈1 and ≈0 for
/// `z_max ≥ 16`, indistinguishable at Q16 resolution).
#[derive(Clone, Debug)]
pub struct PwlLogistic {
    z_max: f64,
    inv_step: f64,
    /// Q16 endpoint values, length `segments + 1`.
    table: Vec<u32>,
    /// Precomputed f64 endpoints, padded with one duplicated tail entry
    /// (`table_f64[segments+1] == table_f64[segments]`) so the hot-path
    /// interpolation is branchless: `pos` clamps to `[0, segments]` and
    /// `idx + 1` never reads out of bounds.
    table_f64: Vec<f64>,
    /// `z` beyond which the output is exactly the tail value (flat run).
    sat_hi_z: f64,
    /// `z` below which the output is exactly the head value (flat run).
    sat_lo_z: f64,
}

impl Default for PwlLogistic {
    /// The configuration used throughout the reproduction: 256 segments
    /// over [−16, 16] — 1 BRAM's worth of table on the FPGA, max absolute
    /// error ≈ 2e-4 (verified by `max_error_is_small`).
    fn default() -> Self {
        Self::new(256, 16.0)
    }
}

impl PwlLogistic {
    /// Build a table with `segments` uniform pieces over `[-z_max, z_max]`.
    pub fn new(segments: usize, z_max: f64) -> Self {
        assert!(segments >= 2 && z_max > 0.0);
        let step = 2.0 * z_max / segments as f64;
        let table: Vec<u32> = (0..=segments)
            .map(|i| {
                let z = -z_max + i as f64 * step;
                (glauber_exact(z) * ONE_Q16 as f64).round() as u32
            })
            .collect();
        let mut table_f64: Vec<f64> = table.iter().map(|&v| v as f64).collect();
        table_f64.push(table_f64[segments]); // pad for branchless idx+1
        // Flat-saturation boundaries: the first index from which every
        // entry equals the tail value, and the last index up to which
        // every entry equals the head value. Within those runs the lerp
        // is exactly the endpoint, so evaluation can be skipped.
        let tail = table[segments];
        let mut hi_start = segments;
        while hi_start > 0 && table[hi_start - 1] == tail {
            hi_start -= 1;
        }
        let head = table[0];
        let mut lo_end = 0;
        while lo_end < segments && table[lo_end + 1] == head {
            lo_end += 1;
        }
        let sat_hi_z = -z_max + hi_start as f64 * step;
        let sat_lo_z = -z_max + lo_end as f64 * step;
        Self { z_max, inv_step: 1.0 / step, table, table_f64, sat_hi_z, sat_lo_z }
    }

    /// Smallest `z` from which `eval_q16(z) == tail value` exactly.
    pub fn sat_hi_z(&self) -> f64 {
        self.sat_hi_z
    }

    /// Largest `z` up to which `eval_q16(z) == head value` exactly.
    pub fn sat_lo_z(&self) -> f64 {
        self.sat_lo_z
    }

    /// Head/tail saturated values (`eval(−∞)`, `eval(+∞)`).
    pub fn sat_values(&self) -> (u32, u32) {
        (self.table[0], self.table[self.table.len() - 1])
    }

    /// Number of linear segments.
    pub fn segments(&self) -> usize {
        self.table.len() - 1
    }

    /// Domain half-width.
    pub fn z_max(&self) -> f64 {
        self.z_max
    }

    /// The raw Q16 endpoint table (exported to the python side).
    pub fn table_q16(&self) -> &[u32] {
        &self.table
    }

    /// Evaluate the PWL approximation at `z`, returning Q16 in [0, 2^16].
    ///
    /// Branchless hot path: the position clamps into `[0, segments]`
    /// (saturating the endpoint values exactly, since the padded table
    /// duplicates the tail) and both endpoint loads come from the
    /// precomputed f64 table. The JAX model computes the identical f64
    /// sequence (`python/compile/kernels/pwl.py::eval_q16`).
    #[inline(always)]
    pub fn eval_q16(&self, z: f64) -> u32 {
        // Saturation early-outs first: in a cold chain most lanes sit far
        // outside the domain (p ≈ 0 or 1), so these two compares skip the
        // whole interpolation for the common case (measured 2× on the
        // K2000 roulette loop). The clamped/lerped interior value is
        // IDENTICAL to what the early-outs return at the boundaries, so
        // the branch-free JAX mirror stays bit-equal.
        if z <= -self.z_max {
            return ONE_Q16.min(self.table[0]);
        }
        let segs = self.table.len() - 1;
        if z >= self.z_max {
            return self.table[segs];
        }
        let pos = ((z + self.z_max) * self.inv_step).clamp(0.0, segs as f64);
        let idx = pos as usize; // floor; pos in [0, segs]
        let frac = pos - idx as f64;
        let a = self.table_f64[idx];
        let b = self.table_f64[idx + 1];
        (a + (b - a) * frac) as u32
    }

    /// Flip probability for an energy change `ΔE` at temperature `T`
    /// (Q16). `T <= 0` degenerates to the zero-temperature rule:
    /// accept iff ΔE < 0, coin-flip at ΔE == 0 (paper Fig. 3 limits).
    ///
    /// Perf note: `z = ΔE · (1/T)` (reciprocal multiply), not `ΔE / T` —
    /// the engine hot loop hoists the reciprocal via
    /// [`Self::flip_prob_q16_inv`]. The JAX model computes the identical
    /// `1/T`-then-multiply sequence so f64 results stay bit-equal.
    #[inline(always)]
    pub fn flip_prob_q16(&self, delta_e: i64, t: f64) -> u32 {
        if t <= 0.0 {
            return match delta_e.cmp(&0) {
                std::cmp::Ordering::Less => ONE_Q16,
                std::cmp::Ordering::Equal => ONE_Q16 / 2,
                std::cmp::Ordering::Greater => 0,
            };
        }
        self.eval_q16(delta_e as f64 * (1.0 / t))
    }

    /// Hot-loop variant with the reciprocal temperature precomputed
    /// (caller guarantees `inv_t = 1/T` for some `T > 0`).
    #[inline(always)]
    pub fn flip_prob_q16_inv(&self, delta_e: i64, inv_t: f64) -> u32 {
        self.eval_q16(delta_e as f64 * inv_t)
    }

    /// Convenience f64 view of the approximation.
    pub fn eval(&self, z: f64) -> f64 {
        self.eval_q16(z) as f64 / ONE_Q16 as f64
    }

    /// Maximum absolute error against the exact logistic, sampled at
    /// `samples` points (used by tests and the perf notes in DESIGN.md).
    pub fn max_error(&self, samples: usize) -> f64 {
        (0..samples)
            .map(|i| {
                let z = -self.z_max + 2.0 * self.z_max * i as f64 / (samples - 1) as f64;
                (self.eval(z) - glauber_exact(z)).abs()
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_and_midpoint() {
        let l = PwlLogistic::default();
        // z = 0 → exactly 1/2.
        assert_eq!(l.eval_q16(0.0), ONE_Q16 / 2);
        // Deep negative → ~1, deep positive → ~0.
        assert_eq!(l.eval_q16(-100.0), ONE_Q16);
        assert_eq!(l.eval_q16(100.0), 0);
    }

    #[test]
    fn max_error_is_small() {
        let l = PwlLogistic::default();
        let err = l.max_error(100_000);
        assert!(err < 5e-4, "PWL max error {err} too large");
        // Finer table → smaller error (monotone refinement sanity).
        let l2 = PwlLogistic::new(1024, 16.0);
        assert!(l2.max_error(100_000) < err);
    }

    #[test]
    fn monotone_decreasing_in_z() {
        let l = PwlLogistic::default();
        let mut prev = u32::MAX;
        for i in 0..1000 {
            let z = -20.0 + 40.0 * i as f64 / 999.0;
            let v = l.eval_q16(z);
            assert!(v <= prev, "PWL must be non-increasing");
            prev = v;
        }
    }

    #[test]
    fn zero_temperature_limits_match_fig3() {
        let l = PwlLogistic::default();
        assert_eq!(l.flip_prob_q16(-5, 0.0), ONE_Q16);
        assert_eq!(l.flip_prob_q16(0, 0.0), ONE_Q16 / 2);
        assert_eq!(l.flip_prob_q16(5, 0.0), 0);
    }

    #[test]
    fn high_temperature_approaches_half() {
        let l = PwlLogistic::default();
        let p = l.flip_prob_q16(10, 1e9);
        assert!((p as i64 - (ONE_Q16 / 2) as i64).abs() <= 2);
    }

    /// Cross-language golden pins — the same table lives in
    /// `python/tests/test_pwl_parity.py::GOLDEN`.
    #[test]
    fn cross_language_golden_values() {
        let l = PwlLogistic::default();
        for (de, t, expect) in [
            (2i64, 1.0, 7812u32),
            (-2, 1.0, 57724),
            (3, 0.7, 891),
            (0, 5.0, 32768),
            (40, 1.0, 0),
            (-40, 1.0, 65536),
            (1, 0.05, 0),
            (-1, 0.05, 65536),
            (0, 0.0, 32768),
            (-5, 0.0, 65536),
            (5, 0.0, 0),
        ] {
            assert_eq!(l.flip_prob_q16(de, t), expect, "ΔE={de}, T={t}");
        }
    }

    #[test]
    fn glauber_detailed_balance_identity() {
        // P(z) / P(-z) == e^{-z}: the identity behind Eq. (8). Check the
        // exact function, and that the PWL honours it to table precision.
        for &z in &[0.5f64, 1.0, 2.0, 4.0] {
            let ratio = glauber_exact(z) / glauber_exact(-z);
            assert!((ratio - (-z).exp()).abs() < 1e-12);
            let l = PwlLogistic::default();
            let approx = l.eval(z) / l.eval(-z);
            assert!((approx - (-z).exp()).abs() < 2e-3, "z={z}: {approx}");
        }
    }
}
