//! Markov-chain diagnostics: the machinery behind the paper's §III-B
//! convergence analysis (Eqs. 3–5) and §IV-A correctness proofs
//! (Eqs. 6–9), made executable.
//!
//! For small instances the full transition kernel over all 2^N
//! configurations can be built explicitly. That lets us *verify*, not
//! just assert:
//!
//! * the sequential random-scan kernel satisfies **detailed balance**
//!   wrt the Gibbs distribution (Eq. 9) and converges to it;
//! * the roulette-wheel kernel, though period-2 (no self-loops), keeps
//!   the Gibbs-weighted *time averages* correct (§IV-A2 ergodic-theorem
//!   argument) — its stationary distribution exists and is unique;
//! * the **naive synchronous all-spin** kernel (Eq. 4) *violates*
//!   detailed balance (Eq. 5) and exhibits period-2 oscillation — the
//!   §III-B failure mode that motivates Snowball's asynchronous updates.

use crate::engine::lut::glauber_exact;
use crate::ising::{IsingModel, SpinVec};

/// Dense distribution / kernel over all `2^n` configurations (n ≤ 14).
pub struct DenseKernel {
    pub n: usize,
    /// Row-stochastic transition matrix, `p[from][to]`.
    pub p: Vec<Vec<f64>>,
}

/// Configuration index → SpinVec.
pub fn config(n: usize, bits: usize) -> SpinVec {
    let mut s = SpinVec::all_down(n);
    for i in 0..n {
        if (bits >> i) & 1 == 1 {
            s.set(i, 1);
        }
    }
    s
}

/// The Gibbs distribution `π_T(s) ∝ exp(−H(s)/T)` (normalized).
pub fn gibbs(model: &IsingModel, t: f64) -> Vec<f64> {
    let n = model.len();
    let e = crate::problems::landscape::enumerate(model);
    let min = *e.iter().min().unwrap() as f64;
    let w: Vec<f64> = e.iter().map(|&v| (-((v as f64) - min) / t).exp()).collect();
    let z: f64 = w.iter().sum();
    let _ = n;
    w.into_iter().map(|v| v / z).collect()
}

/// Exact flip probability `1/(1+exp(ΔE/T))` (Eq. 2), f64.
fn p_flip(model: &IsingModel, s: &SpinVec, i: usize, t: f64) -> f64 {
    let de = IsingModel::delta_e(s.get(i), model.local_field(s, i));
    glauber_exact(de as f64 / t)
}

/// Sequential random-scan kernel `P_seq` (Eq. 6).
pub fn random_scan_kernel(model: &IsingModel, t: f64) -> DenseKernel {
    let n = model.len();
    assert!(n <= 14);
    let states = 1usize << n;
    let mut p = vec![vec![0.0; states]; states];
    for from in 0..states {
        let s = config(n, from);
        let mut stay = 1.0;
        for i in 0..n {
            let flip = p_flip(model, &s, i, t) / n as f64;
            p[from][from ^ (1 << i)] += flip;
            stay -= flip;
        }
        p[from][from] += stay;
    }
    DenseKernel { n, p }
}

/// Roulette-wheel kernel (Eq. 10): select one spin ∝ p_flip, flip it
/// deterministically (rejection-free, no self-loops when W > 0).
pub fn roulette_kernel(model: &IsingModel, t: f64) -> DenseKernel {
    let n = model.len();
    assert!(n <= 14);
    let states = 1usize << n;
    let mut p = vec![vec![0.0; states]; states];
    for from in 0..states {
        let s = config(n, from);
        let weights: Vec<f64> = (0..n).map(|i| p_flip(model, &s, i, t)).collect();
        let w: f64 = weights.iter().sum();
        if w <= 0.0 {
            p[from][from] = 1.0;
            continue;
        }
        for i in 0..n {
            p[from][from ^ (1 << i)] += weights[i] / w;
        }
    }
    DenseKernel { n, p }
}

/// Naive synchronous all-spin kernel (Eq. 4): every spin updates
/// independently from the CURRENT configuration.
pub fn synchronous_kernel(model: &IsingModel, t: f64) -> DenseKernel {
    let n = model.len();
    assert!(n <= 10, "synchronous kernel is 4^n-ish; keep n small");
    let states = 1usize << n;
    let mut p = vec![vec![0.0; states]; states];
    for from in 0..states {
        let s = config(n, from);
        let flip: Vec<f64> = (0..n).map(|i| p_flip(model, &s, i, t)).collect();
        for to in 0..states {
            let mut prob = 1.0;
            for i in 0..n {
                let flipped = ((from ^ to) >> i) & 1 == 1;
                prob *= if flipped { flip[i] } else { 1.0 - flip[i] };
            }
            p[from][to] = prob;
        }
    }
    DenseKernel { n, p }
}

impl DenseKernel {
    /// Max detailed-balance violation `|π_i P_ij − π_j P_ji|` (Eq. 3).
    pub fn detailed_balance_violation(&self, pi: &[f64]) -> f64 {
        let states = self.p.len();
        let mut worst = 0.0f64;
        for i in 0..states {
            for j in 0..states {
                worst = worst.max((pi[i] * self.p[i][j] - pi[j] * self.p[j][i]).abs());
            }
        }
        worst
    }

    /// Max global-balance violation `|Σ_i π_i P_ij − π_j|` (stationarity).
    pub fn stationarity_violation(&self, pi: &[f64]) -> f64 {
        let states = self.p.len();
        let mut worst = 0.0f64;
        for j in 0..states {
            let inflow: f64 = (0..states).map(|i| pi[i] * self.p[i][j]).sum();
            worst = worst.max((inflow - pi[j]).abs());
        }
        worst
    }

    /// Evolve a distribution one step: `μ' = μ P`.
    pub fn step_distribution(&self, mu: &[f64]) -> Vec<f64> {
        let states = self.p.len();
        let mut out = vec![0.0; states];
        for i in 0..states {
            if mu[i] == 0.0 {
                continue;
            }
            for j in 0..states {
                out[j] += mu[i] * self.p[i][j];
            }
        }
        out
    }

    /// Total-variation distance between distributions.
    pub fn tv(a: &[f64], b: &[f64]) -> f64 {
        0.5 * a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>()
    }

    /// Iterate from `mu0` and report TV distance to `pi` after `steps`.
    pub fn mixing_tv(&self, mu0: &[f64], pi: &[f64], steps: usize) -> f64 {
        let mut mu = mu0.to_vec();
        for _ in 0..steps {
            mu = self.step_distribution(&mu);
        }
        Self::tv(&mu, pi)
    }

    /// Period-2 oscillation amplitude: TV distance between the
    /// distributions at two successive (late) steps.
    pub fn oscillation(&self, mu0: &[f64], burn: usize) -> f64 {
        let mut mu = mu0.to_vec();
        for _ in 0..burn {
            mu = self.step_distribution(&mu);
        }
        let next = self.step_distribution(&mu);
        Self::tv(&mu, &next)
    }

    /// Stationary distribution by power iteration on `Pᵀ`.
    pub fn stationary(&self, iters: usize) -> Vec<f64> {
        let states = self.p.len();
        let mut mu = vec![1.0 / states as f64; states];
        for _ in 0..iters {
            mu = self.step_distribution(&mu);
            // Average successive iterates to kill period-2 components.
            let nx = self.step_distribution(&mu);
            for j in 0..states {
                mu[j] = 0.5 * (mu[j] + nx[j]);
            }
        }
        mu
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frustrated_model() -> IsingModel {
        let mut m = IsingModel::zeros(4);
        m.set_j(0, 1, 1);
        m.set_j(1, 2, -2);
        m.set_j(2, 3, 1);
        m.set_j(0, 3, 1);
        m.set_h(1, 1);
        m
    }

    #[test]
    fn random_scan_satisfies_detailed_balance() {
        let m = frustrated_model();
        let t = 1.7;
        let pi = gibbs(&m, t);
        let k = random_scan_kernel(&m, t);
        assert!(k.detailed_balance_violation(&pi) < 1e-12, "Eq. 9 must hold exactly");
        assert!(k.stationarity_violation(&pi) < 1e-12);
    }

    #[test]
    fn random_scan_mixes_to_gibbs() {
        let m = frustrated_model();
        let t = 1.5;
        let pi = gibbs(&m, t);
        let k = random_scan_kernel(&m, t);
        let mut mu0 = vec![0.0; 16];
        mu0[0] = 1.0; // worst-case start: point mass
        assert!(k.mixing_tv(&mu0, &pi, 400) < 1e-6, "chain failed to mix");
    }

    #[test]
    fn roulette_breaks_detailed_balance_but_keeps_unique_stationary() {
        let m = frustrated_model();
        let t = 1.2;
        let pi = gibbs(&m, t);
        let k = roulette_kernel(&m, t);
        // Rejection-free selection does NOT preserve π (it reweights by
        // total flip rate) — the paper leans on the ergodic theorem, not
        // on π-invariance, for Mode II.
        assert!(k.detailed_balance_violation(&pi) > 1e-4);
        // Unique stationary distribution exists (averaged power iteration
        // converges and is stationary under the 2-step chain).
        let st = k.stationary(4000);
        let two_step = k.step_distribution(&k.step_distribution(&st));
        assert!(DenseKernel::tv(&st, &two_step) < 1e-8, "no stationary behaviour found");
        // And it still concentrates on low-energy states at low T.
        let e = crate::problems::landscape::enumerate(&m);
        let best = e.iter().enumerate().min_by_key(|(_, &v)| v).unwrap().0;
        let mass_best = st[best];
        let mass_worst = st[e.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0];
        assert!(mass_best > mass_worst * 3.0, "stationary mass not energy-ordered");
    }

    #[test]
    fn synchronous_kernel_violates_detailed_balance_and_oscillates() {
        // Detailed-balance violation (Eq. 5) on an asymmetric instance
        // (a perfectly symmetric 2-spin ferromagnet can coincidentally
        // balance, so use the frustrated model for this half).
        let fm = frustrated_model();
        let tk = synchronous_kernel(&fm, 1.2);
        assert!(
            tk.detailed_balance_violation(&gibbs(&fm, 1.2)) > 1e-4,
            "Eq. 5: synchronous updates must violate detailed balance"
        );
        // The §III-B oscillation case: a 2-spin ferromagnet at low T under
        // naive all-spin synchronous updates flips both spins nearly
        // every step → period-2 distribution oscillation.
        let mut m = IsingModel::zeros(2);
        m.set_j(0, 1, 2);
        let t = 0.3;
        let k = synchronous_kernel(&m, t);
        // Start from one aligned state: the chain keeps swinging between
        // the two mixed/aligned patterns.
        let mut mu0 = vec![0.0; 4];
        mu0[0b01] = 1.0; // anti-aligned start amplifies the swing
        let osc_sync = k.oscillation(&mu0, 200);
        // The asynchronous (random-scan) kernel from the same start has
        // self-loops and settles smoothly.
        let osc_seq = random_scan_kernel(&m, t).oscillation(&mu0, 200);
        assert!(
            osc_sync > 10.0 * osc_seq.max(1e-12),
            "synchronous oscillation {osc_sync} not ≫ sequential {osc_seq}"
        );
    }

    #[test]
    fn kernels_are_row_stochastic() {
        let m = frustrated_model();
        for k in [random_scan_kernel(&m, 2.0), roulette_kernel(&m, 2.0)] {
            for row in &k.p {
                let s: f64 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-12);
                assert!(row.iter().all(|&v| v >= 0.0));
            }
        }
    }
}
