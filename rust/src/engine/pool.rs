//! Shared replica pool: fans *independent* chain computations across a
//! dedicated rayon thread pool.
//!
//! The paper's 8× TTS win comes from massively parallel lane evaluation
//! on the FPGA; the software analogue is replica-level parallelism, and
//! Snowball's stateless RNG (paper §IV-B3d) makes it trivial to do
//! **deterministically**: every replica's stream is a pure function of
//! `StatelessRng::child(index)`, so results are bit-identical for any
//! worker count or interleaving. Every multi-replica path in the repo —
//! [`crate::engine::tempering::ParallelTempering`], the coordinator's
//! [`crate::coordinator::ReplicaScheduler`], and the TTS harness
//! (`crate::harness::table3`) — fans out through this one abstraction.
//!
//! Determinism contract: the closures handed to [`ReplicaPool::run_indexed`]
//! / [`ReplicaPool::for_each_mut`] must be pure functions of their index
//! (plus the per-index state they own). The pool then guarantees results
//! in index order, independent of scheduling — asserted by the
//! `identical_for_any_worker_count` test below and the integration suite
//! (`rust/tests/pool_determinism.rs`).

use rayon::prelude::*;

/// A fixed-size worker pool for replica fan-out.
///
/// Owns a dedicated rayon [`rayon::ThreadPool`] rather than using the
/// global one, so worker counts are explicit (`1` forces serial
/// execution — the reference point for determinism tests) and nested
/// pools (coordinator jobs × replica bursts) never deadlock-share a
/// global injector.
pub struct ReplicaPool {
    pool: rayon::ThreadPool,
    workers: usize,
}

impl ReplicaPool {
    /// Build a pool with `workers` threads; `0` = one per available CPU.
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 { Self::auto_workers() } else { workers };
        let pool = rayon::ThreadPoolBuilder::new()
            .num_threads(workers)
            .thread_name(|i| format!("snowball-replica-{i}"))
            .build()
            .expect("building the replica thread pool cannot fail");
        Self { pool, workers }
    }

    /// The worker count `0` resolves to: one per available CPU.
    pub fn auto_workers() -> usize {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4)
    }

    /// Worker threads in this pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Evaluate `f(0), f(1), …, f(count-1)` across the pool and return the
    /// results **in index order**. Bit-identical to a serial loop for any
    /// worker count, provided `f` is a pure function of its index.
    pub fn run_indexed<T, F>(&self, count: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.pool.install(|| (0..count).into_par_iter().map(|i| f(i)).collect())
    }

    /// Enqueue one fire-and-forget work item on the pool and return
    /// immediately. This is the primitive behind the coordinator's
    /// *overlapping* dispatch: each replica of each job becomes one
    /// spawned item, so replicas of different jobs interleave on the
    /// same workers and the pool never idles between jobs.
    ///
    /// Determinism is unaffected: a spawned closure must still be a pure
    /// function of the state it captures (its job seed + replica index),
    /// and whoever assembles the results is responsible for ordering
    /// them by index, never by completion time.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'static,
    {
        self.pool.spawn(f);
    }

    /// Apply `f(index, &mut item)` to every element of `items` in
    /// parallel. Used for in-place replica bursts (parallel tempering)
    /// where each worker owns exactly one element — no element is ever
    /// visible to two workers, so the result is scheduling-independent.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        self.pool.install(|| {
            items.par_iter_mut().enumerate().for_each(|(i, item)| f(i, item));
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StatelessRng;

    #[test]
    fn results_arrive_in_index_order() {
        let pool = ReplicaPool::new(4);
        let out = pool.run_indexed(64, |i| i * i);
        let expect: Vec<usize> = (0..64).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn identical_for_any_worker_count() {
        // A stand-in for a replica computation: a chained stateless-RNG
        // walk keyed on the index.
        let work = |i: usize| -> u64 {
            let rng = StatelessRng::new(0xBEEF).child(i as u64);
            (0..500u64).fold(0u64, |acc, t| acc ^ rng.u64(1, t, 0))
        };
        let serial = ReplicaPool::new(1).run_indexed(16, work);
        let wide = ReplicaPool::new(7).run_indexed(16, work);
        assert_eq!(serial, wide, "pool results must not depend on worker count");
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        let pool = ReplicaPool::new(3);
        let mut items = vec![0u64; 40];
        pool.for_each_mut(&mut items, |i, v| *v += i as u64 + 1);
        let expect: Vec<u64> = (0..40).map(|i| i + 1).collect();
        assert_eq!(items, expect);
    }

    #[test]
    fn spawned_items_all_execute() {
        let pool = ReplicaPool::new(3);
        let (tx, rx) = std::sync::mpsc::channel::<usize>();
        for i in 0..32 {
            let tx = tx.clone();
            pool.spawn(move || {
                let _ = tx.send(i);
            });
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn zero_workers_means_auto() {
        let pool = ReplicaPool::new(0);
        assert_eq!(pool.workers(), ReplicaPool::auto_workers());
        assert!(pool.workers() >= 1);
    }
}
