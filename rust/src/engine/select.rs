//! Roulette-wheel spin selection structures (Mode II hot path).
//!
//! The FPGA selects the flipped spin with a comparator tree over the N
//! lane weights in Θ(log N) levels (paper §IV-B3c). The software
//! analogue here is a Fenwick (binary indexed) tree over the Q16 lane
//! weights: Θ(log N) sampled selection from the same `r` draw, Θ(log N)
//! single-lane weight updates, Θ(N) bulk rebuild. Selection is
//! **bit-identical** to a linear prefix scan over the same weights —
//! both return the unique `j` with `cum(j−1) <= r < cum(j)` — which is
//! what lets the engine switch between the legacy scan and the Fenwick
//! path without changing a single output bit (asserted by
//! `tests/select_parity.rs`).

/// Which Mode II selection implementation the engine runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectorKind {
    /// Legacy Θ(N) linear prefix scan with full lane re-evaluation every
    /// step (the pre-PR-2 behaviour; kept so benches can prove the win).
    LinearScan,
    /// Fenwick-tree selection with incremental dirty-lane refresh
    /// (Θ(deg + log N) per plateau-interior step).
    Fenwick,
}

impl SelectorKind {
    /// CLI names (`rwa-fenwick` vs the legacy scan).
    pub fn parse(s: &str) -> anyhow::Result<SelectorKind> {
        match s {
            "scan" | "linear" | "linear-scan" => Ok(SelectorKind::LinearScan),
            "fenwick" | "rwa-fenwick" | "tree" => Ok(SelectorKind::Fenwick),
            other => anyhow::bail!("unknown selector '{other}' (scan|fenwick)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SelectorKind::LinearScan => "scan",
            SelectorKind::Fenwick => "fenwick",
        }
    }
}

/// Fenwick (binary indexed) tree over `n` non-negative integer weights.
///
/// Stored 1-based: `tree[i]` holds the sum of weights `(i − lsb(i), i]`.
/// Node sums fit `u64` for any realistic instance (`N · 2^16 < 2^64`);
/// negative point deltas are applied with two's-complement wrapping adds,
/// which is exact because every true node sum stays non-negative.
///
/// The tree is range-parameterized by construction: `n` is whatever
/// lane count the caller owns, so a range-restricted lane kernel
/// (`engine::lane`, the sharded engine's per-shard instantiation)
/// builds a tree over its `N/S` *local* lanes and selects with
/// range-local draws — no global-index awareness needed here.
#[derive(Clone, Debug)]
pub struct Fenwick {
    n: usize,
    tree: Vec<u64>,
    total: u64,
}

impl Fenwick {
    /// An all-zero tree over `n` lanes.
    pub fn new(n: usize) -> Self {
        Self { n, tree: vec![0; n + 1], total: 0 }
    }

    /// Number of lanes.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the tree has no lanes.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Aggregate weight `W = Σ w_i` (maintained, Θ(1)).
    #[inline(always)]
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Θ(N) bulk rebuild from raw lane weights (plateau boundaries and
    /// the dense-row fast path).
    pub fn rebuild(&mut self, weights: &[u32]) {
        assert_eq!(weights.len(), self.n);
        self.tree.fill(0);
        let mut total = 0u64;
        for i in 1..=self.n {
            let w = weights[i - 1] as u64;
            total += w;
            self.tree[i] += w;
            let parent = i + (i & i.wrapping_neg());
            if parent <= self.n {
                let v = self.tree[i];
                self.tree[parent] += v;
            }
        }
        self.total = total;
    }

    /// Θ(log N) point update: `w_i += delta` (the caller guarantees the
    /// lane weight stays non-negative).
    #[inline]
    pub fn add(&mut self, i: usize, delta: i64) {
        debug_assert!(i < self.n);
        self.total = self.total.wrapping_add(delta as u64);
        let mut idx = i + 1;
        while idx <= self.n {
            self.tree[idx] = self.tree[idx].wrapping_add(delta as u64);
            idx += idx & idx.wrapping_neg();
        }
    }

    /// Sum of the first `i` lane weights, Θ(log N).
    pub fn prefix(&self, i: usize) -> u64 {
        let mut s = 0u64;
        let mut idx = i.min(self.n);
        while idx > 0 {
            s += self.tree[idx];
            idx &= idx - 1;
        }
        s
    }

    /// The unique 0-based lane `j` with `prefix(j) <= r < prefix(j+1)` —
    /// the same lane a linear scan (`first j with r < cumsum(0..=j)`)
    /// returns, in Θ(log N). Requires `r < total()` (and so a non-empty,
    /// non-degenerate tree); zero-weight lanes are never selected.
    #[inline]
    pub fn select(&self, r: u64) -> usize {
        debug_assert!(r < self.total, "select draw {r} out of range (W = {})", self.total);
        let mut pos = 0usize;
        let mut rem = r;
        let mut k = self.n.next_power_of_two();
        while k > 0 {
            let next = pos + k;
            if next <= self.n {
                let w = self.tree[next];
                if w <= rem {
                    rem -= w;
                    pos = next;
                }
            }
            k >>= 1;
        }
        debug_assert!(pos < self.n);
        pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{salt, StatelessRng};

    /// Reference: the engine's legacy linear prefix scan.
    fn linear_select(weights: &[u32], r: u64) -> usize {
        let mut acc = 0u64;
        for (i, &w) in weights.iter().enumerate() {
            acc += w as u64;
            if r < acc {
                return i;
            }
        }
        weights.len() - 1
    }

    fn random_weights(n: usize, seed: u64, max: u32) -> Vec<u32> {
        let rng = StatelessRng::new(seed);
        (0..n).map(|i| rng.below(0, i as u64, salt::PROBLEM, max + 1)).collect()
    }

    #[test]
    fn select_matches_linear_scan_exhaustively() {
        // Small enough to sweep EVERY draw value, with zero lanes mixed
        // in (head, tail, interior runs) to hit all boundary cases.
        for weights in [
            vec![5u32, 0, 3, 1, 0, 0, 2],
            vec![0, 0, 7],
            vec![4, 4, 4, 4],
            vec![1],
            vec![0, 1, 0, 1, 0],
        ] {
            let mut f = Fenwick::new(weights.len());
            f.rebuild(&weights);
            let total: u64 = weights.iter().map(|&w| w as u64).sum();
            assert_eq!(f.total(), total);
            for r in 0..total {
                assert_eq!(
                    f.select(r),
                    linear_select(&weights, r),
                    "weights {weights:?}, r = {r}"
                );
            }
        }
    }

    #[test]
    fn select_matches_linear_scan_randomized() {
        for seed in 0..5u64 {
            for n in [1usize, 2, 63, 64, 65, 200, 1000] {
                let weights = random_weights(n, seed * 1000 + n as u64, 1 << 16);
                let mut f = Fenwick::new(n);
                f.rebuild(&weights);
                let total = f.total();
                if total == 0 {
                    continue;
                }
                let rng = StatelessRng::new(seed);
                for trial in 0..200u64 {
                    let r = rng.u64(1, trial, salt::ROULETTE) % total;
                    assert_eq!(f.select(r), linear_select(&weights, r), "n={n} seed={seed}");
                }
                // Boundary draws.
                assert_eq!(f.select(0), linear_select(&weights, 0));
                assert_eq!(f.select(total - 1), linear_select(&weights, total - 1));
            }
        }
    }

    #[test]
    fn add_tracks_point_updates() {
        let mut weights = random_weights(300, 9, 1 << 16);
        let mut f = Fenwick::new(weights.len());
        f.rebuild(&weights);
        let rng = StatelessRng::new(10);
        for step in 0..500u64 {
            let i = rng.below(2, step, salt::SITE, 300) as usize;
            let new = rng.below(3, step, salt::PROBLEM, 1 << 16);
            let delta = new as i64 - weights[i] as i64;
            f.add(i, delta);
            weights[i] = new;
            if step % 100 == 99 {
                // Full agreement with a from-scratch rebuild.
                let mut fresh = Fenwick::new(weights.len());
                fresh.rebuild(&weights);
                assert_eq!(f.total(), fresh.total());
                for i in 0..=weights.len() {
                    assert_eq!(f.prefix(i), fresh.prefix(i), "prefix({i}) after {step} updates");
                }
            }
        }
        let total = f.total();
        let rng = StatelessRng::new(11);
        for trial in 0..200u64 {
            let r = rng.u64(4, trial, salt::ROULETTE) % total;
            assert_eq!(f.select(r), linear_select(&weights, r));
        }
    }

    #[test]
    fn prefix_sums() {
        let weights = [2u32, 0, 5, 1];
        let mut f = Fenwick::new(4);
        f.rebuild(&weights);
        assert_eq!(f.prefix(0), 0);
        assert_eq!(f.prefix(1), 2);
        assert_eq!(f.prefix(2), 2);
        assert_eq!(f.prefix(3), 7);
        assert_eq!(f.prefix(4), 8);
        assert_eq!(f.total(), 8);
    }

    #[test]
    fn selector_kind_parses() {
        assert_eq!(SelectorKind::parse("scan").unwrap(), SelectorKind::LinearScan);
        assert_eq!(SelectorKind::parse("fenwick").unwrap(), SelectorKind::Fenwick);
        assert_eq!(SelectorKind::parse("rwa-fenwick").unwrap(), SelectorKind::Fenwick);
        assert!(SelectorKind::parse("bogus").is_err());
    }
}
