//! Asynchronous sharded spin updates: within-instance parallelism
//! (paper §IV-B — the asynchronous update units — scaled from one MCMC
//! lane to `S` of them).
//!
//! The rest of the engine stack parallelizes at the **replica** level:
//! every individual chain is still one sequential loop, so a large-N
//! instance is bound by one core. This module partitions one instance's
//! spins into `S` contiguous, degree-balanced shards
//! ([`crate::ising::Partition`]) and runs a dual-mode MCMC lane per
//! shard, in one of two merge modes:
//!
//! * **[`MergeMode::VirtualTime`]** — deterministic reference: the
//!   shard lanes are interleaved in a fixed order on one thread, and
//!   every per-step quantity (lane weights, aggregate W, roulette
//!   draw, selected spin, field updates) is composed shard-by-shard so
//!   the run is **bit-identical** to the single-shard
//!   [`SnowballEngine`] with the same seed (pinned by
//!   `rust/tests/shard_parity.rs`). This is the testing/debugging mode
//!   and the semantic spec of the async mode.
//! * **[`MergeMode::Async`]** — the production mode: each shard lane
//!   runs on its own OS thread, updating its local spins immediately
//!   and exchanging flips with its peers through lock-free SPSC
//!   mailboxes ([`mailbox::MailboxGrid`]). Staleness is bounded by an
//!   epoch barrier every `window` local steps (and by the mailbox
//!   capacity itself), at which point the lanes also assemble an exact
//!   global energy sample — so best-energy tracking costs Θ(N) per
//!   epoch instead of Θ(N²). Results are *not* bit-reproducible across
//!   runs (thread interleaving is real nondeterminism); quality parity
//!   is what the tests assert.
//!
//! Shard lanes get dedicated OS threads rather than `ReplicaPool`
//! workers because they block on each other at epoch barriers: parking
//! a work-stealing rayon worker inside a barrier deadlocks the pool
//! whenever `S` exceeds the free worker count. Replica-level fan-out
//! (which never blocks) stays on the pool; the
//! [`plan_parallelism`] policy decides which level gets the machine.
//! With [`EngineConfig::pin_lanes`] each lane thread additionally pins
//! itself round-robin to a core ([`affinity`]), so long async runs keep
//! their partition rows and mailbox lines cache-local; with
//! [`EngineConfig::local_rows`] on top, each lane copies its own
//! coupling-row window on that pinned thread so first-touch page
//! placement makes the hot row walks NUMA-node-local ([`placement`]).
//!
//! Each lane's per-step selection/update state is a range-restricted
//! [`LaneKernel`] — the same kernel the single-lane engine runs — so
//! lanes honor [`EngineConfig::selector`] end to end: with the Fenwick
//! selector a local step costs `Θ(log(N/S) + deg)` (remote flips from
//! the mailboxes land in the kernel's dirty set via the per-shard
//! CSR / bit-plane row slices instead of forcing full recomputes), and
//! with the legacy scan it stays the `Θ(N/S)` bulk refresh.
//!
//! **Concurrency verification.** The lock-free core of this module —
//! the [`mailbox`] SPSC rings, the [`gate::SyncGate`] epoch barrier
//! and the per-lane energy partials — is built on [`crate::sync`] and
//! model-checked by loom (`rust/tests/loom_shard.rs`, run with
//! `RUSTFLAGS="--cfg loom" cargo test --features loom --test
//! loom_shard`); CI additionally runs the unit tests under Miri and
//! the async parity tests under ThreadSanitizer. See
//! `docs/ARCHITECTURE.md` § Concurrency correctness.
//!
//! [`SnowballEngine`]: super::SnowballEngine
//! [`LaneKernel`]: super::lane::LaneKernel

// `mailbox` and `affinity` are audited-unsafe allowlist members (see
// docs/ARCHITECTURE.md § Concurrency correctness); `gate` is pure safe
// code and stays forbidden like the rest of the crate.
pub mod affinity;
#[forbid(unsafe_code)]
pub mod gate;
pub mod mailbox;
#[forbid(unsafe_code)]
pub mod placement;

use self::gate::{GateAborted, SyncGate};
use self::mailbox::{Flip, MailboxGrid};
use super::lane::LaneKernel;
use super::lut::{PwlLogistic, ONE_Q16};
use super::snowball::{EngineConfig, Mode, RunResult, STOP_CHECK_STRIDE};
use crate::bitplane::BitPlanes;
use crate::ising::{Adjacency, IsingModel, Partition, SpinVec};
use crate::rng::{salt, StatelessRng};
use crate::stop::StopToken;
use crate::sync::atomic::{AtomicI64, Ordering};
use std::sync::Mutex;

/// Below this spin count sharding is never chosen automatically —
/// replica-level parallelism already saturates the machine and the
/// cross-shard exchange would be pure overhead.
pub const SHARD_AUTO_MIN_N: usize = 4096;
/// Auto-sharding keeps at least this many spins per lane.
pub const MIN_SPINS_PER_SHARD: usize = 512;
/// Hard cap on the shard count (also enforced at the protocol edge).
pub const MAX_SHARDS: usize = 64;
/// Default bounded-staleness window (local steps between epoch syncs).
pub const DEFAULT_WINDOW: u64 = 64;

/// How the shard lanes' updates are merged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeMode {
    /// Deterministic fixed-order interleave; bit-identical to the
    /// single-shard engine. Single-threaded — for testing.
    VirtualTime,
    /// One thread per shard, mailbox exchange, bounded staleness.
    Async,
}

impl MergeMode {
    /// CLI names.
    pub fn parse(s: &str) -> anyhow::Result<MergeMode> {
        match s {
            "virtual" | "virtual-time" | "merge" => Ok(MergeMode::VirtualTime),
            "async" => Ok(MergeMode::Async),
            other => anyhow::bail!("unknown merge mode '{other}' (async|virtual)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MergeMode::VirtualTime => "virtual",
            MergeMode::Async => "async",
        }
    }
}

/// How a worker budget should be split between replica-level and
/// shard-level parallelism (see [`plan_parallelism`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelismPlan {
    /// Units (replicas / tempering chains) to run concurrently.
    pub replica_workers: usize,
    /// Shards per unit (1 = no sharding).
    pub shards: usize,
}

/// Decide between replica-level and shard-level parallelism for `units`
/// independent chains over an `n`-spin instance on `machine_workers`
/// cores. The rule the whole stack shares ([`ReplicaScheduler`] for
/// auto-shard jobs, [`ParallelTempering::with_auto_parallelism`] for
/// tempering ladders):
///
/// * many units or a small instance → replica-level only (each unit is
///   cheap; sharding would add exchange overhead for nothing);
/// * few units over a big instance (`n ≥ SHARD_AUTO_MIN_N`) → give each
///   unit the spare cores as shard lanes, keeping at least
///   [`MIN_SPINS_PER_SHARD`] spins per lane.
///
/// [`ReplicaScheduler`]: crate::coordinator::ReplicaScheduler
/// [`ParallelTempering::with_auto_parallelism`]: crate::engine::ParallelTempering::with_auto_parallelism
pub fn plan_parallelism(n: usize, units: usize, machine_workers: usize) -> ParallelismPlan {
    let units = units.max(1);
    let machine = machine_workers.max(1);
    if n >= SHARD_AUTO_MIN_N && machine > units {
        let shards = (machine / units)
            .min(n / MIN_SPINS_PER_SHARD.max(1))
            .min(MAX_SHARDS)
            .max(1);
        ParallelismPlan { replica_workers: units, shards }
    } else {
        ParallelismPlan { replica_workers: units.min(machine), shards: 1 }
    }
}

/// Diagnostics of a sharded run.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Lanes the run actually used (after clamping).
    pub shards: usize,
    /// Largest staleness any lane observed: |consumer local step −
    /// producer local step at flip time|. Bounded by the window.
    pub max_lag: u64,
    /// Flips per lane (sums to the result's `flips`).
    pub per_shard_flips: Vec<u64>,
    /// Epoch synchronization points taken (global energy samples).
    pub sync_points: u64,
    /// Lanes whose thread was successfully pinned to a core
    /// ([`EngineConfig::pin_lanes`]; 0 when pinning is off, on
    /// non-Linux hosts, or in the single-threaded virtual-time mode).
    pub pinned_lanes: usize,
    /// Bytes of lane-local coupling rows materialized by
    /// [`EngineConfig::local_rows`] (first-touch NUMA placement, see
    /// [`placement`]), summed over lanes. 0 when the knob is off, in
    /// virtual-time mode, or on the bit-plane datapath.
    pub local_row_bytes: usize,
}

/// The sharded engine over one Ising instance.
///
/// Consumes the same [`EngineConfig`] as [`SnowballEngine`], honored
/// end to end: `shards` picks the lane count, [`MergeMode`] the
/// execution strategy, `selector` the per-lane Mode II implementation
/// (Fenwick = incremental `Θ(log(N/S) + deg)` local steps, scan = the
/// legacy `Θ(N/S)` bulk refresh — bit-identical outcomes either way),
/// `datapath` the field-update source shared by every lane (dense/CSR
/// rows or the bit-plane column store), and `pin_lanes` the per-thread
/// core affinity in async mode.
///
/// [`SnowballEngine`]: super::SnowballEngine
pub struct ShardedEngine<'m> {
    model: &'m IsingModel,
    cfg: EngineConfig,
    merge: MergeMode,
    window: u64,
    part: Partition,
}

impl<'m> ShardedEngine<'m> {
    /// Build a sharded engine; `cfg.shards` is clamped to
    /// `[1, min(N, MAX_SHARDS)]` and the partition is degree-balanced.
    pub fn new(model: &'m IsingModel, cfg: EngineConfig, merge: MergeMode) -> Self {
        let shards = cfg.shards.clamp(1, MAX_SHARDS).min(model.len().max(1));
        let part = Partition::by_degree(model, shards);
        Self { model, cfg, merge, window: DEFAULT_WINDOW, part }
    }

    /// Set the bounded-staleness window (local steps between epoch
    /// syncs; also sizes the mailboxes). Must be ≥ 1.
    pub fn with_window(mut self, window: u64) -> Self {
        assert!(window >= 1, "staleness window must be >= 1");
        self.window = window;
        self
    }

    /// The degree-balanced partition in use.
    pub fn partition(&self) -> &Partition {
        &self.part
    }

    /// Effective lane count.
    pub fn shards(&self) -> usize {
        self.part.shards()
    }

    /// Run to completion (see [`Self::run_with_stats`]).
    pub fn run(&mut self) -> RunResult {
        self.run_with_stats().0
    }

    /// Run to completion, returning the result plus shard diagnostics.
    pub fn run_with_stats(&mut self) -> (RunResult, ShardStats) {
        self.run_with_stop(&StopToken::new())
    }

    /// Run, honoring cooperative preemption: the virtual-time loop
    /// polls `stop` every [`STOP_CHECK_STRIDE`] steps; async lanes
    /// check it at each epoch boundary and propagate the cause to
    /// their siblings through [`SyncGate::stop`] — so a preempted
    /// sharded run returns its best incumbent as of the last sync
    /// point (`stopped = Some(cause)`) instead of wedging or vanishing.
    pub fn run_with_stop(&mut self, stop: &StopToken) -> (RunResult, ShardStats) {
        match self.merge {
            MergeMode::VirtualTime => self.run_virtual(stop),
            MergeMode::Async => self.run_async(stop),
        }
    }

    // ------------------------------------------------------------------
    // Virtual-time merge: deterministic fixed-order interleave.
    // ------------------------------------------------------------------

    /// One global MCMC chain over S range-restricted [`LaneKernel`]s,
    /// with every per-step quantity composed shard-by-shard in
    /// ascending shard order. Because the partition is contiguous,
    /// concatenating the kernels' lanes reproduces the global lane
    /// order; because `u64`/`i64` sums are exact, the kernels share the
    /// single-lane engine's refresh policy, and the stateless RNG is
    /// addressed by `(t, salt)` rather than call order, every draw,
    /// weight, selection and field update equals the single-shard
    /// engine's — byte for byte, for BOTH selectors and BOTH datapaths.
    ///
    /// [`LaneKernel`]: super::lane::LaneKernel
    fn run_virtual(&mut self, stop: &StopToken) -> (RunResult, ShardStats) {
        let start = std::time::Instant::now();
        let model = self.model;
        let n = model.len();
        let s_count = self.part.shards();
        let lut = PwlLogistic::default();
        let rng = StatelessRng::new(self.cfg.seed);
        let mut spins = SpinVec::random(n, &rng);
        let u = model.local_fields(&spins);
        let mut energy = model.energy(&spins);

        // The same field-update sources and incremental-selection gate
        // the single-lane engine derives from the config (one shared
        // derivation — `EngineConfig::field_sources`).
        let (adj, planes) = self.cfg.field_sources(model);
        let (adj, planes) = (adj.as_ref(), planes.as_ref());
        let incremental = self.cfg.incremental_selection();
        let mut kernels: Vec<LaneKernel> = self
            .part
            .ranges()
            .map(|r| LaneKernel::new(r, &spins, &u, &lut, incremental))
            .collect();

        let steps = self.cfg.steps;
        let mut best_energy = energy;
        let mut best_step = 0u64;
        let mut best_spins = spins.clone();
        let mut trace = Vec::new();
        let (mut flips, mut fallbacks, mut nulls) = (0u64, 0u64, 0u64);
        if self.cfg.trace_stride > 0 {
            trace.push((0, energy));
        }

        let uniformized = matches!(self.cfg.mode, Mode::RouletteUniformized);
        let mut w_shard = vec![0u64; s_count];
        let mut executed = 0u64;
        let mut stopped = None;
        for t in 0..steps {
            if t % STOP_CHECK_STRIDE == 0 {
                if let Some(cause) = stop.get() {
                    stopped = Some(cause);
                    break;
                }
            }
            let temp = self.cfg.schedule.temperature(t, steps);
            match self.cfg.mode {
                Mode::RandomScan => {
                    if let Some(de) = virtual_random_scan(
                        &mut kernels,
                        &self.part,
                        model,
                        adj,
                        planes,
                        &mut spins,
                        &lut,
                        &rng,
                        t,
                        temp,
                    ) {
                        energy += de;
                        flips += 1;
                    }
                }
                Mode::RouletteWheel | Mode::RouletteUniformized => {
                    // Per-shard kernel sync in shard order; W_s are
                    // summed exactly as `eval_lanes` sums lane weights
                    // (u64 adds are exact, so any grouping agrees).
                    let mut w_total = 0u64;
                    for (s, k) in kernels.iter_mut().enumerate() {
                        let w_s = k.sync_weights(&lut, temp);
                        w_shard[s] = w_s;
                        w_total += w_s;
                    }
                    if w_total == 0 {
                        // Degenerate weight → Mode I fallback, exactly
                        // like the engine (fallback bookkeeping too).
                        fallbacks += 1;
                        if let Some(de) = virtual_random_scan(
                            &mut kernels,
                            &self.part,
                            model,
                            adj,
                            planes,
                            &mut spins,
                            &lut,
                            &rng,
                            t,
                            temp,
                        ) {
                            energy += de;
                            flips += 1;
                        }
                    } else {
                        let w_star = (n as u64) * ONE_Q16 as u64;
                        let domain = if uniformized { w_star } else { w_total };
                        let raw = rng.u64(t, 0, salt::ROULETTE);
                        let r = ((raw as u128 * domain as u128) >> 64) as u64;
                        if uniformized && r >= w_total {
                            nulls += 1;
                        } else {
                            // Locate the owning shard by weight prefix,
                            // then the lane inside it — the same unique
                            // j the global prefix scan (or tree
                            // descent) finds.
                            let mut cum = 0u64;
                            let mut chosen = n - 1;
                            for (s, &w_s) in w_shard.iter().enumerate() {
                                if r < cum + w_s {
                                    chosen =
                                        self.part.range(s).start + kernels[s].select_local(r - cum);
                                    break;
                                }
                                cum += w_s;
                            }
                            let de = flip_across_lanes(
                                &mut kernels,
                                &self.part,
                                model,
                                adj,
                                planes,
                                &mut spins,
                                chosen,
                            );
                            energy += de;
                            flips += 1;
                        }
                    }
                }
            }
            if energy < best_energy {
                best_energy = energy;
                best_step = t + 1;
                best_spins.assign_from(&spins);
            }
            if self.cfg.trace_stride > 0 && (t + 1) % self.cfg.trace_stride == 0 {
                trace.push((t + 1, energy));
            }
            executed = t + 1;
        }
        let result = RunResult {
            best_energy,
            best_step,
            best_spins,
            final_energy: energy,
            final_spins: spins,
            trace,
            steps: executed,
            flips,
            fallbacks,
            nulls,
            wall: start.elapsed(),
            stopped,
        };
        let stats = ShardStats {
            shards: s_count,
            max_lag: 0,
            per_shard_flips: vec![0; s_count], // interleaved, not per-lane
            sync_points: 0,
            pinned_lanes: 0,
            local_row_bytes: 0,
        };
        (result, stats)
    }

    // ------------------------------------------------------------------
    // Async merge: one thread per shard, mailboxes, epoch barriers.
    // ------------------------------------------------------------------

    fn run_async(&mut self, stop: &StopToken) -> (RunResult, ShardStats) {
        let start = std::time::Instant::now();
        let model = self.model;
        let n = model.len();
        let s_count = self.part.shards();
        let window = self.window;
        // `cfg.steps` is the TOTAL step budget across lanes (comparable
        // work to a single-shard run of the same step count); each lane
        // runs the same local count so epoch barriers line up.
        let steps_local = self.cfg.steps.div_ceil(s_count as u64);
        let total_steps = steps_local * s_count as u64;

        // Initial global configuration: same derivation as the engine.
        let rng = StatelessRng::new(self.cfg.seed);
        let init_spins = SpinVec::random(n, &rng);
        let init_u = model.local_fields(&init_spins);
        let init_energy = model.energy(&init_spins);

        let mut result = RunResult {
            best_energy: init_energy,
            best_step: 0,
            best_spins: init_spins.clone(),
            final_energy: init_energy,
            final_spins: init_spins.clone(),
            trace: if self.cfg.trace_stride > 0 { vec![(0, init_energy)] } else { Vec::new() },
            steps: total_steps,
            flips: 0,
            fallbacks: 0,
            nulls: 0,
            wall: std::time::Duration::ZERO,
            stopped: None,
        };
        let mut stats = ShardStats {
            shards: s_count,
            max_lag: 0,
            per_shard_flips: vec![0; s_count],
            sync_points: 0,
            pinned_lanes: 0,
            local_row_bytes: 0,
        };
        if steps_local == 0 || n == 0 {
            result.wall = start.elapsed();
            return (result, stats);
        }

        // Shared field-update sources (the engine's datapath choice,
        // via the one shared `EngineConfig::field_sources` derivation):
        // CSR rows (sparse instances) / dense rows, or the bit-plane
        // column store — lanes slice either to their own range for
        // Θ(deg ∩ range) remote applies.
        let (adj, planes) = self.cfg.field_sources(model);
        let lut = PwlLogistic::default();
        let epochs = steps_local.div_ceil(window);
        // Ring capacity ≥ the flips a producer can emit between the
        // consumer's epoch drains (one per local step).
        let grid = MailboxGrid::new(s_count, window as usize + 2);
        let gate = SyncGate::new(s_count);
        let partials: Vec<AtomicI64> = (0..s_count).map(|_| AtomicI64::new(0)).collect();
        let snapshot = Mutex::new(init_spins.clone());
        let tracker = Mutex::new(EnergyTracker {
            best_energy: init_energy,
            best_step: 0,
            best_spins: init_spins.clone(),
            last_energy: init_energy,
            samples: Vec::new(),
        });

        let incremental = self.cfg.incremental_selection();
        let mut lanes: Vec<Lane> = self
            .part
            .ranges()
            .enumerate()
            .map(|(s, range)| Lane {
                index: s,
                kernel: LaneKernel::new(range, &init_spins, &init_u, &lut, incremental),
                rng: rng.child(s as u64),
                flips: 0,
                fallbacks: 0,
                nulls: 0,
                max_lag: 0,
                steps_done: 0,
                pinned: false,
                local_bytes: 0,
            })
            .collect();
        // Round-robin pin targets come from the kernel's OWN report of
        // allowed CPUs, not an assumed 0-based range — under a
        // restricted cpuset (containers, `taskset`) the allowed ids
        // may start anywhere. Empty (non-Linux, or getaffinity
        // failure) disables pinning.
        let pin_targets = if cfg!(target_os = "linux") && self.cfg.pin_lanes {
            affinity::allowed_cpus()
        } else {
            Vec::new()
        };

        // A panicking lane must fail the whole run, not wedge its
        // siblings at the gate: the panic payload is parked here, the
        // gate is aborted (waking everyone), and the payload re-raised
        // after the scope joins — so the replica-level `catch_unwind`
        // boundary in the scheduler sees an ordinary panic.
        let panic_slot: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
        let cfg = &self.cfg;
        let (model_ref, adj_ref, planes_ref) = (model, adj.as_ref(), planes.as_ref());
        let (lut_ref, pins_ref) = (&lut, &pin_targets);
        let (grid_ref, gate_ref, partials_ref) = (&grid, &gate, &partials);
        let (snapshot_ref, tracker_ref, panic_ref) = (&snapshot, &tracker, &panic_slot);
        let stop_ref = stop;
        std::thread::scope(|scope| {
            for lane in lanes.iter_mut() {
                scope.spawn(move || {
                    // Round-robin pinning over the allowed CPUs; a pin
                    // failure just leaves the lane floating (reported
                    // via ShardStats.pinned_lanes).
                    if let Some(&cpu) = pins_ref.get(lane.index % pins_ref.len().max(1)) {
                        lane.pinned = affinity::pin_current_thread(cpu);
                    }
                    // Materialize the lane's row window AFTER the pin,
                    // on this thread, so first-touch places the copy's
                    // pages on the lane's node (see `placement`). The
                    // bit-plane datapath keeps its shared column store.
                    if cfg.local_rows && planes_ref.is_none() {
                        lane.local_bytes =
                            lane.kernel.materialize_local_rows(model_ref, adj_ref);
                    }
                    let outcome =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            lane.run(
                                model_ref,
                                adj_ref,
                                planes_ref,
                                lut_ref,
                                cfg,
                                steps_local,
                                window,
                                s_count,
                                grid_ref,
                                gate_ref,
                                partials_ref,
                                snapshot_ref,
                                tracker_ref,
                                stop_ref,
                            );
                        }));
                    if let Err(payload) = outcome {
                        panic_ref.lock().unwrap().get_or_insert(payload);
                        gate_ref.abort();
                    }
                });
            }
        });
        if let Some(payload) = panic_slot.into_inner().unwrap() {
            std::panic::resume_unwind(payload);
        }

        let tracker = tracker.into_inner().unwrap();
        result.best_energy = tracker.best_energy;
        result.best_step = tracker.best_step;
        result.best_spins = tracker.best_spins;
        result.final_energy = tracker.last_energy;
        result.final_spins = snapshot.into_inner().unwrap();
        if self.cfg.trace_stride > 0 {
            result.trace.extend(tracker.samples);
        }
        result.steps = 0;
        for lane in &lanes {
            result.flips += lane.flips;
            result.fallbacks += lane.fallbacks;
            result.nulls += lane.nulls;
            result.steps += lane.steps_done;
            stats.per_shard_flips[lane.index] = lane.flips;
            stats.max_lag = stats.max_lag.max(lane.max_lag);
            stats.pinned_lanes += lane.pinned as usize;
            stats.local_row_bytes += lane.local_bytes;
        }
        result.stopped = gate.stop_cause();
        if result.stopped.is_some() {
            // Preempted mid-barrier: the spin snapshot may mix slices
            // published after the last leader pass with older ones, so
            // the tracked `last_energy` can describe a configuration
            // the snapshot no longer holds. One oracle evaluation (once
            // per preempted run) restores the final-state invariant;
            // best_* stays internally consistent by construction (the
            // leader copies energy and spins under one lock).
            result.final_energy = model.energy(&result.final_spins);
        }
        stats.sync_points = epochs;
        result.wall = start.elapsed();
        (result, stats)
    }
}

/// Best/final energy bookkeeping, written only by the barrier leader.
struct EnergyTracker {
    best_energy: i64,
    best_step: u64,
    best_spins: SpinVec,
    last_energy: i64,
    /// `(approx global step, exact energy)` per epoch sync.
    samples: Vec<(u64, i64)>,
}

/// One asynchronous shard lane: a range-restricted [`LaneKernel`]
/// (spins in `[lo, hi)`, their local fields — which include every
/// remote flip applied so far — lane weights and incremental selection
/// state) plus the lane's own stateless RNG stream and counters.
///
/// [`LaneKernel`]: super::lane::LaneKernel
struct Lane {
    index: usize,
    kernel: LaneKernel,
    rng: StatelessRng,
    flips: u64,
    fallbacks: u64,
    nulls: u64,
    max_lag: u64,
    /// Local steps completed, updated at each epoch boundary — summed
    /// across lanes into `RunResult.steps` so a preempted run reports
    /// how far it actually got.
    steps_done: u64,
    /// Whether this lane's thread was pinned to a core.
    pinned: bool,
    /// Resident bytes of the lane-local row copy (0 = not materialized).
    local_bytes: usize,
}

impl Lane {
    /// Apply a peer's flip to this lane's kernel: fold the coupling row
    /// restricted to the lane's range (CSR slice / bit-plane column
    /// slice / dense row segment) into the fields AND the kernel's
    /// dirty set — a mailbox message costs `Θ(deg ∩ range)` marks, not
    /// a lane-wide recompute.
    fn apply_remote(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        planes: Option<&BitPlanes>,
        flip: Flip,
    ) {
        self.kernel.apply_remote(model, adj, planes, flip.j as usize, flip.s_old);
    }

    /// Flip local spin `j_local` through the kernel (fields + dirty
    /// set, single source of truth) and broadcast the flip to peers.
    fn apply_local(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        planes: Option<&BitPlanes>,
        grid: &MailboxGrid,
        j_local: usize,
        step: u64,
    ) {
        let (j, s_old, _de) = self.kernel.flip_local(model, adj, planes, j_local);
        grid.post(self.index, Flip { j: j as u32, s_old, step });
        self.flips += 1;
    }

    /// One local MCMC step at temperature `temp` (dual-mode, mirroring
    /// the engine's step but over the lane's own kernel and RNG
    /// stream). With the Fenwick selector the kernel's `sync_weights`
    /// makes plateau-interior steps `Θ(dirty + log(N/S))`; the legacy
    /// scan selector re-evaluates the `Θ(N/S)` local lanes every step.
    #[allow(clippy::too_many_arguments)]
    fn step(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        planes: Option<&BitPlanes>,
        lut: &PwlLogistic,
        grid: &MailboxGrid,
        mode: Mode,
        k: u64,
        temp: f64,
    ) {
        let n_local = self.kernel.n_local();
        // `move` copies the (Copy) shared refs in, so `adj`/`planes`
        // keep their `Option<&_>` types inside the closure.
        let random_scan = move |lane: &mut Lane, is_fallback: bool| {
            let j = lane.rng.below(k, 0, salt::SITE, n_local as u32) as usize;
            let de = lane.kernel.delta_e(j);
            let p = lut.flip_prob_q16(de, temp);
            let r = lane.rng.u32(k, 0, salt::ACCEPT) >> 16;
            if r < p {
                lane.apply_local(model, adj, planes, grid, j, k);
            }
            if is_fallback {
                lane.fallbacks += 1;
            }
        };
        match mode {
            Mode::RandomScan => random_scan(self, false),
            Mode::RouletteWheel | Mode::RouletteUniformized => {
                let w_total = self.kernel.sync_weights(lut, temp);
                if w_total == 0 {
                    random_scan(self, true);
                    return;
                }
                let uniformized = mode == Mode::RouletteUniformized;
                let w_star = (n_local as u64) * ONE_Q16 as u64;
                let domain = if uniformized { w_star } else { w_total };
                let raw = self.rng.u64(k, 0, salt::ROULETTE);
                let r = ((raw as u128 * domain as u128) >> 64) as u64;
                if uniformized && r >= w_total {
                    self.nulls += 1;
                    return;
                }
                let chosen = self.kernel.select_local(r);
                self.apply_local(model, adj, planes, grid, chosen, k);
            }
        }
    }

    /// The lane's thread body: epochs of `window` local steps with
    /// opportunistic mailbox drains, then the three-phase sync —
    /// (A) quiesce, (B) drain + publish partial energy and the local
    /// spin slice, (C) leader records the exact global energy. Returns
    /// early (cleanly) if the gate aborts — a sibling lane panicked.
    #[allow(clippy::too_many_arguments)]
    fn run(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        planes: Option<&BitPlanes>,
        lut: &PwlLogistic,
        cfg: &EngineConfig,
        steps_local: u64,
        window: u64,
        s_count: usize,
        grid: &MailboxGrid,
        gate: &SyncGate,
        partials: &[AtomicI64],
        snapshot: &Mutex<SpinVec>,
        tracker: &Mutex<EnergyTracker>,
        stop: &StopToken,
    ) {
        let epochs = steps_local.div_ceil(window);
        for e in 0..epochs {
            // Preemption check once per epoch: whichever lane notices
            // first stops the gate with the cause, which releases (and
            // permanently fails) every sibling's next `wait` — all S
            // lanes unwind within one epoch.
            if let Some(cause) = stop.get() {
                gate.stop(cause);
                return;
            }
            let end = ((e + 1) * window).min(steps_local);
            for k in (e * window)..end {
                // Opportunistic drain keeps cross-shard fields as fresh
                // as the interleaving allows (staleness well under the
                // window in practice; the barrier only enforces the
                // bound).
                grid.drain(self.index, |f| {
                    let lag = (k as i64 - f.step as i64).unsigned_abs();
                    self.max_lag = self.max_lag.max(lag);
                    self.apply_remote(model, adj, planes, f);
                });
                let temp = cfg.schedule.temperature(k, steps_local);
                self.step(model, adj, planes, lut, grid, cfg.mode, k, temp);
            }
            self.steps_done = end;
            // Phase A: every lane has finished the epoch — no more
            // producers until phase C releases.
            if gate.wait().is_err() {
                return;
            }
            // Phase B prep: apply the stragglers, then publish this
            // lane's energy partial Σ sᵢ(uᵢ + hᵢ) and its spin slice.
            grid.drain(self.index, |f| {
                let lag = (end as i64 - f.step as i64).unsigned_abs();
                self.max_lag = self.max_lag.max(lag);
                self.apply_remote(model, adj, planes, f);
            });
            let lo = self.kernel.lo();
            let mut partial = 0i64;
            for i in 0..self.kernel.n_local() {
                let s = self.kernel.spin(i) as i64;
                partial += s * (self.kernel.field(i) + model.h(lo + i) as i64);
            }
            partials[self.index].store(partial, Ordering::Relaxed);
            {
                let mut snap = snapshot.lock().unwrap();
                for i in 0..self.kernel.n_local() {
                    snap.set(lo + i, self.kernel.spin(i));
                }
            }
            match gate.wait() {
                Err(GateAborted) => return,
                Ok(true) => {
                    // Leader: all partials and slices are published
                    // (the gate gives happens-before) —
                    // E = −(Σ sᵢuᵢ + Σ sᵢhᵢ)/2, exact.
                    let total: i64 = partials.iter().map(|p| p.load(Ordering::Relaxed)).sum();
                    let energy = -total / 2;
                    let global_step = end * s_count as u64;
                    let mut t = tracker.lock().unwrap();
                    t.last_energy = energy;
                    if cfg.trace_stride > 0 {
                        // Only consumed as the run's trace — don't
                        // accumulate unbounded samples with tracing off.
                        t.samples.push((global_step, energy));
                    }
                    if energy < t.best_energy {
                        t.best_energy = energy;
                        t.best_step = global_step;
                        let snap = snapshot.lock().unwrap();
                        t.best_spins.assign_from(&snap);
                    }
                }
                Ok(false) => {}
            }
            // Phase C: resume only after the leader finished reading.
            if gate.wait().is_err() {
                return;
            }
        }
    }
}

/// Mode I site draw + Glauber accept on the GLOBAL stream — the shared
/// helper of the virtual-time mode (both as Mode I proper and as the
/// Mode II fallback). Returns `Some(ΔE)` when a flip was accepted and
/// applied across the lanes. Byte-compatible with
/// `SnowballEngine::step_random_scan`: same draws, and the ΔE comes
/// from the owning kernel's fields exactly as the engine reads its own.
#[allow(clippy::too_many_arguments)]
fn virtual_random_scan(
    kernels: &mut [LaneKernel],
    part: &Partition,
    model: &IsingModel,
    adj: Option<&Adjacency>,
    planes: Option<&BitPlanes>,
    spins: &mut SpinVec,
    lut: &PwlLogistic,
    rng: &StatelessRng,
    t: u64,
    temp: f64,
) -> Option<i64> {
    let n = model.len() as u32;
    let j = rng.below(t, 0, salt::SITE, n) as usize;
    let owner = part.owner(j);
    let de = kernels[owner].delta_e(j - part.range(owner).start);
    let p = lut.flip_prob_q16(de, temp);
    let r = rng.u32(t, 0, salt::ACCEPT) >> 16;
    if r < p {
        let applied = flip_across_lanes(kernels, part, model, adj, planes, spins, j);
        debug_assert_eq!(applied, de);
        Some(de)
    } else {
        None
    }
}

/// Propagate a flip of global spin `j` into every lane kernel — the
/// owner through `flip_local` (which also returns ΔE from its own
/// fields), every peer through `apply_remote` — plus the global spin
/// mirror. Kernels walk their own row segment, so the total work is
/// the same i64 adds as the engine's single-lane flip, grouped by
/// shard; the kernels' dirty sets absorb the touched-lane reports.
fn flip_across_lanes(
    kernels: &mut [LaneKernel],
    part: &Partition,
    model: &IsingModel,
    adj: Option<&Adjacency>,
    planes: Option<&BitPlanes>,
    spins: &mut SpinVec,
    j: usize,
) -> i64 {
    let owner = part.owner(j);
    let s_old = spins.flip(j);
    let mut de = 0i64;
    for (s, kernel) in kernels.iter_mut().enumerate() {
        if s == owner {
            let (_, k_s_old, k_de) =
                kernel.flip_local(model, adj, planes, j - part.range(s).start);
            debug_assert_eq!(k_s_old, s_old);
            de = k_de;
        } else {
            kernel.apply_remote(model, adj, planes, j, s_old);
        }
    }
    de
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::engine::{Datapath, Schedule, SelectorKind, SnowballEngine};
    use crate::graph::generators;
    use crate::problems::MaxCut;

    fn cfg(mode: Mode, steps: u64, seed: u64, shards: usize) -> EngineConfig {
        EngineConfig {
            mode,
            datapath: Datapath::Dense,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.1 },
            steps,
            seed,
            planes: None,
            trace_stride: 0,
            shards,
            pin_lanes: false,
            local_rows: false,
        }
    }

    #[test]
    fn virtual_time_matches_engine_smoke() {
        // The in-module smoke of the tentpole guarantee; the full
        // mode × selector × seed × shard matrix lives in
        // rust/tests/shard_parity.rs.
        let rng = StatelessRng::new(41);
        let p = MaxCut::new(generators::erdos_renyi(72, 300, &[-1, 1], &rng));
        for mode in [Mode::RandomScan, Mode::RouletteWheel, Mode::RouletteUniformized] {
            let mut reference = SnowballEngine::new(p.model(), cfg(mode, 600, 9, 1));
            let want = reference.run();
            let mut sharded =
                ShardedEngine::new(p.model(), cfg(mode, 600, 9, 4), MergeMode::VirtualTime);
            let got = sharded.run();
            assert_eq!(got.best_energy, want.best_energy, "{mode:?}");
            assert_eq!(got.final_energy, want.final_energy, "{mode:?}");
            assert_eq!(got.final_spins, want.final_spins, "{mode:?}");
            assert_eq!(got.best_spins, want.best_spins, "{mode:?}");
            assert_eq!(
                (got.flips, got.fallbacks, got.nulls, got.best_step),
                (want.flips, want.fallbacks, want.nulls, want.best_step),
                "{mode:?}"
            );
        }
    }

    #[test]
    fn async_bookkeeping_is_exact_at_sync_points() {
        let rng = StatelessRng::new(42);
        let p = MaxCut::new(generators::erdos_renyi(192, 800, &[-1, 1], &rng));
        let mut e =
            ShardedEngine::new(p.model(), cfg(Mode::RouletteWheel, 8_000, 3, 4), MergeMode::Async)
                .with_window(16);
        let (r, stats) = e.run_with_stats();
        // The distributed energy bookkeeping must agree with the dense
        // oracle on the final configuration...
        assert_eq!(r.final_energy, p.model().energy(&r.final_spins), "final energy drifted");
        // ...and on the recorded best configuration.
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins), "best energy drifted");
        assert!(r.best_energy <= r.final_energy);
        assert_eq!(stats.shards, 4);
        assert_eq!(stats.per_shard_flips.iter().sum::<u64>(), r.flips);
        assert!(stats.max_lag <= 16, "staleness {} exceeded the window", stats.max_lag);
        assert_eq!(stats.sync_points, 8_000u64.div_ceil(4).div_ceil(16));
        assert!(r.flips > 0, "async lanes must make progress");
    }

    #[test]
    fn async_single_shard_and_zero_steps_degenerate_cleanly() {
        let rng = StatelessRng::new(43);
        let p = MaxCut::new(generators::erdos_renyi(48, 160, &[-1, 1], &rng));
        // S = 1: one lane, no peers, still correct.
        let mut one =
            ShardedEngine::new(p.model(), cfg(Mode::RouletteWheel, 500, 7, 1), MergeMode::Async);
        let r = one.run();
        assert_eq!(r.final_energy, p.model().energy(&r.final_spins));
        // steps = 0: initial configuration everywhere.
        let mut zero =
            ShardedEngine::new(p.model(), cfg(Mode::RouletteWheel, 0, 7, 3), MergeMode::Async);
        let r0 = zero.run();
        assert_eq!(r0.best_energy, p.model().energy(&r0.best_spins));
        assert_eq!(r0.flips, 0);
        assert_eq!(r0.steps, 0);
    }

    /// Cooperative preemption in both merge modes: a tripped
    /// [`StopToken`] turns the run into a well-formed partial result —
    /// `stopped` carries the cause, `steps` reports how far the run
    /// got, and the energies still match the dense oracle.
    #[test]
    fn stop_token_preempts_both_merge_modes() {
        use crate::stop::StopCause;
        let rng = StatelessRng::new(47);
        let p = MaxCut::new(generators::erdos_renyi(96, 380, &[-1, 1], &rng));

        // Pre-tripped: both modes must bail before doing any work.
        for merge in [MergeMode::VirtualTime, MergeMode::Async] {
            let stop = StopToken::new();
            stop.trip(StopCause::Cancel);
            let mut e = ShardedEngine::new(p.model(), cfg(Mode::RouletteWheel, 10_000, 5, 3), merge)
                .with_window(16);
            let (r, _) = e.run_with_stop(&stop);
            assert_eq!(r.stopped, Some(StopCause::Cancel), "{merge:?}");
            assert_eq!(r.steps, 0, "{merge:?}: no step may run after a pre-trip");
            assert_eq!(r.final_energy, p.model().energy(&r.final_spins), "{merge:?}");
            assert_eq!(r.best_energy, p.model().energy(&r.best_spins), "{merge:?}");
        }

        // Mid-run: trip from another thread; async lanes must propagate
        // the cause through the gate and all unwind within one epoch.
        let stop = std::sync::Arc::new(StopToken::new());
        let tripper = {
            let stop = stop.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(10));
                stop.trip(StopCause::Deadline);
            })
        };
        let mut e = ShardedEngine::new(
            p.model(),
            cfg(Mode::RouletteWheel, 400_000_000, 5, 3),
            MergeMode::Async,
        )
        .with_window(64);
        let (r, _) = e.run_with_stop(&stop);
        tripper.join().unwrap();
        assert_eq!(r.stopped, Some(StopCause::Deadline));
        assert!(r.steps < 400_000_000, "preempted run must stop early");
        assert_eq!(r.final_energy, p.model().energy(&r.final_spins));
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins));
    }

    /// Lanes honor `EngineConfig.selector`: both selectors make
    /// progress with exact bookkeeping, and in the deterministic
    /// virtual-time mode they are bit-identical to each other (the
    /// in-module smoke of the selector × shard matrix in
    /// rust/tests/shard_parity.rs).
    #[test]
    fn lanes_honor_the_selector_config() {
        let rng = StatelessRng::new(45);
        let p = MaxCut::new(generators::erdos_renyi(160, 640, &[-1, 1], &rng));
        let run_virtual = |selector: SelectorKind| {
            let mut c = cfg(Mode::RouletteWheel, 3_000, 5, 4);
            c.selector = selector;
            c.schedule = Schedule::Geometric { t0: 4.0, t1: 0.1 }.quantized(16);
            let r = ShardedEngine::new(p.model(), c, MergeMode::VirtualTime).run();
            (r.best_energy, r.final_energy, r.flips, r.fallbacks, r.nulls)
        };
        assert_eq!(
            run_virtual(SelectorKind::Fenwick),
            run_virtual(SelectorKind::LinearScan),
            "virtual-time selectors diverged"
        );
        for selector in [SelectorKind::Fenwick, SelectorKind::LinearScan] {
            let mut c = cfg(Mode::RouletteWheel, 4_000, 7, 3);
            c.selector = selector;
            let (r, _) = ShardedEngine::new(p.model(), c, MergeMode::Async)
                .with_window(16)
                .run_with_stats();
            assert_eq!(
                r.final_energy,
                p.model().energy(&r.final_spins),
                "{selector:?}: bookkeeping drifted"
            );
            assert!(r.flips > 0, "{selector:?}: async lanes made no progress");
        }
    }

    /// `pin_lanes` pins the async lane threads (round-robin) and
    /// reports the count; runs stay exact either way, and the
    /// single-threaded virtual mode reports zero.
    #[test]
    fn pin_lanes_is_plumbed_and_harmless() {
        let rng = StatelessRng::new(46);
        let p = MaxCut::new(generators::erdos_renyi(96, 380, &[-1, 1], &rng));
        let mut c = cfg(Mode::RouletteWheel, 2_000, 3, 3);
        c.pin_lanes = true;
        let (r, stats) = ShardedEngine::new(p.model(), c.clone(), MergeMode::Async)
            .with_window(16)
            .run_with_stats();
        assert_eq!(r.final_energy, p.model().energy(&r.final_spins));
        assert!(stats.pinned_lanes <= stats.shards);
        // Lanes pin round-robin over the kernel-reported allowed CPU
        // set; whenever that set is non-empty (any Linux host,
        // restricted cpuset or not), every lane's target is allowed
        // and all the pins must stick.
        if !affinity::allowed_cpus().is_empty() {
            assert_eq!(stats.pinned_lanes, stats.shards, "allowed CPUs but lanes unpinned");
        }
        let (_, vstats) =
            ShardedEngine::new(p.model(), c, MergeMode::VirtualTime).run_with_stats();
        assert_eq!(vstats.pinned_lanes, 0, "virtual mode runs unpinned on the caller");
    }

    /// `local_rows` materializes per-lane row copies (CSR and dense),
    /// reports their footprint, keeps runs exact, and stays inert in
    /// virtual-time mode and on the bit-plane datapath.
    #[test]
    fn local_rows_is_plumbed_and_harmless() {
        let rng = StatelessRng::new(48);
        // Sparse instance → CSR slabs; complete graph → dense slabs.
        let sparse = MaxCut::new(generators::erdos_renyi(96, 380, &[-1, 1], &rng));
        let dense = MaxCut::new(generators::complete(96, &[-1, 1], &rng));
        for p in [&sparse, &dense] {
            let mut c = cfg(Mode::RouletteWheel, 2_000, 3, 3);
            c.pin_lanes = true;
            c.local_rows = true;
            let (r, stats) = ShardedEngine::new(p.model(), c.clone(), MergeMode::Async)
                .with_window(16)
                .run_with_stats();
            assert_eq!(r.final_energy, p.model().energy(&r.final_spins));
            assert_eq!(r.best_energy, p.model().energy(&r.best_spins));
            assert!(stats.local_row_bytes > 0, "copies must be reported");
            // Virtual-time mode never materializes.
            let (_, vstats) =
                ShardedEngine::new(p.model(), c.clone(), MergeMode::VirtualTime).run_with_stats();
            assert_eq!(vstats.local_row_bytes, 0);
            // The bit-plane datapath keeps its shared column store.
            c.datapath = Datapath::BitPlane;
            let (rb, bstats) = ShardedEngine::new(p.model(), c, MergeMode::Async)
                .with_window(16)
                .run_with_stats();
            assert_eq!(rb.final_energy, p.model().energy(&rb.final_spins));
            assert_eq!(bstats.local_row_bytes, 0, "bit-plane runs must not copy rows");
        }
    }

    #[test]
    fn shard_count_clamps() {
        let rng = StatelessRng::new(44);
        let p = MaxCut::new(generators::erdos_renyi(10, 20, &[-1, 1], &rng));
        let e = ShardedEngine::new(p.model(), cfg(Mode::RandomScan, 10, 1, 500), MergeMode::Async);
        assert_eq!(e.shards(), 10, "shards clamp to N");
        let e = ShardedEngine::new(p.model(), cfg(Mode::RandomScan, 10, 1, 0), MergeMode::Async);
        assert_eq!(e.shards(), 1, "shards = 0 clamps to 1");
    }

    #[test]
    fn parallelism_plan_policy() {
        // Small instance: replica-level only, whatever the machine.
        assert_eq!(plan_parallelism(256, 8, 32), ParallelismPlan { replica_workers: 8, shards: 1 });
        // Big instance, many units: still replica-level (units fill the
        // machine).
        assert_eq!(
            plan_parallelism(8192, 16, 16),
            ParallelismPlan { replica_workers: 16, shards: 1 }
        );
        // Big instance, few units: spare cores become shard lanes.
        let p = plan_parallelism(8192, 2, 16);
        assert_eq!(p.replica_workers, 2);
        assert!(p.shards >= 2 && p.shards <= 8, "{p:?}");
        // Lane floor: never shard below MIN_SPINS_PER_SHARD spins/lane.
        let p = plan_parallelism(4096, 1, 64);
        assert!(p.shards <= 4096 / MIN_SPINS_PER_SHARD, "{p:?}");
        // Degenerate inputs.
        assert_eq!(plan_parallelism(0, 0, 0), ParallelismPlan { replica_workers: 1, shards: 1 });
    }

    #[test]
    fn merge_mode_parses() {
        assert_eq!(MergeMode::parse("async").unwrap(), MergeMode::Async);
        assert_eq!(MergeMode::parse("virtual").unwrap(), MergeMode::VirtualTime);
        assert_eq!(MergeMode::parse("virtual-time").unwrap(), MergeMode::VirtualTime);
        assert!(MergeMode::parse("bogus").is_err());
    }
}
