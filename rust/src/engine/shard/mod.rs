//! Asynchronous sharded spin updates: within-instance parallelism
//! (paper §IV-B — the asynchronous update units — scaled from one MCMC
//! lane to `S` of them).
//!
//! The rest of the engine stack parallelizes at the **replica** level:
//! every individual chain is still one sequential loop, so a large-N
//! instance is bound by one core. This module partitions one instance's
//! spins into `S` contiguous, degree-balanced shards
//! ([`crate::ising::Partition`]) and runs a dual-mode MCMC lane per
//! shard, in one of two merge modes:
//!
//! * **[`MergeMode::VirtualTime`]** — deterministic reference: the
//!   shard lanes are interleaved in a fixed order on one thread, and
//!   every per-step quantity (lane weights, aggregate W, roulette
//!   draw, selected spin, field updates) is composed shard-by-shard so
//!   the run is **bit-identical** to the single-shard
//!   [`SnowballEngine`] with the same seed (pinned by
//!   `rust/tests/shard_parity.rs`). This is the testing/debugging mode
//!   and the semantic spec of the async mode.
//! * **[`MergeMode::Async`]** — the production mode: each shard lane
//!   runs on its own OS thread, updating its local spins immediately
//!   and exchanging flips with its peers through lock-free SPSC
//!   mailboxes ([`mailbox::MailboxGrid`]). Staleness is bounded by an
//!   epoch barrier every `window` local steps (and by the mailbox
//!   capacity itself), at which point the lanes also assemble an exact
//!   global energy sample — so best-energy tracking costs Θ(N) per
//!   epoch instead of Θ(N²). Results are *not* bit-reproducible across
//!   runs (thread interleaving is real nondeterminism); quality parity
//!   is what the tests assert.
//!
//! Shard lanes get dedicated OS threads rather than `ReplicaPool`
//! workers because they block on each other at epoch barriers: parking
//! a work-stealing rayon worker inside a barrier deadlocks the pool
//! whenever `S` exceeds the free worker count. Replica-level fan-out
//! (which never blocks) stays on the pool; the
//! [`plan_parallelism`] policy decides which level gets the machine.
//!
//! [`SnowballEngine`]: super::SnowballEngine

pub mod mailbox;

use self::mailbox::{Flip, MailboxGrid};
use super::lut::{PwlLogistic, ONE_Q16};
use super::snowball::{EngineConfig, Mode, RunResult};
use crate::ising::{Adjacency, IsingModel, Partition, SpinVec};
use crate::rng::{salt, StatelessRng};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Condvar, Mutex};

/// Below this spin count sharding is never chosen automatically —
/// replica-level parallelism already saturates the machine and the
/// cross-shard exchange would be pure overhead.
pub const SHARD_AUTO_MIN_N: usize = 4096;
/// Auto-sharding keeps at least this many spins per lane.
pub const MIN_SPINS_PER_SHARD: usize = 512;
/// Hard cap on the shard count (also enforced at the protocol edge).
pub const MAX_SHARDS: usize = 64;
/// Default bounded-staleness window (local steps between epoch syncs).
pub const DEFAULT_WINDOW: u64 = 64;

/// How the shard lanes' updates are merged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeMode {
    /// Deterministic fixed-order interleave; bit-identical to the
    /// single-shard engine. Single-threaded — for testing.
    VirtualTime,
    /// One thread per shard, mailbox exchange, bounded staleness.
    Async,
}

impl MergeMode {
    /// CLI names.
    pub fn parse(s: &str) -> anyhow::Result<MergeMode> {
        match s {
            "virtual" | "virtual-time" | "merge" => Ok(MergeMode::VirtualTime),
            "async" => Ok(MergeMode::Async),
            other => anyhow::bail!("unknown merge mode '{other}' (async|virtual)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MergeMode::VirtualTime => "virtual",
            MergeMode::Async => "async",
        }
    }
}

/// How a worker budget should be split between replica-level and
/// shard-level parallelism (see [`plan_parallelism`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelismPlan {
    /// Units (replicas / tempering chains) to run concurrently.
    pub replica_workers: usize,
    /// Shards per unit (1 = no sharding).
    pub shards: usize,
}

/// Decide between replica-level and shard-level parallelism for `units`
/// independent chains over an `n`-spin instance on `machine_workers`
/// cores. The rule the whole stack shares ([`ReplicaScheduler`] for
/// auto-shard jobs, [`ParallelTempering::with_auto_parallelism`] for
/// tempering ladders):
///
/// * many units or a small instance → replica-level only (each unit is
///   cheap; sharding would add exchange overhead for nothing);
/// * few units over a big instance (`n ≥ SHARD_AUTO_MIN_N`) → give each
///   unit the spare cores as shard lanes, keeping at least
///   [`MIN_SPINS_PER_SHARD`] spins per lane.
///
/// [`ReplicaScheduler`]: crate::coordinator::ReplicaScheduler
/// [`ParallelTempering::with_auto_parallelism`]: crate::engine::ParallelTempering::with_auto_parallelism
pub fn plan_parallelism(n: usize, units: usize, machine_workers: usize) -> ParallelismPlan {
    let units = units.max(1);
    let machine = machine_workers.max(1);
    if n >= SHARD_AUTO_MIN_N && machine > units {
        let shards = (machine / units)
            .min(n / MIN_SPINS_PER_SHARD.max(1))
            .min(MAX_SHARDS)
            .max(1);
        ParallelismPlan { replica_workers: units, shards }
    } else {
        ParallelismPlan { replica_workers: units.min(machine), shards: 1 }
    }
}

/// Diagnostics of a sharded run.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Lanes the run actually used (after clamping).
    pub shards: usize,
    /// Largest staleness any lane observed: |consumer local step −
    /// producer local step at flip time|. Bounded by the window.
    pub max_lag: u64,
    /// Flips per lane (sums to the result's `flips`).
    pub per_shard_flips: Vec<u64>,
    /// Epoch synchronization points taken (global energy samples).
    pub sync_points: u64,
}

/// The sharded engine over one Ising instance.
///
/// Consumes the same [`EngineConfig`] as [`SnowballEngine`]; the
/// `shards` field picks the lane count and [`MergeMode`] picks the
/// execution strategy. `datapath` is ignored (shard lanes are a dense /
/// CSR datapath of their own); `selector` is ignored in the lanes (the
/// virtual-time mode matches *both* selectors, which are bit-identical
/// to each other by the PR-2 parity contract).
///
/// [`SnowballEngine`]: super::SnowballEngine
pub struct ShardedEngine<'m> {
    model: &'m IsingModel,
    cfg: EngineConfig,
    merge: MergeMode,
    window: u64,
    part: Partition,
}

impl<'m> ShardedEngine<'m> {
    /// Build a sharded engine; `cfg.shards` is clamped to
    /// `[1, min(N, MAX_SHARDS)]` and the partition is degree-balanced.
    pub fn new(model: &'m IsingModel, cfg: EngineConfig, merge: MergeMode) -> Self {
        let shards = cfg.shards.clamp(1, MAX_SHARDS).min(model.len().max(1));
        let part = Partition::by_degree(model, shards);
        Self { model, cfg, merge, window: DEFAULT_WINDOW, part }
    }

    /// Set the bounded-staleness window (local steps between epoch
    /// syncs; also sizes the mailboxes). Must be ≥ 1.
    pub fn with_window(mut self, window: u64) -> Self {
        assert!(window >= 1, "staleness window must be >= 1");
        self.window = window;
        self
    }

    /// The degree-balanced partition in use.
    pub fn partition(&self) -> &Partition {
        &self.part
    }

    /// Effective lane count.
    pub fn shards(&self) -> usize {
        self.part.shards()
    }

    /// Run to completion (see [`Self::run_with_stats`]).
    pub fn run(&mut self) -> RunResult {
        self.run_with_stats().0
    }

    /// Run to completion, returning the result plus shard diagnostics.
    pub fn run_with_stats(&mut self) -> (RunResult, ShardStats) {
        match self.merge {
            MergeMode::VirtualTime => self.run_virtual(),
            MergeMode::Async => self.run_async(),
        }
    }

    // ------------------------------------------------------------------
    // Virtual-time merge: deterministic fixed-order interleave.
    // ------------------------------------------------------------------

    /// One global MCMC chain, with every per-step quantity composed
    /// shard-by-shard in ascending shard order. Because the partition
    /// is contiguous, concatenating the shards' lanes reproduces the
    /// global lane order; because `u64`/`i64` sums are exact and the
    /// stateless RNG is addressed by `(t, salt)` rather than call
    /// order, every draw, weight, selection and field update equals the
    /// single-shard engine's — byte for byte.
    fn run_virtual(&mut self) -> (RunResult, ShardStats) {
        let start = std::time::Instant::now();
        let model = self.model;
        let n = model.len();
        let s_count = self.part.shards();
        let lut = PwlLogistic::default();
        let rng = StatelessRng::new(self.cfg.seed);
        let mut spins = SpinVec::random(n, &rng);
        let mut u = model.local_fields(&spins);
        let mut energy = model.energy(&spins);
        let mut p_q16 = vec![0u32; n];

        let steps = self.cfg.steps;
        let mut best_energy = energy;
        let mut best_step = 0u64;
        let mut best_spins = spins.clone();
        let mut trace = Vec::new();
        let (mut flips, mut fallbacks, mut nulls) = (0u64, 0u64, 0u64);
        if self.cfg.trace_stride > 0 {
            trace.push((0, energy));
        }

        let uniformized = matches!(self.cfg.mode, Mode::RouletteUniformized);
        let mut w_shard = vec![0u64; s_count];
        for t in 0..steps {
            let temp = self.cfg.schedule.temperature(t, steps);
            match self.cfg.mode {
                Mode::RandomScan => {
                    if let Some((j, de)) =
                        virtual_random_scan(model, &lut, &rng, &spins, &u, t, temp)
                    {
                        apply_flip_sharded(model, &self.part, &mut u, j, spins.get(j));
                        // `apply_flip_sharded` updates fields only; the
                        // flip + energy happen here, like the engine.
                        spins.flip(j);
                        energy += de;
                        flips += 1;
                    }
                }
                Mode::RouletteWheel | Mode::RouletteUniformized => {
                    // Per-shard lane refresh in shard order; W_s are
                    // summed exactly as `eval_lanes` sums lane weights.
                    let ctx = lut.lane_ctx(temp);
                    let mut w_total = 0u64;
                    for s in 0..s_count {
                        let mut w_s = 0u64;
                        for i in self.part.range(s) {
                            let p = lut.lane_p(&ctx, spins.bit(i), u[i]);
                            p_q16[i] = p;
                            w_s += p as u64;
                        }
                        w_shard[s] = w_s;
                        w_total += w_s;
                    }
                    if w_total == 0 {
                        // Degenerate weight → Mode I fallback, exactly
                        // like the engine (fallback bookkeeping too).
                        fallbacks += 1;
                        if let Some((j, de)) =
                            virtual_random_scan(model, &lut, &rng, &spins, &u, t, temp)
                        {
                            apply_flip_sharded(model, &self.part, &mut u, j, spins.get(j));
                            spins.flip(j);
                            energy += de;
                            flips += 1;
                        }
                    } else {
                        let w_star = (n as u64) * ONE_Q16 as u64;
                        let domain = if uniformized { w_star } else { w_total };
                        let raw = rng.u64(t, 0, salt::ROULETTE);
                        let r = ((raw as u128 * domain as u128) >> 64) as u64;
                        if uniformized && r >= w_total {
                            nulls += 1;
                        } else {
                            // Locate the owning shard by prefix, then
                            // the lane inside it — the same unique j
                            // the global prefix scan finds.
                            let mut cum = 0u64;
                            let mut chosen = n - 1;
                            'outer: for s in 0..s_count {
                                if r < cum + w_shard[s] {
                                    let mut acc = cum;
                                    for i in self.part.range(s) {
                                        acc += p_q16[i] as u64;
                                        if r < acc {
                                            chosen = i;
                                            break 'outer;
                                        }
                                    }
                                }
                                cum += w_shard[s];
                            }
                            let de = IsingModel::delta_e(spins.get(chosen), u[chosen]);
                            let s_old = spins.get(chosen);
                            apply_flip_sharded(model, &self.part, &mut u, chosen, s_old);
                            spins.flip(chosen);
                            energy += de;
                            flips += 1;
                        }
                    }
                }
            }
            if energy < best_energy {
                best_energy = energy;
                best_step = t + 1;
                best_spins.assign_from(&spins);
            }
            if self.cfg.trace_stride > 0 && (t + 1) % self.cfg.trace_stride == 0 {
                trace.push((t + 1, energy));
            }
        }
        let result = RunResult {
            best_energy,
            best_step,
            best_spins,
            final_energy: energy,
            final_spins: spins,
            trace,
            steps,
            flips,
            fallbacks,
            nulls,
            wall: start.elapsed(),
        };
        let stats = ShardStats {
            shards: s_count,
            max_lag: 0,
            per_shard_flips: vec![0; s_count], // interleaved, not per-lane
            sync_points: 0,
        };
        (result, stats)
    }

    // ------------------------------------------------------------------
    // Async merge: one thread per shard, mailboxes, epoch barriers.
    // ------------------------------------------------------------------

    fn run_async(&mut self) -> (RunResult, ShardStats) {
        let start = std::time::Instant::now();
        let model = self.model;
        let n = model.len();
        let s_count = self.part.shards();
        let window = self.window;
        // `cfg.steps` is the TOTAL step budget across lanes (comparable
        // work to a single-shard run of the same step count); each lane
        // runs the same local count so epoch barriers line up.
        let steps_local = self.cfg.steps.div_ceil(s_count as u64);
        let total_steps = steps_local * s_count as u64;

        // Initial global configuration: same derivation as the engine.
        let rng = StatelessRng::new(self.cfg.seed);
        let init_spins = SpinVec::random(n, &rng);
        let init_u = model.local_fields(&init_spins);
        let init_energy = model.energy(&init_spins);

        let mut result = RunResult {
            best_energy: init_energy,
            best_step: 0,
            best_spins: init_spins.clone(),
            final_energy: init_energy,
            final_spins: init_spins.clone(),
            trace: if self.cfg.trace_stride > 0 { vec![(0, init_energy)] } else { Vec::new() },
            steps: total_steps,
            flips: 0,
            fallbacks: 0,
            nulls: 0,
            wall: std::time::Duration::ZERO,
        };
        let mut stats = ShardStats {
            shards: s_count,
            max_lag: 0,
            per_shard_flips: vec![0; s_count],
            sync_points: 0,
        };
        if steps_local == 0 || n == 0 {
            result.wall = start.elapsed();
            return (result, stats);
        }

        // Shared CSR (sparse instances): lanes slice rows to their own
        // range for Θ(deg ∩ range) remote applies.
        let adj = Adjacency::build_if_sparse(model, 0.25);
        let lut = PwlLogistic::default();
        let epochs = steps_local.div_ceil(window);
        // Ring capacity ≥ the flips a producer can emit between the
        // consumer's epoch drains (one per local step).
        let grid = MailboxGrid::new(s_count, window as usize + 2);
        let gate = SyncGate::new(s_count);
        let partials: Vec<AtomicI64> = (0..s_count).map(|_| AtomicI64::new(0)).collect();
        let snapshot = Mutex::new(init_spins.clone());
        let tracker = Mutex::new(EnergyTracker {
            best_energy: init_energy,
            best_step: 0,
            best_spins: init_spins.clone(),
            last_energy: init_energy,
            samples: Vec::new(),
        });

        let mut lanes: Vec<Lane> = (0..s_count)
            .map(|s| {
                let range = self.part.range(s);
                let mut spins = SpinVec::all_down(range.len());
                for (k, i) in range.clone().enumerate() {
                    spins.set(k, init_spins.get(i));
                }
                Lane {
                    index: s,
                    lo: range.start,
                    hi: range.end,
                    spins,
                    u: init_u[range.clone()].to_vec(),
                    p: vec![0u32; range.len()],
                    rng: rng.child(s as u64),
                    flips: 0,
                    fallbacks: 0,
                    nulls: 0,
                    max_lag: 0,
                }
            })
            .collect();

        // A panicking lane must fail the whole run, not wedge its
        // siblings at the gate: the panic payload is parked here, the
        // gate is aborted (waking everyone), and the payload re-raised
        // after the scope joins — so the replica-level `catch_unwind`
        // boundary in the scheduler sees an ordinary panic.
        let panic_slot: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
        let cfg = &self.cfg;
        let (model_ref, adj_ref, lut_ref) = (model, adj.as_ref(), &lut);
        let (grid_ref, gate_ref, partials_ref) = (&grid, &gate, &partials);
        let (snapshot_ref, tracker_ref, panic_ref) = (&snapshot, &tracker, &panic_slot);
        std::thread::scope(|scope| {
            for lane in lanes.iter_mut() {
                scope.spawn(move || {
                    let outcome =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            lane.run(
                                model_ref,
                                adj_ref,
                                lut_ref,
                                cfg,
                                steps_local,
                                window,
                                s_count,
                                grid_ref,
                                gate_ref,
                                partials_ref,
                                snapshot_ref,
                                tracker_ref,
                            );
                        }));
                    if let Err(payload) = outcome {
                        panic_ref.lock().unwrap().get_or_insert(payload);
                        gate_ref.abort();
                    }
                });
            }
        });
        if let Some(payload) = panic_slot.into_inner().unwrap() {
            std::panic::resume_unwind(payload);
        }

        let tracker = tracker.into_inner().unwrap();
        result.best_energy = tracker.best_energy;
        result.best_step = tracker.best_step;
        result.best_spins = tracker.best_spins;
        result.final_energy = tracker.last_energy;
        result.final_spins = snapshot.into_inner().unwrap();
        if self.cfg.trace_stride > 0 {
            result.trace.extend(tracker.samples);
        }
        for lane in &lanes {
            result.flips += lane.flips;
            result.fallbacks += lane.fallbacks;
            result.nulls += lane.nulls;
            stats.per_shard_flips[lane.index] = lane.flips;
            stats.max_lag = stats.max_lag.max(lane.max_lag);
        }
        stats.sync_points = epochs;
        result.wall = start.elapsed();
        (result, stats)
    }
}

/// An abortable S-party barrier for the epoch syncs.
///
/// `std::sync::Barrier` cannot be interrupted: if one lane dies, its
/// siblings wait forever and the job wedges — exactly the failure mode
/// the coordinator's panic path exists to prevent. This gate adds
/// [`abort`](Self::abort): aborting wakes every current waiter and
/// makes every future [`wait`](Self::wait) return `Err(GateAborted)`
/// immediately, so surviving lanes unwind cleanly and the panic can be
/// re-raised at the replica boundary.
struct SyncGate {
    parties: usize,
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    arrived: usize,
    generation: u64,
    aborted: bool,
}

/// The gate was aborted — a sibling lane panicked.
#[derive(Clone, Copy, Debug)]
struct GateAborted;

impl SyncGate {
    fn new(parties: usize) -> Self {
        Self {
            parties: parties.max(1),
            state: Mutex::new(GateState { arrived: 0, generation: 0, aborted: false }),
            cv: Condvar::new(),
        }
    }

    /// Block until all parties arrive; the LAST arriver is the leader
    /// (`Ok(true)`). Returns `Err(GateAborted)` — immediately, or from
    /// mid-wait — once [`abort`](Self::abort) has been called.
    fn wait(&self) -> Result<bool, GateAborted> {
        let mut st = self.state.lock().unwrap();
        if st.aborted {
            return Err(GateAborted);
        }
        let gen = st.generation;
        st.arrived += 1;
        if st.arrived == self.parties {
            st.arrived = 0;
            st.generation += 1;
            self.cv.notify_all();
            return Ok(true);
        }
        while st.generation == gen && !st.aborted {
            st = self.cv.wait(st).unwrap();
        }
        if st.aborted {
            Err(GateAborted)
        } else {
            Ok(false)
        }
    }

    /// Wake every waiter and fail all future waits.
    fn abort(&self) {
        self.state.lock().unwrap().aborted = true;
        self.cv.notify_all();
    }
}

/// Best/final energy bookkeeping, written only by the barrier leader.
struct EnergyTracker {
    best_energy: i64,
    best_step: u64,
    best_spins: SpinVec,
    last_energy: i64,
    /// `(approx global step, exact energy)` per epoch sync.
    samples: Vec<(u64, i64)>,
}

/// One asynchronous shard lane: the spins in `[lo, hi)`, their local
/// fields (which include every remote flip applied so far), and the
/// lane's own stateless RNG stream.
struct Lane {
    index: usize,
    lo: usize,
    hi: usize,
    /// Local spins, indexed `0..hi-lo`.
    spins: SpinVec,
    /// Local fields of the local spins (global `u[lo..hi]`).
    u: Vec<i64>,
    /// Mode II lane weights (local).
    p: Vec<u32>,
    rng: StatelessRng,
    flips: u64,
    fallbacks: u64,
    nulls: u64,
    max_lag: u64,
}

impl Lane {
    fn n_local(&self) -> usize {
        self.hi - self.lo
    }

    /// Apply a peer's flip to this lane's fields: walk the coupling row
    /// restricted to `[lo, hi)` (CSR slice when the instance is sparse,
    /// dense row segment otherwise).
    fn apply_remote(&mut self, model: &IsingModel, adj: Option<&Adjacency>, flip: Flip) {
        let j = flip.j as usize;
        let factor = 2 * flip.s_old as i64;
        match adj {
            Some(adj) => {
                let (neigh, vals) = adj.row(j);
                let from = neigh.partition_point(|&i| (i as usize) < self.lo);
                for (&i, &jv) in neigh[from..].iter().zip(vals[from..].iter()) {
                    if i as usize >= self.hi {
                        break;
                    }
                    self.u[i as usize - self.lo] -= factor * jv as i64;
                }
            }
            None => {
                let row = &model.j_row(j)[self.lo..self.hi];
                for (ui, &jv) in self.u.iter_mut().zip(row.iter()) {
                    *ui -= factor * jv as i64;
                }
            }
        }
    }

    /// Flip local spin `j_local`, update the lane's own fields, and
    /// broadcast the flip. Returns the pre-flip sign.
    fn apply_local(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        grid: &MailboxGrid,
        j_local: usize,
        step: u64,
    ) {
        let s_old = self.spins.flip(j_local);
        let j = self.lo + j_local;
        self.apply_remote(model, adj, Flip { j: j as u32, s_old, step });
        grid.post(self.index, Flip { j: j as u32, s_old, step });
        self.flips += 1;
    }

    /// One local MCMC step at temperature `temp` (dual-mode, mirroring
    /// the engine's step but over the lane's own spins and RNG stream).
    #[allow(clippy::too_many_arguments)]
    fn step(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        lut: &PwlLogistic,
        grid: &MailboxGrid,
        mode: Mode,
        k: u64,
        temp: f64,
    ) {
        let n_local = self.n_local();
        // `move` copies the (Copy) shared refs in, so `adj` keeps its
        // `Option<&Adjacency>` type inside the closure.
        let random_scan = move |lane: &mut Lane, is_fallback: bool| {
            let j = lane.rng.below(k, 0, salt::SITE, n_local as u32) as usize;
            let de = IsingModel::delta_e(lane.spins.get(j), lane.u[j]);
            let p = lut.flip_prob_q16(de, temp);
            let r = lane.rng.u32(k, 0, salt::ACCEPT) >> 16;
            if r < p {
                lane.apply_local(model, adj, grid, j, k);
            }
            if is_fallback {
                lane.fallbacks += 1;
            }
        };
        match mode {
            Mode::RandomScan => random_scan(self, false),
            Mode::RouletteWheel | Mode::RouletteUniformized => {
                let ctx = lut.lane_ctx(temp);
                let w_total = lut.eval_lanes(&ctx, &self.u, self.spins.words(), &mut self.p);
                if w_total == 0 {
                    random_scan(self, true);
                    return;
                }
                let uniformized = mode == Mode::RouletteUniformized;
                let w_star = (n_local as u64) * ONE_Q16 as u64;
                let domain = if uniformized { w_star } else { w_total };
                let raw = self.rng.u64(k, 0, salt::ROULETTE);
                let r = ((raw as u128 * domain as u128) >> 64) as u64;
                if uniformized && r >= w_total {
                    self.nulls += 1;
                    return;
                }
                let mut acc = 0u64;
                let mut chosen = n_local - 1;
                for (i, &p) in self.p.iter().enumerate() {
                    acc += p as u64;
                    if r < acc {
                        chosen = i;
                        break;
                    }
                }
                self.apply_local(model, adj, grid, chosen, k);
            }
        }
    }

    /// The lane's thread body: epochs of `window` local steps with
    /// opportunistic mailbox drains, then the three-phase sync —
    /// (A) quiesce, (B) drain + publish partial energy and the local
    /// spin slice, (C) leader records the exact global energy. Returns
    /// early (cleanly) if the gate aborts — a sibling lane panicked.
    #[allow(clippy::too_many_arguments)]
    fn run(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        lut: &PwlLogistic,
        cfg: &EngineConfig,
        steps_local: u64,
        window: u64,
        s_count: usize,
        grid: &MailboxGrid,
        gate: &SyncGate,
        partials: &[AtomicI64],
        snapshot: &Mutex<SpinVec>,
        tracker: &Mutex<EnergyTracker>,
    ) {
        let epochs = steps_local.div_ceil(window);
        for e in 0..epochs {
            let end = ((e + 1) * window).min(steps_local);
            for k in (e * window)..end {
                // Opportunistic drain keeps cross-shard fields as fresh
                // as the interleaving allows (staleness well under the
                // window in practice; the barrier only enforces the
                // bound).
                grid.drain(self.index, |f| {
                    let lag = (k as i64 - f.step as i64).unsigned_abs();
                    self.max_lag = self.max_lag.max(lag);
                    self.apply_remote(model, adj, f);
                });
                let temp = cfg.schedule.temperature(k, steps_local);
                self.step(model, adj, lut, grid, cfg.mode, k, temp);
            }
            // Phase A: every lane has finished the epoch — no more
            // producers until phase C releases.
            if gate.wait().is_err() {
                return;
            }
            // Phase B prep: apply the stragglers, then publish this
            // lane's energy partial Σ sᵢ(uᵢ + hᵢ) and its spin slice.
            grid.drain(self.index, |f| {
                let lag = (end as i64 - f.step as i64).unsigned_abs();
                self.max_lag = self.max_lag.max(lag);
                self.apply_remote(model, adj, f);
            });
            let mut partial = 0i64;
            for i in 0..self.n_local() {
                let s = self.spins.get(i) as i64;
                partial += s * (self.u[i] + model.h(self.lo + i) as i64);
            }
            partials[self.index].store(partial, Ordering::Relaxed);
            {
                let mut snap = snapshot.lock().unwrap();
                for i in 0..self.n_local() {
                    snap.set(self.lo + i, self.spins.get(i));
                }
            }
            match gate.wait() {
                Err(GateAborted) => return,
                Ok(true) => {
                    // Leader: all partials and slices are published
                    // (the gate gives happens-before) —
                    // E = −(Σ sᵢuᵢ + Σ sᵢhᵢ)/2, exact.
                    let total: i64 = partials.iter().map(|p| p.load(Ordering::Relaxed)).sum();
                    let energy = -total / 2;
                    let global_step = end * s_count as u64;
                    let mut t = tracker.lock().unwrap();
                    t.last_energy = energy;
                    if cfg.trace_stride > 0 {
                        // Only consumed as the run's trace — don't
                        // accumulate unbounded samples with tracing off.
                        t.samples.push((global_step, energy));
                    }
                    if energy < t.best_energy {
                        t.best_energy = energy;
                        t.best_step = global_step;
                        let snap = snapshot.lock().unwrap();
                        t.best_spins.assign_from(&snap);
                    }
                }
                Ok(false) => {}
            }
            // Phase C: resume only after the leader finished reading.
            if gate.wait().is_err() {
                return;
            }
        }
    }
}

/// Mode I site draw + Glauber accept on the GLOBAL stream — the shared
/// helper of the virtual-time mode (both as Mode I proper and as the
/// Mode II fallback). Returns `Some((j, ΔE))` when the flip is
/// accepted; the caller applies it. Byte-compatible with
/// `SnowballEngine::step_random_scan`.
fn virtual_random_scan(
    model: &IsingModel,
    lut: &PwlLogistic,
    rng: &StatelessRng,
    spins: &SpinVec,
    u: &[i64],
    t: u64,
    temp: f64,
) -> Option<(usize, i64)> {
    let n = model.len() as u32;
    let j = rng.below(t, 0, salt::SITE, n) as usize;
    let de = IsingModel::delta_e(spins.get(j), u[j]);
    let p = lut.flip_prob_q16(de, temp);
    let r = rng.u32(t, 0, salt::ACCEPT) >> 16;
    if r < p {
        Some((j, de))
    } else {
        None
    }
}

/// Propagate a flip of global spin `j` (current sign `s_j`, about to be
/// flipped by the caller) into the full field vector, walking the row
/// one shard segment at a time in shard order — the same i64 adds as
/// the engine's dense row walk, grouped differently.
fn apply_flip_sharded(
    model: &IsingModel,
    part: &Partition,
    u: &mut [i64],
    j: usize,
    s_old: i8,
) {
    let row = model.j_row(j);
    let factor = 2 * s_old as i64;
    for s in 0..part.shards() {
        let r = part.range(s);
        for (ui, &jv) in u[r.clone()].iter_mut().zip(row[r].iter()) {
            *ui -= factor * jv as i64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Datapath, Schedule, SelectorKind, SnowballEngine};
    use crate::graph::generators;
    use crate::problems::MaxCut;

    fn cfg(mode: Mode, steps: u64, seed: u64, shards: usize) -> EngineConfig {
        EngineConfig {
            mode,
            datapath: Datapath::Dense,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.1 },
            steps,
            seed,
            planes: None,
            trace_stride: 0,
            shards,
        }
    }

    #[test]
    fn virtual_time_matches_engine_smoke() {
        // The in-module smoke of the tentpole guarantee; the full
        // mode × selector × seed × shard matrix lives in
        // rust/tests/shard_parity.rs.
        let rng = StatelessRng::new(41);
        let p = MaxCut::new(generators::erdos_renyi(72, 300, &[-1, 1], &rng));
        for mode in [Mode::RandomScan, Mode::RouletteWheel, Mode::RouletteUniformized] {
            let mut reference = SnowballEngine::new(p.model(), cfg(mode, 600, 9, 1));
            let want = reference.run();
            let mut sharded =
                ShardedEngine::new(p.model(), cfg(mode, 600, 9, 4), MergeMode::VirtualTime);
            let got = sharded.run();
            assert_eq!(got.best_energy, want.best_energy, "{mode:?}");
            assert_eq!(got.final_energy, want.final_energy, "{mode:?}");
            assert_eq!(got.final_spins, want.final_spins, "{mode:?}");
            assert_eq!(got.best_spins, want.best_spins, "{mode:?}");
            assert_eq!(
                (got.flips, got.fallbacks, got.nulls, got.best_step),
                (want.flips, want.fallbacks, want.nulls, want.best_step),
                "{mode:?}"
            );
        }
    }

    #[test]
    fn async_bookkeeping_is_exact_at_sync_points() {
        let rng = StatelessRng::new(42);
        let p = MaxCut::new(generators::erdos_renyi(192, 800, &[-1, 1], &rng));
        let mut e =
            ShardedEngine::new(p.model(), cfg(Mode::RouletteWheel, 8_000, 3, 4), MergeMode::Async)
                .with_window(16);
        let (r, stats) = e.run_with_stats();
        // The distributed energy bookkeeping must agree with the dense
        // oracle on the final configuration...
        assert_eq!(r.final_energy, p.model().energy(&r.final_spins), "final energy drifted");
        // ...and on the recorded best configuration.
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins), "best energy drifted");
        assert!(r.best_energy <= r.final_energy);
        assert_eq!(stats.shards, 4);
        assert_eq!(stats.per_shard_flips.iter().sum::<u64>(), r.flips);
        assert!(stats.max_lag <= 16, "staleness {} exceeded the window", stats.max_lag);
        assert_eq!(stats.sync_points, 8_000u64.div_ceil(4).div_ceil(16));
        assert!(r.flips > 0, "async lanes must make progress");
    }

    #[test]
    fn async_single_shard_and_zero_steps_degenerate_cleanly() {
        let rng = StatelessRng::new(43);
        let p = MaxCut::new(generators::erdos_renyi(48, 160, &[-1, 1], &rng));
        // S = 1: one lane, no peers, still correct.
        let mut one =
            ShardedEngine::new(p.model(), cfg(Mode::RouletteWheel, 500, 7, 1), MergeMode::Async);
        let r = one.run();
        assert_eq!(r.final_energy, p.model().energy(&r.final_spins));
        // steps = 0: initial configuration everywhere.
        let mut zero =
            ShardedEngine::new(p.model(), cfg(Mode::RouletteWheel, 0, 7, 3), MergeMode::Async);
        let r0 = zero.run();
        assert_eq!(r0.best_energy, p.model().energy(&r0.best_spins));
        assert_eq!(r0.flips, 0);
        assert_eq!(r0.steps, 0);
    }

    #[test]
    fn shard_count_clamps() {
        let rng = StatelessRng::new(44);
        let p = MaxCut::new(generators::erdos_renyi(10, 20, &[-1, 1], &rng));
        let e = ShardedEngine::new(p.model(), cfg(Mode::RandomScan, 10, 1, 500), MergeMode::Async);
        assert_eq!(e.shards(), 10, "shards clamp to N");
        let e = ShardedEngine::new(p.model(), cfg(Mode::RandomScan, 10, 1, 0), MergeMode::Async);
        assert_eq!(e.shards(), 1, "shards = 0 clamps to 1");
    }

    #[test]
    fn parallelism_plan_policy() {
        // Small instance: replica-level only, whatever the machine.
        assert_eq!(plan_parallelism(256, 8, 32), ParallelismPlan { replica_workers: 8, shards: 1 });
        // Big instance, many units: still replica-level (units fill the
        // machine).
        assert_eq!(
            plan_parallelism(8192, 16, 16),
            ParallelismPlan { replica_workers: 16, shards: 1 }
        );
        // Big instance, few units: spare cores become shard lanes.
        let p = plan_parallelism(8192, 2, 16);
        assert_eq!(p.replica_workers, 2);
        assert!(p.shards >= 2 && p.shards <= 8, "{p:?}");
        // Lane floor: never shard below MIN_SPINS_PER_SHARD spins/lane.
        let p = plan_parallelism(4096, 1, 64);
        assert!(p.shards <= 4096 / MIN_SPINS_PER_SHARD, "{p:?}");
        // Degenerate inputs.
        assert_eq!(plan_parallelism(0, 0, 0), ParallelismPlan { replica_workers: 1, shards: 1 });
    }

    /// A sibling-lane panic must not wedge the survivors: aborting the
    /// gate wakes every current waiter and fails every future wait.
    #[test]
    fn sync_gate_abort_releases_all_waiters() {
        let gate = std::sync::Arc::new(SyncGate::new(4));
        let waiters: Vec<_> = (0..3)
            .map(|_| {
                let gate = gate.clone();
                std::thread::spawn(move || gate.wait().is_err())
            })
            .collect();
        // Give the three waiters time to block (4th party never comes —
        // it "panicked"), then abort as the panic handler would.
        std::thread::sleep(std::time::Duration::from_millis(20));
        gate.abort();
        for w in waiters {
            assert!(w.join().unwrap(), "waiter must observe the abort");
        }
        assert!(gate.wait().is_err(), "post-abort waits must fail immediately");
    }

    /// Normal rounds elect exactly one leader per round and reuse
    /// cleanly across rounds.
    #[test]
    fn sync_gate_elects_one_leader_per_round() {
        let gate = std::sync::Arc::new(SyncGate::new(3));
        let leaders = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let threads: Vec<_> = (0..3)
            .map(|_| {
                let (gate, leaders) = (gate.clone(), leaders.clone());
                std::thread::spawn(move || {
                    for _ in 0..10 {
                        if gate.wait().unwrap() {
                            leaders.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(leaders.load(Ordering::Relaxed), 10, "one leader per round");
    }

    #[test]
    fn merge_mode_parses() {
        assert_eq!(MergeMode::parse("async").unwrap(), MergeMode::Async);
        assert_eq!(MergeMode::parse("virtual").unwrap(), MergeMode::VirtualTime);
        assert_eq!(MergeMode::parse("virtual-time").unwrap(), MergeMode::VirtualTime);
        assert!(MergeMode::parse("bogus").is_err());
    }
}
