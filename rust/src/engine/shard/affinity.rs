//! Minimal thread→core pinning for shard lanes (the ROADMAP
//! "NUMA/affinity" item, smallest useful cut).
//!
//! Shard lanes are long-lived OS threads that ping-pong cache lines
//! through their mailboxes and stream their own partition rows; letting
//! the scheduler migrate them across cores (or worse, sockets) costs
//! exactly the locality the partition bought. `pin_current_thread`
//! pins the calling thread to one CPU via a raw `sched_setaffinity(2)`
//! call on Linux — no `libc` crate, just the symbol every Linux libc
//! exports — and is an honest no-op (returns `false`) elsewhere.
//!
//! The policy (round-robin over [`allowed_cpus`], so restricted
//! cpusets whose ids start above 0 still pin correctly) lives in the
//! caller; this module only does the syscalls. Failures are reported,
//! not fatal: a pin that doesn't stick simply leaves the lane
//! floating, and [`ShardStats::pinned_lanes`] says how many did.
//!
//! [`ShardStats::pinned_lanes`]: super::ShardStats::pinned_lanes

// AUDITED UNSAFE ALLOWLIST MEMBER (see docs/ARCHITECTURE.md
// § Concurrency correctness): the only unsafe here is the FFI
// boundary — two raw libc syscall bindings whose buffers are local,
// correctly sized and outlive the call. Every unsafe operation
// carries a `SAFETY:` comment (enforced by
// `cargo run -p xtask -- lint-safety`).
#![allow(unsafe_code)]

/// Pin the calling thread to CPU `cpu % 1024`, returning whether the
/// kernel accepted the mask. Linux-only; other platforms return `false`.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cpu: usize) -> bool {
    // A fixed 1024-bit cpu_set_t, the glibc default width.
    const MASK_WORDS: usize = 16;
    extern "C" {
        // pid 0 = the calling thread. The symbol is part of every Linux
        // libc's stable ABI; binding it directly avoids a crate
        // dependency the offline build environment does not have.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let mut mask = [0u64; MASK_WORDS];
    let cpu = cpu % (MASK_WORDS * 64);
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    // SAFETY: `mask` outlives the call and `cpusetsize` matches its
    // byte length; the kernel only reads the buffer.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// Non-Linux platforms: no portable affinity API in std — report
/// "not pinned" and let the lane float.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_cpu: usize) -> bool {
    false
}

/// The CPU ids the calling thread is currently allowed to run on
/// (Linux: read back via `sched_getaffinity(2)`; empty elsewhere).
/// Diagnostic companion of [`pin_current_thread`] — a restricted
/// cpuset (container, `--cpuset-cpus`) may start well above CPU 0, in
/// which case round-robin pins near 0 legitimately fail and
/// `ShardStats::pinned_lanes` reports it.
#[cfg(target_os = "linux")]
pub fn allowed_cpus() -> Vec<usize> {
    const MASK_WORDS: usize = 16;
    extern "C" {
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
    }
    let mut mask = [0u64; MASK_WORDS];
    // SAFETY: `mask` outlives the call and `cpusetsize` matches its
    // byte length; the kernel only writes within the buffer.
    let rc = unsafe { sched_getaffinity(0, std::mem::size_of_val(&mask), mask.as_mut_ptr()) };
    if rc != 0 {
        return Vec::new();
    }
    (0..MASK_WORDS * 64).filter(|&c| mask[c / 64] >> (c % 64) & 1 == 1).collect()
}

/// Non-Linux: no affinity introspection.
#[cfg(not(target_os = "linux"))]
pub fn allowed_cpus() -> Vec<usize> {
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pinning must never crash or wedge, whatever the index — including
    /// indices past the core count (the round-robin wrap case) — and on
    /// Linux, pinning to a CPU the kernel itself reports as allowed
    /// must succeed (candidates come from `sched_getaffinity`, not an
    /// assumed 0-based range, so restricted cpusets don't fail this).
    #[test]
    #[cfg_attr(miri, ignore = "FFI: Miri cannot emulate sched_{get,set}affinity")]
    fn pinning_is_safe_and_reports_honestly() {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        // Arbitrary indices (including past the core count — the
        // round-robin wrap case) must not crash, whatever they return.
        for c in 0..(cores * 2).max(4) {
            std::thread::spawn(move || pin_current_thread(c)).join().expect("no panic");
        }
        let allowed = allowed_cpus();
        if cfg!(target_os = "linux") {
            assert!(!allowed.is_empty(), "a running thread must have allowed CPUs");
            let cpu = allowed[0];
            let ok =
                std::thread::spawn(move || pin_current_thread(cpu)).join().expect("no panic");
            assert!(ok, "pin to kernel-reported allowed CPU {cpu} failed");
        } else {
            assert!(allowed.is_empty(), "non-Linux reports no affinity introspection");
            assert!(!pin_current_thread(0), "non-Linux must report not-pinned");
        }
    }
}
