//! [`SyncGate`]: the abortable S-party epoch barrier of the async
//! shard engine.
//!
//! `std::sync::Barrier` cannot be interrupted: if one lane dies, its
//! siblings wait forever and the job wedges — exactly the failure mode
//! the coordinator's panic path exists to prevent. This gate adds
//! [`abort`](SyncGate::abort): aborting wakes every current waiter and
//! makes every future [`wait`](SyncGate::wait) return
//! `Err(`[`GateAborted`]`)` immediately, so surviving lanes unwind
//! cleanly and the panic can be re-raised at the replica boundary.
//!
//! The same release path doubles as **graceful preemption**:
//! [`stop`](SyncGate::stop) aborts the gate *with* a [`StopCause`]
//! (cancel / deadline / shutdown). Waiters unwind identically; the
//! shard engine then reads [`stop_cause`](SyncGate::stop_cause) after
//! joining its lanes to tell "a lane died" (no cause — re-raise the
//! panic) from "the job was preempted" (cause — return the best-so-far
//! incumbent as a partial result). The first cause recorded wins and
//! is sticky, matching [`crate::stop::StopToken`] semantics.
//!
//! Rounds are tracked by a **wrapping** generation counter: a waiter
//! parks while `generation` still equals the value it read on arrival,
//! and the last arriver bumps the counter (waking the round). Equality
//! is wraparound-safe, so the gate survives generation rollover — a
//! property the tests pin by starting the counter at `u64::MAX`
//! ([`SyncGate::with_start_generation`]) rather than hoping 2⁶⁴ epochs
//! never happen.
//!
//! **Verification.** The gate is built exclusively on [`crate::sync`]
//! primitives, so under `--cfg loom` it compiles against loom's
//! instrumented `Mutex`/`Condvar` and `rust/tests/loom_shard.rs`
//! model-checks arrive/leader-election, abort-while-parked and
//! generation rollover across every interleaving. The deterministic
//! in-module stress tests below additionally run under Miri in CI.

use crate::stop::StopCause;
use crate::sync::{Condvar, Mutex};

/// An abortable S-party barrier (see the module docs).
///
/// One round: each party calls [`wait`](Self::wait); the LAST arriver
/// is the leader (`Ok(true)`), everyone else `Ok(false)`. The gate is
/// reusable round after round. [`abort`](Self::abort) permanently
/// fails the gate: all current waiters wake with `Err(GateAborted)`
/// and all future waits fail immediately.
pub struct SyncGate {
    parties: usize,
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    arrived: usize,
    generation: u64,
    aborted: bool,
    /// Why the gate was aborted, when the abort was a *preemption*
    /// ([`SyncGate::stop`]) rather than a lane panic ([`SyncGate::abort`]).
    cause: Option<StopCause>,
}

/// The gate was aborted — a sibling lane panicked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GateAborted;

impl SyncGate {
    /// Gate for `parties` participants (min 1).
    pub fn new(parties: usize) -> Self {
        Self::with_start_generation(parties, 0)
    }

    /// Gate whose generation counter starts at `generation` — lets the
    /// rollover tests cross the `u64::MAX → 0` wrap in one round
    /// instead of 2⁶⁴. Behaviour is otherwise identical to
    /// [`new`](Self::new): the counter only ever matters through
    /// wrapping-equality comparisons.
    pub fn with_start_generation(parties: usize, generation: u64) -> Self {
        Self {
            parties: parties.max(1),
            state: Mutex::new(GateState { arrived: 0, generation, aborted: false, cause: None }),
            cv: Condvar::new(),
        }
    }

    /// Number of participants per round.
    pub fn parties(&self) -> usize {
        self.parties
    }

    /// Block until all parties arrive; the LAST arriver is the leader
    /// (`Ok(true)`). Returns `Err(GateAborted)` — immediately, or from
    /// mid-wait — once [`abort`](Self::abort) has been called.
    pub fn wait(&self) -> Result<bool, GateAborted> {
        crate::failpoint::hit("gate.arrive");
        let mut st = self.state.lock().unwrap();
        if st.aborted {
            return Err(GateAborted);
        }
        let gen = st.generation;
        st.arrived += 1;
        if st.arrived == self.parties {
            st.arrived = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(true);
        }
        while st.generation == gen && !st.aborted {
            st = self.cv.wait(st).unwrap();
        }
        if st.aborted {
            Err(GateAborted)
        } else {
            Ok(false)
        }
    }

    /// Wake every waiter and fail all future waits.
    pub fn abort(&self) {
        self.state.lock().unwrap().aborted = true;
        self.cv.notify_all();
    }

    /// Abort the gate as a *preemption*, recording why. Identical
    /// release semantics to [`abort`](Self::abort); additionally the
    /// first cause ever recorded is kept (sticky, first wins) so a
    /// panic-abort racing a cancel-stop cannot relabel the outcome.
    pub fn stop(&self, cause: StopCause) {
        let mut st = self.state.lock().unwrap();
        st.aborted = true;
        if st.cause.is_none() {
            st.cause = Some(cause);
        }
        self.cv.notify_all();
    }

    /// The preemption cause, if the gate was released by
    /// [`stop`](Self::stop) rather than a bare [`abort`](Self::abort).
    pub fn stop_cause(&self) -> Option<StopCause> {
        self.state.lock().unwrap().cause
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// A sibling-lane panic must not wedge the survivors: aborting the
    /// gate wakes every current waiter and fails every future wait.
    #[test]
    fn abort_releases_all_waiters() {
        let gate = Arc::new(SyncGate::new(4));
        let waiters: Vec<_> = (0..3)
            .map(|_| {
                let gate = gate.clone();
                std::thread::spawn(move || gate.wait().is_err())
            })
            .collect();
        // Give the three waiters time to block (4th party never comes —
        // it "panicked"), then abort as the panic handler would.
        std::thread::sleep(std::time::Duration::from_millis(20));
        gate.abort();
        for w in waiters {
            assert!(w.join().unwrap(), "waiter must observe the abort");
        }
        assert!(gate.wait().is_err(), "post-abort waits must fail immediately");
    }

    /// Deterministic abort-while-parked stress: round after round, a
    /// waiter parks with no hope of a full quorum and the controller
    /// aborts it. Every wait must resolve to `Err(GateAborted)` — no
    /// round may wedge, whatever the park/abort interleaving was.
    #[test]
    fn abort_while_parked_stress_never_wedges() {
        let rounds: usize = if cfg!(miri) { 8 } else { 200 };
        for round in 0..rounds {
            let gate = Arc::new(SyncGate::new(2));
            let parked = {
                let gate = gate.clone();
                std::thread::spawn(move || gate.wait())
            };
            if round % 2 == 0 {
                // Let the waiter actually park before aborting (best
                // effort; aborting earlier is equally valid).
                std::thread::yield_now();
            }
            gate.abort();
            assert_eq!(parked.join().unwrap(), Err(GateAborted), "round {round}");
            assert_eq!(gate.wait(), Err(GateAborted), "round {round}: abort must be sticky");
        }
    }

    /// Normal rounds elect exactly one leader per round and reuse
    /// cleanly across rounds.
    #[test]
    fn elects_one_leader_per_round() {
        let rounds: usize = if cfg!(miri) { 4 } else { 10 };
        let gate = Arc::new(SyncGate::new(3));
        let leaders = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..3)
            .map(|_| {
                let (gate, leaders) = (gate.clone(), leaders.clone());
                std::thread::spawn(move || {
                    for _ in 0..rounds {
                        if gate.wait().unwrap() {
                            leaders.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(leaders.load(Ordering::Relaxed), rounds, "one leader per round");
    }

    /// Generation wraparound: a gate whose counter starts just below
    /// `u64::MAX` must run many rounds straight across the wrap with
    /// exactly one leader per round and no wedged waiter. (Wrapping
    /// equality is what the park loop relies on; this pins it.)
    #[test]
    fn generation_rollover_is_seamless() {
        let rounds: usize = if cfg!(miri) { 8 } else { 100 };
        // Start so the wrap lands mid-stress, not at the edges.
        let gate = Arc::new(SyncGate::with_start_generation(3, u64::MAX - (rounds as u64) / 2));
        let leaders = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..3)
            .map(|_| {
                let (gate, leaders) = (gate.clone(), leaders.clone());
                std::thread::spawn(move || {
                    for r in 0..rounds {
                        match gate.wait() {
                            Ok(true) => {
                                leaders.fetch_add(1, Ordering::Relaxed);
                            }
                            Ok(false) => {}
                            Err(GateAborted) => panic!("spurious abort in round {r}"),
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(leaders.load(Ordering::Relaxed), rounds, "one leader per wrapped round");
    }

    /// `stop` releases waiters exactly like `abort` but records a
    /// sticky first-wins cause; a bare `abort` records none.
    #[test]
    fn stop_carries_a_sticky_first_cause() {
        use crate::stop::StopCause;
        let gate = Arc::new(SyncGate::new(2));
        let parked = {
            let gate = gate.clone();
            std::thread::spawn(move || gate.wait())
        };
        gate.stop(StopCause::Deadline);
        assert_eq!(parked.join().unwrap(), Err(GateAborted), "stop must release waiters");
        assert_eq!(gate.wait(), Err(GateAborted), "stop is sticky like abort");
        assert_eq!(gate.stop_cause(), Some(StopCause::Deadline));
        // Later causes (and bare aborts) never relabel the first.
        gate.stop(StopCause::Cancel);
        gate.abort();
        assert_eq!(gate.stop_cause(), Some(StopCause::Deadline));

        let plain = SyncGate::new(1);
        plain.abort();
        assert_eq!(plain.stop_cause(), None, "panic-abort carries no cause");
    }

    /// Degenerate single-party gate: every wait is its own leader.
    #[test]
    fn single_party_gate_is_a_no_op_barrier() {
        let gate = SyncGate::new(1);
        assert_eq!(gate.parties(), 1);
        for _ in 0..3 {
            assert_eq!(gate.wait(), Ok(true));
        }
        gate.abort();
        assert_eq!(gate.wait(), Err(GateAborted));
    }
}
