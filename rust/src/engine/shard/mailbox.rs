//! Lock-free flip mailboxes for the asynchronous sharded engine.
//!
//! When shard `p` flips one of its spins it must eventually reach every
//! other shard's local fields (the cross-partition coupler terms). The
//! paper's asynchronous update units exchange exactly this information
//! over dedicated wires; the software analogue is one single-producer /
//! single-consumer ring per **ordered** shard pair. A message is a
//! [`Flip`] — the flipped spin's global index plus its pre-flip sign —
//! and the *receiver* derives its own field deltas by walking its slice
//! of the coupling row, so a flip costs one message per peer regardless
//! of degree. On the consumer side a drained flip feeds the lane
//! kernel's **dirty set** ([`LaneKernel::apply_remote`]): the touched
//! in-range lanes are marked for the next incremental weight refresh,
//! so cross-shard traffic never forces a full `Θ(N/S)` lane recompute.
//!
//! [`LaneKernel::apply_remote`]: crate::engine::lane::LaneKernel::apply_remote
//!
//! The rings are classic Lamport SPSC queues: the producer owns `tail`,
//! the consumer owns `head`, and a release-store / acquire-load pair on
//! each index publishes the slot contents. No locks, no CAS loops — a
//! push and a pop are each one atomic store plus one atomic load in the
//! common case. Capacity doubles as the staleness backstop: a ring
//! sized to the engine's staleness window can never hold more flips
//! than the window allows, so a producer that somehow outruns the epoch
//! barrier parks in [`MailboxGrid::post`] instead of widening the
//! window.
//!
//! **Verification.** Every primitive here comes from [`crate::sync`],
//! so the ring compiles against loom's instrumented doubles under
//! `--cfg loom`: `rust/tests/loom_shard.rs` model-checks push/pop
//! delivery, wraparound reuse, full-ring refusal and the `len()`
//! snapshot against every interleaving (and memory-model reordering)
//! loom can produce. The in-module tests additionally run under Miri
//! in CI, which checks the `UnsafeCell` accesses for aliasing and
//! initialization errors the type system cannot see.

// AUDITED UNSAFE ALLOWLIST MEMBER (see docs/ARCHITECTURE.md
// § Concurrency correctness): the SPSC slot accesses below are the
// crate's only lock-free unsafe. Every unsafe operation carries a
// `SAFETY:` comment (enforced by `cargo run -p xtask -- lint-safety`)
// and the whole protocol is loom-model-checked.
#![allow(unsafe_code)]

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::UnsafeCell;

/// One spin flip, as exchanged between shards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Flip {
    /// Global index of the flipped spin.
    pub j: u32,
    /// The spin's value BEFORE the flip (±1) — what the incremental
    /// field update `u_i -= 2 · s_old · J_ij` needs (paper Eq. 17).
    pub s_old: i8,
    /// The producer shard's local step counter when it flipped — lets
    /// the consumer measure the staleness it actually observed.
    pub step: u64,
}

/// Single-producer single-consumer Lamport ring.
///
/// Usage contract (enforced by [`MailboxGrid`]'s indexing, not the
/// type system): exactly one thread calls [`try_push`](Self::try_push)
/// and exactly one thread calls [`pop`](Self::pop) over the ring's
/// lifetime. Distinct slots are only written by the producer while not
/// visible to the consumer (tail not yet published) and only read by
/// the consumer while not reusable by the producer (head not yet
/// published), so the `UnsafeCell` accesses never race.
///
/// The payload is constrained to `T: Copy` so a slot hand-off is a
/// plain bitwise copy: no destructor can run twice when a slot is
/// recycled and no partially-moved value can be observed. The shard
/// engine instantiates it as [`FlipRing`].
pub struct SpscRing<T> {
    slots: Box<[UnsafeCell<T>]>,
    mask: usize,
    /// Next slot to read; owned by the consumer.
    head: AtomicUsize,
    /// Next slot to write; owned by the producer.
    tail: AtomicUsize,
}

/// The shard engine's ring of [`Flip`] messages.
pub type FlipRing = SpscRing<Flip>;

// SAFETY: moving a ring to another thread moves the payload values in
// its slots with it, so `Send` needs `T: Send`; `T: Copy` guarantees
// the slots hold plain bits with no drop obligations that could be
// split across threads.
unsafe impl<T: Copy + Send> Send for SpscRing<T> {}

// SAFETY: `&SpscRing<T>` is shared between exactly one producer and
// one consumer (the struct-level contract). Each slot is accessed
// exclusively — the producer writes slot `i` only while `i` is outside
// the published `[head, tail)` window, the consumer reads it only
// while inside — and the release-store / acquire-load pairs on
// `tail`/`head` order those accesses. Values cross threads by copy,
// so `T: Send` (with `T: Copy`) is required and sufficient.
unsafe impl<T: Copy + Send> Sync for SpscRing<T> {}

impl<T: Copy + Default> SpscRing<T> {
    /// Ring with capacity `cap` rounded up to a power of two (min 2).
    ///
    /// The index arithmetic (`idx & mask`, wrapping monotone counters)
    /// is only sound for power-of-two capacities, so the invariant is
    /// asserted here at the single point of construction rather than
    /// trusted throughout: `next_power_of_two` wraps to 0 in release
    /// builds when `cap` exceeds the largest representable power of
    /// two, and a zero capacity would turn `mask` into `usize::MAX`.
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(2).next_power_of_two();
        assert!(
            cap.is_power_of_two() && cap >= 2,
            "SpscRing capacity must round to a power of two >= 2 (overflowed?)"
        );
        let slots = (0..cap).map(|_| UnsafeCell::new(T::default())).collect();
        Self {
            slots,
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Slots in the ring.
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Producer side: append `value`, or return `false` when full.
    #[inline]
    pub fn try_push(&self, value: T) -> bool {
        let tail = self.tail.load(Ordering::Relaxed); // producer-owned
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) == self.capacity() {
            return false;
        }
        // SAFETY: slot `tail` is outside the published `[head, tail)`
        // window so the consumer cannot be reading it (it only reads
        // after observing our release-store of `tail`), and the SPSC
        // contract makes us the only producer — the raw pointer is
        // exclusive for the duration of the closure.
        self.slots[tail & self.mask].with_mut(|slot| unsafe { *slot = value });
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        true
    }

    /// Consumer side: take the oldest pending value, if any.
    #[inline]
    pub fn pop(&self) -> Option<T> {
        let head = self.head.load(Ordering::Relaxed); // consumer-owned
        let tail = self.tail.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        // SAFETY: slot `head` is inside `[head, tail)`: the acquire
        // load of `tail` synchronized with the producer's release
        // store, so the slot write happens-before this read; the
        // producer will not reuse the slot until it observes our
        // release-store of the advanced `head`. `T: Copy`, so reading
        // through the shared pointer duplicates plain bits.
        let value = self.slots[head & self.mask].with(|slot| unsafe { *slot });
        self.head.store(head.wrapping_add(1), Ordering::Release);
        Some(value)
    }

    /// Backlog snapshot. Exact when called from the producer or the
    /// consumer thread between that endpoint's own operations (the
    /// loads then bracket a quiescent own-index); from any *other*
    /// thread it is approximate — possibly stale, possibly counting
    /// in-flight traffic — but never underflows: `head` is loaded
    /// FIRST, so the `tail` value read afterwards is always `>=` it
    /// (tail only grows, and `tail >= head` holds at every instant).
    /// Loading in the opposite order could observe a `tail` older than
    /// an advancing `head` and wrap the subtraction to a huge value —
    /// the hazard this ordering exists to rule out.
    pub fn len(&self) -> usize {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        tail.wrapping_sub(head)
    }

    /// True when no values are pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// All `S × (S − 1)` directed mailboxes of an `S`-shard engine.
///
/// Ring `(p → c)` is indexed `p * shards + c`; shard `p` only ever
/// pushes to row `p`, shard `c` only ever pops column `c`, which is
/// exactly the SPSC contract [`FlipRing`] requires.
pub struct MailboxGrid {
    rings: Vec<FlipRing>,
    shards: usize,
}

impl MailboxGrid {
    /// Grid for `shards` shards with per-ring capacity `cap`.
    pub fn new(shards: usize, cap: usize) -> Self {
        let rings = (0..shards * shards).map(|_| FlipRing::new(cap)).collect();
        Self { rings, shards }
    }

    /// Number of shards the grid serves.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Broadcast `flip` from shard `from` to every other shard. Parks
    /// (spin-yield) on a full ring — with rings sized to the staleness
    /// window this only triggers if a peer stops draining entirely, in
    /// which case stalling *is* the bounded-staleness guarantee.
    pub fn post(&self, from: usize, flip: Flip) {
        crate::failpoint::hit("mailbox.post");
        for c in 0..self.shards {
            if c == from {
                continue;
            }
            let ring = &self.rings[from * self.shards + c];
            while !ring.try_push(flip) {
                crate::sync::yield_now();
            }
        }
    }

    /// Drain every flip pending for shard `to`, in per-producer FIFO
    /// order (producers are visited in shard order; cross-producer
    /// ordering is whatever the race produced — the field updates are
    /// commutative integer adds, so it does not matter).
    pub fn drain(&self, to: usize, mut apply: impl FnMut(Flip)) {
        for p in 0..self.shards {
            if p == to {
                continue;
            }
            let ring = &self.rings[p * self.shards + to];
            while let Some(flip) = ring.pop() {
                apply(flip);
            }
        }
    }

    /// Total flips currently pending for shard `to` (diagnostic).
    pub fn pending(&self, to: usize) -> usize {
        (0..self.shards)
            .filter(|&p| p != to)
            .map(|p| self.rings[p * self.shards + to].len())
            .sum()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ring_fifo_and_capacity() {
        let r = FlipRing::new(3); // rounds up to 4
        assert_eq!(r.capacity(), 4);
        for k in 0..4u32 {
            assert!(r.try_push(Flip { j: k, s_old: 1, step: k as u64 }));
        }
        assert!(!r.try_push(Flip { j: 99, s_old: -1, step: 0 }), "full ring must refuse");
        for k in 0..4u32 {
            assert_eq!(r.pop().unwrap().j, k, "FIFO order");
        }
        assert!(r.pop().is_none());
        // Wrap-around reuse after draining.
        assert!(r.try_push(Flip { j: 7, s_old: -1, step: 9 }));
        assert_eq!(r.pop(), Some(Flip { j: 7, s_old: -1, step: 9 }));
    }

    #[test]
    fn ring_delivers_across_threads_in_order() {
        let r = Arc::new(FlipRing::new(8));
        // Miri executes this faithfully but ~2 orders of magnitude
        // slower; a shorter stream checks the same protocol.
        let total: u32 = if cfg!(miri) { 256 } else { 10_000 };
        let producer = {
            let r = r.clone();
            std::thread::spawn(move || {
                for k in 0..total {
                    while !r.try_push(Flip { j: k, s_old: 1, step: k as u64 }) {
                        std::thread::yield_now();
                    }
                }
            })
        };
        let mut next = 0u32;
        while next < total {
            if let Some(f) = r.pop() {
                assert_eq!(f.j, next, "lost or reordered flip");
                next += 1;
            } else {
                std::thread::yield_now();
            }
        }
        producer.join().unwrap();
        assert!(r.is_empty());
    }

    /// Full-ring backpressure (the staleness backstop): a tiny ring
    /// refuses pushes while full, resumes after a pop, and never loses
    /// or duplicates a message under sustained producer pressure. The
    /// deterministic single-threaded prefix pins the exact
    /// full/refuse/resume sequence; the threaded suffix runs the same
    /// protocol with real contention. Runs under Miri in CI; the loom
    /// twin (`loom_ring_full_refusal_then_wraparound_reuse` in
    /// `rust/tests/loom_shard.rs`) model-checks the interleavings this
    /// test can only sample.
    #[test]
    fn full_ring_backpressure_refuses_then_resumes() {
        let r = FlipRing::new(2);
        assert_eq!(r.capacity(), 2);
        // Deterministic: fill, refuse, drain one, resume, wrap.
        assert!(r.try_push(Flip { j: 0, s_old: 1, step: 0 }));
        assert!(r.try_push(Flip { j: 1, s_old: 1, step: 1 }));
        assert!(!r.try_push(Flip { j: 2, s_old: 1, step: 2 }), "full ring must refuse");
        assert_eq!(r.len(), 2, "consumer-side len is exact");
        assert_eq!(r.pop().map(|f| f.j), Some(0));
        assert!(r.try_push(Flip { j: 2, s_old: 1, step: 2 }), "one free slot after pop");
        assert!(!r.try_push(Flip { j: 3, s_old: 1, step: 3 }), "full again");
        assert_eq!(r.pop().map(|f| f.j), Some(1));
        assert_eq!(r.pop().map(|f| f.j), Some(2));
        assert!(r.pop().is_none());

        // Contended: cap-2 ring, many messages — the producer MUST hit
        // backpressure (it can never be more than 2 ahead) and every
        // message must still arrive exactly once, in order.
        let r = Arc::new(FlipRing::new(2));
        let total: u32 = if cfg!(miri) { 64 } else { 4_096 };
        let producer = {
            let r = r.clone();
            std::thread::spawn(move || {
                let mut refusals = 0u64;
                for k in 0..total {
                    while !r.try_push(Flip { j: k, s_old: -1, step: k as u64 }) {
                        refusals += 1;
                        std::thread::yield_now();
                    }
                }
                refusals
            })
        };
        let mut next = 0u32;
        while next < total {
            if let Some(f) = r.pop() {
                assert_eq!(f.j, next, "lost, duplicated or reordered under backpressure");
                next += 1;
            } else {
                std::thread::yield_now();
            }
        }
        let _refusals = producer.join().unwrap();
        assert!(r.is_empty());
        assert_eq!(r.len(), 0);
    }

    /// `len()` from a third-party observer thread never underflows
    /// (the head-before-tail load order): concurrent traffic may make
    /// it stale, but it can never wrap to a huge value.
    #[test]
    fn len_never_underflows_for_observers() {
        let r = Arc::new(FlipRing::new(4));
        let rounds: u32 = if cfg!(miri) { 64 } else { 20_000 };
        let traffic = {
            let r = r.clone();
            std::thread::spawn(move || {
                for k in 0..rounds {
                    while !r.try_push(Flip { j: k, s_old: 1, step: 0 }) {
                        std::thread::yield_now();
                    }
                    while r.pop().is_none() {
                        std::thread::yield_now();
                    }
                }
            })
        };
        // Observer: under the old tail-then-head order this could see
        // tail from before a pop and head from after it → wrap to
        // ~usize::MAX. Bound it by a generous sanity ceiling.
        while !traffic.is_finished() {
            let len = r.len();
            assert!(len <= 1024, "observer len() underflowed/wrapped: {len}");
            std::thread::yield_now();
        }
        traffic.join().unwrap();
    }

    #[test]
    fn grid_routes_to_every_peer_but_not_self() {
        let g = MailboxGrid::new(3, 8);
        g.post(0, Flip { j: 5, s_old: -1, step: 2 });
        g.post(1, Flip { j: 9, s_old: 1, step: 4 });
        assert_eq!(g.pending(0), 1); // from shard 1
        assert_eq!(g.pending(1), 1); // from shard 0
        assert_eq!(g.pending(2), 2); // from both
        let mut got = Vec::new();
        g.drain(2, |f| got.push(f.j));
        got.sort_unstable();
        assert_eq!(got, vec![5, 9]);
        assert_eq!(g.pending(2), 0);
        let mut own = Vec::new();
        g.drain(0, |f| own.push(f.j));
        assert_eq!(own, vec![9], "shard 0 must not receive its own flip");
    }
}
