//! Lock-free flip mailboxes for the asynchronous sharded engine.
//!
//! When shard `p` flips one of its spins it must eventually reach every
//! other shard's local fields (the cross-partition coupler terms). The
//! paper's asynchronous update units exchange exactly this information
//! over dedicated wires; the software analogue is one single-producer /
//! single-consumer ring per **ordered** shard pair. A message is a
//! [`Flip`] — the flipped spin's global index plus its pre-flip sign —
//! and the *receiver* derives its own field deltas by walking its slice
//! of the coupling row, so a flip costs one message per peer regardless
//! of degree. On the consumer side a drained flip feeds the lane
//! kernel's **dirty set** ([`LaneKernel::apply_remote`]): the touched
//! in-range lanes are marked for the next incremental weight refresh,
//! so cross-shard traffic never forces a full `Θ(N/S)` lane recompute.
//!
//! [`LaneKernel::apply_remote`]: crate::engine::lane::LaneKernel::apply_remote
//!
//! The rings are classic Lamport SPSC queues: the producer owns `tail`,
//! the consumer owns `head`, and a release-store / acquire-load pair on
//! each index publishes the slot contents. No locks, no CAS loops — a
//! push and a pop are each one atomic store plus one atomic load in the
//! common case. Capacity doubles as the staleness backstop: a ring
//! sized to the engine's staleness window can never hold more flips
//! than the window allows, so a producer that somehow outruns the epoch
//! barrier parks in [`MailboxGrid::post`] instead of widening the
//! window.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One spin flip, as exchanged between shards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Flip {
    /// Global index of the flipped spin.
    pub j: u32,
    /// The spin's value BEFORE the flip (±1) — what the incremental
    /// field update `u_i -= 2 · s_old · J_ij` needs (paper Eq. 17).
    pub s_old: i8,
    /// The producer shard's local step counter when it flipped — lets
    /// the consumer measure the staleness it actually observed.
    pub step: u64,
}

/// Single-producer single-consumer ring of [`Flip`]s.
///
/// Safety contract (enforced by [`MailboxGrid`]'s indexing, not the
/// type system): exactly one thread calls [`try_push`](Self::try_push)
/// and exactly one thread calls [`pop`](Self::pop) over the ring's
/// lifetime. Distinct slots are only written by the producer while not
/// visible to the consumer (tail not yet published) and only read by
/// the consumer while not reusable by the producer (head not yet
/// published), so the `UnsafeCell` accesses never race.
pub struct FlipRing {
    slots: Box<[UnsafeCell<Flip>]>,
    mask: usize,
    /// Next slot to read; owned by the consumer.
    head: AtomicUsize,
    /// Next slot to write; owned by the producer.
    tail: AtomicUsize,
}

// SAFETY: see the struct-level contract — SPSC usage makes every
// UnsafeCell access exclusive, and the atomics publish between the two
// threads with release/acquire pairs.
unsafe impl Send for FlipRing {}
unsafe impl Sync for FlipRing {}

impl FlipRing {
    /// Ring with capacity `cap` rounded up to a power of two (min 2).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(2).next_power_of_two();
        let slots = (0..cap).map(|_| UnsafeCell::new(Flip::default())).collect();
        Self { slots, mask: cap - 1, head: AtomicUsize::new(0), tail: AtomicUsize::new(0) }
    }

    /// Slots in the ring.
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Producer side: append `flip`, or return `false` when full.
    #[inline]
    pub fn try_push(&self, flip: Flip) -> bool {
        let tail = self.tail.load(Ordering::Relaxed); // producer-owned
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) == self.capacity() {
            return false;
        }
        // SAFETY: slot `tail` is outside [head, tail) so the consumer
        // cannot be reading it, and we are the only producer.
        unsafe { *self.slots[tail & self.mask].get() = flip };
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        true
    }

    /// Consumer side: take the oldest pending flip, if any.
    #[inline]
    pub fn pop(&self) -> Option<Flip> {
        let head = self.head.load(Ordering::Relaxed); // consumer-owned
        let tail = self.tail.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        // SAFETY: slot `head` is inside [head, tail): published by the
        // producer's release-store of `tail`, not yet recycled.
        let flip = unsafe { *self.slots[head & self.mask].get() };
        self.head.store(head.wrapping_add(1), Ordering::Release);
        Some(flip)
    }

    /// Approximate backlog (exact when called from either endpoint's
    /// thread between its own operations).
    pub fn len(&self) -> usize {
        self.tail.load(Ordering::Acquire).wrapping_sub(self.head.load(Ordering::Acquire))
    }

    /// True when no flips are pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// All `S × (S − 1)` directed mailboxes of an `S`-shard engine.
///
/// Ring `(p → c)` is indexed `p * shards + c`; shard `p` only ever
/// pushes to row `p`, shard `c` only ever pops column `c`, which is
/// exactly the SPSC contract [`FlipRing`] requires.
pub struct MailboxGrid {
    rings: Vec<FlipRing>,
    shards: usize,
}

impl MailboxGrid {
    /// Grid for `shards` shards with per-ring capacity `cap`.
    pub fn new(shards: usize, cap: usize) -> Self {
        let rings = (0..shards * shards).map(|_| FlipRing::new(cap)).collect();
        Self { rings, shards }
    }

    /// Number of shards the grid serves.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Broadcast `flip` from shard `from` to every other shard. Parks
    /// (spin-yield) on a full ring — with rings sized to the staleness
    /// window this only triggers if a peer stops draining entirely, in
    /// which case stalling *is* the bounded-staleness guarantee.
    pub fn post(&self, from: usize, flip: Flip) {
        for c in 0..self.shards {
            if c == from {
                continue;
            }
            let ring = &self.rings[from * self.shards + c];
            while !ring.try_push(flip) {
                std::thread::yield_now();
            }
        }
    }

    /// Drain every flip pending for shard `to`, in per-producer FIFO
    /// order (producers are visited in shard order; cross-producer
    /// ordering is whatever the race produced — the field updates are
    /// commutative integer adds, so it does not matter).
    pub fn drain(&self, to: usize, mut apply: impl FnMut(Flip)) {
        for p in 0..self.shards {
            if p == to {
                continue;
            }
            let ring = &self.rings[p * self.shards + to];
            while let Some(flip) = ring.pop() {
                apply(flip);
            }
        }
    }

    /// Total flips currently pending for shard `to` (diagnostic).
    pub fn pending(&self, to: usize) -> usize {
        (0..self.shards)
            .filter(|&p| p != to)
            .map(|p| self.rings[p * self.shards + to].len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ring_fifo_and_capacity() {
        let r = FlipRing::new(3); // rounds up to 4
        assert_eq!(r.capacity(), 4);
        for k in 0..4u32 {
            assert!(r.try_push(Flip { j: k, s_old: 1, step: k as u64 }));
        }
        assert!(!r.try_push(Flip { j: 99, s_old: -1, step: 0 }), "full ring must refuse");
        for k in 0..4u32 {
            assert_eq!(r.pop().unwrap().j, k, "FIFO order");
        }
        assert!(r.pop().is_none());
        // Wrap-around reuse after draining.
        assert!(r.try_push(Flip { j: 7, s_old: -1, step: 9 }));
        assert_eq!(r.pop(), Some(Flip { j: 7, s_old: -1, step: 9 }));
    }

    #[test]
    fn ring_delivers_across_threads_in_order() {
        let r = Arc::new(FlipRing::new(8));
        let total = 10_000u32;
        let producer = {
            let r = r.clone();
            std::thread::spawn(move || {
                for k in 0..total {
                    while !r.try_push(Flip { j: k, s_old: 1, step: k as u64 }) {
                        std::thread::yield_now();
                    }
                }
            })
        };
        let mut next = 0u32;
        while next < total {
            if let Some(f) = r.pop() {
                assert_eq!(f.j, next, "lost or reordered flip");
                next += 1;
            } else {
                std::thread::yield_now();
            }
        }
        producer.join().unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn grid_routes_to_every_peer_but_not_self() {
        let g = MailboxGrid::new(3, 8);
        g.post(0, Flip { j: 5, s_old: -1, step: 2 });
        g.post(1, Flip { j: 9, s_old: 1, step: 4 });
        assert_eq!(g.pending(0), 1); // from shard 1
        assert_eq!(g.pending(1), 1); // from shard 0
        assert_eq!(g.pending(2), 2); // from both
        let mut got = Vec::new();
        g.drain(2, |f| got.push(f.j));
        got.sort_unstable();
        assert_eq!(got, vec![5, 9]);
        assert_eq!(g.pending(2), 0);
        let mut own = Vec::new();
        g.drain(0, |f| own.push(f.j));
        assert_eq!(own, vec![9], "shard 0 must not receive its own flip");
    }
}
