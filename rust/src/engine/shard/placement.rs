//! First-touch NUMA placement of per-lane coupling rows
//! (`EngineConfig::local_rows`).
//!
//! A pinned shard lane spends its life walking the same column window
//! `[lo, hi)` of the coupling matrix — every local flip and every
//! remote flip folds one row segment into its fields. With the matrix
//! allocated once by the coordinator, those segments live wherever the
//! allocator's first writer touched them, which on a multi-socket host
//! is usually one node serving every lane across the interconnect.
//!
//! [`LocalRows`] is the fix, with no libnuma dependency: each lane
//! *copies* its own row slice — dense rows as a packed column slab at
//! the model's storage tier, CSR rows as `row_range` segments — and
//! the copy is built **on the lane's pinned thread**, so Linux's
//! default first-touch page placement lands the pages on that thread's
//! NUMA node. The async shard engine materializes the copy whenever
//! `local_rows` is on — pair it with `pin_lanes`, since an unpinned
//! lane can migrate away from its copy (leaving only the
//! pre-sliced-row win: CSR windows keep their two binary searches
//! paid once at build either way);
//! the bit-plane datapath keeps its shared column store and never
//! copies. The values are byte-for-byte the shared matrix's, so runs
//! are bit-identical with the knob on or off — `local_rows` trades
//! `ShardStats::local_row_bytes` of duplicated memory (the dense slabs
//! across all lanes sum to one extra matrix copy) for node-local row
//! walks.

use crate::ising::{Adjacency, IsingModel, JRow, Tier};
use std::ops::Range;

/// A lane-local copy of the coupling rows restricted to the lane's
/// column window — see the module docs for the placement contract.
pub struct LocalRows {
    slab: Slab,
}

enum Slab {
    /// Dense column slab: row `j` of the model, columns `lo..hi`,
    /// packed contiguously at the model's tier (`n` rows of `width`).
    Dense { width: usize, data: DenseData },
    /// CSR segments: row `j`'s in-window entries, global column
    /// indices, `i32` weights — the exact `Adjacency::row_range`
    /// output, concatenated.
    Csr { offsets: Vec<usize>, cols: Vec<u32>, vals: Vec<i32> },
}

enum DenseData {
    I8(Vec<i8>),
    I16(Vec<i16>),
    I32(Vec<i32>),
}

impl LocalRows {
    /// Copy the rows for a lane owning `range`. CSR when the engine
    /// built an adjacency (sparse instances), dense otherwise — the
    /// same gate (`MAX_CSR_DENSITY`) the flip path dispatches on, so
    /// the materialized form always matches the walk that consumes it.
    /// Call this on the lane's pinned thread: the copy's pages are
    /// placed by first touch.
    pub fn build(model: &IsingModel, adj: Option<&Adjacency>, range: Range<usize>) -> Self {
        let n = model.len();
        let slab = match adj {
            Some(adj) => {
                let mut offsets = Vec::with_capacity(n + 1);
                offsets.push(0usize);
                let mut cols = Vec::new();
                let mut vals = Vec::new();
                for j in 0..n {
                    let (neigh, w) = adj.row_range(j, range.clone());
                    cols.extend_from_slice(neigh);
                    vals.extend_from_slice(w);
                    offsets.push(cols.len());
                }
                Slab::Csr { offsets, cols, vals }
            }
            None => {
                let width = range.len();
                let mut data = match model.tier() {
                    Tier::I8 => DenseData::I8(Vec::with_capacity(n * width)),
                    Tier::I16 => DenseData::I16(Vec::with_capacity(n * width)),
                    Tier::I32 => DenseData::I32(Vec::with_capacity(n * width)),
                };
                for j in 0..n {
                    match (model.j_row(j).slice(range.clone()), &mut data) {
                        (JRow::I8(s), DenseData::I8(v)) => v.extend_from_slice(s),
                        (JRow::I16(s), DenseData::I16(v)) => v.extend_from_slice(s),
                        (JRow::I32(s), DenseData::I32(v)) => v.extend_from_slice(s),
                        // The tier is fixed for the model borrow's
                        // lifetime (stores widen only on mutation).
                        _ => unreachable!("model tier changed mid-build"),
                    }
                }
                Slab::Dense { width, data }
            }
        };
        Self { slab }
    }

    /// Row `j`'s dense column window as a typed slice — identical
    /// values to `model.j_row(j).slice(lo..hi)`, lane-local memory.
    /// Only valid for dense-built rows.
    #[inline(always)]
    pub fn dense_row(&self, j: usize) -> JRow<'_> {
        match &self.slab {
            Slab::Dense { width, data } => {
                let (a, b) = (j * width, (j + 1) * width);
                match data {
                    DenseData::I8(v) => JRow::I8(&v[a..b]),
                    DenseData::I16(v) => JRow::I16(&v[a..b]),
                    DenseData::I32(v) => JRow::I32(&v[a..b]),
                }
            }
            Slab::Csr { .. } => panic!("dense_row on a CSR-built LocalRows"),
        }
    }

    /// Row `j`'s in-window CSR segment — identical slices to
    /// `adj.row_range(j, lo..hi)`, lane-local memory, O(1) lookup
    /// (the two binary searches were paid once at build). Only valid
    /// for CSR-built rows.
    #[inline(always)]
    pub fn csr_row(&self, j: usize) -> (&[u32], &[i32]) {
        match &self.slab {
            Slab::Csr { offsets, cols, vals } => {
                let (a, b) = (offsets[j], offsets[j + 1]);
                (&cols[a..b], &vals[a..b])
            }
            Slab::Dense { .. } => panic!("csr_row on a dense-built LocalRows"),
        }
    }

    /// Bytes this copy keeps resident on the lane's node — what
    /// `ShardStats::local_row_bytes` aggregates.
    pub fn resident_bytes(&self) -> usize {
        match &self.slab {
            Slab::Dense { data, .. } => match data {
                DenseData::I8(v) => v.len(),
                DenseData::I16(v) => v.len() * 2,
                DenseData::I32(v) => v.len() * 4,
            },
            Slab::Csr { offsets, cols, vals } => {
                offsets.len() * std::mem::size_of::<usize>() + cols.len() * 4 + vals.len() * 4
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::StatelessRng;

    #[test]
    fn dense_slab_matches_model_rows() {
        let rng = StatelessRng::new(41);
        // Dense-ish instance; tier i8 (±1 weights).
        let p = MaxCut::new(generators::complete(40, &[-1, 1], &rng));
        let m = p.model();
        for range in [0usize..13, 13..40, 0..40, 20..20] {
            let local = LocalRows::build(m, None, range.clone());
            let mut want_bytes = 0usize;
            for j in 0..m.len() {
                let got: Vec<i32> = local.dense_row(j).iter().collect();
                let want: Vec<i32> = m.j_row(j).slice(range.clone()).iter().collect();
                assert_eq!(got, want, "row {j}, range {range:?}");
                want_bytes += range.len() * m.tier().bytes_per_coupling();
            }
            assert_eq!(local.resident_bytes(), want_bytes, "range {range:?}");
        }
    }

    #[test]
    fn csr_slab_matches_row_range() {
        let rng = StatelessRng::new(43);
        let p = MaxCut::new(generators::erdos_renyi(60, 150, &[-2, -1, 1, 2], &rng));
        let m = p.model();
        let adj = m.adjacency();
        for range in [0usize..21, 21..47, 47..60, 0..60] {
            let local = LocalRows::build(m, Some(&adj), range.clone());
            for j in 0..m.len() {
                let (gn, gv) = local.csr_row(j);
                let (wn, wv) = adj.row_range(j, range.clone());
                assert_eq!((gn, gv), (wn, wv), "row {j}, range {range:?}");
            }
            assert!(local.resident_bytes() > 0);
        }
    }
}
