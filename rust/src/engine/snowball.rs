//! The Snowball engine: dual-mode MCMC spin selection with asynchronous
//! single-spin updates (paper §IV-A, Algorithm 1; hardware datapath of
//! §IV-B3).
//!
//! Two selection modes share one datapath:
//!
//! * **Mode I — random-scan (RSA)**: uniform random site, Glauber accept.
//!   Satisfies detailed balance wrt the Gibbs distribution (Eqs. 6–9).
//! * **Mode II — roulette-wheel (RWA)**: flip probabilities for all N
//!   spins are evaluated in parallel, ONE spin is sampled with probability
//!   ∝ p_flip (Eq. 10/29) and flipped deterministically (rejection-free).
//!   Falls back to Mode I when the aggregate weight degenerates (W == 0);
//!   the optional *uniformized* variant compares W against W* = N and
//!   null-transitions with probability 1 − W/W* (§IV-B3c).
//!
//! Exactly one spin is updated per step in either mode, and its effect is
//! propagated to all local fields immediately (asynchronous update,
//! Eq. 12/17) — `u` is never stale.
//!
//! Two interchangeable datapaths compute those field updates:
//! `Datapath::Dense` walks the i32 coupling row (the CPU-fast hot path),
//! `Datapath::BitPlane` streams the column-major bit-planes word by word
//! (bit-faithful to the FPGA; same results, verified by tests).
//!
//! The per-step selection/update machinery itself — lane weights,
//! Fenwick tree, dirty-set refresh, flip application — lives in the
//! shared [`LaneKernel`](super::lane::LaneKernel); this engine is its
//! single-lane (`range == 0..N`) instantiation, and the sharded engine
//! ([`crate::engine::shard`]) composes S range-restricted instances of
//! the same kernel.

use super::lane::{LaneKernel, MAX_CSR_DENSITY};
use super::lut::{PwlLogistic, ONE_Q16};
use super::schedule::Schedule;
use super::select::SelectorKind;
use crate::bitplane::BitPlanes;
use crate::ising::{Adjacency, IsingModel, SpinVec};
use crate::rng::{salt, StatelessRng};
use crate::stop::{StopCause, StopToken};

/// How often the run loop polls its [`StopToken`]: one `Acquire` load
/// every this many steps — noise next to a single step's field walk,
/// yet ~10⁴× finer than any millisecond-scale deadline needs.
pub const STOP_CHECK_STRIDE: u64 = 64;

/// Spin-selection mode (the paper's dual-mode switch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Mode I: random-scan + Glauber accept (RSA).
    RandomScan,
    /// Mode II: roulette-wheel selection, rejection-free (RWA).
    RouletteWheel,
    /// Mode II with uniformization against W* = N (null transitions).
    RouletteUniformized,
}

impl Mode {
    /// CLI names.
    pub fn parse(s: &str) -> anyhow::Result<Mode> {
        match s {
            "rsa" | "random-scan" => Ok(Mode::RandomScan),
            "rwa" | "roulette" => Ok(Mode::RouletteWheel),
            "rwa-uniform" | "uniformized" => Ok(Mode::RouletteUniformized),
            other => anyhow::bail!("unknown mode '{other}' (rsa|rwa|rwa-uniform)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::RandomScan => "RSA",
            Mode::RouletteWheel => "RWA",
            Mode::RouletteUniformized => "RWA-U",
        }
    }
}

/// Which field-update datapath to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Datapath {
    /// Dense i32 row walk (CPU hot path).
    Dense,
    /// Column-major bit-plane streaming (hardware-faithful, Eqs. 19–20).
    BitPlane,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub mode: Mode,
    pub datapath: Datapath,
    /// Mode II selection implementation (Fenwick tree vs legacy scan);
    /// both produce bit-identical runs, differing only in per-step cost.
    pub selector: SelectorKind,
    pub schedule: Schedule,
    /// Total Monte Carlo steps (one selected spin per step).
    pub steps: u64,
    pub seed: u64,
    /// Bit-planes to allocate (None = minimum for the instance).
    pub planes: Option<u32>,
    /// Record `(step, energy)` every `trace_stride` steps (0 = off).
    pub trace_stride: u64,
    /// Within-instance shard lanes (see [`crate::engine::shard`]).
    /// `SnowballEngine` itself is the single-lane engine and ignores
    /// this; [`crate::engine::ShardedEngine`] partitions the instance
    /// into this many lanes (clamped to `[1, min(N, MAX_SHARDS)]`).
    pub shards: usize,
    /// Pin each shard lane thread round-robin over the process's
    /// *allowed* CPU set (`sched_setaffinity`, Linux only; a no-op
    /// elsewhere — see [`crate::engine::shard::affinity`]). Only the
    /// async sharded engine consults this; the single-lane engine and
    /// the virtual-time merge run on the caller's thread.
    pub pin_lanes: bool,
    /// Materialize each shard lane's coupling-row window into memory
    /// the lane's own (pinned) thread first-touches, so multi-socket
    /// hosts serve row walks from the local NUMA node
    /// ([`crate::engine::shard::placement`]). Bit-identical results
    /// either way; only the async sharded engine consults this, and it
    /// is intended to pair with `pin_lanes` (an unpinned lane can
    /// migrate away from its copy, keeping only the pre-sliced-row
    /// win). Ignored by the bit-plane datapath, which keeps its shared
    /// column store.
    pub local_rows: bool,
}

impl EngineConfig {
    /// A sensible default: RWA, dense datapath, Fenwick selection,
    /// geometric cooling.
    pub fn new(mode: Mode, steps: u64, seed: u64) -> Self {
        Self {
            mode,
            datapath: Datapath::Dense,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 10.0, t1: 0.05 },
            steps,
            seed,
            planes: None,
            trace_stride: 0,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
        }
    }

    /// The flip-application data sources this config implies for
    /// `model`: `(CSR adjacency, bit-plane store)`, at most one
    /// `Some` (both `None` = dense row walk). The ONE derivation the
    /// single-lane engine and both sharded modes share — if the CSR
    /// density gate or plane sizing ever changes, it changes for all
    /// three at once, so the bit-identity contract cannot drift.
    pub(crate) fn field_sources(
        &self,
        model: &IsingModel,
    ) -> (Option<Adjacency>, Option<BitPlanes>) {
        match self.datapath {
            Datapath::Dense => (Adjacency::build_if_sparse(model, MAX_CSR_DENSITY), None),
            Datapath::BitPlane => (None, Some(BitPlanes::encode(model, self.planes))),
        }
    }

    /// True when Mode II selection runs the incremental Fenwick /
    /// dirty-set path (shared gate of the engine and the shard lanes).
    pub(crate) fn incremental_selection(&self) -> bool {
        matches!(self.mode, Mode::RouletteWheel | Mode::RouletteUniformized)
            && self.selector == SelectorKind::Fenwick
    }
}

/// Outcome of one run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub best_energy: i64,
    pub best_step: u64,
    pub best_spins: SpinVec,
    pub final_energy: i64,
    pub final_spins: SpinVec,
    /// `(step, energy)` samples when tracing was enabled.
    pub trace: Vec<(u64, i64)>,
    /// Steps actually executed — `cfg.steps` unless the run was
    /// preempted (then [`stopped`](Self::stopped) says why).
    pub steps: u64,
    /// Accepted flips (== steps − nulls − rejected in Mode I).
    pub flips: u64,
    /// Mode II → Mode I fallbacks (W == 0).
    pub fallbacks: u64,
    /// Uniformized null transitions.
    pub nulls: u64,
    pub wall: std::time::Duration,
    /// `Some(cause)` when a [`StopToken`] preempted the run before
    /// `cfg.steps`; the best/final state is the valid best-so-far
    /// incumbent at the preemption point.
    pub stopped: Option<StopCause>,
}

/// A point-in-time snapshot of a running engine, sufficient to resume
/// the run **bit-identically** (see
/// [`SnowballEngine::from_checkpoint`]): the stateless RNG is keyed by
/// `(seed, t, salt)` and the schedule temperature is a pure function
/// of `(t, steps)`, so replaying from `(spins, step)` regenerates
/// exactly the trajectory an uninterrupted run would have taken.
///
/// This is what the coordinator's `JobJournal` stores per replica —
/// the checkpoint/retry path never re-materializes coupling state (the
/// model stays shared) and never re-runs completed steps.
#[derive(Clone, Debug)]
pub struct EngineCheckpoint {
    /// The engine seed (`cfg.seed` — already the per-replica child
    /// seed when the scheduler took the snapshot).
    pub seed: u64,
    /// Steps already executed; the resumed loop starts at `t = step`.
    pub step: u64,
    /// Chain configuration at `step`.
    pub spins: SpinVec,
    /// Energy of `spins` (cross-checked on resume).
    pub energy: i64,
    pub best_energy: i64,
    pub best_step: u64,
    pub best_spins: SpinVec,
    /// Cumulative counters at `step`, carried across resume so the
    /// final `RunResult` is identical to an uninterrupted run's.
    pub flips: u64,
    pub fallbacks: u64,
    pub nulls: u64,
}

/// The Snowball engine over one Ising instance.
pub struct SnowballEngine<'m> {
    model: &'m IsingModel,
    cfg: EngineConfig,
    lut: PwlLogistic,
    rng: StatelessRng,
    bitplanes: Option<BitPlanes>,
    /// CSR adjacency for sparse dense-datapath instances: Θ(deg) field
    /// updates with an exact touched-lane report.
    adj: Option<Adjacency>,
    /// The single full-range lane: spins, local fields
    /// `u_i = u_i^(J) + h_i` (h folded in at init; every update path
    /// only ever adds coupler deltas, Eq. 12), Mode II lane weights and
    /// the incremental Fenwick/dirty-set state.
    kernel: LaneKernel,
    energy: i64,
}

impl<'m> SnowballEngine<'m> {
    /// Build an engine; initial spins drawn from the stateless RNG.
    pub fn new(model: &'m IsingModel, cfg: EngineConfig) -> Self {
        let rng = StatelessRng::new(cfg.seed);
        let spins = SpinVec::random(model.len(), &rng);
        Self::with_spins(model, cfg, spins)
    }

    /// Rebuild an engine from a [`EngineCheckpoint`], ready for
    /// [`run_session`](Self::run_session) with `resume = Some(ck)`.
    /// Local fields and energy are recomputed from the snapshot spins
    /// (the cheap part — the model itself is shared, never rebuilt).
    pub fn from_checkpoint(
        model: &'m IsingModel,
        cfg: EngineConfig,
        ck: &EngineCheckpoint,
    ) -> Self {
        assert_eq!(cfg.seed, ck.seed, "resume must reuse the checkpointed seed");
        Self::with_spins(model, cfg, ck.spins.clone())
    }

    /// Build with an explicit initial configuration.
    pub fn with_spins(model: &'m IsingModel, cfg: EngineConfig, spins: SpinVec) -> Self {
        assert_eq!(spins.len(), model.len());
        let rng = StatelessRng::new(cfg.seed);
        let (adj, bitplanes) = cfg.field_sources(model);
        let u = model.local_fields(&spins);
        let energy = model.energy(&spins);
        let n = model.len();
        let lut = PwlLogistic::default();
        let kernel = LaneKernel::new(0..n, &spins, &u, &lut, cfg.incremental_selection());
        Self { model, cfg, lut, rng, bitplanes, adj, kernel, energy }
    }

    /// Current spins.
    pub fn spins(&self) -> &SpinVec {
        self.kernel.spins()
    }

    /// Current local fields.
    pub fn fields(&self) -> &[i64] {
        self.kernel.fields()
    }

    /// Current (incrementally tracked) energy.
    pub fn energy(&self) -> i64 {
        self.energy
    }

    /// The PWL LUT in use.
    pub fn lut(&self) -> &PwlLogistic {
        &self.lut
    }

    /// Run the configured number of steps.
    pub fn run(&mut self) -> RunResult {
        self.run_with_stop(&StopToken::new())
    }

    /// Run, checking `stop` at [`STOP_CHECK_STRIDE`]-step boundaries; a
    /// tripped token returns the best-so-far incumbent as a well-formed
    /// partial result (`stopped = Some(cause)`).
    pub fn run_with_stop(&mut self, stop: &StopToken) -> RunResult {
        self.run_session(stop, None, 0, |_| {})
    }

    /// The full-control run loop behind [`run`](Self::run): cooperative
    /// preemption via `stop`, optional resume from a checkpoint, and
    /// periodic checkpoint capture.
    ///
    /// * `resume` — continue a run snapshot taken by an earlier
    ///   session; the engine must have been built with
    ///   [`from_checkpoint`](Self::from_checkpoint) on the same
    ///   checkpoint. Because every RNG draw is keyed by `(seed, t,
    ///   salt)` and the temperature is a pure function of `(t, steps)`,
    ///   the resumed trajectory is **bit-identical** to an
    ///   uninterrupted run (pinned by `tests/lifecycle.rs`); only
    ///   `trace` (covers the resumed tail) and `wall` differ.
    /// * `checkpoint_stride` — hand a fresh [`EngineCheckpoint`] to
    ///   `on_checkpoint` every that-many steps (0 = never). Capture
    ///   draws nothing from the RNG, so checkpointing cannot perturb
    ///   the run.
    pub fn run_session(
        &mut self,
        stop: &StopToken,
        resume: Option<&EngineCheckpoint>,
        checkpoint_stride: u64,
        mut on_checkpoint: impl FnMut(&EngineCheckpoint),
    ) -> RunResult {
        let start = std::time::Instant::now();
        let steps = self.cfg.steps;
        let t0 = resume.map_or(0, |ck| ck.step);
        if let Some(ck) = resume {
            assert_eq!(ck.seed, self.cfg.seed, "resume must reuse the checkpointed seed");
            assert_eq!(
                ck.energy, self.energy,
                "resume state mismatch: engine was not built from this checkpoint"
            );
        }
        let mut best_energy = resume.map_or(self.energy, |ck| ck.best_energy);
        let mut best_step = resume.map_or(0, |ck| ck.best_step);
        let mut best_spins =
            resume.map_or_else(|| self.kernel.spins().clone(), |ck| ck.best_spins.clone());
        let mut trace = Vec::new();
        let mut flips = resume.map_or(0, |ck| ck.flips);
        let mut fallbacks = resume.map_or(0, |ck| ck.fallbacks);
        let mut nulls = resume.map_or(0, |ck| ck.nulls);
        let mut executed = t0;
        let mut stopped = None;
        if self.cfg.trace_stride > 0 && t0 == 0 {
            trace.push((0, self.energy));
        }
        for t in t0..steps {
            if t % STOP_CHECK_STRIDE == 0 {
                if let Some(cause) = stop.get() {
                    stopped = Some(cause);
                    break;
                }
            }
            let temp = self.cfg.schedule.temperature(t, steps);
            let outcome = self.step(t, temp);
            match outcome {
                StepOutcome::Flipped(_) => flips += 1,
                StepOutcome::FallbackFlipped(_) => {
                    flips += 1;
                    fallbacks += 1;
                }
                StepOutcome::FallbackRejected => fallbacks += 1,
                StepOutcome::Null => nulls += 1,
                StepOutcome::Rejected => {}
            }
            if self.energy < best_energy {
                best_energy = self.energy;
                best_step = t + 1;
                // Overwrite the preallocated buffer — no allocation on
                // the (frequent, early-anneal) improvement path.
                best_spins.assign_from(self.kernel.spins());
            }
            if self.cfg.trace_stride > 0 && (t + 1) % self.cfg.trace_stride == 0 {
                trace.push((t + 1, self.energy));
            }
            executed = t + 1;
            if checkpoint_stride > 0 && (t + 1) % checkpoint_stride == 0 && t + 1 < steps {
                let ck = EngineCheckpoint {
                    seed: self.cfg.seed,
                    step: t + 1,
                    spins: self.kernel.spins().clone(),
                    energy: self.energy,
                    best_energy,
                    best_step,
                    best_spins: best_spins.clone(),
                    flips,
                    fallbacks,
                    nulls,
                };
                on_checkpoint(&ck);
                crate::failpoint::hit("engine.checkpoint");
            }
        }
        RunResult {
            best_energy,
            best_step,
            best_spins,
            final_energy: self.energy,
            final_spins: self.kernel.spins().clone(),
            trace,
            steps: executed,
            flips,
            fallbacks,
            nulls,
            wall: start.elapsed(),
            stopped,
        }
    }

    /// One Monte Carlo step at temperature `temp` (public for tests and
    /// the hardware-sim cycle accounting).
    pub fn step(&mut self, t: u64, temp: f64) -> StepOutcome {
        match self.cfg.mode {
            Mode::RandomScan => self.step_random_scan(t, temp, false),
            Mode::RouletteWheel => self.step_roulette(t, temp, false),
            Mode::RouletteUniformized => self.step_roulette(t, temp, true),
        }
    }

    /// Mode I (paper §IV-B3b): select uniformly, Glauber accept.
    fn step_random_scan(&mut self, t: u64, temp: f64, is_fallback: bool) -> StepOutcome {
        let n = self.model.len() as u32;
        let j = self.rng.below(t, 0, salt::SITE, n) as usize; // Eq. 22
        let de = self.kernel.delta_e(j); // Eq. 24
        let p = self.lut.flip_prob_q16(de, temp); // Eq. 25
        let r = self.rng.u32(t, 0, salt::ACCEPT) >> 16; // 16-bit uniform
        if r < p {
            self.apply_flip(j);
            if is_fallback {
                StepOutcome::FallbackFlipped(j)
            } else {
                StepOutcome::Flipped(j)
            }
        } else if is_fallback {
            StepOutcome::FallbackRejected
        } else {
            StepOutcome::Rejected
        }
    }

    /// Mode II (paper §IV-B3c): evaluate all spins, roulette-select one,
    /// flip deterministically.
    ///
    /// Two bit-identical implementations share this entry point, both
    /// inside [`LaneKernel`]. The legacy scan re-evaluates all N lanes
    /// and prefix-scans them every step (Θ(N) twice). The Fenwick path
    /// keeps the lane weights and their tree current incrementally —
    /// inside a temperature plateau only the lanes whose local field
    /// actually changed since the last flip are re-evaluated (Θ(deg)
    /// with CSR/bit-plane delta reports, a bulk kernel refresh on the
    /// dense row walk), and selection descends the tree in Θ(log N).
    fn step_roulette(&mut self, t: u64, temp: f64, uniformized: bool) -> StepOutcome {
        let n = self.model.len();
        let w_total = self.kernel.sync_weights(&self.lut, temp);
        if w_total == 0 {
            // Degenerate aggregate weight → sequential fallback (paper:
            // "falls back to a conventional one-site update").
            return self.step_random_scan(t, temp, true);
        }
        // Uniformization: compare W against the fixed max rate W* = N
        // (in Q16, N·2^16); null transition with probability 1 − W/W*.
        let w_star = (n as u64) * ONE_Q16 as u64;
        let draw_domain = if uniformized { w_star } else { w_total };
        let r = self.draw_below(t, draw_domain);
        if uniformized && r >= w_total {
            return StepOutcome::Null;
        }
        let chosen = self.kernel.select_local(r);
        self.apply_flip(chosen);
        StepOutcome::Flipped(chosen)
    }

    /// Uniform draw in [0, bound) from the stateless stream (64-bit
    /// fixed-point multiply; bias < 2^-64).
    #[inline(always)]
    fn draw_below(&self, t: u64, bound: u64) -> u64 {
        let raw = self.rng.u64(t, 0, salt::ROULETTE);
        ((raw as u128 * bound as u128) >> 64) as u64
    }

    /// Flip spin `j` and propagate to all local fields (asynchronous
    /// update, Eqs. 12/17/27/31) and the tracked energy — one call into
    /// the shared kernel, which also reports every touched field into
    /// the Fenwick dirty set (when one is active), so the incremental
    /// lane maintenance never misses a changed `u_i`.
    fn apply_flip(&mut self, j: usize) {
        let (_, _, de) =
            self.kernel.flip_local(self.model, self.adj.as_ref(), self.bitplanes.as_ref(), j);
        self.energy += de;
    }
}

/// What a single step did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepOutcome {
    /// A spin was flipped (index).
    Flipped(usize),
    /// Mode I rejected the proposal.
    Rejected,
    /// Mode II fell back to Mode I and flipped.
    FallbackFlipped(usize),
    /// Mode II fell back to Mode I and rejected.
    FallbackRejected,
    /// Uniformized null transition.
    Null,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    fn small_instance(seed: u64) -> MaxCut {
        let rng = StatelessRng::new(seed);
        MaxCut::new(generators::erdos_renyi(48, 200, &[-1, 1], &rng))
    }

    #[test]
    fn energy_tracking_is_exact_all_modes_and_datapaths() {
        let p = small_instance(101);
        for mode in [Mode::RandomScan, Mode::RouletteWheel, Mode::RouletteUniformized] {
            for dp in [Datapath::Dense, Datapath::BitPlane] {
                let mut cfg = EngineConfig::new(mode, 300, 7);
                cfg.datapath = dp;
                let mut e = SnowballEngine::new(p.model(), cfg);
                for t in 0..300 {
                    e.step(t, 1.5);
                }
                assert_eq!(
                    e.energy(),
                    p.model().energy(e.spins()),
                    "incremental energy drifted ({mode:?}, {dp:?})"
                );
                assert_eq!(
                    e.fields(),
                    &p.model().local_fields(e.spins())[..],
                    "incremental fields drifted ({mode:?}, {dp:?})"
                );
            }
        }
    }

    #[test]
    fn dense_and_bitplane_paths_agree_exactly() {
        let p = small_instance(102);
        let mk = |dp| {
            let mut cfg = EngineConfig::new(Mode::RouletteWheel, 500, 99);
            cfg.datapath = dp;
            let mut e = SnowballEngine::new(p.model(), cfg);
            let r = e.run();
            (r.best_energy, r.final_energy, r.flips)
        };
        assert_eq!(mk(Datapath::Dense), mk(Datapath::BitPlane));
    }

    #[test]
    fn annealing_finds_low_energy() {
        let p = small_instance(103);
        let mut cfg = EngineConfig::new(Mode::RouletteWheel, 4000, 3);
        cfg.schedule = Schedule::Geometric { t0: 6.0, t1: 0.02 };
        let mut e = SnowballEngine::new(p.model(), cfg);
        let r = e.run();
        // Random config has expected energy 0; the anneal must do far
        // better (cut ≥ |E|·0.55 empirically on ±1 ER graphs).
        let cut = p.cut_of_energy(r.best_energy);
        assert!(cut > 0, "cut {cut} not positive");
        assert!(r.best_energy < -40, "best energy {} too high", r.best_energy);
    }

    #[test]
    fn rwa_is_rejection_free_at_positive_temperature() {
        let p = small_instance(104);
        let mut cfg = EngineConfig::new(Mode::RouletteWheel, 200, 11);
        // Warm enough that p_flip never underflows the Q16 LUT: W > 0
        // every step → no fallbacks, a flip every step (the paper's
        // "rejection-free" property). (At very low T the Q16 lanes can
        // all quantize to zero — that is exactly the W == 0 fallback
        // case, covered by `rwa_falls_back_when_frozen`.)
        cfg.schedule = Schedule::Constant(2.0);
        let mut e = SnowballEngine::new(p.model(), cfg);
        let r = e.run();
        assert_eq!(r.fallbacks, 0);
        assert_eq!(r.flips, r.steps);
    }

    #[test]
    fn rwa_falls_back_when_frozen() {
        // Construct a state where every flip is strictly uphill: aligned
        // 2-spin ferromagnet. At T = 0 all p == 0 → W == 0 → Mode II must
        // fall back to Mode I (which then rejects the uphill move).
        let mut m = IsingModel::zeros(2);
        m.set_j(0, 1, 1);
        let cfg = EngineConfig::new(Mode::RouletteWheel, 0, 13);
        let mut e = SnowballEngine::with_spins(&m, cfg, SpinVec::from_spins(&[1, 1]));
        for t in 0..20 {
            match e.step(t, 0.0) {
                StepOutcome::FallbackRejected => {}
                other => panic!("expected FallbackRejected, got {other:?}"),
            }
        }
        assert_eq!(e.energy(), -1, "ground state must be undisturbed");
    }

    #[test]
    fn uniformized_mode_takes_null_transitions() {
        let p = small_instance(106);
        let mut cfg = EngineConfig::new(Mode::RouletteUniformized, 500, 17);
        // Low temperature → small W → mostly null transitions.
        cfg.schedule = Schedule::Constant(0.3);
        let mut e = SnowballEngine::new(p.model(), cfg);
        let r = e.run();
        assert!(r.nulls > 0, "uniformized chain never nulled");
        assert_eq!(r.nulls + r.flips + r.fallbacks, r.steps);
    }

    #[test]
    fn runs_are_reproducible_by_seed() {
        let p = small_instance(107);
        let run = |seed| {
            let mut e = SnowballEngine::new(p.model(), EngineConfig::new(Mode::RouletteWheel, 300, seed));
            e.run().final_energy
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn trace_records_at_stride() {
        let p = small_instance(108);
        let mut cfg = EngineConfig::new(Mode::RandomScan, 100, 1);
        cfg.trace_stride = 25;
        let mut e = SnowballEngine::new(p.model(), cfg);
        let r = e.run();
        let steps: Vec<u64> = r.trace.iter().map(|&(s, _)| s).collect();
        assert_eq!(steps, vec![0, 25, 50, 75, 100]);
    }

    /// A pre-tripped stop token preempts the run at the first check
    /// boundary with a well-formed partial result; an untripped one is
    /// invisible.
    #[test]
    fn stop_token_preempts_with_valid_partial_result() {
        let p = small_instance(110);
        let cfg = EngineConfig::new(Mode::RouletteWheel, 5_000, 19);
        let stop = StopToken::new();
        stop.trip(StopCause::Cancel);
        let mut e = SnowballEngine::new(p.model(), cfg.clone());
        let r = e.run_with_stop(&stop);
        assert_eq!(r.stopped, Some(StopCause::Cancel));
        assert_eq!(r.steps, 0, "pre-tripped token stops at the first boundary");
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins), "incumbent must be valid");

        let mut e = SnowballEngine::new(p.model(), cfg);
        let r = e.run_with_stop(&StopToken::new());
        assert_eq!(r.stopped, None);
        assert_eq!(r.steps, 5_000);
    }

    /// Checkpoint capture + resume is bit-identical to the
    /// uninterrupted run (the contract the coordinator's retry path
    /// builds on), and capture itself never perturbs the trajectory.
    #[test]
    fn checkpoint_resume_is_bit_identical() {
        let p = small_instance(111);
        let cfg = EngineConfig::new(Mode::RouletteWheel, 2_000, 23);
        let baseline = SnowballEngine::new(p.model(), cfg.clone()).run();

        let mut checkpoints = Vec::new();
        let mut e = SnowballEngine::new(p.model(), cfg.clone());
        let observed =
            e.run_session(&StopToken::new(), None, 300, |ck| checkpoints.push(ck.clone()));
        assert_eq!(observed.best_energy, baseline.best_energy, "capture perturbed the run");
        assert_eq!(observed.final_energy, baseline.final_energy);
        assert_eq!(checkpoints.len(), 6, "2000/300 interior checkpoints");

        // Resume from EVERY checkpoint: identical observable run tuple.
        for ck in &checkpoints {
            let mut r = SnowballEngine::from_checkpoint(p.model(), cfg.clone(), ck);
            let resumed = r.run_session(&StopToken::new(), Some(ck), 0, |_| {});
            assert_eq!(resumed.best_energy, baseline.best_energy, "resume from {}", ck.step);
            assert_eq!(resumed.best_step, baseline.best_step);
            assert_eq!(resumed.best_spins, baseline.best_spins);
            assert_eq!(resumed.final_energy, baseline.final_energy);
            assert_eq!(resumed.final_spins, baseline.final_spins);
            assert_eq!(resumed.steps, baseline.steps);
            assert_eq!(
                (resumed.flips, resumed.fallbacks, resumed.nulls),
                (baseline.flips, baseline.fallbacks, baseline.nulls),
                "cumulative counters must carry across resume (from {})",
                ck.step
            );
            assert_eq!(resumed.stopped, None);
        }
    }

    /// Statistical check of the detailed-balance consequence: at fixed T
    /// the random-scan chain's empirical distribution over a tiny model
    /// matches the Gibbs distribution.
    #[test]
    fn rsa_samples_gibbs_on_tiny_model() {
        let mut m = IsingModel::zeros(3);
        m.set_j(0, 1, 1);
        m.set_j(1, 2, -1);
        m.set_h(0, 1);
        let t = 2.0;
        let mut cfg = EngineConfig::new(Mode::RandomScan, 0, 21);
        cfg.schedule = Schedule::Constant(t);
        let mut e = SnowballEngine::new(&m, cfg);
        // Burn-in.
        for step in 0..2000 {
            e.step(step, t);
        }
        let mut counts = [0u64; 8];
        let samples = 400_000u64;
        for step in 0..samples {
            e.step(2000 + step, t);
            let idx = (0..3).fold(0usize, |a, i| a | ((e.spins().bit(i) as usize) << i));
            counts[idx] += 1;
        }
        // Gibbs reference.
        let energies = crate::problems::landscape::enumerate(&m);
        let z: f64 = energies.iter().map(|&e| (-(e as f64) / t).exp()).sum();
        for (idx, &c) in counts.iter().enumerate() {
            let expect = (-(energies[idx] as f64) / t).exp() / z;
            let got = c as f64 / samples as f64;
            assert!(
                (got - expect).abs() < 0.01,
                "state {idx}: empirical {got:.4} vs Gibbs {expect:.4}"
            );
        }
    }

    /// Roulette selection frequencies must be proportional to p_flip
    /// (Eq. 29), through BOTH selection implementations: freeze the
    /// fields by zeroing J and using only h.
    #[test]
    fn roulette_selection_proportional_to_weights() {
        let mut m = IsingModel::zeros(4);
        // No couplings: flipping a spin never changes others' ΔE.
        m.set_h(0, 2);
        m.set_h(1, 1);
        m.set_h(2, 0);
        m.set_h(3, -1);
        let t = 1.0;
        let spins = SpinVec::from_spins(&[1, 1, 1, 1]);
        let lut = PwlLogistic::default();
        // Expected first-step weights: ΔE_i = 2 s_i h_i (u_i == h_i).
        let w: Vec<f64> =
            (0..4).map(|i| lut.flip_prob_q16(2 * m.h(i) as i64, t) as f64).collect();
        let w_sum: f64 = w.iter().sum();
        for selector in [SelectorKind::LinearScan, SelectorKind::Fenwick] {
            let mut counts = [0u64; 4];
            let trials = 200_000u64;
            for trial in 0..trials {
                // Fresh engine with a distinct seed each trial; we only
                // observe the FIRST selection from the fixed start state.
                let mut cfg = EngineConfig::new(Mode::RouletteWheel, 0, trial);
                cfg.schedule = Schedule::Constant(t);
                cfg.selector = selector;
                let mut e2 = SnowballEngine::with_spins(&m, cfg, spins.clone());
                if let StepOutcome::Flipped(j) = e2.step(0, t) {
                    counts[j] += 1;
                }
            }
            for i in 0..4 {
                let expect = w[i] / w_sum;
                let got = counts[i] as f64 / trials as f64;
                assert!(
                    (got - expect).abs() < 0.01,
                    "{selector:?} spin {i}: selected {got:.4}, expected {expect:.4}"
                );
            }
        }
    }

    /// Both selectors, both datapaths: identical observable run tuples on
    /// a mid-size sparse instance (the in-module smoke version of
    /// `tests/select_parity.rs`).
    #[test]
    fn fenwick_and_scan_selectors_agree_exactly() {
        let p = small_instance(109);
        for mode in [Mode::RouletteWheel, Mode::RouletteUniformized] {
            for dp in [Datapath::Dense, Datapath::BitPlane] {
                let mk = |selector| {
                    let mut cfg = EngineConfig::new(mode, 800, 17);
                    cfg.datapath = dp;
                    cfg.selector = selector;
                    let mut e = SnowballEngine::new(p.model(), cfg);
                    let r = e.run();
                    (r.best_energy, r.final_energy, r.flips, r.fallbacks, r.nulls)
                };
                assert_eq!(
                    mk(SelectorKind::LinearScan),
                    mk(SelectorKind::Fenwick),
                    "selector divergence ({mode:?}, {dp:?})"
                );
            }
        }
    }
}
