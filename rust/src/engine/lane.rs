//! The shared lane kernel: one contiguous spin range's worth of
//! dual-mode MCMC selection state, extracted so the single-lane engine
//! and the sharded engine's lanes run the *same* machinery.
//!
//! A [`LaneKernel`] owns a view of the spins in `[lo, hi)`: their packed
//! signs, their local fields `u_i` (h folded in at init), the Mode II
//! lane weights `p_q16`, and — when the incremental selector is on — a
//! Fenwick tree over those weights plus the dirty-set bookkeeping that
//! keeps both current at `Θ(dirty + log(hi−lo))` per step instead of
//! `Θ(hi−lo)`.
//!
//! Instantiations:
//!
//! * [`SnowballEngine`] is the single-lane case, `range == 0..N`: its
//!   per-step selection (`sync_weights` + `select_local`) and its flip
//!   application (`flip_local`) are this kernel, unchanged.
//! * Each sharded lane ([`crate::engine::shard`]) is a range-restricted
//!   case: local flips go through `flip_local`; peers' flips arriving
//!   over the mailboxes go through [`apply_remote`](LaneKernel::apply_remote),
//!   which folds only the row slice that intersects the range and feeds
//!   the **same dirty set** — so cross-shard traffic costs
//!   `Θ(deg ∩ range)` marks, never a full lane recompute.
//!
//! Refresh policy (identical for every instantiation, which is what
//! keeps the sharded virtual-time merge bit-identical to the engine):
//! a temperature change or a dense-row flip forces one bulk refresh
//! through the chunked lane kernel and only marks the tree stale (that
//! step selects by prefix scan; the `Θ(n)` rebuild is paid lazily iff
//! an incremental step follows), while plateau-interior steps
//! re-evaluate exactly the dirtied lanes and descend the tree.
//!
//! [`SnowballEngine`]: super::SnowballEngine

use super::lut::{LaneCtx, PwlLogistic};
use super::select::Fenwick;
use super::shard::placement::LocalRows;
use crate::bitplane::BitPlanes;
use crate::ising::{Adjacency, IsingModel, SpinVec};
use std::ops::Range;

/// Above this directed density the flip paths keep the dense row walk
/// and bulk-refresh every lane per flip instead of building a CSR
/// adjacency (CSR walks lose to the contiguous row once most entries
/// are nonzero anyway).
pub(crate) const MAX_CSR_DENSITY: f64 = 0.25;

/// Incremental Mode II selection state: the Fenwick tree over the Q16
/// lane weights plus dirty-lane bookkeeping (see the module docs for
/// the refresh policy).
struct SelState {
    fenwick: Fenwick,
    /// Lane-evaluation context for `cached_temp`.
    ctx: LaneCtx,
    /// Temperature the lanes/tree currently reflect (None = stale).
    cached_temp: Option<f64>,
    /// Lanes (local indices) whose `(s_i, u_i)` changed since the last
    /// sync — fed by local flips AND remote-flip applications.
    dirty: Vec<u32>,
    /// Epoch stamps deduplicating `dirty` pushes.
    stamp: Vec<u64>,
    epoch: u64,
    /// Set by the dense-row fast path (no CSR): the flip touched ~every
    /// lane, so the next sync does one bulk refresh instead of n marks.
    all_dirty: bool,
    /// True while the tree does not reflect `p_q16`. Bulk refreshes only
    /// mark the tree stale instead of paying a Θ(n) rebuild — selection
    /// falls back to the prefix scan for that step, and the rebuild
    /// happens lazily on the first *incremental* step that follows. A
    /// run that bulk-refreshes every step (continuous ramp, dense row)
    /// therefore never builds the tree at all and costs exactly what the
    /// legacy scan does.
    tree_stale: bool,
}

impl SelState {
    fn new(n: usize, lut: &PwlLogistic) -> Self {
        Self {
            fenwick: Fenwick::new(n),
            ctx: lut.lane_ctx(1.0), // placeholder; cached_temp None forces a refresh
            cached_temp: None,
            dirty: Vec::new(),
            stamp: vec![0; n],
            epoch: 1,
            all_dirty: false,
            tree_stale: true,
        }
    }

    #[inline(always)]
    fn mark(&mut self, i: usize) {
        if self.stamp[i] != self.epoch {
            self.stamp[i] = self.epoch;
            self.dirty.push(i as u32);
        }
    }
}

/// One contiguous spin range's selection/update state (module docs).
///
/// All indices on the public API are **range-local** (`0..hi−lo`)
/// except the `j` of [`apply_remote`](Self::apply_remote), which is the
/// global index of a spin some *other* kernel owns. The kernel does not
/// hold the field-update data sources; each flip call takes the model
/// plus the optional CSR / bit-plane stores, so the same kernel value
/// works whether those are owned (the engine) or shared across lane
/// threads (the sharded engine).
pub struct LaneKernel {
    lo: usize,
    hi: usize,
    /// Local spins, indexed `0..hi−lo`.
    spins: SpinVec,
    /// Local fields of the local spins (global `u[lo..hi]`, h included).
    u: Vec<i64>,
    /// Mode II lane weights (Q16, local).
    p_q16: Vec<u32>,
    /// Incremental selection state; `None` runs the legacy full
    /// evaluate + prefix scan every step (`SelectorKind::LinearScan`,
    /// or a mode that never selects by roulette).
    sel: Option<SelState>,
    /// Lane-local copy of this range's coupling rows
    /// ([`materialize_local_rows`](Self::materialize_local_rows));
    /// `None` walks the shared matrix / CSR directly.
    local: Option<LocalRows>,
}

impl LaneKernel {
    /// Build a kernel over `range`, slicing the initial global spins and
    /// fields. `incremental` arms the Fenwick/dirty-set state (the
    /// caller passes `mode is roulette && selector == Fenwick`).
    pub fn new(
        range: Range<usize>,
        init_spins: &SpinVec,
        init_u: &[i64],
        lut: &PwlLogistic,
        incremental: bool,
    ) -> Self {
        assert!(range.end <= init_spins.len() && range.end <= init_u.len());
        let n = range.len();
        let mut spins = SpinVec::all_down(n);
        for (k, i) in range.clone().enumerate() {
            spins.set(k, init_spins.get(i));
        }
        Self {
            lo: range.start,
            hi: range.end,
            spins,
            u: init_u[range].to_vec(),
            p_q16: vec![0; n],
            sel: incremental.then(|| SelState::new(n, lut)),
            local: None,
        }
    }

    /// Copy this kernel's coupling-row window into lane-owned memory
    /// (dense column slab or CSR segments — whichever form the flip
    /// path walks, per `adj`), returning the copy's resident bytes.
    /// Call on the lane's pinned thread: first-touch page placement
    /// puts the copy on that thread's NUMA node
    /// (`engine::shard::placement`). Values are identical to the
    /// shared sources, so flips stay bit-identical.
    pub fn materialize_local_rows(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
    ) -> usize {
        let local = LocalRows::build(model, adj, self.lo..self.hi);
        let bytes = local.resident_bytes();
        self.local = Some(local);
        bytes
    }

    /// The global index range this kernel owns.
    pub fn range(&self) -> Range<usize> {
        self.lo..self.hi
    }

    /// Start of the owned range (global index of local lane 0).
    #[inline(always)]
    pub fn lo(&self) -> usize {
        self.lo
    }

    /// Lanes in the kernel.
    #[inline(always)]
    pub fn n_local(&self) -> usize {
        self.hi - self.lo
    }

    /// The local spins (bit `k` is global spin `lo + k`).
    pub fn spins(&self) -> &SpinVec {
        &self.spins
    }

    /// Local spin `k` (±1).
    #[inline(always)]
    pub fn spin(&self, k: usize) -> i8 {
        self.spins.get(k)
    }

    /// The local fields (h folded in).
    pub fn fields(&self) -> &[i64] {
        &self.u
    }

    /// Local field of lane `k`.
    #[inline(always)]
    pub fn field(&self, k: usize) -> i64 {
        self.u[k]
    }

    /// The current lane-weight buffer (meaningful after
    /// [`sync_weights`](Self::sync_weights)).
    pub fn weights(&self) -> &[u32] {
        &self.p_q16
    }

    /// ΔE of flipping local lane `k` right now (Eq. 24).
    #[inline(always)]
    pub fn delta_e(&self, k: usize) -> i64 {
        IsingModel::delta_e(self.spins.get(k), self.u[k])
    }

    /// Bring the lane weights (and, incrementally, the Fenwick tree) in
    /// sync with the current `(spins, u, temp)`; returns this kernel's
    /// aggregate weight `W = Σ p_q16`. Without incremental state this is
    /// one bulk evaluation through the chunked lane kernel — the legacy
    /// scan path. With it, a temperature change (plateau boundary) or a
    /// dense-row flip forces the bulk refresh; otherwise only the lanes
    /// dirtied since the last sync are re-evaluated.
    pub fn sync_weights(&mut self, lut: &PwlLogistic, temp: f64) -> u64 {
        let Some(st) = self.sel.as_mut() else {
            let ctx = lut.lane_ctx(temp);
            return lut.eval_lanes(&ctx, &self.u, self.spins.words(), &mut self.p_q16);
        };
        if st.cached_temp != Some(temp) || st.all_dirty {
            // Bulk refresh: re-evaluate every lane, but only mark the
            // tree stale — this step selects by prefix scan, and the
            // Θ(n) rebuild is paid once, lazily, iff an incremental step
            // follows (so back-to-back bulk steps cost what the legacy
            // scan costs).
            st.ctx = lut.lane_ctx(temp);
            let w = lut.eval_lanes(&st.ctx, &self.u, self.spins.words(), &mut self.p_q16);
            st.tree_stale = true;
            st.cached_temp = Some(temp);
            st.all_dirty = false;
            st.dirty.clear();
            st.epoch += 1;
            w
        } else {
            if st.tree_stale {
                st.fenwick.rebuild(&self.p_q16);
                st.tree_stale = false;
            }
            let words = self.spins.words();
            for &i in &st.dirty {
                let i = i as usize;
                let bit = (words[i >> 6] >> (i & 63)) & 1;
                let p = lut.lane_p(&st.ctx, bit, self.u[i]);
                let old = self.p_q16[i];
                if p != old {
                    st.fenwick.add(i, p as i64 - old as i64);
                    self.p_q16[i] = p;
                }
            }
            st.dirty.clear();
            st.epoch += 1;
            st.fenwick.total()
        }
    }

    /// The unique local lane `k` with `cum(k−1) <= r < cum(k)` over the
    /// synced weights: Θ(log n) tree descent when the Fenwick tree is
    /// current, Θ(n) prefix scan otherwise (the legacy path, and
    /// bulk-refresh steps where rebuilding the tree for one selection
    /// would cost more than the scan) — identical `k` either way.
    /// Requires `r < W` from the matching [`sync_weights`](Self::sync_weights).
    pub fn select_local(&self, r: u64) -> usize {
        match &self.sel {
            Some(st) if !st.tree_stale => st.fenwick.select(r),
            _ => {
                let mut acc = 0u64;
                let mut chosen = self.p_q16.len() - 1;
                for (i, &w) in self.p_q16.iter().enumerate() {
                    acc += w as u64;
                    if r < acc {
                        chosen = i;
                        break;
                    }
                }
                chosen
            }
        }
    }

    /// Flip local lane `k`, fold the flip into THIS kernel's fields
    /// (asynchronous update, Eqs. 12/17/27/31) and dirty-set, and return
    /// `(global index, pre-flip sign, ΔE)`. The caller owns energy
    /// bookkeeping (`energy += ΔE`) and, in the sharded case, posting
    /// the flip to peer mailboxes — this method is the single source of
    /// truth for the field updates themselves.
    pub fn flip_local(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        planes: Option<&BitPlanes>,
        k: usize,
    ) -> (usize, i8, i64) {
        let de = self.delta_e(k);
        let s_old = self.spins.flip(k);
        let j = self.lo + k;
        self.fold_flip(model, adj, planes, j, s_old);
        if let Some(st) = self.sel.as_mut() {
            // The flipped spin's own lane changes sign (ΔE_k → −ΔE_k)
            // even though u_k does not (J_kk == 0).
            st.mark(k);
        }
        (j, s_old, de)
    }

    /// Fold a flip of global spin `j` (owned by ANOTHER kernel; pre-flip
    /// sign `s_old`) into this kernel's fields, marking the touched
    /// lanes dirty — the mailbox-consumer path. Costs `Θ(deg ∩ range)`
    /// through the CSR row slice or the masked bit-plane column walk;
    /// only the dense row walk (no CSR built) bulk-dirties the kernel.
    pub fn apply_remote(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        planes: Option<&BitPlanes>,
        j: usize,
        s_old: i8,
    ) {
        debug_assert!(j < self.lo || j >= self.hi, "apply_remote on an owned spin");
        self.fold_flip(model, adj, planes, j, s_old);
    }

    /// `u_i ← u_i − 2·s_old·J_ij` over this kernel's range, through
    /// whichever data source exists: bit-plane column slice, CSR row
    /// slice, or dense row segment. Exactly one of `adj` / `planes`
    /// should be `Some` (both `None` = dense row walk).
    fn fold_flip(
        &mut self,
        model: &IsingModel,
        adj: Option<&Adjacency>,
        planes: Option<&BitPlanes>,
        j: usize,
        s_old: i8,
    ) {
        let factor = 2 * s_old as i64;
        if let Some(bp) = planes {
            // Bit-plane column walk, masked to [lo, hi): Θ(B·W_local)
            // words, Θ(deg ∩ range) adds, each reported into the dirty
            // set (range-local indices — exactly what `mark` wants).
            match self.sel.as_mut() {
                Some(st) => bp.incr_update_range_touched(
                    &mut self.u,
                    self.lo..self.hi,
                    j,
                    s_old,
                    |i| st.mark(i),
                ),
                None => {
                    bp.incr_update_range_touched(&mut self.u, self.lo..self.hi, j, s_old, |_| {})
                }
            }
        } else if let Some(adj) = adj {
            // Sparse: Θ(deg ∩ range) CSR slice walk; the touched set is
            // the in-range row. A materialized lane-local slab serves
            // the identical slices from node-local memory (and skips
            // the per-flip binary searches).
            let (neigh, vals) = match &self.local {
                Some(local) => local.csr_row(j),
                None => adj.row_range(j, self.lo..self.hi),
            };
            match self.sel.as_mut() {
                Some(st) => {
                    for (&i, &jv) in neigh.iter().zip(vals.iter()) {
                        let k = i as usize - self.lo;
                        self.u[k] -= factor * jv as i64;
                        st.mark(k);
                    }
                }
                None => {
                    for (&i, &jv) in neigh.iter().zip(vals.iter()) {
                        self.u[i as usize - self.lo] -= factor * jv as i64;
                    }
                }
            }
        } else {
            // Dense-row fast path: contiguous Θ(hi−lo) walk
            // (u_i ← u_i − 2 J_ij s_j_old, J symmetric) through the
            // packed typed row — AVX2-widened when available, and
            // served from a lane-local slab when one is materialized;
            // nearly every lane changes, so the incremental state
            // takes one bulk refresh instead of n individual marks.
            let row = match &self.local {
                Some(local) => local.dense_row(j),
                None => model.j_row(j).slice(self.lo..self.hi),
            };
            row.fold_delta(factor, &mut self.u);
            if let Some(st) = self.sel.as_mut() {
                st.all_dirty = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::{salt, StatelessRng};

    fn sparse_instance(n: usize, seed: u64) -> MaxCut {
        let rng = StatelessRng::new(seed);
        MaxCut::new(generators::erdos_renyi(n, 4 * n, &[-1, 1], &rng))
    }

    /// Reference weights: one bulk evaluation over the kernel's range of
    /// the CURRENT global configuration.
    fn bulk_weights(
        lut: &PwlLogistic,
        model: &IsingModel,
        spins: &SpinVec,
        range: Range<usize>,
        temp: f64,
    ) -> (Vec<u32>, u64) {
        let u = model.local_fields(spins);
        let mut local = SpinVec::all_down(range.len());
        for (k, i) in range.clone().enumerate() {
            local.set(k, spins.get(i));
        }
        let ctx = lut.lane_ctx(temp);
        let mut out = vec![0u32; range.len()];
        let w = lut.eval_lanes(&ctx, &u[range], local.words(), &mut out);
        (out, w)
    }

    /// Drive a kernel with a mix of local and remote flips across
    /// plateaus and temperature changes; after every sync the weights,
    /// aggregate W, fields and selections must match a from-scratch bulk
    /// evaluation — through the CSR, dense-row and bit-plane sources.
    #[test]
    fn kernel_incremental_matches_bulk_through_every_source() {
        let p = sparse_instance(72, 5);
        let m = p.model();
        let adj = m.adjacency();
        let bp = crate::bitplane::BitPlanes::encode(m, None);
        let lut = PwlLogistic::default();
        let rng = StatelessRng::new(6);
        for (label, use_adj, use_bp) in
            [("csr", true, false), ("dense", false, false), ("bitplane", false, true)]
        {
            let adj = use_adj.then_some(&adj);
            let planes = use_bp.then_some(&bp);
            let mut spins = SpinVec::random(72, &rng);
            let u = m.local_fields(&spins);
            let range = 16usize..57;
            let mut k = LaneKernel::new(range.clone(), &spins, &u, &lut, true);
            let temps = [1.5f64, 1.5, 1.5, 0.8, 0.8, 1.5];
            for (step, &temp) in temps.iter().enumerate() {
                // A few flips between syncs: local ones through the
                // kernel, out-of-range ones as remote applications.
                for f in 0..4u64 {
                    let j =
                        rng.below(step as u64 + 10, f, salt::SITE, 72) as usize;
                    if range.contains(&j) {
                        let (jg, _, de) = k.flip_local(m, adj, planes, j - range.start);
                        assert_eq!(jg, j, "{label}");
                        let want_de =
                            IsingModel::delta_e(spins.get(j), m.local_field(&spins, j));
                        assert_eq!(de, want_de, "{label}: ΔE from kernel fields");
                        spins.flip(j);
                    } else {
                        let s_old = spins.flip(j);
                        k.apply_remote(m, adj, planes, j, s_old);
                    }
                }
                // Fields must track the dense oracle continuously.
                let u_now = m.local_fields(&spins);
                assert_eq!(k.fields(), &u_now[range.clone()], "{label}: fields drifted");
                // Weights after sync must equal the bulk evaluation.
                let w = k.sync_weights(&lut, temp);
                let (want_p, want_w) = bulk_weights(&lut, m, &spins, range.clone(), temp);
                assert_eq!(w, want_w, "{label}: aggregate W at step {step}");
                assert_eq!(k.weights(), &want_p[..], "{label}: weights at step {step}");
                // Selection parity against the linear reference, both on
                // bulk-refresh steps (stale tree → scan) and
                // plateau-interior steps (fresh tree → descent).
                if w > 0 {
                    for trial in 0..16u64 {
                        let r = rng.u64(step as u64 + 40, trial, salt::ROULETTE) % w;
                        let mut acc = 0u64;
                        let mut want = want_p.len() - 1;
                        for (i, &pw) in want_p.iter().enumerate() {
                            acc += pw as u64;
                            if r < acc {
                                want = i;
                                break;
                            }
                        }
                        assert_eq!(k.select_local(r), want, "{label}: r = {r}");
                    }
                }
            }
        }
    }

    /// A full-range kernel without incremental state is the legacy path:
    /// every sync is a bulk refresh and selection is the prefix scan —
    /// and it must agree with an incremental full-range kernel.
    #[test]
    fn legacy_and_incremental_kernels_agree_on_full_range() {
        let p = sparse_instance(48, 9);
        let m = p.model();
        let adj = m.adjacency();
        let lut = PwlLogistic::default();
        let rng = StatelessRng::new(10);
        let spins = SpinVec::random(48, &rng);
        let u = m.local_fields(&spins);
        let mut legacy = LaneKernel::new(0..48, &spins, &u, &lut, false);
        let mut incr = LaneKernel::new(0..48, &spins, &u, &lut, true);
        for step in 0..40u64 {
            let temp = if step < 20 { 1.2 } else { 0.6 };
            let wl = legacy.sync_weights(&lut, temp);
            let wi = incr.sync_weights(&lut, temp);
            assert_eq!(wl, wi, "step {step}");
            assert_eq!(legacy.weights(), incr.weights(), "step {step}");
            if wl == 0 {
                continue;
            }
            let r = rng.u64(1, step, salt::ROULETTE) % wl;
            let chosen = legacy.select_local(r);
            assert_eq!(chosen, incr.select_local(r), "step {step}");
            let (jl, sl, dl) = legacy.flip_local(m, Some(&adj), None, chosen);
            let (ji, si, di) = incr.flip_local(m, Some(&adj), None, chosen);
            assert_eq!((jl, sl, dl), (ji, si, di), "step {step}");
        }
        assert_eq!(legacy.fields(), incr.fields());
        assert_eq!(legacy.spins().to_spins(), incr.spins().to_spins());
    }

    /// Tiling a model into range-restricted kernels and folding every
    /// flip into all of them (owner via `flip_local`, peers via
    /// `apply_remote`) reproduces a single full-range kernel exactly.
    #[test]
    fn tiled_kernels_reproduce_the_full_range_kernel() {
        let p = sparse_instance(60, 11);
        let m = p.model();
        let adj = m.adjacency();
        let lut = PwlLogistic::default();
        let rng = StatelessRng::new(12);
        let spins = SpinVec::random(60, &rng);
        let u = m.local_fields(&spins);
        let cuts = [0usize, 17, 33, 60];
        let mut whole = LaneKernel::new(0..60, &spins, &u, &lut, true);
        let mut tiles: Vec<LaneKernel> = cuts
            .windows(2)
            .map(|w| LaneKernel::new(w[0]..w[1], &spins, &u, &lut, true))
            .collect();
        for step in 0..60u64 {
            let temp = 1.0 + (step % 3) as f64 * 0.4;
            let w_whole = whole.sync_weights(&lut, temp);
            let w_tiles: u64 = tiles.iter_mut().map(|t| t.sync_weights(&lut, temp)).sum();
            assert_eq!(w_whole, w_tiles, "step {step}: aggregate W");
            if w_whole == 0 {
                continue;
            }
            let r = rng.u64(2, step, salt::ROULETTE) % w_whole;
            let chosen = whole.select_local(r);
            // Locate the owning tile by weight prefix; the local pick
            // must land on the same global spin.
            let mut cum = 0u64;
            let mut global = usize::MAX;
            for t in tiles.iter() {
                let w_t: u64 = t.weights().iter().map(|&w| w as u64).sum();
                if r < cum + w_t {
                    global = t.lo() + t.select_local(r - cum);
                    break;
                }
                cum += w_t;
            }
            assert_eq!(global, chosen, "step {step}: tiled selection diverged");
            let (_, s_old, _) = whole.flip_local(m, Some(&adj), None, chosen);
            for t in tiles.iter_mut() {
                if t.range().contains(&chosen) {
                    let (_, so, _) = t.flip_local(m, Some(&adj), None, chosen - t.lo());
                    assert_eq!(so, s_old);
                } else {
                    t.apply_remote(m, Some(&adj), None, chosen, s_old);
                }
            }
        }
        for t in &tiles {
            let r = t.range();
            assert_eq!(t.fields(), &whole.fields()[r.clone()], "tile {r:?} fields");
            for k in 0..t.n_local() {
                assert_eq!(t.spin(k), whole.spin(r.start + k), "tile {r:?} spin {k}");
            }
        }
    }

    /// A kernel with materialized lane-local rows must stay
    /// bit-identical to one walking the shared sources, through both
    /// the CSR and the dense flip paths, across local and remote flips.
    #[test]
    fn materialized_local_rows_are_bit_identical() {
        let p = sparse_instance(64, 17);
        let m = p.model();
        let adj = m.adjacency();
        let lut = PwlLogistic::default();
        let rng = StatelessRng::new(18);
        for (label, use_adj) in [("csr", true), ("dense", false)] {
            let adj = use_adj.then_some(&adj);
            let mut spins = SpinVec::random(64, &rng);
            let u = m.local_fields(&spins);
            let range = 11usize..49;
            let mut shared = LaneKernel::new(range.clone(), &spins, &u, &lut, true);
            let mut local = LaneKernel::new(range.clone(), &spins, &u, &lut, true);
            let bytes = local.materialize_local_rows(m, adj);
            assert!(bytes > 0, "{label}: copy reports resident bytes");
            for step in 0..30u64 {
                let temp = if step % 2 == 0 { 1.1 } else { 0.7 };
                let j = rng.below(20 + step, 0, salt::SITE, 64) as usize;
                if range.contains(&j) {
                    let a = shared.flip_local(m, adj, None, j - range.start);
                    let b = local.flip_local(m, adj, None, j - range.start);
                    assert_eq!(a, b, "{label}: local flip at step {step}");
                    spins.flip(j);
                } else {
                    let s_old = spins.flip(j);
                    shared.apply_remote(m, adj, None, j, s_old);
                    local.apply_remote(m, adj, None, j, s_old);
                }
                assert_eq!(
                    shared.sync_weights(&lut, temp),
                    local.sync_weights(&lut, temp),
                    "{label}: aggregate W at step {step}"
                );
                assert_eq!(shared.fields(), local.fields(), "{label}: fields at step {step}");
                assert_eq!(shared.weights(), local.weights(), "{label}: weights at step {step}");
            }
        }
    }
}
