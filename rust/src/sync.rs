//! Concurrency shim: `std::sync` primitives in normal builds, `loom`'s
//! model-checked doubles under `RUSTFLAGS="--cfg loom"`.
//!
//! The asynchronous shard engine rests on hand-rolled lock-free code —
//! the `FlipRing` SPSC mailboxes, the abortable `SyncGate` epoch
//! barrier, the per-lane energy partials. Races in that code do not
//! crash an Ising machine; they silently degrade solution quality,
//! which is the worst possible failure mode for a solver whose claims
//! are statistical. So the memory-model contract is machine-checked:
//! every type that participates in cross-thread publication imports its
//! primitives from THIS module, and the loom permutation tests
//! (`rust/tests/loom_shard.rs`) recompile the crate with
//! `--cfg loom` + `--features loom` to run those types through loom's
//! exhaustive interleaving explorer (C11-memory-model aware: it tries
//! the reorderings a relaxed architecture is allowed to perform, not
//! just the ones one test machine happens to exhibit).
//!
//! Build matrix:
//!
//! * default build — everything here is a zero-cost re-export of
//!   `std::sync` (plus a thin `UnsafeCell` wrapper, see below), so the
//!   production binary is byte-for-byte what it was before the shim.
//! * `RUSTFLAGS="--cfg loom" cargo test --features loom --test
//!   loom_shard` — the same paths resolve to `loom`'s instrumented
//!   doubles and the model tests run. The `loom` cargo feature gates
//!   the optional `loom` dependency; the `--cfg` flag swaps the types.
//!   Setting the cfg without the feature is a compile error (below)
//!   rather than a pile of unresolved imports.
//!
//! The `UnsafeCell` here is a wrapper, not a re-export: loom's cell
//! exposes closure-based `with`/`with_mut` accessors (so the model can
//! track every access), and the std version mirrors that API over
//! `std::cell::UnsafeCell`. Code written against the closure API is
//! therefore checkable for free — which is exactly why `clippy.toml`
//! bans `std::cell::UnsafeCell` everywhere else in the tree.
//!
//! Policy (enforced by `cargo run -p xtask -- lint-safety` in CI, see
//! `docs/ARCHITECTURE.md` § Concurrency correctness): the literal path
//! `std::sync::atomic` may appear only in this file and in the audited
//! allowlist; `Ordering::SeqCst` is banned outright (if a new algorithm
//! seems to need it, it needs a loom model first); `Ordering::Relaxed`
//! is restricted to audited files whose relaxed operations are
//! single-owner index reads or commutative counter updates.

// AUDITED UNSAFE ALLOWLIST MEMBER (see docs/ARCHITECTURE.md
// § Concurrency correctness). The only unsafe here is in the in-module
// tests, dereferencing the raw pointers the closure API hands out —
// the same obligation every production caller of `with`/`with_mut`
// documents with its own `SAFETY:` comment.
#![allow(unsafe_code)]

#[cfg(all(loom, not(feature = "loom")))]
compile_error!(
    "`--cfg loom` requires the `loom` cargo feature: \
     RUSTFLAGS=\"--cfg loom\" cargo test --features loom --test loom_shard"
);

/// Atomic integers and [`atomic::Ordering`], model-checked under loom.
#[cfg(not(loom))]
pub mod atomic {
    #[allow(clippy::disallowed_types)] // the one sanctioned re-export point
    pub use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
}

/// Atomic integers and [`atomic::Ordering`], model-checked under loom.
#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
}

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex};

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex};

/// Yield the current thread's timeslice. In loom models this is the
/// scheduler hint that lets bounded spin loops (mailbox backpressure)
/// terminate instead of exploding the state space.
#[cfg(not(loom))]
pub fn yield_now() {
    std::thread::yield_now();
}

/// Yield the current thread's timeslice (loom-instrumented).
#[cfg(loom)]
pub fn yield_now() {
    loom::thread::yield_now();
}

/// Interior-mutability cell with loom's closure-based access API.
#[cfg(loom)]
pub use loom::cell::UnsafeCell;

/// Interior-mutability cell with loom's closure-based access API.
///
/// The std flavour: a transparent wrapper over
/// [`std::cell::UnsafeCell`] exposing `with`/`with_mut` so the same
/// call sites compile against loom's instrumented cell under
/// `--cfg loom`. The closures receive raw pointers; dereferencing them
/// is still `unsafe` and still the caller's obligation — the wrapper
/// only fixes the *shape* of the access so the model checker can see
/// every read and write.
#[cfg(not(loom))]
#[derive(Debug, Default)]
pub struct UnsafeCell<T>(
    #[allow(clippy::disallowed_types)] // the wrapper IS the sanctioned use
    std::cell::UnsafeCell<T>,
);

#[cfg(not(loom))]
impl<T> UnsafeCell<T> {
    /// Wrap `data`.
    pub fn new(data: T) -> UnsafeCell<T> {
        #[allow(clippy::disallowed_types)]
        UnsafeCell(std::cell::UnsafeCell::new(data))
    }

    /// Run `f` with a shared raw pointer to the contents.
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        f(self.0.get())
    }

    /// Run `f` with an exclusive raw pointer to the contents.
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        f(self.0.get())
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    /// The std wrapper must behave like a plain cell through the
    /// closure API (this is what Miri exercises for aliasing hygiene).
    #[test]
    fn unsafe_cell_with_and_with_mut_round_trip() {
        let cell = UnsafeCell::new(41u64);
        // SAFETY: single-threaded test — no concurrent access to the
        // cell exists while either raw pointer is live.
        let read = cell.with(|p| unsafe { *p });
        assert_eq!(read, 41);
        // SAFETY: as above; the exclusive pointer is the only live one.
        cell.with_mut(|p| unsafe { *p += 1 });
        // SAFETY: as above.
        assert_eq!(cell.with(|p| unsafe { *p }), 42);
    }

    #[test]
    fn atomics_and_locks_are_std_in_normal_builds() {
        let a = atomic::AtomicUsize::new(7);
        a.store(9, atomic::Ordering::Release);
        assert_eq!(a.load(atomic::Ordering::Acquire), 9);
        let m = Mutex::new(3i32);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 4);
        yield_now(); // must not panic outside a loom model
    }
}
