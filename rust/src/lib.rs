//! # Snowball
//!
//! Reproduction of *"Snowball: A Scalable All-to-All Ising Machine with
//! Dual-Mode Markov Chain Monte Carlo Spin Selection and Asynchronous
//! Spin Updates for Fast Combinatorial Optimization"* as a three-layer
//! Rust + JAX + Pallas system (see DESIGN.md).
//!
//! * [`ising`], [`graph`], [`problems`] — problem substrates.
//! * [`bitplane`] — the paper's signed bit-plane coupler store with
//!   Hamming-weight initialization and incremental column updates.
//! * [`engine`] — the dual-mode MCMC engine (random-scan / roulette).
//! * [`hwsim`] — cycle-approximate FPGA model (Alveo U250 substitution).
//! * [`baselines`] — every comparator of Tables II/III.
//! * [`tts`] — time-to-solution statistics (Eq. 32).
//! * [`runtime`] — PJRT loader for the AOT-compiled JAX/Pallas artifacts.
//! * [`coordinator`] — size-classed admission queue, overlapping job
//!   dispatch over the shared replica pool, metrics, TCP service
//!   (`docs/ARCHITECTURE.md`, `docs/PROTOCOL.md`).
//! * [`portfolio`] — heterogeneous solver racing (Snowball configs vs.
//!   the baseline fleet under one budget, first-finisher-wins) plus the
//!   coupling-precision sweep harness
//!   (`docs/ARCHITECTURE.md` § Portfolio layer).
//! * [`harness`] — regeneration of every paper table and figure.
//! * [`sync`] — the concurrency shim: `std::sync` in normal builds,
//!   loom's instrumented primitives under `--cfg loom`, so the shard
//!   engine's synchronization is model-checkable
//!   (`docs/ARCHITECTURE.md` § Concurrency correctness).
//! * [`stop`] — the shared cancel/deadline/shutdown [`stop::StopToken`]
//!   behind the fault-tolerant job lifecycle.
//! * [`failpoint`] — named fault-injection sites (feature
//!   `failpoints`; zero-cost when off) driving `tests/chaos.rs`.
//!
//! ## Unsafe-code policy
//!
//! `unsafe` is **denied crate-wide** and re-forbidden on every module
//! below except the five audited allowlist members ([`sync`],
//! `engine::lut`, `engine::shard::mailbox`, `engine::shard::affinity`,
//! `ising::store`), which opt back in with a file-local
//! `#![allow(unsafe_code)]` plus an audit header. Every unsafe operation in those files must carry a
//! `SAFETY:` comment — enforced by `cargo run -p xtask -- lint-safety`
//! in CI, alongside the loom, Miri and ThreadSanitizer lanes.

// deny (not forbid) at the crate root so the audited allowlist modules
// can locally `#![allow(unsafe_code)]`; everything else is re-escalated
// to forbid on its `mod` item, which no inner allow can override.
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

#[forbid(unsafe_code)]
pub mod baselines;
#[forbid(unsafe_code)]
pub mod bitplane;
#[forbid(unsafe_code)]
pub mod cli;
#[forbid(unsafe_code)]
pub mod config;
#[forbid(unsafe_code)]
pub mod coordinator;
pub mod engine;
#[forbid(unsafe_code)]
pub mod failpoint;
#[forbid(unsafe_code)]
pub mod graph;
#[forbid(unsafe_code)]
pub mod harness;
#[forbid(unsafe_code)]
pub mod hwsim;
// `ising::store` is an audited-unsafe member (AVX2 widening row
// kernels); the per-submodule forbids live in `ising/mod.rs`.
pub mod ising;
#[forbid(unsafe_code)]
pub mod portfolio;
#[forbid(unsafe_code)]
pub mod problems;
#[forbid(unsafe_code)]
pub mod rng;
#[forbid(unsafe_code)]
pub mod runtime;
#[forbid(unsafe_code)]
pub mod stop;
pub mod sync;
#[forbid(unsafe_code)]
pub mod testutil;
#[forbid(unsafe_code)]
pub mod tts;
