//! # Snowball
//!
//! Reproduction of *"Snowball: A Scalable All-to-All Ising Machine with
//! Dual-Mode Markov Chain Monte Carlo Spin Selection and Asynchronous
//! Spin Updates for Fast Combinatorial Optimization"* as a three-layer
//! Rust + JAX + Pallas system (see DESIGN.md).
//!
//! * [`ising`], [`graph`], [`problems`] — problem substrates.
//! * [`bitplane`] — the paper's signed bit-plane coupler store with
//!   Hamming-weight initialization and incremental column updates.
//! * [`engine`] — the dual-mode MCMC engine (random-scan / roulette).
//! * [`hwsim`] — cycle-approximate FPGA model (Alveo U250 substitution).
//! * [`baselines`] — every comparator of Tables II/III.
//! * [`tts`] — time-to-solution statistics (Eq. 32).
//! * [`runtime`] — PJRT loader for the AOT-compiled JAX/Pallas artifacts.
//! * [`coordinator`] — size-classed admission queue, overlapping job
//!   dispatch over the shared replica pool, metrics, TCP service
//!   (`docs/ARCHITECTURE.md`, `docs/PROTOCOL.md`).
//! * [`harness`] — regeneration of every paper table and figure.

pub mod baselines;
pub mod bitplane;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod harness;
pub mod hwsim;
pub mod ising;
pub mod problems;
pub mod rng;
pub mod runtime;
pub mod testutil;
pub mod tts;
