//! Stateless counter-based pseudorandom number generation (paper §IV-B3d).
//!
//! Snowball's hardware uses a *stateless* RNG: every variate is a pure
//! function of a global 64-bit seed supplied by the host and a small set of
//! indices (annealing stage `k`, iteration `t`, and a purpose-specific salt
//! `r`), rather than an updated global RNG state. On an FPGA this removes
//! contention on shared RNG state and maps to LUTs/DSPs; here it gives us
//! (a) perfectly reproducible runs, (b) embarrassingly parallel replica
//! streams, and (c) bit-identical streams between this Rust implementation
//! and the jnp implementation in `python/compile/kernels/rng_ref.py`
//! (checked by golden-vector tests on both sides).
//!
//! The mixing function is the "squares" counter-based generator
//! (B. Widynski, *Squares: A Fast Counter-Based RNG*, 2020): four rounds of
//! squaring and word swaps of `ctr * key`. We derive the per-call counter
//! from `(stage, iter, salt)` with splitmix-style avalanche so neighbouring
//! indices decorrelate.

/// Purpose-specific salts, so distinct draws at the same (stage, iter)
/// never collide.
pub mod salt {
    /// Site selection in random-scan mode (Eq. 22).
    pub const SITE: u64 = 0x01;
    /// Accept/reject uniform in random-scan mode (Eq. 26).
    pub const ACCEPT: u64 = 0x02;
    /// Roulette-wheel position `r in [0, W)` (Eq. 28).
    pub const ROULETTE: u64 = 0x03;
    /// Uniformization null-transition draw.
    pub const UNIFORMIZE: u64 = 0x04;
    /// Initial spin configuration.
    pub const INIT: u64 = 0x05;
    /// Workload/problem generation.
    pub const PROBLEM: u64 = 0x06;
    /// Baseline-internal draws.
    pub const BASELINE: u64 = 0x07;
}

/// Stateless RNG keyed by a host-supplied 64-bit seed.
///
/// All methods are `&self`: there is no internal state to advance. Two
/// `StatelessRng` values with the same seed produce identical streams.
#[derive(Clone, Copy, Debug)]
pub struct StatelessRng {
    seed: u64,
}

/// splitmix64 finalizer — avalanche a 64-bit value.
#[inline(always)]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Widynski "squares" 32-bit counter-based RNG (4 rounds).
#[inline(always)]
pub fn squares32(ctr: u64, key: u64) -> u32 {
    let mut x = ctr.wrapping_mul(key);
    let y = x;
    let z = y.wrapping_add(key);
    // round 1
    x = x.wrapping_mul(x).wrapping_add(y);
    x = x.rotate_right(32);
    // round 2
    x = x.wrapping_mul(x).wrapping_add(z);
    x = x.rotate_right(32);
    // round 3
    x = x.wrapping_mul(x).wrapping_add(y);
    x = x.rotate_right(32);
    // round 4
    (x.wrapping_mul(x).wrapping_add(z) >> 32) as u32
}

impl StatelessRng {
    /// Create a generator for the given host seed.
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// The host seed this generator is keyed on.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Derive a child generator (e.g. one per replica) with a decorrelated
    /// seed. Pure function of (seed, index).
    pub fn child(&self, index: u64) -> Self {
        Self { seed: mix64(self.seed ^ mix64(index ^ 0xC2B2_AE3D_27D4_EB4F)) }
    }

    /// Combine the call indices into the squares counter.
    #[inline(always)]
    fn counter(&self, stage: u64, iter: u64, salt: u64) -> u64 {
        // Distinct-odd-constant mixing keeps (stage, iter, salt) lanes
        // independent; the final mix64 avalanches neighbouring counters.
        mix64(
            stage
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(iter.wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
                .wrapping_add(salt.wrapping_mul(0x1656_67B1_9E37_79F9)),
        )
    }

    /// Uniform 32-bit draw for (stage, iter, salt).
    #[inline(always)]
    pub fn u32(&self, stage: u64, iter: u64, salt: u64) -> u32 {
        // The key must be odd-ish and rich in set bits; mix the seed once.
        squares32(self.counter(stage, iter, salt), mix64(self.seed) | 1)
    }

    /// Uniform 64-bit draw (two 32-bit lanes).
    #[inline(always)]
    pub fn u64(&self, stage: u64, iter: u64, salt: u64) -> u64 {
        let lo = self.u32(stage, iter, salt) as u64;
        let hi = self.u32(stage, iter, salt ^ 0x8000_0000_0000_0000) as u64;
        (hi << 32) | lo
    }

    /// Uniform f32 in [0, 1): top 24 bits of a u32 draw.
    #[inline(always)]
    pub fn unit_f32(&self, stage: u64, iter: u64, salt: u64) -> f32 {
        (self.u32(stage, iter, salt) >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform f64 in [0, 1): 53 bits from a u64 draw.
    #[inline(always)]
    pub fn unit_f64(&self, stage: u64, iter: u64, salt: u64) -> f64 {
        (self.u64(stage, iter, salt) >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0)
    }

    /// Uniform integer in `{0, .., n-1}` via the paper's Eq. (22):
    /// `j = floor(u * N / 2^32)` — a fixed-point multiply, no modulo bias
    /// worth correcting at the N << 2^32 scales used here.
    #[inline(always)]
    pub fn below(&self, stage: u64, iter: u64, salt: u64, n: u32) -> u32 {
        ((self.u32(stage, iter, salt) as u64 * n as u64) >> 32) as u32
    }

    /// Random ±1 spin.
    #[inline(always)]
    pub fn spin(&self, stage: u64, iter: u64, salt: u64) -> i8 {
        if self.u32(stage, iter, salt) & 1 == 1 {
            1
        } else {
            -1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stateless() {
        let r = StatelessRng::new(42);
        let a = r.u32(1, 2, 3);
        let b = r.u32(1, 2, 3);
        assert_eq!(a, b, "same indices must give same draw");
        let r2 = StatelessRng::new(42);
        assert_eq!(r2.u32(1, 2, 3), a, "same seed must give same stream");
    }

    #[test]
    fn distinct_indices_decorrelate() {
        let r = StatelessRng::new(7);
        let mut seen = std::collections::HashSet::new();
        for stage in 0..16u64 {
            for iter in 0..16u64 {
                for s in [salt::SITE, salt::ACCEPT, salt::ROULETTE] {
                    seen.insert(r.u32(stage, iter, s));
                }
            }
        }
        // 768 draws; collisions in 2^32 space are ~0 — demand none.
        assert_eq!(seen.len(), 16 * 16 * 3);
    }

    #[test]
    fn unit_f32_in_range_and_roughly_uniform() {
        let r = StatelessRng::new(0xDEADBEEF);
        let mut sum = 0.0f64;
        let n = 100_000;
        for i in 0..n {
            let v = r.unit_f32(0, i, salt::ACCEPT);
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let r = StatelessRng::new(1);
        let n = 17u32;
        let mut counts = vec![0u32; n as usize];
        for i in 0..50_000u64 {
            let v = r.below(3, i, salt::SITE, n);
            assert!(v < n);
            counts[v as usize] += 1;
        }
        let expect = 50_000.0 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.8 && (c as f64) < expect * 1.2,
                "bucket {i} count {c} vs expected {expect}"
            );
        }
    }

    #[test]
    fn child_streams_differ() {
        let r = StatelessRng::new(5);
        let c0 = r.child(0);
        let c1 = r.child(1);
        assert_ne!(c0.u32(0, 0, 0), c1.u32(0, 0, 0));
        assert_ne!(c0.seed(), r.seed());
    }

    /// Golden vectors pinning the exact stream; the python side
    /// (`python/tests/test_rng_parity.py`) asserts the same values, so the
    /// Rust engine and the jnp/Pallas model draw identical randomness.
    #[test]
    fn golden_vectors() {
        let r = StatelessRng::new(0x5EED_0000_0000_0001);
        let got: Vec<u32> = (0..4).map(|i| r.u32(2, i, salt::SITE)).collect();
        let expect: Vec<u32> = vec![
            squares32(r.counter(2, 0, salt::SITE), mix64(0x5EED_0000_0000_0001) | 1),
            squares32(r.counter(2, 1, salt::SITE), mix64(0x5EED_0000_0000_0001) | 1),
            squares32(r.counter(2, 2, salt::SITE), mix64(0x5EED_0000_0000_0001) | 1),
            squares32(r.counter(2, 3, salt::SITE), mix64(0x5EED_0000_0000_0001) | 1),
        ];
        assert_eq!(got, expect);
        // Fixed literals so any refactor that changes the stream is caught.
        assert_eq!(mix64(0), 0xE220_A839_7B1D_CDAF, "splitmix64(0) reference value");
    }
}
