//! Chimera hardware topology and minor embedding (paper §III-A, Fig. 5).
//!
//! Snowball's all-to-all architecture exists to *avoid* this machinery;
//! building it makes the §III-A overhead argument quantitative: the
//! classic triangle layout embeds `K_n` into an `m × m` Chimera with
//! chains of length `⌈n/4⌉ + 1`, so `n` logical spins cost `Θ(n²/4)`
//! physical qubits — the overhead Table/Fig-5 style analyses report.

use super::Graph;

/// A Chimera(m, m, 4) topology: an `m × m` grid of `K_{4,4}` unit cells.
#[derive(Clone, Debug)]
pub struct Chimera {
    pub m: usize,
}

impl Chimera {
    pub fn new(m: usize) -> Self {
        Self { m }
    }

    /// Total physical qubits `8·m²`.
    pub fn qubits(&self) -> usize {
        8 * self.m * self.m
    }

    /// Qubit id for (row, col, side, index): side 0 = "left/vertical"
    /// partition, side 1 = "right/horizontal"; index 0..4 within the
    /// partition.
    pub fn qubit(&self, row: usize, col: usize, side: usize, idx: usize) -> usize {
        debug_assert!(row < self.m && col < self.m && side < 2 && idx < 4);
        ((row * self.m + col) * 2 + side) * 4 + idx
    }

    /// The hardware graph: intra-cell `K_{4,4}` plus inter-cell couplers
    /// (vertical qubits couple along columns, horizontal along rows).
    pub fn graph(&self) -> Graph {
        let mut g = Graph::empty(self.qubits());
        for r in 0..self.m {
            for c in 0..self.m {
                // K_{4,4} inside the cell.
                for a in 0..4 {
                    for b in 0..4 {
                        g.add_edge(
                            self.qubit(r, c, 0, a) as u32,
                            self.qubit(r, c, 1, b) as u32,
                            1,
                        );
                    }
                }
                // Vertical chains: side-0 qubits to the cell below.
                if r + 1 < self.m {
                    for a in 0..4 {
                        g.add_edge(
                            self.qubit(r, c, 0, a) as u32,
                            self.qubit(r + 1, c, 0, a) as u32,
                            1,
                        );
                    }
                }
                // Horizontal chains: side-1 qubits to the cell right.
                if c + 1 < self.m {
                    for a in 0..4 {
                        g.add_edge(
                            self.qubit(r, c, 1, a) as u32,
                            self.qubit(r, c + 1, 1, a) as u32,
                            1,
                        );
                    }
                }
            }
        }
        g
    }
}

/// A minor embedding: logical spin → chain of physical qubits.
#[derive(Clone, Debug)]
pub struct Embedding {
    pub chains: Vec<Vec<usize>>,
    pub chimera: Chimera,
}

impl Embedding {
    /// Physical qubits used.
    pub fn physical_spins(&self) -> usize {
        self.chains.iter().map(|c| c.len()).sum()
    }

    /// Longest chain (ferromagnetic-chain length; drives chain-break
    /// probability on real annealers).
    pub fn max_chain(&self) -> usize {
        self.chains.iter().map(|c| c.len()).max().unwrap_or(0)
    }

    /// Overhead factor `physical / logical`.
    pub fn overhead(&self) -> f64 {
        self.physical_spins() as f64 / self.chains.len() as f64
    }
}

/// Classic triangle-layout embedding of `K_n` into Chimera (Choi 2011):
/// logical spin `i` (block `b_i = i/4`, lane `k = i mod 4`) occupies an
/// L-shaped chain — a horizontal side-1 run across row `b_i`, columns
/// `b_i..blocks`, and a vertical side-0 run down column `b_i`, rows
/// `0..=b_i`, meeting at diagonal cell `(b_i, b_i)`. For `b_i < b_j` the
/// chains cross at cell `(b_i, b_j)` where i's horizontal (side-1) qubit
/// couples j's vertical (side-0) qubit through the intra-cell `K_{4,4}`.
/// Requires `m ≥ ⌈n/4⌉`; returns None if it cannot fit.
pub fn embed_complete(n: usize, chimera: &Chimera) -> Option<Embedding> {
    let blocks = n.div_ceil(4);
    if blocks > chimera.m {
        return None;
    }
    let mut chains = Vec::with_capacity(n);
    for i in 0..n {
        let b = i / 4;
        let k = i % 4;
        let mut chain = Vec::new();
        // Horizontal run: side-1 qubit k across cells (b, b..blocks).
        for c in b..blocks {
            chain.push(chimera.qubit(b, c, 1, k));
        }
        // Vertical run: side-0 qubit k up cells (0..=b, b).
        for r in 0..=b {
            chain.push(chimera.qubit(r, b, 0, k));
        }
        chains.push(chain);
    }
    Some(Embedding { chains, chimera: chimera.clone() })
}

/// Verify an embedding against the hardware graph: chains are connected
/// subtrees, chains are vertex-disjoint, and every logical edge (u, v)
/// of the complete graph has at least one physical coupler between the
/// two chains.
pub fn verify_complete_embedding(emb: &Embedding) -> Result<(), String> {
    let hw = emb.chimera.graph();
    let mut adj: std::collections::HashMap<usize, Vec<usize>> = Default::default();
    for e in &hw.edges {
        adj.entry(e.u as usize).or_default().push(e.v as usize);
        adj.entry(e.v as usize).or_default().push(e.u as usize);
    }
    // Disjointness.
    let mut owner = std::collections::HashMap::new();
    for (i, chain) in emb.chains.iter().enumerate() {
        for &q in chain {
            if owner.insert(q, i).is_some() {
                return Err(format!("qubit {q} used by two chains"));
            }
        }
    }
    // Connectivity of each chain (BFS within chain vertices).
    for (i, chain) in emb.chains.iter().enumerate() {
        let set: std::collections::HashSet<usize> = chain.iter().copied().collect();
        let mut seen = std::collections::HashSet::new();
        let mut queue = vec![chain[0]];
        seen.insert(chain[0]);
        while let Some(q) = queue.pop() {
            for &nb in adj.get(&q).map(|v| v.as_slice()).unwrap_or(&[]) {
                if set.contains(&nb) && seen.insert(nb) {
                    queue.push(nb);
                }
            }
        }
        if seen.len() != chain.len() {
            return Err(format!("chain {i} is disconnected"));
        }
    }
    // Logical edge coverage.
    let n = emb.chains.len();
    for u in 0..n {
        let cu: std::collections::HashSet<usize> = emb.chains[u].iter().copied().collect();
        for v in (u + 1)..n {
            let connected = emb.chains[v].iter().any(|&q| {
                adj.get(&q).map(|nbs| nbs.iter().any(|nb| cu.contains(nb))).unwrap_or(false)
            });
            if !connected {
                return Err(format!("logical edge ({u},{v}) has no physical coupler"));
            }
        }
    }
    Ok(())
}

/// §III-A overhead table row: embedding `K_n` cost vs all-to-all.
pub fn overhead_row(n: usize) -> Option<(usize, usize, usize, f64)> {
    let m = n.div_ceil(4);
    let ch = Chimera::new(m);
    let emb = embed_complete(n, &ch)?;
    Some((n, emb.physical_spins(), emb.max_chain(), emb.overhead()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chimera_counts() {
        let c = Chimera::new(2);
        assert_eq!(c.qubits(), 32);
        let g = c.graph();
        // 4 cells × 16 intra + 1 col × 4 + ... : m=2 → inter: vertical
        // 4 qubits × m cols × (m-1) = 8, horizontal 8. 4*16+16 = 80.
        assert_eq!(g.edge_count(), 80);
        assert!(!g.has_duplicate_edges());
    }

    #[test]
    fn k6_embedding_like_fig5() {
        // Fig 5: K6 on Chimera needs more than six physical spins.
        let ch = Chimera::new(2);
        let emb = embed_complete(6, &ch).expect("K6 fits Chimera(2)");
        assert!(emb.physical_spins() > 6, "embedding must cost extra spins");
        verify_complete_embedding(&emb).expect("valid embedding");
    }

    #[test]
    fn larger_complete_graphs_verify() {
        for n in [4usize, 8, 12, 16] {
            let ch = Chimera::new(n.div_ceil(4));
            let emb = embed_complete(n, &ch).expect("fits");
            verify_complete_embedding(&emb).unwrap_or_else(|e| panic!("K{n}: {e}"));
            // Quadratic-ish growth of physical spins.
            assert!(emb.physical_spins() >= n * (n / 4).max(1));
        }
    }

    #[test]
    fn embedding_rejects_too_small_hardware() {
        assert!(embed_complete(9, &Chimera::new(2)).is_none());
    }

    #[test]
    fn overhead_grows_superlinearly() {
        let (_, p16, _, o16) = overhead_row(16).unwrap();
        let (_, p32, _, o32) = overhead_row(32).unwrap();
        assert!(p32 > 2 * p16, "physical spins must grow superlinearly");
        assert!(o32 > o16, "overhead factor must grow with n");
    }
}
