//! Gset benchmark instances (Table I): parser for the Stanford file format
//! plus an offline synthesizer.
//!
//! The real Gset files (`https://web.stanford.edu/~yyye/yyye/Gset/`) are
//! not redistributable inside this repository and the build environment is
//! offline, so `instance()` synthesizes graphs that match Table I exactly
//! in topology class, |V|, |E| and the |E⁺|/|E⁻| sign split (weights are
//! ±1, as in the signed Gset instances the paper uses). When a real file
//! is present under `$GSET_DIR` (or `./data/gset/`), `load_or_synthesize`
//! prefers it, so the harness transparently upgrades to the true instances
//! when they are available. See DESIGN.md §3 for why this substitution
//! preserves the evaluation's comparative structure.

use super::{generators, Graph};
use crate::rng::StatelessRng;
use std::io::BufRead;
use std::path::Path;

/// The instances used in the paper's evaluation (Table I), plus K2000.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GsetId {
    G6,
    G61,
    G18,
    G64,
    G11,
    G62,
    K2000,
}

impl GsetId {
    /// All Table I instances in paper order.
    pub const ALL: [GsetId; 7] =
        [GsetId::G6, GsetId::G61, GsetId::G18, GsetId::G64, GsetId::G11, GsetId::G62, GsetId::K2000];

    /// The six Gset instances of Table II (excludes K2000).
    pub const TABLE2: [GsetId; 6] =
        [GsetId::G6, GsetId::G61, GsetId::G18, GsetId::G64, GsetId::G11, GsetId::G62];

    pub fn name(self) -> &'static str {
        match self {
            GsetId::G6 => "G6",
            GsetId::G61 => "G61",
            GsetId::G18 => "G18",
            GsetId::G64 => "G64",
            GsetId::G11 => "G11",
            GsetId::G62 => "G62",
            GsetId::K2000 => "K2000",
        }
    }

    /// Table I row: (topology, |V|, |E|, |E+|, |E-|).
    pub fn spec(self) -> InstanceSpec {
        match self {
            GsetId::G6 => InstanceSpec::new("Erdos-Renyi", 800, 19176, 9665, 9511),
            GsetId::G61 => InstanceSpec::new("Erdos-Renyi", 7000, 17148, 8755, 8393),
            GsetId::G18 => InstanceSpec::new("Small-world", 800, 4694, 2379, 2315),
            GsetId::G64 => InstanceSpec::new("Small-world", 7000, 41459, 20993, 20466),
            GsetId::G11 => InstanceSpec::new("Torus", 800, 1600, 817, 783),
            GsetId::G62 => InstanceSpec::new("Torus", 7000, 14000, 6960, 7040),
            GsetId::K2000 => InstanceSpec::new("Complete", 2000, 1999000, 998314, 1000686),
        }
    }
}

/// Target statistics for one benchmark instance (a Table I row).
#[derive(Clone, Copy, Debug)]
pub struct InstanceSpec {
    pub topology: &'static str,
    pub v: usize,
    pub e: usize,
    pub e_pos: usize,
    pub e_neg: usize,
}

impl InstanceSpec {
    fn new(topology: &'static str, v: usize, e: usize, e_pos: usize, e_neg: usize) -> Self {
        debug_assert_eq!(e_pos + e_neg, e);
        Self { topology, v, e, e_pos, e_neg }
    }

    /// Edge density ρ (Table I last column).
    pub fn density(&self) -> f64 {
        2.0 * self.e as f64 / (self.v as f64 * (self.v as f64 - 1.0))
    }
}

/// Parse a Gset-format file: first line `|V| |E|`, then one `u v w` edge
/// per line (1-indexed vertices).
pub fn parse<R: BufRead>(reader: R) -> anyhow::Result<Graph> {
    let mut lines = reader.lines();
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty Gset file"))??;
    let mut it = header.split_whitespace();
    let n: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad header"))?.parse()?;
    let m: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad header"))?.parse()?;
    let mut g = Graph::empty(n);
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u32 = it.next().ok_or_else(|| anyhow::anyhow!("bad edge line: {t}"))?.parse()?;
        let v: u32 = it.next().ok_or_else(|| anyhow::anyhow!("bad edge line: {t}"))?.parse()?;
        let w: i32 = it.next().ok_or_else(|| anyhow::anyhow!("bad edge line: {t}"))?.parse()?;
        anyhow::ensure!(u >= 1 && v >= 1, "Gset vertices are 1-indexed");
        g.add_edge(u - 1, v - 1, w);
    }
    anyhow::ensure!(g.edge_count() == m, "header says {m} edges, file has {}", g.edge_count());
    Ok(g)
}

/// Write a graph in Gset format (for interchange with other solvers).
pub fn write<W: std::io::Write>(g: &Graph, mut w: W) -> std::io::Result<()> {
    writeln!(w, "{} {}", g.n, g.edge_count())?;
    for e in &g.edges {
        writeln!(w, "{} {} {}", e.u + 1, e.v + 1, e.w)?;
    }
    Ok(())
}

/// Synthesize an instance matching the Table I statistics. Pure function
/// of `(id, seed)`.
pub fn instance(id: GsetId, seed: u64) -> Graph {
    let spec = id.spec();
    let rng = StatelessRng::new(seed ^ (id as u64).wrapping_mul(0xA5A5_5A5A_0F0F_F0F0));
    let mut g = match id {
        GsetId::G6 | GsetId::G61 => erdos_renyi_matching(&spec, &rng),
        GsetId::G18 | GsetId::G64 => small_world_matching(&spec, &rng),
        GsetId::G11 | GsetId::G62 => torus_matching(&spec, &rng),
        GsetId::K2000 => generators::complete(spec.v, &[-1, 1], &rng),
    };
    // Match the paper's realized |E+|/|E-| split exactly (Table I); for
    // K2000 the paper draws ±1 uniformly and reports the realized split,
    // which we reproduce by adjusting the tail of the draw.
    force_sign_split(&mut g, spec.e_pos, spec.e_neg);
    g
}

/// Load the real Gset file if present under `dir` (file named e.g. `G6`),
/// else synthesize.
pub fn load_or_synthesize(id: GsetId, dir: Option<&Path>, seed: u64) -> Graph {
    let dirs: Vec<std::path::PathBuf> = match dir {
        Some(d) => vec![d.to_path_buf()],
        None => {
            let mut v = vec![std::path::PathBuf::from("data/gset")];
            if let Ok(env_dir) = std::env::var("GSET_DIR") {
                v.insert(0, env_dir.into());
            }
            v
        }
    };
    for d in dirs {
        let path = d.join(id.name());
        if let Ok(f) = std::fs::File::open(&path) {
            if let Ok(g) = parse(std::io::BufReader::new(f)) {
                return g;
            }
        }
    }
    instance(id, seed)
}

fn erdos_renyi_matching(spec: &InstanceSpec, rng: &StatelessRng) -> Graph {
    generators::erdos_renyi(spec.v, spec.e, &[-1, 1], rng)
}

fn small_world_matching(spec: &InstanceSpec, rng: &StatelessRng) -> Graph {
    // Watts–Strogatz gives exactly n·k edges; match |E| by a base ring of
    // k = floor(|E|/n) plus an ER top-up of the remainder.
    let k = spec.e / spec.v;
    let mut g = if k >= 1 {
        generators::small_world(spec.v, k, 0.1, &[-1, 1], rng)
    } else {
        Graph::empty(spec.v)
    };
    let missing = spec.e - g.edge_count();
    if missing > 0 {
        let mut seen: std::collections::HashSet<u64> =
            g.edges.iter().map(|e| ((e.u as u64) << 32) | e.v as u64).collect();
        let mut draw = 0u64;
        let mut added = 0;
        while added < missing {
            let u = rng.below(21, draw, crate::rng::salt::PROBLEM, spec.v as u32);
            let v = rng.below(22, draw, crate::rng::salt::PROBLEM, spec.v as u32);
            draw += 1;
            if u == v {
                continue;
            }
            let (a, b) = if u < v { (u, v) } else { (v, u) };
            if !seen.insert(((a as u64) << 32) | b as u64) {
                continue;
            }
            g.add_edge(a, b, 1);
            added += 1;
        }
    }
    g
}

fn torus_matching(spec: &InstanceSpec, rng: &StatelessRng) -> Graph {
    // |E| = 2|V| on a torus; pick near-square dims with rows*cols = |V|.
    let mut rows = (spec.v as f64).sqrt() as usize;
    while spec.v % rows != 0 {
        rows -= 1;
    }
    let cols = spec.v / rows;
    let g = generators::torus(rows, cols, &[-1, 1], rng);
    debug_assert_eq!(g.edge_count(), 2 * spec.v);
    g
}

/// Adjust edge signs in place so exactly `pos` edges are +1 and `neg` are
/// −1 (weights are ±1 here by construction).
fn force_sign_split(g: &mut Graph, pos: usize, neg: usize) {
    assert_eq!(pos + neg, g.edge_count());
    let mut cur_pos = g.edges.iter().filter(|e| e.w > 0).count();
    for e in g.edges.iter_mut() {
        if cur_pos > pos && e.w > 0 {
            e.w = -1;
            cur_pos -= 1;
        } else if cur_pos < pos && e.w < 0 {
            e.w = 1;
            cur_pos += 1;
        }
    }
    debug_assert_eq!(g.edges.iter().filter(|e| e.w > 0).count(), pos);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesized_instances_match_table1() {
        // Skip the two |V| = 7000 instances here to keep unit tests fast;
        // the integration suite covers them.
        for id in [GsetId::G6, GsetId::G18, GsetId::G11] {
            let spec = id.spec();
            let g = instance(id, 42);
            assert_eq!(g.n, spec.v, "{}: |V|", id.name());
            assert_eq!(g.edge_count(), spec.e, "{}: |E|", id.name());
            let (p, m) = g.sign_counts();
            assert_eq!(p, spec.e_pos, "{}: |E+|", id.name());
            assert_eq!(m, spec.e_neg, "{}: |E-|", id.name());
            assert!(!g.has_duplicate_edges(), "{}", id.name());
        }
    }

    #[test]
    fn parse_roundtrip() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 1, 1);
        g.add_edge(1, 2, -1);
        g.add_edge(2, 3, 1);
        let mut buf = Vec::new();
        write(&g, &mut buf).unwrap();
        let g2 = parse(std::io::BufReader::new(&buf[..])).unwrap();
        assert_eq!(g2.n, 4);
        assert_eq!(g2.edges, g.edges);
    }

    #[test]
    fn parse_rejects_bad_header() {
        assert!(parse(std::io::BufReader::new(&b""[..])).is_err());
        assert!(parse(std::io::BufReader::new(&b"3 1\n0 1 1\n"[..])).is_err()); // 0-indexed
    }

    #[test]
    fn density_matches_paper() {
        assert!((GsetId::G6.spec().density() - 0.06).abs() < 0.001);
        assert!((GsetId::K2000.spec().density() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = instance(GsetId::G11, 1);
        let b = instance(GsetId::G11, 1);
        assert_eq!(a.edges, b.edges);
    }
}
