//! Weighted undirected graphs: the problem side of the reproduction.
//!
//! Max-Cut / graph-partitioning instances live here as edge lists;
//! `crate::problems` maps them onto `IsingModel`s. `generators` builds the
//! topology classes of Table I (Erdős–Rényi, Watts–Strogatz small-world,
//! torus, complete) and `gset` parses real Gset files or synthesizes
//! instances matching the Table I statistics when the originals are not
//! available offline (see DESIGN.md §3).

pub mod chimera;
pub mod generators;
pub mod gset;

/// An undirected edge `{u, v}` with integer weight `w`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Edge {
    pub u: u32,
    pub v: u32,
    pub w: i32,
}

/// An undirected weighted graph as an edge list (each edge stored once,
/// with `u < v`).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    /// Number of vertices.
    pub n: usize,
    /// Edges with `u < v`, no duplicates.
    pub edges: Vec<Edge>,
}

impl Graph {
    /// Empty graph on `n` vertices.
    pub fn empty(n: usize) -> Self {
        Self { n, edges: Vec::new() }
    }

    /// Add edge `{u, v}` with weight `w`; normalizes to `u < v`.
    /// Self-loops are rejected.
    pub fn add_edge(&mut self, u: u32, v: u32, w: i32) {
        assert_ne!(u, v, "self-loops are not allowed");
        assert!((u as usize) < self.n && (v as usize) < self.n);
        let (u, v) = if u < v { (u, v) } else { (v, u) };
        self.edges.push(Edge { u, v, w });
    }

    /// Number of edges.
    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Count of strictly positive / strictly negative edges
    /// (`|E⁺|`, `|E⁻|` of Table I).
    pub fn sign_counts(&self) -> (usize, usize) {
        let pos = self.edges.iter().filter(|e| e.w > 0).count();
        let neg = self.edges.iter().filter(|e| e.w < 0).count();
        (pos, neg)
    }

    /// Edge density `ρ = 2|E| / (|V|(|V|−1))` (Table I).
    pub fn density(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        2.0 * self.edges.len() as f64 / (self.n as f64 * (self.n as f64 - 1.0))
    }

    /// Total weight `Σ w_e`.
    pub fn total_weight(&self) -> i64 {
        self.edges.iter().map(|e| e.w as i64).sum()
    }

    /// Sum of |w_e| (used by quality normalizations).
    pub fn total_abs_weight(&self) -> i64 {
        self.edges.iter().map(|e| e.w.unsigned_abs() as i64).sum()
    }

    /// Vertex degrees.
    pub fn degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.n];
        for e in &self.edges {
            d[e.u as usize] += 1;
            d[e.v as usize] += 1;
        }
        d
    }

    /// Detect duplicate edges (same unordered pair listed twice).
    pub fn has_duplicate_edges(&self) -> bool {
        let mut seen = std::collections::HashSet::with_capacity(self.edges.len());
        for e in &self.edges {
            if !seen.insert(((e.u as u64) << 32) | e.v as u64) {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_edge_normalizes_order() {
        let mut g = Graph::empty(4);
        g.add_edge(3, 1, 5);
        assert_eq!(g.edges[0], Edge { u: 1, v: 3, w: 5 });
    }

    #[test]
    fn density_of_complete_graph_is_one() {
        let mut g = Graph::empty(5);
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                g.add_edge(u, v, 1);
            }
        }
        assert!((g.density() - 1.0).abs() < 1e-12);
        assert_eq!(g.sign_counts(), (10, 0));
        assert!(!g.has_duplicate_edges());
    }

    #[test]
    #[should_panic(expected = "self-loops")]
    fn self_loop_rejected() {
        let mut g = Graph::empty(2);
        g.add_edge(1, 1, 1);
    }
}
