//! Random-graph generators for the Table I topology classes.
//!
//! All generators are driven by the stateless RNG so instance construction
//! is a pure function of the seed — the same property the paper relies on
//! for reproducible benchmarking.

use super::Graph;
use crate::rng::{salt, StatelessRng};

/// Erdős–Rényi G(n, m): exactly `m` distinct edges sampled uniformly.
/// Weights are drawn from `weights` uniformly at random.
pub fn erdos_renyi(n: usize, m: usize, weights: &[i32], rng: &StatelessRng) -> Graph {
    let max_m = n * (n - 1) / 2;
    assert!(m <= max_m, "m = {m} exceeds the {max_m} possible edges");
    let mut g = Graph::empty(n);
    let mut seen = std::collections::HashSet::with_capacity(m * 2);
    let mut draw = 0u64;
    while g.edges.len() < m {
        let u = rng.below(1, draw, salt::PROBLEM, n as u32);
        let v = rng.below(2, draw, salt::PROBLEM, n as u32);
        draw += 1;
        if u == v {
            continue;
        }
        let (a, b) = if u < v { (u, v) } else { (v, u) };
        if !seen.insert(((a as u64) << 32) | b as u64) {
            continue;
        }
        let w = pick_weight(weights, rng, 3, draw);
        g.add_edge(a, b, w);
    }
    g
}

/// Watts–Strogatz small-world: ring lattice with `k` nearest neighbours
/// per side, each edge rewired with probability `beta`. Produces the
/// "Small-world" rows of Table I (G18/G64-like).
pub fn small_world(n: usize, k: usize, beta: f64, weights: &[i32], rng: &StatelessRng) -> Graph {
    assert!(k >= 1 && 2 * k < n);
    let mut seen = std::collections::HashSet::new();
    let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(n * k);
    // Ring lattice.
    for u in 0..n {
        for d in 1..=k {
            let v = (u + d) % n;
            let (a, b) = if u < v { (u as u32, v as u32) } else { (v as u32, u as u32) };
            if seen.insert(((a as u64) << 32) | b as u64) {
                pairs.push((a, b));
            }
        }
    }
    // Rewire.
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(pairs.len());
    for (idx, &(a, b)) in pairs.iter().enumerate() {
        let t = idx as u64;
        if rng.unit_f64(4, t, salt::PROBLEM) < beta {
            // Rewire endpoint b to a uniform non-neighbour.
            let mut attempt = 0u64;
            loop {
                let c = rng.below(5, t * 97 + attempt, salt::PROBLEM, n as u32);
                attempt += 1;
                if c == a {
                    continue;
                }
                let (x, y) = if a < c { (a, c) } else { (c, a) };
                let key = ((x as u64) << 32) | y as u64;
                if seen.contains(&key) {
                    if attempt > 64 {
                        // Dense corner case: keep the original edge.
                        out.push((a, b));
                        break;
                    }
                    continue;
                }
                seen.remove(&(((a as u64) << 32) | b as u64));
                seen.insert(key);
                out.push((x, y));
                break;
            }
        } else {
            out.push((a, b));
        }
    }
    let mut g = Graph::empty(n);
    for (idx, (a, b)) in out.into_iter().enumerate() {
        let w = pick_weight(weights, rng, 6, idx as u64);
        g.add_edge(a, b, w);
    }
    g
}

/// 2-D torus (periodic grid) of `rows × cols` vertices, 4-neighbour
/// connectivity — the "Torus" rows of Table I (G11/G62-like).
pub fn torus(rows: usize, cols: usize, weights: &[i32], rng: &StatelessRng) -> Graph {
    let n = rows * cols;
    let mut g = Graph::empty(n);
    let id = |r: usize, c: usize| (r * cols + c) as u32;
    let mut idx = 0u64;
    for r in 0..rows {
        for c in 0..cols {
            let w1 = pick_weight(weights, rng, 7, idx);
            idx += 1;
            g.add_edge(id(r, c), id(r, (c + 1) % cols), w1);
            let w2 = pick_weight(weights, rng, 7, idx);
            idx += 1;
            g.add_edge(id(r, c), id((r + 1) % rows, c), w2);
        }
    }
    g
}

/// Complete graph K_n with weights drawn uniformly from `weights` —
/// the K2000 construction of §V-A2 with `weights = [-1, +1]`.
pub fn complete(n: usize, weights: &[i32], rng: &StatelessRng) -> Graph {
    let mut g = Graph::empty(n);
    let mut idx = 0u64;
    for u in 0..n as u32 {
        for v in (u + 1)..n as u32 {
            let w = pick_weight(weights, rng, 8, idx);
            idx += 1;
            g.add_edge(u, v, w);
        }
    }
    g
}

/// 2-D open grid (no wraparound) — substrate for the Fig. 4 "ISCA26"
/// planted-ground-state demonstration.
pub fn grid(rows: usize, cols: usize, w: i32) -> Graph {
    let n = rows * cols;
    let mut g = Graph::empty(n);
    let id = |r: usize, c: usize| (r * cols + c) as u32;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                g.add_edge(id(r, c), id(r, c + 1), w);
            }
            if r + 1 < rows {
                g.add_edge(id(r, c), id(r + 1, c), w);
            }
        }
    }
    g
}

#[inline]
fn pick_weight(weights: &[i32], rng: &StatelessRng, stage: u64, idx: u64) -> i32 {
    debug_assert!(!weights.is_empty());
    weights[rng.below(stage, idx, salt::PROBLEM, weights.len() as u32) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    const PM1: [i32; 2] = [-1, 1];

    #[test]
    fn erdos_renyi_exact_edge_count() {
        let rng = StatelessRng::new(11);
        let g = erdos_renyi(100, 500, &PM1, &rng);
        assert_eq!(g.n, 100);
        assert_eq!(g.edge_count(), 500);
        assert!(!g.has_duplicate_edges());
        let (p, m) = g.sign_counts();
        assert_eq!(p + m, 500);
        // ±1 uniform: both signs should appear in force.
        assert!(p > 150 && m > 150, "sign split {p}/{m} too skewed");
    }

    #[test]
    fn small_world_edge_count_preserved() {
        let rng = StatelessRng::new(13);
        let g = small_world(200, 3, 0.1, &PM1, &rng);
        assert_eq!(g.edge_count(), 200 * 3);
        assert!(!g.has_duplicate_edges());
    }

    #[test]
    fn torus_has_2n_edges_and_degree_4() {
        let rng = StatelessRng::new(17);
        let g = torus(10, 8, &PM1, &rng);
        assert_eq!(g.n, 80);
        assert_eq!(g.edge_count(), 160);
        assert!(g.degrees().iter().all(|&d| d == 4));
        assert!(!g.has_duplicate_edges());
    }

    #[test]
    fn complete_graph_density_one() {
        let rng = StatelessRng::new(19);
        let g = complete(50, &PM1, &rng);
        assert_eq!(g.edge_count(), 50 * 49 / 2);
        assert!((g.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn grid_edge_count() {
        let g = grid(3, 4, 1);
        // horizontal: 3*3, vertical: 2*4
        assert_eq!(g.edge_count(), 9 + 8);
    }

    #[test]
    fn generators_are_deterministic_in_seed() {
        let a = erdos_renyi(60, 200, &PM1, &StatelessRng::new(5));
        let b = erdos_renyi(60, 200, &PM1, &StatelessRng::new(5));
        assert_eq!(a.edges, b.edges);
        let c = erdos_renyi(60, 200, &PM1, &StatelessRng::new(6));
        assert_ne!(a.edges, c.edges);
    }
}
