//! Cooperative preemption: a shared [`StopToken`] that carries *why* a
//! job is being stopped.
//!
//! The fault-tolerant job lifecycle (docs/ARCHITECTURE.md § Job
//! lifecycle & fault tolerance) needs one signal that reaches every
//! layer — `Coordinator::cancel`, the deadline wheel, and shutdown all
//! trip the same token; the engine checks it at plateau boundaries and
//! the shard lanes at epoch barriers. A preempted run then returns its
//! best-so-far incumbent as a well-formed partial result instead of
//! vanishing.
//!
//! The token is a single atomic: the **first** cause to trip wins and
//! is sticky (a deadline firing after a cancel does not relabel the
//! job), and observers read it with one `Acquire` load — cheap enough
//! to poll every few engine steps. All primitives come from
//! [`crate::sync`], so the token stays loom-checkable; only
//! Acquire/Release orderings are used (the atomics policy bans SeqCst
//! and restricts Relaxed — see `xtask lint-safety`).

use crate::sync::atomic::{AtomicUsize, Ordering};

/// Why a run is being asked to stop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopCause {
    /// An explicit `Coordinator::cancel` / protocol `CANCEL`.
    Cancel,
    /// The job's `budget_ms` deadline elapsed.
    Deadline,
    /// Coordinator shutdown after `shutdown_grace_ms`.
    Shutdown,
}

impl StopCause {
    fn code(self) -> usize {
        match self {
            StopCause::Cancel => 1,
            StopCause::Deadline => 2,
            StopCause::Shutdown => 3,
        }
    }

    fn from_code(code: usize) -> Option<Self> {
        match code {
            1 => Some(StopCause::Cancel),
            2 => Some(StopCause::Deadline),
            3 => Some(StopCause::Shutdown),
            _ => None,
        }
    }
}

/// A shared, sticky, first-cause-wins stop request.
///
/// Clone-free by design: share it behind an `Arc` (the coordinator
/// hands one per job to every replica and keeps one to trip).
#[derive(Debug)]
pub struct StopToken(AtomicUsize);

// Manual impl: loom's `AtomicUsize` double has no `Default`, and the
// token must stay loom-checkable.
impl Default for StopToken {
    fn default() -> Self {
        Self::new()
    }
}

impl StopToken {
    /// A fresh, untripped token.
    pub fn new() -> Self {
        Self(AtomicUsize::new(0))
    }

    /// Request a stop with `cause`. Returns `true` if this call was the
    /// first to trip the token; a later cause never overwrites the
    /// first (cancel-then-deadline stays `Cancel`).
    pub fn trip(&self, cause: StopCause) -> bool {
        self.0.compare_exchange(0, cause.code(), Ordering::AcqRel, Ordering::Acquire).is_ok()
    }

    /// The cause the token was tripped with, if any.
    pub fn get(&self) -> Option<StopCause> {
        StopCause::from_code(self.0.load(Ordering::Acquire))
    }

    /// True once any cause has been recorded.
    pub fn is_stopped(&self) -> bool {
        self.get().is_some()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fresh_token_is_untripped() {
        let t = StopToken::new();
        assert_eq!(t.get(), None);
        assert!(!t.is_stopped());
    }

    #[test]
    fn first_cause_wins_and_is_sticky() {
        let t = StopToken::new();
        assert!(t.trip(StopCause::Cancel));
        assert!(!t.trip(StopCause::Deadline), "second trip must lose");
        assert!(!t.trip(StopCause::Cancel), "even the same cause trips once");
        assert_eq!(t.get(), Some(StopCause::Cancel));
        assert!(t.is_stopped());
    }

    #[test]
    fn every_cause_round_trips() {
        for cause in [StopCause::Cancel, StopCause::Deadline, StopCause::Shutdown] {
            let t = StopToken::new();
            assert!(t.trip(cause));
            assert_eq!(t.get(), Some(cause));
        }
    }

    #[test]
    fn racing_trips_elect_exactly_one_cause() {
        // Not a loom model (the token is one CAS — the interesting
        // property is agreement, not ordering): many threads race to
        // trip with different causes; all must observe the same winner.
        let t = Arc::new(StopToken::new());
        let handles: Vec<_> = [StopCause::Cancel, StopCause::Deadline, StopCause::Shutdown]
            .into_iter()
            .cycle()
            .take(12)
            .map(|cause| {
                let t = t.clone();
                std::thread::spawn(move || t.trip(cause))
            })
            .collect();
        let winners = handles.into_iter().filter(|h| h.join().unwrap()).count();
        assert_eq!(winners, 1, "exactly one trip call may win");
        assert!(t.get().is_some());
    }
}
