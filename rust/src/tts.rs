//! Time-to-solution statistics (paper §V-B2, Eq. 32).
//!
//! Each run is a Bernoulli trial that reaches the target with probability
//! `P_a(t_a)` within computing time `t_a`; the number of runs needed for
//! success probability `p` is `R ≥ ln(1−p)/ln(1−P_a)`, giving
//! `TTS(p) = t_a · ln(1−p)/ln(1−P_a)`.

/// Estimate of success probability from repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct SuccessEstimate {
    pub runs: usize,
    pub successes: usize,
}

impl SuccessEstimate {
    /// Point estimate `P_a = successes/runs`.
    pub fn p_a(&self) -> f64 {
        if self.runs == 0 {
            0.0
        } else {
            self.successes as f64 / self.runs as f64
        }
    }

    /// Wilson score interval (95%) for `P_a` — used to report error bars
    /// on the TTS rows.
    pub fn wilson_95(&self) -> (f64, f64) {
        if self.runs == 0 {
            return (0.0, 1.0);
        }
        let n = self.runs as f64;
        let p = self.p_a();
        let z = 1.96f64;
        let z2 = z * z;
        let denom = 1.0 + z2 / n;
        let center = (p + z2 / (2.0 * n)) / denom;
        let half = (z / denom) * (p * (1.0 - p) / n + z2 / (4.0 * n * n)).sqrt();
        ((center - half).max(0.0), (center + half).min(1.0))
    }
}

/// TTS(p) for a single-run time `t_a` (seconds) and success estimate.
///
/// Degenerate cases follow the conventions of the TTS literature
/// ([Rønnow et al. 2014]): `P_a == 0` → ∞; `P_a ≥ p` → a single run
/// suffices but never less than one run's time (`R` is clamped to ≥ 1).
pub fn tts(p: f64, t_a_seconds: f64, est: SuccessEstimate) -> f64 {
    assert!(p > 0.0 && p < 1.0, "target probability must be in (0,1)");
    let pa = est.p_a();
    if pa <= 0.0 {
        return f64::INFINITY;
    }
    if pa >= 1.0 {
        return t_a_seconds;
    }
    let r = (1.0 - p).ln() / (1.0 - pa).ln();
    t_a_seconds * r.max(1.0)
}

/// TTS(0.99), the figure of merit used throughout §V.
pub fn tts99(t_a_seconds: f64, est: SuccessEstimate) -> f64 {
    tts(0.99, t_a_seconds, est)
}

/// One row of the Table III comparison.
#[derive(Clone, Debug)]
pub struct TtsRow {
    pub machine: String,
    pub hardware: String,
    pub t_a_ms: f64,
    pub p_a: f64,
    pub tts99_ms: f64,
}

impl TtsRow {
    /// Build a row from measurements.
    pub fn measured(machine: &str, hardware: &str, t_a_seconds: f64, est: SuccessEstimate) -> Self {
        Self {
            machine: machine.to_string(),
            hardware: hardware.to_string(),
            t_a_ms: t_a_seconds * 1e3,
            p_a: est.p_a(),
            tts99_ms: tts99(t_a_seconds, est) * 1e3,
        }
    }

    /// A literature row quoted from the paper (CIM optics etc. that we
    /// cannot run); marked as such by the harness printer.
    pub fn quoted(machine: &str, hardware: &str, t_a_ms: f64, p_a: f64, tts99_ms: f64) -> Self {
        Self {
            machine: machine.to_string(),
            hardware: hardware.to_string(),
            t_a_ms,
            p_a,
            tts99_ms,
        }
    }

    /// Speedup of this row over a baseline TTS (Fig. 13's metric).
    pub fn speedup_over(&self, baseline_tts99_ms: f64) -> f64 {
        baseline_tts99_ms / self.tts99_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq32_worked_example() {
        // Paper Table III, Neal column: t_a = 4610 ms, P_a = 0.38
        // → TTS(0.99) = 4610 · ln(0.01)/ln(0.62) ≈ 44413 ms.
        let est = SuccessEstimate { runs: 100, successes: 38 };
        let v = tts99(4.610, est) * 1e3;
        assert!((v - 44413.0).abs() / 44413.0 < 0.01, "got {v}");
    }

    #[test]
    fn snowball_pa_099_single_run() {
        // Paper: Snowball reaches P_a = 0.99 within t_a, so TTS == t_a.
        let est = SuccessEstimate { runs: 100, successes: 99 };
        let v = tts99(0.128e-3, est);
        assert!((v - 0.128e-3).abs() < 1e-9);
    }

    #[test]
    fn zero_successes_is_infinite() {
        let est = SuccessEstimate { runs: 50, successes: 0 };
        assert!(tts99(1.0, est).is_infinite());
    }

    #[test]
    fn all_successes_is_one_run() {
        let est = SuccessEstimate { runs: 50, successes: 50 };
        assert_eq!(tts99(2.0, est), 2.0);
    }

    #[test]
    fn tts_monotone_in_pa() {
        let t = 1.0;
        let lo = tts99(t, SuccessEstimate { runs: 100, successes: 10 });
        let hi = tts99(t, SuccessEstimate { runs: 100, successes: 90 });
        assert!(hi < lo);
    }

    #[test]
    fn wilson_interval_contains_point() {
        let est = SuccessEstimate { runs: 200, successes: 120 };
        let (lo, hi) = est.wilson_95();
        let p = est.p_a();
        assert!(lo < p && p < hi);
        assert!(lo > 0.5 && hi < 0.7);
    }

    #[test]
    fn speedup_matches_fig13_shape() {
        // Fig 13: Snowball sequential mode 0.085 ms vs Neal 17693 ms
        // → 208,153×.
        let row = TtsRow::quoted("Snowball", "FPGA", 0.085, 0.99, 0.085);
        let s = row.speedup_over(17693.0);
        assert!((s - 208_153.0).abs() / 208_153.0 < 0.01);
    }
}
