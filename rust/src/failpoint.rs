//! Fault-injection failpoints: named sites where tests can make the
//! process misbehave on purpose.
//!
//! Compiled only under the `failpoints` cargo feature; in default
//! builds [`hit`] is an empty `#[inline(always)]` function, so the
//! sites cost nothing in release binaries and cannot fire in
//! production. With the feature on, a test arms a site by name
//! ([`arm_panic`]) and the next hits of that site count down a skip
//! budget and then panic — exercising exactly the unwind paths the
//! checkpoint/retry machinery (docs/ARCHITECTURE.md § Job lifecycle &
//! fault tolerance) exists to survive.
//!
//! Sites in the tree (grep for `failpoint::hit`):
//!
//! | site                | where it fires                                  |
//! |---------------------|--------------------------------------------------|
//! | `pool.run`          | inside a replica work item, before the run       |
//! | `mailbox.post`      | a shard lane broadcasting a flip to its peers    |
//! | `gate.arrive`       | a shard lane arriving at the epoch barrier       |
//! | `engine.checkpoint` | right after a replica records a checkpoint       |
//!
//! The registry is process-global, so tests that arm sites must not
//! run concurrently with tests that assume clean sites —
//! `tests/chaos.rs` runs under `--test-threads=1` in CI and disarms in
//! a drop guard. The panic payload carries the site name
//! (`"failpoint <site> fired"`), which the scheduler's catch-unwind
//! path surfaces verbatim in the job's failure message.

#[cfg(feature = "failpoints")]
mod imp {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};

    fn registry() -> &'static Mutex<HashMap<String, usize>> {
        static REGISTRY: OnceLock<Mutex<HashMap<String, usize>>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// Arm `site` to panic on its `skip + 1`-th upcoming hit (`skip = 0`
    /// fires on the very next hit). One-shot: firing disarms the site.
    /// Re-arming an armed site replaces its skip budget.
    pub fn arm_panic(site: &str, skip: usize) {
        registry().lock().unwrap().insert(site.to_string(), skip);
    }

    /// Disarm `site` if armed.
    pub fn disarm(site: &str) {
        registry().lock().unwrap().remove(site);
    }

    /// Disarm every site (test-teardown hygiene).
    pub fn disarm_all() {
        registry().lock().unwrap().clear();
    }

    /// Execution passes through the failpoint `site`: counts down an
    /// armed skip budget and panics when it expires. The lock is
    /// released before panicking so the registry is never poisoned.
    pub fn hit(site: &str) {
        let fire = {
            let mut reg = registry().lock().unwrap();
            match reg.get_mut(site) {
                Some(0) => {
                    reg.remove(site);
                    true
                }
                Some(skip) => {
                    *skip -= 1;
                    false
                }
                None => false,
            }
        };
        if fire {
            panic!("failpoint {site} fired");
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn unarmed_sites_are_inert_and_skip_counts_down() {
            // One sequential test owns every site it touches (the
            // registry is process-global; site names are unique here).
            hit("fp.test.inert");

            arm_panic("fp.test.skip", 2);
            hit("fp.test.skip");
            hit("fp.test.skip");
            let fired =
                std::panic::catch_unwind(|| hit("fp.test.skip")).expect_err("third hit fires");
            let msg = fired.downcast_ref::<String>().cloned().unwrap_or_default();
            assert!(msg.contains("failpoint fp.test.skip fired"), "payload names the site: {msg}");
            // One-shot: the site disarmed itself.
            hit("fp.test.skip");

            arm_panic("fp.test.disarm", 0);
            disarm("fp.test.disarm");
            hit("fp.test.disarm");

            arm_panic("fp.test.all", 0);
            disarm_all();
            hit("fp.test.all");
        }
    }
}

#[cfg(feature = "failpoints")]
pub use imp::{arm_panic, disarm, disarm_all, hit};

/// Default build: failpoints compile to nothing.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn hit(_site: &str) {}
