//! Table/figure row formatting shared by benches and the CLI.

/// Render an aligned text table: `header` then `rows`.
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(c.len())))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

/// Format seconds as engineering-style ms with sensible precision.
pub fn fmt_ms(seconds: f64) -> String {
    if !seconds.is_finite() {
        return "inf".into();
    }
    let ms = seconds * 1e3;
    if ms >= 100.0 {
        format!("{ms:.0}")
    } else if ms >= 1.0 {
        format!("{ms:.2}")
    } else {
        format!("{ms:.4}")
    }
}

/// An ASCII sparkline of a numeric series (used for energy traces in
/// bench output).
pub fn sparkline(values: &[f64]) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() {
        return String::new();
    }
    let min = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (max - min).max(1e-12);
    values
        .iter()
        .map(|&v| GLYPHS[(((v - min) / span) * 7.0).round() as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_aligned() {
        let t = render_table(
            "T",
            &["name", "value"],
            &[vec!["a".into(), "1".into()], vec!["longer".into(), "22".into()]],
        );
        assert!(t.contains("== T =="));
        for line in t.lines().skip(1) {
            if line.starts_with('-') || line.is_empty() {
                continue;
            }
        }
        assert!(t.contains("longer"));
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(fmt_ms(4.61), "4610");
        assert_eq!(fmt_ms(0.00461), "4.61");
        assert_eq!(fmt_ms(0.000085), "0.0850");
        assert_eq!(fmt_ms(f64::INFINITY), "inf");
    }

    #[test]
    fn sparkline_shape() {
        let s = sparkline(&[0.0, 1.0, 0.5]);
        assert_eq!(s.chars().count(), 3);
        assert!(s.starts_with('▁'));
    }
}
