//! One function per paper experiment (Tables I–III, Figs 2–4, 8, 12–15).
//!
//! Every function returns plain data; the bench binaries print it with
//! `printers` and EXPERIMENTS.md records measured-vs-paper. Budgets are
//! parameters so CI can run scaled-down versions of the same code paths.

use crate::baselines::{table2_lineup, Budget, Solver};
use crate::bitplane::BitPlanes;
use crate::engine::{
    glauber_exact, Datapath, EngineConfig, Mode, PwlLogistic, ReplicaPool, Schedule, SelectorKind,
    SnowballEngine,
};
use crate::graph::gset::{self, GsetId};
use crate::hwsim::{Geometry, HwModel};
use crate::ising::{IsingModel, SpinVec};
use crate::problems::{landscape, quantize, MaxCut};
use crate::rng::StatelessRng;
use crate::tts::{self, SuccessEstimate, TtsRow};

// ---------------------------------------------------------------- Table I

/// Table I row: measured statistics of one (synthesized) instance.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub name: String,
    pub topology: &'static str,
    pub v: usize,
    pub e: usize,
    pub e_pos: usize,
    pub e_neg: usize,
    pub density: f64,
}

/// Regenerate Table I by building every instance and measuring it.
pub fn table1(seed: u64) -> Vec<Table1Row> {
    GsetId::ALL
        .iter()
        .map(|&id| {
            let g = gset::instance(id, seed);
            let (p, n) = g.sign_counts();
            Table1Row {
                name: id.name().to_string(),
                topology: id.spec().topology,
                v: g.n,
                e: g.edge_count(),
                e_pos: p,
                e_neg: n,
                density: g.density(),
            }
        })
        .collect()
}

// --------------------------------------------------------- Table II / Fig 12

/// One (instance × solver) cell of Table II with its Fig 12 runtime.
#[derive(Clone, Debug)]
pub struct QualityCell {
    pub instance: String,
    pub solver: String,
    pub cut: i64,
    pub seconds: f64,
}

/// Run the full Table II line-up on the given instances. `sweeps` is the
/// per-solver budget (the paper's exact budgets are unspecified; all
/// solvers get the same sweep budget, the fairness criterion ReAIM uses).
pub fn table2(instances: &[GsetId], sweeps: u64, seed: u64) -> Vec<QualityCell> {
    let mut out = Vec::new();
    for &id in instances {
        let g = gset::load_or_synthesize(id, None, seed);
        let problem = MaxCut::new(g);
        for solver in table2_lineup() {
            let r = solver.solve(problem.model(), Budget::sweeps(sweeps), seed ^ 0xBEEF);
            out.push(QualityCell {
                instance: id.name().to_string(),
                solver: solver.name().to_string(),
                cut: problem.cut_of_energy(r.best_energy),
                seconds: r.wall.as_secs_f64(),
            });
        }
    }
    out
}

// --------------------------------------------------------- Table III / Fig 13

/// Configuration for the K2000 TTS experiment.
#[derive(Clone, Debug)]
pub struct TtsConfig {
    /// Success threshold on the cut value (paper: 33000).
    pub cut_threshold: i64,
    /// Independent runs per machine.
    pub runs: u32,
    /// Per-run sweep budget.
    pub sweeps: u64,
    pub seed: u64,
    /// Worker threads for the per-machine trial fan-out. The success
    /// statistics (P_a, best cut) are worker-count independent
    /// (stateless child seeds), but each trial's measured wall time —
    /// and therefore the reported `t_a`/TTS columns — picks up
    /// cross-trial cache/bandwidth contention when trials run
    /// concurrently. Default 1 (serial) for measurement fidelity;
    /// raise it (0 = one per CPU) when turnaround matters more than
    /// comparable timing rows.
    pub workers: usize,
}

impl Default for TtsConfig {
    fn default() -> Self {
        Self { cut_threshold: 33_000, runs: 20, sweeps: 2_000, seed: 1, workers: 1 }
    }
}

/// Paper-reported Table III rows that require hardware we cannot run
/// (quoted for side-by-side context in the bench output).
pub fn table3_quoted_rows() -> Vec<TtsRow> {
    vec![
        TtsRow::quoted("Neal (paper)", "CPU", 4610.0, 0.38, 44413.0),
        TtsRow::quoted("CIM (paper)", "Optics", 5.0, 0.02, 1139.74),
        TtsRow::quoted("SB (paper)", "FPGA", 0.5, 0.04, 56.14),
        TtsRow::quoted("STATICA (paper)", "CMOS", 0.48, 0.77, 1.5),
        TtsRow::quoted("ReAIM (paper)", "CMOS", 0.23, 0.8, 0.68),
        TtsRow::quoted("Snowball RWA (paper)", "FPGA", 0.128, 0.99, 0.128),
        TtsRow::quoted("Snowball RSA (paper)", "FPGA", 0.085, 0.99, 0.085),
    ]
}

/// Measured Table III: every machine reimplemented and run on the same
/// synthesized K2000 instance. Returns `(rows, best_cut_seen)`.
///
/// Snowball rows additionally get an FPGA-projected time from the cycle
/// model (`hwsim`), which is what makes the absolute scale comparable to
/// the paper's 300 MHz implementation.
pub fn table3(cfg: &TtsConfig) -> (Vec<TtsRow>, i64) {
    let g = gset::load_or_synthesize(GsetId::K2000, None, cfg.seed);
    let problem = MaxCut::new(g);
    let model = problem.model();
    let target_energy = problem.energy_of_cut(cfg.cut_threshold);
    let mut rows = Vec::new();
    let mut best_cut = i64::MIN;

    // Comparator set with iso-TIME sweep multipliers: one RWA step costs
    // Θ(N) evaluations while single-flip solvers pay Θ(1) per attempt,
    // so equal-sweep budgets would under-drive the cheap machines by
    // ~100×. TTS(p) already normalizes by t_a, so each machine runs at
    // a budget that spends comparable wall time (the operating-point
    // freedom the TTS literature assumes).
    let solvers: Vec<(Box<dyn Solver>, u64)> = vec![
        (Box::new(crate::baselines::Neal::default()), 100),
        (Box::new(crate::baselines::Cim::default()), 1),
        (Box::new(crate::baselines::SimulatedBifurcation::default()), 1),
        (Box::new(crate::baselines::Statica::default()), 100),
        (Box::new(crate::baselines::ReAim::asa()), 2),
        (Box::new(crate::baselines::SnowballSolver::rwa()), 1),
        (Box::new(crate::baselines::SnowballSolver::rsa()), 100),
    ];
    let hw = HwModel::default();
    let geom = Geometry { n: model.len(), planes: 1 };
    // Every machine's independent trials fan out over the shared replica
    // pool: seeds are stateless children of the trial index, so the
    // P_a / best-cut statistics are identical for any worker count.
    // NOTE: t_a sums per-trial wall times, which inflate under
    // concurrent execution (cache/bandwidth contention) — hence the
    // serial default in `TtsConfig::workers`; see its doc comment.
    let pool = ReplicaPool::new(cfg.workers);
    for (solver, mult) in solvers {
        let solver: &dyn Solver = solver.as_ref();
        let root = StatelessRng::new(cfg.seed ^ 0xD00D);
        let trials = pool.run_indexed(cfg.runs as usize, |run| {
            solver.solve(model, Budget::sweeps(cfg.sweeps * mult), root.child(run as u64).seed())
        });
        let mut successes = 0usize;
        let mut total_secs = 0f64;
        for r in &trials {
            best_cut = best_cut.max(problem.cut_of_energy(r.best_energy));
            if r.best_energy <= target_energy {
                successes += 1;
            }
            total_secs += r.wall.as_secs_f64();
        }
        let est = SuccessEstimate { runs: cfg.runs as usize, successes };
        let t_a = total_secs / cfg.runs as f64;
        let name = solver.name();
        rows.push(TtsRow::measured(name, "CPU (measured)", t_a, est));
        // FPGA projection for the Snowball modes (kernel cycles @300MHz).
        if name == "RWA" || name == "RSA" {
            let steps = cfg.sweeps * mult * model.len() as u64;
            let report = if name == "RWA" {
                hw.roulette_run(geom, steps)
            } else {
                hw.random_scan_run(geom, steps, steps / 2)
            };
            rows.push(TtsRow::measured(
                if name == "RWA" { "RWA (FPGA-projected)" } else { "RSA (FPGA-projected)" },
                "FPGA @300MHz (cycle model)",
                report.end_to_end_seconds,
                est,
            ));
        }
    }
    (rows, best_cut)
}

/// Fig 13: speedups of every row over the Neal baseline row.
pub fn fig13(rows: &[TtsRow]) -> Vec<(String, f64)> {
    let neal = rows
        .iter()
        .find(|r| r.machine.starts_with("Neal"))
        .map(|r| r.tts99_ms)
        .unwrap_or(f64::NAN);
    rows.iter().map(|r| (r.machine.clone(), neal / r.tts99_ms)).collect()
}

// ------------------------------------------------------------------ Fig 14

/// One Fig 14 point: runtimes at a Monte Carlo step count.
#[derive(Clone, Debug)]
pub struct Fig14Point {
    pub steps: u64,
    pub kernel_ms: f64,
    pub end_to_end_ms: f64,
    pub naive_ms: f64,
}

/// Fig 14 from the cycle model: kernel-only vs end-to-end (with DMA) vs
/// naive (no incremental updates) across step counts, K2000 geometry.
pub fn fig14_model(step_counts: &[u64]) -> Vec<Fig14Point> {
    let hw = HwModel::default();
    let g = Geometry { n: 2000, planes: 1 };
    step_counts
        .iter()
        .map(|&steps| {
            let inc = hw.roulette_run(g, steps);
            let naive = hw.naive_run(g, steps);
            Fig14Point {
                steps,
                kernel_ms: inc.kernel_seconds * 1e3,
                end_to_end_ms: inc.end_to_end_seconds * 1e3,
                naive_ms: naive.end_to_end_seconds * 1e3,
            }
        })
        .collect()
}

/// Measured companion to Fig 14: CPU wall-clock of the incremental
/// engine vs a from-scratch ("naive") field recompute per step, on a
/// smaller instance so the naive path stays tractable.
pub fn fig14_measured(n: usize, steps: u64, seed: u64) -> (f64, f64) {
    let rng = StatelessRng::new(seed);
    let g = crate::graph::generators::complete(n, &[-1, 1], &rng);
    let p = MaxCut::new(g);
    // Incremental: the real engine.
    let cfg = EngineConfig::new(Mode::RouletteWheel, steps, seed);
    let mut engine = SnowballEngine::new(p.model(), cfg);
    let start = std::time::Instant::now();
    engine.run();
    let incremental = start.elapsed().as_secs_f64();
    // Naive: recompute all fields from scratch every step.
    let mut spins = SpinVec::random(n, &rng);
    let lut = PwlLogistic::default();
    let start = std::time::Instant::now();
    let schedule = Schedule::Geometric { t0: 10.0, t1: 0.05 };
    for t in 0..steps {
        let temp = schedule.temperature(t, steps);
        let u = p.model().local_fields(&spins); // Θ(N²) — the waste
        let mut w = 0u64;
        let mut probs = vec![0u32; n];
        for i in 0..n {
            probs[i] = lut.flip_prob_q16(IsingModel::delta_e(spins.get(i), u[i]), temp);
            w += probs[i] as u64;
        }
        if w == 0 {
            continue;
        }
        let r = ((rng.u64(t, 0, crate::rng::salt::ROULETTE) as u128 * w as u128) >> 64) as u64;
        let mut acc = 0u64;
        for i in 0..n {
            acc += probs[i] as u64;
            if r < acc {
                spins.flip(i);
                break;
            }
        }
    }
    let naive = start.elapsed().as_secs_f64();
    (incremental, naive)
}

// ------------------------------------------------------------------ Fig 15

/// Fig 15 result: 16-bit bit-plane field encode → anneal → decode.
#[derive(Clone, Debug)]
pub struct Fig15Result {
    /// Fraction of pixels whose decoded 16-bit value matches the target
    /// exactly (paper: 99.5%).
    pub pixel_accuracy: f64,
    /// Energy trace of the cosine-annealed run (z-scored Fig 15 curve).
    pub energy_trace: Vec<(u64, i64)>,
    /// Ground-state alignment: fraction of spins at their planted value.
    pub spin_alignment: f64,
}

/// Fig 15: encode a 64×64 16-bit target field into coupler bit-planes
/// (bipartite row-spin × column-spin block, B = 16), anneal with the
/// cosine schedule, then decode the planes and compare pixel-exact.
/// See EXPERIMENTS.md for the mapping rationale.
pub fn fig15(seed: u64) -> Fig15Result {
    let rows = 64usize;
    let cols = 64usize;
    // Smooth synthetic 16-bit target (sum of sinusoids like the paper's
    // 3-D surface), values in [-32767, 32767].
    let mut target = vec![0i32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let x = r as f64 / rows as f64 * std::f64::consts::TAU;
            let y = c as f64 / cols as f64 * std::f64::consts::TAU;
            let v = (x.sin() * y.cos() * 0.5 + (2.0 * x).cos() * 0.25 + (3.0 * y).sin() * 0.25)
                * 32767.0;
            target[r * cols + c] = v.round().clamp(-32767.0, 32767.0) as i32;
        }
    }
    // Bipartite encoding: spin r (rows) × spin 64+c (cols);
    // J[r][64+c] = target pixel. 128 spins, B = 16 planes.
    let n = rows + cols;
    let mut model = IsingModel::zeros(n);
    for r in 0..rows {
        for c in 0..cols {
            let v = target[r * cols + c];
            if v != 0 {
                model.set_j(r, rows + c, v);
            }
        }
    }
    let planes = BitPlanes::encode(&model, Some(16));
    // Anneal with the paper's cosine schedule; the ground state of the
    // bipartite ±field model aligns spins with the dominant pixel signs.
    let cfg = EngineConfig {
        mode: Mode::RouletteWheel,
        datapath: Datapath::BitPlane,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Cosine { t0: 60_000.0, t1: 1.0 },
        steps: 20_000,
        seed,
        planes: Some(16),
        trace_stride: 500,
        shards: 1,
        pin_lanes: false,
        local_rows: false,
    };
    let mut engine = SnowballEngine::new(&model, cfg);
    let run = engine.run();
    // Decode the planes back to pixels (the "recovered landscape").
    let mut exact = 0usize;
    for r in 0..rows {
        for c in 0..cols {
            if planes.decode_j(r, rows + c) == target[r * cols + c] {
                exact += 1;
            }
        }
    }
    // Alignment against the exhaustively-known bipartite optimum is
    // expensive; report alignment with the best-found configuration's
    // energy ratio instead: H_best / H_min_bound.
    let h_bound: i64 = target.iter().map(|&v| (v as i64).abs()).sum();
    let alignment = (-run.best_energy) as f64 / h_bound as f64;
    Fig15Result {
        pixel_accuracy: exact as f64 / (rows * cols) as f64,
        energy_trace: run.trace,
        spin_alignment: alignment,
    }
}

// ----------------------------------------------------------- Figs 2, 3, 8

/// Fig 3 data: `(ΔE, P_flip)` curves at several temperatures, exact and
/// LUT-approximated.
pub fn fig3(temps: &[f64], de_range: i64) -> Vec<(f64, Vec<(i64, f64, f64)>)> {
    let lut = PwlLogistic::default();
    temps
        .iter()
        .map(|&t| {
            let pts = (-de_range..=de_range)
                .map(|de| {
                    let exact = if t > 0.0 { glauber_exact(de as f64 / t) } else { f64::NAN };
                    let approx = lut.flip_prob_q16(de, t) as f64 / crate::engine::ONE_Q16 as f64;
                    (de, exact, approx)
                })
                .collect();
            (t, pts)
        })
        .collect()
}

/// Fig 2: the K5 instance's full energy landscape.
pub fn fig2() -> (IsingModel, Vec<i64>) {
    let m = landscape::fig2_k5();
    let e = landscape::enumerate(&m);
    (m, e)
}

/// Fig 8: the K5 landscape before and after 2-bit arithmetic-shift
/// quantization, plus whether the ground state moved.
pub fn fig8() -> (Vec<i64>, Vec<i64>, bool) {
    let m = landscape::fig2_k5();
    let q = quantize::arithmetic_shift(&m, 2);
    let e0 = landscape::enumerate(&m);
    let e1 = landscape::enumerate(&q);
    let g0 = e0.iter().enumerate().min_by_key(|(_, &v)| v).map(|(i, _)| i);
    let g1 = e1.iter().enumerate().min_by_key(|(_, &v)| v).map(|(i, _)| i);
    (e0, e1, g0 != g1)
}

// ------------------------------------------------------------------ Fig 4

/// Fig 4: plant "ISCA26"-style text as the ground state of a grid
/// antiferromagnet-ish Max-Cut instance and recover it by annealing.
/// Returns `(recovered fraction, trace, grid dims)`.
pub fn fig4(steps: u64, seed: u64) -> (f64, Vec<(u64, i64)>, (usize, usize)) {
    let (rows, cols, pattern) = isca_pattern();
    let n = rows * cols;
    // Planted Max-Cut: edges with equal planted spins get weight −1
    // (cutting them is penalized), differing get +1 — the unique max cut
    // (up to global flip) is the planted pattern.
    let mut g = crate::graph::Graph::empty(n);
    let id = |r: usize, c: usize| (r * cols + c) as u32;
    for r in 0..rows {
        for c in 0..cols {
            let s = pattern[r * cols + c];
            if c + 1 < cols {
                let w = if s == pattern[r * cols + c + 1] { -1 } else { 1 };
                g.add_edge(id(r, c), id(r, c + 1), w);
            }
            if r + 1 < rows {
                let w = if s == pattern[(r + 1) * cols + c] { -1 } else { 1 };
                g.add_edge(id(r, c), id(r + 1, c), w);
            }
        }
    }
    let p = MaxCut::new(g);
    let cfg = EngineConfig {
        mode: Mode::RouletteWheel,
        datapath: Datapath::Dense,
        selector: SelectorKind::Fenwick,
        schedule: Schedule::Linear { t0: 3.0, t1: 0.0 },
        steps,
        seed,
        planes: None,
        trace_stride: (steps / 64).max(1),
        shards: 1,
        pin_lanes: false,
        local_rows: false,
    };
    let mut engine = SnowballEngine::new(p.model(), cfg);
    let run = engine.run();
    // Recovered fraction (mod global spin flip).
    let mut same = 0usize;
    for i in 0..n {
        if run.best_spins.get(i) == pattern[i] {
            same += 1;
        }
    }
    let frac = (same.max(n - same)) as f64 / n as f64;
    (frac, run.trace, (rows, cols))
}

/// A 7×38 dot-matrix "ISCA26" pattern as ±1 spins.
pub fn isca_pattern() -> (usize, usize, Vec<i8>) {
    const ART: [&str; 7] = [
        " ###  ###   ##   ###   ##    ##  ",
        "  #  #     #  # #   # #  #  #  # ",
        "  #  #     #    #   #    #  #    ",
        "  #   ###  #    #####   ##  ####  ",
        "  #      # #    #   #  #    #   #",
        "  #      # #  # #   # #     #   #",
        " ###  ###   ##  #   # ####   ### ",
    ];
    let rows = ART.len();
    let cols = ART.iter().map(|l| l.len()).max().unwrap();
    let mut v = vec![-1i8; rows * cols];
    for (r, line) in ART.iter().enumerate() {
        for (c, ch) in line.chars().enumerate() {
            if ch == '#' {
                v[r * cols + c] = 1;
            }
        }
    }
    (rows, cols, v)
}

/// Render a spin grid as ASCII art (Fig 4 checkpoints).
pub fn render_grid(spins: &SpinVec, rows: usize, cols: usize) -> String {
    let mut out = String::new();
    for r in 0..rows {
        for c in 0..cols {
            out.push(if spins.get(r * cols + c) == 1 { '#' } else { ' ' });
        }
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------- helpers

/// Success-threshold helper used by examples: TTS from a set of measured
/// best energies.
pub fn tts_from_runs(
    energies: &[i64],
    per_run_seconds: f64,
    target_energy: i64,
) -> (SuccessEstimate, f64) {
    let est = SuccessEstimate {
        runs: energies.len(),
        successes: energies.iter().filter(|&&e| e <= target_energy).count(),
    };
    (est, tts::tts99(per_run_seconds, est))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_spec() {
        for row in table1(42) {
            let spec = GsetId::ALL.iter().find(|id| id.name() == row.name).unwrap().spec();
            assert_eq!(row.v, spec.v, "{}", row.name);
            assert_eq!(row.e, spec.e, "{}", row.name);
            assert_eq!(row.e_pos, spec.e_pos, "{}", row.name);
        }
    }

    #[test]
    fn table2_small_run_has_all_cells() {
        let cells = table2(&[GsetId::G11], 10, 7);
        assert_eq!(cells.len(), 11); // 11 solvers
        assert!(cells.iter().all(|c| c.seconds > 0.0));
    }

    #[test]
    fn fig3_exact_vs_lut_agree() {
        let data = fig3(&[0.5, 2.0], 10);
        for (_, pts) in data {
            for (_, exact, approx) in pts {
                assert!((exact - approx).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn fig8_quantization_moves_ground_state_or_distorts() {
        let (e0, e1, _moved) = fig8();
        assert_ne!(e0, e1);
    }

    #[test]
    fn fig14_model_shapes() {
        let pts = fig14_model(&[100, 1000]);
        assert_eq!(pts.len(), 2);
        for p in pts {
            assert!(p.naive_ms > p.end_to_end_ms, "naive must be slower");
            assert!(p.end_to_end_ms >= p.kernel_ms);
        }
    }

    #[test]
    fn fig4_pattern_dimensions() {
        let (r, c, v) = isca_pattern();
        assert_eq!(v.len(), r * c);
        assert!(v.iter().any(|&s| s == 1) && v.iter().any(|&s| s == -1));
    }

    #[test]
    fn fig15_bitplane_recovery_is_exact() {
        let r = fig15(3);
        // Our digital store is lossless: accuracy must meet/beat the
        // paper's 99.5%.
        assert!(r.pixel_accuracy >= 0.995, "accuracy {}", r.pixel_accuracy);
        assert!(!r.energy_trace.is_empty());
    }
}
