//! Regeneration harness: one entry point per paper table/figure
//! (DESIGN.md §5 experiment index). The bench binaries and the
//! `snowball bench` CLI subcommand are thin wrappers over these.

pub mod experiments;
pub mod printers;

pub use experiments::*;
pub use printers::*;
