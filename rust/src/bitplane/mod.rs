//! Signed bit-plane representation of the dense coupling matrix
//! (paper §IV-B1) and the two access paths built on it:
//!
//! * **row-major planes + Hamming-weight accumulation** — from-scratch
//!   local-field initialization (Eqs. 14–16);
//! * **column-major planes + bit scanning** — Θ(N) incremental updates
//!   after each accepted flip (Eqs. 17–20).
//!
//! `J_ij = Σ_b 2^b (B⁺_b(i,j) − B⁻_b(i,j))` (Eq. 13), with
//! `B⁺, B⁻ ∈ {0,1}^{N×N}` packed 64 couplers per word exactly like the
//! FPGA's BRAM words. This module is bit-faithful to the hardware
//! datapath: every arithmetic step is a popcount, shift or integer add.

pub mod planes;

pub use planes::BitPlanes;
