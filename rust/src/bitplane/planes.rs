//! Bit-plane storage and the Hamming-weight / bit-scan datapaths.

use crate::ising::{IsingModel, SpinVec};

/// Signed-magnitude bit-plane store for a dense `n × n` coupling matrix,
/// in BOTH row-major and column-major layouts (paper §IV-B1: row-major
/// feeds dense initialization, column-major feeds incremental updates).
///
/// Indexing: plane `b`, line `i`, word `w` → `[(b * n + i) * words + w]`.
/// For the row arrays a "line" is a matrix row; for the column arrays it
/// is a matrix column (i.e. `col_pos` holds B⁺ᵀ).
#[derive(Clone, Debug)]
pub struct BitPlanes {
    n: usize,
    b: u32,
    words: usize,
    row_pos: Vec<u64>,
    row_neg: Vec<u64>,
    col_pos: Vec<u64>,
    col_neg: Vec<u64>,
}

impl BitPlanes {
    /// Encode a model's couplings. `planes` defaults to the minimum `B`
    /// that represents every `|J_ij|` exactly; passing a larger `B`
    /// reproduces the paper's configurable-precision setting.
    pub fn encode(model: &IsingModel, planes: Option<u32>) -> Self {
        let n = model.len();
        let need = crate::problems::quantize::required_bits(model);
        let b = planes.unwrap_or(need);
        assert!(b >= need, "B = {b} planes cannot represent max |J| (needs {need})");
        assert!(b <= 31);
        let words = n.div_ceil(64);
        let sz = b as usize * n * words;
        let mut s = Self {
            n,
            b,
            words,
            row_pos: vec![0; sz],
            row_neg: vec![0; sz],
            col_pos: vec![0; sz],
            col_neg: vec![0; sz],
        };
        for i in 0..n {
            let row = model.j_row(i);
            for (j, v) in row.iter().enumerate() {
                if v == 0 {
                    continue;
                }
                let mag = v.unsigned_abs();
                for plane in 0..b {
                    if (mag >> plane) & 1 == 1 {
                        if v > 0 {
                            s.set_bit(true, plane, i, j);
                        } else {
                            s.set_bit(false, plane, i, j);
                        }
                    }
                }
            }
        }
        s
    }

    fn set_bit(&mut self, positive: bool, plane: u32, i: usize, j: usize) {
        let idx = (plane as usize * self.n + i) * self.words + (j >> 6);
        let bit = 1u64 << (j & 63);
        let tidx = (plane as usize * self.n + j) * self.words + (i >> 6);
        let tbit = 1u64 << (i & 63);
        if positive {
            self.row_pos[idx] |= bit;
            self.col_pos[tidx] |= tbit;
        } else {
            self.row_neg[idx] |= bit;
            self.col_neg[tidx] |= tbit;
        }
    }

    /// Number of spins.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the store is empty.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of magnitude planes `B`.
    pub fn planes(&self) -> u32 {
        self.b
    }

    /// 64-bit words per row (`W = ceil(N/64)`).
    pub fn words_per_row(&self) -> usize {
        self.words
    }

    /// Reconstruct `J_ij` from the planes (Eq. 13) — decode path used by
    /// round-trip tests and the Fig. 15 field-recovery experiment.
    pub fn decode_j(&self, i: usize, j: usize) -> i32 {
        let mut v = 0i32;
        for plane in 0..self.b {
            let idx = (plane as usize * self.n + i) * self.words + (j >> 6);
            let bit = 1u64 << (j & 63);
            if self.row_pos[idx] & bit != 0 {
                v += 1 << plane;
            }
            if self.row_neg[idx] & bit != 0 {
                v -= 1 << plane;
            }
        }
        v
    }

    /// Full decode to a dense model (zero fields).
    pub fn decode(&self) -> IsingModel {
        let mut j = vec![0i32; self.n * self.n];
        for i in 0..self.n {
            for k in 0..self.n {
                j[i * self.n + k] = self.decode_j(i, k);
            }
        }
        IsingModel::new(self.n, j, vec![0; self.n])
    }

    /// **Initialization path** (Eqs. 14–16): coupler-induced local fields
    /// `u_i^(J) = Σ_j J_ij s_j` for every `i`, computed with per-word
    /// Hamming weights over the row-major planes:
    ///
    /// `Δu⁺ = 2^b (2·popcnt(B⁺_word & x_word) − popcnt(B⁺_word))`, and the
    /// negated analogue for B⁻. Only bitwise ops and integer adds — the
    /// FPGA accumulator, word for word.
    pub fn init_fields(&self, x: &SpinVec) -> Vec<i64> {
        assert_eq!(x.len(), self.n);
        let xw = x.words();
        let mut u = vec![0i64; self.n];
        for plane in 0..self.b as usize {
            let wb = 1i64 << plane;
            for i in 0..self.n {
                let base = (plane * self.n + i) * self.words;
                let mut acc = 0i64;
                for w in 0..self.words {
                    let p = self.row_pos[base + w];
                    let ng = self.row_neg[base + w];
                    let m_p = p.count_ones() as i64;
                    let o_p = (p & xw[w]).count_ones() as i64;
                    let m_n = ng.count_ones() as i64;
                    let o_n = (ng & xw[w]).count_ones() as i64;
                    acc += (2 * o_p - m_p) - (2 * o_n - m_n);
                }
                u[i] += wb * acc;
            }
        }
        u
    }

    /// **Incremental path** (Eqs. 17–20): after spin `j` flips from
    /// `s_j_old`, stream column `j` of the column-major planes and apply
    /// `u_i ← u_i ∓ 2·2^b·s_j_old` at every set bit. Θ(B·W) words
    /// scanned, Θ(deg j) adds.
    pub fn incr_update(&self, u: &mut [i64], j: usize, s_j_old: i8) {
        self.incr_update_touched(u, j, s_j_old, |_| {});
    }

    /// [`Self::incr_update`] that additionally reports every field index
    /// it adjusted through `touched` — the delta feed of the engine's
    /// incremental Mode II lane maintenance. A field spanning multiple
    /// magnitude planes is reported once per plane; callers deduplicate
    /// (the engine's dirty-lane stamp does). The closure is monomorphized
    /// away, so the plain `incr_update` pays nothing for it.
    pub fn incr_update_touched(
        &self,
        u: &mut [i64],
        j: usize,
        s_j_old: i8,
        touched: impl FnMut(usize),
    ) {
        debug_assert_eq!(u.len(), self.n);
        self.incr_update_range_touched(u, 0..self.n, j, s_j_old, touched);
    }

    /// Range-restricted incremental update — the shard-lane view of
    /// column `j`. Only spins in `range` are updated: `u_local` is the
    /// field slice `u[range]` (indexed from 0), and `touched` receives
    /// **range-local** indices (`global − range.start`), which is what
    /// feeds a range-restricted lane kernel's dirty set directly. Words
    /// outside the range are never scanned and boundary words are
    /// masked, so the cost is `Θ(B · ⌈|range|/64⌉)` words plus
    /// `Θ(deg j ∩ range)` adds. With `range == 0..n` this is exactly
    /// [`Self::incr_update_touched`] (same adds, same order).
    pub fn incr_update_range_touched(
        &self,
        u_local: &mut [i64],
        range: std::ops::Range<usize>,
        j: usize,
        s_j_old: i8,
        mut touched: impl FnMut(usize),
    ) {
        let (lo, hi) = (range.start, range.end);
        debug_assert!(hi <= self.n && lo <= hi);
        debug_assert_eq!(u_local.len(), hi - lo);
        if lo == hi {
            return;
        }
        let w0 = lo >> 6;
        let w1 = (hi + 63) >> 6;
        let s_old = s_j_old as i64;
        for plane in 0..self.b as usize {
            let delta = 2i64 * (1i64 << plane) * s_old;
            let base = (plane * self.n + j) * self.words;
            for w in w0..w1 {
                // Mask off bits below `lo` in the first word and at or
                // above `hi` in the last word.
                let mut keep = u64::MAX;
                if w == w0 {
                    keep &= u64::MAX << (lo & 63);
                }
                if w == w1 - 1 && (hi & 63) != 0 {
                    keep &= u64::MAX >> (64 - (hi & 63));
                }
                // Positive planes: u_i -= 2·2^b·s_old (Eq. 19)
                let mut bits = self.col_pos[base + w] & keep;
                while bits != 0 {
                    let t = bits.trailing_zeros() as usize;
                    let i = (w << 6) + t - lo;
                    u_local[i] -= delta;
                    touched(i);
                    bits &= bits - 1;
                }
                // Negative planes: u_i += 2·2^b·s_old (Eq. 20)
                let mut bits = self.col_neg[base + w] & keep;
                while bits != 0 {
                    let t = bits.trailing_zeros() as usize;
                    let i = (w << 6) + t - lo;
                    u_local[i] += delta;
                    touched(i);
                    bits &= bits - 1;
                }
            }
        }
    }

    /// Bytes of on-chip storage the four plane arrays occupy — the
    /// quantity the paper's "memory grows linearly in B" claim is about.
    pub fn storage_bytes(&self) -> usize {
        4 * self.b as usize * self.n * self.words * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{salt, StatelessRng};

    fn random_model(n: usize, max_abs: i32, seed: u64) -> IsingModel {
        let rng = StatelessRng::new(seed);
        let mut m = IsingModel::zeros(n);
        let mut idx = 0u64;
        for i in 0..n {
            for k in (i + 1)..n {
                let v = rng.below(9, idx, salt::PROBLEM, (2 * max_abs + 1) as u32) as i32 - max_abs;
                idx += 1;
                if v != 0 {
                    m.set_j(i, k, v);
                }
            }
        }
        m
    }

    #[test]
    fn encode_decode_roundtrip() {
        let m = random_model(70, 100, 1);
        let bp = BitPlanes::encode(&m, None);
        assert_eq!(bp.planes(), 7); // 100 needs 7 bits
        let d = bp.decode();
        assert_eq!(d.j_matrix(), m.j_matrix());
    }

    #[test]
    fn extra_planes_still_roundtrip() {
        let m = random_model(20, 3, 2);
        let bp = BitPlanes::encode(&m, Some(16));
        assert_eq!(bp.planes(), 16);
        assert_eq!(bp.decode().j_matrix(), m.j_matrix());
    }

    #[test]
    #[should_panic(expected = "cannot represent")]
    fn too_few_planes_rejected() {
        let m = random_model(10, 9, 3); // needs 4 bits
        BitPlanes::encode(&m, Some(2));
    }

    #[test]
    fn init_fields_matches_dense() {
        let m = random_model(130, 7, 4);
        let bp = BitPlanes::encode(&m, None);
        let rng = StatelessRng::new(5);
        for t in 0..5u64 {
            let s = SpinVec::random(130, &rng.child(t));
            let dense: Vec<i64> =
                (0..130).map(|i| m.local_field(&s, i) - m.h(i) as i64).collect();
            assert_eq!(bp.init_fields(&s), dense, "trial {t}");
        }
    }

    #[test]
    fn incremental_matches_reinit_over_flip_sequence() {
        let m = random_model(100, 15, 6);
        let bp = BitPlanes::encode(&m, None);
        let rng = StatelessRng::new(7);
        let mut s = SpinVec::random(100, &rng);
        let mut u = bp.init_fields(&s);
        for t in 0..200u64 {
            let j = rng.below(10, t, salt::SITE, 100) as usize;
            let s_old = s.flip(j);
            bp.incr_update(&mut u, j, s_old);
            if t % 50 == 49 {
                assert_eq!(u, bp.init_fields(&s), "drift after {} flips", t + 1);
            }
        }
    }

    /// The touched-field report must be exactly the neighbourhood of the
    /// flipped spin: every `i` with `J_ij != 0`, nothing else.
    #[test]
    fn incr_update_reports_touched_neighbourhood() {
        let m = random_model(90, 15, 12);
        let bp = BitPlanes::encode(&m, None);
        let rng = StatelessRng::new(13);
        let mut s = SpinVec::random(90, &rng);
        let mut u = bp.init_fields(&s);
        for t in 0..50u64 {
            let j = rng.below(14, t, salt::SITE, 90) as usize;
            let s_old = s.flip(j);
            let mut touched = std::collections::BTreeSet::new();
            bp.incr_update_touched(&mut u, j, s_old, |i| {
                touched.insert(i);
            });
            let expect: std::collections::BTreeSet<usize> =
                (0..90).filter(|&i| m.j(i, j) != 0).collect();
            assert_eq!(touched, expect, "flip {t} at spin {j}");
        }
        assert_eq!(u, bp.init_fields(&s), "fields must still track exactly");
    }

    /// The range-restricted update is the full update, tiled: for any
    /// partition of `0..n` into ranges, applying the range variant per
    /// slice produces the same fields as the global update, and the
    /// range-local touched reports union to the global touched set.
    #[test]
    fn incr_update_range_tiles_the_full_update() {
        let m = random_model(150, 15, 21);
        let bp = BitPlanes::encode(&m, None);
        let rng = StatelessRng::new(22);
        let mut s = SpinVec::random(150, &rng);
        // Uneven cuts that exercise word-boundary masking (64, interior
        // of a word, exact word edge).
        let cuts = [0usize, 37, 64, 65, 128, 150];
        let mut u_full = bp.init_fields(&s);
        let mut u_tiled = u_full.clone();
        for t in 0..60u64 {
            let j = rng.below(23, t, salt::SITE, 150) as usize;
            let s_old = s.flip(j);
            let mut want_touched = std::collections::BTreeSet::new();
            bp.incr_update_touched(&mut u_full, j, s_old, |i| {
                want_touched.insert(i);
            });
            let mut got_touched = std::collections::BTreeSet::new();
            for w in cuts.windows(2) {
                let (lo, hi) = (w[0], w[1]);
                bp.incr_update_range_touched(&mut u_tiled[lo..hi], lo..hi, j, s_old, |i| {
                    got_touched.insert(lo + i);
                });
            }
            assert_eq!(got_touched, want_touched, "flip {t} at spin {j}");
            assert_eq!(u_tiled, u_full, "flip {t} at spin {j}");
        }
        // Empty range is a no-op.
        bp.incr_update_range_touched(&mut [], 10..10, 0, 1, |_| panic!("no-op touched"));
    }

    #[test]
    fn storage_grows_linearly_in_planes() {
        let m = random_model(64, 1, 8);
        let b2 = BitPlanes::encode(&m, Some(2)).storage_bytes();
        let b8 = BitPlanes::encode(&m, Some(8)).storage_bytes();
        assert_eq!(b8, 4 * b2);
    }
}
