//! Run-configuration files: a strict INI/TOML-subset parser so jobs and
//! benchmark campaigns are declarative (`snowball solve --config run.toml`
//! style), without external dependencies.
//!
//! Supported syntax: `[section]` headers, `key = value` pairs, `#`/`;`
//! comments, quoted strings, integers, floats, booleans.

use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// A parsed configuration: `section → key → value`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

/// A configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    fn parse(raw: &str) -> Value {
        let t = raw.trim();
        if let Some(stripped) = t.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
            return Value::Str(stripped.to_string());
        }
        match t {
            "true" => return Value::Bool(true),
            "false" => return Value::Bool(false),
            _ => {}
        }
        if let Ok(i) = t.parse::<i64>() {
            return Value::Int(i);
        }
        if let Ok(f) = t.parse::<f64>() {
            return Value::Float(f);
        }
        Value::Str(t.to_string())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl Config {
    /// Parse configuration text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), Value::parse(v));
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Typed getters with defaults.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Section names.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    /// Service / dispatch-tier settings from a `[serve]` section.
    /// Every key is optional; defaults match the CLI flag defaults
    /// (`snowball serve` with no arguments).
    pub fn serve(&self) -> ServeConfig {
        let reg_cap = crate::coordinator::registry::DEFAULT_CAPACITY_BYTES;
        let model_max = crate::coordinator::registry::DEFAULT_MAX_MODEL_BYTES;
        ServeConfig {
            addr: self.str_or("serve", "addr", "127.0.0.1:7878"),
            workers: self.i64_or("serve", "workers", 0) as usize,
            dispatch_workers: self.i64_or("serve", "dispatch_workers", 1) as usize,
            max_inflight_replicas: self.i64_or("serve", "max_inflight_replicas", 0) as usize,
            reject_saturated: self.bool_or("serve", "reject_saturated", false),
            shutdown_grace_ms: self.i64_or("serve", "shutdown_grace_ms", 0) as u64,
            registry_capacity_bytes: self
                .i64_or("serve", "registry_capacity_bytes", reg_cap as i64)
                as usize,
            max_model_bytes: self.i64_or("serve", "max_model_bytes", model_max as i64) as usize,
        }
    }

    /// Build a JobSpec skeleton from a `[job]` section (instance name,
    /// mode, selector, schedule, steps, replicas, seed, target).
    pub fn job(&self, seed_default: u64) -> Result<JobConfig> {
        Ok(JobConfig {
            instance: self.str_or("job", "instance", "G11"),
            mode: crate::engine::Mode::parse(&self.str_or("job", "mode", "rwa"))?,
            selector: crate::engine::SelectorKind::parse(&self.str_or(
                "job",
                "selector",
                "fenwick",
            ))?,
            schedule: crate::engine::Schedule::parse(&self.str_or(
                "job",
                "schedule",
                "geometric:8:0.05",
            ))?,
            steps: self.i64_or("job", "steps", 100_000) as u64,
            replicas: self.i64_or("job", "replicas", 8) as u32,
            seed: self.i64_or("job", "seed", seed_default as i64) as u64,
            target: self.get("job", "target").and_then(|v| v.as_i64()),
            shards: self.i64_or("job", "shards", 1) as u32,
            pin_lanes: self.bool_or("job", "pin_lanes", false),
            local_rows: self.bool_or("job", "local_rows", false),
            portfolio: self.get("job", "portfolio").and_then(|v| v.as_str()).map(str::to_string),
        })
    }
}

/// Declarative job description (the `[job]` section).
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub instance: String,
    pub mode: crate::engine::Mode,
    pub selector: crate::engine::SelectorKind,
    pub schedule: crate::engine::Schedule,
    pub steps: u64,
    pub replicas: u32,
    pub seed: u64,
    pub target: Option<i64>,
    /// Shard lanes per replica (`1` = classic engine, `0` = auto,
    /// `>1` = async sharded lanes — see `crate::engine::shard`).
    pub shards: u32,
    /// Pin shard lane threads to cores (`pin_lanes = true`; Linux).
    pub pin_lanes: bool,
    /// Materialize NUMA-local per-lane coupling rows
    /// (`local_rows = true`; pair with `pin_lanes`).
    pub local_rows: bool,
    /// Portfolio roster (`portfolio = "auto"`, `"full"`, or a
    /// comma-separated contender list — see `crate::portfolio`).
    /// `None` runs the single configured engine as usual.
    pub portfolio: Option<String>,
}

/// Declarative service description (the `[serve]` section).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (`host:port`).
    pub addr: String,
    /// Compute threads per coordinator worker (0 = one per CPU).
    pub workers: usize,
    /// Coordinator workers behind the routing front-end: `1` (the
    /// default) serves a single coordinator, `>= 2` starts the
    /// dispatch tier (`crate::coordinator::Router`).
    pub dispatch_workers: usize,
    /// Per-worker in-flight replica cap (0 = unbounded).
    pub max_inflight_replicas: usize,
    /// Refuse `SOLVE` while saturated instead of queueing.
    pub reject_saturated: bool,
    /// Shutdown grace before in-flight jobs are preempted (0 = drain).
    pub shutdown_grace_ms: u64,
    /// Registry byte capacity before LRU eviction.
    pub registry_capacity_bytes: usize,
    /// Per-model `PUT` size limit in bytes.
    pub max_model_bytes: usize,
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' | ';' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# campaign config
[job]
instance = "K2000"
mode = "rwa"
steps = 2000000
replicas = 16
target = -65000
schedule = "geometric:10:0.05"

[service]
addr = "127.0.0.1:7878"   # bind here
verbose = true
tolerance = 0.25
"#;

    #[test]
    fn parse_types_and_sections() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("job", "instance", ""), "K2000");
        assert_eq!(c.i64_or("job", "steps", 0), 2_000_000);
        assert_eq!(c.f64_or("service", "tolerance", 0.0), 0.25);
        assert!(c.bool_or("service", "verbose", false));
        assert_eq!(c.str_or("service", "addr", ""), "127.0.0.1:7878");
        assert_eq!(c.sections().count(), 2);
    }

    #[test]
    fn job_section_builds() {
        let c = Config::parse(SAMPLE).unwrap();
        let j = c.job(1).unwrap();
        assert_eq!(j.instance, "K2000");
        assert_eq!(j.replicas, 16);
        assert_eq!(j.target, Some(-65000));
        assert_eq!(j.shards, 1, "sharding defaults off");
        assert!(!j.pin_lanes, "pinning defaults off");
        assert!(j.portfolio.is_none(), "portfolio defaults off");
        let cp = Config::parse("[job]\nportfolio = \"rsa,neal,tabu\"\n").unwrap();
        assert_eq!(cp.job(1).unwrap().portfolio.as_deref(), Some("rsa,neal,tabu"));
        let cs = Config::parse("[job]\nshards = 8\npin_lanes = true\n").unwrap();
        assert_eq!(cs.job(1).unwrap().shards, 8);
        assert!(cs.job(1).unwrap().pin_lanes);
        assert!(!j.local_rows, "local rows default off");
        let cl = Config::parse("[job]\nshards = 8\npin_lanes = true\nlocal_rows = true\n").unwrap();
        assert!(cl.job(1).unwrap().local_rows);
        assert!(matches!(j.mode, crate::engine::Mode::RouletteWheel));
        // Defaults to the Fenwick selection path; `selector = "scan"`
        // switches to the legacy prefix scan.
        assert!(matches!(j.selector, crate::engine::SelectorKind::Fenwick));
        let c2 = Config::parse("[job]\nselector = \"scan\"\n").unwrap();
        assert!(matches!(c2.job(1).unwrap().selector, crate::engine::SelectorKind::LinearScan));
    }

    #[test]
    fn serve_section_builds_with_defaults_and_overrides() {
        let defaults = Config::parse("").unwrap().serve();
        assert_eq!(defaults.addr, "127.0.0.1:7878");
        assert_eq!(defaults.dispatch_workers, 1, "single coordinator by default");
        assert_eq!(
            defaults.registry_capacity_bytes,
            crate::coordinator::registry::DEFAULT_CAPACITY_BYTES
        );
        assert_eq!(
            defaults.max_model_bytes,
            crate::coordinator::registry::DEFAULT_MAX_MODEL_BYTES
        );
        let c = Config::parse(
            "[serve]\naddr = \"0.0.0.0:9000\"\ndispatch_workers = 4\nworkers = 2\n\
             max_inflight_replicas = 64\nreject_saturated = true\nshutdown_grace_ms = 500\n\
             registry_capacity_bytes = 1048576\nmax_model_bytes = 65536\n",
        )
        .unwrap()
        .serve();
        assert_eq!(c.addr, "0.0.0.0:9000");
        assert_eq!((c.dispatch_workers, c.workers), (4, 2));
        assert_eq!(c.max_inflight_replicas, 64);
        assert!(c.reject_saturated);
        assert_eq!(c.shutdown_grace_ms, 500);
        assert_eq!((c.registry_capacity_bytes, c.max_model_bytes), (1 << 20, 64 << 10));
    }

    #[test]
    fn comments_and_defaults() {
        let c = Config::parse("[a]\nx = 1 # trailing\ny = \"a # not comment\"\n").unwrap();
        assert_eq!(c.i64_or("a", "x", 0), 1);
        assert_eq!(c.str_or("a", "y", ""), "a # not comment");
        assert_eq!(c.i64_or("a", "missing", 7), 7);
    }

    #[test]
    fn bad_lines_error() {
        assert!(Config::parse("[a]\nnot a pair\n").is_err());
    }
}
