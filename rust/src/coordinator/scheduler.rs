//! Replica scheduler: turns a job into independently schedulable
//! replica work items on the shared [`ReplicaPool`] (rayon workers; the
//! service layer uses one thread per connection and this pool for
//! compute).
//!
//! Replicas are embarrassingly parallel: each gets a decorrelated child
//! seed from the job seed (stateless RNG `child`, paper §IV-B3d) so the
//! result set is identical regardless of worker count or interleaving —
//! asserted by `deterministic_across_worker_counts` and by the
//! cross-job tests in `rust/tests/pool_determinism.rs`.
//!
//! Two execution shapes share one per-replica body ([`run_replica`]):
//!
//! * [`ReplicaScheduler::run_native`] — blocking fan-out of one job
//!   (`ReplicaPool::run_indexed`); the serial dispatcher and direct
//!   callers (benches, TTS harness) use this.
//! * [`ReplicaScheduler::spawn_native`] — every replica becomes one
//!   fire-and-forget pool item and the call returns immediately; a
//!   shared collector assembles results **by replica index** and the
//!   last replica to finish invokes the completion callback. This is
//!   what lets the coordinator overlap many jobs on one pool: replicas
//!   of job B start the moment a worker frees up, even while job A is
//!   still running (see `docs/ARCHITECTURE.md`).

use super::job::{JobSpec, ReplicaResult};
use crate::engine::pool::ReplicaPool;
use crate::engine::{Datapath, EngineConfig, SnowballEngine};
use crate::rng::StatelessRng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Run one replica of `spec`: the per-replica body shared by the
/// blocking and the overlapping path, so the two are bit-identical by
/// construction (same `EngineConfig`, same `child(r)` seed derivation).
pub fn run_replica(spec: &JobSpec, r: usize) -> ReplicaResult {
    let root = StatelessRng::new(spec.seed);
    let cfg = EngineConfig {
        mode: spec.mode,
        datapath: Datapath::Dense,
        selector: spec.selector,
        schedule: spec.schedule.clone(),
        steps: spec.steps,
        seed: root.child(r as u64).seed(),
        planes: None,
        trace_stride: 0,
    };
    let mut engine = SnowballEngine::new(&spec.model, cfg);
    let run = engine.run();
    ReplicaResult {
        replica: r as u32,
        best_energy: run.best_energy,
        flips: run.flips,
        wall: run.wall,
    }
}

/// Collects replica results by index; the closing replica hands the
/// completed, index-ordered vector to the job's completion callback.
struct Collector {
    slots: Mutex<Vec<Option<ReplicaResult>>>,
    remaining: AtomicUsize,
    on_done: Mutex<Option<Box<dyn FnOnce(Vec<ReplicaResult>) + Send>>>,
}

/// Replica scheduler over the shared worker pool.
pub struct ReplicaScheduler {
    pool: ReplicaPool,
}

impl ReplicaScheduler {
    /// `workers = 0` → one per available CPU.
    pub fn new(workers: usize) -> Self {
        Self { pool: ReplicaPool::new(workers) }
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The underlying pool (for callers that batch other fan-out work —
    /// e.g. tempering bursts — onto the same threads).
    pub fn pool(&self) -> &ReplicaPool {
        &self.pool
    }

    /// Run all replicas of `spec` on the native engine, returning results
    /// ordered by replica index. Blocks until the whole job is done.
    pub fn run_native(&self, spec: &JobSpec) -> Vec<ReplicaResult> {
        self.pool.run_indexed(spec.replicas as usize, |r| run_replica(spec, r))
    }

    /// Enqueue every replica of `spec` as its own pool work item and
    /// return immediately; `on_done` runs (on the pool thread that
    /// finishes last) with the results in replica-index order —
    /// bit-identical to [`run_native`](Self::run_native) because both
    /// share [`run_replica`]. `on_replica_done` fires after each replica
    /// completes (occupancy accounting).
    pub fn spawn_native<F, G>(&self, spec: Arc<JobSpec>, on_replica_done: G, on_done: F)
    where
        F: FnOnce(Vec<ReplicaResult>) + Send + 'static,
        G: Fn() + Send + Sync + 'static,
    {
        let n = spec.replicas as usize;
        if n == 0 {
            on_done(Vec::new());
            return;
        }
        let collector = Arc::new(Collector {
            slots: Mutex::new(vec![None; n]),
            remaining: AtomicUsize::new(n),
            on_done: Mutex::new(Some(Box::new(on_done))),
        });
        let on_replica_done = Arc::new(on_replica_done);
        for r in 0..n {
            let spec = spec.clone();
            let collector = collector.clone();
            let on_replica_done = on_replica_done.clone();
            self.pool.spawn(move || {
                let result = run_replica(&spec, r);
                collector.slots.lock().unwrap()[r] = Some(result);
                on_replica_done();
                // AcqRel: the closing thread must see every slot write.
                if collector.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    let slots = std::mem::take(&mut *collector.slots.lock().unwrap());
                    let done =
                        collector.on_done.lock().unwrap().take().expect("on_done fires once");
                    done(slots.into_iter().map(|s| s.expect("all slots filled")).collect());
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Backend;
    use crate::engine::{Mode, Schedule, SelectorKind};
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use std::sync::Arc;

    fn spec(replicas: u32) -> JobSpec {
        let rng = StatelessRng::new(55);
        let p = MaxCut::new(generators::erdos_renyi(40, 150, &[-1, 1], &rng));
        JobSpec {
            model: Arc::new(p.model().clone()),
            label: "test".into(),
            mode: Mode::RouletteWheel,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.05 },
            steps: 800,
            replicas,
            seed: 42,
            target_energy: None,
            backend: Backend::Native,
        }
    }

    #[test]
    fn all_replicas_run_exactly_once() {
        let s = ReplicaScheduler::new(3);
        let out = s.run_native(&spec(10));
        let ids: Vec<u32> = out.iter().map(|r| r.replica).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let a: Vec<i64> =
            ReplicaScheduler::new(1).run_native(&spec(8)).iter().map(|r| r.best_energy).collect();
        let b: Vec<i64> =
            ReplicaScheduler::new(7).run_native(&spec(8)).iter().map(|r| r.best_energy).collect();
        assert_eq!(a, b, "replica results must not depend on scheduling");
    }

    #[test]
    fn replicas_are_decorrelated() {
        let out = ReplicaScheduler::new(4).run_native(&spec(6));
        // Not all best energies identical (distinct seeds explore
        // differently on a frustrated instance with this few steps).
        let first = out[0].best_energy;
        assert!(out.iter().any(|r| r.best_energy != first || r.flips != out[0].flips));
    }

    /// The overlapping path must produce the exact result vector of the
    /// blocking path — same order, same energies, same flip counts.
    #[test]
    fn spawn_native_matches_run_native() {
        let s = ReplicaScheduler::new(4);
        let spec = Arc::new(spec(9));
        let blocking = s.run_native(&spec);
        let (tx, rx) = std::sync::mpsc::channel();
        let ticks = Arc::new(AtomicUsize::new(0));
        let t = ticks.clone();
        s.spawn_native(
            spec.clone(),
            move || {
                t.fetch_add(1, Ordering::Relaxed);
            },
            move |results| {
                let _ = tx.send(results);
            },
        );
        let spawned = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(ticks.load(Ordering::Relaxed), 9, "one tick per replica");
        let key = |v: &[ReplicaResult]| -> Vec<(u32, i64, u64)> {
            v.iter().map(|r| (r.replica, r.best_energy, r.flips)).collect()
        };
        assert_eq!(key(&blocking), key(&spawned));
    }

    /// Several jobs spawned back-to-back interleave on the pool but
    /// still each assemble their own, correctly ordered result set.
    #[test]
    fn overlapping_jobs_stay_isolated() {
        let s = ReplicaScheduler::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        for k in 0..5u64 {
            let mut sp = spec(4);
            sp.seed = 100 + k;
            sp.label = format!("job-{k}");
            let tx = tx.clone();
            s.spawn_native(Arc::new(sp), || {}, move |results| {
                let _ = tx.send((k, results));
            });
        }
        drop(tx);
        let serial = ReplicaScheduler::new(1);
        for (k, results) in rx.iter() {
            let mut want = spec(4);
            want.seed = 100 + k;
            let want = serial.run_native(&want);
            let key = |v: &[ReplicaResult]| -> Vec<(u32, i64, u64)> {
                v.iter().map(|r| (r.replica, r.best_energy, r.flips)).collect()
            };
            assert_eq!(key(&results), key(&want), "job {k} diverged under overlap");
        }
    }
}
