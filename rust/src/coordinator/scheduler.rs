//! Replica scheduler: fans a job's independent replicas out over the
//! shared [`ReplicaPool`] (rayon workers; the service layer uses one
//! thread per connection and this pool for compute).
//!
//! Replicas are embarrassingly parallel: each gets a decorrelated child
//! seed from the job seed (stateless RNG `child`, paper §IV-B3d) so the
//! result set is identical regardless of worker count or interleaving —
//! asserted by `deterministic_across_worker_counts`.

use super::job::{JobSpec, ReplicaResult};
use crate::engine::pool::ReplicaPool;
use crate::engine::{Datapath, EngineConfig, SnowballEngine};
use crate::rng::StatelessRng;

/// Replica scheduler over the shared worker pool.
pub struct ReplicaScheduler {
    pool: ReplicaPool,
}

impl ReplicaScheduler {
    /// `workers = 0` → one per available CPU.
    pub fn new(workers: usize) -> Self {
        Self { pool: ReplicaPool::new(workers) }
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The underlying pool (for callers that batch other fan-out work —
    /// e.g. tempering bursts — onto the same threads).
    pub fn pool(&self) -> &ReplicaPool {
        &self.pool
    }

    /// Run all replicas of `spec` on the native engine, returning results
    /// ordered by replica index.
    pub fn run_native(&self, spec: &JobSpec) -> Vec<ReplicaResult> {
        let root = StatelessRng::new(spec.seed);
        self.pool.run_indexed(spec.replicas as usize, |r| {
            let cfg = EngineConfig {
                mode: spec.mode,
                datapath: Datapath::Dense,
                selector: spec.selector,
                schedule: spec.schedule.clone(),
                steps: spec.steps,
                seed: root.child(r as u64).seed(),
                planes: None,
                trace_stride: 0,
            };
            let mut engine = SnowballEngine::new(&spec.model, cfg);
            let run = engine.run();
            ReplicaResult {
                replica: r as u32,
                best_energy: run.best_energy,
                flips: run.flips,
                wall: run.wall,
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Backend;
    use crate::engine::{Mode, Schedule, SelectorKind};
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use std::sync::Arc;

    fn spec(replicas: u32) -> JobSpec {
        let rng = StatelessRng::new(55);
        let p = MaxCut::new(generators::erdos_renyi(40, 150, &[-1, 1], &rng));
        JobSpec {
            model: Arc::new(p.model().clone()),
            label: "test".into(),
            mode: Mode::RouletteWheel,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.05 },
            steps: 800,
            replicas,
            seed: 42,
            target_energy: None,
            backend: Backend::Native,
        }
    }

    #[test]
    fn all_replicas_run_exactly_once() {
        let s = ReplicaScheduler::new(3);
        let out = s.run_native(&spec(10));
        let ids: Vec<u32> = out.iter().map(|r| r.replica).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let a: Vec<i64> =
            ReplicaScheduler::new(1).run_native(&spec(8)).iter().map(|r| r.best_energy).collect();
        let b: Vec<i64> =
            ReplicaScheduler::new(7).run_native(&spec(8)).iter().map(|r| r.best_energy).collect();
        assert_eq!(a, b, "replica results must not depend on scheduling");
    }

    #[test]
    fn replicas_are_decorrelated() {
        let out = ReplicaScheduler::new(4).run_native(&spec(6));
        // Not all best energies identical (distinct seeds explore
        // differently on a frustrated instance with this few steps).
        let first = out[0].best_energy;
        assert!(out.iter().any(|r| r.best_energy != first || r.flips != out[0].flips));
    }
}
