//! Replica scheduler: turns a job into independently schedulable
//! replica work items on the shared [`ReplicaPool`] (rayon workers; the
//! service layer uses one thread per connection and this pool for
//! compute).
//!
//! Replicas are embarrassingly parallel: each gets a decorrelated child
//! seed from the job seed (stateless RNG `child`, paper §IV-B3d) so the
//! result set is identical regardless of worker count or interleaving —
//! asserted by `deterministic_across_worker_counts` and by the
//! cross-job tests in `rust/tests/pool_determinism.rs`.
//!
//! Two execution shapes share one per-replica body ([`run_replica`]):
//!
//! * [`ReplicaScheduler::try_run_native`] — blocking fan-out of one job
//!   (`ReplicaPool::run_indexed`); the serial dispatcher and direct
//!   callers (benches, TTS harness) use this (or the panicking
//!   [`ReplicaScheduler::run_native`] convenience wrapper).
//! * [`ReplicaScheduler::spawn_native`] — every replica becomes one
//!   fire-and-forget pool item and the call returns immediately; a
//!   shared collector assembles results **by replica index** and the
//!   last replica to finish invokes the completion callback. This is
//!   what lets the coordinator overlap many jobs on one pool: replicas
//!   of job B start the moment a worker frees up, even while job A is
//!   still running (see `docs/ARCHITECTURE.md`).
//!
//! Replica panics (poisoned instances, absurd sizes, injected faults)
//! are caught at the work-item boundary — a panicking replica fails
//! its **job** (the coordinator flips it to `JobState::Failed` and
//! wakes waiters), never the dispatcher, the pool, or the process.
//! With `JobSpec.max_retries > 0` the panic boundary first **retries**
//! the replica (exponential backoff, resuming from its last journaled
//! [`EngineCheckpoint`](crate::engine::EngineCheckpoint) — see
//! [`super::journal`]); only when the retry budget is exhausted does
//! the job fail. Every replica body also polls the job's
//! [`StopToken`](crate::stop::StopToken), so cancel / deadline /
//! shutdown preempt mid-run and the replica returns its best-so-far
//! incumbent.
//!
//! Each replica's *engine* is chosen per job: `spec.shards <= 1` runs
//! the classic single-lane [`SnowballEngine`] (bit-reproducible);
//! `spec.shards > 1` runs the asynchronous sharded engine
//! ([`crate::engine::ShardedEngine`]) with that many lanes;
//! `spec.shards == 0` lets [`shard::plan_parallelism`] choose shard- vs
//! replica-level parallelism from the instance size and machine width.

use super::job::{JobSpec, ReplicaResult};
use super::journal::JobCtl;
use crate::engine::pool::ReplicaPool;
use crate::engine::{shard, Datapath, EngineConfig, MergeMode, ShardedEngine, SnowballEngine};
use crate::rng::StatelessRng;
use crate::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Lanes `spec` resolves to under a `worker_budget`-thread compute
/// budget: the explicit count, or the [`shard::plan_parallelism`]
/// choice for `shards == 0` (auto). The budget is the scheduler's
/// configured pool width — NOT the raw machine width — so an operator's
/// `--workers` cap bounds the auto-sharding thread footprint too.
pub fn effective_shards(spec: &JobSpec, worker_budget: usize) -> usize {
    match spec.shards {
        0 => {
            shard::plan_parallelism(
                spec.model.len(),
                spec.replicas.max(1) as usize,
                worker_budget,
            )
            .shards
        }
        s => s as usize,
    }
}

/// Run one replica of `spec` under a `worker_budget`-thread compute
/// budget: the per-replica body shared by the blocking and the
/// overlapping path, so the two are bit-identical by construction
/// (same `EngineConfig`, same `child(r)` seed derivation).
pub fn run_replica(spec: &JobSpec, r: usize, worker_budget: usize) -> ReplicaResult {
    run_replica_ctl(spec, r, worker_budget, &JobCtl::unmanaged())
}

/// How often a retryable single-lane replica journals a checkpoint: 8
/// per run, clamped so tiny jobs still checkpoint and huge jobs don't
/// snapshot megabyte spin vectors every few milliseconds.
fn checkpoint_stride(steps: u64) -> u64 {
    (steps / 8).clamp(1_000, 250_000)
}

/// [`run_replica`] under a [`JobCtl`]: honors the job's stop token
/// (single-lane via `run_session`, sharded via `run_with_stop`), and —
/// when the job allows retries — journals periodic checkpoints and
/// resumes from the latest one a previous attempt recorded. Resumed
/// runs are bit-identical to uninterrupted ones (stateless RNG +
/// pure schedule; pinned by the engine's resume test and the chaos
/// suite). Sharded replicas don't checkpoint (their interleaving is
/// real nondeterminism) — a retried sharded replica restarts from
/// step 0.
pub fn run_replica_ctl(
    spec: &JobSpec,
    r: usize,
    worker_budget: usize,
    ctl: &JobCtl,
) -> ReplicaResult {
    crate::failpoint::hit("pool.run");
    let root = StatelessRng::new(spec.seed);
    let shards = effective_shards(spec, worker_budget);
    let cfg = EngineConfig {
        mode: spec.mode,
        datapath: Datapath::Dense,
        selector: spec.selector,
        schedule: spec.schedule.clone(),
        steps: spec.steps,
        seed: root.child(r as u64).seed(),
        planes: None,
        trace_stride: 0,
        shards,
        pin_lanes: spec.pin_lanes,
        local_rows: spec.local_rows,
    };
    let (run, pinned_lanes, local_row_bytes) = if shards > 1 {
        let (run, stats) =
            ShardedEngine::new(&spec.model, cfg, MergeMode::Async).run_with_stop(&ctl.stop);
        (run, stats.pinned_lanes, stats.local_row_bytes)
    } else {
        // Retryable jobs journal for their own resume; router-managed
        // jobs (ctl.checkpoint) journal so a re-dispatch to another
        // worker resumes instead of restarting.
        let stride = if ctl.max_retries > 0 || ctl.checkpoint {
            checkpoint_stride(spec.steps)
        } else {
            0
        };
        let resume = ctl.journal.checkpoint(r as u32);
        let mut engine = match &resume {
            Some(ck) => SnowballEngine::from_checkpoint(&spec.model, cfg, ck),
            None => SnowballEngine::new(&spec.model, cfg),
        };
        let journal = ctl.journal.clone();
        let run = engine.run_session(&ctl.stop, resume.as_ref(), stride, |ck| {
            journal.record(r as u32, ck.clone());
        });
        (run, 0, 0)
    };
    ReplicaResult {
        replica: r as u32,
        best_energy: run.best_energy,
        flips: run.flips,
        wall: run.wall,
        stopped: run.stopped.is_some(),
        pinned_lanes,
        local_row_bytes,
    }
}

/// [`run_replica_ctl`] with the panic boundary AND the retry loop: a
/// panicking replica is re-run up to `ctl.max_retries` times with
/// exponential backoff (5 ms doubling, capped at 100 ms), resuming
/// from its journaled checkpoint; only when the budget is exhausted —
/// or the job was preempted anyway — does it become an `Err`
/// describing the first panic (rayon would escalate an uncaught panic
/// in a spawned item to a process abort).
fn run_replica_caught(
    spec: &JobSpec,
    r: usize,
    worker_budget: usize,
    ctl: &JobCtl,
) -> Result<ReplicaResult, String> {
    let mut attempt = 0u32;
    loop {
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_replica_ctl(spec, r, worker_budget, ctl)
        }));
        match caught {
            Ok(result) => return Ok(result),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".into());
                // A preempted job never retries: the point of the stop
                // was to give the machine back.
                if attempt >= ctl.max_retries || ctl.stop.is_stopped() {
                    return Err(format!("replica {r} panicked: {msg}"));
                }
                attempt += 1;
                ctl.journal.note_retry();
                let backoff = 5u64 << (attempt - 1).min(5);
                std::thread::sleep(std::time::Duration::from_millis(backoff.min(100)));
            }
        }
    }
}

/// Collects replica results by index; the closing replica hands the
/// completed, index-ordered vector (or the first failure) to the job's
/// completion callback.
struct Collector {
    slots: Mutex<Vec<Option<Result<ReplicaResult, String>>>>,
    remaining: AtomicUsize,
    #[allow(clippy::type_complexity)]
    on_done: Mutex<Option<Box<dyn FnOnce(Result<Vec<ReplicaResult>, String>) + Send>>>,
}

/// Replica scheduler over the shared worker pool.
pub struct ReplicaScheduler {
    pool: ReplicaPool,
}

impl ReplicaScheduler {
    /// `workers = 0` → one per available CPU.
    pub fn new(workers: usize) -> Self {
        Self { pool: ReplicaPool::new(workers) }
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The underlying pool (for callers that batch other fan-out work —
    /// e.g. tempering bursts — onto the same threads).
    pub fn pool(&self) -> &ReplicaPool {
        &self.pool
    }

    /// Run all replicas of `spec` on the native engine, returning
    /// results ordered by replica index, or the first replica failure.
    /// Blocks until the whole job is done.
    pub fn try_run_native(&self, spec: &JobSpec) -> Result<Vec<ReplicaResult>, String> {
        self.try_run_native_ctl(spec, &JobCtl::unmanaged())
    }

    /// [`Self::try_run_native`] under a job control block: replicas
    /// honor `ctl.stop` (the serial dispatcher's cancel/deadline path)
    /// and panics retry per `ctl.max_retries`.
    pub fn try_run_native_ctl(
        &self,
        spec: &JobSpec,
        ctl: &JobCtl,
    ) -> Result<Vec<ReplicaResult>, String> {
        if spec.portfolio.is_some() {
            return crate::portfolio::run_for_job(spec, &ctl.stop);
        }
        let budget = self.workers();
        self.pool
            .run_indexed(spec.replicas as usize, |r| run_replica_caught(spec, r, budget, ctl))
            .into_iter()
            .collect()
    }

    /// [`Self::try_run_native`] for callers that treat a replica panic
    /// as fatal (tests, benches, the TTS harness).
    pub fn run_native(&self, spec: &JobSpec) -> Vec<ReplicaResult> {
        self.try_run_native(spec).expect("replica failed")
    }

    /// Enqueue every replica of `spec` as its own pool work item and
    /// return immediately; `on_done` runs (on the pool thread that
    /// finishes last) with the results in replica-index order — or the
    /// first replica failure — bit-identical to
    /// [`try_run_native`](Self::try_run_native) because both share
    /// [`run_replica_ctl`]. `on_replica_done` fires after each replica
    /// completes (occupancy accounting). `ctl` carries the job's stop
    /// token, checkpoint journal and retry budget.
    pub fn spawn_native<F, G>(&self, spec: Arc<JobSpec>, ctl: JobCtl, on_replica_done: G, on_done: F)
    where
        F: FnOnce(Result<Vec<ReplicaResult>, String>) + Send + 'static,
        G: Fn() + Send + Sync + 'static,
    {
        if spec.portfolio.is_some() {
            // A portfolio race spawns and joins its own contender
            // threads (std::thread::scope inside `run_for_job`). Running
            // it as ONE pool work item keeps the pool deadlock-free: if
            // each contender were its own pool item, a race could occupy
            // every worker and then wait on contenders that can never be
            // scheduled.
            self.pool.spawn(move || {
                let out = crate::portfolio::run_for_job(&spec, &ctl.stop);
                on_replica_done();
                on_done(out);
            });
            return;
        }
        let n = spec.replicas as usize;
        if n == 0 {
            on_done(Ok(Vec::new()));
            return;
        }
        let collector = Arc::new(Collector {
            slots: Mutex::new(vec![None; n]),
            remaining: AtomicUsize::new(n),
            on_done: Mutex::new(Some(Box::new(on_done))),
        });
        let on_replica_done = Arc::new(on_replica_done);
        let budget = self.workers();
        for r in 0..n {
            let spec = spec.clone();
            let ctl = ctl.clone();
            let collector = collector.clone();
            let on_replica_done = on_replica_done.clone();
            self.pool.spawn(move || {
                let result = run_replica_caught(&spec, r, budget, &ctl);
                collector.slots.lock().unwrap()[r] = Some(result);
                on_replica_done();
                // AcqRel: the closing thread must see every slot write.
                if collector.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    let slots = std::mem::take(&mut *collector.slots.lock().unwrap());
                    let done =
                        collector.on_done.lock().unwrap().take().expect("on_done fires once");
                    done(slots
                        .into_iter()
                        .map(|s| s.expect("all slots filled"))
                        .collect::<Result<Vec<_>, String>>());
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Backend;
    use crate::engine::{Mode, Schedule, SelectorKind};
    use crate::graph::generators;
    use crate::ising::IsingModel;
    use crate::problems::MaxCut;
    use std::sync::Arc;

    fn spec(replicas: u32) -> JobSpec {
        let rng = StatelessRng::new(55);
        let p = MaxCut::new(generators::erdos_renyi(40, 150, &[-1, 1], &rng));
        JobSpec {
            model: Arc::new(p.model().clone()),
            label: "test".into(),
            mode: Mode::RouletteWheel,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.05 },
            steps: 800,
            replicas,
            seed: 42,
            target_energy: None,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
            budget_ms: 0,
            max_retries: 0,
            backend: Backend::Native,
            portfolio: None,
        }
    }

    #[test]
    fn all_replicas_run_exactly_once() {
        let s = ReplicaScheduler::new(3);
        let out = s.run_native(&spec(10));
        let ids: Vec<u32> = out.iter().map(|r| r.replica).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let a: Vec<i64> =
            ReplicaScheduler::new(1).run_native(&spec(8)).iter().map(|r| r.best_energy).collect();
        let b: Vec<i64> =
            ReplicaScheduler::new(7).run_native(&spec(8)).iter().map(|r| r.best_energy).collect();
        assert_eq!(a, b, "replica results must not depend on scheduling");
    }

    #[test]
    fn replicas_are_decorrelated() {
        let out = ReplicaScheduler::new(4).run_native(&spec(6));
        // Not all best energies identical (distinct seeds explore
        // differently on a frustrated instance with this few steps).
        let first = out[0].best_energy;
        assert!(out.iter().any(|r| r.best_energy != first || r.flips != out[0].flips));
    }

    /// A job over a poisoned instance (no spins, nonzero steps) must
    /// come back as an `Err` naming the replica — not panic the caller,
    /// not abort the process.
    #[test]
    fn replica_panic_is_caught_as_job_failure() {
        let mut bad = spec(3);
        bad.model = Arc::new(IsingModel::zeros(0));
        let s = ReplicaScheduler::new(2);
        let err = s.try_run_native(&bad).expect_err("empty model must fail");
        assert!(err.contains("panicked"), "unexpected error text: {err}");
        // The scheduler must stay usable afterwards.
        assert_eq!(s.try_run_native(&spec(2)).unwrap().len(), 2);
    }

    /// Sharded replicas (shards > 1) go through the async sharded
    /// engine and still produce one well-formed result per replica.
    #[test]
    fn sharded_replicas_produce_results() {
        let mut sp = spec(3);
        sp.shards = 4;
        sp.steps = 2_000;
        let out = ReplicaScheduler::new(2).run_native(&sp);
        assert_eq!(out.len(), 3);
        for (r, result) in out.iter().enumerate() {
            assert_eq!(result.replica, r as u32);
            assert!(result.flips > 0, "replica {r} made no progress");
        }
    }

    /// `shards == 0` resolves through the size policy: tiny instances
    /// stay single-lane.
    #[test]
    fn auto_shards_stays_single_lane_on_small_instances() {
        let mut sp = spec(2);
        sp.shards = 0;
        assert_eq!(effective_shards(&sp, 64), 1, "40-spin instance must not shard");
        // And the worker budget bounds the lane count on big instances.
        let mut big = spec(1);
        big.model = Arc::new(crate::ising::IsingModel::zeros(8192));
        big.shards = 0;
        assert_eq!(effective_shards(&big, 2), 2, "budget of 2 must cap the lanes");
        assert_eq!(effective_shards(&big, 1), 1, "budget of 1 means no sharding");
        let out = ReplicaScheduler::new(2).run_native(&sp);
        // Bit-identical to the explicit single-lane run.
        let want = ReplicaScheduler::new(2).run_native(&spec(2));
        let key = |v: &[ReplicaResult]| -> Vec<(u32, i64, u64)> {
            v.iter().map(|r| (r.replica, r.best_energy, r.flips)).collect()
        };
        assert_eq!(key(&out), key(&want));
    }

    /// The overlapping path must produce the exact result vector of the
    /// blocking path — same order, same energies, same flip counts.
    #[test]
    fn spawn_native_matches_run_native() {
        let s = ReplicaScheduler::new(4);
        let spec = Arc::new(spec(9));
        let blocking = s.run_native(&spec);
        let (tx, rx) = std::sync::mpsc::channel();
        let ticks = Arc::new(AtomicUsize::new(0));
        let t = ticks.clone();
        s.spawn_native(
            spec.clone(),
            JobCtl::unmanaged(),
            move || {
                t.fetch_add(1, Ordering::Relaxed);
            },
            move |results| {
                let _ = tx.send(results);
            },
        );
        let spawned =
            rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap().expect("job succeeds");
        assert_eq!(ticks.load(Ordering::Relaxed), 9, "one tick per replica");
        let key = |v: &[ReplicaResult]| -> Vec<(u32, i64, u64)> {
            v.iter().map(|r| (r.replica, r.best_energy, r.flips)).collect()
        };
        assert_eq!(key(&blocking), key(&spawned));
    }

    /// A pre-tripped stop token preempts every replica promptly; the
    /// job still yields one well-formed (partial) result per replica —
    /// preemption is not a failure.
    #[test]
    fn preempted_job_returns_partial_results() {
        let s = ReplicaScheduler::new(2);
        let mut sp = spec(3);
        sp.steps = 1_000_000_000; // would run for minutes if not stopped
        let ctl = JobCtl::unmanaged();
        ctl.stop.trip(crate::stop::StopCause::Cancel);
        let t0 = std::time::Instant::now();
        let out = s.try_run_native_ctl(&sp, &ctl).expect("preemption is not a failure");
        assert_eq!(out.len(), 3);
        for (r, result) in out.iter().enumerate() {
            assert_eq!(result.replica, r as u32);
        }
        assert!(t0.elapsed() < std::time::Duration::from_secs(10), "preemption must be prompt");
    }

    /// Turning on the checkpoint journal (max_retries > 0) must not
    /// change a healthy job's results — checkpoint capture draws no
    /// randomness and mutates nothing.
    #[test]
    fn checkpointing_does_not_perturb_results() {
        let s = ReplicaScheduler::new(2);
        let mut sp = spec(4);
        sp.steps = 4_000; // > the 1000-step stride floor, so checkpoints fire
        let plain = s.run_native(&sp);
        let mut ctl = JobCtl::unmanaged();
        ctl.max_retries = 2;
        let journaled = s.try_run_native_ctl(&sp, &ctl).unwrap();
        let key = |v: &[ReplicaResult]| -> Vec<(u32, i64, u64)> {
            v.iter().map(|r| (r.replica, r.best_energy, r.flips)).collect()
        };
        assert_eq!(key(&plain), key(&journaled));
        // And the journal actually accumulated checkpoints to resume from.
        assert!(ctl.journal.checkpoint(0).is_some(), "stride must journal checkpoints");
    }

    /// The overlapping path reports failures through the callback too.
    #[test]
    fn spawn_native_reports_panics() {
        let mut bad = spec(2);
        bad.model = Arc::new(IsingModel::zeros(0));
        let s = ReplicaScheduler::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        s.spawn_native(Arc::new(bad), JobCtl::unmanaged(), || {}, move |results| {
            let _ = tx.send(results);
        });
        let got = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(got.is_err(), "empty model must fail the job");
    }

    /// Several jobs spawned back-to-back interleave on the pool but
    /// still each assemble their own, correctly ordered result set.
    #[test]
    fn overlapping_jobs_stay_isolated() {
        let s = ReplicaScheduler::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        for k in 0..5u64 {
            let mut sp = spec(4);
            sp.seed = 100 + k;
            sp.label = format!("job-{k}");
            let tx = tx.clone();
            s.spawn_native(Arc::new(sp), JobCtl::unmanaged(), || {}, move |results| {
                let _ = tx.send((k, results));
            });
        }
        drop(tx);
        let serial = ReplicaScheduler::new(1);
        for (k, results) in rx.iter() {
            let results = results.expect("jobs succeed");
            let mut want = spec(4);
            want.seed = 100 + k;
            let want = serial.run_native(&want);
            let key = |v: &[ReplicaResult]| -> Vec<(u32, i64, u64)> {
                v.iter().map(|r| (r.replica, r.best_energy, r.flips)).collect()
            };
            assert_eq!(key(&results), key(&want), "job {k} diverged under overlap");
        }
    }
}
