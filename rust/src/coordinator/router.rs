//! Multi-worker dispatch tier: a routing front-end over several
//! [`Coordinator`] workers.
//!
//! The [`Router`] implements the same [`Dispatch`] surface the TCP
//! service drives, so a client cannot tell a routed tier from a single
//! coordinator — except that jobs spread over `dispatch_workers`
//! independent machines and survive the death of any one of them:
//!
//! * **Placement** ([`Router::submit_spec`]): registry locality first —
//!   a `SOLVE model=<hash>` job prefers the worker that last ran that
//!   hash (`router_locality_hits`), so a hot model's pages stay warm on
//!   one machine — then size-class spread: jobs are ranked by
//!   [`batcher::class_of`] and round-robined across live workers per
//!   class, so one worker does not accumulate all the big instances.
//! * **Journaled re-dispatch** ([`Router::kill_worker`]): every routed
//!   job runs with a router-owned [`JobJournal`] and forced
//!   checkpointing, so when a worker dies its live jobs are cancelled
//!   and resubmitted to survivors *with the same journal* — the replica
//!   resumes from its last [`EngineCheckpoint`] on the identical
//!   deterministic trajectory, so the final result is bit-identical to
//!   an undisturbed run (`router_redispatches` counts them).
//! * **Shared registry**: one [`Registry`] (and therefore one
//!   `Arc<IsingModel>` per distinct model) serves every worker; the
//!   router holds one pin per live registry-backed job and each worker
//!   holds its own, so eviction can never race a running job.
//!
//! There is no background thread: router job state is reconciled
//! demand-driven (`sync_job`) from `state`/`result`/`wait_for`/
//! `cancel`/`kill_worker`, and blocking waits ride the workers' own
//! condvar-backed [`Coordinator::wait_for`] in bounded slices.
//!
//! Lock ordering (deadlock freedom): `jobs` → { `alive`, `locality`,
//! `rr`, `next_id` }, each of the inner locks taken briefly and never
//! the other way around. `kill_worker` flips `alive` in its own scope
//! *before* taking `jobs` for the drain.
//!
//! [`EngineCheckpoint`]: super::journal::EngineCheckpoint

use super::{
    batcher, AdmissionError, Coordinator, CoordinatorConfig, Dispatch, JobJournal, JobResult,
    JobSpec, JobState, Metrics, ModelHash, Registry, WaitOutcome,
};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on one blocking slice against a worker's `wait_for`, so
/// a re-dispatched job's waiter re-reads its placement promptly.
const WAIT_SLICE: Duration = Duration::from_millis(50);

/// A routed job as the router tracks it.
struct RouterJob {
    /// Everything needed to resubmit the job elsewhere.
    spec: JobSpec,
    /// Registry hash when submitted by `SOLVE model=`; the router owns
    /// one pin for the job's lifetime (released at adoption).
    hash: Option<ModelHash>,
    /// The shared checkpoint journal every (re-)dispatch of this job
    /// records into and resumes from.
    journal: Arc<JobJournal>,
    /// `(worker index, worker-local job id)` of the current dispatch.
    placement: Option<(usize, u64)>,
    /// A client requested cancellation; honored across re-dispatch.
    cancelled: bool,
    /// Adopted terminal state — set once, never changes.
    terminal: Option<JobState>,
    /// Adopted result, `job_id` rewritten to the router's id.
    result: Option<JobResult>,
}

struct RouterInner {
    workers: Vec<Coordinator>,
    /// `alive[w]` — false once [`Router::kill_worker`] claimed `w`.
    alive: Mutex<Vec<bool>>,
    registry: Arc<Registry>,
    jobs: Mutex<HashMap<u64, RouterJob>>,
    next_id: Mutex<u64>,
    /// Size classes the placement rank is computed against.
    classes: Vec<usize>,
    /// hash → worker that last received a job for it.
    locality: Mutex<HashMap<ModelHash, usize>>,
    /// Round-robin cursor for the size-class spread.
    rr: Mutex<usize>,
}

/// The routing front-end. Cloneable handle, like [`Coordinator`].
#[derive(Clone)]
pub struct Router {
    inner: Arc<RouterInner>,
    /// Tier-level metrics: `router_redispatches`,
    /// `router_locality_hits` / `router_locality_misses`, plus the
    /// shared registry's gauges and whatever the service adds.
    pub metrics: Arc<Metrics>,
}

impl Router {
    /// Start `dispatch_workers` coordinator workers (each configured
    /// with `cfg`, sharing one registry) behind a router.
    pub fn start_with(dispatch_workers: usize, cfg: CoordinatorConfig) -> Self {
        assert!(dispatch_workers >= 1, "router needs at least one worker");
        let metrics = Arc::new(Metrics::new());
        let registry = match cfg.registry.clone() {
            Some(shared) => shared,
            None => Arc::new(Registry::with_defaults()),
        };
        // First-writer-wins: tier-wide registry gauges land in the
        // router's METRICS output, not in any single worker's.
        registry.attach_metrics(metrics.clone());
        let classes = if cfg.classes.is_empty() {
            batcher::DEFAULT_CLASSES.to_vec()
        } else {
            cfg.classes.clone()
        };
        let workers: Vec<Coordinator> = (0..dispatch_workers)
            .map(|_| {
                Coordinator::start_with(CoordinatorConfig {
                    registry: Some(registry.clone()),
                    ..cfg.clone()
                })
            })
            .collect();
        let alive = vec![true; dispatch_workers];
        Self {
            inner: Arc::new(RouterInner {
                workers,
                alive: Mutex::new(alive),
                registry,
                jobs: Mutex::new(HashMap::new()),
                next_id: Mutex::new(1),
                classes,
                locality: Mutex::new(HashMap::new()),
                rr: Mutex::new(0),
            }),
            metrics,
        }
    }

    /// [`Self::start_with`] with default worker configuration
    /// (`workers_per` compute threads each, overlapping dispatch).
    pub fn start(dispatch_workers: usize, workers_per: usize) -> Self {
        Self::start_with(
            dispatch_workers,
            CoordinatorConfig { workers: workers_per, ..Default::default() },
        )
    }

    /// The shared content-addressed model store.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.inner.registry
    }

    /// Number of workers behind the router (live or killed).
    pub fn worker_count(&self) -> usize {
        self.inner.workers.len()
    }

    /// Direct handle to worker `w` — the churn harness uses it to
    /// assert per-worker invariants (`committed_weight()` drains to 0).
    pub fn worker(&self, w: usize) -> &Coordinator {
        &self.inner.workers[w]
    }

    /// Routed jobs currently placed on worker `w` and not yet adopted
    /// as terminal — what [`Self::kill_worker`] would have to drain.
    pub fn live_jobs_on(&self, w: usize) -> usize {
        self.inner
            .jobs
            .lock()
            .unwrap()
            .values()
            .filter(|j| j.terminal.is_none() && matches!(j.placement, Some((pw, _)) if pw == w))
            .count()
    }

    /// Pick a live worker for `spec`: registry locality first, then
    /// size-class rank + round-robin. `None` when no worker is live.
    /// Takes `alive`/`locality`/`rr` briefly; safe under `jobs`.
    fn place(&self, spec: &JobSpec, hash: Option<ModelHash>) -> Option<usize> {
        let alive = self.inner.alive.lock().unwrap();
        let live: Vec<usize> =
            alive.iter().enumerate().filter(|(_, &a)| a).map(|(w, _)| w).collect();
        drop(alive);
        if live.is_empty() {
            return None;
        }
        if let Some(h) = hash {
            if let Some(&w) = self.inner.locality.lock().unwrap().get(&h) {
                if live.contains(&w) {
                    self.metrics.inc("router_locality_hits");
                    return Some(w);
                }
            }
            self.metrics.inc("router_locality_misses");
        }
        // Same-class jobs round-robin from a per-class offset, so each
        // class spreads over every live worker instead of piling onto
        // worker 0.
        let rank = match batcher::class_of(spec.model.len(), &self.inner.classes) {
            Some(class) => {
                self.inner.classes.iter().filter(|&&c| c < class).count()
            }
            None => self.inner.classes.len(), // overflow class
        };
        let mut rr = self.inner.rr.lock().unwrap();
        let w = live[(rank + *rr) % live.len()];
        *rr = rr.wrapping_add(1);
        Some(w)
    }

    /// Adopt a worker-terminal outcome into the router job (caller
    /// holds the `jobs` lock): record the terminal state, rewrite the
    /// result to the router's id and release the router's model pin.
    fn adopt(
        registry: &Registry,
        metrics: &Metrics,
        id: u64,
        job: &mut RouterJob,
        state: JobState,
        result: Option<JobResult>,
    ) {
        job.terminal = Some(state);
        job.result = result.map(|mut r| {
            r.job_id = id;
            r
        });
        if let Some(h) = job.hash {
            registry.unpin(h);
        }
        metrics.inc("router_jobs_adopted");
    }

    /// Demand-driven reconciliation: if the job's current worker is
    /// live and reports a terminal state, adopt it. Jobs on a killed
    /// worker are left alone — `kill_worker`'s drain owns their fate.
    fn sync_job(&self, id: u64) {
        let mut jobs = self.inner.jobs.lock().unwrap();
        let Some(job) = jobs.get_mut(&id) else { return };
        if job.terminal.is_some() {
            return;
        }
        let Some((w, wid)) = job.placement else { return };
        if !self.inner.alive.lock().unwrap()[w] {
            return;
        }
        let worker = &self.inner.workers[w];
        if let Some(state) = Dispatch::state(worker, wid) {
            if state.is_terminal() {
                let result = Dispatch::result(worker, wid);
                Self::adopt(&self.inner.registry, &self.metrics, id, job, state, result);
            }
        }
    }

    /// Kill worker `w`: mark it dead, adopt its already-terminal jobs,
    /// cancel its live ones and re-dispatch them to survivors — same
    /// spec, same journal, so each resumes from its last checkpoint and
    /// finishes bit-identical to an undisturbed run. Finally shuts the
    /// worker down so its threads drain. Idempotent.
    pub fn kill_worker(&self, w: usize) {
        {
            let mut alive = self.inner.alive.lock().unwrap();
            if !alive[w] {
                return;
            }
            alive[w] = false;
        }
        // Hold the jobs lock for the whole drain: submits, waits and
        // syncs observe either the old placement (pre-drain) or the
        // re-dispatched one — never a half-drained tier.
        let mut jobs = self.inner.jobs.lock().unwrap();
        let victims: Vec<u64> = jobs
            .iter()
            .filter(|(_, j)| {
                j.terminal.is_none() && matches!(j.placement, Some((pw, _)) if pw == w)
            })
            .map(|(&id, _)| id)
            .collect();
        let worker = &self.inner.workers[w];
        for id in victims {
            let job = jobs.get_mut(&id).expect("victim listed above");
            let (_, wid) = job.placement.expect("victim has a placement");
            match Dispatch::state(worker, wid) {
                // Finished before the kill: adopt the real outcome.
                Some(state) if state.is_terminal() => {
                    let result = Dispatch::result(worker, wid);
                    Self::adopt(&self.inner.registry, &self.metrics, id, job, state, result);
                }
                _ => {
                    // Preempt the orphaned run; its replica threads may
                    // linger briefly, but both the old and the new run
                    // walk the same deterministic trajectory, so any
                    // checkpoint either records is a valid resume point.
                    Dispatch::cancel(worker, wid);
                    if job.cancelled {
                        // The client already asked for cancellation —
                        // finalize instead of resurrecting the job
                        // (empty partial result, like a pre-dispatch
                        // cancel on a single coordinator).
                        let result = JobResult {
                            job_id: id,
                            label: job.spec.label.clone(),
                            replicas: Vec::new(),
                            wall: Duration::ZERO,
                            completed: false,
                            portfolio: None,
                        };
                        Self::adopt(
                            &self.inner.registry,
                            &self.metrics,
                            id,
                            job,
                            JobState::Cancelled,
                            Some(result),
                        );
                        continue;
                    }
                    match self.place(&job.spec, job.hash) {
                        None => {
                            let msg = "no live workers to re-dispatch to".to_string();
                            Self::adopt(
                                &self.inner.registry,
                                &self.metrics,
                                id,
                                job,
                                JobState::Failed(msg),
                                None,
                            );
                        }
                        Some(target) => {
                            if let Some(h) = job.hash {
                                // The survivor gets its own pin; the
                                // dead worker releases the old one when
                                // its cancelled run drains.
                                self.inner.registry.pin(h);
                                self.inner.locality.lock().unwrap().insert(h, target);
                            }
                            let new_wid = self.inner.workers[target]
                                .submit_managed(
                                    job.spec.clone(),
                                    job.journal.clone(),
                                    job.hash,
                                    // Never reject a re-dispatch: "zero
                                    // lost jobs" beats the cap for work
                                    // that was already admitted once.
                                    false,
                                )
                                .expect("unenforced submit cannot be rejected");
                            job.placement = Some((target, new_wid));
                            self.metrics.inc("router_redispatches");
                        }
                    }
                }
            }
        }
        drop(jobs);
        // Let the dead worker's queue and in-flight (now cancelled)
        // jobs drain; its committed weight returns to zero.
        Dispatch::shutdown(worker);
    }
}

impl Dispatch for Router {
    /// Place and submit. The `jobs` lock is held across worker
    /// selection and submission so a concurrent [`Router::kill_worker`]
    /// either sees the fully recorded placement or runs first (in
    /// which case `place` already excludes the dead worker).
    fn submit_spec(&self, spec: JobSpec, hash: Option<ModelHash>) -> Result<u64, AdmissionError> {
        let mut jobs = self.inner.jobs.lock().unwrap();
        let Some(w) = self.place(&spec, hash) else {
            return Err(AdmissionError::NoLiveWorkers);
        };
        if let Some(h) = hash {
            // One pin for the worker (released when its job goes
            // terminal); the caller's checkout pin becomes the router's
            // job-lifetime pin on success.
            self.inner.registry.pin(h);
        }
        // The journal outlives any single dispatch: a re-dispatch after
        // a worker death resumes from whatever it recorded.
        let journal = Arc::new(JobJournal::new());
        match self.inner.workers[w].submit_managed(spec.clone(), journal.clone(), hash, true) {
            Err(e) => {
                if let Some(h) = hash {
                    // The worker refused: take back its pin. The
                    // caller keeps (and must release) the checkout pin.
                    self.inner.registry.unpin(h);
                }
                Err(e)
            }
            Ok(wid) => {
                if let Some(h) = hash {
                    self.inner.locality.lock().unwrap().insert(h, w);
                }
                let id = {
                    let mut next = self.inner.next_id.lock().unwrap();
                    let id = *next;
                    *next += 1;
                    id
                };
                jobs.insert(
                    id,
                    RouterJob {
                        spec,
                        hash,
                        journal,
                        placement: Some((w, wid)),
                        cancelled: false,
                        terminal: None,
                        result: None,
                    },
                );
                self.metrics.inc("jobs_submitted");
                Ok(id)
            }
        }
    }

    fn cancel(&self, id: u64) -> bool {
        self.sync_job(id);
        let mut jobs = self.inner.jobs.lock().unwrap();
        match jobs.get_mut(&id) {
            None => false,
            Some(j) if j.terminal.is_some() => false,
            Some(j) => {
                j.cancelled = true;
                match j.placement {
                    Some((w, wid)) if self.inner.alive.lock().unwrap()[w] => {
                        Dispatch::cancel(&self.inner.workers[w], wid)
                    }
                    // Dead worker: the kill drain honors `cancelled`.
                    _ => true,
                }
            }
        }
    }

    fn state(&self, id: u64) -> Option<JobState> {
        self.sync_job(id);
        let jobs = self.inner.jobs.lock().unwrap();
        let job = jobs.get(&id)?;
        if let Some(s) = &job.terminal {
            return Some(s.clone());
        }
        match job.placement {
            None => Some(JobState::Queued),
            Some((w, wid)) => {
                if !self.inner.alive.lock().unwrap()[w] {
                    // Mid-kill: the drain will adopt or re-dispatch.
                    return Some(JobState::Running);
                }
                match Dispatch::state(&self.inner.workers[w], wid) {
                    // A terminal state the sync above did not adopt is
                    // a benign race; report the pre-adoption view.
                    Some(s) if s.is_terminal() => Some(JobState::Running),
                    Some(s) => Some(s),
                    None => Some(JobState::Running),
                }
            }
        }
    }

    fn result(&self, id: u64) -> Option<JobResult> {
        self.sync_job(id);
        self.inner.jobs.lock().unwrap().get(&id).and_then(|j| j.result.clone())
    }

    fn wait_for(&self, id: u64, timeout: Duration) -> WaitOutcome {
        let deadline = Instant::now() + timeout;
        loop {
            self.sync_job(id);
            let placement = {
                let jobs = self.inner.jobs.lock().unwrap();
                match jobs.get(&id) {
                    None => return WaitOutcome::Unknown,
                    Some(j) => match &j.terminal {
                        Some(s) => return WaitOutcome::Terminal(s.clone()),
                        None => j.placement,
                    },
                }
            };
            let now = Instant::now();
            if now >= deadline {
                return WaitOutcome::Pending;
            }
            let slice = (deadline - now).min(WAIT_SLICE);
            match placement {
                // Ride the worker's condvar; bounded so a re-dispatch
                // (placement change) is observed within one slice.
                Some((w, wid)) => {
                    let _ = Dispatch::wait_for(&self.inner.workers[w], wid, slice);
                }
                None => std::thread::sleep(slice.min(Duration::from_millis(5))),
            }
        }
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn registry(&self) -> &Arc<Registry> {
        &self.inner.registry
    }

    /// Shut down every live worker, then adopt whatever drained.
    fn shutdown(&self) {
        let live: Vec<usize> = {
            let alive = self.inner.alive.lock().unwrap();
            alive.iter().enumerate().filter(|(_, &a)| a).map(|(w, _)| w).collect()
        };
        for w in live {
            Dispatch::shutdown(&self.inner.workers[w]);
        }
        let ids: Vec<u64> = {
            let jobs = self.inner.jobs.lock().unwrap();
            jobs.iter().filter(|(_, j)| j.terminal.is_none()).map(|(&id, _)| id).collect()
        };
        for id in ids {
            self.sync_job(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Backend;
    use crate::engine::{Mode, Schedule, SelectorKind};
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::StatelessRng;

    fn spec(label: &str, seed: u64, steps: u64) -> JobSpec {
        let rng = StatelessRng::new(seed);
        let p = MaxCut::new(generators::erdos_renyi(48, 160, &[-1, 1], &rng));
        JobSpec {
            model: Arc::new(p.model().clone()),
            label: label.into(),
            mode: Mode::RouletteWheel,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.05 },
            steps,
            replicas: 2,
            seed,
            target_energy: None,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
            budget_ms: 0,
            max_retries: 0,
            backend: Backend::Native,
            portfolio: None,
        }
    }

    fn wait_terminal(r: &Router, id: u64) -> JobState {
        loop {
            match r.wait_for(id, Duration::from_secs(60)) {
                WaitOutcome::Terminal(s) => return s,
                WaitOutcome::Pending => continue,
                WaitOutcome::Unknown => panic!("job {id} unknown"),
            }
        }
    }

    fn key(r: &JobResult) -> Vec<(u32, i64, u64)> {
        r.replicas.iter().map(|p| (p.replica, p.best_energy, p.flips)).collect()
    }

    /// A routed job is bit-identical to the same spec on a plain
    /// coordinator — routing must not perturb results.
    #[test]
    fn routed_results_match_single_coordinator() {
        let router = Router::start(2, 2);
        let single = Coordinator::start(2);
        let rid = router.submit_spec(spec("routed", 11, 600), None).unwrap();
        let sid = single.submit(spec("routed", 11, 600));
        assert_eq!(wait_terminal(&router, rid), JobState::Done);
        let routed = Dispatch::result(&router, rid).unwrap();
        let direct = single.wait(sid).unwrap();
        assert_eq!(routed.job_id, rid, "adopted result carries the router id");
        assert_eq!(key(&routed), key(&direct));
        Dispatch::shutdown(&router);
        single.shutdown();
    }

    /// By-hash jobs stick to the worker that last saw the hash; the
    /// locality counters account every placement decision.
    #[test]
    fn locality_prefers_the_resident_worker() {
        let router = Router::start(3, 1);
        let model = (*spec("loc", 5, 200).model).clone();
        let h = router.registry().put(model).unwrap();
        let mut first_worker = None;
        for k in 0..4u64 {
            let arc = router.registry().checkout(h).expect("stored");
            let mut s = spec("loc", 5, 200);
            s.model = arc;
            s.seed = 5 + k;
            let id = router.submit_spec(s, Some(h)).unwrap();
            let jobs = router.inner.jobs.lock().unwrap();
            let (w, _) = jobs[&id].placement.unwrap();
            drop(jobs);
            match first_worker {
                None => first_worker = Some(w),
                Some(fw) => assert_eq!(w, fw, "by-hash jobs must stay on the resident worker"),
            }
            assert_eq!(wait_terminal(&router, id), JobState::Done);
        }
        // Pins drain with the jobs: checkout pin → router (released at
        // adoption), minted pin → worker (released at terminal).
        assert_eq!(router.registry().stats().pinned, 0);
        let misses = router.metrics.get("router_locality_misses");
        assert_eq!(misses, 1, "only the first placement misses");
        assert_eq!(router.metrics.get("router_locality_hits"), 3);
        Dispatch::shutdown(&router);
    }

    /// Killing a worker mid-run re-dispatches its jobs to survivors
    /// with the same journal: everything still terminates `Done`,
    /// bit-identical to an undisturbed single-coordinator run.
    #[test]
    fn kill_worker_redispatches_and_preserves_results() {
        let router = Router::start(2, 2);
        // Enough steps that the kill lands mid-run, small enough to
        // finish promptly after re-dispatch.
        let ids: Vec<u64> = (0..4)
            .map(|k| router.submit_spec(spec(&format!("k{k}"), 70 + k, 2_500_000), None).unwrap())
            .collect();
        // Find a worker that actually holds live jobs, then kill it.
        let victim = (0..router.worker_count())
            .max_by_key(|&w| router.live_jobs_on(w))
            .unwrap();
        assert!(router.live_jobs_on(victim) >= 1, "placement must spread jobs");
        router.kill_worker(victim);
        assert!(router.metrics.get("router_redispatches") >= 1, "kill mid-run must re-dispatch");
        let reference = Coordinator::start(2);
        for (k, id) in ids.iter().enumerate() {
            assert_eq!(wait_terminal(&router, *id), JobState::Done, "job {id} lost");
            let routed = Dispatch::result(&router, *id).unwrap();
            let sid = reference.submit(spec(&format!("k{k}"), 70 + k as u64, 2_500_000));
            let direct = reference.wait(sid).unwrap();
            assert_eq!(key(&routed), key(&direct), "re-dispatched job {id} must be bit-identical");
        }
        // Idempotent; killing the last workers leaves re-dispatch
        // nowhere to go only for *live* jobs — none remain here.
        router.kill_worker(victim);
        for w in 0..router.worker_count() {
            assert_eq!(router.worker(w).committed_weight(), 0, "worker {w} budget must drain");
        }
        Dispatch::shutdown(&router);
        reference.shutdown();
    }

    /// A routed portfolio job survives worker death: re-dispatch
    /// restarts the race on a survivor (races don't checkpoint — their
    /// contender interleaving is real nondeterminism) and the job still
    /// terminates `Done` with the full roster of contender results and
    /// a winner.
    #[test]
    fn portfolio_job_survives_kill_worker_redispatch() {
        let router = Router::start(2, 2);
        let mut sp = spec("race", 5, 2_500_000);
        sp.portfolio = Some(crate::portfolio::PortfolioSpec::List(vec![
            "rsa".into(),
            "rwa".into(),
            "neal".into(),
        ]));
        let id = router.submit_spec(sp, None).unwrap();
        let (victim, _) = {
            let jobs = router.inner.jobs.lock().unwrap();
            jobs[&id].placement.unwrap()
        };
        router.kill_worker(victim);
        assert_eq!(wait_terminal(&router, id), JobState::Done, "race lost to the kill");
        let r = Dispatch::result(&router, id).unwrap();
        assert_eq!(r.replicas.len(), 3, "full roster must report");
        let p = r.portfolio.expect("portfolio outcome must survive adoption");
        assert_eq!(
            p.contenders,
            vec!["rsa".to_string(), "rwa".to_string(), "neal".to_string()]
        );
        assert!(p.contenders.contains(&p.winner), "winner {} not in roster", p.winner);
        for w in 0..router.worker_count() {
            assert_eq!(router.worker(w).committed_weight(), 0, "worker {w} budget must drain");
        }
        Dispatch::shutdown(&router);
    }

    /// CANCEL before a kill is honored across the drain: the job lands
    /// `Cancelled`, never resurrected onto a survivor.
    #[test]
    fn cancelled_job_is_not_resurrected_by_kill() {
        let router = Router::start(2, 1);
        let id = router.submit_spec(spec("c", 9, 2_000_000_000), None).unwrap();
        assert!(Dispatch::cancel(&router, id));
        let (w, _) = {
            let jobs = router.inner.jobs.lock().unwrap();
            jobs[&id].placement.unwrap()
        };
        router.kill_worker(w);
        let s = wait_terminal(&router, id);
        assert_eq!(s, JobState::Cancelled);
        assert_eq!(router.metrics.get("router_redispatches"), 0);
        Dispatch::shutdown(&router);
    }
}
