//! Size-class batching for the admission queue and the XLA backend.
//!
//! Two consumers share this planner:
//!
//! * The **overlapping dispatcher** (`coordinator::Coordinator`) drains
//!   its admission queue and calls [`plan`] to group the drained jobs
//!   by instance size class, so each class's jobs enter the replica
//!   pool together (small jobs ride one fan-out instead of queuing
//!   behind a large job) — see `docs/ARCHITECTURE.md`.
//! * The **XLA backend**: AOT artifacts are compiled for fixed shapes;
//!   incoming instances are padded up to the nearest artifact size
//!   (padding spins carry zero couplings and frozen fields — see
//!   `runtime::chunk`), so one compiled executable serves each group.
//!   [`BatchPlan::padding_waste`] tells operators when a new artifact
//!   size would pay off.

/// The spin-count classes the coordinator's admission queue groups by
/// (also sensible artifact sizes for the XLA backend). Jobs above the
/// largest class land in [`BatchPlan::overflow`] and dispatch
/// individually.
pub const DEFAULT_CLASSES: [usize; 6] = [64, 256, 1024, 4096, 16_384, 65_536];

/// Assignment of a job to a size class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    /// Index of the job in the submitted order.
    pub job: usize,
    /// Artifact size class chosen.
    pub class_n: usize,
}

/// Result of batching a set of job sizes against the available classes.
#[derive(Clone, Debug, Default)]
pub struct BatchPlan {
    pub assignments: Vec<Assignment>,
    /// Jobs too large for any class (must run on the native backend).
    pub overflow: Vec<usize>,
}

impl BatchPlan {
    /// Groups of job indices per class, in ascending class order.
    pub fn groups(&self) -> Vec<(usize, Vec<usize>)> {
        let mut map: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
        for a in &self.assignments {
            map.entry(a.class_n).or_default().push(a.job);
        }
        map.into_iter().collect()
    }

    /// Fraction of padded lanes wasted, per class (`Σ(class−n)/Σclass`).
    pub fn padding_waste(&self, sizes: &[usize]) -> f64 {
        let mut padded = 0usize;
        let mut used = 0usize;
        for a in &self.assignments {
            padded += a.class_n;
            used += sizes[a.job];
        }
        if padded == 0 {
            0.0
        } else {
            1.0 - used as f64 / padded as f64
        }
    }
}

/// Assign each job size to the smallest class that fits. Every job
/// lands in exactly one place: an [`Assignment`] to a class, or
/// [`BatchPlan::overflow`] if no class is large enough.
///
/// ```
/// use snowball::coordinator::batcher;
///
/// let plan = batcher::plan(&[100, 256, 300, 5000], &[256, 2048]);
/// assert_eq!(plan.groups(), vec![(256, vec![0, 1]), (2048, vec![2])]);
/// assert_eq!(plan.overflow, vec![3]); // larger than every class
/// ```
pub fn plan(job_sizes: &[usize], classes: &[usize]) -> BatchPlan {
    let mut sorted: Vec<usize> = classes.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    let mut out = BatchPlan::default();
    for (job, &n) in job_sizes.iter().enumerate() {
        match sorted.iter().find(|&&c| c >= n) {
            Some(&c) => out.assignments.push(Assignment { job, class_n: c }),
            None => out.overflow.push(job),
        }
    }
    out
}

/// The smallest class that fits one job of `size` spins, or `None` when
/// it fits no class (the overflow case). Single-job form of [`plan`] —
/// the dispatch-tier router uses it to spread jobs over workers by size
/// class without building a whole plan per submission.
pub fn class_of(size: usize, classes: &[usize]) -> Option<usize> {
    classes.iter().copied().filter(|&c| c >= size).min()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smallest_fitting_class_wins() {
        let p = plan(&[100, 256, 300, 2048, 5000], &[256, 2048]);
        let classes: Vec<usize> = p.assignments.iter().map(|a| a.class_n).collect();
        assert_eq!(classes, vec![256, 256, 2048, 2048]);
        assert_eq!(p.overflow, vec![4]);
    }

    #[test]
    fn groups_are_per_class() {
        let p = plan(&[10, 300, 20], &[256, 2048]);
        let g = p.groups();
        assert_eq!(g, vec![(256, vec![0, 2]), (2048, vec![1])]);
    }

    #[test]
    fn padding_waste_accounting() {
        let sizes = [128usize, 256];
        let p = plan(&sizes, &[256]);
        // used = 384, padded = 512 → waste = 0.25
        assert!((p.padding_waste(&sizes) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn class_of_agrees_with_plan() {
        let classes = [2048usize, 256]; // deliberately unsorted
        for size in [1usize, 100, 256, 257, 2048, 2049, 5000] {
            let p = plan(&[size], &classes);
            let want = p.assignments.first().map(|a| a.class_n);
            assert_eq!(class_of(size, &classes), want, "size {size}");
        }
        assert_eq!(class_of(10, &[]), None);
    }

    #[test]
    fn empty_inputs() {
        let p = plan(&[], &[256]);
        assert!(p.assignments.is_empty() && p.overflow.is_empty());
        assert_eq!(p.padding_waste(&[]), 0.0);
    }
}
