//! Line-oriented TCP service over the coordinator (the "host software"
//! face of the Ising machine).
//!
//! **The full wire protocol is specified in `docs/PROTOCOL.md`** —
//! every command (`PING`/`PUT`/`REGISTRY`/`SOLVE`/`STATUS`/`WAIT`/
//! `CANCEL`/`RESULT`/`METRICS`/`QUIT`), every `ERR` form, and the
//! `selector=`/`schedule=` syntax. In one breath: one request per
//! line, one reply per line (`METRICS` is multi-line, terminated by
//! `END`, and `PUT` has a multi-line *body*, terminated by `END`);
//! `SOLVE` returns `JOB id=<u64>` immediately and the job runs
//! asynchronously on the coordinator; `WAIT id=` blocks until the job
//! is terminal; `CANCEL id=` requests cooperative preemption; errors
//! reply `ERR <message>`.
//!
//! The service is generic over its [`Dispatch`] back-end: a single
//! [`Coordinator`] (the default) or the multi-worker
//! [`Router`](super::Router) dispatch tier — the wire protocol is
//! identical either way.
//!
//! **Content-addressed submission**: `PUT n=<n>` uploads a model body
//! (`<i> <k> <J>` coupling lines, `H <i> <h>` field lines, `END`) into
//! the [`Registry`](super::Registry) and replies `STORED model=<hash>`;
//! `SOLVE model=<hash>` then references it without re-shipping the
//! matrix, and every such job shares one `Arc<IsingModel>`. The
//! checkout pin taken while parsing `SOLVE` is handed to the dispatcher
//! on success and released here on a refused submit, so no `ERR` path
//! leaks a pin.
//!
//! One thread per connection; compute runs on the coordinator pool
//! (overlapping dispatch by default, so many clients' jobs execute
//! concurrently), which means slow jobs never block the listener — the
//! load harness in `rust/tests/service_load.rs` drives 100+ concurrent
//! clients through this path.
//!
//! **Client hang-up mid-`WAIT`**: a blocked `WAIT` probes its socket
//! between bounded `wait_for` windows; when the peer is gone the
//! handler returns immediately, releasing the connection thread and
//! its waiter registration (`service_waiters` gauge, guard-scoped) —
//! a disconnected client can no longer pin coordinator state. Pinned
//! by the disconnect cohort in `rust/tests/service_load.rs` and the
//! chaos suite.

use super::{
    Backend, Coordinator, Dispatch, JobSpec, JobState, Metrics, ModelHash, PutError, WaitOutcome,
};
use crate::engine::{Mode, Schedule, SelectorKind};
use crate::graph::{generators, gset};
use crate::ising::IsingModel;
use crate::rng::StatelessRng;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// The TCP service, generic over its [`Dispatch`] back-end (a single
/// [`Coordinator`] by default, or a [`Router`](super::Router)).
pub struct Service<D: Dispatch = Coordinator> {
    coordinator: D,
    listener: TcpListener,
}

impl<D: Dispatch> Service<D> {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(coordinator: D, addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Self { coordinator, listener })
    }

    /// The bound address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Serve forever (one thread per connection).
    pub fn serve(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            let stream = stream?;
            let coord = self.coordinator.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(coord, stream);
            });
        }
        Ok(())
    }

    /// Serve in a background thread, returning immediately.
    pub fn serve_in_background(self) -> std::net::SocketAddr {
        let addr = self.addr();
        std::thread::spawn(move || {
            let _ = self.serve();
        });
        addr
    }
}

fn handle_connection<D: Dispatch>(coord: D, stream: TcpStream) -> Result<()> {
    let peer_read = stream.try_clone()?;
    let mut reader = BufReader::new(peer_read);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // peer closed
        }
        let trimmed = line.trim();
        // PUT is the one command with a multi-line body, so it is
        // handled here where the connection's reader lives.
        let cmd = trimmed.split_whitespace().next().unwrap_or("");
        let reply = if cmd == "PUT" {
            match handle_put(&coord, trimmed, &mut reader) {
                Ok(s) => s,
                Err(e) => format!("ERR {e}"),
            }
        } else {
            match handle_line(&coord, trimmed, &writer) {
                Ok(Reply::Line(s)) => s,
                Ok(Reply::Quit) => {
                    writeln!(writer, "BYE")?;
                    return Ok(());
                }
                // Peer vanished mid-blocking-command: nothing to write,
                // no one to write it to — just release the thread.
                Ok(Reply::Disconnect) => return Ok(()),
                Err(e) => format!("ERR {e}"),
            }
        };
        writeln!(writer, "{reply}")?;
        writer.flush()?;
        coord.metrics().inc("service_requests");
    }
}

/// Handle a `PUT n=<n>` upload: read body lines (`<i> <k> <J>`
/// couplings, `H <i> <h>` fields) until `END`, store the model in the
/// registry, reply `STORED model=<hash>`. On any body error the rest of
/// the body is still drained to `END` so the connection stays
/// line-synchronized, then the `ERR` is reported.
fn handle_put<D: Dispatch>(
    coord: &D,
    header: &str,
    reader: &mut BufReader<TcpStream>,
) -> Result<String> {
    let kv: HashMap<&str, &str> =
        header.split_whitespace().skip(1).filter_map(|t| t.split_once('=')).collect();
    // A refused header must still drain the body to END: the client
    // already has it in flight, and leaving it unread would desync the
    // connection (body lines would parse as commands).
    let n = match kv.get("n").context("missing n=").and_then(|v| Ok(v.parse::<usize>()?)) {
        Ok(n) => n,
        Err(e) => {
            drain_put_body(reader)?;
            return Err(e);
        }
    };
    // Body format: `ising` (default; `<i> <k> <J>` / `H <i> <h>`) or
    // `qubo` (qbsolv entries `<i> <j> <q>`, diagonal = linear term,
    // converted to Ising at store time — docs/PROTOCOL.md).
    let qubo = match kv.get("format").copied().unwrap_or("ising") {
        "ising" => false,
        "qubo" => true,
        other => {
            let msg = format!("format must be ising|qubo (got {other})");
            drain_put_body(reader)?;
            anyhow::bail!("{msg}");
        }
    };
    let max = coord.registry().max_model_bytes();
    let bytes = IsingModel::approx_bytes_for(n);
    // Refuse before materializing an O(N²) matrix; the registry would
    // apply the same check, this just does it allocation-free.
    if bytes > max {
        drain_put_body(reader)?;
        anyhow::bail!("{}", PutError::TooLarge { bytes, max });
    }
    let mut model = IsingModel::zeros(if qubo { 0 } else { n });
    let mut entries: Vec<(usize, usize, i64)> = Vec::new();
    let mut body_err: Option<String> = None;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            anyhow::bail!("connection closed mid-PUT (missing END)");
        }
        let body = line.trim();
        if body == "END" {
            break;
        }
        if body.is_empty() || body_err.is_some() {
            continue; // drain the rest after the first error
        }
        let applied = if qubo {
            apply_qubo_line(&mut entries, body, n)
        } else {
            apply_put_line(&mut model, body, n)
        };
        if let Err(e) = applied {
            body_err = Some(e);
        }
    }
    if let Some(e) = body_err {
        anyhow::bail!("{e}");
    }
    if qubo {
        // The conversion offset is dropped here: jobs report Ising
        // energies; clients recover the QUBO objective as (H + C) / 4
        // ([`crate::problems::qubo`]).
        model = crate::problems::Qubo::from_entries(n, &entries)
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .model;
    }
    let hash = coord.registry().put(model).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(format!("STORED model={hash}"))
}

/// One `PUT format=qubo` body line: a qbsolv `<i> <j> <q>` entry
/// (diagonal = linear term), accumulated for the Ising conversion.
fn apply_qubo_line(
    entries: &mut Vec<(usize, usize, i64)>,
    line: &str,
    n: usize,
) -> std::result::Result<(), String> {
    let malformed = format!("malformed PUT body line '{line}' (expect '<i> <j> <q>' for qubo)");
    let toks: Vec<&str> = line.split_whitespace().collect();
    match toks.as_slice() {
        [i, j, v] => {
            let i: usize = i.parse().map_err(|_| malformed.clone())?;
            let j: usize = j.parse().map_err(|_| malformed.clone())?;
            let v: i64 = v.parse().map_err(|_| malformed)?;
            if i >= n || j >= n {
                return Err(format!("spin index {} out of range (n={n})", i.max(j)));
            }
            entries.push((i, j, v));
            Ok(())
        }
        _ => Err(malformed),
    }
}

/// One `PUT` body line into the model under construction.
fn apply_put_line(model: &mut IsingModel, line: &str, n: usize) -> std::result::Result<(), String> {
    let malformed =
        format!("malformed PUT body line '{line}' (expect '<i> <k> <J>' or 'H <i> <h>')");
    let toks: Vec<&str> = line.split_whitespace().collect();
    match toks.as_slice() {
        ["H", i, h] => {
            let i: usize = i.parse().map_err(|_| malformed.clone())?;
            let h: i32 = h.parse().map_err(|_| malformed.clone())?;
            if i >= n {
                return Err(format!("spin index {i} out of range (n={n})"));
            }
            model.set_h(i, h);
            Ok(())
        }
        [i, k, w] => {
            let i: usize = i.parse().map_err(|_| malformed.clone())?;
            let k: usize = k.parse().map_err(|_| malformed.clone())?;
            let w: i32 = w.parse().map_err(|_| malformed.clone())?;
            if i >= n || k >= n {
                return Err(format!("spin index {} out of range (n={n})", i.max(k)));
            }
            if i == k {
                return Err(format!("self-coupling {i} {k} is not allowed (zero diagonal)"));
            }
            model.set_j(i, k, w);
            Ok(())
        }
        _ => Err(malformed),
    }
}

/// Consume body lines up to `END` (used when the header was refused).
fn drain_put_body(reader: &mut BufReader<TcpStream>) -> Result<()> {
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            anyhow::bail!("connection closed mid-PUT (missing END)");
        }
        if line.trim() == "END" {
            return Ok(());
        }
    }
}

enum Reply {
    Line(String),
    Quit,
    /// The client hung up while the handler was blocked (WAIT).
    Disconnect,
}

/// Wire name of a job state (docs/PROTOCOL.md state table).
fn state_name(state: &JobState) -> &'static str {
    match state {
        JobState::Queued => "queued",
        JobState::Running => "running",
        JobState::Done => "done",
        JobState::Failed(_) => "failed",
        JobState::Cancelled => "cancelled",
        JobState::TimedOut => "timed_out",
    }
}

/// Liveness probe for a blocked handler: peek the socket without
/// consuming. `Ok(0)` is an orderly hang-up; pending bytes (pipelined
/// requests) and `WouldBlock` (idle but connected) mean alive; any
/// other error means the connection is unusable.
fn peer_gone(stream: &TcpStream) -> bool {
    let mut probe = [0u8; 1];
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let gone = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    // Restore; failing to means reads/writes would misbehave, so treat
    // it as gone too.
    stream.set_nonblocking(false).is_err() || gone
}

/// Decrements `service_waiters` however the WAIT ends (reply, ERR,
/// disconnect) — the gauge cannot leak on any exit path.
struct WaiterGuard<'a>(&'a Metrics);
impl Drop for WaiterGuard<'_> {
    fn drop(&mut self) {
        self.0.gauge_add("service_waiters", -1);
    }
}

fn handle_line<D: Dispatch>(coord: &D, line: &str, stream: &TcpStream) -> Result<Reply> {
    let mut parts = line.split_whitespace();
    let cmd = parts.next().unwrap_or("");
    let kv: HashMap<&str, &str> = parts.filter_map(|t| t.split_once('=')).collect();
    match cmd {
        "PING" => Ok(Reply::Line("PONG".into())),
        "QUIT" => Ok(Reply::Quit),
        "METRICS" => Ok(Reply::Line(format!("{}END", coord.metrics().render()))),
        "REGISTRY" => {
            let s = coord.registry().stats();
            if s.entries == 0 {
                anyhow::bail!("registry empty (PUT a model first)");
            }
            Ok(Reply::Line(format!(
                "REGISTRY entries={} bytes={} pinned={} hits={} misses={} evictions={} dedup={}",
                s.entries, s.bytes, s.pinned, s.hits, s.misses, s.evictions, s.dedup
            )))
        }
        "SOLVE" => {
            let instance = kv.get("instance").copied();
            let model_ref = kv.get("model").copied();
            anyhow::ensure!(
                !(instance.is_some() && model_ref.is_some()),
                "instance= and model= are mutually exclusive"
            );
            let mode = Mode::parse(kv.get("mode").copied().unwrap_or("rwa"))?;
            let selector = SelectorKind::parse(kv.get("selector").copied().unwrap_or("fenwick"))?;
            let steps: u64 = kv.get("steps").copied().unwrap_or("100000").parse()?;
            let replicas: u32 = kv.get("replicas").copied().unwrap_or("8").parse()?;
            let seed: u64 = kv.get("seed").copied().unwrap_or("1").parse()?;
            let target = kv.get("target").map(|v| v.parse::<i64>()).transpose()?;
            // Within-instance shard lanes: 1 (default) = classic
            // bit-reproducible engine, >1 = async sharded lanes,
            // 0 = auto by instance size (docs/PROTOCOL.md).
            let shards: u32 = kv.get("shards").copied().unwrap_or("1").parse()?;
            anyhow::ensure!(
                shards as usize <= crate::engine::shard::MAX_SHARDS,
                "shards must be <= {} (got {shards})",
                crate::engine::shard::MAX_SHARDS
            );
            // Core affinity for the shard lane threads (async sharded
            // replicas only; docs/PROTOCOL.md). Strict like every other
            // SOLVE field: unrecognized values are an ERR, not a
            // silent `false`.
            let pin_lanes: bool = match kv.get("pin_lanes").copied() {
                None | Some("0") | Some("false") => false,
                Some("1") | Some("true") => true,
                Some(other) => anyhow::bail!("pin_lanes must be 0|1|true|false (got {other})"),
            };
            // NUMA-local lane rows (async sharded replicas only, pair
            // with pin_lanes=1; docs/PROTOCOL.md). Same strictness.
            let local_rows: bool = match kv.get("local_rows").copied() {
                None | Some("0") | Some("false") => false,
                Some("1") | Some("true") => true,
                Some(other) => anyhow::bail!("local_rows must be 0|1|true|false (got {other})"),
            };
            let schedule = match kv.get("schedule") {
                Some(s) => Schedule::parse(s)?,
                None => Schedule::Geometric { t0: 8.0, t1: 0.05 },
            };
            // Fault-tolerant lifecycle knobs (docs/PROTOCOL.md):
            // budget_ms=0 = no deadline, max_retries=0 = fail on the
            // first replica panic.
            let budget_ms: u64 = kv.get("budget_ms").copied().unwrap_or("0").parse()?;
            let max_retries: u32 = kv.get("max_retries").copied().unwrap_or("0").parse()?;
            // Portfolio racing (docs/PROTOCOL.md): `portfolio=auto|full|
            // <name>[,<name>...]` turns the job into a contender race.
            // Both ERR forms come verbatim from `PortfolioSpec::parse`.
            let portfolio = kv
                .get("portfolio")
                .map(|v| crate::portfolio::PortfolioSpec::parse(v))
                .transpose()
                .map_err(|e| anyhow::anyhow!(e))?;
            // Resolve the model LAST, after every other field parsed:
            // the registry checkout takes a pin, and doing it here
            // means no earlier `ERR` path can leak one.
            let (label, model, hash) = match (instance, model_ref) {
                (Some(name), _) => {
                    let (label, m) = build_instance(name, seed)?;
                    (label, Arc::new(m), None)
                }
                (None, Some(hex)) => {
                    let h = ModelHash::parse(hex).map_err(|e| anyhow::anyhow!(e))?;
                    // Atomic lookup-and-pin: the model cannot be
                    // evicted between here and job registration.
                    let m = coord
                        .registry()
                        .checkout(h)
                        .with_context(|| format!("unknown model {h} (PUT it first)"))?;
                    (format!("model:{}", &h.to_hex()[..12]), m, Some(h))
                }
                (None, None) => anyhow::bail!("missing instance= (or model=<hash>)"),
            };
            // submit_spec: with admission control configured, a
            // saturated back-end refuses here (`ERR saturated …`)
            // instead of parking the client's job forever. On success
            // the dispatcher owns the checkout pin; on refusal it is
            // released right here.
            let submitted = coord.submit_spec(
                JobSpec {
                    model,
                    label,
                    mode,
                    selector,
                    schedule,
                    steps,
                    replicas,
                    seed,
                    target_energy: target,
                    shards,
                    pin_lanes,
                    local_rows,
                    budget_ms,
                    max_retries,
                    backend: Backend::Native,
                    portfolio,
                },
                hash,
            );
            match submitted {
                Ok(id) => Ok(Reply::Line(format!("JOB id={id}"))),
                Err(e) => {
                    if let Some(h) = hash {
                        coord.registry().unpin(h);
                    }
                    Err(e.into())
                }
            }
        }
        "STATUS" => {
            let id: u64 = kv.get("id").context("missing id=")?.parse()?;
            let state = match coord.state(id) {
                None => anyhow::bail!("unknown job {id}"),
                Some(s) => state_name(&s),
            };
            Ok(Reply::Line(format!("STATE id={id} state={state}")))
        }
        "CANCEL" => {
            let id: u64 = kv.get("id").context("missing id=")?.parse()?;
            match coord.state(id) {
                None => anyhow::bail!("unknown job {id}"),
                Some(s) if s.is_terminal() => {
                    anyhow::bail!("job {id} already terminal ({})", state_name(&s))
                }
                Some(_) => {}
            }
            if coord.cancel(id) {
                // Delivery, not completion: rendezvous with WAIT.
                Ok(Reply::Line(format!("CANCELLED id={id}")))
            } else {
                // Lost the race against the job's own completion.
                anyhow::bail!("job {id} already terminal")
            }
        }
        "WAIT" => {
            // Blocking is fine: the service runs one thread per
            // connection and compute happens on the coordinator pool.
            // The block is a bounded-probe loop rather than one
            // indefinite wait so a client hang-up releases this thread
            // (and its waiter registration) instead of pinning them
            // until the job ends.
            let id: u64 = kv.get("id").context("missing id=")?.parse()?;
            coord.metrics().gauge_add("service_waiters", 1);
            let _waiter = WaiterGuard(coord.metrics());
            loop {
                match coord.wait_for(id, Duration::from_millis(100)) {
                    WaitOutcome::Unknown => anyhow::bail!("unknown job {id}"),
                    WaitOutcome::Terminal(state) => {
                        return Ok(Reply::Line(format!(
                            "STATE id={id} state={}",
                            state_name(&state)
                        )));
                    }
                    WaitOutcome::Pending => {
                        if peer_gone(stream) {
                            return Ok(Reply::Disconnect);
                        }
                    }
                }
            }
        }
        "RESULT" => {
            let id: u64 = kv.get("id").context("missing id=")?.parse()?;
            let state = coord.state(id);
            if let Some(JobState::Failed(msg)) = state {
                anyhow::bail!("job {id} failed: {msg}");
            }
            let r = coord.result(id).with_context(|| format!("job {id} has no result yet"))?;
            // The result exists, so the job is terminal — but re-read
            // defensively for the wire field.
            let state = state.map_or("done", |s| state_name(&s));
            let ta = r.mean_replica_seconds();
            let (pa, tts) = match kv.get("target").map(|v| v.parse::<i64>()).transpose()? {
                Some(t) => {
                    let est = r.successes(t);
                    let tts = crate::tts::tts99(ta, est);
                    (est.p_a(), tts)
                }
                None => (f64::NAN, f64::NAN),
            };
            // Portfolio jobs append the race outcome: the winner and
            // one `c<i>=<name>:<energy>:<attempts>:<wall_ms>` field per
            // contender; any job that pinned shard lanes appends the
            // total (docs/PROTOCOL.md).
            let mut extra = String::new();
            if let Some(p) = &r.portfolio {
                extra.push_str(&format!(" winner={}", p.winner));
                for (rep, name) in r.replicas.iter().zip(&p.contenders) {
                    extra.push_str(&format!(
                        " c{}={}:{}:{}:{:.3}",
                        rep.replica,
                        name,
                        rep.best_energy,
                        rep.flips,
                        rep.wall.as_secs_f64() * 1e3,
                    ));
                }
            }
            let pinned: usize = r.replicas.iter().map(|x| x.pinned_lanes).sum();
            if pinned > 0 {
                extra.push_str(&format!(" pinned_lanes={pinned}"));
            }
            // Likewise for NUMA-local row copies: jobs run with
            // local_rows=1 report the total resident footprint.
            let local: usize = r.replicas.iter().map(|x| x.local_row_bytes).sum();
            if local > 0 {
                extra.push_str(&format!(" local_row_bytes={local}"));
            }
            Ok(Reply::Line(format!(
                "RESULT id={id} label={} state={state} completed={} best={} replicas={} \
                 pa={pa:.3} ta_ms={:.3} tts99_ms={:.3}{extra}",
                r.label,
                r.completed,
                r.best_energy(),
                r.replicas.len(),
                ta * 1e3,
                tts * 1e3,
            )))
        }
        other => anyhow::bail!("unknown command '{other}'"),
    }
}

/// Build a Max-Cut model from an instance name: a Table I id, `K2000`,
/// or `er:<n>:<m>` for an ad-hoc Erdős–Rényi ±1 instance.
pub fn build_instance(name: &str, seed: u64) -> Result<(String, crate::ising::IsingModel)> {
    if let Some(rest) = name.strip_prefix("er:") {
        let (n, m) = rest.split_once(':').context("er:<n>:<m>")?;
        let n: usize = n.parse()?;
        let m: usize = m.parse()?;
        let g = generators::erdos_renyi(n, m, &[-1, 1], &StatelessRng::new(seed));
        return Ok((format!("er:{n}:{m}"), crate::problems::MaxCut::new(g).model().clone()));
    }
    for id in gset::GsetId::ALL {
        if id.name().eq_ignore_ascii_case(name) {
            let g = gset::load_or_synthesize(id, None, seed);
            return Ok((id.name().to_string(), crate::problems::MaxCut::new(g).model().clone()));
        }
    }
    anyhow::bail!("unknown instance '{name}' (Gset id, K2000 or er:<n>:<m>)")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    fn roundtrip(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{req}").unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        line.trim().to_string()
    }

    fn start() -> std::net::SocketAddr {
        let coord = Coordinator::start(2);
        Service::bind(coord, "127.0.0.1:0").unwrap().serve_in_background()
    }

    #[test]
    fn ping_pong() {
        let addr = start();
        assert_eq!(roundtrip(addr, "PING"), "PONG");
    }

    #[test]
    fn solve_status_result_flow() {
        let addr = start();
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "SOLVE instance=er:32:100 mode=rwa steps=500 replicas=3 seed=5").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        // Block on the condvar-backed WAIT (no STATUS poll loop), then
        // fetch the result on the same connection.
        writeln!(s, "WAIT id={id}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STATE id={id} state=done"));
        writeln!(s, "RESULT id={id}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("replicas=3"), "{line}");
        assert!(line.contains("best=-"), "should find a negative energy: {line}");
    }

    #[test]
    fn errors_are_reported() {
        let addr = start();
        assert!(roundtrip(addr, "BOGUS").starts_with("ERR"));
        assert!(roundtrip(addr, "STATUS id=42").starts_with("ERR"));
        assert!(roundtrip(addr, "WAIT id=42").starts_with("ERR"));
        assert!(roundtrip(addr, "SOLVE instance=nope").starts_with("ERR"));
        assert!(roundtrip(addr, "SOLVE instance=er:8:10 selector=bogus").starts_with("ERR"));
        assert!(roundtrip(addr, "SOLVE instance=er:8:10 shards=bogus").starts_with("ERR"));
        let over = roundtrip(addr, "SOLVE instance=er:8:10 shards=65");
        assert!(over.starts_with("ERR shards must be <= 64"), "{over}");
    }

    /// `shards=` flows end to end: the job runs on the async sharded
    /// engine and produces a normal RESULT line.
    #[test]
    fn solve_with_shards_flows() {
        let addr = start();
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "SOLVE instance=er:96:300 mode=rwa steps=4000 replicas=2 seed=3 shards=3")
            .unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        writeln!(s, "WAIT id={id}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STATE id={id} state=done"));
        writeln!(s, "RESULT id={id}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("replicas=2"), "{line}");
    }

    /// `portfolio=` flows end to end: the job races its roster, WAIT
    /// completes, and RESULT carries `winner=` plus one
    /// `c<i>=<name>:<energy>:<attempts>:<wall_ms>` field per contender.
    #[test]
    fn solve_with_portfolio_flows() {
        let addr = start();
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        writeln!(s, "SOLVE instance=er:32:100 steps=2000 seed=3 portfolio=rsa,neal,tabu").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        line.clear();
        writeln!(s, "WAIT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STATE id={id} state=done"));
        line.clear();
        writeln!(s, "RESULT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("replicas=3"), "{line}");
        assert!(line.contains(" winner="), "{line}");
        for c in ["c0=rsa:", "c1=neal:", "c2=tabu:"] {
            assert!(line.contains(c), "missing {c} in {line}");
        }
        // The two portfolio= ERR forms, verbatim (docs/PROTOCOL.md).
        assert_eq!(
            roundtrip(addr, "SOLVE instance=er:8:10 portfolio="),
            "ERR portfolio must be auto|full|<name>[,<name>...]"
        );
        assert_eq!(
            roundtrip(addr, "SOLVE instance=er:8:10 portfolio=bogus"),
            format!(
                "ERR unknown portfolio contender 'bogus' (expected {})",
                crate::portfolio::KNOWN_CONTENDERS.join("|")
            )
        );
    }

    /// `PUT format=qubo` stores a converted Ising model that solves by
    /// hash like any other; bad formats and malformed entries ERR.
    #[test]
    fn put_qubo_format_flow() {
        let addr = start();
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        write!(s, "PUT n=3 format=qubo\n0 0 -3\n1 1 2\n0 1 4\n1 2 -5\nEND\n").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("STORED model="), "{line}");
        let hash = line.trim().rsplit('=').next().unwrap().to_string();
        line.clear();
        writeln!(s, "SOLVE model={hash} steps=300 replicas=2 seed=3").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        line.clear();
        writeln!(s, "WAIT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STATE id={id} state=done"));
        line.clear();
        write!(s, "PUT n=3 format=wat\n0 0 1\nEND\n").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR format must be ising|qubo"), "{line}");
        line.clear();
        write!(s, "PUT n=3 format=qubo\n0 nope 1\nEND\n").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR malformed PUT body line"), "{line}");
    }

    /// The saturation ERR form: a coordinator with a tiny replica cap
    /// and rejection enabled refuses the second SOLVE.
    #[test]
    fn saturated_solve_is_rejected_with_err() {
        let coord = Coordinator::start_with(crate::coordinator::CoordinatorConfig {
            workers: 1,
            max_inflight_replicas: 2,
            reject_when_saturated: true,
            ..Default::default()
        });
        let addr = Service::bind(coord, "127.0.0.1:0").unwrap().serve_in_background();
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        writeln!(s, "SOLVE instance=er:64:256 steps=200000 replicas=2 seed=1").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        line.clear();
        writeln!(s, "SOLVE instance=er:16:40 steps=100 replicas=2 seed=2").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR saturated"), "{line}");
        // Drain, then admission recovers.
        writeln!(s, "WAIT id={id}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        line.clear();
        writeln!(s, "SOLVE instance=er:16:40 steps=100 replicas=2 seed=2").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "after drain: {line}");
    }

    /// A failed job (poisoned instance) is observable end to end:
    /// WAIT reports `state=failed` and RESULT carries the message.
    #[test]
    fn failed_job_reports_over_the_wire() {
        let coord = Coordinator::start(1);
        let mut bad_spec = {
            let (label, model) = build_instance("er:8:10", 1).unwrap();
            JobSpec {
                model: Arc::new(model),
                label,
                mode: Mode::RouletteWheel,
                selector: SelectorKind::Fenwick,
                schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
                steps: 100,
                replicas: 1,
                seed: 1,
                target_energy: None,
                shards: 1,
                pin_lanes: false,
                local_rows: false,
                budget_ms: 0,
                max_retries: 0,
                backend: Backend::Native,
                portfolio: None,
            }
        };
        bad_spec.model = Arc::new(crate::ising::IsingModel::zeros(0));
        let id = coord.submit(bad_spec);
        let addr = Service::bind(coord, "127.0.0.1:0").unwrap().serve_in_background();
        let wait = roundtrip(addr, &format!("WAIT id={id}"));
        assert_eq!(wait, format!("STATE id={id} state=failed"));
        let status = roundtrip(addr, &format!("STATUS id={id}"));
        assert_eq!(status, format!("STATE id={id} state=failed"));
        let result = roundtrip(addr, &format!("RESULT id={id}"));
        assert!(result.starts_with(&format!("ERR job {id} failed:")), "{result}");
    }

    #[test]
    fn quit_closes() {
        let addr = start();
        assert_eq!(roundtrip(addr, "QUIT"), "BYE");
    }

    /// PUT → STORED, dedup across upload order, REGISTRY stats, then
    /// SOLVE by hash end to end.
    #[test]
    fn put_registry_solve_by_hash_flow() {
        let addr = start();
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        write!(s, "PUT n=6\n0 1 2\n1 2 -1\nH 0 1\nEND\n").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("STORED model="), "{line}");
        let hash = line.trim().rsplit('=').next().unwrap().to_string();
        assert_eq!(hash.len(), 32, "{hash}");
        // Same body in a different line order → same canonical hash,
        // deduplicated to one entry.
        line.clear();
        write!(s, "PUT n=6\nH 0 1\n1 2 -1\n0 1 2\nEND\n").unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STORED model={hash}"));
        line.clear();
        writeln!(s, "REGISTRY").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("REGISTRY entries=1 "), "{line}");
        assert!(line.contains("dedup=1"), "{line}");
        line.clear();
        writeln!(s, "SOLVE model={hash} steps=300 replicas=2 seed=3").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        line.clear();
        writeln!(s, "WAIT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STATE id={id} state=done"));
        line.clear();
        writeln!(s, "RESULT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.contains(&format!("label=model:{}", &hash[..12])), "{line}");
    }

    /// The same protocol over a router-backed service: the generic
    /// front-end serves a dispatch tier without any wire change.
    #[test]
    fn router_backed_service_speaks_the_same_protocol() {
        let router = crate::coordinator::Router::start(2, 1);
        let addr = Service::bind(router, "127.0.0.1:0").unwrap().serve_in_background();
        assert_eq!(roundtrip(addr, "PING"), "PONG");
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        writeln!(s, "SOLVE instance=er:16:40 steps=300 replicas=2 seed=2").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        line.clear();
        writeln!(s, "WAIT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STATE id={id} state=done"));
        line.clear();
        writeln!(s, "RESULT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("replicas=2"), "{line}");
    }

    /// CANCEL end to end: SOLVE a job that would run for minutes,
    /// CANCEL it, WAIT reports `state=cancelled`, RESULT carries
    /// `completed=false` — all promptly. Plus the CANCEL ERR forms.
    #[test]
    fn cancel_flows_end_to_end() {
        let addr = start();
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        writeln!(s, "SOLVE instance=er:64:256 steps=2000000000 replicas=2 seed=9").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        line.clear();
        writeln!(s, "CANCEL id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("CANCELLED id={id}"));
        line.clear();
        let t0 = std::time::Instant::now();
        writeln!(s, "WAIT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STATE id={id} state=cancelled"));
        assert!(t0.elapsed() < std::time::Duration::from_secs(30), "cancel must be prompt");
        line.clear();
        writeln!(s, "RESULT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("state=cancelled"), "{line}");
        assert!(line.contains("completed=false"), "{line}");
        // ERR forms: unknown id, then already-terminal.
        assert!(roundtrip(addr, "CANCEL id=424242").starts_with("ERR unknown job"));
        let second = roundtrip(addr, &format!("CANCEL id={id}"));
        assert!(second.starts_with(&format!("ERR job {id} already terminal")), "{second}");
    }

    /// `budget_ms=` end to end: an oversized SOLVE with a 50 ms budget
    /// comes back `state=timed_out` with a valid best-so-far partial
    /// result, well within the acceptance envelope.
    #[test]
    fn budget_ms_flows_end_to_end() {
        let addr = start();
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        writeln!(s, "SOLVE instance=er:128:512 steps=2000000000 replicas=2 seed=4 budget_ms=50")
            .unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        line.clear();
        writeln!(s, "WAIT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STATE id={id} state=timed_out"));
        line.clear();
        writeln!(s, "RESULT id={id}").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("state=timed_out"), "{line}");
        assert!(line.contains("completed=false"), "{line}");
        assert!(line.contains("replicas=2"), "{line}");
        assert!(line.contains("best=-"), "partial result still carries an incumbent: {line}");
        // Malformed budgets are strict ERRs like every other field.
        assert!(roundtrip(addr, "SOLVE instance=er:8:10 budget_ms=soon").starts_with("ERR"));
        assert!(roundtrip(addr, "SOLVE instance=er:8:10 max_retries=lots").starts_with("ERR"));
    }
}
