//! Line-oriented TCP service over the coordinator (the "host software"
//! face of the Ising machine).
//!
//! **The full wire protocol is specified in `docs/PROTOCOL.md`** —
//! every command (`PING`/`SOLVE`/`STATUS`/`WAIT`/`RESULT`/`METRICS`/
//! `QUIT`), every `ERR` form, and the `selector=`/`schedule=` syntax.
//! In one breath: one request per line, one reply per line (`METRICS`
//! is multi-line, terminated by `END`); `SOLVE` returns `JOB id=<u64>`
//! immediately and the job runs asynchronously on the coordinator;
//! `WAIT id=` blocks (condvar-notified, no client poll loop) until the
//! job is terminal; errors reply `ERR <message>`.
//!
//! One thread per connection; compute runs on the coordinator pool
//! (overlapping dispatch by default, so many clients' jobs execute
//! concurrently), which means slow jobs never block the listener — the
//! load harness in `rust/tests/service_load.rs` drives 100+ concurrent
//! clients through this path.

use super::{Backend, Coordinator, JobSpec, JobState};
use crate::engine::{Mode, Schedule, SelectorKind};
use crate::graph::{generators, gset};
use crate::rng::StatelessRng;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// The TCP service.
pub struct Service {
    coordinator: Coordinator,
    listener: TcpListener,
}

impl Service {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(coordinator: Coordinator, addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Self { coordinator, listener })
    }

    /// The bound address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Serve forever (one thread per connection).
    pub fn serve(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            let stream = stream?;
            let coord = self.coordinator.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(coord, stream);
            });
        }
        Ok(())
    }

    /// Serve in a background thread, returning immediately.
    pub fn serve_in_background(self) -> std::net::SocketAddr {
        let addr = self.addr();
        std::thread::spawn(move || {
            let _ = self.serve();
        });
        addr
    }
}

fn handle_connection(coord: Coordinator, stream: TcpStream) -> Result<()> {
    let peer_read = stream.try_clone()?;
    let mut reader = BufReader::new(peer_read);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // peer closed
        }
        let reply = match handle_line(&coord, line.trim()) {
            Ok(Reply::Line(s)) => s,
            Ok(Reply::Quit) => {
                writeln!(writer, "BYE")?;
                return Ok(());
            }
            Err(e) => format!("ERR {e}"),
        };
        writeln!(writer, "{reply}")?;
        writer.flush()?;
        coord.metrics.inc("service_requests");
    }
}

enum Reply {
    Line(String),
    Quit,
}

fn handle_line(coord: &Coordinator, line: &str) -> Result<Reply> {
    let mut parts = line.split_whitespace();
    let cmd = parts.next().unwrap_or("");
    let kv: HashMap<&str, &str> = parts.filter_map(|t| t.split_once('=')).collect();
    match cmd {
        "PING" => Ok(Reply::Line("PONG".into())),
        "QUIT" => Ok(Reply::Quit),
        "METRICS" => Ok(Reply::Line(format!("{}END", coord.metrics.render()))),
        "SOLVE" => {
            let instance = kv.get("instance").context("missing instance=")?;
            let mode = Mode::parse(kv.get("mode").copied().unwrap_or("rwa"))?;
            let selector = SelectorKind::parse(kv.get("selector").copied().unwrap_or("fenwick"))?;
            let steps: u64 = kv.get("steps").copied().unwrap_or("100000").parse()?;
            let replicas: u32 = kv.get("replicas").copied().unwrap_or("8").parse()?;
            let seed: u64 = kv.get("seed").copied().unwrap_or("1").parse()?;
            let target = kv.get("target").map(|v| v.parse::<i64>()).transpose()?;
            let schedule = match kv.get("schedule") {
                Some(s) => Schedule::parse(s)?,
                None => Schedule::Geometric { t0: 8.0, t1: 0.05 },
            };
            let (label, model) = build_instance(instance, seed)?;
            let id = coord.submit(JobSpec {
                model: Arc::new(model),
                label,
                mode,
                selector,
                schedule,
                steps,
                replicas,
                seed,
                target_energy: target,
                backend: Backend::Native,
            });
            Ok(Reply::Line(format!("JOB id={id}")))
        }
        "STATUS" => {
            let id: u64 = kv.get("id").context("missing id=")?.parse()?;
            let state = match coord.state(id) {
                None => anyhow::bail!("unknown job {id}"),
                Some(JobState::Queued) => "queued",
                Some(JobState::Running) => "running",
                Some(JobState::Done) => "done",
                Some(JobState::Failed(_)) => "failed",
            };
            Ok(Reply::Line(format!("STATE id={id} state={state}")))
        }
        "WAIT" => {
            // Blocking is fine: the service runs one thread per
            // connection and compute happens on the coordinator pool.
            let id: u64 = kv.get("id").context("missing id=")?.parse()?;
            match coord.wait(id) {
                Some(_) => Ok(Reply::Line(format!("STATE id={id} state=done"))),
                None => match coord.state(id) {
                    None => anyhow::bail!("unknown job {id}"),
                    _ => Ok(Reply::Line(format!("STATE id={id} state=failed"))),
                },
            }
        }
        "RESULT" => {
            let id: u64 = kv.get("id").context("missing id=")?.parse()?;
            let r = coord.result(id).with_context(|| format!("job {id} has no result yet"))?;
            let ta = r.mean_replica_seconds();
            let (pa, tts) = match kv.get("target").map(|v| v.parse::<i64>()).transpose()? {
                Some(t) => {
                    let est = r.successes(t);
                    let tts = crate::tts::tts99(ta, est);
                    (est.p_a(), tts)
                }
                None => (f64::NAN, f64::NAN),
            };
            Ok(Reply::Line(format!(
                "RESULT id={id} label={} best={} replicas={} pa={pa:.3} ta_ms={:.3} tts99_ms={:.3}",
                r.label,
                r.best_energy(),
                r.replicas.len(),
                ta * 1e3,
                tts * 1e3,
            )))
        }
        other => anyhow::bail!("unknown command '{other}'"),
    }
}

/// Build a Max-Cut model from an instance name: a Table I id, `K2000`,
/// or `er:<n>:<m>` for an ad-hoc Erdős–Rényi ±1 instance.
pub fn build_instance(name: &str, seed: u64) -> Result<(String, crate::ising::IsingModel)> {
    if let Some(rest) = name.strip_prefix("er:") {
        let (n, m) = rest.split_once(':').context("er:<n>:<m>")?;
        let n: usize = n.parse()?;
        let m: usize = m.parse()?;
        let g = generators::erdos_renyi(n, m, &[-1, 1], &StatelessRng::new(seed));
        return Ok((format!("er:{n}:{m}"), crate::problems::MaxCut::new(g).model().clone()));
    }
    for id in gset::GsetId::ALL {
        if id.name().eq_ignore_ascii_case(name) {
            let g = gset::load_or_synthesize(id, None, seed);
            return Ok((id.name().to_string(), crate::problems::MaxCut::new(g).model().clone()));
        }
    }
    anyhow::bail!("unknown instance '{name}' (Gset id, K2000 or er:<n>:<m>)")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    fn roundtrip(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{req}").unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        line.trim().to_string()
    }

    fn start() -> std::net::SocketAddr {
        let coord = Coordinator::start(2);
        Service::bind(coord, "127.0.0.1:0").unwrap().serve_in_background()
    }

    #[test]
    fn ping_pong() {
        let addr = start();
        assert_eq!(roundtrip(addr, "PING"), "PONG");
    }

    #[test]
    fn solve_status_result_flow() {
        let addr = start();
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "SOLVE instance=er:32:100 mode=rwa steps=500 replicas=3 seed=5").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("JOB id="), "{line}");
        let id: u64 = line.trim().rsplit('=').next().unwrap().parse().unwrap();
        // Block on the condvar-backed WAIT (no STATUS poll loop), then
        // fetch the result on the same connection.
        writeln!(s, "WAIT id={id}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), format!("STATE id={id} state=done"));
        writeln!(s, "RESULT id={id}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("replicas=3"), "{line}");
        assert!(line.contains("best=-"), "should find a negative energy: {line}");
    }

    #[test]
    fn errors_are_reported() {
        let addr = start();
        assert!(roundtrip(addr, "BOGUS").starts_with("ERR"));
        assert!(roundtrip(addr, "STATUS id=42").starts_with("ERR"));
        assert!(roundtrip(addr, "WAIT id=42").starts_with("ERR"));
        assert!(roundtrip(addr, "SOLVE instance=nope").starts_with("ERR"));
        assert!(roundtrip(addr, "SOLVE instance=er:8:10 selector=bogus").starts_with("ERR"));
    }

    #[test]
    fn quit_closes() {
        let addr = start();
        assert_eq!(roundtrip(addr, "QUIT"), "BYE");
    }
}
