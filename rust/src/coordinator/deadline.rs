//! The deadline wheel: one background timer thread that trips job
//! [`StopToken`]s when their `budget_ms` deadlines (or the shutdown
//! grace period) elapse.
//!
//! A min-heap of `(when, token, cause)` entries, drained by the
//! dedicated "snowball-deadline" thread the coordinator spawns at
//! startup. The thread sleeps exactly until the earliest pending
//! deadline (condvar with timeout, re-woken on every
//! [`schedule`](DeadlineWheel::schedule)), trips everything due, and
//! parks again — no polling interval, so deadline latency is bounded
//! by OS scheduling, not a tick.
//!
//! Cancellation is **lazy**: entries for jobs that finished early are
//! left in the heap and simply trip a token nobody reads anymore —
//! [`StopToken::trip`] on a job that already reached a terminal state
//! is a no-op by construction (first-cause-wins, and the replicas that
//! would observe it are gone). This keeps the hot path (`schedule`,
//! job completion) free of heap surgery.

use crate::stop::{StopCause, StopToken};
use std::collections::BinaryHeap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One pending deadline.
struct Entry {
    when: Instant,
    /// Tie-break so the heap order is total without comparing tokens.
    seq: u64,
    cause: StopCause,
    token: Arc<StopToken>,
}

// `BinaryHeap` is a max-heap; reverse the comparison so the EARLIEST
// deadline surfaces at the top. Only `when`/`seq` participate —
// tokens are payload, not identity.
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.when == other.when && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.when.cmp(&self.when).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct WheelState {
    heap: BinaryHeap<Entry>,
    closed: bool,
    next_seq: u64,
}

/// See the module docs.
pub struct DeadlineWheel {
    state: Mutex<WheelState>,
    cv: Condvar,
}

impl DeadlineWheel {
    pub fn new() -> Self {
        Self {
            state: Mutex::new(WheelState { heap: BinaryHeap::new(), closed: false, next_seq: 0 }),
            cv: Condvar::new(),
        }
    }

    /// Arrange for `token` to be tripped with `cause` at `when`.
    /// Past-due instants trip on the wheel thread's next pass
    /// (immediately — scheduling always wakes it).
    pub fn schedule(&self, when: Instant, cause: StopCause, token: Arc<StopToken>) {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            // Wheel thread gone (coordinator shut down): honor the
            // contract inline so no deadline is silently dropped.
            drop(st);
            token.trip(cause);
            return;
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.heap.push(Entry { when, seq, cause, token });
        self.cv.notify_one();
    }

    /// Stop the wheel thread. Entries still pending trip immediately
    /// (a shutdown must not leave replicas waiting on a deadline that
    /// will never fire).
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        for e in st.heap.drain() {
            e.token.trip(e.cause);
        }
        self.cv.notify_all();
    }

    /// The wheel thread body: trip everything due, sleep until the
    /// next deadline (or forever, until a `schedule`/`close` wakes us).
    pub fn run(&self) {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return;
            }
            let now = Instant::now();
            while st.heap.peek().is_some_and(|e| e.when <= now) {
                let e = st.heap.pop().unwrap();
                e.token.trip(e.cause);
            }
            match st.heap.peek().map(|e| e.when) {
                Some(when) => {
                    let timeout = when.saturating_duration_since(now);
                    let (guard, _) = self.cv.wait_timeout(st, timeout).unwrap();
                    st = guard;
                }
                None => st = self.cv.wait(st).unwrap(),
            }
        }
    }
}

impl Default for DeadlineWheel {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn spawn_wheel() -> (Arc<DeadlineWheel>, std::thread::JoinHandle<()>) {
        let wheel = Arc::new(DeadlineWheel::new());
        let body = wheel.clone();
        let h = std::thread::spawn(move || body.run());
        (wheel, h)
    }

    #[test]
    fn due_entries_trip_in_deadline_order() {
        let (wheel, h) = spawn_wheel();
        let (a, b) = (Arc::new(StopToken::new()), Arc::new(StopToken::new()));
        let now = Instant::now();
        // Scheduled out of order; the later one must not gate the earlier.
        wheel.schedule(now + Duration::from_millis(40), StopCause::Deadline, b.clone());
        wheel.schedule(now + Duration::from_millis(5), StopCause::Deadline, a.clone());
        let t0 = Instant::now();
        while a.get().is_none() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(a.get(), Some(StopCause::Deadline));
        while b.get().is_none() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(b.get(), Some(StopCause::Deadline));
        wheel.close();
        h.join().unwrap();
    }

    #[test]
    fn lazy_cancellation_is_harmless_and_past_due_fires() {
        let (wheel, h) = spawn_wheel();
        // A token whose job "already finished": tripping it later must
        // not disturb anything (first-cause-wins keeps the label).
        let done = Arc::new(StopToken::new());
        done.trip(StopCause::Cancel);
        wheel.schedule(Instant::now() + Duration::from_millis(1), StopCause::Deadline, done.clone());
        // A deadline already in the past fires on the next pass.
        let late = Arc::new(StopToken::new());
        wheel.schedule(Instant::now(), StopCause::Deadline, late.clone());
        let t0 = Instant::now();
        while late.get().is_none() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(late.get(), Some(StopCause::Deadline));
        assert_eq!(done.get(), Some(StopCause::Cancel), "lazy entry must not relabel");
        wheel.close();
        h.join().unwrap();
    }

    #[test]
    fn close_trips_pending_and_stops_the_thread() {
        let (wheel, h) = spawn_wheel();
        let far = Arc::new(StopToken::new());
        wheel.schedule(Instant::now() + Duration::from_secs(3600), StopCause::Shutdown, far.clone());
        wheel.close();
        h.join().unwrap(); // must return promptly despite the 1h entry
        assert_eq!(far.get(), Some(StopCause::Shutdown), "close must not drop deadlines");
        // Post-close schedules trip inline.
        let after = Arc::new(StopToken::new());
        wheel.schedule(Instant::now() + Duration::from_secs(3600), StopCause::Deadline, after.clone());
        assert_eq!(after.get(), Some(StopCause::Deadline));
    }
}
