//! Job specifications and results for the coordinator.
//!
//! A [`JobSpec`] is the unit clients submit ([`Coordinator::submit`]);
//! the dispatcher expands it into `replicas` independent work items,
//! each seeded `StatelessRng::new(seed).child(replica)`, and folds the
//! per-replica outcomes back into one [`JobResult`]. Because the seed
//! derivation is a pure function of `(seed, replica)`, the result is
//! bit-identical however the work items are scheduled — see
//! `docs/ARCHITECTURE.md` for the determinism contract.
//!
//! [`Coordinator::submit`]: super::Coordinator::submit

use crate::engine::{Mode, Schedule, SelectorKind};
use crate::ising::IsingModel;
use std::sync::Arc;

/// A request to anneal one instance with R independent replicas.
#[derive(Clone)]
pub struct JobSpec {
    /// The Ising instance (shared, read-only).
    pub model: Arc<IsingModel>,
    /// Human-readable instance label (e.g. "K2000").
    pub label: String,
    pub mode: Mode,
    /// Mode II selection implementation (bit-identical either way).
    pub selector: SelectorKind,
    pub schedule: Schedule,
    /// Engine steps per replica.
    pub steps: u64,
    /// Independent replicas (each gets a decorrelated child seed).
    pub replicas: u32,
    pub seed: u64,
    /// Success threshold: a replica succeeds if `best_energy <= target`.
    pub target_energy: Option<i64>,
    /// Within-instance shard lanes per replica: `1` = the classic
    /// single-lane engine (bit-reproducible, the default), `>1` = run
    /// each replica as that many asynchronous shard lanes
    /// ([`crate::engine::ShardedEngine`]; faster on large instances,
    /// NOT bit-reproducible across runs), `0` = let the scheduler pick
    /// by instance size ([`crate::engine::shard::plan_parallelism`]).
    pub shards: u32,
    /// Pin shard lane threads round-robin to cores (async sharded
    /// replicas only; Linux `sched_setaffinity`, no-op elsewhere — see
    /// [`crate::engine::shard::affinity`]).
    pub pin_lanes: bool,
    /// Execution backend for this job.
    pub backend: Backend,
}

/// Which execution engine runs the replicas.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Native Rust engine (headline numbers).
    Native,
    /// AOT XLA artifact through the PJRT runtime (roulette mode only).
    Xla,
}

/// Per-replica outcome.
#[derive(Clone, Debug)]
pub struct ReplicaResult {
    pub replica: u32,
    pub best_energy: i64,
    pub flips: u64,
    pub wall: std::time::Duration,
}

/// Aggregated job outcome.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub job_id: u64,
    pub label: String,
    pub replicas: Vec<ReplicaResult>,
    pub wall: std::time::Duration,
}

impl JobResult {
    /// Best energy across replicas.
    pub fn best_energy(&self) -> i64 {
        self.replicas.iter().map(|r| r.best_energy).min().unwrap_or(i64::MAX)
    }

    /// Success estimate against a target energy.
    pub fn successes(&self, target: i64) -> crate::tts::SuccessEstimate {
        crate::tts::SuccessEstimate {
            runs: self.replicas.len(),
            successes: self.replicas.iter().filter(|r| r.best_energy <= target).count(),
        }
    }

    /// Mean per-replica wall time in seconds (the `t_a` of Eq. 32).
    pub fn mean_replica_seconds(&self) -> f64 {
        if self.replicas.is_empty() {
            return 0.0;
        }
        self.replicas.iter().map(|r| r.wall.as_secs_f64()).sum::<f64>() / self.replicas.len() as f64
    }
}

/// Lifecycle of a submitted job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed(String),
}
