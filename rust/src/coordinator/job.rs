//! Job specifications and results for the coordinator.
//!
//! A [`JobSpec`] is the unit clients submit ([`Coordinator::submit`]);
//! the dispatcher expands it into `replicas` independent work items,
//! each seeded `StatelessRng::new(seed).child(replica)`, and folds the
//! per-replica outcomes back into one [`JobResult`]. Because the seed
//! derivation is a pure function of `(seed, replica)`, the result is
//! bit-identical however the work items are scheduled — see
//! `docs/ARCHITECTURE.md` for the determinism contract.
//!
//! [`Coordinator::submit`]: super::Coordinator::submit

use crate::engine::{Mode, Schedule, SelectorKind};
use crate::ising::IsingModel;
use std::sync::Arc;

/// A request to anneal one instance with R independent replicas.
#[derive(Clone)]
pub struct JobSpec {
    /// The Ising instance (shared, read-only).
    pub model: Arc<IsingModel>,
    /// Human-readable instance label (e.g. "K2000").
    pub label: String,
    pub mode: Mode,
    /// Mode II selection implementation (bit-identical either way).
    pub selector: SelectorKind,
    pub schedule: Schedule,
    /// Engine steps per replica.
    pub steps: u64,
    /// Independent replicas (each gets a decorrelated child seed).
    pub replicas: u32,
    pub seed: u64,
    /// Success threshold: a replica succeeds if `best_energy <= target`.
    pub target_energy: Option<i64>,
    /// Within-instance shard lanes per replica: `1` = the classic
    /// single-lane engine (bit-reproducible, the default), `>1` = run
    /// each replica as that many asynchronous shard lanes
    /// ([`crate::engine::ShardedEngine`]; faster on large instances,
    /// NOT bit-reproducible across runs), `0` = let the scheduler pick
    /// by instance size ([`crate::engine::shard::plan_parallelism`]).
    pub shards: u32,
    /// Pin shard lane threads round-robin to cores (async sharded
    /// replicas only; Linux `sched_setaffinity`, no-op elsewhere — see
    /// [`crate::engine::shard::affinity`]).
    pub pin_lanes: bool,
    /// Materialize per-lane coupling-row copies on the lanes' own
    /// (pinned) threads — first-touch NUMA placement of the hot row
    /// walks (async sharded replicas only, pair with `pin_lanes`; see
    /// [`crate::engine::shard::placement`]). Bit-identical results;
    /// footprint surfaces as [`ReplicaResult::local_row_bytes`].
    pub local_rows: bool,
    /// Wall-clock budget in milliseconds (`0` = none). When it elapses
    /// the coordinator's deadline wheel trips the job's stop token; the
    /// replicas return their best-so-far incumbents and the job lands
    /// in [`JobState::TimedOut`] with a partial [`JobResult`]
    /// (`completed == false`).
    pub budget_ms: u64,
    /// How many times a panicking replica is retried (`0` = fail the
    /// job on the first panic, the legacy behaviour). Retries resume
    /// from the replica's last journaled checkpoint with exponential
    /// backoff and are bit-identical to an uninterrupted run — see
    /// docs/ARCHITECTURE.md § Job lifecycle & fault tolerance.
    pub max_retries: u32,
    /// Execution backend for this job.
    pub backend: Backend,
    /// `Some` turns the job into a solver *race*: instead of `replicas`
    /// identical engines, the roster's contenders (Snowball
    /// configurations and baseline heuristics) run concurrently on the
    /// same instance under one budget, first-to-target wins, and every
    /// loser is stop-tripped ([`crate::portfolio`]). Each contender
    /// reports as one [`ReplicaResult`] (indexed in roster order);
    /// `replicas` is normalized to 1 at admission and `mode` /
    /// `selector` / `shards` only apply to contenders that use them.
    pub portfolio: Option<crate::portfolio::PortfolioSpec>,
}

impl JobSpec {
    /// Bytes the job's model materializes
    /// ([`IsingModel::approx_bytes`]) — what the registry bench and the
    /// dispatch tier account when comparing inline (one copy per job)
    /// against by-hash (one shared copy) submission.
    pub fn model_bytes(&self) -> usize {
        self.model.approx_bytes()
    }
}

/// Which execution engine runs the replicas.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Native Rust engine (headline numbers).
    Native,
    /// AOT XLA artifact through the PJRT runtime (roulette mode only).
    Xla,
}

/// Per-replica outcome. For portfolio jobs each roster contender is one
/// "replica" (in roster order), `flips` counts its attempts, and
/// `stopped` records whether it lost the race.
#[derive(Clone, Debug)]
pub struct ReplicaResult {
    pub replica: u32,
    pub best_energy: i64,
    pub flips: u64,
    pub wall: std::time::Duration,
    /// Preempted before running its full budget (race loser, cancel,
    /// deadline, shutdown).
    pub stopped: bool,
    /// Shard lane threads this replica pinned to cores (async sharded
    /// engine with `pin_lanes` only; 0 otherwise). Surfaced as the
    /// `pinned_lanes` METRICS gauge and RESULT field.
    pub pinned_lanes: usize,
    /// Bytes of lane-local coupling-row copies this replica's shard
    /// lanes materialized (async sharded engine with `local_rows` only;
    /// 0 otherwise). Surfaced as the `local_row_bytes` METRICS gauge
    /// and RESULT field.
    pub local_row_bytes: usize,
}

/// Aggregated job outcome.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub job_id: u64,
    pub label: String,
    pub replicas: Vec<ReplicaResult>,
    pub wall: std::time::Duration,
    /// `true` when every replica ran its full step budget; `false` for
    /// a preempted job (cancel / deadline / shutdown), whose replica
    /// results are the best-so-far incumbents at preemption time. A
    /// cancelled job preempted before dispatch has `replicas` empty.
    pub completed: bool,
    /// Race outcome for portfolio jobs (`None` for plain jobs and for
    /// portfolio jobs preempted before dispatch).
    pub portfolio: Option<PortfolioOutcome>,
}

/// Which contender won a portfolio race and who it beat.
#[derive(Clone, Debug)]
pub struct PortfolioOutcome {
    /// Winning contender name (lowest energy; roster order breaks
    /// ties). Indexes into `JobResult::replicas` via `contenders`.
    pub winner: String,
    /// Roster names in replica order.
    pub contenders: Vec<String>,
}

impl JobResult {
    /// Best energy across replicas.
    pub fn best_energy(&self) -> i64 {
        self.replicas.iter().map(|r| r.best_energy).min().unwrap_or(i64::MAX)
    }

    /// Success estimate against a target energy.
    pub fn successes(&self, target: i64) -> crate::tts::SuccessEstimate {
        crate::tts::SuccessEstimate {
            runs: self.replicas.len(),
            successes: self.replicas.iter().filter(|r| r.best_energy <= target).count(),
        }
    }

    /// Mean per-replica wall time in seconds (the `t_a` of Eq. 32).
    pub fn mean_replica_seconds(&self) -> f64 {
        if self.replicas.is_empty() {
            return 0.0;
        }
        self.replicas.iter().map(|r| r.wall.as_secs_f64()).sum::<f64>() / self.replicas.len() as f64
    }
}

/// Lifecycle of a submitted job.
///
/// Legal transitions (pinned by `rust/tests/properties.rs`):
/// `Queued → Running → {Done, Failed, Cancelled, TimedOut}`, plus the
/// pre-dispatch shortcut `Queued → {Cancelled, TimedOut}` for jobs
/// preempted while still in the admission queue. Terminal states never
/// change again.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed(String),
    /// Preempted by `Coordinator::cancel` / protocol `CANCEL`, or by a
    /// graceful shutdown after `shutdown_grace_ms`. A partial
    /// [`JobResult`] (`completed == false`) is still published.
    Cancelled,
    /// Preempted by the job's own `budget_ms` deadline; partial
    /// [`JobResult`] published like [`JobState::Cancelled`].
    TimedOut,
}

impl JobState {
    /// Terminal states never transition again.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}
