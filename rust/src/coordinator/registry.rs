//! Content-addressed instance registry (wire verbs `PUT` / `REGISTRY`
//! / `SOLVE model=`, docs/PROTOCOL.md).
//!
//! Every inline `SOLVE` re-ships and re-materializes its full O(N²)
//! coupling matrix; the registry is the reuse path: a model is uploaded
//! once, stored under its canonical content hash
//! ([`IsingModel::content_digest`]), and every job referencing the hash
//! shares **one** `Arc<IsingModel>` — the copy-on-write contract the
//! whole dispatch tier leans on (no job ever mutates a model; derived
//! views like the CSR adjacency are built from the shared matrix).
//!
//! Entries are refcount-pinned while any in-flight job references them
//! and evicted least-recently-used when the store exceeds its byte
//! capacity; eviction never removes a pinned entry (pinned by the
//! registry property tests in `rust/tests/properties.rs`).
//!
//! Concurrency: one `Mutex` over the whole store. `PUT`/lookup are
//! O(1) hash-map operations plus (on insert) a hash of the body — the
//! store is never on the per-step hot path, so a single lock is the
//! simple correct choice and keeps this module free of atomics (see
//! the unsafe/atomics policy in docs/ARCHITECTURE.md).

use super::Metrics;
use crate::ising::{IsingModel, Tier};
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

/// Default byte capacity of a registry: 256 MiB of dense couplings
/// (an N=8192 all-to-all instance is 256 MiB; typical instances are
/// far smaller).
pub const DEFAULT_CAPACITY_BYTES: usize = 256 << 20;

/// Default per-model `PUT` size limit: 64 MiB (N=4096 all-to-all).
pub const DEFAULT_MAX_MODEL_BYTES: usize = 64 << 20;

/// Canonical content hash of an [`IsingModel`]: 128 bits, rendered as
/// exactly 32 lowercase hex chars on the wire (`STORED model=<hash>`).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelHash(u128);

impl ModelHash {
    /// The hash the registry would store `m` under.
    pub fn of_model(m: &IsingModel) -> Self {
        ModelHash(m.content_digest())
    }

    /// Wire form: 32 lowercase hex chars.
    pub fn to_hex(self) -> String {
        format!("{:032x}", self.0)
    }

    /// Parse the wire form; rejects anything that is not exactly 32 hex
    /// chars (the error text is the `ERR` body, see docs/PROTOCOL.md).
    pub fn parse(s: &str) -> Result<Self, String> {
        if s.len() != 32 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return Err(format!("malformed model hash '{s}' (expect 32 hex chars)"));
        }
        u128::from_str_radix(s, 16).map(ModelHash).map_err(|e| e.to_string())
    }
}

impl fmt::Display for ModelHash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

impl fmt::Debug for ModelHash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ModelHash({:032x})", self.0)
    }
}

/// Why a `PUT` was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PutError {
    /// The body exceeds the registry's per-model limit. The wire layer
    /// checks `IsingModel::approx_bytes_for(n)` against the same limit
    /// before allocating, so an oversized `PUT` never materializes.
    TooLarge { bytes: usize, max: usize },
}

impl fmt::Display for PutError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PutError::TooLarge { bytes, max } => {
                write!(f, "model too large: {bytes} bytes exceeds max_model_bytes {max}")
            }
        }
    }
}

impl std::error::Error for PutError {}

/// A consistent snapshot of the store (`REGISTRY` wire reply).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    /// Distinct models currently stored.
    pub entries: usize,
    /// Bytes those models materialize ([`IsingModel::approx_bytes`]).
    pub bytes: usize,
    /// Entries pinned by at least one in-flight job.
    pub pinned: usize,
    /// Lookups (`get`/`checkout`) that found their hash.
    pub hits: u64,
    /// Lookups that did not.
    pub misses: u64,
    /// Entries evicted by the LRU capacity sweep.
    pub evictions: u64,
    /// `PUT`s deduplicated against an existing entry.
    pub dedup: u64,
}

struct Entry {
    model: Arc<IsingModel>,
    bytes: usize,
    /// In-flight jobs referencing this entry; eviction skips pins > 0.
    pins: u64,
    /// LRU clock stamp of the last put/get/checkout.
    last_used: u64,
}

#[derive(Default)]
struct RegInner {
    map: HashMap<ModelHash, Entry>,
    bytes: usize,
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    dedup: u64,
}

/// The content-addressed model store. Shared `Arc<Registry>` between
/// the service front door (checkout at `SOLVE model=`), the router
/// (locality placement + re-dispatch pins) and every coordinator
/// worker (unpin at job completion).
pub struct Registry {
    capacity_bytes: usize,
    max_model_bytes: usize,
    inner: Mutex<RegInner>,
    /// Metrics sink for `registry_hits`/`registry_misses` counters and
    /// the `registry_bytes`/`registry_entries` gauges. First writer
    /// wins: a standalone coordinator attaches its own metrics only
    /// when it created the registry itself; under a router the router
    /// attaches first and the workers leave it alone.
    metrics: Mutex<Option<Arc<Metrics>>>,
}

impl Registry {
    /// A registry with explicit capacity and per-model limits (bytes).
    pub fn new(capacity_bytes: usize, max_model_bytes: usize) -> Self {
        Self {
            capacity_bytes,
            max_model_bytes,
            inner: Mutex::new(RegInner::default()),
            metrics: Mutex::new(None),
        }
    }

    /// A registry with the default limits.
    pub fn with_defaults() -> Self {
        Self::new(DEFAULT_CAPACITY_BYTES, DEFAULT_MAX_MODEL_BYTES)
    }

    /// The per-model `PUT` limit (what the wire layer pre-checks).
    pub fn max_model_bytes(&self) -> usize {
        self.max_model_bytes
    }

    /// Route hit/miss counters and occupancy gauges into `m`. No-op if
    /// a sink is already attached (first writer wins).
    pub fn attach_metrics(&self, m: Arc<Metrics>) {
        let mut slot = self.metrics.lock().unwrap();
        if slot.is_none() {
            *slot = Some(m);
        }
    }

    /// Store `model`, returning its content hash. A body already
    /// present is deduplicated (one entry, `dedup` counted); a new body
    /// LRU-evicts unpinned entries while the store exceeds capacity.
    /// Bodies over `max_model_bytes` are refused.
    pub fn put(&self, model: IsingModel) -> Result<ModelHash, PutError> {
        let bytes = model.approx_bytes();
        if bytes > self.max_model_bytes {
            return Err(PutError::TooLarge { bytes, max: self.max_model_bytes });
        }
        let hash = ModelHash::of_model(&model);
        let m = self.metrics.lock().unwrap().clone();
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        if let Some(e) = inner.map.get_mut(&hash) {
            e.last_used = clock;
            inner.dedup += 1;
        } else {
            inner.map.insert(
                hash,
                Entry { model: Arc::new(model), bytes, pins: 0, last_used: clock },
            );
            inner.bytes += bytes;
            self.evict_locked(&mut inner, hash);
        }
        self.publish(&m, &inner);
        Ok(hash)
    }

    /// Evict least-recently-used *unpinned* entries (never `keep`)
    /// until the store fits its capacity or nothing more is evictable.
    fn evict_locked(&self, inner: &mut RegInner, keep: ModelHash) {
        while inner.bytes > self.capacity_bytes {
            let victim = inner
                .map
                .iter()
                .filter(|(h, e)| e.pins == 0 && **h != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(h, _)| *h);
            match victim {
                Some(h) => {
                    let e = inner.map.remove(&h).expect("victim came from the map");
                    inner.bytes -= e.bytes;
                    inner.evictions += 1;
                }
                None => break, // everything left is pinned
            }
        }
    }

    /// Look up a model without pinning it.
    pub fn get(&self, hash: ModelHash) -> Option<Arc<IsingModel>> {
        self.lookup(hash, false)
    }

    /// Look up a model **and pin it** in one atomic step — the caller
    /// owns one pin and must balance it with [`Self::unpin`] (the
    /// coordinator does so when the job reaches a terminal state).
    /// Checking out before submitting is what makes eviction safe: a
    /// hash can never be evicted between lookup and job registration.
    pub fn checkout(&self, hash: ModelHash) -> Option<Arc<IsingModel>> {
        self.lookup(hash, true)
    }

    fn lookup(&self, hash: ModelHash, pin: bool) -> Option<Arc<IsingModel>> {
        let m = self.metrics.lock().unwrap().clone();
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        let found = match inner.map.get_mut(&hash) {
            Some(e) => {
                e.last_used = clock;
                if pin {
                    e.pins += 1;
                }
                Some(e.model.clone())
            }
            None => None,
        };
        if found.is_some() {
            inner.hits += 1;
            if let Some(m) = &m {
                m.inc("registry_hits");
            }
        } else {
            inner.misses += 1;
            if let Some(m) = &m {
                m.inc("registry_misses");
            }
        }
        self.publish(&m, &inner);
        found
    }

    /// Add one pin to an existing entry (router re-dispatch path).
    /// Returns false if the hash is not stored.
    pub fn pin(&self, hash: ModelHash) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get_mut(&hash) {
            Some(e) => {
                e.pins += 1;
                true
            }
            None => false,
        }
    }

    /// Release one pin. Saturating: unpinning an unpinned (or absent)
    /// hash is a no-op — the refcount can never go negative.
    pub fn unpin(&self, hash: ModelHash) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.map.get_mut(&hash) {
            e.pins = e.pins.saturating_sub(1);
        }
    }

    /// Whether `hash` is currently stored.
    pub fn contains(&self, hash: ModelHash) -> bool {
        self.inner.lock().unwrap().map.contains_key(&hash)
    }

    /// Consistent snapshot of the store.
    pub fn stats(&self) -> RegistryStats {
        let inner = self.inner.lock().unwrap();
        RegistryStats {
            entries: inner.map.len(),
            bytes: inner.bytes,
            pinned: inner.map.values().filter(|e| e.pins > 0).count(),
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            dedup: inner.dedup,
        }
    }

    fn publish(&self, m: &Option<Arc<Metrics>>, inner: &RegInner) {
        if let Some(m) = m {
            m.gauge_set("registry_bytes", inner.bytes as i64);
            m.gauge_set("registry_entries", inner.map.len() as i64);
            // Occupancy by coupling-storage tier: how much of the store
            // the precision packing is actually saving (an i8 entry
            // materializes 4× fewer coupling bytes than its i32 form).
            // O(entries) per publish, and the registry is never on the
            // per-step hot path.
            let mut by_tier = [0usize; 3];
            for e in inner.map.values() {
                let slot = match e.model.tier() {
                    Tier::I8 => 0,
                    Tier::I16 => 1,
                    Tier::I32 => 2,
                };
                by_tier[slot] += e.bytes;
            }
            m.gauge_set("coupling_bytes_i8", by_tier[0] as i64);
            m.gauge_set("coupling_bytes_i16", by_tier[1] as i64);
            m.gauge_set("coupling_bytes_i32", by_tier[2] as i64);
        }
    }
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.stats();
        f.debug_struct("Registry")
            .field("capacity_bytes", &self.capacity_bytes)
            .field("max_model_bytes", &self.max_model_bytes)
            .field("entries", &s.entries)
            .field("bytes", &s.bytes)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(n: usize, j01: i32) -> IsingModel {
        let mut m = IsingModel::zeros(n);
        m.set_j(0, 1, j01);
        m
    }

    #[test]
    fn hash_wire_roundtrip_and_malformed_forms() {
        let h = ModelHash::of_model(&model(4, 2));
        let hex = h.to_hex();
        assert_eq!(hex.len(), 32);
        assert_eq!(ModelHash::parse(&hex).unwrap(), h);
        let nonhex = "g".repeat(32);
        for bad in ["", "deadbeef", nonhex.as_str(), &hex[..31]] {
            let err = ModelHash::parse(bad).unwrap_err();
            assert_eq!(err, format!("malformed model hash '{bad}' (expect 32 hex chars)"));
        }
    }

    #[test]
    fn put_dedupes_and_checkout_shares_one_arc() {
        let reg = Registry::with_defaults();
        let h1 = reg.put(model(8, 3)).unwrap();
        let h2 = reg.put(model(8, 3)).unwrap();
        assert_eq!(h1, h2);
        let s = reg.stats();
        assert_eq!((s.entries, s.dedup), (1, 1));
        // Accounted at the packed footprint (±3 couplings pack as i8),
        // not the conservative i32 worst case.
        assert_eq!(s.bytes, model(8, 3).approx_bytes());
        assert!(s.bytes < IsingModel::approx_bytes_for(8));
        let a = reg.checkout(h1).unwrap();
        let b = reg.checkout(h1).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "checkout must share one instance");
        assert_eq!(reg.stats().pinned, 1);
        assert_eq!(reg.stats().hits, 2);
        reg.unpin(h1);
        reg.unpin(h1);
        reg.unpin(h1); // saturates at zero
        assert_eq!(reg.stats().pinned, 0);
    }

    #[test]
    fn oversized_put_is_refused() {
        // The limit is checked against the PACKED footprint: an 8-spin
        // i8 model fits a max sized exactly to it, a 9-spin one does
        // not (the wire layer's pre-allocation check still uses the
        // conservative i32 bound, `approx_bytes_for`).
        let max = model(8, 1).approx_bytes();
        let reg = Registry::new(1 << 20, max);
        assert!(reg.put(model(8, 1)).is_ok());
        let bytes = model(9, 1).approx_bytes();
        assert!(bytes > max);
        let err = reg.put(model(9, 1)).unwrap_err();
        assert_eq!(err, PutError::TooLarge { bytes, max });
        assert_eq!(
            err.to_string(),
            format!("model too large: {bytes} bytes exceeds max_model_bytes {max}")
        );
        // Widening the same instance to i32 quadruples the coupling
        // footprint past the limit — the tier, not just N, decides.
        let mut wide = model(8, 1);
        wide.force_tier(crate::ising::Tier::I32);
        assert_eq!(reg.put(wide).unwrap_err(), PutError::TooLarge {
            bytes: IsingModel::approx_bytes_for(8),
            max,
        });
    }

    #[test]
    fn lru_eviction_skips_pins_and_the_incoming_entry() {
        // Capacity fits exactly two 8-spin models (packed footprint —
        // every model(8, _) here has i8 couplings, so they all weigh
        // the same).
        let per = model(8, 1).approx_bytes();
        let reg = Registry::new(2 * per, per);
        let h1 = reg.put(model(8, 1)).unwrap();
        let h2 = reg.put(model(8, 2)).unwrap();
        // Touch h1 so h2 is the LRU entry, then insert a third.
        assert!(reg.get(h1).is_some());
        let h3 = reg.put(model(8, 3)).unwrap();
        assert!(reg.contains(h1) && reg.contains(h3));
        assert!(!reg.contains(h2), "LRU entry should be evicted");
        assert_eq!(reg.stats().evictions, 1);
        // Pin both survivors: the next insert cannot evict either, so
        // the store is allowed to exceed capacity rather than drop a
        // pinned model out from under an in-flight job.
        assert!(reg.checkout(h1).is_some() && reg.checkout(h3).is_some());
        let h4 = reg.put(model(8, 4)).unwrap();
        assert!(reg.contains(h1) && reg.contains(h3) && reg.contains(h4));
        assert_eq!(reg.stats().bytes, 3 * per);
        // Unpinning makes them evictable again.
        reg.unpin(h1);
        reg.unpin(h3);
        let h5 = reg.put(model(8, 5)).unwrap();
        assert!(reg.contains(h5));
        assert_eq!(reg.stats().bytes, 2 * per);
    }

    /// The per-tier occupancy gauges track inserts AND evictions, so
    /// operators can read how much the precision packing saves.
    #[test]
    fn tier_gauges_track_store_contents() {
        use crate::coordinator::Metrics;
        let narrow = model(8, 3); // i8
        let mid = model(8, 1_000); // i16
        let wide = model(8, 100_000); // i32
        let per = narrow.approx_bytes();
        // Capacity sized so the i32 insert must evict both smaller
        // entries (they are LRU and unpinned).
        let reg = Registry::new(wide.approx_bytes(), wide.approx_bytes());
        let metrics = Arc::new(Metrics::new());
        reg.attach_metrics(metrics.clone());
        reg.put(narrow).unwrap();
        reg.put(mid.clone()).unwrap();
        assert_eq!(metrics.gauge("coupling_bytes_i8"), per as i64);
        assert_eq!(metrics.gauge("coupling_bytes_i16"), mid.approx_bytes() as i64);
        assert_eq!(metrics.gauge("coupling_bytes_i32"), 0);
        let h = reg.put(wide.clone()).unwrap();
        assert!(reg.contains(h));
        assert_eq!(metrics.gauge("coupling_bytes_i8"), 0, "i8 entry evicted");
        assert_eq!(metrics.gauge("coupling_bytes_i16"), 0, "i16 entry evicted");
        assert_eq!(metrics.gauge("coupling_bytes_i32"), wide.approx_bytes() as i64);
        assert_eq!(metrics.gauge("registry_bytes"), wide.approx_bytes() as i64);
    }

    #[test]
    fn miss_counters_and_pin_of_absent_hash() {
        let reg = Registry::with_defaults();
        let absent = ModelHash::of_model(&model(4, 9));
        assert!(reg.get(absent).is_none());
        assert!(reg.checkout(absent).is_none());
        assert!(!reg.pin(absent));
        reg.unpin(absent); // no-op
        let s = reg.stats();
        assert_eq!((s.hits, s.misses), (0, 2));
    }
}
