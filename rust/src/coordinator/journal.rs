//! Per-job fault-tolerance state: the checkpoint journal and the
//! control block ([`JobCtl`]) the coordinator threads through the
//! scheduler into every replica.
//!
//! Replicas running the single-lane engine snapshot an
//! [`EngineCheckpoint`] into their job's [`JobJournal`] every
//! checkpoint stride. When a replica panics (a real fault or an
//! injected one — see [`crate::failpoint`]) and the job allows
//! retries, the scheduler re-runs the replica **resuming from the last
//! journaled checkpoint**; because the engine's RNG is stateless
//! (addressed by `(seed, step, salt)`, never by call order) the
//! resumed run is bit-identical to an uninterrupted one — pinned by
//! `checkpoint_resume_is_bit_identical` in the engine and the
//! chaos-suite determinism test.
//!
//! Everything here is in-memory and job-scoped: the journal dies with
//! the job, which is exactly the durability the retry path needs (a
//! coordinator crash loses the jobs themselves anyway).

use crate::engine::EngineCheckpoint;
use crate::stop::StopToken;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// In-memory checkpoint store for one job: the latest
/// [`EngineCheckpoint`] per replica, plus the retry count the metrics
/// report as `jobs_retried`.
#[derive(Default)]
pub struct JobJournal {
    slots: Mutex<HashMap<u32, EngineCheckpoint>>,
    retries: Mutex<u64>,
}

impl JobJournal {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `ck` as replica `replica`'s latest checkpoint (replacing
    /// any earlier one — retries only ever resume from the newest).
    pub fn record(&self, replica: u32, ck: EngineCheckpoint) {
        self.slots.lock().unwrap().insert(replica, ck);
    }

    /// The replica's latest checkpoint, if it ever recorded one.
    pub fn checkpoint(&self, replica: u32) -> Option<EngineCheckpoint> {
        self.slots.lock().unwrap().get(&replica).cloned()
    }

    /// Count one replica retry (any replica; the metric is per job).
    pub fn note_retry(&self) {
        *self.retries.lock().unwrap() += 1;
    }

    /// Total replica retries this job performed.
    pub fn retries(&self) -> u64 {
        *self.retries.lock().unwrap()
    }
}

/// The per-job control block: one stop token (cancel / deadline /
/// shutdown all trip it), one checkpoint journal, and the job's retry
/// and deadline policy. Cheap to clone — everything shared is behind
/// an `Arc`.
#[derive(Clone)]
pub struct JobCtl {
    /// The job's shared preemption signal.
    pub stop: Arc<StopToken>,
    /// The job's checkpoint journal (retry resume source).
    pub journal: Arc<JobJournal>,
    /// Panicking replicas are re-run up to this many times.
    pub max_retries: u32,
    /// Journal checkpoints even when `max_retries == 0`. Router-managed
    /// jobs set this: the dispatch tier shares one journal across
    /// placements, so a job re-dispatched off a dead worker resumes
    /// from its last checkpoint instead of step 0
    /// (`coordinator::router`).
    pub checkpoint: bool,
    /// Absolute deadline derived from `JobSpec.budget_ms` at submit
    /// time (`None` = no budget). The wheel trips `stop` at this
    /// instant; the terminal path measures `deadline_slack_us` from it.
    pub deadline: Option<Instant>,
}

impl JobCtl {
    /// A control block for callers outside the coordinator lifecycle
    /// (direct scheduler users, benches, tests): never preempted,
    /// never retried, journal unused.
    pub fn unmanaged() -> Self {
        Self {
            stop: Arc::new(StopToken::new()),
            journal: Arc::new(JobJournal::new()),
            max_retries: 0,
            checkpoint: false,
            deadline: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::SpinVec;

    fn ck(step: u64) -> EngineCheckpoint {
        EngineCheckpoint {
            seed: 7,
            step,
            spins: SpinVec::all_up(4),
            energy: -1,
            best_energy: -2,
            best_step: 1,
            best_spins: SpinVec::all_up(4),
            flips: 3,
            fallbacks: 0,
            nulls: 0,
        }
    }

    #[test]
    fn journal_keeps_latest_checkpoint_per_replica() {
        let j = JobJournal::new();
        assert!(j.checkpoint(0).is_none());
        j.record(0, ck(100));
        j.record(1, ck(200));
        j.record(0, ck(300)); // replaces the step-100 snapshot
        assert_eq!(j.checkpoint(0).unwrap().step, 300);
        assert_eq!(j.checkpoint(1).unwrap().step, 200);
        assert!(j.checkpoint(2).is_none());
        assert_eq!(j.retries(), 0);
        j.note_retry();
        j.note_retry();
        assert_eq!(j.retries(), 2);
    }

    #[test]
    fn unmanaged_ctl_is_inert() {
        let ctl = JobCtl::unmanaged();
        assert!(ctl.stop.get().is_none());
        assert_eq!(ctl.max_retries, 0);
        assert!(ctl.deadline.is_none());
    }
}
